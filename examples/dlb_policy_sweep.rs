//! DLB policy sweep: the paper's closing discussion made executable.
//!
//! "In practice one must weigh partitioning time, migration cost and
//! solver time together" (§4). This example sweeps the imbalance
//! trigger lambda for one method and prints the resulting trade-off:
//! a low trigger repartitions constantly (ParMETIS-style quality
//! chasing -- more DLB time, best balance), a high trigger tolerates
//! skew (less DLB, worse solve balance). The sweet spot depends on how
//! expensive the method's partition+migration is -- which is exactly
//! why the paper pairs cheap incremental partitioners with moderate
//! triggers.
//!
//! ```sh
//! cargo run --release --example dlb_policy_sweep [method]
//! ```

use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::fem::SolverOpts;
use phg_dlb::mesh::generator;

fn main() {
    let method = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "PHG/HSFC".to_string());
    let triggers = [1.02, 1.05, 1.1, 1.2, 1.5, 2.5];

    println!("== DLB policy sweep: method {method}, parabolic moving peak, p = 32 ==\n");
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "trigger", "repartitions", "DLB total(s)", "mean lambda", "STP mean(s)", "TAL(s)"
    );

    let mut rows: Vec<(f64, usize, f64, f64, f64, f64)> = Vec::new();
    for &trigger in &triggers {
        let cfg = DriverConfig {
            nparts: 32,
            method: method.clone(),
            lambda_trigger: trigger,
            theta_refine: 0.45,
            theta_coarsen: 0.04,
            max_elements: 30_000,
            solver: SolverOpts {
                tol: 1e-5,
                max_iter: 600,
            },
            use_pjrt: true,
            nsteps: 12,
            dt: 1.0 / 512.0,
        };
        let mut d = AdaptiveDriver::new(generator::cube_mesh(4), cfg);
        d.run_parabolic(0.0);
        let reps = d.timeline.repartition_count();
        let dlb: f64 = d.timeline.records.iter().map(|r| r.dlb_time()).sum();
        let mean_lambda: f64 = d
            .timeline
            .records
            .iter()
            .map(|r| r.imbalance_after)
            .sum::<f64>()
            / d.timeline.records.len() as f64;
        let (tal, _, _, stp) = d.timeline.table_columns();
        println!(
            "{:>8.2} {:>12} {:>12.4} {:>12.3} {:>12.4} {:>10.3}",
            trigger, reps, dlb, mean_lambda, stp, tal
        );
        rows.push((trigger, reps, dlb, mean_lambda, stp, tal));
    }

    // the qualitative law the paper states: tighter triggers buy
    // balance with DLB time
    let first = &rows[0];
    let last = &rows[rows.len() - 1];
    assert!(
        first.1 >= last.1,
        "low trigger should repartition at least as often"
    );
    assert!(
        first.3 <= last.3 + 0.35,
        "low trigger should hold lambda lower on average"
    );
    println!(
        "\ntrade-off confirmed: trigger {:.2} -> {} repartitions, mean lambda {:.3}; \
         trigger {:.2} -> {} repartitions, mean lambda {:.3}",
        first.0, first.1, first.3, last.0, last.1, last.3
    );
}
