//! DLB policy sweep: the paper's closing discussion made executable.
//!
//! "In practice one must weigh partitioning time, migration cost and
//! solver time together" (§4). This example sweeps the *trigger
//! policies* and *element weight models* of the `dlb` subsystem for
//! one method on the parabolic moving-peak scenario and prints the
//! resulting trade-off: always-repartitioning buys perfect balance
//! with DLB time every step; a lambda threshold tolerates bounded
//! skew; a fixed cadence ignores lambda entirely; the cost/benefit
//! policy pays for a rebalance only when the modeled
//! partition+remap+migration cost is beaten by the modeled solve time
//! it recovers -- and therefore lands the lowest modeled total time.
//!
//! ```sh
//! cargo run --release --example dlb_policy_sweep [method]
//! ```

use phg_dlb::coordinator::report::format_rebalance_table;
use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::dlb::RebalanceReport;
use phg_dlb::fem::SolverOpts;
use phg_dlb::mesh::generator;

struct SweepRow {
    trigger: String,
    weights: String,
    repartitions: usize,
    dlb_total: f64,
    mean_lambda: f64,
    tal: f64,
    last_report: Option<RebalanceReport>,
}

fn run_policy(method: &str, trigger: &str, weights: &str) -> SweepRow {
    let cfg = DriverConfig {
        problem: "parabolic".to_string(),
        nparts: 32,
        method: method.to_string(),
        trigger: trigger.to_string(),
        weights: weights.to_string(),
        strategy: "scratch".to_string(),
        exec: "virtual".to_string(),
        exec_threads: 0,
        lambda_trigger: 1.2,
        theta_refine: 0.45,
        theta_coarsen: 0.04,
        max_elements: 30_000,
        solver: SolverOpts {
            tol: 1e-5,
            max_iter: 600,
        },
        use_pjrt: cfg!(feature = "pjrt"),
        nsteps: 12,
        dt: 1.0 / 512.0,
    };
    let mut d = AdaptiveDriver::new(generator::cube_mesh(4), cfg).expect("valid policy specs");
    d.run();
    let n = d.timeline.records.len() as f64;
    let mean_lambda = d
        .timeline
        .records
        .iter()
        .map(|r| r.solve_imbalance)
        .sum::<f64>()
        / n;
    let (tal, _, _, _) = d.timeline.table_columns();
    SweepRow {
        trigger: trigger.to_string(),
        weights: weights.to_string(),
        repartitions: d.timeline.repartition_count(),
        dlb_total: d.timeline.records.iter().map(|r| r.dlb_time()).sum(),
        mean_lambda,
        tal,
        last_report: d
            .timeline
            .records
            .iter()
            .rev()
            .find_map(|r| r.rebalance.clone()),
    }
}

fn main() {
    let method = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "PHG/HSFC".to_string());
    let triggers = ["always", "lambda:1.05", "lambda:1.2", "every:4", "costbenefit:2"];
    let weight_models = ["unit", "dof", "measured"];

    println!("== DLB policy sweep: method {method}, parabolic moving peak, p = 32 ==\n");
    println!(
        "{:<16} {:<10} {:>12} {:>12} {:>12} {:>12}",
        "trigger", "weights", "repartitions", "DLB total(s)", "mean lambda", "TAL(s)"
    );

    let mut rows: Vec<SweepRow> = Vec::new();
    for trigger in triggers {
        for weights in weight_models {
            let row = run_policy(&method, trigger, weights);
            println!(
                "{:<16} {:<10} {:>12} {:>12.4} {:>12.3} {:>12.4}",
                row.trigger, row.weights, row.repartitions, row.dlb_total, row.mean_lambda, row.tal
            );
            rows.push(row);
        }
    }

    // per-policy RebalanceReport of the final rebalance (unit weights)
    println!("\nlast rebalance per trigger policy (unit weights):");
    let report_rows: Vec<(String, RebalanceReport)> = rows
        .iter()
        .filter(|r| r.weights == "unit")
        .filter_map(|r| r.last_report.clone().map(|rep| (r.trigger.clone(), rep)))
        .collect();
    print!("{}", format_rebalance_table(&report_rows));

    let get = |trigger: &str, weights: &str| {
        rows.iter()
            .find(|r| r.trigger == trigger && r.weights == weights)
            .unwrap()
    };

    // the qualitative law of the paper's discussion: tighter triggers
    // buy balance with DLB time
    let always = get("always", "unit");
    let loose = get("lambda:1.2", "unit");
    assert!(
        always.repartitions >= loose.repartitions,
        "always-repartitioning should repartition at least as often ({} vs {})",
        always.repartitions,
        loose.repartitions
    );
    assert!(
        always.mean_lambda <= loose.mean_lambda + 0.35,
        "always-repartitioning should hold lambda lower on average"
    );
    assert_eq!(
        always.repartitions, 12,
        "the always policy must fire every step"
    );

    // the new quantitative law: paying for a rebalance only when the
    // modeled saving beats the modeled cost yields a lower modeled
    // total time than repartitioning unconditionally
    let cb = get("costbenefit:2", "unit");
    assert!(
        cb.tal < always.tal,
        "cost/benefit TAL {:.4}s should beat always-repartitioning TAL {:.4}s",
        cb.tal,
        always.tal
    );
    println!(
        "\ncost/benefit vs always: TAL {:.4}s vs {:.4}s with {} vs {} repartitions",
        cb.tal, always.tal, cb.repartitions, always.repartitions
    );
    println!("trade-off confirmed: the trigger policy, not just the method, sets the bill");
}
