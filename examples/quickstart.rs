//! Quickstart: the library in ~60 lines.
//!
//! Build a mesh, refine it adaptively, partition it with every method
//! the paper compares, and print the quality metrics -- then run three
//! adaptive FEM steps with dynamic load balancing.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::dist::Distribution;
use phg_dlb::dlb::Registry;
use phg_dlb::mesh::generator;
use phg_dlb::mesh::topology::LeafTopology;
use phg_dlb::partition::{metrics, PartitionInput};
use phg_dlb::util::timer::Stopwatch;

fn main() {
    // 1. A mesh: the paper's long cylinder, locally refined at one end
    //    to create realistic imbalance.
    let mut mesh = generator::omega1_cylinder(3);
    for _ in 0..2 {
        let marked: Vec<_> = mesh
            .leaves_unordered()
            .into_iter()
            .filter(|&id| mesh.centroid(id).x < 2.0)
            .collect();
        mesh.refine(&marked);
    }
    println!("mesh: {} tets, {} vertices\n", mesh.n_leaves(), mesh.n_vertices());

    // 2. Partition with every method; report speed and quality.
    let nparts = 16;
    let leaves = mesh.leaves_unordered();
    let weights = vec![1.0; leaves.len()];
    Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
    let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
    let topo = LeafTopology::build_for(&mesh, leaves.clone());

    println!(
        "{:<12} {:>9} {:>10} {:>12} {:>9}",
        "method", "time(ms)", "imbalance", "iface-faces", "surface%"
    );
    for name in Registry::paper_names() {
        let p = Registry::create(name).unwrap();
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, nparts);
        let sw = Stopwatch::start();
        let r = p.partition(&input);
        let q = metrics::quality(&topo, &r.parts, &weights, nparts);
        println!(
            "{:<12} {:>9.2} {:>10.4} {:>12} {:>9.2}",
            name,
            sw.elapsed() * 1e3,
            q.imbalance,
            q.interface_faces,
            100.0 * q.surface_index
        );
    }

    // 3. Three adaptive steps of the `helmholtz` scenario with DLB
    //    (RTK method); swap `problem` for any `phg-dlb methods` entry.
    println!("\nadaptive loop (helmholtz scenario, RTK, 8 virtual procs):");
    let cfg = DriverConfig {
        problem: "helmholtz".into(),
        nparts: 8,
        method: "RTK".into(),
        nsteps: 3,
        max_elements: 60_000,
        ..DriverConfig::default()
    };
    let mut driver = AdaptiveDriver::new(generator::cube_mesh(4), cfg).unwrap();
    driver.run();
    for r in &driver.timeline.records {
        println!(
            "step {}: {} tets, {} dofs, lambda {:.3} -> {:.3}{}, solve {:.1} ms ({} iters), L2 err {:.2e}",
            r.step,
            r.n_elements,
            r.n_dofs,
            r.imbalance_before,
            r.imbalance_after,
            if r.repartitioned { " [DLB]" } else { "" },
            r.total_solve_time() * 1e3,
            r.solve_iterations,
            r.l2_error
        );
    }
}
