//! Example 3.2 (scaled): time-dependent problem with a moving peak.
//!
//!   u_t - lap u = f  on (0,1)^3,  exact solution a narrow bump whose
//! center circles in the x-y plane near z = 1 (the paper's trajectory).
//! Every time step the mesh refines ahead of the peak and coarsens
//! behind it, so the load keeps shifting between the virtual processes
//! and the DLB machinery earns its keep.
//!
//! ```sh
//! cargo run --release --example parabolic_moving_peak [method] [nsteps]
//! ```

use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::fem::problems::peak_center;
use phg_dlb::fem::SolverOpts;
use phg_dlb::mesh::generator;
use phg_dlb::util::timer::Stopwatch;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let method = args
        .first()
        .cloned()
        .unwrap_or_else(|| "PHG/HSFC".to_string());
    let nsteps: usize = args.get(2 - 1).and_then(|s| s.parse().ok()).unwrap_or(20);

    let cfg = DriverConfig {
        problem: "parabolic".to_string(),
        nparts: 16,
        method: method.clone(),
        trigger: "lambda".to_string(),
        weights: "unit".to_string(),
        strategy: "auto".to_string(),
        exec: "virtual".to_string(),
        exec_threads: 0,
        lambda_trigger: 1.15,
        theta_refine: 0.45,
        theta_coarsen: 0.04,
        max_elements: 60_000,
        solver: SolverOpts {
            tol: 1e-5,
            max_iter: 800,
        },
        use_pjrt: cfg!(feature = "pjrt"),
        nsteps,
        dt: 1.0 / 512.0,
    };
    let mut driver = AdaptiveDriver::new(generator::cube_mesh(4), cfg.clone()).unwrap();
    if cfg!(feature = "pjrt") && driver.runtime.is_none() {
        eprintln!("WARNING: artifacts missing; using native engines (run `make artifacts`)");
    }

    println!(
        "{:>4} {:>7} {:>9} {:>8} {:>7} {:>5} {:>9} {:>9} {:>24}",
        "step", "time", "elements", "dofs", "lambda", "DLB", "solve(ms)", "maxerr", "peak center"
    );
    let sw = Stopwatch::start();
    for n in 1..=nsteps {
        let t = n as f64 * cfg.dt;
        driver.step();
        let r = driver.timeline.records.last().unwrap();
        let c = peak_center(t);
        println!(
            "{:>4} {:>7.4} {:>9} {:>8} {:>7.3} {:>5} {:>9.1} {:>9.2e}     ({:.2}, {:.2}, {:.2})",
            r.step,
            t,
            r.n_elements,
            r.n_dofs,
            r.imbalance_before,
            if r.repartitioned { "yes" } else { "-" },
            r.total_solve_time() * 1e3,
            r.max_error,
            c.x,
            c.y,
            c.z
        );
    }
    let wall = sw.elapsed();

    let (tal, dlb, sol, stp) = driver.timeline.table_columns();
    println!(
        "\nmethod {method}: wall {wall:.2}s | TAL {tal:.3} | DLB {dlb:.4} | SOL {sol:.4} | STP {stp:.4} | repartitions {}",
        driver.timeline.repartition_count()
    );

    // sanity: mesh tracked the peak (refined elements concentrate there)
    let t_final = nsteps as f64 * cfg.dt;
    let c = peak_center(t_final);
    let mesh = &driver.mesh;
    let mut near = 0usize;
    let mut near_fine = 0usize;
    for id in mesh.leaves_unordered() {
        if (mesh.centroid(id) - c).norm() < 0.3 {
            near += 1;
            if mesh.elem(id).generation > 0 {
                near_fine += 1;
            }
        }
    }
    println!(
        "mesh tracking: {near_fine}/{near} elements near the peak are refined"
    );
    assert!(driver.timeline.records.last().unwrap().max_error < 0.1);
    driver.mesh.check_invariants().unwrap();
    println!("parabolic run OK");
}
