//! END-TO-END VALIDATION DRIVER (example 3.1, scaled).
//!
//! The full system on a real workload: adaptive FEM solution of the
//! Helmholtz problem  -lap u + u = f  on the long cylinder Omega_1,
//! exact solution u = cos(2 pi x) cos(2 pi y) cos(2 pi z).
//!
//! Everything composes here: the cylinder mesher, bisection refinement
//! driven by the residual estimator, the RTK partitioner + Oliker-
//! Biswas remap + migration under the lambda-trigger DLB policy, P1
//! assembly batched through the Pallas `elem_tet` artifact, and the
//! Jacobi-PCG solve running one `cg_step` PJRT execute per iteration.
//!
//! Prints the per-step log (the "loss curve" equivalent: L2 error vs
//! DOFs, which must decrease) and the paper-format summary. Recorded
//! in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! make artifacts && cargo run --release --example helmholtz_cylinder [method] [nsteps]
//! ```

use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::fem::SolverOpts;
use phg_dlb::mesh::generator;
use phg_dlb::util::timer::Stopwatch;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let method = args.first().cloned().unwrap_or_else(|| "RTK".to_string());
    let nsteps: usize = args
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);

    let mesh = generator::omega1_cylinder(3);
    println!(
        "Omega_1 cylinder: {} tets, aspect ratio {:.1}",
        mesh.n_leaves(),
        mesh.bounding_box().aspect_ratio()
    );

    let cfg = DriverConfig {
        problem: "helmholtz".to_string(),
        nparts: 32,
        method: method.clone(),
        trigger: "lambda".to_string(),
        weights: "unit".to_string(),
        strategy: "scratch".to_string(),
        exec: "virtual".to_string(),
        exec_threads: 0,
        lambda_trigger: 1.15,
        theta_refine: 0.4,
        theta_coarsen: 0.0,
        max_elements: 150_000,
        solver: SolverOpts {
            tol: 1e-5,
            max_iter: 1500,
        },
        use_pjrt: cfg!(feature = "pjrt"),
        nsteps,
        dt: 0.0,
    };
    let mut driver = AdaptiveDriver::new(mesh, cfg).unwrap();
    if cfg!(feature = "pjrt") && driver.runtime.is_none() {
        eprintln!("WARNING: artifacts missing; using native engines (run `make artifacts`)");
    }

    println!(
        "\n{:>4} {:>9} {:>9} {:>7} {:>7} {:>5} {:>10} {:>6} {:>10} {:>10}",
        "step", "elements", "dofs", "lam_in", "lam_out", "DLB", "solve(ms)", "iters", "L2err", "maxerr"
    );
    let sw = Stopwatch::start();
    for _ in 0..nsteps {
        let more = driver.step();
        let r = driver.timeline.records.last().unwrap();
        println!(
            "{:>4} {:>9} {:>9} {:>7.3} {:>7.3} {:>5} {:>10.1} {:>6} {:>10.3e} {:>10.3e}",
            r.step,
            r.n_elements,
            r.n_dofs,
            r.imbalance_before,
            r.imbalance_after,
            if r.repartitioned { "yes" } else { "-" },
            r.total_solve_time() * 1e3,
            r.solve_iterations,
            r.l2_error,
            r.max_error
        );
        if !more {
            break;
        }
    }
    let wall = sw.elapsed();

    let (tal, dlb, sol, stp) = driver.timeline.table_columns();
    println!("\nmethod {method}: wall {wall:.2}s");
    println!(
        "TAL {tal:.3}s | mean DLB {:.4}s | mean SOL {:.4}s | mean STP {:.4}s | repartitions {}",
        dlb,
        sol,
        stp,
        driver.timeline.repartition_count()
    );

    // convergence check: the error-vs-dofs curve must trend down
    let errs: Vec<(usize, f64)> = driver
        .timeline
        .records
        .iter()
        .map(|r| (r.n_dofs, r.l2_error))
        .collect();
    let first = errs.first().unwrap();
    let last = errs.last().unwrap();
    println!(
        "\nerror curve: {} dofs @ L2 {:.3e}  ->  {} dofs @ L2 {:.3e}",
        first.0, first.1, last.0, last.1
    );
    assert!(
        last.1 < first.1,
        "adaptive refinement failed to reduce the L2 error"
    );
    println!("E2E VALIDATION OK: error decreased under adaptive refinement with DLB");

    let csv = driver.timeline.to_csv();
    if let Ok(p) = phg_dlb::coordinator::report::write_report(
        &format!("helmholtz_cylinder_{}.csv", method.replace('/', "_")),
        &csv,
    ) {
        println!("timeline csv: {}", p.display());
    }
}
