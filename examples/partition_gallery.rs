//! Partition gallery: write VTK files of the cylinder partitioned by
//! every method, for visual inspection in ParaView -- the qualitative
//! counterpart of the paper's quality tables. Also dumps the Hilbert
//! curve order as cell data so the SFC locality is visible.
//!
//! ```sh
//! cargo run --release --example partition_gallery   # writes out/*.vtk
//! ```

use phg_dlb::dist::Distribution;
use phg_dlb::dlb::Registry;
use phg_dlb::mesh::generator;
use phg_dlb::mesh::io::write_vtk;
use phg_dlb::partition::sfc::{sfc_keys, Curve, Normalization};
use phg_dlb::partition::PartitionInput;
use std::path::Path;

fn main() {
    let mut mesh = generator::omega1_cylinder(3);
    // refine one end so partitions must adapt to non-uniform density
    let marked: Vec<_> = mesh
        .leaves_unordered()
        .into_iter()
        .filter(|&id| mesh.centroid(id).x < 2.0)
        .collect();
    mesh.refine(&marked);

    let nparts = 12;
    let leaves = mesh.leaves_unordered();
    let weights = vec![1.0; leaves.len()];
    Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
    let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();

    std::fs::create_dir_all("out").unwrap();
    for name in Registry::names() {
        let p = Registry::create(name).unwrap();
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, nparts);
        let r = p.partition(&input);
        let data: Vec<f64> = r.parts.iter().map(|&x| x as f64).collect();
        let fname = format!("out/partition_{}.vtk", name.replace('/', "_"));
        write_vtk(&mesh, &data, "part", Path::new(&fname)).unwrap();
        println!("wrote {fname}");
    }

    // hilbert curve position as cell data (both normalizations)
    for (norm, tag) in [
        (Normalization::AspectPreserving, "aspect"),
        (Normalization::PerAxis, "peraxis"),
    ] {
        let keys = sfc_keys(&mesh, &leaves, Curve::Hilbert, norm);
        let mut order: Vec<usize> = (0..keys.len()).collect();
        order.sort_by_key(|&i| keys[i]);
        let mut pos = vec![0.0f64; keys.len()];
        for (rank, &i) in order.iter().enumerate() {
            pos[i] = rank as f64 / keys.len() as f64;
        }
        let fname = format!("out/hilbert_order_{tag}.vtk");
        write_vtk(&mesh, &pos, "curve_pos", Path::new(&fname)).unwrap();
        println!("wrote {fname}");
    }
    println!("open the files in ParaView and color by the cell scalar");
}
