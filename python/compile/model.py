"""L2: the FEM compute graph, in JAX, calling the L1 Pallas kernels.

Two entry points, both AOT-lowered by aot.py and executed from Rust:

  * assemble_batch -- batched P1 tet element matrices (elem_tet kernel).
    Rust gathers element coordinates into fixed-size batches, runs the
    executable, and scatter-adds the 4x4 blocks into its CSR/ELL matrix.

  * cg_step -- ONE full Jacobi-preconditioned CG iteration over an ELL
    matrix (spmv_ell kernel + dense reductions). Rust owns the outer
    loop and the convergence test; each iteration is a single PJRT
    execute. alpha/beta are computed inside the graph so no reductions
    ever cross the FFI boundary.

Nothing in this module may be imported at runtime -- it exists only for
`make artifacts` (and the pytest suite).
"""

import jax.numpy as jnp

from .kernels.elem_tet import elem_tet
from .kernels.spmv_ell import spmv_ell


def assemble_batch(coords, fvals, *, block=512):
    """Batched element matrices; see kernels/elem_tet.py.

    coords (B,4,3) f32, fvals (B,4) f32 -> (K (B,4,4), M (B,4,4), b (B,4)).
    """
    return elem_tet(coords, fvals, block=block)


def cg_step(vals, cols, diag_inv, x, r, p, rz, *, block=1024):
    """One Jacobi-PCG iteration.

    vals (N,W) f32, cols (N,W) i32, diag_inv (N,) f32 (0.0 on padded and
    Dirichlet-eliminated rows keeps them exactly invariant), x/r/p (N,)
    f32, rz () f32 = <r, z> from the previous iteration.

    Returns (x', r', p', rz', rnorm2).
    """
    q = spmv_ell(vals, cols, p, block=block)
    pq = jnp.dot(p, q)
    alpha = jnp.where(pq != 0.0, rz / pq, 0.0)
    x1 = x + alpha * p
    r1 = r - alpha * q
    z1 = diag_inv * r1
    rz1 = jnp.dot(r1, z1)
    beta = jnp.where(rz != 0.0, rz1 / rz, 0.0)
    p1 = z1 + beta * p
    rnorm2 = jnp.dot(r1, r1)
    return x1, r1, p1, rz1, rnorm2


def spmv(vals, cols, x, *, block=1024):
    """Standalone ELL SpMV (used by the residual check and benches)."""
    return spmv_ell(vals, cols, x, block=block)
