"""L1 Pallas kernel: SpMV over an ELL (ELLPACK) matrix.

ELL stores a sparse N x N matrix as two dense (N, W) arrays -- values and
column indices -- with rows padded to the fixed width W (padding entries
carry value 0.0 and column 0, which contributes exactly nothing).

ELL is the sparse layout a VMEM/MXU machine wants (see DESIGN.md
#Hardware-Adaptation): dense, regular tiles with a single gather per
lane, instead of CSR's per-row variable-length indirection. The paper's
platform runs CSR SpMV inside Hypre on CPUs; our AOT hot path needs
fixed shapes anyway, so ELL with a size ladder is the natural port.

The kernel blocks over rows; the x vector is small enough (<= 1 MiB for
the ladder sizes) to keep resident per block.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def spmv_ell_kernel(vals_ref, cols_ref, x_ref, y_ref):
    """y[i] = sum_w vals[i, w] * x[cols[i, w]] over one row block.

    vals_ref: (BLK, W) f32, cols_ref: (BLK, W) i32, x_ref: (N,) f32
    y_ref: (BLK,) f32
    """
    vals = vals_ref[...]
    cols = cols_ref[...]
    x = x_ref[...]
    y_ref[...] = jnp.sum(vals * x[cols], axis=1)


@functools.partial(jax.jit, static_argnames=("block",))
def spmv_ell(vals, cols, x, *, block=None):
    """ELL SpMV via the Pallas kernel. vals/cols: (N, W); x: (N,).

    `block=None` (the default, and what aot.py lowers) uses a single
    block spanning all rows. Rationale: every row block needs the whole
    x vector, and interpret-mode Pallas *materializes* each block's
    operands per grid step -- row-blocking therefore costs
    O(N^2 / block) memory traffic (measured: 250x slowdown at N = 256k;
    EXPERIMENTS.md #Perf). On a real TPU one would row-block with x
    resident in HBM and a dynamic gather per tile; on CPU-interpret the
    single block is the faithful O(N) schedule.
    """
    n, _w = vals.shape
    if block is None:
        block = n
    if n % block != 0:
        raise ValueError(f"rows {n} not a multiple of block {block}")
    grid = (n // block,)
    return pl.pallas_call(
        spmv_ell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, vals.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((block, cols.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(vals, cols, x)
