"""L1 Pallas kernel: batched P1 tetrahedral element matrices.

Given a batch of tetrahedra (vertex coordinates) and the P1-interpolated
source values at the vertices, compute for every element

  * the 4x4 local stiffness matrix  K_ij = V * (grad phi_i . grad phi_j)
  * the 4x4 local consistent mass   M_ij = V/20 * (1 + delta_ij)
  * the 4-vector local load         b_i  = sum_j M_ij f_j

This is the geometric hot-spot of FEM assembly: on the paper's platform
(PHG) it is the per-element inner loop; here it is a single fixed-shape
batched kernel so it AOT-compiles to one HLO module per batch size.

TPU shaping (see DESIGN.md #Hardware-Adaptation): the kernel blocks over
the batch dimension only; each block holds (BLK, 4, 3) coordinates plus
(BLK, 4) source values in VMEM (a few hundred KiB at BLK=2048) and emits
three dense outputs -- a regular streaming HBM<->VMEM schedule with all
arithmetic as dense batched products (einsum 'bik,bjk->bij' feeds the
MXU). interpret=True is mandatory on CPU PJRT (Mosaic custom-calls are
TPU-only).

Degenerate elements (|det J| < eps), which we use as batch padding, get
exactly-zero K, M and b.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEG_EPS = 1e-12


def _cross(a, b):
    """Batched 3-vector cross product, shapes (..., 3)."""
    ax, ay, az = a[..., 0], a[..., 1], a[..., 2]
    bx, by, bz = b[..., 0], b[..., 1], b[..., 2]
    return jnp.stack(
        [ay * bz - az * by, az * bx - ax * bz, ax * by - ay * bx], axis=-1
    )


def elem_tet_kernel(coords_ref, fvals_ref, k_ref, m_ref, b_ref):
    """Pallas kernel body over one batch block.

    coords_ref: (BLK, 4, 3) f32   tet vertex coordinates
    fvals_ref:  (BLK, 4)    f32   source values at vertices
    k_ref:      (BLK, 4, 4) f32   out: stiffness
    m_ref:      (BLK, 4, 4) f32   out: consistent mass
    b_ref:      (BLK, 4)    f32   out: load vector
    """
    c = coords_ref[...]
    f = fvals_ref[...]

    d1 = c[:, 1, :] - c[:, 0, :]
    d2 = c[:, 2, :] - c[:, 0, :]
    d3 = c[:, 3, :] - c[:, 0, :]

    c23 = _cross(d2, d3)
    c31 = _cross(d3, d1)
    c12 = _cross(d1, d2)

    det = jnp.sum(d1 * c23, axis=-1)  # 6 * signed volume
    degenerate = jnp.abs(det) < DEG_EPS
    safe_det = jnp.where(degenerate, 1.0, det)
    vol = jnp.where(degenerate, 0.0, jnp.abs(det) / 6.0)

    inv_det = 1.0 / safe_det
    g1 = c23 * inv_det[:, None]
    g2 = c31 * inv_det[:, None]
    g3 = c12 * inv_det[:, None]
    g0 = -(g1 + g2 + g3)
    grads = jnp.stack([g0, g1, g2, g3], axis=1)  # (BLK, 4, 3)

    # K = V * G G^T : a batched (4,3)x(3,4) product -- MXU-friendly.
    k = vol[:, None, None] * jnp.einsum("bik,bjk->bij", grads, grads)

    ones_eye = 1.0 + jnp.eye(4, dtype=c.dtype)  # (4, 4)
    m = (vol / 20.0)[:, None, None] * ones_eye[None, :, :]

    b = jnp.einsum("bij,bj->bi", m, f)

    k_ref[...] = k
    m_ref[...] = m
    b_ref[...] = b


@functools.partial(jax.jit, static_argnames=("block",))
def elem_tet(coords, fvals, *, block=512):
    """Batched P1 tet element matrices via the Pallas kernel.

    coords: (B, 4, 3) f32, fvals: (B, 4) f32 with B % block == 0.
    Returns (K, M, b) of shapes (B,4,4), (B,4,4), (B,4).
    """
    batch = coords.shape[0]
    if batch % block != 0:
        raise ValueError(f"batch {batch} not a multiple of block {block}")
    grid = (batch // block,)
    dtype = coords.dtype
    return pl.pallas_call(
        elem_tet_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, 4, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, 4), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, 4, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, 4, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, 4), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, 4, 4), dtype),
            jax.ShapeDtypeStruct((batch, 4, 4), dtype),
            jax.ShapeDtypeStruct((batch, 4), dtype),
        ],
        interpret=True,
    )(coords, fvals)
