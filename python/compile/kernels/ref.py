"""Pure-jnp/numpy correctness oracles for the Pallas kernels and the L2 graph.

Everything here is written with the most naive constructions available
(explicit loops, or loops replaced only by vmap) so the oracle is
obviously correct by inspection. pytest/hypothesis compare the kernels
against these.
"""

import jax
import jax.numpy as jnp
import numpy as np

DEG_EPS = 1e-12


def elem_tet_ref_single(coords, fvals):
    """Reference P1 tet element matrices for ONE element.

    coords: (4, 3), fvals: (4,). Returns (K (4,4), M (4,4), b (4,)).
    """
    d = jnp.stack([coords[i] - coords[0] for i in (1, 2, 3)], axis=1)  # J: cols = edges
    det = jnp.linalg.det(d)  # 6 * signed volume
    degenerate = jnp.abs(det) < DEG_EPS
    vol = jnp.where(degenerate, 0.0, jnp.abs(det) / 6.0)

    # gradients of barycentric coords 1..3 are the rows of inv(J)^T? No:
    # lambda_i(x) for i=1..3 satisfies J^T grad lambda_i = e_i, so the
    # grads are the rows of inv(J).
    safe_j = jnp.where(degenerate, jnp.eye(3), d)
    inv_j = jnp.linalg.inv(safe_j)
    g123 = inv_j  # (3,3): row i-1 = grad lambda_i
    g0 = -jnp.sum(g123, axis=0, keepdims=True)
    grads = jnp.concatenate([g0, g123], axis=0)  # (4, 3)
    grads = jnp.where(degenerate, 0.0, 1.0) * grads

    k = vol * grads @ grads.T
    m = vol / 20.0 * (jnp.ones((4, 4)) + jnp.eye(4))
    b = m @ fvals
    return k, m, b


def elem_tet_ref(coords, fvals):
    """Batched oracle: coords (B,4,3), fvals (B,4)."""
    return jax.vmap(elem_tet_ref_single)(coords, fvals)


def spmv_ell_ref(vals, cols, x):
    """Naive ELL SpMV oracle (python loops)."""
    vals = np.asarray(vals)
    cols = np.asarray(cols)
    x = np.asarray(x)
    n, w = vals.shape
    y = np.zeros(n, dtype=np.float64)
    for i in range(n):
        for j in range(w):
            y[i] += float(vals[i, j]) * float(x[cols[i, j]])
    return y.astype(x.dtype)


def cg_step_ref(vals, cols, diag_inv, x, r, p, rz):
    """One Jacobi-PCG iteration, oracle form (float64 numpy)."""
    vals64 = np.asarray(vals, dtype=np.float64)
    diag_inv = np.asarray(diag_inv, dtype=np.float64)
    x = np.asarray(x, dtype=np.float64)
    r = np.asarray(r, dtype=np.float64)
    p = np.asarray(p, dtype=np.float64)
    rz = float(rz)

    q = np.zeros_like(x)
    cols = np.asarray(cols)
    n, w = vals64.shape
    for i in range(n):
        for j in range(w):
            q[i] += vals64[i, j] * p[cols[i, j]]

    pq = float(p @ q)
    alpha = rz / pq if pq != 0.0 else 0.0
    x1 = x + alpha * p
    r1 = r - alpha * q
    z1 = diag_inv * r1
    rz1 = float(r1 @ z1)
    beta = rz1 / rz if rz != 0.0 else 0.0
    p1 = z1 + beta * p
    rnorm2 = float(r1 @ r1)
    return x1, r1, p1, rz1, rnorm2
