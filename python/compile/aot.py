"""AOT bridge: lower the L2 graph to HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT `lowered.compile()` / serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids,
which the xla crate's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`). The text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Every entry point is lowered at a ladder of fixed shapes ("one compiled
executable per model variant"); Rust pads its data up to the next rung.
A plain-text manifest lists every artifact with its parameters so the
Rust executable cache can pick rungs without hard-coding the ladder.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Shape ladders. Batches/vectors are padded up to the next rung by Rust.
ELEM_BATCHES = [2048, 8192, 32768, 131072]
ELEM_BLOCK = 512
CG_SIZES = [4096, 16384, 65536, 262144]
ELL_WIDTH = 32
CG_BLOCK = None  # single block: see kernels/spmv_ell.py (O(N^2) otherwise)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_elem_tet(batch):
    fn = lambda c, f: model.assemble_batch(c, f, block=ELEM_BLOCK)
    return jax.jit(fn).lower(f32(batch, 4, 3), f32(batch, 4))


def lower_cg_step(n, w):
    fn = lambda vals, cols, dinv, x, r, p, rz: model.cg_step(
        vals, cols, dinv, x, r, p, rz, block=CG_BLOCK
    )
    return jax.jit(fn).lower(
        f32(n, w), i32(n, w), f32(n), f32(n), f32(n), f32(n), f32()
    )


def lower_spmv(n, w):
    fn = lambda vals, cols, x: model.spmv(vals, cols, x, block=CG_BLOCK)
    return jax.jit(fn).lower(f32(n, w), i32(n, w), f32(n))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = []

    for b in ELEM_BATCHES:
        name = f"elem_tet_b{b}"
        text = to_hlo_text(lower_elem_tet(b))
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest.append(f"{name} elem_tet {fname} batch={b}")
        print(f"wrote {fname} ({len(text)} chars)")

    for n in CG_SIZES:
        name = f"cg_step_n{n}_w{ELL_WIDTH}"
        text = to_hlo_text(lower_cg_step(n, ELL_WIDTH))
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest.append(f"{name} cg_step {fname} n={n} w={ELL_WIDTH}")
        print(f"wrote {fname} ({len(text)} chars)")

        name = f"spmv_n{n}_w{ELL_WIDTH}"
        text = to_hlo_text(lower_spmv(n, ELL_WIDTH))
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest.append(f"{name} spmv {fname} n={n} w={ELL_WIDTH}")
        print(f"wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
