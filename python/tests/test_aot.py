"""AOT lowering sanity: every ladder rung lowers to parseable HLO text
with the expected parameter shapes mentioned in the module."""

import pytest

from compile import aot


@pytest.mark.parametrize("batch", [2048])
def test_elem_tet_lowers(batch):
    text = aot.to_hlo_text(aot.lower_elem_tet(batch))
    assert "HloModule" in text
    assert f"f32[{batch},4,3]" in text
    assert f"f32[{batch},4,4]" in text


@pytest.mark.parametrize("n", [4096])
def test_cg_step_lowers(n):
    text = aot.to_hlo_text(aot.lower_cg_step(n, aot.ELL_WIDTH))
    assert "HloModule" in text
    assert f"f32[{n},{aot.ELL_WIDTH}]" in text
    assert f"s32[{n},{aot.ELL_WIDTH}]" in text


def test_spmv_lowers():
    text = aot.to_hlo_text(aot.lower_spmv(4096, aot.ELL_WIDTH))
    assert "HloModule" in text


def test_ladders_are_sane():
    assert all(b % aot.ELEM_BLOCK == 0 for b in aot.ELEM_BATCHES)
    # CG lowers single-block (None) -- see kernels/spmv_ell.py
    assert aot.CG_BLOCK is None or all(n % aot.CG_BLOCK == 0 for n in aot.CG_SIZES)
    assert sorted(aot.ELEM_BATCHES) == aot.ELEM_BATCHES
    assert sorted(aot.CG_SIZES) == aot.CG_SIZES
