"""L2 graph tests: cg_step semantics and CG convergence on a real system."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def laplacian_1d_ell(n, w=4, dtype=np.float32):
    """Tridiagonal 1-D Laplacian (SPD) in ELL form, rows padded to w."""
    vals = np.zeros((n, w), dtype)
    cols = np.zeros((n, w), np.int32)
    for i in range(n):
        ents = [(i, 2.0)]
        if i > 0:
            ents.append((i - 1, -1.0))
        if i < n - 1:
            ents.append((i + 1, -1.0))
        for j, (c, v) in enumerate(ents):
            vals[i, j] = v
            cols[i, j] = c
    return vals, cols


class TestCgStep:
    def test_one_step_matches_reference(self):
        n = 32
        vals, cols = laplacian_1d_ell(n)
        rng = np.random.default_rng(7)
        b = rng.uniform(-1, 1, n).astype(np.float32)
        diag_inv = (1.0 / vals[:, 0]).astype(np.float32)
        x = np.zeros(n, np.float32)
        r = b.copy()
        z = diag_inv * r
        p = z.copy()
        rz = np.float32(r @ z)

        got = model.cg_step(vals, cols, diag_inv, x, r, p, rz, block=8)
        want = ref.cg_step_ref(vals, cols, diag_inv, x, r, p, rz)
        for g, w_ in zip(got, want):
            np.testing.assert_allclose(
                np.asarray(g, dtype=np.float64), w_, rtol=1e-4, atol=1e-5
            )

    def test_cg_converges_on_laplacian(self):
        """Full Jacobi-PCG loop (python driver) solves the 1-D Laplacian."""
        n = 64
        vals, cols = laplacian_1d_ell(n)
        rng = np.random.default_rng(3)
        xstar = rng.uniform(-1, 1, n).astype(np.float32)
        b = ref.spmv_ell_ref(vals, cols, xstar).astype(np.float32)

        diag_inv = (1.0 / vals[:, 0]).astype(np.float32)
        x = np.zeros(n, np.float32)
        r = b.copy()
        z = diag_inv * r
        p = z.copy()
        rz = np.float32(r @ z)

        for _ in range(2 * n):
            x, r, p, rz, rnorm2 = (
                np.asarray(v) for v in model.cg_step(vals, cols, diag_inv, x, r, p, rz, block=8)
            )
            if float(rnorm2) < 1e-10:
                break
        np.testing.assert_allclose(x, xstar, rtol=1e-2, atol=1e-3)

    def test_padded_rows_invariant(self):
        """Rows with diag_inv = 0 and zero matrix rows never change x."""
        n = 16
        vals, cols = laplacian_1d_ell(n)
        # last 4 rows are padding
        vals[12:] = 0.0
        diag_inv = np.zeros(n, np.float32)
        diag_inv[:12] = 1.0 / vals[:12, 0].clip(min=1.0)
        # also zero the columns that touch padded rows to keep A block-diag
        vals[11, 2] = 0.0

        b = np.zeros(n, np.float32)
        b[:12] = 1.0
        x = np.zeros(n, np.float32)
        r = b.copy()
        z = diag_inv * r
        p = z.copy()
        rz = np.float32(r @ z)
        for _ in range(5):
            x, r, p, rz, _ = (
                np.asarray(v)
                for v in model.cg_step(vals, cols, diag_inv, x, r, p, rz, block=8)
            )
        np.testing.assert_array_equal(x[12:], 0.0)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_hypothesis_spd_random(self, seed):
        """cg_step on a random SPD diagonal-dominant ELL matrix == oracle."""
        rng = np.random.default_rng(seed)
        n, w = 24, 6
        vals = np.zeros((n, w), np.float32)
        cols = np.zeros((n, w), np.int32)
        dense = np.zeros((n, n))
        for i in range(n):
            nbrs = rng.choice(n, size=w - 1, replace=False)
            row_ents = []
            for c in nbrs:
                if c != i:
                    v = rng.uniform(-0.5, 0.0)
                    row_ents.append((c, v))
            row_ents = row_ents[: w - 1]
            diag = 1.0 + sum(-v for _, v in row_ents)
            dense[i, i] += diag
            vals[i, 0] = diag
            cols[i, 0] = i
            for j, (c, v) in enumerate(row_ents, start=1):
                vals[i, j] = v
                cols[i, j] = c
                dense[i, c] += v
        # symmetrize-ish not needed for a one-step algebraic check
        diag_inv = (1.0 / vals[:, 0]).astype(np.float32)
        b = rng.uniform(-1, 1, n).astype(np.float32)
        x = np.zeros(n, np.float32)
        r = b.copy()
        z = diag_inv * r
        p = z.copy()
        rz = np.float32(r @ z)
        got = model.cg_step(vals, cols, diag_inv, x, r, p, rz, block=8)
        want = ref.cg_step_ref(vals, cols, diag_inv, x, r, p, rz)
        for g, w_ in zip(got, want):
            np.testing.assert_allclose(
                np.asarray(g, np.float64), w_, rtol=1e-3, atol=1e-4
            )


class TestShapes:
    def test_assemble_batch_shapes(self):
        c = np.zeros((16, 4, 3), np.float32)
        f = np.zeros((16, 4), np.float32)
        k, m, b = model.assemble_batch(c, f, block=8)
        assert k.shape == (16, 4, 4)
        assert m.shape == (16, 4, 4)
        assert b.shape == (16, 4)

    def test_spmv_shape(self):
        vals = np.zeros((16, 3), np.float32)
        cols = np.zeros((16, 3), np.int32)
        x = np.zeros(16, np.float32)
        assert model.spmv(vals, cols, x, block=8).shape == (16,)
