"""Kernel-vs-oracle correctness: the CORE L1 signal.

hypothesis sweeps shapes and values of both Pallas kernels against the
pure-jnp/numpy references in kernels/ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.elem_tet import elem_tet
from compile.kernels.spmv_ell import spmv_ell
from compile.kernels import ref

RNG = np.random.default_rng(20170712)


def random_tets(batch, rng, scale=1.0, degenerate_frac=0.0):
    coords = rng.uniform(-scale, scale, size=(batch, 4, 3)).astype(np.float32)
    ndeg = int(batch * degenerate_frac)
    if ndeg:
        # squash first ndeg tets flat (all vertices equal) -> det = 0
        coords[:ndeg] = coords[:ndeg, :1, :]
    fvals = rng.uniform(-2, 2, size=(batch, 4)).astype(np.float32)
    return coords, fvals


class TestElemTet:
    def test_reference_unit_tet(self):
        """K and M of the reference unit tet against hand-computed values."""
        coords = np.array(
            [[[0, 0, 0], [1, 0, 0], [0, 1, 0], [0, 0, 1]]], dtype=np.float32
        )
        fvals = np.ones((1, 4), dtype=np.float32)
        k, m, b = elem_tet(coords, fvals, block=1)
        k, m, b = np.asarray(k[0]), np.asarray(m[0]), np.asarray(b[0])
        vol = 1.0 / 6.0
        # grads: g0 = (-1,-1,-1), g1 = (1,0,0), g2 = (0,1,0), g3 = (0,0,1)
        g = np.array([[-1, -1, -1], [1, 0, 0], [0, 1, 0], [0, 0, 1]], float)
        np.testing.assert_allclose(k, vol * g @ g.T, rtol=1e-6)
        np.testing.assert_allclose(m, vol / 20 * (np.ones((4, 4)) + np.eye(4)), rtol=1e-6)
        # b = M @ 1 = row sums of M = vol/20 * 5 = vol/4 each
        np.testing.assert_allclose(b, np.full(4, vol / 4), rtol=1e-6)

    def test_stiffness_row_sums_zero(self):
        """Constants are in the P1 kernel: K @ 1 = 0 for every element."""
        coords, fvals = random_tets(64, RNG)
        k, _, _ = elem_tet(coords, fvals, block=32)
        rowsums = np.asarray(k).sum(axis=2)
        np.testing.assert_allclose(rowsums, 0.0, atol=1e-4)

    def test_mass_total(self):
        """sum(M) = element volume (integral of 1)."""
        coords, fvals = random_tets(64, RNG)
        _, m, _ = elem_tet(coords, fvals, block=32)
        m = np.asarray(m)
        vols = m.sum(axis=(1, 2))
        # independent volume computation
        d1 = coords[:, 1] - coords[:, 0]
        d2 = coords[:, 2] - coords[:, 0]
        d3 = coords[:, 3] - coords[:, 0]
        det = np.einsum("bi,bi->b", d1, np.cross(d2, d3))
        np.testing.assert_allclose(vols, np.abs(det) / 6.0, rtol=1e-4)

    def test_matches_reference(self):
        coords, fvals = random_tets(128, RNG)
        k, m, b = elem_tet(coords, fvals, block=64)
        kr, mr, br = ref.elem_tet_ref(coords, fvals)
        np.testing.assert_allclose(np.asarray(k), np.asarray(kr), rtol=2e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(m), np.asarray(mr), rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(np.asarray(b), np.asarray(br), rtol=1e-4, atol=1e-6)

    def test_degenerate_padding_rows_are_zero(self):
        coords, fvals = random_tets(32, RNG, degenerate_frac=0.5)
        k, m, b = elem_tet(coords, fvals, block=16)
        np.testing.assert_array_equal(np.asarray(k[:16]), 0.0)
        np.testing.assert_array_equal(np.asarray(m[:16]), 0.0)
        np.testing.assert_array_equal(np.asarray(b[:16]), 0.0)
        assert np.abs(np.asarray(k[16:])).max() > 0

    def test_translation_invariance(self):
        """K is invariant under translation of the element."""
        coords, fvals = random_tets(16, RNG)
        shifted = coords + np.array([10.0, -3.0, 7.0], dtype=np.float32)
        k0, _, _ = elem_tet(coords, fvals, block=16)
        k1, _, _ = elem_tet(shifted, fvals, block=16)
        np.testing.assert_allclose(np.asarray(k0), np.asarray(k1), rtol=1e-2, atol=1e-4)

    def test_spd_on_constant_free_space(self):
        """x^T K x >= 0 (K is PSD)."""
        coords, fvals = random_tets(32, RNG)
        k, _, _ = elem_tet(coords, fvals, block=32)
        k = np.asarray(k, dtype=np.float64)
        v = RNG.normal(size=(32, 4))
        quad = np.einsum("bi,bij,bj->b", v, k, v)
        assert (quad >= -1e-6).all()

    @settings(max_examples=20, deadline=None)
    @given(
        batch_log=st.integers(min_value=0, max_value=4),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        scale=st.sampled_from([0.1, 1.0, 50.0]),
    )
    def test_hypothesis_vs_reference(self, batch_log, seed, scale):
        batch = 2**batch_log * 8
        rng = np.random.default_rng(seed)
        coords, fvals = random_tets(batch, rng, scale=scale, degenerate_frac=0.1)
        block = min(batch, 8)
        k, m, b = elem_tet(coords, fvals, block=block)
        kr, mr, br = ref.elem_tet_ref(coords, fvals)
        # relative tolerance scaled: K entries scale like V/h^2 ~ scale
        np.testing.assert_allclose(
            np.asarray(k), np.asarray(kr), rtol=5e-3, atol=1e-3 * scale
        )
        np.testing.assert_allclose(
            np.asarray(m), np.asarray(mr), rtol=1e-4, atol=1e-6 * scale**3
        )


def random_ell(n, w, rng, dtype=np.float32):
    vals = rng.uniform(-1, 1, size=(n, w)).astype(dtype)
    cols = rng.integers(0, n, size=(n, w)).astype(np.int32)
    # emulate padding: ~25% of entries zeroed with col 0
    mask = rng.uniform(size=(n, w)) < 0.25
    vals[mask] = 0.0
    cols[mask] = 0
    x = rng.uniform(-1, 1, size=n).astype(dtype)
    return vals, cols, x


class TestSpmvEll:
    def test_identity(self):
        n, w = 16, 4
        vals = np.zeros((n, w), np.float32)
        cols = np.zeros((n, w), np.int32)
        vals[:, 0] = 1.0
        cols[:, 0] = np.arange(n)
        x = RNG.uniform(-1, 1, n).astype(np.float32)
        y = spmv_ell(vals, cols, x, block=8)
        np.testing.assert_allclose(np.asarray(y), x, rtol=1e-6)

    def test_matches_reference(self):
        vals, cols, x = random_ell(64, 8, RNG)
        y = spmv_ell(vals, cols, x, block=16)
        yr = ref.spmv_ell_ref(vals, cols, x)
        np.testing.assert_allclose(np.asarray(y), yr, rtol=1e-4, atol=1e-5)

    def test_single_block(self):
        vals, cols, x = random_ell(32, 5, RNG)
        y1 = spmv_ell(vals, cols, x, block=32)
        y2 = spmv_ell(vals, cols, x, block=8)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        n_blocks=st.integers(min_value=1, max_value=8),
        w=st.integers(min_value=1, max_value=12),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_vs_reference(self, n_blocks, w, seed):
        n = 8 * n_blocks
        rng = np.random.default_rng(seed)
        vals, cols, x = random_ell(n, w, rng)
        y = spmv_ell(vals, cols, x, block=8)
        yr = ref.spmv_ell_ref(vals, cols, x)
        np.testing.assert_allclose(np.asarray(y), yr, rtol=1e-3, atol=1e-4)

    def test_rejects_bad_block(self):
        vals, cols, x = random_ell(10, 3, RNG)
        with pytest.raises(ValueError):
            spmv_ell(vals, cols, x, block=4)
