//! Observability integration: the Chrome-trace export must be
//! well-formed JSON with labelled lanes, the two execution schedules
//! must emit identical *logical* compute spans (tracing is an
//! observer, never a numerics or schedule influence), and the driver
//! must feed the metrics registry every step.
//!
//! The tracer and the metrics registry are process-wide; every test
//! that enables tracing or reads global counters serializes on
//! `OBS_LOCK` so the harness's concurrent test threads cannot
//! interleave spans.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Mutex, MutexGuard};

use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::dist::Distribution;
use phg_dlb::exec::{executor_by_name, Executor, RankPlan};
use phg_dlb::fem::{Csr, DofMap, SolverOpts};
use phg_dlb::mesh::generator;
use phg_dlb::mesh::topology::LeafTopology;
use phg_dlb::mesh::TetMesh;
use phg_dlb::obs::{self, Phase, DRIVER_LANE};

static OBS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    // a panicked test must not wedge the rest of the suite
    OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------- JSON
// A minimal recursive-descent JSON syntax checker: enough to prove the
// trace export parses, with zero dependencies.

struct Json<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Json<'a> {
    fn new(s: &'a str) -> Self {
        Self { b: s.as_bytes(), i: 0 }
    }

    fn peek(&self) -> u8 {
        *self.b.get(self.i).unwrap_or(&0)
    }

    fn bump(&mut self) -> u8 {
        let c = self.peek();
        self.i += 1;
        c
    }

    fn ws(&mut self) {
        while matches!(self.peek(), b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn expect(&mut self, want: u8) {
        let got = self.bump();
        assert_eq!(got, want, "json byte {}: got {:?}", self.i, got as char);
    }

    fn value(&mut self) {
        self.ws();
        match self.peek() {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => self.string(),
            b't' => self.lit(b"true"),
            b'f' => self.lit(b"false"),
            b'n' => self.lit(b"null"),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &[u8]) {
        for &c in s {
            self.expect(c);
        }
    }

    fn object(&mut self) {
        self.expect(b'{');
        self.ws();
        if self.peek() == b'}' {
            self.i += 1;
            return;
        }
        loop {
            self.ws();
            self.string();
            self.ws();
            self.expect(b':');
            self.value();
            self.ws();
            match self.bump() {
                b',' => continue,
                b'}' => return,
                c => panic!("json byte {}: expected , or }} got {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) {
        self.expect(b'[');
        self.ws();
        if self.peek() == b']' {
            self.i += 1;
            return;
        }
        loop {
            self.value();
            self.ws();
            match self.bump() {
                b',' => continue,
                b']' => return,
                c => panic!("json byte {}: expected , or ] got {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) {
        self.expect(b'"');
        loop {
            match self.bump() {
                b'"' => return,
                b'\\' => {
                    self.i += 1;
                }
                0 => panic!("json: unterminated string"),
                _ => {}
            }
        }
    }

    fn number(&mut self) {
        let start = self.i;
        if self.peek() == b'-' {
            self.i += 1;
        }
        while matches!(self.peek(), b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
            self.i += 1;
        }
        assert!(self.i > start, "json byte {start}: expected a value");
        std::str::from_utf8(&self.b[start..self.i])
            .unwrap()
            .parse::<f64>()
            .expect("json: malformed number");
    }
}

fn assert_valid_json(s: &str) {
    let mut p = Json::new(s);
    p.value();
    p.ws();
    assert_eq!(p.i, p.b.len(), "trailing bytes after the json value");
}

// ------------------------------------------------------------ fixtures

fn fem_setup(nparts: usize) -> (TetMesh, LeafTopology, DofMap, RankPlan) {
    let mut mesh = generator::cube_mesh(2);
    mesh.refine(&mesh.leaves_unordered());
    let leaves = mesh.leaves_unordered();
    Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
    let topo = LeafTopology::build(&mesh);
    let dof = DofMap::build(&mesh, &topo);
    let owners: Vec<u16> = topo.leaves.iter().map(|&id| mesh.elem(id).owner).collect();
    let plan = RankPlan::build(&mesh, &topo, &dof, &owners, nparts);
    (mesh, topo, dof, plan)
}

fn driver_cfg(exec: &str, nsteps: usize) -> DriverConfig {
    DriverConfig {
        problem: "helmholtz".to_string(),
        nparts: 4,
        method: "PHG/HSFC".to_string(),
        trigger: "lambda".to_string(),
        weights: "unit".to_string(),
        strategy: "scratch".to_string(),
        exec: exec.to_string(),
        exec_threads: 0,
        lambda_trigger: 1.1,
        theta_refine: 0.4,
        theta_coarsen: 0.03,
        max_elements: 30_000,
        solver: SolverOpts {
            tol: 1e-5,
            max_iter: 600,
        },
        use_pjrt: false,
        nsteps,
        dt: 1.5e-3,
    }
}

/// Run one assemble + solve under the named executor with the global
/// tracer on; return (per-(lane, phase) compute-span counts,
/// wait-span count, solver iterations).
fn traced_step(exec: &str) -> (BTreeMap<(u32, &'static str), usize>, usize, usize) {
    let (mesh, topo, dof, plan) = fem_setup(4);
    let e = executor_by_name(exec, 4, 2).unwrap();
    let tr = obs::tracer();
    tr.clear();
    tr.set_enabled(true);
    let src = vec![1.0; dof.n_dofs];
    let sys = e.assemble(&plan, &mesh, &topo, &dof, &src, None);
    let a = Csr::linear_combination(1.0, &sys.k, 1.0, &sys.m);
    let mut u = vec![0.0; dof.n_dofs];
    let stats = e.pcg(&plan, &a, &sys.b, &mut u, &SolverOpts::default(), None);
    tr.set_enabled(false);
    let events = tr.take();
    let mut compute: BTreeMap<(u32, &'static str), usize> = BTreeMap::new();
    let mut waits = 0usize;
    for ev in &events {
        assert!(ev.t1_ns >= ev.t0_ns, "span ends before it starts");
        match ev.phase {
            Phase::Assemble | Phase::Spmv | Phase::Dot | Phase::Axpy => {
                *compute.entry((ev.rank, ev.phase.name())).or_insert(0) += 1;
            }
            Phase::HaloSend | Phase::HaloRecv | Phase::BarrierWait => waits += 1,
            other => panic!("executor emitted a driver phase: {}", other.name()),
        }
    }
    (compute, waits, stats.iterations)
}

// --------------------------------------------------------------- tests

#[test]
fn chrome_trace_export_is_wellformed_and_labelled() {
    // a local tracer: no global state, no lock needed
    let t = phg_dlb::obs::Tracer::new();
    t.set_enabled(true);
    for rk in 0..3usize {
        let _sp = t.span(rk, Phase::Spmv);
        let _nested = t.span(rk, Phase::Dot);
    }
    {
        let _drv = t.span_lane(DRIVER_LANE, Phase::Partition);
    }
    let json = t.chrome_trace_json();
    assert_valid_json(&json);
    assert_eq!(json.matches("\"ph\":\"X\"").count(), 7);
    // one thread_name per lane (3 ranks + driver) + one process_name
    assert_eq!(json.matches("\"ph\":\"M\"").count(), 5);
    assert!(json.contains("\"name\":\"driver\""));
    assert!(json.contains("\"name\":\"rank 2\""));
    assert!(json.contains("\"cat\":\"dlb\""));
}

#[test]
fn executors_emit_equal_logical_span_counts() {
    let _g = lock();
    let (virt, virt_waits, virt_iters) = traced_step("virtual");
    let (thr, thr_waits, thr_iters) = traced_step("threads");
    assert_eq!(virt_iters, thr_iters, "schedules diverged");
    assert!(!virt.is_empty(), "virtual emitted no compute spans");
    assert_eq!(
        virt, thr,
        "logical compute spans (assemble/spmv/dot/axpy per rank) must \
         not depend on the execution schedule"
    );
    // waits are physical: only the threaded schedule has them
    assert_eq!(virt_waits, 0, "virtual executor never waits");
    assert!(thr_waits > 0, "threaded executor emitted no wait spans");
    // every rank assembled exactly once
    for rk in 0..4u32 {
        assert_eq!(virt.get(&(rk, "assemble")), Some(&1));
    }
}

#[test]
fn traced_driver_run_exports_driver_and_rank_lanes() {
    let _g = lock();
    let tr = obs::tracer();
    tr.clear();
    tr.set_enabled(true);
    let mut d = AdaptiveDriver::for_scenario(driver_cfg("threads", 2)).unwrap();
    d.run();
    tr.set_enabled(false);
    let events = tr.snapshot();
    let json = tr.chrome_trace_json();
    tr.clear();
    assert_eq!(d.timeline.records.len(), 2);
    assert_valid_json(&json);

    let driver_phases: BTreeSet<&str> = events
        .iter()
        .filter(|e| e.rank == DRIVER_LANE)
        .map(|e| e.phase.name())
        .collect();
    for must in ["solve", "estimate", "mark"] {
        assert!(driver_phases.contains(must), "driver lane missing {must}");
    }
    // rank lanes carry the physical schedule, waits included
    assert!(events
        .iter()
        .any(|e| e.rank != DRIVER_LANE && e.phase == Phase::BarrierWait));
    assert!(events
        .iter()
        .any(|e| e.rank != DRIVER_LANE && e.phase == Phase::Spmv));
}

#[test]
fn driver_feeds_metrics_every_step() {
    let _g = lock();
    let m = obs::metrics();
    let steps0 = m.counter("driver.steps");
    let solves0 = m.histogram("driver.solve_s").map_or(0, |h| h.count);
    let mut d = AdaptiveDriver::for_scenario(driver_cfg("virtual", 2)).unwrap();
    d.run();
    assert_eq!(
        m.counter("driver.steps"),
        steps0 + 2,
        "driver.steps must count every adaptive step"
    );
    let solves = m.histogram("driver.solve_s").expect("solve histogram");
    assert_eq!(solves.count, solves0 + 2);
    assert!(solves.max > 0.0);
    let dump = m.dump();
    assert!(dump.contains("driver.steps = "), "{dump}");
    assert_eq!(dump, m.dump(), "dump must be deterministic");
}
