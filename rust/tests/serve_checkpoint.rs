//! Service-mode acceptance: checkpoint/restore is bitwise resume
//! equivalence (DESIGN.md §13), snapshots are version-tagged with
//! offset-naming corruption errors, and the serve daemon runs many
//! tenants to correct terminal states -- surviving a panicking job and
//! draining resumably.

use phg_dlb::coordinator::checkpoint::{MAGIC, VERSION};
use phg_dlb::coordinator::timeline::StepRecord;
use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::dlb::WeightModel;
use phg_dlb::fem::SolverOpts;
use phg_dlb::scenario::SCENARIOS;
use phg_dlb::serve::json::{self, Json};
use phg_dlb::serve::{serve, JobSpec, JobState, ServeOptions};
use phg_dlb::util::hash::FxHasher;
use std::hash::Hasher;
use std::path::PathBuf;

fn cfg(problem: &str, exec: &str) -> DriverConfig {
    DriverConfig {
        problem: problem.to_string(),
        nparts: 4,
        method: "PHG/HSFC".to_string(),
        trigger: "lambda".to_string(),
        weights: "unit".to_string(),
        strategy: "scratch".to_string(),
        exec: exec.to_string(),
        exec_threads: 0,
        lambda_trigger: 1.1,
        theta_refine: 0.4,
        theta_coarsen: 0.03,
        max_elements: 30_000,
        solver: SolverOpts {
            tol: 1e-5,
            max_iter: 600,
        },
        use_pjrt: false,
        nsteps: 3,
        dt: 1.5e-3,
    }
}

fn run_steps(d: &mut AdaptiveDriver, n: usize) {
    while d.steps_completed() < n {
        if !d.step() {
            break;
        }
    }
}

/// The wall-independent step invariants that must be bitwise equal
/// between an uninterrupted run and a checkpoint-resumed one. Measured
/// times (and quantities derived from them, like the threaded
/// executor's `solve_imbalance`) are process-local and excluded.
fn assert_steps_match(a: &StepRecord, b: &StepRecord, tag: &str) {
    let step = a.step;
    assert_eq!(a.step, b.step, "{tag}: step numbering diverged");
    assert_eq!(a.nparts, b.nparts, "{tag} step {step}");
    assert_eq!(a.n_elements, b.n_elements, "{tag} step {step}: n_elements");
    assert_eq!(a.n_dofs, b.n_dofs, "{tag} step {step}: n_dofs");
    assert_eq!(
        a.solve_iterations, b.solve_iterations,
        "{tag} step {step}: solver iterations"
    );
    assert_eq!(
        a.interface_faces, b.interface_faces,
        "{tag} step {step}: interface faces"
    );
    assert_eq!(
        a.repartitioned, b.repartitioned,
        "{tag} step {step}: DLB decision"
    );
    assert_eq!(
        a.strategy.map(|s| s.name()),
        b.strategy.map(|s| s.name()),
        "{tag} step {step}: strategy"
    );
    for (name, x, y) in [
        ("imbalance_before", a.imbalance_before, b.imbalance_before),
        ("imbalance_after", a.imbalance_after, b.imbalance_after),
        ("l2_error", a.l2_error, b.l2_error),
        ("max_error", a.max_error, b.max_error),
        ("remap_kept_fraction", a.remap_kept_fraction, b.remap_kept_fraction),
        ("partition_comm_modeled", a.partition_comm_modeled, b.partition_comm_modeled),
        ("migrate_modeled", a.migrate_modeled, b.migrate_modeled),
        ("solve_comm_modeled", a.solve_comm_modeled, b.solve_comm_modeled),
    ] {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{tag} step {step}: {name} diverged ({x} vs {y})"
        );
    }
    match (&a.migration, &b.migration) {
        (None, None) => {}
        (Some(ma), Some(mb)) => {
            assert_eq!(ma.total_v.to_bits(), mb.total_v.to_bits(), "{tag} step {step}");
            assert_eq!(ma.max_v.to_bits(), mb.max_v.to_bits(), "{tag} step {step}");
            assert_eq!(
                ma.moved_fraction.to_bits(),
                mb.moved_fraction.to_bits(),
                "{tag} step {step}"
            );
        }
        _ => panic!("{tag} step {step}: migration presence diverged"),
    }
}

/// Run `n` steps uninterrupted; run `k` steps, checkpoint, restore,
/// run to `n`; every post-restore StepRecord and the final solution
/// must match the uninterrupted run bitwise.
fn check_resume_equivalence(problem: &str, exec: &str, k: usize, n: usize) {
    let tag = format!("{problem}/{exec} (k={k}, n={n})");
    let mut full = AdaptiveDriver::for_scenario(cfg(problem, exec)).unwrap();
    run_steps(&mut full, n);

    let mut prefix = AdaptiveDriver::for_scenario(cfg(problem, exec)).unwrap();
    run_steps(&mut prefix, k);
    assert_eq!(prefix.steps_completed(), k, "{tag}: prefix stopped early");
    let bytes = prefix.checkpoint_bytes();

    let mut resumed = AdaptiveDriver::restore_bytes(cfg(problem, exec), &bytes).unwrap();
    assert_eq!(resumed.steps_completed(), k, "{tag}: restored step counter");
    assert!(resumed.timeline.records.is_empty(), "{tag}: restored timeline not fresh");
    run_steps(&mut resumed, n);

    assert_eq!(
        full.timeline.records.len(),
        k + resumed.timeline.records.len(),
        "{tag}: step counts diverged"
    );
    for (a, b) in full.timeline.records[k..].iter().zip(&resumed.timeline.records) {
        assert_steps_match(a, b, &tag);
    }
    let (ua, ub) = (full.solution(), resumed.solution());
    assert_eq!(ua.len(), ub.len(), "{tag}: solution lengths diverged");
    for (i, (x, y)) in ua.iter().zip(ub).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: solution[{i}] diverged ({x} vs {y})");
    }
}

#[test]
fn resume_matches_uninterrupted_on_all_scenarios() {
    for spec in &SCENARIOS {
        for exec in ["virtual", "threads"] {
            check_resume_equivalence(spec.name, exec, 1, 3);
        }
    }
}

#[test]
fn resume_matches_after_a_deeper_prefix() {
    // two post-restore steps after two pre-checkpoint adaptations: the
    // restored forest (parents, mid-vertices, free lists) must keep
    // producing the same ids the uninterrupted process would
    check_resume_equivalence("helmholtz", "threads", 2, 4);
}

fn fx_checksum(bytes: &[u8]) -> u64 {
    let mut h = FxHasher::default();
    h.write(bytes);
    h.finish()
}

/// Re-frame a payload with a freshly computed trailing checksum, so a
/// deliberate payload edit exercises the parser instead of tripping the
/// checksum-first gate.
fn reframe(payload: &[u8]) -> Vec<u8> {
    let mut out = payload.to_vec();
    out.extend_from_slice(&fx_checksum(payload).to_le_bytes());
    out
}

#[test]
fn snapshots_are_version_tagged_and_corruption_names_the_offset() {
    let mut d = AdaptiveDriver::for_scenario(cfg("helmholtz", "virtual")).unwrap();
    run_steps(&mut d, 1);
    let bytes = d.checkpoint_bytes();
    assert!(bytes.starts_with(MAGIC), "checkpoint must lead with the magic tag");

    // too short to even hold the frame
    let err = AdaptiveDriver::restore_bytes(cfg("helmholtz", "virtual"), &bytes[..10])
        .unwrap_err()
        .to_string();
    assert!(err.contains("truncated") && err.contains("offset"), "{err}");

    // a valid frame around a truncated payload: the reader names the
    // byte offset where it ran out
    let payload = &bytes[..bytes.len() - 8];
    let cut = reframe(&payload[..payload.len() - 50]);
    let err = AdaptiveDriver::restore_bytes(cfg("helmholtz", "virtual"), &cut)
        .unwrap_err()
        .to_string();
    assert!(err.contains("offset"), "truncation must name the offset: {err}");

    // a flipped payload byte under the original checksum
    let mut corrupt = bytes.clone();
    let mid = corrupt.len() / 2;
    corrupt[mid] ^= 0xff;
    let err = AdaptiveDriver::restore_bytes(cfg("helmholtz", "virtual"), &corrupt)
        .unwrap_err()
        .to_string();
    assert!(err.contains("checksum mismatch") && err.contains("offset"), "{err}");

    // a future format version is rejected by name, not misparsed
    let mut newer = payload.to_vec();
    newer[8..12].copy_from_slice(&(VERSION + 1).to_le_bytes());
    let err = AdaptiveDriver::restore_bytes(cfg("helmholtz", "virtual"), &reframe(&newer))
        .unwrap_err()
        .to_string();
    assert!(err.contains("version") && err.contains("this build reads"), "{err}");

    // a non-checkpoint file is named as such
    let mut alien = payload.to_vec();
    alien[0] ^= 0xff;
    let err = AdaptiveDriver::restore_bytes(cfg("helmholtz", "virtual"), &reframe(&alien))
        .unwrap_err()
        .to_string();
    assert!(err.contains("bad magic"), "{err}");

    // the config must name the snapshot's problem and part count
    let err = AdaptiveDriver::restore_bytes(cfg("lshape", "virtual"), &bytes)
        .unwrap_err()
        .to_string();
    assert!(err.contains("problem"), "{err}");
    let mut other = cfg("helmholtz", "virtual");
    other.nparts = 8;
    let err = AdaptiveDriver::restore_bytes(other, &bytes).unwrap_err().to_string();
    assert!(err.contains("nparts"), "{err}");
}

#[test]
fn learned_dlb_state_survives_the_roundtrip() {
    // measured-EWMA weights are part of the adaptive state: the
    // restored driver must re-serialize to the identical byte stream
    // (which covers the weight table, the wall EWMAs, clock and forest)
    let mut c = cfg("parabolic", "threads");
    c.weights = "measured".to_string();
    let mut d = AdaptiveDriver::for_scenario(c.clone()).unwrap();
    run_steps(&mut d, 2);
    let state = d.weight_model.export_state().expect("measured model exports state");
    assert!(!state.costs.is_empty(), "no per-element costs learned");

    let bytes = d.checkpoint_bytes();
    let restored = AdaptiveDriver::restore_bytes(c, &bytes).unwrap();
    assert_eq!(restored.weight_model.export_state(), Some(state));
    assert_eq!(
        restored.checkpoint_bytes(),
        bytes,
        "restore -> checkpoint must be the identity on the byte stream"
    );
}

fn temp_opts(tag: &str) -> (ServeOptions, PathBuf) {
    let base = std::env::temp_dir().join(format!("phg_serve_it_{tag}_{}", std::process::id()));
    let opts = ServeOptions {
        workers: 2,
        checkpoint_dir: base.join("ckpt"),
        trace_dir: Some(base.join("trace")),
        drain_timeout_s: 0.0,
        retry_base_ms: 1,
        status_port: None,
    };
    (opts, base)
}

const SMALL: &str = "\"nparts\": 4, \"max_elements\": 30000, \"theta_refine\": 0.4, \
                     \"solver_tol\": 1e-4, \"solver_max_iter\": 400";

/// A parabolic tenant: time-dependent, so `step()` never stops early
/// on the growth budget and step counts are exactly the budget.
fn parabolic_overrides() -> Vec<(String, String)> {
    [
        ("problem", "parabolic"),
        ("nparts", "4"),
        ("max_elements", "30000"),
        ("theta_refine", "0.4"),
        ("solver_tol", "1e-4"),
        ("solver_max_iter", "400"),
        ("dt", "1.5e-3"),
    ]
    .iter()
    .map(|(k, v)| (k.to_string(), v.to_string()))
    .collect()
}

#[test]
fn three_job_serve_completes_with_per_job_timelines() {
    let jsonl = format!(
        "# three tenants, mixed scenarios\n\
         {{\"id\": \"helm\", \"problem\": \"helmholtz\", \"steps\": 2, {SMALL}}}\n\
         {{\"id\": \"para\", \"problem\": \"parabolic\", \"steps\": 2, \"dt\": 1.5e-3, {SMALL}}}\n\
         {{\"id\": \"lshape\", \"problem\": \"lshape\", \"steps\": 2, {SMALL}}}\n"
    );
    let specs = JobSpec::parse_jsonl(&jsonl).unwrap();
    let (opts, base) = temp_opts("three");
    let summary = serve(specs, &opts).unwrap();

    assert_eq!(summary.jobs.len(), 3);
    for job in &summary.jobs {
        assert_eq!(job.state, JobState::Done, "{}: {:?}", job.spec.id, job.error);
        assert_eq!(job.attempts, 1, "{}", job.spec.id);
        assert_eq!(job.steps_done, 2, "{}", job.spec.id);
        assert!(job.n_elements > 0 && job.n_dofs > 0, "{}", job.spec.id);
        assert!(job.l2_error.is_finite() && job.l2_error > 0.0, "{}", job.spec.id);
    }
    let table = summary.format_table();
    assert!(table.contains("serve: jobs=3 done=3 failed=0 cancelled=0"), "{table}");

    // disjoint per-job timelines: every tenant gets its own parseable
    // trace file naming itself, plus a CSV with one row per step
    for id in ["helm", "para", "lshape"] {
        let trace = std::fs::read_to_string(base.join("trace").join(format!("job-{id}.json")))
            .unwrap_or_else(|e| panic!("job-{id}.json: {e}"));
        let v = json::parse(&trace).unwrap_or_else(|e| panic!("job-{id}.json: {e}"));
        let events = match v.get("traceEvents") {
            Some(Json::Arr(events)) => events,
            other => panic!("job-{id}.json: traceEvents is {other:?}"),
        };
        // lifecycle span + one event per step
        assert_eq!(events.len(), 3, "job-{id}.json event count");
        let name = events[0].get("name").and_then(|n| n.as_str()).unwrap();
        assert_eq!(name, format!("job:{id}"));
        let csv = std::fs::read_to_string(base.join("trace").join(format!("job-{id}.csv")))
            .unwrap_or_else(|e| panic!("job-{id}.csv: {e}"));
        assert_eq!(csv.lines().count(), 3, "job-{id}.csv: header + 2 steps");
    }
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn a_panicking_job_is_isolated_retried_and_failed() {
    // nparts 0 trips a hard assertion deep in the driver composition;
    // the daemon must convert that panic into a failed row (after the
    // budgeted retry) while the good tenant completes untouched
    let jsonl = format!(
        "{{\"id\": \"good\", \"problem\": \"helmholtz\", \"steps\": 1, {SMALL}}}\n\
         {{\"id\": \"boom\", \"problem\": \"helmholtz\", \"steps\": 1, \"retries\": 1, \
           \"nparts\": 0}}\n"
    );
    let specs = JobSpec::parse_jsonl(&jsonl).unwrap();
    let (opts, base) = temp_opts("panic");
    let summary = serve(specs, &opts).unwrap();

    let good = &summary.jobs[0];
    assert_eq!(good.state, JobState::Done, "{:?}", good.error);
    let boom = &summary.jobs[1];
    assert_eq!(boom.state, JobState::Failed);
    assert_eq!(boom.attempts, 2, "one retry after the first panic");
    let err = boom.error.as_deref().unwrap_or("");
    assert!(err.contains("panicked"), "panic not surfaced: {err:?}");
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn drain_checkpoints_in_flight_jobs_and_resume_finishes_them() {
    let (opts, base) = temp_opts("drain");
    let mut opts = opts;
    opts.workers = 1;
    let long = JobSpec {
        id: "long".to_string(),
        overrides: parabolic_overrides(),
        steps: 5,
        max_retries: 0,
        resume_from: None,
        drain_after: Some(2),
    };
    let short = JobSpec {
        id: "short".to_string(),
        overrides: parabolic_overrides(),
        steps: 1,
        max_retries: 0,
        resume_from: None,
        drain_after: None,
    };
    let summary = serve(vec![long.clone(), short], &opts).unwrap();

    // the in-flight job drained at a step boundary, resumably
    let drained = &summary.jobs[0];
    assert_eq!(drained.state, JobState::Cancelled);
    assert_eq!(drained.steps_done, 2, "drained after two steps");
    let ckpt = drained.checkpoint.clone().expect("drained job leaves a checkpoint");
    assert!(ckpt.exists(), "{}", ckpt.display());
    // the queued job was cancelled without ever starting
    let skipped = &summary.jobs[1];
    assert_eq!(skipped.state, JobState::Cancelled);
    assert!(skipped.checkpoint.is_none());
    assert_eq!(skipped.attempts, 0);

    // resuming the drained spec finishes the original budget
    let resumed = JobSpec {
        resume_from: Some(ckpt),
        drain_after: None,
        ..long
    };
    let summary = serve(vec![resumed], &opts).unwrap();
    let job = &summary.jobs[0];
    assert_eq!(job.state, JobState::Done, "{:?}", job.error);
    assert_eq!(job.steps_done, 5, "budget is total steps, resumed included");
    std::fs::remove_dir_all(&base).ok();
}
