//! Overhead guard: tracing is disabled by default, and the disabled
//! span path on the PCG hot loop performs **zero** allocations (it is
//! two relaxed atomic loads and no clock read); the threaded PCG's
//! steady-state iteration loop (halo exchange included) also
//! allocates nothing per iteration; the disabled flight recorder and
//! the absent status plane (no `--status-port`) add no allocations and
//! spawn no thread. Enforced with a counting global allocator, which
//! is why this is its own test binary with exactly one `#[test]`: any
//! concurrent test thread would pollute the allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use phg_dlb::exec::{pcg_threaded, GhostPlan, RankPlan};
use phg_dlb::fem::{Csr, SolverOpts};
use phg_dlb::obs::{self, Phase};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Thread count of this process via `/proc/self/task` (Linux); `None`
/// where procfs is unavailable, which skips the thread assertions.
fn thread_count() -> Option<usize> {
    std::fs::read_dir("/proc/self/task")
        .ok()
        .map(|dir| dir.count())
}

#[test]
fn disabled_tracing_adds_no_allocations_to_the_hot_loop() {
    let tr = obs::tracer();
    assert!(!tr.enabled(), "tracing must be disabled by default");

    // warm up: the OnceLock init and shard vector allocation happen
    // here, outside the measured window
    for rk in 0..4usize {
        let _sp = obs::span(rk, Phase::Spmv);
    }
    assert!(tr.is_empty(), "disabled spans must record nothing");

    // the hot loop: per-rank per-iteration span guards, disabled
    let before = ALLOCS.load(Ordering::Relaxed);
    for it in 0..100_000usize {
        let _sp = obs::span(it & 3, Phase::Dot);
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after, before,
        "disabled span path allocated {} times over 100k calls",
        after - before
    );
    assert!(tr.is_empty());

    // warm metrics feeding (existing &'static str entry) is also
    // allocation-free -- it is on every step path unconditionally
    let m = obs::metrics();
    m.observe("obs_overhead.probe_s", 1.0e-3); // creates the entry
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..10_000usize {
        m.observe("obs_overhead.probe_s", 2.0e-3);
        m.counter_add("obs_overhead.probe_s_ticks", 0);
    }
    // the counter entry was created inside the loop's first pass: one
    // node insertion is permitted, steady state must be flat
    let after = ALLOCS.load(Ordering::Relaxed);
    assert!(
        after - before <= 1,
        "warm metrics path allocated {} times over 10k observations",
        after - before
    );

    // ---- threaded PCG steady state allocates nothing per iteration.
    // Two solves identical except for the iteration budget must show
    // the *same* allocation total: every per-solve allocation (worker
    // threads, rank states, SELL kernels, halo slot buffers) is
    // iteration-independent, and the iteration loop itself -- halo
    // publish/consume through the reusable slots included -- is
    // allocation-free.
    {
        let grid = 8usize;
        let n = grid * grid;
        let id = |i: usize, j: usize| (i * grid + j) as u32;
        let mut t = Vec::new();
        for i in 0..grid {
            for j in 0..grid {
                let r = id(i, j);
                t.push((r, r, 4.0));
                if i > 0 {
                    t.push((r, id(i - 1, j), -1.0));
                }
                if i + 1 < grid {
                    t.push((r, id(i + 1, j), -1.0));
                }
                if j > 0 {
                    t.push((r, id(i, j - 1), -1.0));
                }
                if j + 1 < grid {
                    t.push((r, id(i, j + 1), -1.0));
                }
            }
        }
        let a = Csr::from_triplets(n, t);
        let nranks = 3usize;
        let mut rank_of_dof = vec![0u16; n];
        let mut rows: Vec<Vec<u32>> = vec![Vec::new(); nranks];
        for d in 0..n {
            let r = d * nranks / n;
            rank_of_dof[d] = r as u16;
            rows[r].push(d as u32);
        }
        let plan = RankPlan {
            nranks,
            elems: vec![Vec::new(); nranks],
            rank_of_dof,
            interior: vec![Vec::new(); nranks],
            boundary: rows.clone(),
            rows,
        };
        let ghost = GhostPlan::build(&plan, &a);
        let b = vec![1.0; n];
        // tol = 0 never converges early: iteration count == max_iter
        let solve = |max_iter: usize| {
            let opts = SolverOpts { tol: 0.0, max_iter };
            let mut x = vec![0.0; n];
            let before = ALLOCS.load(Ordering::Relaxed);
            let (stats, _, _) = pcg_threaded(&plan, &ghost, &a, &b, &mut x, &opts, 2);
            assert_eq!(stats.iterations, max_iter);
            ALLOCS.load(Ordering::Relaxed) - before
        };
        solve(3); // warm-up: creates the lazy metrics entries
        let short = solve(3);
        let long = solve(9);
        assert_eq!(
            long, short,
            "threaded PCG allocated {} times over 6 extra iterations",
            long - short
        );
    }

    // ---- disabled flight recorder: what a run without `--flight`
    // pays at every trigger evaluation is this gate -- one relaxed
    // load -- and the coordinator gates event *construction* on it, so
    // nothing downstream (candidate table, strings) is ever built.
    // A record() call on a disabled recorder is an immediate return:
    // no lock, no allocation (the pre-built event is merely dropped).
    let fl = obs::flight();
    assert!(!fl.enabled(), "flight recorder must be off by default");
    let probe = obs::FlightEvent {
        step: 0,
        lambda: 1.0,
        trigger: "lambda:1.20".to_string(),
        fired: false,
        rebalance_cost: 0.0,
        saving_per_step: 0.0,
        candidates: Vec::new(),
        chosen: None,
        realized: None,
    };
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..100_000usize {
        std::hint::black_box(fl.enabled());
    }
    fl.record(probe);
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after, before,
        "disabled flight path allocated {} times over 100k gates + 1 record",
        after - before
    );
    assert!(fl.is_empty(), "disabled recorder must record nothing");
    assert_eq!(fl.dropped(), 0);

    // ---- absent status plane: without `--status-port` there is no
    // server object at all -- the run path holds `None`, which costs
    // no allocation and spawns no thread (compare PR 9: the baseline
    // thread census is whatever the harness + PCG warm-up left us)
    let threads_baseline = thread_count();
    let before = ALLOCS.load(Ordering::Relaxed);
    let status: Option<obs::StatusServer> = None;
    assert!(status.is_none());
    drop(status);
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(after, before, "a disabled status plane must not allocate");
    if let (Some(t0), Some(t1)) = (threads_baseline, thread_count()) {
        assert_eq!(t1, t0, "a disabled status plane must not spawn threads");
    }

    // positive control: the counting allocator really counts -- an
    // *enabled* span must allocate (first push into an empty shard)
    tr.set_enabled(true);
    let before = ALLOCS.load(Ordering::Relaxed);
    {
        let _sp = obs::span(0, Phase::Spmv);
    }
    tr.set_enabled(false);
    assert!(
        ALLOCS.load(Ordering::Relaxed) > before,
        "counting allocator saw no allocation from an enabled span"
    );
    assert_eq!(tr.len(), 1);
    tr.clear();

    // positive control: an *enabled* recorder really records (and so
    // the disabled assertions above are not vacuous)
    fl.set_enabled(true);
    fl.record(obs::FlightEvent {
        step: 1,
        lambda: 1.2,
        trigger: "lambda:1.20".to_string(),
        fired: false,
        rebalance_cost: 0.0,
        saving_per_step: 0.0,
        candidates: Vec::new(),
        chosen: None,
        realized: None,
    });
    fl.set_enabled(false);
    assert_eq!(fl.len(), 1);
    fl.clear();

    // positive control: a *started* status server runs exactly one
    // accept thread, and stop() joins it back out of the census
    if let Some(t0) = thread_count() {
        let srv = obs::StatusServer::start(0, None).expect("ephemeral status server");
        let t1 = thread_count().expect("procfs stays available");
        assert_eq!(t1, t0 + 1, "status server must run exactly one thread");
        srv.stop();
        let t2 = thread_count().expect("procfs stays available");
        assert_eq!(t2, t0, "stop() must join the accept thread");
    }
}
