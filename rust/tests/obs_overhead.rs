//! Overhead guard: tracing is disabled by default, and the disabled
//! span path on the PCG hot loop performs **zero** allocations (it is
//! two relaxed atomic loads and no clock read). Enforced with a
//! counting global allocator, which is why this is its own test
//! binary with exactly one `#[test]`: any concurrent test thread
//! would pollute the allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use phg_dlb::obs::{self, Phase};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn disabled_tracing_adds_no_allocations_to_the_hot_loop() {
    let tr = obs::tracer();
    assert!(!tr.enabled(), "tracing must be disabled by default");

    // warm up: the OnceLock init and shard vector allocation happen
    // here, outside the measured window
    for rk in 0..4usize {
        let _sp = obs::span(rk, Phase::Spmv);
    }
    assert!(tr.is_empty(), "disabled spans must record nothing");

    // the hot loop: per-rank per-iteration span guards, disabled
    let before = ALLOCS.load(Ordering::Relaxed);
    for it in 0..100_000usize {
        let _sp = obs::span(it & 3, Phase::Dot);
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after, before,
        "disabled span path allocated {} times over 100k calls",
        after - before
    );
    assert!(tr.is_empty());

    // warm metrics feeding (existing &'static str entry) is also
    // allocation-free -- it is on every step path unconditionally
    let m = obs::metrics();
    m.observe("obs_overhead.probe_s", 1.0e-3); // creates the entry
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..10_000usize {
        m.observe("obs_overhead.probe_s", 2.0e-3);
        m.counter_add("obs_overhead.probe_s_ticks", 0);
    }
    // the counter entry was created inside the loop's first pass: one
    // node insertion is permitted, steady state must be flat
    let after = ALLOCS.load(Ordering::Relaxed);
    assert!(
        after - before <= 1,
        "warm metrics path allocated {} times over 10k observations",
        after - before
    );

    // positive control: the counting allocator really counts -- an
    // *enabled* span must allocate (first push into an empty shard)
    tr.set_enabled(true);
    let before = ALLOCS.load(Ordering::Relaxed);
    {
        let _sp = obs::span(0, Phase::Spmv);
    }
    tr.set_enabled(false);
    assert!(
        ALLOCS.load(Ordering::Relaxed) > before,
        "counting allocator saw no allocation from an enabled span"
    );
    assert_eq!(tr.len(), 1);
    tr.clear();
}
