//! Property tests for the million-element hot path (DESIGN.md §11):
//!
//! * the SELL kernel is a *bitwise* drop-in for the CSR row gather
//!   [`spmv_rows`] on random sparsity patterns, for any row subset in
//!   any order, with rows wider than [`SELL_MAX_WIDTH`] refusing to
//!   build (which is what forces the CSR fallback in [`RankSpmv`]);
//! * pattern-reuse assembly reproduces the triplet + stable-sort
//!   construction exactly -- same structure, same bits -- on every
//!   registered scenario's first-step mesh.

use phg_dlb::exec::{spmv_rows, RankSpmv};
use phg_dlb::fem::{
    assemble, assemble_with_pattern, AssemblyPattern, Csr, DofMap, SellF64, SELL_MAX_WIDTH,
};
use phg_dlb::mesh::topology::LeafTopology;
use phg_dlb::scenario::{Scenario, SCENARIOS};
use phg_dlb::util::rng::Pcg32;

/// A random sparse matrix: `n` rows, per-row width drawn from
/// `[0, max_width]`, duplicate columns allowed (the triplet fold
/// handles them), values from a normal so signs and magnitudes vary.
fn random_csr(rng: &mut Pcg32, n: usize, max_width: usize) -> Csr {
    let mut trips = Vec::new();
    for r in 0..n as u32 {
        let w = rng.gen_range(max_width + 1);
        for _ in 0..w {
            let c = rng.gen_range(n) as u32;
            trips.push((r, c, rng.gen_normal()));
        }
    }
    Csr::from_triplets(n, trips)
}

fn random_x(rng: &mut Pcg32, n: usize) -> Vec<f64> {
    (0..n)
        .map(|_| match rng.gen_range(8) {
            // stress the padding contract: signed zeros and exact
            // negatives must not leak through ghost lanes
            0 => -0.0,
            1 => 0.0,
            _ => rng.gen_normal(),
        })
        .collect()
}

#[test]
fn sell_spmv_is_bitwise_identical_to_csr_on_random_patterns() {
    let mut rng = Pcg32::new(0x5e11);
    for trial in 0..40 {
        let n = 5 + rng.gen_range(120);
        let max_w = 1 + rng.gen_range(SELL_MAX_WIDTH.min(n));
        let a = random_csr(&mut rng, n, max_w);
        let x = random_x(&mut rng, n);

        // any subset of rows, in any order: full ascending, a strided
        // subset, and a shuffled subset
        let full: Vec<u32> = (0..n as u32).collect();
        let strided: Vec<u32> = (0..n as u32).step_by(3).collect();
        let mut shuffled = full.clone();
        rng.shuffle(&mut shuffled);
        shuffled.truncate(n / 2 + 1);

        for rows in [&full, &strided, &shuffled] {
            let sell = SellF64::build(&a, rows)
                .unwrap_or_else(|| panic!("trial {trial}: width {max_w} must build"));
            let mut y_ref = vec![f64::NAN; n];
            let mut y_sell = vec![f64::NAN; n];
            spmv_rows(&a, rows, &x, &mut y_ref);
            sell.spmv(&x, &mut y_sell);
            for &r in rows.iter() {
                let (c, s) = (y_ref[r as usize], y_sell[r as usize]);
                assert_eq!(
                    c.to_bits(),
                    s.to_bits(),
                    "trial {trial}: row {r} diverged: csr {c:e} sell {s:e}"
                );
            }
            // rows outside the subset are untouched by both kernels
            let touched: std::collections::HashSet<u32> = rows.iter().copied().collect();
            for r in 0..n {
                if !touched.contains(&(r as u32)) {
                    assert!(y_sell[r].is_nan(), "trial {trial}: row {r} written");
                }
            }
        }
    }
}

#[test]
fn rows_wider_than_ell_width_refuse_sell_and_fall_back_to_csr() {
    let mut rng = Pcg32::new(0x1de);
    let n = SELL_MAX_WIDTH + 16;
    // one dense row pushes past the width cap
    let mut trips: Vec<(u32, u32, f64)> = (0..n as u32).map(|c| (3, c, 1.0)).collect();
    for r in 0..n as u32 {
        trips.push((r, r, 2.0 + rng.gen_f64()));
    }
    let a = Csr::from_triplets(n, trips);
    let rows: Vec<u32> = (0..n as u32).collect();
    assert!(SellF64::build(&a, &rows).is_none(), "a {n}-wide row must refuse the SELL layout");
    // ...but only if the wide row is actually in the subset
    let without: Vec<u32> = rows.iter().copied().filter(|&r| r != 3).collect();
    assert!(SellF64::build(&a, &without).is_some());

    // the per-rank kernel selector takes the CSR fallback whenever
    // either split contains the wide row
    let (interior, boundary) = without.split_at(without.len() / 2);
    assert!(RankSpmv::build(&a, interior, boundary).is_sell());
    assert!(!RankSpmv::build(&a, &rows[..8], &rows[..8]).is_sell());
}

#[test]
fn pattern_assembly_reproduces_triplet_assembly_on_every_scenario() {
    for spec in &SCENARIOS {
        let scen = (spec.make)();
        let mesh = scen.default_mesh();
        let topo = LeafTopology::build(&mesh);
        let dof = DofMap::build(&mesh, &topo);
        let src = dof.eval_at_dofs(&mesh, |p| (1.3 * p.x).sin() + 0.7 * p.y - p.z);

        let reference = assemble(&mesh, &topo, &dof, &src, None);
        let pat = AssemblyPattern::build(&mesh, &topo, &dof);
        let fast = assemble_with_pattern(&mesh, &topo, &dof, &src, &pat);

        assert_eq!(reference.k.row_ptr, fast.k.row_ptr, "{}: K structure", spec.name);
        assert_eq!(reference.k.col_idx, fast.k.col_idx, "{}: K columns", spec.name);
        for (i, (a, b)) in reference.k.vals.iter().zip(&fast.k.vals).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{}: K slot {i}", spec.name);
        }
        for (i, (a, b)) in reference.m.vals.iter().zip(&fast.m.vals).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{}: M slot {i}", spec.name);
        }
        for (i, (a, b)) in reference.b.iter().zip(&fast.b).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{}: b[{i}]", spec.name);
        }
    }
}
