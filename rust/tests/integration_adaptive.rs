//! Integration tests: full adaptive loops exercising every layer
//! together (mesh + refine + estimate + partition + remap + migrate +
//! assemble + solve), on small meshes so the suite stays fast.

use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::dlb::Registry;
use phg_dlb::fem::SolverOpts;
use phg_dlb::mesh::generator;

/// Executor under test: `PHG_EXEC=threads cargo test` re-runs the
/// whole suite on the shared-memory executor (the CI tier-1 matrix).
fn exec_from_env() -> String {
    std::env::var("PHG_EXEC").unwrap_or_else(|_| "virtual".to_string())
}

fn cfg(method: &str, nparts: usize, nsteps: usize) -> DriverConfig {
    DriverConfig {
        problem: "helmholtz".to_string(),
        nparts,
        method: method.to_string(),
        trigger: "lambda".to_string(),
        weights: "unit".to_string(),
        strategy: "scratch".to_string(),
        exec: exec_from_env(),
        exec_threads: 0,
        lambda_trigger: 1.1,
        theta_refine: 0.45,
        theta_coarsen: 0.0,
        max_elements: 30_000,
        solver: SolverOpts {
            tol: 1e-5,
            max_iter: 600,
        },
        use_pjrt: false,
        nsteps,
        dt: 1.5e-3,
    }
}

#[test]
fn full_lineup_helmholtz_cylinder() {
    // every method must drive the paper's primary experiment without
    // losing mesh invariants or load control
    for name in Registry::paper_names() {
        let mesh = generator::omega1_cylinder(2);
        let mut d = AdaptiveDriver::new(mesh, cfg(name, 8, 3)).unwrap();
        d.run();
        d.mesh.check_invariants().unwrap();
        assert_eq!(d.timeline.records.len(), 3, "{name}");
        let last = d.timeline.records.last().unwrap();
        assert!(
            last.imbalance_after < 1.35,
            "{name}: final imbalance {}",
            last.imbalance_after
        );
        assert!(last.l2_error.is_finite() && last.l2_error > 0.0);
    }
}

#[test]
fn helmholtz_error_converges_with_dlb_active() {
    let mesh = generator::cube_mesh(3);
    let mut d = AdaptiveDriver::new(mesh, cfg("RTK", 6, 5)).unwrap();
    d.run();
    let first = &d.timeline.records[0];
    let last = d.timeline.records.last().unwrap();
    assert!(last.n_dofs > first.n_dofs);
    assert!(
        last.l2_error < first.l2_error,
        "L2 {} -> {}",
        first.l2_error,
        last.l2_error
    );
}

#[test]
fn parabolic_with_coarsening_stays_bounded() {
    let mesh = generator::cube_mesh(3);
    let mut c = cfg("PHG/HSFC", 6, 6);
    c.problem = "parabolic".to_string();
    c.theta_coarsen = 0.05;
    c.max_elements = 20_000;
    let mut d = AdaptiveDriver::new(mesh, c).unwrap();
    d.run();
    d.mesh.check_invariants().unwrap();
    for r in &d.timeline.records {
        assert!(r.max_error < 0.2, "step {}: err {}", r.step, r.max_error);
        assert!(r.n_elements <= 40_000);
    }
}

#[test]
fn dlb_actually_reduces_imbalance_on_skewed_load() {
    // refine only one corner so one rank becomes heavily overloaded,
    // then verify a single DLB pass restores balance for each method
    for name in Registry::paper_names() {
        let mesh = generator::cube_mesh(3);
        let mut d = AdaptiveDriver::new(mesh, cfg(name, 8, 1)).unwrap();
        // induce skew: refine the elements of rank 0 twice
        for _ in 0..2 {
            let marked: Vec<_> = d
                .mesh
                .leaves_unordered()
                .into_iter()
                .filter(|&id| d.mesh.elem(id).owner == 0)
                .collect();
            d.mesh.refine(&marked);
        }
        let leaves = d.mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let lam0 = d.pipeline.dist.imbalance(&d.mesh, &leaves, &weights);
        assert!(lam0 > 1.3, "{name}: skew not induced ({lam0})");
        d.step();
        let rec = d.timeline.records.last().unwrap();
        assert!(rec.repartitioned, "{name}: DLB did not trigger");
        assert!(
            rec.imbalance_after < 1.2,
            "{name}: lambda {} after DLB",
            rec.imbalance_after
        );
    }
}

#[test]
fn migration_consistency_owner_count_matches_partition() {
    use phg_dlb::dist::{migrate, NetworkModel};
    use phg_dlb::partition::PartitionInput;

    let mut mesh = generator::cube_mesh(3);
    let leaves = mesh.leaves_unordered();
    let weights = vec![1.0; leaves.len()];
    phg_dlb::dist::Distribution::new(5).assign_blocks(&mut mesh, &leaves);
    let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
    let p = Registry::create("PHG/HSFC").unwrap();
    let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 5);
    let r = p.partition(&input);
    let net = NetworkModel::infiniband(5);
    migrate(&mut mesh, &leaves, &r.parts, &weights, &net);
    for (i, &id) in leaves.iter().enumerate() {
        assert_eq!(mesh.elem(id).owner, r.parts[i]);
    }
}

#[test]
fn pjrt_and_native_drivers_agree_on_errors() {
    // same scenario through both engines: the L2/L1 artifacts must
    // reproduce the native numerics to f32 accuracy
    let run = |use_pjrt: bool| -> Vec<f64> {
        let mesh = generator::cube_mesh(2);
        let mut c = cfg("RTK", 4, 3);
        c.use_pjrt = use_pjrt;
        let mut d = AdaptiveDriver::new(mesh, c).unwrap();
        d.run();
        d.timeline.records.iter().map(|r| r.l2_error).collect()
    };
    let native = run(false);
    let pjrt = run(true);
    // if artifacts are missing the pjrt run silently used native; the
    // comparison is then trivially exact, which is fine
    for (a, b) in native.iter().zip(&pjrt) {
        let rel = (a - b).abs() / a.abs().max(1e-12);
        assert!(rel < 2e-2, "L2 errors diverge: {a} vs {b}");
    }
}
