//! Cross-module property tests: invariants that must hold for EVERY
//! partitioning method on randomized meshes, weights and process
//! counts -- the proptest-style layer over the whole L3 coordinator
//! surface (see util::propcheck; the proptest crate is not vendored).

use phg_dlb::dlb::Registry;
use phg_dlb::dist::Distribution;
use phg_dlb::mesh::{generator, TetMesh};
use phg_dlb::partition::metrics::migration_volume;
use phg_dlb::partition::PartitionInput;
use phg_dlb::remap::{apply_map, oliker_biswas, SimilarityMatrix};
use phg_dlb::util::propcheck;
use phg_dlb::util::rng::Pcg32;

const ALL_METHODS: [&str; 7] = [
    "RTK",
    "MSFC",
    "PHG/HSFC",
    "Zoltan/HSFC",
    "RCB",
    "RIB",
    "ParMETIS",
];

/// Random adaptive mesh: a cube or cylinder with 1-3 rounds of random
/// local refinement.
fn random_mesh(rng: &mut Pcg32) -> TetMesh {
    let mut mesh = if rng.gen_bool(0.5) {
        generator::cube_mesh(2)
    } else {
        generator::cylinder_mesh(6, 2, 0.5, 3.0)
    };
    let rounds = 1 + rng.gen_range(2);
    for _ in 0..rounds {
        let leaves = mesh.leaves_unordered();
        let marked: Vec<_> = leaves
            .into_iter()
            .filter(|_| rng.gen_bool(0.4))
            .collect();
        mesh.refine(&marked);
    }
    mesh
}

#[test]
fn every_method_assigns_every_leaf_in_range() {
    propcheck::check_with(101, 12, "partition completeness", |rng| {
        let mut mesh = random_mesh(rng);
        let leaves = mesh.leaves_unordered();
        let weights: Vec<f64> = (0..leaves.len())
            .map(|_| rng.gen_uniform(0.5, 2.0))
            .collect();
        let nparts = 2 + rng.gen_range(14);
        Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
        let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let method = ALL_METHODS[rng.gen_range(ALL_METHODS.len())];
        let p = Registry::create(method).unwrap();
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, nparts);
        let r = p.partition(&input);
        assert_eq!(r.parts.len(), leaves.len(), "{method}");
        assert!(
            r.parts.iter().all(|&x| (x as usize) < nparts),
            "{method}: part out of range"
        );
    });
}

#[test]
fn every_method_controls_imbalance() {
    propcheck::check_with(202, 10, "partition balance bound", |rng| {
        let mut mesh = random_mesh(rng);
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0f64; leaves.len()];
        let nparts = 2 + rng.gen_range(6);
        Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
        let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let method = ALL_METHODS[rng.gen_range(ALL_METHODS.len())];
        let p = Registry::create(method).unwrap();
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, nparts);
        let r = p.partition(&input);
        let mut wsum = vec![0.0; nparts];
        for (i, &part) in r.parts.iter().enumerate() {
            wsum[part as usize] += weights[i];
        }
        let lam = phg_dlb::util::stats::imbalance(&wsum);
        // generous uniform bound: every method should stay under 1.35
        // on unit weights at these sizes (graph methods allow epsilon,
        // geometric methods can strand a few elements at splitters)
        assert!(lam < 1.35, "{method}: imbalance {lam} (p={nparts})");
    });
}

#[test]
fn remap_never_increases_migration() {
    propcheck::check_with(303, 10, "remap reduces TotalV", |rng| {
        let mut mesh = random_mesh(rng);
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0f64; leaves.len()];
        let nparts = 2 + rng.gen_range(8);
        Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
        let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let method = ALL_METHODS[rng.gen_range(ALL_METHODS.len())];
        let p = Registry::create(method).unwrap();
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, nparts);
        let r = p.partition(&input);

        let before = migration_volume(&owners, &r.parts, &weights, nparts);
        let sim = SimilarityMatrix::build(&owners, &r.parts, &weights, nparts, nparts);
        let remap = oliker_biswas(&sim);
        let mut parts = r.parts.clone();
        apply_map(&mut parts, &remap.map);
        let after = migration_volume(&owners, &parts, &weights, nparts);
        assert!(
            after.total_v <= before.total_v + 1e-9,
            "{method}: remap increased TotalV {} -> {}",
            before.total_v,
            after.total_v
        );
    });
}

#[test]
fn refinement_preserves_volume_and_conformity_under_random_marking() {
    propcheck::check_with(404, 10, "refine/coarsen fuzz", |rng| {
        let mut mesh = generator::cube_mesh(2);
        let v0 = mesh.total_volume();
        for _ in 0..3 {
            let leaves = mesh.leaves_unordered();
            if rng.gen_bool(0.7) {
                let marked: Vec<_> = leaves
                    .into_iter()
                    .filter(|_| rng.gen_bool(0.3))
                    .collect();
                mesh.refine(&marked);
            } else {
                let marked: Vec<_> = leaves
                    .into_iter()
                    .filter(|_| rng.gen_bool(0.5))
                    .collect();
                mesh.coarsen(&marked);
            }
            mesh.check_invariants().unwrap();
            assert!((mesh.total_volume() - v0).abs() < 1e-9);
        }
    });
}

#[test]
fn rtk_respects_dfs_contiguity_on_random_weights() {
    propcheck::check_with(505, 10, "rtk contiguity", |rng| {
        let mut mesh = random_mesh(rng);
        let leaves = mesh.leaves_unordered();
        let weights: Vec<f64> = (0..leaves.len())
            .map(|_| rng.gen_uniform(0.1, 3.0))
            .collect();
        let nparts = 2 + rng.gen_range(8);
        Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
        let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let p = Registry::create("RTK").unwrap();
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, nparts);
        let r = p.partition(&input);
        let index_of: std::collections::HashMap<u32, usize> = leaves
            .iter()
            .enumerate()
            .map(|(i, &id)| (id, i))
            .collect();
        let dfs = mesh.leaves_dfs();
        let seq: Vec<u16> = dfs.iter().map(|id| r.parts[index_of[id]]).collect();
        for w in seq.windows(2) {
            assert!(w[0] <= w[1], "RTK parts not monotone in DFS order");
        }
    });
}

#[test]
fn failure_injection_degenerate_inputs() {
    // zero weights, single part, more parts than elements
    let mut mesh = generator::cube_mesh(1);
    let leaves = mesh.leaves_unordered();
    Distribution::new(2).assign_blocks(&mut mesh, &leaves);
    let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();

    for method in ALL_METHODS {
        let p = Registry::create(method).unwrap();
        // all-zero weights must not panic or divide by zero
        let zero_w = vec![0.0f64; leaves.len()];
        let input = PartitionInput::from_mesh(&mesh, &leaves, &zero_w, &owners, 3);
        let r = p.partition(&input);
        assert_eq!(r.parts.len(), leaves.len(), "{method} zero weights");

        // single part
        let w = vec![1.0f64; leaves.len()];
        let input = PartitionInput::from_mesh(&mesh, &leaves, &w, &owners, 1);
        let r = p.partition(&input);
        assert!(r.parts.iter().all(|&x| x == 0), "{method} single part");

        // more parts than elements (6 leaves, 10 parts): must not panic
        let input = PartitionInput::from_mesh(&mesh, &leaves, &w, &owners, 10);
        let r = p.partition(&input);
        assert!(
            r.parts.iter().all(|&x| (x as usize) < 10),
            "{method} overpartition"
        );
    }
}
