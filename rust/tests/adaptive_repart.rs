//! Subsystem tests for multilevel k-way adaptive repartitioning
//! (`AdaptiveRepart`): the `itr` tradeoff's two limits (minimal
//! migration vs scratch-quality cut), fixed-seed determinism through
//! the registry and the pipeline, the owner-projection invariant of
//! the restricted coarsening, and the `Auto` strategy's three-way
//! modeled argmin.

use phg_dlb::dist::Distribution;
use phg_dlb::dlb::{RebalancePipeline, Registry, RepartitionStrategy};
use phg_dlb::mesh::topology::LeafTopology;
use phg_dlb::mesh::{generator, ElemId, TetMesh};
use phg_dlb::partition::diffusion::DiffusionRepartitioner;
use phg_dlb::partition::graph::adaptive::owner_constrained_matching;
use phg_dlb::partition::graph::CsrGraph;
use phg_dlb::partition::metrics::migration_volume;
use phg_dlb::partition::{PartitionInput, Partitioner};
use phg_dlb::util::rng::Pcg32;
use phg_dlb::util::stats::imbalance;

fn owners_of(mesh: &TetMesh, leaves: &[ElemId]) -> Vec<u16> {
    leaves.iter().map(|&id| mesh.elem(id).owner).collect()
}

fn rank_loads(parts: &[u16], weights: &[f64], p: usize) -> Vec<f64> {
    let mut l = vec![0.0; p];
    for (&r, &w) in parts.iter().zip(weights) {
        l[r as usize] += w;
    }
    l
}

fn cut_of(mesh: &TetMesh, leaves: &[ElemId], parts: &[u16]) -> usize {
    LeafTopology::build_for(mesh, leaves.to_vec()).interface_faces(parts)
}

/// Mild *scattered* skew: every other rank refines every third of its
/// elements once (same regime as tests/diffusion.rs).
fn mild_scattered(nparts: usize) -> (TetMesh, Vec<ElemId>) {
    let mut mesh = generator::cube_mesh(4);
    let leaves = mesh.leaves_unordered();
    Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
    let marked: Vec<_> = mesh
        .leaves_unordered()
        .into_iter()
        .enumerate()
        .filter(|(i, id)| mesh.elem(*id).owner % 2 == 0 && i % 3 == 0)
        .map(|(_, id)| id)
        .collect();
    mesh.refine(&marked);
    let leaves = mesh.leaves_unordered();
    (mesh, leaves)
}

/// Severe refinement front: one rank's block refined twice.
fn refinement_front(nparts: usize) -> (TetMesh, Vec<ElemId>) {
    let mut mesh = generator::cube_mesh(3);
    let leaves = mesh.leaves_unordered();
    Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
    for _ in 0..2 {
        let marked: Vec<_> = mesh
            .leaves_unordered()
            .into_iter()
            .filter(|&id| mesh.elem(id).owner == 0)
            .collect();
        mesh.refine(&marked);
    }
    let leaves = mesh.leaves_unordered();
    (mesh, leaves)
}

#[test]
fn itr_zero_degenerates_toward_minimal_migration() {
    // itr = 0 scores moves by migration alone: the only accepted moves
    // drain overweight parts, so TotalV must not exceed the diffusive
    // flow realization (which balances to the *tighter* lambda_tol =
    // 0.01 < the refiner's epsilon = 0.03 and therefore moves more)
    let nparts = 8;
    let (mesh, leaves) = mild_scattered(nparts);
    let weights = vec![1.0f64; leaves.len()];
    let owners = owners_of(&mesh, &leaves);
    let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, nparts);

    let adaptive = Registry::create("AdaptiveRepart:itr=0").unwrap();
    let a = adaptive.partition(&input);
    let a_v = migration_volume(&owners, &a.parts, &weights, nparts).total_v;

    let d = DiffusionRepartitioner::new().partition(&input);
    let d_v = migration_volume(&owners, &d.parts, &weights, nparts).total_v;

    assert!(
        a_v <= d_v + 1e-9,
        "itr=0 moved {a_v}, more than diffusion's {d_v}"
    );
    // and it still lands under the refiner's (looser) balance target
    let lam = imbalance(&rank_loads(&a.parts, &weights, nparts));
    assert!(lam <= 1.1, "itr=0 left lambda {lam}");
}

#[test]
fn itr_large_tracks_scratch_cut_and_the_spec_string_changes_behavior() {
    // the cut-focused limit: itr -> infinity ignores migration, so the
    // refined cut must track the scratch multilevel partitioner's
    let nparts = 8;
    let (mesh, leaves) = mild_scattered(nparts);
    let weights = vec![1.0f64; leaves.len()];
    let owners = owners_of(&mesh, &leaves);
    let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, nparts);

    let scratch = Registry::create("ParMETIS").unwrap().partition(&input);
    let s_cut = cut_of(&mesh, &leaves, &scratch.parts);

    let hi = Registry::create("AdaptiveRepart:itr=1e9").unwrap().partition(&input);
    let hi_cut = cut_of(&mesh, &leaves, &hi.parts);
    // +2 faces of absolute slack so a near-zero scratch cut cannot
    // turn the 1.2x ratio into an impossible bound
    assert!(
        hi_cut as f64 <= 1.2 * s_cut as f64 + 2.0,
        "itr=1e9 cut {hi_cut} vs scratch cut {s_cut}"
    );

    // `--method AdaptiveRepart:itr=<x>` round-trips behaviorally: the
    // two ends of the knob migrate and cut differently in the
    // documented monotone directions
    let lo = Registry::create("AdaptiveRepart:itr=0").unwrap().partition(&input);
    let lo_v = migration_volume(&owners, &lo.parts, &weights, nparts).total_v;
    let hi_v = migration_volume(&owners, &hi.parts, &weights, nparts).total_v;
    let lo_cut = cut_of(&mesh, &leaves, &lo.parts);
    assert!(
        lo_v <= hi_v + 1e-9,
        "itr=0 migrated {lo_v}, more than itr=1e9's {hi_v}"
    );
    assert!(
        hi_cut <= lo_cut + 2,
        "itr=1e9 cut {hi_cut} worse than cut-blind itr=0's {lo_cut}"
    );
}

#[test]
fn fixed_seed_is_deterministic_through_registry_and_pipeline() {
    let nparts = 6;
    let (mesh, leaves) = mild_scattered(nparts);
    let weights = vec![1.0f64; leaves.len()];
    let owners = owners_of(&mesh, &leaves);
    let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, nparts);

    // same instance twice, and a second registry instance
    let a = Registry::create("AdaptiveRepart").unwrap();
    let r1 = a.partition(&input);
    let r2 = a.partition(&input);
    let r3 = Registry::create("AdaptiveRepart").unwrap().partition(&input);
    assert_eq!(r1.parts, r2.parts);
    assert_eq!(r1.parts, r3.parts);

    // and end-to-end: two independent pipelines produce bit-identical
    // adaptive rebalances (report and migrated ownership)
    let run = || {
        let pipe = RebalancePipeline::from_method("ParMETIS", nparts).unwrap();
        let mut m = mesh.clone();
        let rep = pipe.rebalance_as(RepartitionStrategy::Adaptive, &mut m, &leaves, &weights);
        (rep, owners_of(&m, &leaves))
    };
    let (rep1, own1) = run();
    let (rep2, own2) = run();
    assert_eq!(own1, own2);
    assert_eq!(rep1.method, "AdaptiveRepart");
    assert!((rep1.lambda_after - rep2.lambda_after).abs() < 1e-12);
    assert!((rep1.volume.total_v - rep2.volume.total_v).abs() < 1e-9);
}

#[test]
fn owner_restricted_coarsening_projects_the_partition_at_every_level() {
    let nparts = 6;
    let (mesh, leaves) = mild_scattered(nparts);
    let owners = owners_of(&mesh, &leaves);
    let (xadj, adjncy) = LeafTopology::build_for(&mesh, leaves.clone()).dual_graph_csr();
    let adjwgt = vec![1.0; adjncy.len()];
    let vwgt = vec![1.0; leaves.len()];
    let g = CsrGraph {
        xadj,
        adjncy,
        adjwgt,
        vwgt,
    };
    let total = g.total_vwgt();

    let mut rng = Pcg32::new(42);
    let mut cur = g;
    let mut cur_owners = owners;
    let mut levels = 0;
    while cur.n() > 4 * nparts {
        let (coarse, map, cowners) = owner_constrained_matching(&cur, &cur_owners, &mut rng);
        // the invariant that makes the method adaptive: the current
        // partition projects exactly onto every level
        for (v, &o) in cur_owners.iter().enumerate() {
            assert_eq!(
                o, cowners[map[v] as usize],
                "level {levels}: vertex {v} crossed an owner boundary"
            );
        }
        assert!(
            (coarse.total_vwgt() - total).abs() < 1e-9 * total,
            "level {levels} lost vertex weight"
        );
        if coarse.n() as f64 > 0.95 * cur.n() as f64 {
            break; // stalled: no same-owner matchable edges left
        }
        cur = coarse;
        cur_owners = cowners;
        levels += 1;
    }
    assert!(levels >= 2, "hierarchy too shallow: {levels} levels");
}

#[test]
fn auto_picks_the_modeled_cheapest_of_all_three_strategies() {
    // replicate the pipeline's argmin (candidates in ascending-
    // migration tie order, strict <) from the public estimate API
    let manual_argmin = |pipe: &RebalancePipeline,
                         mesh: &TetMesh,
                         leaves: &[ElemId],
                         weights: &[f64],
                         solve: f64,
                         wall: f64|
     -> RepartitionStrategy {
        let mut best: Option<(RepartitionStrategy, f64)> = None;
        for s in [
            RepartitionStrategy::Diffusive,
            RepartitionStrategy::Adaptive,
            RepartitionStrategy::Scratch,
        ] {
            let (est, lam) = pipe.estimate_for(s, mesh, leaves, weights, solve, wall);
            let total = est.rebalance_cost + solve * (lam - 1.0).max(0.0);
            if best.map(|(_, b)| total < b).unwrap_or(true) {
                best = Some((s, total));
            }
        }
        best.unwrap().0
    };

    let nparts = 8;

    // cell 1 -- mild scattered skew, no solve context: the short-haul
    // flow makes diffusion the cheapest event
    let (mesh, leaves) = mild_scattered(nparts);
    let weights = vec![1.0f64; leaves.len()];
    let pipe = RebalancePipeline::from_method("PHG/HSFC", nparts)
        .unwrap()
        .with_strategy(RepartitionStrategy::Auto);
    let chosen = pipe.resolve_strategy(&mesh, &leaves, &weights, 0.0, 1e-3);
    assert_eq!(chosen, manual_argmin(&pipe, &mesh, &leaves, &weights, 0.0, 1e-3));
    assert_eq!(chosen, RepartitionStrategy::Diffusive, "mild cell");

    // cell 2 -- severe front, starved sweep budget, cheap scratch
    // wall: the diffusive residual is priced out and scratch wins
    let (mesh, leaves) = refinement_front(nparts);
    let weights = vec![1.0f64; leaves.len()];
    let mut pipe = RebalancePipeline::from_method("PHG/HSFC", nparts)
        .unwrap()
        .with_strategy(RepartitionStrategy::Auto);
    pipe.diffusion.max_sweeps = 1;
    let chosen = pipe.resolve_strategy(&mesh, &leaves, &weights, 10.0, 1e-3);
    assert_eq!(chosen, manual_argmin(&pipe, &mesh, &leaves, &weights, 10.0, 1e-3));
    assert_eq!(chosen, RepartitionStrategy::Scratch, "front/cheap-wall cell");

    // cell 3 -- same severe front, but the scratch wall is expensive
    // and the adaptive EWMA is primed by a real adaptive rebalance:
    // AdaptiveRepart is the only candidate that both restores balance
    // (unlike the starved diffusion) and avoids the scratch wall
    let mut pipe = RebalancePipeline::from_method("PHG/HSFC", nparts)
        .unwrap()
        .with_strategy(RepartitionStrategy::Auto);
    pipe.diffusion.max_sweeps = 1;
    assert!(pipe.adaptive_wall_estimate().is_none());
    let mut primer = mesh.clone();
    pipe.rebalance_as(RepartitionStrategy::Adaptive, &mut primer, &leaves, &weights);
    assert!(pipe.adaptive_wall_estimate().is_some());
    let chosen = pipe.resolve_strategy(&mesh, &leaves, &weights, 10.0, 10.0);
    assert_eq!(chosen, manual_argmin(&pipe, &mesh, &leaves, &weights, 10.0, 10.0));
    assert_eq!(chosen, RepartitionStrategy::Adaptive, "front/dear-wall cell");
}
