//! Cross-executor equivalence: `--exec threads` must be a pure
//! execution-schedule change, never a numerics change. Every
//! registered scenario runs the adaptive loop under both executors
//! and must produce identical step invariants and solutions agreeing
//! to <= 1e-10 relative L2 (the design actually delivers bitwise
//! equality -- DESIGN.md §9's deterministic-reduction rule), and the
//! threaded executor must be run-to-run deterministic.

use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::fem::SolverOpts;
use phg_dlb::scenario::SCENARIOS;

fn cfg(problem: &str, exec: &str) -> DriverConfig {
    DriverConfig {
        problem: problem.to_string(),
        nparts: 4,
        method: "PHG/HSFC".to_string(),
        trigger: "lambda".to_string(),
        weights: "unit".to_string(),
        strategy: "scratch".to_string(),
        exec: exec.to_string(),
        exec_threads: 0,
        lambda_trigger: 1.1,
        theta_refine: 0.4,
        theta_coarsen: 0.03,
        max_elements: 30_000,
        solver: SolverOpts {
            tol: 1e-5,
            max_iter: 600,
        },
        use_pjrt: false,
        nsteps: 3,
        dt: 1.5e-3,
    }
}

fn run(problem: &str, exec: &str) -> AdaptiveDriver {
    let mut d = AdaptiveDriver::for_scenario(cfg(problem, exec)).unwrap();
    d.run();
    d
}

fn rel_l2(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "solution lengths differ");
    let diff2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let norm2: f64 = a.iter().map(|x| x * x).sum();
    (diff2 / norm2.max(1e-300)).sqrt()
}

#[test]
fn every_scenario_agrees_across_executors() {
    for spec in &SCENARIOS {
        let dv = run(spec.name, "virtual");
        let dt = run(spec.name, "threads");
        assert_eq!(
            dv.timeline.records.len(),
            dt.timeline.records.len(),
            "{}: step counts differ",
            spec.name
        );
        for (rv, rt) in dv.timeline.records.iter().zip(&dt.timeline.records) {
            let name = spec.name;
            // identical adaptive trajectory: same meshes, same dofs,
            // same solver iteration counts, same DLB decisions
            assert_eq!(rv.n_elements, rt.n_elements, "{name} step {}", rv.step);
            assert_eq!(rv.n_dofs, rt.n_dofs, "{name} step {}", rv.step);
            assert_eq!(
                rv.solve_iterations, rt.solve_iterations,
                "{name} step {}: iteration counts differ",
                rv.step
            );
            assert_eq!(rv.repartitioned, rt.repartitioned, "{name} step {}", rv.step);
            assert_eq!(rv.strategy, rt.strategy, "{name} step {}", rv.step);
            assert_eq!(rv.exec, "virtual");
            assert_eq!(rt.exec, "threads");
            assert!(rt.measured_parallel, "{name}: threads not measured");
            assert!(!rv.measured_parallel, "{name}: virtual claims measurement");
            // errors against the exact solution must agree exactly
            assert_eq!(
                rv.l2_error.to_bits(),
                rt.l2_error.to_bits(),
                "{name} step {}: L2 errors diverge ({} vs {})",
                rv.step,
                rv.l2_error,
                rt.l2_error
            );
        }
        let rel = rel_l2(dv.solution(), dt.solution());
        assert!(
            rel <= 1e-10,
            "{}: solutions diverge, relative L2 {rel}",
            spec.name
        );
    }
}

#[test]
fn threaded_executor_is_run_to_run_deterministic() {
    let first = run("helmholtz", "threads");
    for _ in 0..2 {
        let again = run("helmholtz", "threads");
        assert_eq!(
            first.timeline.records.len(),
            again.timeline.records.len()
        );
        for (a, b) in first.timeline.records.iter().zip(&again.timeline.records) {
            assert_eq!(a.n_elements, b.n_elements);
            assert_eq!(a.n_dofs, b.n_dofs);
            assert_eq!(a.solve_iterations, b.solve_iterations);
            assert_eq!(a.l2_error.to_bits(), b.l2_error.to_bits());
        }
        assert_eq!(first.solution().len(), again.solution().len());
        for (x, y) in first.solution().iter().zip(again.solution()) {
            assert_eq!(x.to_bits(), y.to_bits(), "solution not bit-reproducible");
        }
    }
}

#[test]
fn thread_budget_does_not_change_the_answer() {
    // 4 ranks on 1, 2 and 3 workers: the rank-multiplexed schedules
    // must still be bit-identical (the plan fixes the arithmetic)
    let mut base: Option<Vec<f64>> = None;
    for threads in [1usize, 2, 3] {
        let mut c = cfg("lshape", "threads");
        c.exec_threads = threads;
        let mut d = AdaptiveDriver::for_scenario(c).unwrap();
        d.run();
        let u = d.solution().to_vec();
        match &base {
            None => base = Some(u),
            Some(b) => {
                assert_eq!(b.len(), u.len());
                for (x, y) in b.iter().zip(&u) {
                    assert_eq!(x.to_bits(), y.to_bits(), "threads={threads} diverged");
                }
            }
        }
    }
}

#[test]
fn measured_weights_learn_from_threaded_timings() {
    // the Measured model fed by genuine per-rank walls must still
    // drive the loop with controlled imbalance
    let mut c = cfg("parabolic", "threads");
    c.weights = "measured".to_string();
    c.nsteps = 3;
    let mut d = AdaptiveDriver::for_scenario(c).unwrap();
    d.run();
    assert_eq!(d.timeline.records.len(), 3);
    for r in &d.timeline.records {
        assert!(r.measured_parallel);
        assert!(r.solve_imbalance >= 1.0);
        // the weights come from real wall clocks, so only sanity-check
        // the invariants, never a tight bound (a descheduled CI rank
        // can legitimately skew one step's measured profile)
        assert!(r.imbalance_after.is_finite() && r.imbalance_after >= 1.0);
        assert!(r.l2_error.is_finite() && r.l2_error > 0.0);
    }
}
