//! Scenario conformance: every registered scenario must drive the
//! generic adaptive loop end to end on its own default mesh and keep
//! the StepRecord contract -- the property suite a new registry entry
//! has to pass before it counts as a scenario.

use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::fem::SolverOpts;
use phg_dlb::scenario::{ScenarioRegistry, SCENARIOS};

/// Executor under test: `PHG_EXEC=threads cargo test` re-runs the
/// whole suite on the shared-memory executor (the CI tier-1 matrix).
fn exec_from_env() -> String {
    std::env::var("PHG_EXEC").unwrap_or_else(|_| "virtual".to_string())
}

fn quick_cfg(problem: &str) -> DriverConfig {
    DriverConfig {
        problem: problem.to_string(),
        nparts: 4,
        method: "PHG/HSFC".to_string(),
        trigger: "lambda".to_string(),
        weights: "unit".to_string(),
        strategy: "scratch".to_string(),
        exec: exec_from_env(),
        exec_threads: 0,
        lambda_trigger: 1.1,
        theta_refine: 0.4,
        theta_coarsen: 0.03,
        max_elements: 30_000,
        solver: SolverOpts {
            tol: 1e-5,
            max_iter: 600,
        },
        use_pjrt: false,
        nsteps: 3,
        dt: 1.5e-3,
    }
}

#[test]
fn every_scenario_upholds_the_step_record_contract() {
    for spec in &SCENARIOS {
        let mut d = AdaptiveDriver::for_scenario(quick_cfg(spec.name)).unwrap();
        d.run();
        assert_eq!(d.timeline.records.len(), 3, "{}: short run", spec.name);
        d.mesh.check_invariants().unwrap();
        for r in &d.timeline.records {
            let name = spec.name;
            assert!(r.n_dofs > 0, "{name}: step {} has no dofs", r.step);
            assert!(r.n_elements > 0, "{name}: step {} has no elements", r.step);
            assert!(r.solve_iterations > 0, "{name}: solver did not run");
            assert!(
                r.solve_imbalance >= 1.0,
                "{name}: solve_imbalance {} < 1",
                r.solve_imbalance
            );
            assert!(
                r.imbalance_before >= 1.0 && r.imbalance_after >= 1.0,
                "{name}: lambda below 1"
            );
            // a strategy and a full report are recorded exactly when a
            // rebalance fired
            assert_eq!(r.repartitioned, r.strategy.is_some(), "{name}");
            assert_eq!(r.repartitioned, r.rebalance.is_some(), "{name}");
            if r.repartitioned {
                assert!(
                    r.imbalance_after <= r.imbalance_before + 1e-9,
                    "{name}: rebalance worsened lambda"
                );
            }
            assert!(
                r.l2_error.is_finite() && r.max_error.is_finite(),
                "{name}: non-finite error"
            );
            if ScenarioRegistry::create(spec.name).unwrap().has_exact() {
                assert!(r.l2_error > 0.0, "{name}: exact solution but zero error");
            }
        }
    }
}

#[test]
fn stationary_scenarios_reduce_error_under_refinement() {
    for spec in &SCENARIOS {
        let scenario = ScenarioRegistry::create(spec.name).unwrap();
        if scenario.time_dependent() || !scenario.has_exact() {
            continue;
        }
        let mut cfg = quick_cfg(spec.name);
        cfg.nsteps = 4;
        let mut d = AdaptiveDriver::for_scenario(cfg).unwrap();
        d.run();
        let first = d.timeline.records.first().unwrap();
        let last = d.timeline.records.last().unwrap();
        assert!(last.n_dofs > first.n_dofs, "{}: mesh did not grow", spec.name);
        assert!(
            last.l2_error < first.l2_error,
            "{}: L2 error not reduced by refinement: {} -> {}",
            spec.name,
            first.l2_error,
            last.l2_error
        );
    }
}

#[test]
fn time_dependent_scenarios_track_their_exact_solution() {
    for spec in &SCENARIOS {
        let scenario = ScenarioRegistry::create(spec.name).unwrap();
        if !scenario.time_dependent() {
            continue;
        }
        let mut cfg = quick_cfg(spec.name);
        cfg.nsteps = 4;
        let mut d = AdaptiveDriver::for_scenario(cfg).unwrap();
        d.run();
        assert_eq!(d.timeline.records.len(), 4, "{}: time must march", spec.name);
        for r in &d.timeline.records {
            assert!(
                r.max_error < 0.2,
                "{}: step {} max error {}",
                spec.name,
                r.step,
                r.max_error
            );
        }
    }
}

#[test]
fn rebalance_events_land_in_the_timeline_csv() {
    // force a rebalance every step; the CSV must carry the events
    let mut cfg = quick_cfg("lshape");
    cfg.trigger = "always".to_string();
    let mut d = AdaptiveDriver::for_scenario(cfg).unwrap();
    d.run();
    assert_eq!(d.timeline.repartition_count(), 3);
    let csv = d.timeline.to_csv();
    assert_eq!(csv.lines().count(), 4); // header + 3 steps
    let header = csv.lines().next().unwrap();
    assert!(header.contains("strategy"));
    for line in csv.lines().skip(1) {
        assert!(
            line.contains(",1,scratch,"),
            "rebalance event missing from CSV row: {line}"
        );
    }
}

#[test]
fn unknown_problem_fails_construction_with_the_valid_list() {
    let err = AdaptiveDriver::for_scenario(quick_cfg("nope"))
        .err()
        .unwrap()
        .to_string();
    for name in ScenarioRegistry::names() {
        assert!(err.contains(name), "error does not list {name}: {err}");
    }
}
