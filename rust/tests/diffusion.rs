//! Subsystem tests for diffusive incremental repartitioning: flow
//! conservation, convergence under the trigger threshold, the
//! migration bound, the acceptance comparison against scratch+remap on
//! mild skew, and the `Auto` strategy's per-event selection.

use phg_dlb::dist::Distribution;
use phg_dlb::dlb::{RebalancePipeline, RepartitionStrategy};
use phg_dlb::mesh::{generator, ElemId, TetMesh};
use phg_dlb::partition::diffusion::{chain_loads, solve_flow, DiffusionRepartitioner};
use phg_dlb::partition::metrics::migration_volume;
use phg_dlb::partition::{PartitionInput, Partitioner};
use phg_dlb::util::stats::imbalance;

fn owners_of(mesh: &TetMesh, leaves: &[ElemId]) -> Vec<u16> {
    leaves.iter().map(|&id| mesh.elem(id).owner).collect()
}

fn rank_loads(parts: &[u16], weights: &[f64], p: usize) -> Vec<f64> {
    let mut l = vec![0.0; p];
    for (&r, &w) in parts.iter().zip(weights) {
        l[r as usize] += w;
    }
    l
}

/// Mild *scattered* skew: every other rank refines every third of its
/// elements once -- many small local surpluses, the diffusion-friendly
/// regime.
fn mild_scattered(nparts: usize) -> (TetMesh, Vec<ElemId>) {
    let mut mesh = generator::cube_mesh(4);
    let leaves = mesh.leaves_unordered();
    Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
    let marked: Vec<_> = mesh
        .leaves_unordered()
        .into_iter()
        .enumerate()
        .filter(|(i, id)| mesh.elem(*id).owner % 2 == 0 && i % 3 == 0)
        .map(|(_, id)| id)
        .collect();
    mesh.refine(&marked);
    let leaves = mesh.leaves_unordered();
    (mesh, leaves)
}

/// Severe refinement front: one end of the block distribution refined
/// twice -- a deep, distant surplus that must travel many chain hops.
fn refinement_front(nparts: usize) -> (TetMesh, Vec<ElemId>) {
    let mut mesh = generator::cube_mesh(3);
    let leaves = mesh.leaves_unordered();
    Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
    for _ in 0..2 {
        let marked: Vec<_> = mesh
            .leaves_unordered()
            .into_iter()
            .filter(|&id| mesh.elem(id).owner == 0)
            .collect();
        mesh.refine(&marked);
    }
    let leaves = mesh.leaves_unordered();
    (mesh, leaves)
}

#[test]
fn diffusion_flow_conserves_total_load() {
    let (mesh, leaves) = mild_scattered(8);
    let weights = vec![1.0f64; leaves.len()];
    let owners = owners_of(&mesh, &leaves);
    let (_, chain) = chain_loads(&mesh, &leaves, &owners, &weights, 8);
    let total_before: f64 = chain.iter().sum();
    let flow = solve_flow(&chain, 4096, 1e-6);
    let total_after: f64 = flow.loads_after.iter().sum();
    assert!(
        (total_after - total_before).abs() < 1e-9 * total_before,
        "flow lost load: {total_before} -> {total_after}"
    );
    // and the realized partition conserves it too (it only relabels)
    let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 8);
    let r = DiffusionRepartitioner::new().partition(&input);
    let realized: f64 = rank_loads(&r.parts, &weights, 8).iter().sum();
    assert!((realized - total_before).abs() < 1e-9 * total_before);
}

#[test]
fn diffusion_beats_trigger_threshold_on_two_rank_step() {
    // two ranks, one refined: the canonical step imbalance. A small
    // sweep budget must already land under the lambda = 1.1 trigger.
    let mut mesh = generator::cube_mesh(3);
    let leaves = mesh.leaves_unordered();
    Distribution::new(2).assign_blocks(&mut mesh, &leaves);
    let marked: Vec<_> = mesh
        .leaves_unordered()
        .into_iter()
        .filter(|&id| mesh.elem(id).owner == 0)
        .collect();
    mesh.refine(&marked);
    let leaves = mesh.leaves_unordered();
    let weights = vec![1.0f64; leaves.len()];
    let owners = owners_of(&mesh, &leaves);
    let before = imbalance(&rank_loads(&owners, &weights, 2));
    assert!(before > 1.2, "skew not induced: {before}");

    let d = DiffusionRepartitioner {
        max_sweeps: 8,
        lambda_tol: 0.0,
    };
    let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 2);
    let r = d.partition(&input);
    let after = imbalance(&rank_loads(&r.parts, &weights, 2));
    assert!(after < 1.1, "lambda {after} after {} sweeps", d.max_sweeps);
}

#[test]
fn diffusion_never_migrates_more_than_the_flow_solution() {
    for (p, (mesh, leaves)) in [(8, mild_scattered(8)), (6, refinement_front(6))] {
        let weights = vec![1.0f64; leaves.len()];
        let owners = owners_of(&mesh, &leaves);
        let d = DiffusionRepartitioner::new();
        let (_, chain) = chain_loads(&mesh, &leaves, &owners, &weights, p);
        let flow = solve_flow(&chain, d.max_sweeps, d.lambda_tol);
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, p);
        let r = d.partition(&input);
        let mv = migration_volume(&owners, &r.parts, &weights, p);
        assert!(
            mv.total_v <= flow.total_volume() + 1e-9,
            "TotalV {} exceeds the flow volume {}",
            mv.total_v,
            flow.total_volume()
        );
    }
}

#[test]
fn diffusive_matches_scratch_quality_at_half_the_migration_on_mild_skew() {
    // the acceptance comparison: lambda within 1.1x of the scratch
    // partitioner's while moving no more than half of scratch+remap's
    // TotalV (ParMETIS-class scratch: global relabeling churn)
    let nparts = 8;
    let (mesh, leaves) = mild_scattered(nparts);
    let weights = vec![1.0f64; leaves.len()];
    let owners = owners_of(&mesh, &leaves);
    let lam0 = imbalance(&rank_loads(&owners, &weights, nparts));
    assert!(lam0 > 1.05, "mild skew missing: {lam0}");

    let scratch_pipe = RebalancePipeline::from_method("ParMETIS", nparts).unwrap();
    let mut scratch_mesh = mesh.clone();
    let scratch = scratch_pipe.rebalance(&mut scratch_mesh, &leaves, &weights);

    let diff_pipe = RebalancePipeline::from_method("ParMETIS", nparts)
        .unwrap()
        .with_strategy(RepartitionStrategy::Diffusive);
    let mut diff_mesh = mesh.clone();
    let diff = diff_pipe.rebalance(&mut diff_mesh, &leaves, &weights);

    assert!(
        diff.lambda_after <= 1.1 * scratch.lambda_after + 1e-9,
        "diffusive lambda {} vs scratch {}",
        diff.lambda_after,
        scratch.lambda_after
    );
    assert!(
        diff.volume.total_v <= 0.5 * scratch.volume.total_v,
        "diffusive TotalV {} > 50% of scratch's {}",
        diff.volume.total_v,
        scratch.volume.total_v
    );
}

#[test]
fn auto_equals_the_cheaper_strategy_on_both_regimes() {
    // mild scattered skew: the flow is short-haul, diffusion is the
    // modeled-cheaper event and Auto must both choose it and produce
    // exactly its rebalance
    let nparts = 8;
    for (scenario, (mesh, leaves)) in [
        ("mild", mild_scattered(nparts)),
        ("front", refinement_front(nparts)),
    ] {
        let weights = vec![1.0f64; leaves.len()];

        let mut auto_pipe = RebalancePipeline::from_method("PHG/HSFC", nparts)
            .unwrap()
            .with_strategy(RepartitionStrategy::Auto);
        if scenario == "front" {
            // starve the sweep budget so the distant surplus cannot be
            // evened out: the residual-lambda penalty must price the
            // diffusive path out under a large solve time
            auto_pipe.diffusion.max_sweeps = 1;
        }
        let solve_parallel = if scenario == "front" { 10.0 } else { 0.0 };
        let chosen =
            auto_pipe.resolve_strategy(&mesh, &leaves, &weights, solve_parallel, 1e-3);
        let expected = if scenario == "front" {
            RepartitionStrategy::Scratch
        } else {
            RepartitionStrategy::Diffusive
        };
        assert_eq!(chosen, expected, "scenario {scenario}");

        // Auto's rebalance equals running the chosen strategy directly
        let mut auto_mesh = mesh.clone();
        let auto_rep = auto_pipe.rebalance_as(chosen, &mut auto_mesh, &leaves, &weights);
        let mut direct_pipe = RebalancePipeline::from_method("PHG/HSFC", nparts)
            .unwrap()
            .with_strategy(chosen);
        if scenario == "front" {
            direct_pipe.diffusion.max_sweeps = 1;
        }
        let mut direct_mesh = mesh.clone();
        let direct_rep = direct_pipe.rebalance(&mut direct_mesh, &leaves, &weights);
        assert_eq!(auto_rep.strategy, direct_rep.strategy, "scenario {scenario}");
        assert_eq!(auto_rep.method, direct_rep.method, "scenario {scenario}");
        assert!(
            (auto_rep.lambda_after - direct_rep.lambda_after).abs() < 1e-12,
            "scenario {scenario}: {} vs {}",
            auto_rep.lambda_after,
            direct_rep.lambda_after
        );
        assert!(
            (auto_rep.volume.total_v - direct_rep.volume.total_v).abs() < 1e-9,
            "scenario {scenario}"
        );
    }
}

#[test]
fn diffusive_driver_controls_imbalance_end_to_end() {
    use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
    use phg_dlb::fem::SolverOpts;

    let cfg = DriverConfig {
        problem: "helmholtz".to_string(),
        nparts: 4,
        method: "PHG/HSFC".to_string(),
        trigger: "lambda".to_string(),
        weights: "unit".to_string(),
        strategy: "diffusive".to_string(),
        exec: "virtual".to_string(),
        exec_threads: 0,
        lambda_trigger: 1.1,
        theta_refine: 0.5,
        theta_coarsen: 0.0,
        max_elements: 20_000,
        solver: SolverOpts {
            tol: 1e-5,
            max_iter: 500,
        },
        use_pjrt: false,
        nsteps: 3,
        dt: 1e-3,
    };
    let mut d = AdaptiveDriver::new(generator::cube_mesh(2), cfg).unwrap();
    d.run();
    assert_eq!(d.timeline.records.len(), 3);
    d.mesh.check_invariants().unwrap();
    for r in &d.timeline.records {
        if r.repartitioned {
            assert_eq!(r.strategy, Some(RepartitionStrategy::Diffusive));
            let rep = r.rebalance.as_ref().unwrap();
            assert_eq!(rep.method, "Diffusion");
            assert_eq!(rep.remap_comm_modeled, 0.0);
            assert!(r.imbalance_after <= r.imbalance_before + 1e-9);
        }
    }
    let last = d.timeline.records.last().unwrap();
    assert!(
        last.imbalance_after < 1.6,
        "diffusive driver left lambda {}",
        last.imbalance_after
    );
}
