//! End-to-end status plane + flight recorder (DESIGN.md §14): a
//! multi-job serve run with the loopback HTTP window and the DLB
//! decision log both on, polled over *real* sockets mid-run.
//!
//! This is the only driver-running test in this binary on purpose: the
//! flight ring and the `dlb.flight.*` audit metrics are process-global,
//! so keeping other drivers out makes the deltas below attributable.

use phg_dlb::obs;
use phg_dlb::serve::{self, json, JobRegistry, JobSpec, JobState, ServeOptions};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

/// Blocking loopback GET; returns (status line, body).
fn get(addr: SocketAddr, path: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).expect("connect status plane");
    s.write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").as_bytes())
        .expect("send request");
    let mut text = String::new();
    s.read_to_string(&mut text).expect("read response");
    let (head, body) = text.split_once("\r\n\r\n").expect("header terminator");
    (head.lines().next().unwrap().to_string(), body.to_string())
}

fn temp_opts() -> ServeOptions {
    let base = std::env::temp_dir().join(format!("phg_status_plane_{}", std::process::id()));
    ServeOptions {
        workers: 2,
        checkpoint_dir: base.join("ckpt"),
        trace_dir: None,
        drain_timeout_s: 0.0,
        retry_base_ms: 1,
        // the test owns its StatusServer (ephemeral port) + registry
        // instead of letting serve() wire one up on a fixed port
        status_port: None,
    }
}

/// Small adaptive tenants that *will* rebalance: `auto` strategy with a
/// hair trigger, so every fired event carries the three-way modeled
/// cost table the argmin assertion below needs.
const JOBS: &str = r#"
{"id": "tenant-a", "problem": "helmholtz", "strategy": "auto", "lambda_trigger": 1.05, "nparts": 4, "max_elements": 30000, "theta_refine": 0.4, "solver_tol": 1e-4, "solver_max_iter": 400, "steps": 3}
{"id": "tenant-b", "problem": "lshape", "strategy": "auto", "lambda_trigger": 1.05, "nparts": 4, "max_elements": 30000, "theta_refine": 0.4, "solver_tol": 1e-4, "solver_max_iter": 400, "steps": 3}
{"id": "tenant-c", "problem": "helmholtz", "strategy": "auto", "lambda_trigger": 1.05, "nparts": 4, "max_elements": 20000, "theta_refine": 0.4, "solver_tol": 1e-4, "solver_max_iter": 400, "steps": 3}
"#;

#[test]
fn serve_run_exposes_live_status_and_flight_logs_every_rebalance() {
    let flight = obs::flight();
    flight.clear();
    flight.set_enabled(true);
    let rebalances_before = obs::metrics().counter("dlb.flight.rebalances");

    let specs = JobSpec::parse_jsonl(JOBS).expect("job specs");
    let registry = Arc::new(JobRegistry::new(specs));
    let provider: obs::JobsProvider = {
        let reg = Arc::clone(&registry);
        Arc::new(move || reg.jobs_jsonl())
    };
    let server = obs::StatusServer::start(0, Some(provider)).expect("ephemeral status plane");
    let addr = server.addr();

    // before admission: all three jobs visible over the socket, queued
    let (status, body) = get(addr, "/jobs");
    assert!(status.contains("200"), "{status}");
    assert_eq!(body.lines().count(), 3, "{body}");
    for line in body.lines() {
        let v = json::parse(line).expect("queued /jobs line parses");
        assert_eq!(v.get("state").and_then(|s| s.as_str()), Some("queued"));
        assert_eq!(v.get("steps_done").and_then(|n| n.as_f64()), Some(0.0));
    }
    let (status, body) = get(addr, "/health");
    assert!(status.contains("200"), "{status}");
    assert_eq!(body, "ok\n");

    let opts = temp_opts();
    let drain = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        let worker = scope.spawn(|| serve::run_registry(&registry, &opts, &drain));
        // poll the live tables over the socket while the pool works;
        // every line must be valid JSON at every instant, whatever
        // mixture of queued/running/done the poll catches
        loop {
            let (status, body) = get(addr, "/jobs");
            assert!(status.contains("200"), "{status}");
            for line in body.lines() {
                let v = json::parse(line).expect("mid-run /jobs line parses");
                assert!(v.get("id").is_some(), "{line}");
                assert!(v.get("state").is_some(), "{line}");
                assert!(v.get("lambda").is_some(), "{line}");
                assert!(v.get("wall_s").is_some(), "{line}");
            }
            let (status, metrics) = get(addr, "/metrics");
            assert!(status.contains("200"), "{status}");
            for line in metrics.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
                let (name, value) = line.rsplit_once(' ').expect("name value");
                assert!(value.parse::<f64>().is_ok(), "unparsable: {line}");
                let metric = name.split('{').next().unwrap();
                assert!(!metric.contains('.'), "un-normalized mid-run name: {line}");
            }
            if registry.all_terminal() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        worker.join().expect("worker thread").expect("run_registry");
    });

    for rec in registry.snapshot() {
        assert_eq!(rec.state, JobState::Done, "job {} did not finish", rec.spec.id);
        // stationary tenants may stop early on the growth budget, but
        // never without completing at least one adaptive step
        assert!(
            rec.steps_done >= 1 && rec.steps_done <= 3,
            "job {}: steps_done {}",
            rec.spec.id,
            rec.steps_done
        );
    }

    // the post-run exposition must carry the flight audit family, and
    // the scraped counter must agree with the in-process registry
    let (status, body) = get(addr, "/metrics");
    assert!(status.contains("200"), "{status}");
    assert!(body.contains("# TYPE serve_jobs_completed counter"), "{body}");
    let rebalances = obs::metrics().counter("dlb.flight.rebalances") - rebalances_before;
    let exposed: f64 = body
        .lines()
        .find_map(|l| l.strip_prefix("dlb_flight_rebalances "))
        .expect("dlb_flight_rebalances missing from exposition")
        .parse()
        .expect("counter value");
    assert_eq!(exposed, obs::metrics().counter("dlb.flight.rebalances") as f64);
    server.stop();

    // flight recorder: every rebalance of the whole batch is logged as
    // one fired event whose chosen strategy is the argmin over the
    // per-strategy modeled-cost table recorded with it
    flight.set_enabled(false);
    let events = flight.snapshot();
    assert_eq!(flight.dropped(), 0);
    let fired: Vec<_> = events.iter().filter(|e| e.fired).collect();
    assert!(rebalances >= 1, "hair trigger never fired; no rebalance to audit");
    assert_eq!(
        fired.len() as u64,
        rebalances,
        "every rebalance must produce exactly one fired flight event"
    );
    for e in &events {
        // flight was on for the whole run: even no-fire evaluations
        // carry the full three-way table, in the Auto tie order
        assert_eq!(e.candidates.len(), 3, "step {}", e.step);
        assert_eq!(e.candidates[0].strategy, "diffusive");
        assert_eq!(e.candidates[1].strategy, "adaptive");
        assert_eq!(e.candidates[2].strategy, "scratch");
        for c in &e.candidates {
            assert!(c.total >= c.rebalance_cost, "objective below cost: {c:?}");
            assert!(c.lambda_after >= 1.0, "{c:?}");
        }
        let line = e.to_json();
        json::parse(&line).expect("flight JSONL line parses");
    }
    for e in &fired {
        let chosen = e.chosen.expect("fired event names its strategy");
        let mut best = &e.candidates[0];
        for c in &e.candidates[1..] {
            if c.total < best.total {
                best = c;
            }
        }
        assert_eq!(
            chosen, best.strategy,
            "step {}: chose {} but the recorded table's argmin is {} ({:?})",
            e.step, chosen, best.strategy, e.candidates
        );
        let r = e.realized.expect("fired event carries the realized outcome");
        assert!(r.dlb_wall_s > 0.0, "step {}", e.step);
        assert!(r.total_v >= 0.0);
        assert!(r.lambda_after >= 1.0);
    }
    // the model-error summary reads the same audit metrics and must
    // report the batch's rebalance total
    let summary = obs::model_error_summary();
    assert!(
        summary.contains(&format!(
            "rebalances={}",
            obs::metrics().counter("dlb.flight.rebalances")
        )),
        "{summary}"
    );
    flight.clear();
}
