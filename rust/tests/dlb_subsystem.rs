//! Subsystem tests for `dlb`: trigger policies, weight models and the
//! rebalance pipeline, exercised together through the public API and
//! the adaptive driver.

use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::dist::Distribution;
use phg_dlb::dlb::{
    CostBenefit, RebalancePipeline, Registry, TriggerContext, TriggerPolicy, Unit, WeightModel,
};
use phg_dlb::fem::SolverOpts;
use phg_dlb::mesh::{generator, ElemId, TetMesh};
use phg_dlb::partition::metrics::migration_volume;
use phg_dlb::partition::PartitionInput;

fn cfg(method: &str, trigger: &str, weights: &str) -> DriverConfig {
    DriverConfig {
        problem: "helmholtz".to_string(),
        nparts: 4,
        method: method.to_string(),
        trigger: trigger.to_string(),
        weights: weights.to_string(),
        strategy: "scratch".to_string(),
        exec: "virtual".to_string(),
        exec_threads: 0,
        lambda_trigger: 1.1,
        theta_refine: 0.5,
        theta_coarsen: 0.0,
        max_elements: 20_000,
        solver: SolverOpts {
            tol: 1e-5,
            max_iter: 500,
        },
        use_pjrt: false,
        nsteps: 3,
        dt: 1e-3,
    }
}

/// A block-assigned mesh with rank 0's elements refined twice.
fn skewed_mesh(nparts: usize) -> (TetMesh, Vec<ElemId>) {
    let mut mesh = generator::cube_mesh(2);
    let leaves = mesh.leaves_unordered();
    Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
    for _ in 0..2 {
        let marked: Vec<_> = mesh
            .leaves_unordered()
            .into_iter()
            .filter(|&id| mesh.elem(id).owner == 0)
            .collect();
        mesh.refine(&marked);
    }
    let leaves = mesh.leaves_unordered();
    (mesh, leaves)
}

#[test]
fn cost_benefit_never_fires_on_balanced_mesh() {
    // cube_mesh(2) has 48 leaves; 4 | 48, so block assignment is
    // exactly balanced under unit weights and the modeled saving is 0
    let mut mesh = generator::cube_mesh(2);
    let leaves = mesh.leaves_unordered();
    Distribution::new(4).assign_blocks(&mut mesh, &leaves);
    let weights = vec![1.0f64; leaves.len()];
    let pipe = RebalancePipeline::from_method("PHG/HSFC", 4).unwrap();
    let lambda = pipe.dist.imbalance(&mesh, &leaves, &weights);
    assert!((lambda - 1.0).abs() < 1e-12, "mesh not balanced: {lambda}");

    let mut policy = CostBenefit { horizon: 1000 };
    // even with a huge previous solve time on the table, a balanced
    // mesh offers nothing to recover
    for solve_parallel in [0.0, 1e-3, 10.0] {
        let estimate = pipe.estimate(&mesh, &leaves, &weights, solve_parallel, 1e-3);
        let ctx = TriggerContext {
            step: 0,
            lambda,
            estimate,
        };
        assert!(
            !policy.should_rebalance(&ctx),
            "fired on a balanced mesh (solve_parallel = {solve_parallel})"
        );
    }
}

#[test]
fn cost_benefit_always_fires_beyond_modeled_break_even() {
    let (mesh, leaves) = skewed_mesh(4);
    let weights = vec![1.0f64; leaves.len()];
    let pipe = RebalancePipeline::from_method("PHG/HSFC", 4).unwrap();
    let lambda = pipe.dist.imbalance(&mesh, &leaves, &weights);
    assert!(lambda > 1.3, "skew not induced: {lambda}");

    let mut policy = CostBenefit { horizon: 4 };
    // pick a solve time whose modeled saving sits exactly at 2x the
    // modeled cost over the horizon: must fire
    let probe = pipe.estimate(&mesh, &leaves, &weights, 1.0, 1e-3);
    assert!(probe.saving_per_step > 0.0);
    let break_even_solve = probe.rebalance_cost / (probe.saving_per_step * 4.0);
    let above = pipe.estimate(&mesh, &leaves, &weights, 2.0 * break_even_solve, 1e-3);
    let ctx = TriggerContext {
        step: 0,
        lambda,
        estimate: above,
    };
    assert!(policy.should_rebalance(&ctx), "did not fire above break-even");
    // and at half the break-even saving it must hold its fire
    let below = pipe.estimate(&mesh, &leaves, &weights, 0.5 * break_even_solve, 1e-3);
    let ctx = TriggerContext {
        step: 0,
        lambda,
        estimate: below,
    };
    assert!(!policy.should_rebalance(&ctx), "fired below break-even");
}

#[test]
fn measured_weights_reproduce_unit_on_uniform_timings() {
    let (mesh, leaves) = skewed_mesh(4);
    let mut measured = phg_dlb::dlb::Measured::new();
    measured.observe(&mesh, &leaves, &vec![2.5e-4; leaves.len()]);
    let wm = measured.weights(&mesh, &leaves);
    let wu = Unit.weights(&mesh, &leaves);
    assert_eq!(wm.len(), wu.len());
    for (a, b) in wm.iter().zip(&wu) {
        assert!((a - b).abs() < 1e-12, "{a} vs {b}");
    }
    // identical weights => identical partitions through the pipeline
    let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
    let p = Registry::create("RTK").unwrap();
    let ru = p.partition(&PartitionInput::from_mesh(&mesh, &leaves, &wu, &owners, 4));
    let rm = p.partition(&PartitionInput::from_mesh(&mesh, &leaves, &wm, &owners, 4));
    assert_eq!(ru.parts, rm.parts);
}

#[test]
fn pipeline_remap_never_worse_than_identity_mapping() {
    // the pipeline's migration volume must never exceed what executing
    // the partitioner's raw (identity-mapped) subgrids would have moved
    for method in Registry::names() {
        let (mut mesh, leaves) = skewed_mesh(5);
        let weights = vec![1.0f64; leaves.len()];
        let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();

        // raw partition, identity subgrid -> process mapping
        let p = Registry::create(method).unwrap();
        let raw = p.partition(&PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 5));
        let identity = migration_volume(&owners, &raw.parts, &weights, 5);

        let pipe = RebalancePipeline::from_method(method, 5).unwrap();
        let report = pipe.rebalance(&mut mesh, &leaves, &weights);
        assert!(
            report.volume.total_v <= identity.total_v + 1e-9,
            "{method}: remapped TotalV {} > identity TotalV {}",
            report.volume.total_v,
            identity.total_v
        );
    }
}

#[test]
fn driver_runs_three_steps_under_every_trigger_policy() {
    for trigger in ["lambda:1.1", "every:2", "always", "costbenefit:8"] {
        let mesh = generator::cube_mesh(2);
        let mut d = AdaptiveDriver::new(mesh, cfg("RTK", trigger, "unit")).unwrap();
        d.run();
        assert_eq!(d.timeline.records.len(), 3, "trigger {trigger}");
        d.mesh.check_invariants().unwrap();
        for r in &d.timeline.records {
            assert!(r.solve_iterations > 0, "trigger {trigger}");
            assert!(r.l2_error.is_finite() && r.l2_error > 0.0);
            assert_eq!(r.repartitioned, r.rebalance.is_some());
        }
        let reps = d.timeline.repartition_count();
        match trigger {
            "always" => assert_eq!(reps, 3, "always must fire every step"),
            "every:2" => assert_eq!(reps, 1, "every:2 fires on the 2nd of 3 steps"),
            _ => assert!(reps <= 3),
        }
        // whatever the policy, the driver must keep the mesh usable
        let last = d.timeline.records.last().unwrap();
        assert!(last.n_dofs > 0);
    }
}

#[test]
fn driver_runs_under_every_weight_model() {
    for weights in ["unit", "dof", "measured"] {
        let mesh = generator::cube_mesh(2);
        let mut d = AdaptiveDriver::new(mesh, cfg("PHG/HSFC", "lambda:1.1", weights)).unwrap();
        d.run();
        assert_eq!(d.timeline.records.len(), 3, "weights {weights}");
        let last = d.timeline.records.last().unwrap();
        assert!(
            last.imbalance_after < 1.6,
            "weights {weights}: lambda {} not controlled",
            last.imbalance_after
        );
    }
}
