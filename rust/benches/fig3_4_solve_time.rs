//! Figure 3.4: linear-system solve time vs number of DOFs.
//!
//! Runs the adaptive Helmholtz driver per method; the measured PCG
//! time is identical across methods (same systems, same machine), so
//! the differentiation -- as in the paper -- comes from the modeled
//! per-iteration halo exchange, which scales with each method's
//! interface size. Paper shape: RCB / ParMETIS / RTK best on the long
//! cylinder; PHG/HSFC beats Zoltan/HSFC.
//!
//! ```sh
//! cargo bench --bench fig3_4_solve_time [-- --steps 8 --nparts 32]
//! ```

#[path = "common.rs"]
mod common;

use common::{arg_usize, quick_or, save_csv, write_bench_json, BenchRow};
use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::dlb::Registry;
use phg_dlb::fem::SolverOpts;
use phg_dlb::mesh::generator;

fn main() {
    let steps = arg_usize("--steps", quick_or(8, 3));
    let nparts = arg_usize("--nparts", quick_or(32, 8));

    println!("== Fig 3.4: solve time vs #DOFs (p = {nparts}) ==\n");
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    let mut comm_share: Vec<(String, f64)> = Vec::new();

    for name in Registry::paper_names() {
        let cfg = DriverConfig {
            problem: "helmholtz".to_string(),
            nparts,
            method: name.to_string(),
            trigger: "lambda".to_string(),
            weights: "unit".to_string(),
            strategy: "scratch".to_string(),
            exec: "virtual".to_string(),
            exec_threads: 0,
            lambda_trigger: 1.1,
            theta_refine: 0.4,
            theta_coarsen: 0.0,
            max_elements: quick_or(60_000, 6_000),
            solver: SolverOpts {
                tol: 1e-5,
                max_iter: 1200,
            },
            use_pjrt: cfg!(feature = "pjrt"),
            nsteps: steps,
            dt: 0.0,
        };
        let mut driver = AdaptiveDriver::new(generator::omega1_cylinder(2), cfg).unwrap();
        driver.run();
        let pts: Vec<(f64, f64)> = driver
            .timeline
            .records
            .iter()
            .map(|r| (r.n_dofs as f64, r.total_solve_time() * 1e3))
            .collect();
        let comm: f64 = driver
            .timeline
            .records
            .iter()
            .map(|r| r.solve_comm_modeled)
            .sum();
        let total: f64 = driver
            .timeline
            .records
            .iter()
            .map(|r| r.total_solve_time())
            .sum();
        comm_share.push((name.to_string(), comm / total.max(1e-12)));
        series.push((name.to_string(), pts));
        println!(
            "{name:<12} final dofs {:>8}  total solve {:.3}s  (halo share {:.2}%)",
            driver.timeline.records.last().map(|r| r.n_dofs).unwrap_or(0),
            total,
            100.0 * comm / total.max(1e-12)
        );
    }

    // modeled-comm comparison at the final step (the paper's quality
    // -> solve-time effect, isolated from measured noise)
    println!("\nmodeled halo time at final step (ms):");
    let mut final_comm: Vec<(String, f64)> = Vec::new();
    for (name, pts) in &series {
        let _ = pts;
        final_comm.push((name.clone(), 0.0));
    }
    // recompute from share table for readability
    for (name, share) in &comm_share {
        println!("  {name:<12} halo share {:.2}%", 100.0 * share);
    }
    let _ = final_comm;

    save_csv(
        "fig3_4_solve_time.csv",
        &phg_dlb::coordinator::report::format_figure_csv("dofs", "solve_ms", &series),
    );
    write_bench_json(
        "fig3_4_solve_time",
        &series
            .iter()
            .map(|(name, pts)| {
                let mut row = BenchRow::new(name.clone());
                row.wall_ms = Some(pts.iter().map(|p| p.1).sum::<f64>() / pts.len().max(1) as f64);
                row
            })
            .collect::<Vec<_>>(),
    );
}
