//! Shared helpers for the benchmark harnesses (plain-main benches; the
//! criterion crate is not vendored in this environment).
#![allow(dead_code)] // each bench uses a different subset

use phg_dlb::dist::Distribution;
use phg_dlb::mesh::{generator, ElemId, TetMesh};
use phg_dlb::util::timer::Stopwatch;

/// A deterministic adaptive-mesh scenario: the Omega_1 cylinder with a
/// refinement front sweeping along the axis, mimicking the element-
/// density evolution of the paper's example 3.1 without needing FEM
/// solves. Step `k` refines elements in a band around x = front(k).
pub struct MeshSequence {
    pub mesh: TetMesh,
    pub step: usize,
    pub max_elements: usize,
}

impl MeshSequence {
    pub fn cylinder(scale: usize, nparts: usize, max_elements: usize) -> Self {
        let mut mesh = generator::omega1_cylinder(scale);
        let leaves = mesh.leaves_unordered();
        Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
        Self {
            mesh,
            step: 0,
            max_elements,
        }
    }

    pub fn cube(n: usize, nparts: usize, max_elements: usize) -> Self {
        let mut mesh = generator::cube_mesh(n);
        let leaves = mesh.leaves_unordered();
        Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
        Self {
            mesh,
            step: 0,
            max_elements,
        }
    }

    /// Advance the refinement front; returns false once the element
    /// budget is spent.
    pub fn advance(&mut self) -> bool {
        if self.mesh.n_leaves() >= self.max_elements {
            return false;
        }
        let bb = self.mesh.bounding_box();
        let span = bb.extent().x.max(1e-9);
        let front = bb.lo.x + span * (0.15 + 0.07 * self.step as f64) % span;
        let band = span * 0.18;
        let marked: Vec<ElemId> = self
            .mesh
            .leaves_unordered()
            .into_iter()
            .filter(|&id| (self.mesh.centroid(id).x - front).abs() < band)
            .collect();
        self.mesh.refine(&marked);
        self.step += 1;
        true
    }

    pub fn leaves_weights_owners(&self) -> (Vec<ElemId>, Vec<f64>, Vec<u16>) {
        let leaves = self.mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let owners = leaves
            .iter()
            .map(|&id| self.mesh.elem(id).owner)
            .collect();
        (leaves, weights, owners)
    }
}

/// Median wall time of `reps` runs of `f` (seconds).
pub fn median_time(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let sw = Stopwatch::start();
            f();
            sw.elapsed()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Write a CSV report under out/ and echo the path.
pub fn save_csv(name: &str, content: &str) {
    match phg_dlb::coordinator::report::write_report(name, content) {
        Ok(p) => println!("[csv] {}", p.display()),
        Err(e) => eprintln!("[csv] write failed: {e}"),
    }
}

/// Parse `--key value` style bench args.
pub fn arg_usize(key: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}
