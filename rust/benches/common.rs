//! Shared helpers for the benchmark harnesses (plain-main benches; the
//! criterion crate is not vendored in this environment).
#![allow(dead_code)] // each bench uses a different subset

use phg_dlb::dist::Distribution;
use phg_dlb::mesh::{generator, ElemId, TetMesh};
use phg_dlb::util::timer::Stopwatch;

/// A deterministic adaptive-mesh scenario: the Omega_1 cylinder with a
/// refinement front sweeping along the axis, mimicking the element-
/// density evolution of the paper's example 3.1 without needing FEM
/// solves. Step `k` refines elements in a band around x = front(k).
pub struct MeshSequence {
    pub mesh: TetMesh,
    pub step: usize,
    pub max_elements: usize,
}

impl MeshSequence {
    pub fn cylinder(scale: usize, nparts: usize, max_elements: usize) -> Self {
        let mut mesh = generator::omega1_cylinder(scale);
        let leaves = mesh.leaves_unordered();
        Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
        Self {
            mesh,
            step: 0,
            max_elements,
        }
    }

    pub fn cube(n: usize, nparts: usize, max_elements: usize) -> Self {
        let mut mesh = generator::cube_mesh(n);
        let leaves = mesh.leaves_unordered();
        Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
        Self {
            mesh,
            step: 0,
            max_elements,
        }
    }

    /// Advance the refinement front; returns false once the element
    /// budget is spent.
    pub fn advance(&mut self) -> bool {
        if self.mesh.n_leaves() >= self.max_elements {
            return false;
        }
        let bb = self.mesh.bounding_box();
        let span = bb.extent().x.max(1e-9);
        let front = bb.lo.x + span * (0.15 + 0.07 * self.step as f64) % span;
        let band = span * 0.18;
        let marked: Vec<ElemId> = self
            .mesh
            .leaves_unordered()
            .into_iter()
            .filter(|&id| (self.mesh.centroid(id).x - front).abs() < band)
            .collect();
        self.mesh.refine(&marked);
        self.step += 1;
        true
    }

    pub fn leaves_weights_owners(&self) -> (Vec<ElemId>, Vec<f64>, Vec<u16>) {
        let leaves = self.mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let owners = leaves
            .iter()
            .map(|&id| self.mesh.elem(id).owner)
            .collect();
        (leaves, weights, owners)
    }
}

/// Median wall time of `reps` runs of `f` (seconds).
pub fn median_time(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let sw = Stopwatch::start();
            f();
            sw.elapsed()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Write a CSV report under out/ and echo the path.
pub fn save_csv(name: &str, content: &str) {
    match phg_dlb::coordinator::report::write_report(name, content) {
        Ok(p) => println!("[csv] {}", p.display()),
        Err(e) => eprintln!("[csv] write failed: {e}"),
    }
}

/// Parse `--key value` style bench args.
pub fn arg_usize(key: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// True when the bench was invoked with `--quick`: the CI bench-smoke
/// mode that shrinks every `MeshSequence`/driver budget so the whole
/// suite runs in seconds while still producing `BENCH_*.json`
/// summaries.
pub fn is_quick() -> bool {
    std::env::args().any(|a| a == "--quick")
}

/// `full` normally, `quick` under `--quick`.
pub fn quick_or(full: usize, quick: usize) -> usize {
    if is_quick() {
        quick
    } else {
        full
    }
}

/// One row of a `BENCH_*.json` summary. Fields a bench cannot supply
/// stay `None` and serialize as `null`; metrics that fit none of the
/// shared fields go into `extras` under their own labels (never
/// mislabel a count or a throughput as `total_v`/`wall_ms`), emitted
/// in push order.
pub struct BenchRow {
    pub method: String,
    pub lambda_before: Option<f64>,
    pub lambda_after: Option<f64>,
    pub total_v: Option<f64>,
    pub wall_ms: Option<f64>,
    pub extras: Vec<(&'static str, f64)>,
}

impl BenchRow {
    pub fn new(method: impl Into<String>) -> Self {
        Self {
            method: method.into(),
            lambda_before: None,
            lambda_after: None,
            total_v: None,
            wall_ms: None,
            extras: Vec::new(),
        }
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn json_f64(v: Option<f64>) -> String {
    match v {
        Some(x) if x.is_finite() => format!("{x}"),
        _ => "null".to_string(),
    }
}

/// Write the machine-readable summary `BENCH_<bench>.json` that the
/// CI bench-smoke job uploads as an artifact and, on main, commits
/// to the repo root (the perf trajectory's data points). Lands under
/// `out/` by default; a `BENCH_OUT` environment variable overrides
/// the target directory for tooling that wants the JSON somewhere
/// else directly.
pub fn write_bench_json(bench: &str, rows: &[BenchRow]) {
    let safe: String = bench
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect();
    let mut body = String::new();
    body.push_str("{\n");
    body.push_str(&format!("  \"bench\": {},\n", json_str(bench)));
    body.push_str(&format!("  \"quick\": {},\n", is_quick()));
    body.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let extra: String = r
            .extras
            .iter()
            .map(|&(label, v)| format!(", {}: {}", json_str(label), json_f64(Some(v))))
            .collect();
        body.push_str(&format!(
            "    {{\"method\": {}, \"lambda_before\": {}, \"lambda_after\": {}, \
             \"total_v\": {}, \"wall_ms\": {}{}}}{}\n",
            json_str(&r.method),
            json_f64(r.lambda_before),
            json_f64(r.lambda_after),
            json_f64(r.total_v),
            json_f64(r.wall_ms),
            extra,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    body.push_str("  ]\n}\n");
    let name = format!("BENCH_{safe}.json");
    let written = match std::env::var("BENCH_OUT") {
        Ok(dir) if !dir.is_empty() => {
            let dir = std::path::PathBuf::from(dir);
            std::fs::create_dir_all(&dir).and_then(|()| {
                let p = dir.join(&name);
                std::fs::write(&p, &body).map(|()| p)
            })
        }
        _ => phg_dlb::coordinator::report::write_report(&name, &body),
    };
    match written {
        Ok(p) => println!("[json] {}", p.display()),
        Err(e) => eprintln!("[json] write failed: {e}"),
    }
}
