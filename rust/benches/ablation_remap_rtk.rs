//! Two more ablations of the paper's design choices:
//!
//! 1. **Oliker-Biswas remap on/off (§2.4)**: migration volume with and
//!    without the subgrid->process mapping, per method. The paper's
//!    claim: remapping minimizes TotalV; without it a partitioner that
//!    relabels subgrids forces gratuitous migration.
//!
//! 2. **Prefix-sum RTK vs Mitchell's original refinement-tree method
//!    (§2.1)**: same partition-quality family, but the paper's
//!    reformulation needs only two traversals + one MPI_Scan (O(N))
//!    against Mitchell's subtree-weight bisection (O(N log p + p log N)).
//!
//! ```sh
//! cargo bench --bench ablation_remap_rtk
//! ```

#[path = "common.rs"]
mod common;

use common::{median_time, quick_or, save_csv, write_bench_json, BenchRow, MeshSequence};
use phg_dlb::dlb::Registry;
use phg_dlb::mesh::topology::LeafTopology;
use phg_dlb::partition::metrics::migration_volume;
use phg_dlb::partition::PartitionInput;
use phg_dlb::remap::{apply_map, oliker_biswas, SimilarityMatrix};

fn main() {
    let nparts = 32;
    println!("== Ablation A: Oliker-Biswas remap on/off (p = {nparts}) ==\n");
    let mut csv = String::from("section,method,variant,value\n");

    println!(
        "{:<12} {:>16} {:>16} {:>10}",
        "method", "TotalV no-remap", "TotalV remap", "kept gain"
    );
    let mut json_rows: Vec<BenchRow> = Vec::new();
    for name in ["RTK", "MSFC", "PHG/HSFC", "RCB", "ParMETIS"] {
        let mut seq = MeshSequence::cylinder(quick_or(3, 2), nparts, 200_000);
        for _ in 0..quick_or(4, 2) {
            seq.advance();
        }
        let (leaves, weights, owners) = seq.leaves_weights_owners();
        let p = Registry::create(name).unwrap();
        let input = PartitionInput::from_mesh(&seq.mesh, &leaves, &weights, &owners, nparts);
        let r = p.partition(&input);

        let no_remap = migration_volume(&owners, &r.parts, &weights, nparts);
        let sim = SimilarityMatrix::build(&owners, &r.parts, &weights, nparts, nparts);
        let rm = oliker_biswas(&sim);
        let mut parts = r.parts.clone();
        apply_map(&mut parts, &rm.map);
        let with_remap = migration_volume(&owners, &parts, &weights, nparts);

        println!(
            "{:<12} {:>16.0} {:>16.0} {:>9.1}%",
            name,
            no_remap.total_v,
            with_remap.total_v,
            100.0 * (no_remap.total_v - with_remap.total_v) / no_remap.total_v.max(1.0)
        );
        csv.push_str(&format!(
            "remap,{name},no_remap,{}\nremap,{name},remap,{}\n",
            no_remap.total_v, with_remap.total_v
        ));
        assert!(with_remap.total_v <= no_remap.total_v + 1e-9);
        let mut row = BenchRow::new(name);
        row.total_v = Some(with_remap.total_v);
        json_rows.push(row);
    }

    println!("\n== Ablation B: prefix-sum RTK (paper §2.1) vs Mitchell's original ==\n");
    println!(
        "{:<10} {:>9} {:>14} {:>14} {:>12} {:>12}",
        "elements", "parts", "RTK ms", "Mitchell ms", "RTK cut", "Mitchell cut"
    );
    let rtk = Registry::create("RTK").unwrap();
    let mit = Registry::create("Mitchell-RT").unwrap();
    let mut seq = MeshSequence::cylinder(quick_or(3, 2), 64, 500_000);
    for round in 0..quick_or(5, 2) {
        for _ in 0..2 {
            seq.advance();
        }
        let (leaves, weights, owners) = seq.leaves_weights_owners();
        let input = PartitionInput::from_mesh(&seq.mesh, &leaves, &weights, &owners, 64);
        let t_rtk = median_time(3, || {
            std::hint::black_box(rtk.partition(&input).parts.len());
        });
        let t_mit = median_time(3, || {
            std::hint::black_box(mit.partition(&input).parts.len());
        });
        let topo = LeafTopology::build_for(&seq.mesh, leaves.clone());
        let cut_rtk = topo.interface_faces(&rtk.partition(&input).parts);
        let cut_mit = topo.interface_faces(&mit.partition(&input).parts);
        println!(
            "{:<10} {:>9} {:>14.3} {:>14.3} {:>12} {:>12}",
            leaves.len(),
            64,
            t_rtk * 1e3,
            t_mit * 1e3,
            cut_rtk,
            cut_mit
        );
        csv.push_str(&format!(
            "rtk,round{round},rtk_ms,{}\nrtk,round{round},mitchell_ms,{}\n",
            t_rtk * 1e3,
            t_mit * 1e3
        ));
    }
    println!(
        "\npaper shape: prefix-sum RTK is the cheaper equal-quality formulation"
    );
    save_csv("ablation_remap_rtk.csv", &csv);
    write_bench_json("ablation_remap_rtk", &json_rows);
}
