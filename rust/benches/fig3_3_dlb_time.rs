//! Figure 3.3: dynamic load balancing time (partition + remap +
//! migration) per adaptive step, measured through the `dlb` subsystem's
//! [`RebalancePipeline`] -- the same code path the adaptive driver runs.
//!
//! Paper shape: RTK lowest and smoothest (most incremental -> least
//! migration); geometric methods stable; Zoltan/HSFC worst of the SFC
//! family; migration dominates the DLB time.
//!
//! Each method evolves ITS OWN mesh copy so that incremental behaviour
//! compounds across steps exactly as in the real adaptive run.
//!
//! ```sh
//! cargo bench --bench fig3_3_dlb_time [-- --steps 10 --scale 3 --nparts 64]
//! ```

#[path = "common.rs"]
mod common;

use common::{arg_usize, quick_or, save_csv, write_bench_json, BenchRow, MeshSequence};
use phg_dlb::dlb::{RebalancePipeline, Registry};

fn main() {
    let steps = arg_usize("--steps", quick_or(10, 4));
    let scale = arg_usize("--scale", quick_or(3, 2));
    let nparts = arg_usize("--nparts", quick_or(64, 8));

    println!("== Fig 3.3: DLB time (partition + remap + migrate) per step (p = {nparts}) ==\n");

    let methods = Registry::paper_names();
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    let mut moved_frac: Vec<(String, f64)> = Vec::new();
    let mut json_rows: Vec<BenchRow> = Vec::new();

    for &name in &methods {
        let mut seq = MeshSequence::cylinder(scale, nparts, 400_000);
        let pipeline = RebalancePipeline::from_method(name, nparts).unwrap();
        let mut pts = Vec::new();
        let mut total_moved = 0.0;
        let mut total_weight = 0.0;
        let mut last_lambda = (1.0, 1.0);
        for step in 0..steps {
            seq.advance();
            let (leaves, weights, _owners) = seq.leaves_weights_owners();
            let report = pipeline.rebalance(&mut seq.mesh, &leaves, &weights);
            pts.push((step as f64, report.dlb_time() * 1e3));
            total_moved += report.volume.total_v;
            total_weight += weights.iter().sum::<f64>();
            last_lambda = (report.lambda_before, report.lambda_after);
        }
        let mean_ms = pts.iter().map(|p| p.1).sum::<f64>() / pts.len().max(1) as f64;
        let mut row = BenchRow::new(name);
        row.lambda_before = Some(last_lambda.0);
        row.lambda_after = Some(last_lambda.1);
        row.total_v = Some(total_moved);
        row.wall_ms = Some(mean_ms);
        json_rows.push(row);
        series.push((name.to_string(), pts));
        moved_frac.push((name.to_string(), total_moved / total_weight));
    }

    print!("{:>5}", "step");
    for &name in &methods {
        print!(" {name:>12}");
    }
    println!("   (ms, measured + modeled)");
    for i in 0..steps {
        print!("{i:>5}");
        for s in &series {
            print!(" {:>12.3}", s.1[i].1);
        }
        println!();
    }

    println!("\ncumulative moved fraction of element-weight (incrementality):");
    for (name, f) in &moved_frac {
        println!("  {name:<12} {:.3}", f);
    }

    let mean = |n: &str| {
        let s = series.iter().find(|s| s.0 == n).unwrap();
        s.1.iter().map(|p| p.1).sum::<f64>() / s.1.len() as f64
    };
    let frac = |n: &str| moved_frac.iter().find(|m| m.0 == n).unwrap().1;
    let shape_ok = frac("RTK") <= frac("Zoltan/HSFC") && mean("RTK") < mean("ParMETIS");
    println!(
        "\npaper shape (RTK most incremental, cheaper than ParMETIS): {}",
        if shape_ok { "REPRODUCED" } else { "DIVERGED (see csv)" }
    );

    save_csv(
        "fig3_3_dlb_time.csv",
        &phg_dlb::coordinator::report::format_figure_csv("step", "dlb_ms", &series),
    );
    write_bench_json("fig3_3_dlb_time", &json_rows);
}
