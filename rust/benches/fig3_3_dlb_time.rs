//! Figure 3.3: dynamic load balancing time (partition + remap +
//! migration) per adaptive step.
//!
//! Paper shape: RTK lowest and smoothest (most incremental -> least
//! migration); geometric methods stable; Zoltan/HSFC worst of the SFC
//! family; migration dominates the DLB time.
//!
//! Each method evolves ITS OWN mesh copy so that incremental behaviour
//! compounds across steps exactly as in the real adaptive run.
//!
//! ```sh
//! cargo bench --bench fig3_3_dlb_time [-- --steps 10 --scale 3 --nparts 64]
//! ```

#[path = "common.rs"]
mod common;

use common::{arg_usize, save_csv, MeshSequence};
use phg_dlb::coordinator::{partitioner_by_name, METHOD_NAMES};
use phg_dlb::dist::{migrate, NetworkModel};
use phg_dlb::partition::PartitionInput;
use phg_dlb::remap::{apply_map, oliker_biswas, SimilarityMatrix};
use phg_dlb::util::timer::Stopwatch;

fn main() {
    let steps = arg_usize("--steps", 10);
    let scale = arg_usize("--scale", 3);
    let nparts = arg_usize("--nparts", 64);
    let net = NetworkModel::infiniband(nparts);

    println!("== Fig 3.3: DLB time (partition + remap + migrate) per step (p = {nparts}) ==\n");

    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    let mut moved_frac: Vec<(String, f64)> = Vec::new();

    for name in METHOD_NAMES {
        let mut seq = MeshSequence::cylinder(scale, nparts, 400_000);
        let p = partitioner_by_name(name).unwrap();
        let mut pts = Vec::new();
        let mut total_moved = 0.0;
        let mut total_weight = 0.0;
        for step in 0..steps {
            seq.advance();
            let (leaves, weights, owners) = seq.leaves_weights_owners();
            let input =
                PartitionInput::from_mesh(&seq.mesh, &leaves, &weights, &owners, nparts);
            let sw = Stopwatch::start();
            let result = p.partition(&input);
            let sim =
                SimilarityMatrix::build(&owners, &result.parts, &weights, nparts, nparts);
            let remap = oliker_biswas(&sim);
            let mut parts = result.parts;
            apply_map(&mut parts, &remap.map);
            let out = migrate(&mut seq.mesh, &leaves, &parts, &weights, &net);
            let measured = sw.elapsed();
            let modeled = net.sequence_time(&result.comm)
                + net.sequence_time(&remap.comm)
                + out.modeled_time;
            pts.push((step as f64, (measured + modeled) * 1e3));
            total_moved += out.volume.total_v;
            total_weight += weights.iter().sum::<f64>();
        }
        series.push((name.to_string(), pts));
        moved_frac.push((name.to_string(), total_moved / total_weight));
    }

    print!("{:>5}", "step");
    for name in METHOD_NAMES {
        print!(" {name:>12}");
    }
    println!("   (ms, measured + modeled)");
    for i in 0..steps {
        print!("{i:>5}");
        for s in &series {
            print!(" {:>12.3}", s.1[i].1);
        }
        println!();
    }

    println!("\ncumulative moved fraction of element-weight (incrementality):");
    for (name, f) in &moved_frac {
        println!("  {name:<12} {:.3}", f);
    }

    let mean = |n: &str| {
        let s = series.iter().find(|s| s.0 == n).unwrap();
        s.1.iter().map(|p| p.1).sum::<f64>() / s.1.len() as f64
    };
    let frac = |n: &str| moved_frac.iter().find(|m| m.0 == n).unwrap().1;
    let shape_ok = frac("RTK") <= frac("Zoltan/HSFC") && mean("RTK") < mean("ParMETIS");
    println!(
        "\npaper shape (RTK most incremental, cheaper than ParMETIS): {}",
        if shape_ok { "REPRODUCED" } else { "DIVERGED (see csv)" }
    );

    save_csv(
        "fig3_3_dlb_time.csv",
        &phg_dlb::coordinator::report::format_figure_csv("step", "dlb_ms", &series),
    );
}
