//! Ablation: multilevel k-way adaptive repartitioning (AdaptiveRepart)
//! against scratch and diffusive across imbalance severity
//! (DESIGN.md §12).
//!
//! The same two scenario families as `ablation_diffusion` -- scattered
//! mild skew vs an advancing refinement front -- but the question here
//! is where the *third* strategy earns its keep: AdaptiveRepart should
//! migrate far less than scratch+remap (owner-seeded start) while
//! holding a scratch-class cut (itr-weighted refinement), and `Auto`'s
//! three-way argmin should pick each strategy somewhere in the sweep:
//! diffusion where the flow is short-haul, adaptive where balance must
//! be restored but the scratch wall is dear, scratch where severity
//! makes residual imbalance the only thing that matters.
//!
//! ```sh
//! cargo bench --bench ablation_kway [-- --nparts 16 --quick]
//! ```

#[path = "common.rs"]
mod common;

use common::{arg_usize, quick_or, save_csv, write_bench_json, BenchRow, MeshSequence};
use phg_dlb::dlb::{RebalancePipeline, RepartitionStrategy};
use phg_dlb::mesh::topology::LeafTopology;
use phg_dlb::mesh::TetMesh;

/// Scattered mild skew: ranks 0, 2, 4, ... refine a slice of their
/// elements `rounds` times.
fn scattered(nparts: usize, rounds: usize) -> TetMesh {
    let seq = MeshSequence::cube(quick_or(4, 3), nparts, 1_000_000);
    let mut mesh = seq.mesh;
    for _ in 0..rounds {
        let marked: Vec<_> = mesh
            .leaves_unordered()
            .into_iter()
            .enumerate()
            .filter(|(i, id)| {
                let owner = mesh.elem(*id).owner;
                owner % 2 == 0 && i % 3 == 0
            })
            .map(|(_, id)| id)
            .collect();
        mesh.refine(&marked);
    }
    mesh
}

/// Severe refinement front: the MeshSequence band advances `rounds`
/// times near one end of the cylinder.
fn front(nparts: usize, rounds: usize) -> TetMesh {
    let mut seq = MeshSequence::cylinder(quick_or(3, 2), nparts, 1_000_000);
    for _ in 0..rounds {
        seq.advance();
    }
    seq.mesh
}

struct Outcome {
    resolved: &'static str,
    lambda_before: f64,
    lambda_after: f64,
    total_v: f64,
    cut: usize,
    dlb_ms: f64,
}

/// Run one concrete strategy on a clone of `mesh` through `pipe` and
/// measure the post-migration interface cut alongside the report.
fn run_as(pipe: &RebalancePipeline, mesh: &TetMesh, strategy: RepartitionStrategy) -> Outcome {
    let mut mesh = mesh.clone();
    let leaves = mesh.leaves_unordered();
    let weights = vec![1.0f64; leaves.len()];
    let rep = pipe.rebalance_as(strategy, &mut mesh, &leaves, &weights);
    let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
    let cut = LeafTopology::build_for(&mesh, leaves).interface_faces(&owners);
    Outcome {
        resolved: rep.strategy.name(),
        lambda_before: rep.lambda_before,
        lambda_after: rep.lambda_after,
        total_v: rep.volume.total_v,
        cut,
        dlb_ms: rep.dlb_time() * 1e3,
    }
}

fn main() {
    let nparts = arg_usize("--nparts", quick_or(16, 8));
    // the scratch baseline is the multilevel method: cut comparisons
    // against AdaptiveRepart are then like-for-like
    let method = "ParMETIS";
    println!("== Ablation: k-way adaptive repartitioning vs scratch vs diffusive ==");
    println!("   scratch method {method}, p = {nparts}\n");

    let severities: Vec<usize> = if common::is_quick() {
        vec![1, 3]
    } else {
        vec![1, 2, 4, 6]
    };

    let mut csv = String::from(
        "scenario,severity,strategy,resolved,lambda_before,lambda_after,total_v,cut,dlb_ms\n",
    );
    let mut json_rows: Vec<BenchRow> = Vec::new();
    let mut mild_scratch = None;
    let mut mild_adaptive = None;
    let mut auto_chose: Vec<&'static str> = Vec::new();

    println!(
        "{:<10} {:>8} {:<10} {:<10} {:>8} {:>8} {:>10} {:>8} {:>10}",
        "scenario", "severity", "strategy", "resolved", "lam_in", "lam_out", "TotalV", "cut",
        "dlb(ms)"
    );
    for (scenario, meshes) in [
        (
            "scattered",
            severities
                .iter()
                .map(|&s| (s, scattered(nparts, s)))
                .collect::<Vec<_>>(),
        ),
        (
            "front",
            severities
                .iter()
                .map(|&s| (s, front(nparts, s)))
                .collect::<Vec<_>>(),
        ),
    ] {
        for (severity, mesh) in &meshes {
            let mut pipe = RebalancePipeline::from_method(method, nparts)
                .unwrap()
                .with_strategy(RepartitionStrategy::Auto);
            // give diffusion a realistic O(p) budget so severity is
            // what separates the regimes, not sweep starvation
            pipe.diffusion.max_sweeps = nparts;

            // concrete strategy rows; Adaptive runs first so its
            // measured wall primes the EWMA that Auto's estimate uses
            let mut scratch_wall = 0.0f64;
            for strategy in [
                RepartitionStrategy::Adaptive,
                RepartitionStrategy::Scratch,
                RepartitionStrategy::Diffusive,
            ] {
                let o = run_as(&pipe, mesh, strategy);
                if strategy == RepartitionStrategy::Scratch {
                    scratch_wall = o.dlb_ms * 1e-3;
                }
                let mildest_scattered = scenario == "scattered" && *severity == severities[0];
                if mildest_scattered && strategy == RepartitionStrategy::Scratch {
                    mild_scratch = Some((o.total_v, o.cut));
                }
                if mildest_scattered && strategy == RepartitionStrategy::Adaptive {
                    mild_adaptive = Some((o.total_v, o.cut));
                }
                emit(&mut csv, &mut json_rows, scenario, *severity, strategy.name(), &o);
            }

            // the Auto row: solve-time context scales with severity,
            // the scratch wall estimate is the one just measured
            let leaves = mesh.leaves_unordered();
            let weights = vec![1.0f64; leaves.len()];
            let solve = 10.0 * *severity as f64 * scratch_wall;
            let chosen = pipe.resolve_strategy(mesh, &leaves, &weights, solve, scratch_wall);
            auto_chose.push(chosen.name());
            let o = run_as(&pipe, mesh, chosen);
            emit(&mut csv, &mut json_rows, scenario, *severity, "auto", &o);
        }
    }

    let (s_v, s_cut) = mild_scratch.expect("scattered mildest scratch row missing");
    let (a_v, a_cut) = mild_adaptive.expect("scattered mildest adaptive row missing");
    println!(
        "\nmild scattered skew: adaptive TotalV {a_v:.1} vs scratch {s_v:.1} ({})",
        if a_v <= 0.5 * s_v {
            "REPRODUCED: owner-seeded start halves the migration"
        } else {
            "DIVERGED"
        }
    );
    println!(
        "mild scattered skew: adaptive cut {a_cut} vs scratch cut {s_cut} ({})",
        if a_cut as f64 <= 1.2 * s_cut as f64 {
            "REPRODUCED: itr-weighted refinement holds the cut"
        } else {
            "DIVERGED"
        }
    );
    let mut distinct = auto_chose.clone();
    distinct.sort_unstable();
    distinct.dedup();
    println!(
        "auto chose {{{}}} across {} cells ({})",
        distinct.join(", "),
        auto_chose.len(),
        if distinct.len() >= 3 {
            "REPRODUCED: every strategy wins somewhere"
        } else {
            "DIVERGED: some strategy never won a cell"
        }
    );
    assert!(
        a_v <= 0.5 * s_v + 1e-9,
        "adaptive must migrate at most half of scratch+remap on mild \
         scattered skew ({a_v} vs {s_v})"
    );

    save_csv("ablation_kway.csv", &csv);
    write_bench_json("ablation_kway", &json_rows);
}

fn emit(
    csv: &mut String,
    json_rows: &mut Vec<BenchRow>,
    scenario: &str,
    severity: usize,
    strategy: &str,
    o: &Outcome,
) {
    println!(
        "{:<10} {:>8} {:<10} {:<10} {:>8.3} {:>8.3} {:>10.1} {:>8} {:>10.3}",
        scenario, severity, strategy, o.resolved, o.lambda_before, o.lambda_after, o.total_v,
        o.cut, o.dlb_ms
    );
    csv.push_str(&format!(
        "{scenario},{severity},{strategy},{},{:.4},{:.4},{:.1},{},{:.4}\n",
        o.resolved, o.lambda_before, o.lambda_after, o.total_v, o.cut, o.dlb_ms
    ));
    let mut row = BenchRow::new(format!("{scenario}/s{severity}/{strategy}"));
    row.lambda_before = Some(o.lambda_before);
    row.lambda_after = Some(o.lambda_after);
    row.total_v = Some(o.total_v);
    row.wall_ms = Some(o.dlb_ms);
    row.extras.push(("cut", o.cut as f64));
    json_rows.push(row);
}
