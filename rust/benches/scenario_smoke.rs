//! Bench-smoke over the scenario registry: every registered scenario
//! runs the generic driver end to end on its own default mesh and
//! reports lambda control, repartition count and wall time -- so CI
//! proves each `--problem` entry works, not just the two paper
//! examples.
//!
//! ```sh
//! cargo bench --bench scenario_smoke [-- --quick]
//! ```

#[path = "common.rs"]
mod common;

use common::{arg_usize, quick_or, write_bench_json, BenchRow};
use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::fem::SolverOpts;
use phg_dlb::scenario::ScenarioRegistry;
use phg_dlb::util::timer::Stopwatch;

fn main() {
    let nsteps = arg_usize("--steps", quick_or(6, 2));

    println!("== scenario smoke: every registered scenario through the generic loop ==\n");
    let mut rows = Vec::new();
    for spec in ScenarioRegistry::sorted_specs() {
        let cfg = DriverConfig {
            problem: spec.name.to_string(),
            nparts: 8,
            method: "PHG/HSFC".to_string(),
            trigger: "lambda".to_string(),
            weights: "unit".to_string(),
            strategy: "auto".to_string(),
            exec: "virtual".to_string(),
            exec_threads: 0,
            lambda_trigger: 1.1,
            theta_refine: 0.4,
            theta_coarsen: 0.03,
            max_elements: quick_or(40_000, 5_000),
            solver: SolverOpts {
                tol: 1e-5,
                max_iter: 600,
            },
            use_pjrt: cfg!(feature = "pjrt"),
            nsteps,
            dt: 1.5e-3,
        };
        let mut d = AdaptiveDriver::for_scenario(cfg).expect("registered scenario");
        let sw = Stopwatch::start();
        d.run();
        let wall = sw.elapsed();

        assert!(!d.timeline.records.is_empty(), "{}: no steps ran", spec.name);
        let first = d.timeline.records.first().unwrap();
        let last = d.timeline.records.last().unwrap();
        assert!(
            last.imbalance_after < 1.8,
            "{}: lambda {} uncontrolled",
            spec.name,
            last.imbalance_after
        );
        println!(
            "{:<12} steps {:>2}  elements {:>6} -> {:>6}  lambda {:.3} -> {:.3}  \
             repartitions {}  wall {:.2}s",
            spec.name,
            d.timeline.records.len(),
            first.n_elements,
            last.n_elements,
            first.imbalance_before,
            last.imbalance_after,
            d.timeline.repartition_count(),
            wall
        );

        let mut row = BenchRow::new(spec.name);
        row.lambda_before = Some(first.imbalance_before);
        row.lambda_after = Some(last.imbalance_after);
        row.wall_ms = Some(wall * 1e3);
        let repartitions = d.timeline.repartition_count() as f64;
        row.extras.push(("repartitions", repartitions));
        rows.push(row);
    }
    write_bench_json("scenario_smoke", &rows);
}
