//! Tables 2 & 3: the parabolic moving-peak experiment (example 3.2):
//! TAL / DLB / SOL / STP per method at two process counts.
//!
//! Paper shape: on this rapidly-changing mesh the geometric methods
//! (PHG/HSFC, MSFC, Zoltan/HSFC) beat the graph method; PHG/HSFC edges
//! out Zoltan/HSFC only slightly because the domain is the unit cube
//! (normalizations coincide; the gap appears on anisotropic domains --
//! see the ablation bench).
//!
//! ```sh
//! cargo bench --bench table2_parabolic                  # table 2 (p = 64)
//! cargo bench --bench table2_parabolic -- --procs 96    # table 3 ratio
//! ```

#[path = "common.rs"]
mod common;

use common::{arg_usize, quick_or, save_csv, write_bench_json, BenchRow};
use phg_dlb::coordinator::report::{format_table2, Table2Row};
use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::dlb::Registry;
use phg_dlb::fem::SolverOpts;
use phg_dlb::mesh::generator;

fn main() {
    let nparts = arg_usize("--procs", quick_or(64, 8));
    let steps = arg_usize("--steps", quick_or(14, 3));

    println!(
        "== Table {}: parabolic moving peak, p = {nparts}, {steps} time steps ==\n",
        if nparts == 64 { "2" } else { "3" }
    );

    let mut rows = Vec::new();
    for name in Registry::paper_names() {
        let cfg = DriverConfig {
            problem: "parabolic".to_string(),
            nparts,
            method: name.to_string(),
            trigger: "lambda".to_string(),
            weights: "unit".to_string(),
            strategy: "scratch".to_string(),
            exec: "virtual".to_string(),
            exec_threads: 0,
            lambda_trigger: if name == "ParMETIS" { 1.05 } else { 1.15 },
            theta_refine: 0.45,
            theta_coarsen: 0.04,
            max_elements: quick_or(40_000, 6_000),
            solver: SolverOpts {
                tol: 1e-5,
                max_iter: 800,
            },
            use_pjrt: cfg!(feature = "pjrt"),
            nsteps: steps,
            dt: 1.0 / 512.0,
        };
        let mut driver = AdaptiveDriver::new(generator::cube_mesh(4), cfg).unwrap();
        driver.run();
        rows.push(Table2Row::from_timeline(name, &driver.timeline));
    }
    rows.sort_by(|a, b| a.tal.partial_cmp(&b.tal).unwrap());
    println!("{}", format_table2(&rows));

    let tal = |n: &str| rows.iter().find(|r| r.method == n).unwrap().tal;
    let geo_best = tal("PHG/HSFC").min(tal("MSFC")).min(tal("Zoltan/HSFC"));
    println!(
        "paper shape (geometric methods beat ParMETIS on a fast-changing mesh): {}",
        if geo_best <= tal("ParMETIS") {
            "REPRODUCED"
        } else {
            "DIVERGED"
        }
    );

    let mut csv = String::from("method,tal_s,dlb_s,sol_s,stp_s\n");
    for r in &rows {
        csv.push_str(&format!(
            "{},{:.4},{:.6},{:.6},{:.6}\n",
            r.method, r.tal, r.dlb, r.sol, r.stp
        ));
    }
    save_csv(&format!("table2_parabolic_p{nparts}.csv"), &csv);
    write_bench_json(
        "table2_parabolic",
        &rows
            .iter()
            .map(|r| {
                let mut row = BenchRow::new(r.method.clone());
                row.wall_ms = Some(r.tal * 1e3);
                row
            })
            .collect::<Vec<_>>(),
    );
}
