//! Figure 3.5: total time of each adaptive step (DLB + assembly +
//! solve + estimate + adapt), per method.
//!
//! Paper shape: ordering tracks Fig 3.4 (solve dominates), with the
//! DLB differences from Fig 3.3 layered on top.
//!
//! ```sh
//! cargo bench --bench fig3_5_step_time [-- --steps 8 --nparts 32]
//! ```

#[path = "common.rs"]
mod common;

use common::{arg_usize, quick_or, save_csv, write_bench_json, BenchRow};
use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::dlb::Registry;
use phg_dlb::fem::SolverOpts;
use phg_dlb::mesh::generator;

fn main() {
    let steps = arg_usize("--steps", quick_or(8, 3));
    let nparts = arg_usize("--nparts", quick_or(32, 8));

    println!("== Fig 3.5: per-adaptive-step time (p = {nparts}) ==\n");
    let methods = Registry::paper_names();
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();

    for &name in &methods {
        let cfg = DriverConfig {
            problem: "helmholtz".to_string(),
            nparts,
            method: name.to_string(),
            trigger: "lambda".to_string(),
            weights: "unit".to_string(),
            strategy: "scratch".to_string(),
            exec: "virtual".to_string(),
            exec_threads: 0,
            lambda_trigger: 1.1,
            theta_refine: 0.4,
            theta_coarsen: 0.0,
            max_elements: quick_or(60_000, 6_000),
            solver: SolverOpts {
                tol: 1e-5,
                max_iter: 1200,
            },
            use_pjrt: cfg!(feature = "pjrt"),
            nsteps: steps,
            dt: 0.0,
        };
        let mut driver = AdaptiveDriver::new(generator::omega1_cylinder(2), cfg).unwrap();
        driver.run();
        let pts: Vec<(f64, f64)> = driver
            .timeline
            .records
            .iter()
            .map(|r| (r.step as f64, r.step_time() * 1e3))
            .collect();
        series.push((name.to_string(), pts));
    }

    print!("{:>5}", "step");
    for &name in &methods {
        print!(" {name:>12}");
    }
    println!("   (ms)");
    let n = series[0].1.len();
    for i in 0..n {
        print!("{i:>5}");
        for s in &series {
            print!(
                " {:>12.1}",
                s.1.get(i).map(|p| p.1).unwrap_or(f64::NAN)
            );
        }
        println!();
    }

    println!("\ntotal over the run (s):");
    for (name, pts) in &series {
        let tot: f64 = pts.iter().map(|p| p.1).sum::<f64>() / 1e3;
        println!("  {name:<12} {tot:>8.3}");
    }

    save_csv(
        "fig3_5_step_time.csv",
        &phg_dlb::coordinator::report::format_figure_csv("step", "step_ms", &series),
    );
    write_bench_json(
        "fig3_5_step_time",
        &series
            .iter()
            .map(|(name, pts)| {
                let mut row = BenchRow::new(name.clone());
                row.wall_ms = Some(pts.iter().map(|p| p.1).sum::<f64>());
                row
            })
            .collect::<Vec<_>>(),
    );
}
