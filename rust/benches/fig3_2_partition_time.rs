//! Figure 3.2: partition time per adaptive step, all six methods.
//!
//! Paper shape to reproduce: RTK fastest, then MSFC, PHG/HSFC,
//! Zoltan/HSFC; ParMETIS and RCB slowest; ParMETIS's time oscillates
//! with the mesh distribution while the geometric methods grow
//! smoothly with mesh size.
//!
//! ```sh
//! cargo bench --bench fig3_2_partition_time [-- --steps 12 --scale 3 --nparts 64]
//! ```

#[path = "common.rs"]
mod common;

use common::{arg_usize, median_time, quick_or, save_csv, write_bench_json, BenchRow, MeshSequence};
use phg_dlb::dlb::Registry;
use phg_dlb::partition::PartitionInput;
use phg_dlb::util::stats::coeff_of_variation;

fn main() {
    let steps = arg_usize("--steps", quick_or(12, 4));
    let scale = arg_usize("--scale", quick_or(3, 2));
    let nparts = arg_usize("--nparts", quick_or(64, 16));

    println!("== Fig 3.2: partition time per adaptive step (p = {nparts}) ==\n");
    let methods = Registry::paper_names();
    let mut seq = MeshSequence::cylinder(scale, nparts, 400_000);
    let mut series: Vec<(String, Vec<(f64, f64)>)> = methods
        .iter()
        .map(|m| (m.to_string(), Vec::new()))
        .collect();
    let mut sizes = Vec::new();

    for step in 0..steps {
        let (leaves, weights, owners) = seq.leaves_weights_owners();
        sizes.push(leaves.len());
        for (mi, &name) in methods.iter().enumerate() {
            let p = Registry::create(name).unwrap();
            let input = PartitionInput::from_mesh(&seq.mesh, &leaves, &weights, &owners, nparts);
            let t = median_time(3, || {
                let _ = p.partition(&input);
            });
            series[mi].1.push((step as f64, t * 1e3));
        }
        if !seq.advance() {
            break;
        }
    }

    // table: per-step partition times
    print!("{:>5} {:>9}", "step", "elements");
    for &name in &methods {
        print!(" {name:>12}");
    }
    println!("   (ms)");
    for (i, &n) in sizes.iter().enumerate() {
        print!("{:>5} {:>9}", i, n);
        for s in &series {
            print!(" {:>12.3}", s.1[i].1);
        }
        println!();
    }

    println!("\nsummary (mean ms, oscillation = std/mean):");
    let mut means: Vec<(String, f64, f64)> = Vec::new();
    for (name, pts) in &series {
        let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
        let mean = ys.iter().sum::<f64>() / ys.len() as f64;
        means.push((name.clone(), mean, coeff_of_variation(&ys)));
    }
    for (name, mean, cv) in &means {
        println!("  {name:<12} mean {mean:>9.3} ms   cv {cv:>5.2}");
    }

    // paper-shape checks
    let get = |n: &str| means.iter().find(|m| m.0 == n).unwrap().1;
    let shape_ok = get("RTK") < get("MSFC")
        && get("MSFC") < get("Zoltan/HSFC") * 1.5
        && get("RTK") < get("ParMETIS")
        && get("PHG/HSFC") < get("ParMETIS");
    println!(
        "\npaper shape (RTK fastest; geometric < ParMETIS): {}",
        if shape_ok { "REPRODUCED" } else { "DIVERGED (see csv)" }
    );

    save_csv(
        "fig3_2_partition_time.csv",
        &phg_dlb::coordinator::report::format_figure_csv("step", "partition_ms", &series),
    );
    write_bench_json(
        "fig3_2_partition_time",
        &means
            .iter()
            .map(|(name, mean, _)| {
                let mut row = BenchRow::new(name.clone());
                row.wall_ms = Some(*mean);
                row
            })
            .collect::<Vec<_>>(),
    );
}
