//! Table 1: total running time + number of repartitionings for the
//! Helmholtz experiment (example 3.1, scaled).
//!
//! Paper shape: RCB wins on the regular long cylinder; Zoltan/HSFC is
//! the slowest by a wide margin; ParMETIS repartitions ~3x more than
//! the others (its policy chases partition quality, so it uses a much
//! lower imbalance trigger -- mirrored here).
//!
//! ```sh
//! cargo bench --bench table1_total_time [-- --steps 10 --nparts 32]
//! ```

#[path = "common.rs"]
mod common;

use common::{arg_usize, quick_or, save_csv, write_bench_json, BenchRow};
use phg_dlb::coordinator::report::{format_table1, Table1Row};
use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::dlb::Registry;
use phg_dlb::fem::SolverOpts;
use phg_dlb::mesh::generator;

fn main() {
    let steps = arg_usize("--steps", quick_or(12, 3));
    let nparts = arg_usize("--nparts", quick_or(32, 8));

    println!("== Table 1: total running time & repartitionings (p = {nparts}, {steps} adaptive steps) ==\n");

    let mut rows = Vec::new();
    for name in Registry::paper_names() {
        let cfg = DriverConfig {
            problem: "helmholtz".to_string(),
            nparts,
            method: name.to_string(),
            trigger: "lambda".to_string(),
            weights: "unit".to_string(),
            // ParMETIS-style quality-first policy: much lower trigger
            // -> many more repartitions (the paper's 189 vs ~60)
            strategy: "scratch".to_string(),
            exec: "virtual".to_string(),
            exec_threads: 0,
            lambda_trigger: if name == "ParMETIS" { 1.02 } else { 1.1 },
            theta_refine: 0.6,
            theta_coarsen: 0.0,
            max_elements: quick_or(60_000, 6_000),
            solver: SolverOpts {
                tol: 1e-5,
                max_iter: 1200,
            },
            use_pjrt: cfg!(feature = "pjrt"),
            nsteps: steps,
            dt: 0.0,
        };
        let mut driver = AdaptiveDriver::new(generator::omega1_cylinder(2), cfg).unwrap();
        driver.run();
        let (tal, _, _, _) = driver.timeline.table_columns();
        rows.push(Table1Row {
            method: name.to_string(),
            total_time: tal,
            repartitionings: driver.timeline.repartition_count(),
        });
    }
    rows.sort_by(|a, b| a.total_time.partial_cmp(&b.total_time).unwrap());
    println!("{}", format_table1(&rows));

    let rep = |n: &str| {
        rows.iter()
            .find(|r| r.method == n)
            .unwrap()
            .repartitionings
    };
    println!(
        "paper shape (ParMETIS repartitions most): {}",
        if rep("ParMETIS") >= rep("RTK") && rep("ParMETIS") >= rep("RCB") {
            "REPRODUCED"
        } else {
            "DIVERGED"
        }
    );

    let mut csv = String::from("method,total_time_s,repartitionings\n");
    for r in &rows {
        csv.push_str(&format!(
            "{},{:.4},{}\n",
            r.method, r.total_time, r.repartitionings
        ));
    }
    save_csv("table1_total_time.csv", &csv);
    write_bench_json(
        "table1_total_time",
        &rows
            .iter()
            .map(|r| {
                let mut row = BenchRow::new(r.method.clone());
                row.wall_ms = Some(r.total_time * 1e3);
                row
            })
            .collect::<Vec<_>>(),
    );
}
