//! §Perf: hot-path microbenchmarks across all three layers.
//!
//! L3: SFC key generation, radix sort, the 1-D partitioner, RTK
//!     end-to-end, graph-partitioner phases, topology build.
//! L2/L1 (via PJRT): batched element assembly and one cg_step
//!     iteration at each ladder rung.
//!
//! Used before/after every optimization; results are logged in
//! EXPERIMENTS.md §Perf.
//!
//! ```sh
//! cargo bench --bench perf_hotpath
//! ```

#[path = "common.rs"]
mod common;

use common::{median_time, quick_or, save_csv, write_bench_json, BenchRow};
use phg_dlb::dist::Distribution;
use phg_dlb::dlb::Registry;
use phg_dlb::exec::spmv_rows;
use phg_dlb::fem::{assemble, assemble_with_pattern, AssemblyPattern, Csr, DofMap, SellF64};
use phg_dlb::mesh::generator;
use phg_dlb::mesh::topology::LeafTopology;
use phg_dlb::partition::oned::partition_1d;
use phg_dlb::partition::sfc::{hilbert::hilbert_key, morton::morton_key, sfc_keys, Curve, Normalization};
use phg_dlb::partition::PartitionInput;
use phg_dlb::runtime::Runtime;
use phg_dlb::util::rng::Pcg32;
use phg_dlb::util::sort::radix_sort_by_key;

struct Report {
    rows: Vec<(String, f64, String)>,
}

impl Report {
    fn add(&mut self, name: &str, value: f64, unit: &str) {
        println!("{name:<44} {value:>12.3} {unit}");
        self.rows.push((name.to_string(), value, unit.to_string()));
    }
}

fn main() {
    let mut rep = Report { rows: Vec::new() };
    println!("== §Perf hot-path microbenchmarks ==\n");

    // ---------- L3: SFC keys ----------
    let n = quick_or(1_000_000, 100_000);
    let mut rng = Pcg32::new(42);
    let coords: Vec<(u32, u32, u32)> = (0..n)
        .map(|_| {
            (
                rng.gen_range(1 << 21) as u32,
                rng.gen_range(1 << 21) as u32,
                rng.gen_range(1 << 21) as u32,
            )
        })
        .collect();
    let t = median_time(3, || {
        let mut acc = 0u64;
        for &(x, y, z) in &coords {
            acc = acc.wrapping_add(morton_key(x, y, z));
        }
        std::hint::black_box(acc);
    });
    let nk = format!("{}k", n / 1000);
    rep.add(&format!("morton keys ({nk})"), n as f64 / t / 1e6, "Mkeys/s");

    let t = median_time(3, || {
        let mut acc = 0u64;
        for &(x, y, z) in &coords {
            acc = acc.wrapping_add(hilbert_key(x, y, z));
        }
        std::hint::black_box(acc);
    });
    rep.add(&format!("hilbert keys ({nk})"), n as f64 / t / 1e6, "Mkeys/s");

    // ---------- L3: sorting ----------
    let base: Vec<(u64, u32)> = (0..n).map(|i| (rng.next_u64(), i as u32)).collect();
    let t = median_time(3, || {
        let mut v = base.clone();
        radix_sort_by_key(&mut v);
        std::hint::black_box(v.len());
    });
    rep.add(&format!("radix sort {nk} (u64,u32)"), n as f64 / t / 1e6, "Mitems/s");
    let t = median_time(3, || {
        let mut v = base.clone();
        v.sort_unstable_by_key(|&(k, _)| k);
        std::hint::black_box(v.len());
    });
    rep.add(&format!("std sort {nk} (u64,u32)"), n as f64 / t / 1e6, "Mitems/s");

    // ---------- L3: 1-D partitioner ----------
    let keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
    let weights = vec![1.0f64; n];
    let t = median_time(3, || {
        let r = partition_1d(&keys, &weights, 64, 8, 1e-4);
        std::hint::black_box(r.splitters.len());
    });
    rep.add(&format!("1-D partition {nk} items, p=64"), n as f64 / t / 1e6, "Mitems/s");

    // ---------- L3: whole partitioners on a real mesh ----------
    let mut mesh = generator::omega1_cylinder(quick_or(4, 2));
    let marked: Vec<_> = mesh
        .leaves_unordered()
        .into_iter()
        .filter(|&id| mesh.centroid(id).x < 3.0)
        .collect();
    mesh.refine(&marked);
    let leaves = mesh.leaves_unordered();
    let nel = leaves.len();
    let w = vec![1.0; nel];
    Distribution::new(64).assign_blocks(&mut mesh, &leaves);
    let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();

    for method in ["RTK", "MSFC", "PHG/HSFC", "RCB", "ParMETIS"] {
        let p = Registry::create(method).unwrap();
        let input = PartitionInput::from_mesh(&mesh, &leaves, &w, &owners, 64);
        let t = median_time(3, || {
            let r = p.partition(&input);
            std::hint::black_box(r.parts.len());
        });
        rep.add(
            &format!("partition {method} ({nel} elements, p=64)"),
            nel as f64 / t / 1e6,
            "Melem/s",
        );
    }

    let t = median_time(3, || {
        let topo = LeafTopology::build(&mesh);
        std::hint::black_box(topo.n_interior_faces);
    });
    rep.add("topology build", nel as f64 / t / 1e6, "Melem/s");

    let t = median_time(3, || {
        let k = sfc_keys(&mesh, &leaves, Curve::Hilbert, Normalization::AspectPreserving);
        std::hint::black_box(k.len());
    });
    rep.add("mesh hilbert keys (centroid+key)", nel as f64 / t / 1e6, "Melem/s");

    // ---------- L1: assembly, triplet sort vs pattern reuse ----------
    let topo = LeafTopology::build(&mesh);
    let dof = DofMap::build(&mesh, &topo);
    let src = vec![1.0f64; dof.n_dofs];

    let t_triplet = median_time(3, || {
        let a = assemble(&mesh, &topo, &dof, &src, None);
        std::hint::black_box(a.b.len());
    });
    rep.add(
        &format!("assembly triplets ({nel} elements)"),
        nel as f64 / t_triplet / 1e6,
        "Melem/s",
    );

    let t = median_time(3, || {
        let p = AssemblyPattern::build(&mesh, &topo, &dof);
        std::hint::black_box(p.slots.len());
    });
    rep.add("assembly pattern build (per mesh)", nel as f64 / t / 1e6, "Melem/s");

    let pat = AssemblyPattern::build(&mesh, &topo, &dof);
    let t_fill = median_time(3, || {
        let a = assemble_with_pattern(&mesh, &topo, &dof, &src, &pat);
        std::hint::black_box(a.b.len());
    });
    rep.add(
        &format!("assembly pattern fill ({nel} elements)"),
        nel as f64 / t_fill / 1e6,
        "Melem/s",
    );
    rep.add("  -> pattern-reuse speedup", t_triplet / t_fill, "x");

    // ---------- L1: native f64 spmv, CSR row gather vs SELL ----------
    let nrows = quick_or(1_000_000, 50_000);
    let band: i64 = 7; // 15-wide band: FEM-like row width, ELL-friendly
    let mut trips: Vec<(u32, u32, f64)> = Vec::with_capacity(nrows * 15);
    for r in 0..nrows as i64 {
        for c in (r - band).max(0)..=(r + band).min(nrows as i64 - 1) {
            trips.push((r as u32, c as u32, if r == c { 16.0 } else { -1.0 }));
        }
    }
    let a = Csr::from_triplets(nrows, trips);
    let all_rows: Vec<u32> = (0..nrows as u32).collect();
    let sell = SellF64::build(&a, &all_rows).expect("15-wide band fits SELL");
    let xv: Vec<f64> = (0..nrows).map(|i| 1.0 + (i % 13) as f64 * 0.25).collect();
    let mut y_csr = vec![0.0f64; nrows];
    let mut y_sell = vec![0.0f64; nrows];

    // one multiply streams vals + cols once and x/y once each; the
    // GB/s figures use that traffic model for both kernels
    let bytes = (a.nnz() * (8 + 4) + 2 * nrows * 8) as f64;
    let t_csr = median_time(5, || {
        spmv_rows(&a, &all_rows, &xv, &mut y_csr);
        std::hint::black_box(y_csr[0]);
    });
    rep.add(&format!("spmv csr gather ({nrows} rows, w=15)"), bytes / t_csr / 1e9, "GB/s");
    let t_sell = median_time(5, || {
        sell.spmv(&xv, &mut y_sell);
        std::hint::black_box(y_sell[0]);
    });
    rep.add(&format!("spmv sell c=8 ({nrows} rows, w=15)"), bytes / t_sell / 1e9, "GB/s");
    rep.add("  -> sell/csr speedup", t_csr / t_sell, "x");
    // the substitution contract, spot-checked where we benchmark it
    for (c, s) in y_csr.iter().zip(&y_sell) {
        assert_eq!(c.to_bits(), s.to_bits(), "SELL diverged from CSR");
    }
    if std::env::args().any(|arg| arg == "--assert-spmv") && t_sell > t_csr / 0.9 {
        panic!(
            "--assert-spmv: SELL spmv slower than 0.9x CSR baseline \
             (csr {:.3} ms, sell {:.3} ms)",
            t_csr * 1e3,
            t_sell * 1e3
        );
    }

    // ---------- L1: refine to ~1M elements + topology/dof build ----------
    let target = quick_or(1_000_000, 30_000);
    let mut big = generator::cube_mesh(quick_or(6, 3));
    let sw = std::time::Instant::now();
    let mut big_n = big.leaves_unordered().len();
    while big_n < target {
        big.refine(&big.leaves_unordered());
        big_n = big.leaves_unordered().len();
    }
    let t_ref = sw.elapsed().as_secs_f64();
    rep.add(&format!("uniform refine to {big_n} elements"), big_n as f64 / t_ref / 1e6, "Melem/s");
    let t = median_time(3, || {
        let topo = LeafTopology::build(&big);
        std::hint::black_box(topo.n_interior_faces);
    });
    rep.add(&format!("topology build ({big_n} elements)"), big_n as f64 / t / 1e6, "Melem/s");
    let big_topo = LeafTopology::build(&big);
    let t = median_time(3, || {
        let d = DofMap::build(&big, &big_topo);
        std::hint::black_box(d.n_dofs);
    });
    rep.add(&format!("dof build ({big_n} elements)"), big_n as f64 / t / 1e6, "Melem/s");
    drop(big_topo);
    drop(big);

    // ---------- L2/L1 via PJRT ----------
    match Runtime::open_default() {
        Err(e) => println!("(PJRT section skipped: {e})"),
        Ok(rt) => {
            let t = median_time(3, || {
                let a = assemble(&mesh, &topo, &dof, &src, None);
                std::hint::black_box(a.b.len());
            });
            rep.add("assembly native f64", nel as f64 / t / 1e6, "Melem/s");

            let t = median_time(3, || {
                let a = assemble(&mesh, &topo, &dof, &src, Some(&rt));
                std::hint::black_box(a.b.len());
            });
            rep.add("assembly PJRT batched", nel as f64 / t / 1e6, "Melem/s");

            // cg_step per-iteration cost at each rung
            for &rung in &rt.cg_ladder() {
                let wd = rt.ell_width();
                let mut vals = vec![0.0f32; rung * wd];
                let mut cols = vec![0i32; rung * wd];
                let mut dinv = vec![0.0f32; rung];
                for i in 0..rung {
                    vals[i * wd] = 2.0;
                    cols[i * wd] = i as i32;
                    if i > 0 {
                        vals[i * wd + 1] = -1.0;
                        cols[i * wd + 1] = (i - 1) as i32;
                    }
                    dinv[i] = 0.5;
                }
                let bufs = rt.stage_cg(&vals, &cols, &dinv, rung).unwrap();
                let x = vec![0.0f32; rung];
                let r: Vec<f32> = (0..rung).map(|i| (i % 7) as f32).collect();
                let p: Vec<f32> = r.clone();
                let rz: f32 = r.iter().map(|v| v * v).sum();
                let t = median_time(5, || {
                    let o = bufs.step(&x, &r, &p, rz).unwrap();
                    std::hint::black_box(o.rnorm2);
                });
                rep.add(
                    &format!("cg_step PJRT n={rung}"),
                    t * 1e3,
                    "ms/iter",
                );
                // effective SpMV throughput: 2*n*w flops
                rep.add(
                    &format!("  -> spmv throughput n={rung}"),
                    2.0 * rung as f64 * wd as f64 / t / 1e9,
                    "GFLOP/s",
                );
            }
        }
    }

    let mut csv = String::from("bench,value,unit\n");
    for (n, v, u) in &rep.rows {
        csv.push_str(&format!("{n},{v},{u}\n"));
    }
    save_csv("perf_hotpath.csv", &csv);
    // values are throughputs or per-iter times depending on the row --
    // keep them under a neutral label with the unit in the name rather
    // than mislabeling a Mkeys/s figure as a wall time
    write_bench_json(
        "perf_hotpath",
        &rep.rows
            .iter()
            .map(|(name, value, unit)| {
                let mut row = BenchRow::new(format!("{name} [{unit}]"));
                row.extras.push(("value", *value));
                row
            })
            .collect::<Vec<_>>(),
    );
}
