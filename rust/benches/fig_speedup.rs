//! Measured wall-clock speedup of the shared-memory executor vs rank
//! count: the first *real* hardware numbers in the bench trajectory
//! (everything else prices communication with the alpha-beta model).
//!
//! One FEM system (K + M on a uniformly refined cube) is solved by
//! the distributed Jacobi-PCG under 1, 2, 4 (and 8 in full mode)
//! virtual ranks, one worker per rank capped at the core count; the
//! row is the median wall and the speedup against the 1-rank wall.
//! Because the arithmetic is schedule-independent (DESIGN.md §9),
//! every configuration computes the identical solution -- the wall
//! clock is the only thing that changes.
//!
//! ```sh
//! cargo bench --bench fig_speedup [-- --quick]
//! ```

#[path = "common.rs"]
mod common;

use common::{is_quick, median_time, quick_or, write_bench_json, BenchRow};
use phg_dlb::dist::Distribution;
use phg_dlb::exec::{available_threads, pcg_sequential, pcg_threaded, GhostPlan, RankPlan};
use phg_dlb::fem::{assemble, Csr, DofMap, SolverOpts};
use phg_dlb::mesh::generator;
use phg_dlb::mesh::topology::LeafTopology;

fn main() {
    // big enough that the SpMV dominates the barrier/channel overhead
    // even in quick mode (~40k elements / ~9k dofs)
    let mut mesh = generator::cube_mesh(quick_or(12, 12));
    for _ in 0..quick_or(3, 2) {
        mesh.refine(&mesh.leaves_unordered());
    }
    let topo = LeafTopology::build(&mesh);
    let dof = DofMap::build(&mesh, &topo);
    let src = vec![1.0; dof.n_dofs];
    let sys = assemble(&mesh, &topo, &dof, &src, None);
    let a = Csr::linear_combination(1.0, &sys.k, 1.0, &sys.m);
    let ones = vec![1.0; a.n];
    let mut b = vec![0.0; a.n];
    a.spmv(&ones, &mut b);
    let opts = SolverOpts {
        tol: 1e-8,
        max_iter: 2000,
    };
    let cores = available_threads();
    println!(
        "# fig_speedup: {} elements, {} dofs, {} cores",
        topo.n_leaves(),
        dof.n_dofs,
        cores
    );

    let rank_counts: &[usize] = if is_quick() { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let reps = quick_or(5, 3);
    let mut rows = Vec::new();
    let mut base_wall = 0.0;
    let mut speedup_at_4 = 1.0;
    for &p in rank_counts {
        let leaves = mesh.leaves_unordered();
        Distribution::new(p).assign_blocks(&mut mesh, &leaves);
        let owners: Vec<u16> = topo.leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let plan = RankPlan::build(&mesh, &topo, &dof, &owners, p);
        let ghost = GhostPlan::build(&plan, &a);
        let threads = p.min(cores);

        // the answer must not depend on the schedule: spot-check the
        // threaded solution against the sequential one at this p
        let mut x_seq = vec![0.0; a.n];
        let st_seq = pcg_sequential(&plan, &a, &b, &mut x_seq, &opts);
        let mut x_thr = vec![0.0; a.n];
        let (st_thr, clocks, _) = pcg_threaded(&plan, &ghost, &a, &b, &mut x_thr, &opts, threads);
        assert_eq!(
            st_seq.iterations, st_thr.iterations,
            "p={p}: schedules diverged"
        );
        assert!(
            st_thr.rel_residual < 1e-7,
            "p={p}: solver did not converge ({})",
            st_thr.rel_residual
        );
        for (s, t) in x_seq.iter().zip(&x_thr) {
            assert_eq!(s.to_bits(), t.to_bits(), "p={p}: solution differs");
        }

        let wall = median_time(reps, || {
            let mut x = vec![0.0; a.n];
            let (st, _, _) = pcg_threaded(&plan, &ghost, &a, &b, &mut x, &opts, threads);
            assert!(st.rel_residual < 1e-7);
        });
        if p == 1 {
            base_wall = wall;
        }
        let speedup = if wall > 0.0 { base_wall / wall } else { 1.0 };
        if p == 4 {
            speedup_at_4 = speedup;
        }
        // wait decomposition of the spot-check run (same schedule as
        // the timed reps): how much of the rank-seconds were waits
        let wait_fraction = clocks.wait_fraction();
        println!(
            "ranks {p:>2} (workers {threads}): wall {:>8.2} ms  speedup {speedup:>5.2}x  \
             iters {}  wait {:.1}%",
            wall * 1e3,
            st_thr.iterations,
            100.0 * wait_fraction
        );
        let mut row = BenchRow::new(format!("threads:{p}"));
        row.wall_ms = Some(wall * 1e3);
        let barrier_ms = 1e3 * clocks.max_barrier_wait();
        let halo_ms = 1e3 * clocks.max_halo_wait();
        row.extras.push(("speedup", speedup));
        row.extras.push(("wait_fraction", wait_fraction));
        row.extras.push(("barrier_wait_ms", barrier_ms));
        row.extras.push(("halo_wait_ms", halo_ms));
        rows.push(row);
    }
    write_bench_json("speedup", &rows);

    // the acceptance bar: real parallel hardware time must beat the
    // 1-rank wall at 4 ranks. Hard-assert only with >= 4 workers
    // available (the CI runner class); on 2-3 cores the 4 ranks are
    // multiplexed and the margin over barrier/channel overhead is not
    // guaranteed, so report without failing the job spuriously.
    if cores >= 4 {
        assert!(
            speedup_at_4 > 1.0,
            "no measured speedup at 4 ranks on {cores} cores: {speedup_at_4:.2}x"
        );
    } else {
        println!(
            "only {cores} cores: speedup {speedup_at_4:.2}x at 4 ranks reported, not asserted"
        );
    }
}
