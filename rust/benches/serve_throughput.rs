//! Service-mode throughput: a mixed-scenario job batch through the
//! serve daemon (jobs/s, p95 job wall) plus the checkpoint layer's
//! write/restore cost on a refined driver, and the status plane's
//! scrape cost (Prometheus render + one HTTP round-trip).
//!
//! ```sh
//! cargo bench --bench serve_throughput [-- --quick] [--jobs N] [--workers N]
//! ```

#[path = "common.rs"]
mod common;

use common::{arg_usize, median_time, quick_or, write_bench_json, BenchRow};
use phg_dlb::coordinator::{AdaptiveDriver, DriverConfig};
use phg_dlb::fem::SolverOpts;
use phg_dlb::serve::{serve, JobSpec, JobState, ServeOptions};
use phg_dlb::util::timer::Stopwatch;

fn job(i: usize, steps: usize, max_elements: usize) -> JobSpec {
    // rotate the registered scenarios so the pool runs a genuinely
    // mixed tenancy, not one problem six times
    let problem = ["helmholtz", "parabolic", "lshape"][i % 3];
    let overrides = [
        ("problem", problem.to_string()),
        ("nparts", "4".to_string()),
        ("max_elements", max_elements.to_string()),
        ("theta_refine", "0.4".to_string()),
        ("solver_tol", "1e-4".to_string()),
        ("solver_max_iter", "400".to_string()),
        ("dt", "1.5e-3".to_string()),
    ]
    .iter()
    .map(|(k, v)| (k.to_string(), v.clone()))
    .collect();
    JobSpec {
        id: format!("bench-{i}"),
        overrides,
        steps,
        max_retries: 0,
        resume_from: None,
        drain_after: None,
    }
}

fn driver_cfg() -> DriverConfig {
    DriverConfig {
        problem: "helmholtz".to_string(),
        nparts: 4,
        method: "PHG/HSFC".to_string(),
        trigger: "lambda".to_string(),
        weights: "unit".to_string(),
        strategy: "scratch".to_string(),
        exec: "virtual".to_string(),
        exec_threads: 0,
        lambda_trigger: 1.1,
        theta_refine: 0.4,
        theta_coarsen: 0.03,
        max_elements: quick_or(40_000, 10_000),
        solver: SolverOpts {
            tol: 1e-4,
            max_iter: 400,
        },
        use_pjrt: cfg!(feature = "pjrt"),
        nsteps: 2,
        dt: 1.5e-3,
    }
}

fn main() {
    let n_jobs = arg_usize("--jobs", quick_or(9, 6));
    let workers = arg_usize("--workers", 2);
    let steps = quick_or(3, 2);
    let max_elements = quick_or(20_000, 6_000);

    println!("== serve throughput: {n_jobs} jobs on {workers} workers ==\n");
    let specs: Vec<JobSpec> = (0..n_jobs).map(|i| job(i, steps, max_elements)).collect();
    let opts = ServeOptions {
        workers,
        checkpoint_dir: "out/bench_serve/ckpt".into(),
        trace_dir: None,
        drain_timeout_s: 0.0,
        retry_base_ms: 1,
        status_port: None,
    };
    let sw = Stopwatch::start();
    let summary = serve(specs, &opts).expect("serve batch");
    let wall = sw.elapsed();

    let done = summary.count(JobState::Done);
    assert_eq!(done, n_jobs, "bench jobs must all complete:\n{}", summary.format_table());
    let mut walls: Vec<f64> = summary.jobs.iter().map(|j| j.wall_s).collect();
    walls.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p95 = walls[((walls.len() as f64 * 0.95).ceil() as usize - 1).min(walls.len() - 1)];
    let jobs_per_s = n_jobs as f64 / wall.max(1e-9);
    println!("{}", summary.format_table());
    println!("batch wall {wall:.3}s, {jobs_per_s:.2} jobs/s, p95 job wall {:.1}ms", p95 * 1e3);

    // the checkpoint layer on a refined adaptive state: serialize,
    // parse-and-validate, and the snapshot size itself
    let mut d = AdaptiveDriver::for_scenario(driver_cfg()).expect("driver");
    d.run();
    let bytes = d.checkpoint_bytes();
    let write_s = median_time(quick_or(9, 5), || {
        std::hint::black_box(d.checkpoint_bytes());
    });
    let restore_s = median_time(quick_or(9, 5), || {
        let r = AdaptiveDriver::restore_bytes(driver_cfg(), &bytes).expect("restore");
        std::hint::black_box(r.steps_completed());
    });
    println!(
        "checkpoint: {} bytes, write {:.2}ms, restore {:.2}ms",
        bytes.len(),
        write_s * 1e3,
        restore_s * 1e3
    );

    // status plane: text-exposition render wall on the registry the
    // batch just populated, plus one real loopback scrape round-trip
    let render_s = median_time(quick_or(9, 5), || {
        std::hint::black_box(phg_dlb::obs::metrics().prometheus());
    });
    let server = phg_dlb::obs::StatusServer::start(0, None).expect("status server");
    let addr = server.addr();
    let scrape_s = median_time(quick_or(9, 5), || {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(addr).expect("connect status plane");
        s.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n")
            .expect("scrape request");
        let mut body = String::new();
        s.read_to_string(&mut body).expect("scrape response");
        assert!(body.contains("200 OK"), "scrape failed:\n{body}");
        std::hint::black_box(body.len());
    });
    server.stop();
    println!(
        "status plane: prometheus render {:.3}ms, HTTP scrape {:.3}ms",
        render_s * 1e3,
        scrape_s * 1e3
    );

    let mut batch = BenchRow::new(format!("serve:w{workers}"));
    batch.wall_ms = Some(wall * 1e3);
    batch.extras.push(("jobs_per_s", jobs_per_s));
    batch.extras.push(("p95_job_wall_ms", p95 * 1e3));
    batch.extras.push(("jobs", n_jobs as f64));
    let mut ckpt = BenchRow::new("checkpoint");
    ckpt.wall_ms = Some(write_s * 1e3);
    ckpt.extras.push(("checkpoint_write_ms", write_s * 1e3));
    ckpt.extras.push(("checkpoint_restore_ms", restore_s * 1e3));
    ckpt.extras.push(("checkpoint_bytes", bytes.len() as f64));
    let mut status = BenchRow::new("status_plane");
    status.wall_ms = Some(scrape_s * 1e3);
    status.extras.push(("prometheus_render_ms", render_s * 1e3));
    status.extras.push(("http_scrape_ms", scrape_s * 1e3));
    write_bench_json("serve", &[batch, ckpt, status]);
}
