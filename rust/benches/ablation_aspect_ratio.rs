//! Ablation (§2.2's claim): aspect-ratio-preserving vs per-axis
//! bounding-box normalization for the Hilbert SFC partitioner.
//!
//! Paper claim: PHG's aspect-preserving map keeps spatial locality on
//! anisotropic domains, so PHG/HSFC beats Zoltan/HSFC on the long
//! cylinder -- while on the unit cube the two coincide (Tables 2/3
//! show near-identical times there).
//!
//! ```sh
//! cargo bench --bench ablation_aspect_ratio
//! ```

#[path = "common.rs"]
mod common;

use common::{quick_or, save_csv, write_bench_json, BenchRow};
use phg_dlb::dist::Distribution;
use phg_dlb::mesh::generator;
use phg_dlb::mesh::topology::LeafTopology;
use phg_dlb::partition::sfc::{Curve, Normalization, SfcPartitioner};
use phg_dlb::partition::{metrics, PartitionInput, Partitioner};

fn run_domain(name: &str, mut mesh: phg_dlb::mesh::TetMesh, nparts: usize, csv: &mut String) {
    let ar = mesh.bounding_box().aspect_ratio();
    let leaves = mesh.leaves_unordered();
    let weights = vec![1.0; leaves.len()];
    Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
    let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
    let topo = LeafTopology::build_for(&mesh, leaves.clone());

    println!(
        "\n-- domain {name}: {} tets, aspect ratio {ar:.1}, p = {nparts}",
        leaves.len()
    );
    println!(
        "{:<28} {:>12} {:>10}",
        "variant", "iface-faces", "surface%"
    );
    let mut cuts = Vec::new();
    for (norm, label) in [
        (Normalization::AspectPreserving, "aspect-preserving (PHG)"),
        (Normalization::PerAxis, "per-axis (Zoltan)"),
    ] {
        for (curve, cname) in [(Curve::Hilbert, "HSFC"), (Curve::Morton, "MSFC")] {
            let p = SfcPartitioner::new(curve, norm, "ablation");
            let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, nparts);
            let r = p.partition(&input);
            let q = metrics::quality(&topo, &r.parts, &weights, nparts);
            println!(
                "{:<28} {:>12} {:>10.2}",
                format!("{cname} {label}"),
                q.interface_faces,
                100.0 * q.surface_index
            );
            csv.push_str(&format!(
                "{name},{cname},{label},{},{:.4}\n",
                q.interface_faces, q.surface_index
            ));
            if cname == "HSFC" {
                cuts.push(q.interface_faces);
            }
        }
    }
    let (aspect, peraxis) = (cuts[0], cuts[1]);
    if ar > 2.0 {
        println!(
            "=> anisotropic domain: aspect-preserving cut {} vs per-axis {} ({})",
            aspect,
            peraxis,
            if aspect < peraxis {
                "REPRODUCED: preserving locality wins"
            } else {
                "DIVERGED"
            }
        );
    } else {
        let rel = (aspect as f64 - peraxis as f64).abs() / peraxis.max(1) as f64;
        println!(
            "=> isotropic domain: cuts within {:.1}% ({})",
            rel * 100.0,
            if rel < 0.15 {
                "REPRODUCED: normalizations coincide"
            } else {
                "DIVERGED"
            }
        );
    }
}

fn main() {
    println!("== Ablation: SFC bounding-box normalization (paper §2.2) ==");
    let mut csv = String::from("domain,curve,normalization,interface_faces,surface_index\n");

    run_domain(
        "cylinder_AR8",
        generator::omega1_cylinder(quick_or(4, 2)),
        32,
        &mut csv,
    );

    // extra: an even more extreme aspect ratio to show the trend
    let bar = quick_or(64, 16);
    run_domain(
        "bar_AR16",
        generator::box_mesh(
            bar,
            bar / 16,
            bar / 16,
            phg_dlb::geometry::Vec3::ZERO,
            phg_dlb::geometry::Vec3::new(16.0, 1.0, 1.0),
        ),
        32,
        &mut csv,
    );

    run_domain("cube_AR1", generator::cube_mesh(quick_or(10, 4)), 32, &mut csv);

    save_csv("ablation_aspect_ratio.csv", &csv);
    // machine-readable summary: one row per csv data line
    let rows: Vec<BenchRow> = csv
        .lines()
        .skip(1)
        .filter_map(|l| {
            let f: Vec<&str> = l.split(',').collect();
            if f.len() != 5 {
                return None;
            }
            let mut row = BenchRow::new(format!("{}/{}/{}", f[0], f[1], f[2]));
            if let Ok(v) = f[3].parse() {
                row.extras.push(("interface_faces", v));
            }
            Some(row)
        })
        .collect();
    write_bench_json("ablation_aspect_ratio", &rows);
}
