//! Ablation: scratch vs diffusive repartitioning across imbalance
//! severity (DESIGN.md §7).
//!
//! Two scenario families sweep how concentrated the new load is:
//!
//! * **scattered(k)** -- every other rank refines a fraction of its
//!   elements k times: lots of small, *local* surpluses. The balancing
//!   flow is short-haul, so diffusion moves (almost) only the excess
//!   weight while a scratch partition + remap reshuffles far more.
//! * **front(k)** -- the cylinder's refinement front advances k times
//!   at one end: a deep, *distant* surplus. The flow must haul weight
//!   across many rank-chain hops, its volume grows with the distance,
//!   and the from-scratch partition (which pays no transport) wins.
//!
//! `Auto` should track the winner on both ends of the sweep.
//!
//! ```sh
//! cargo bench --bench ablation_diffusion [-- --nparts 16 --quick]
//! ```

#[path = "common.rs"]
mod common;

use common::{arg_usize, quick_or, save_csv, write_bench_json, BenchRow, MeshSequence};
use phg_dlb::dlb::{RebalancePipeline, RepartitionStrategy};
use phg_dlb::mesh::TetMesh;

/// Scattered mild skew: ranks 0, 2, 4, ... refine a slice of their
/// elements `rounds` times.
fn scattered(nparts: usize, rounds: usize) -> TetMesh {
    let seq = MeshSequence::cube(quick_or(4, 3), nparts, 1_000_000);
    let mut mesh = seq.mesh;
    for _ in 0..rounds {
        let marked: Vec<_> = mesh
            .leaves_unordered()
            .into_iter()
            .enumerate()
            .filter(|(i, id)| {
                let owner = mesh.elem(*id).owner;
                owner % 2 == 0 && i % 3 == 0
            })
            .map(|(_, id)| id)
            .collect();
        mesh.refine(&marked);
    }
    mesh
}

/// Severe refinement front: the MeshSequence band advances `rounds`
/// times near one end of the cylinder.
fn front(nparts: usize, rounds: usize) -> TetMesh {
    let mut seq = MeshSequence::cylinder(quick_or(3, 2), nparts, 1_000_000);
    for _ in 0..rounds {
        seq.advance();
    }
    seq.mesh
}

struct Outcome {
    strategy: String,
    lambda_before: f64,
    lambda_after: f64,
    total_v: f64,
    dlb_ms: f64,
}

fn run(mesh: &TetMesh, nparts: usize, strategy: RepartitionStrategy, method: &str) -> Outcome {
    let mut mesh = mesh.clone();
    let pipe = RebalancePipeline::from_method(method, nparts)
        .unwrap()
        .with_strategy(strategy);
    let leaves = mesh.leaves_unordered();
    let weights = vec![1.0f64; leaves.len()];
    let rep = pipe.rebalance(&mut mesh, &leaves, &weights);
    Outcome {
        strategy: format!("{}={}", strategy.name(), rep.strategy.name()),
        lambda_before: rep.lambda_before,
        lambda_after: rep.lambda_after,
        total_v: rep.volume.total_v,
        dlb_ms: rep.dlb_time() * 1e3,
    }
}

fn main() {
    let nparts = arg_usize("--nparts", quick_or(16, 8));
    let method = "RCB"; // the scratch partitioner being priced against
    println!("== Ablation: scratch vs diffusive vs auto across imbalance severity ==");
    println!("   scratch method {method}, p = {nparts}\n");

    let severities: Vec<usize> = if common::is_quick() {
        vec![1, 3]
    } else {
        vec![1, 2, 4, 6]
    };

    let mut csv = String::from(
        "scenario,severity,strategy,resolved,lambda_before,lambda_after,total_v,dlb_ms\n",
    );
    let mut json_rows: Vec<BenchRow> = Vec::new();
    let mut mild_scratch_v = f64::NAN;
    let mut mild_diff_v = f64::NAN;
    let mut severe_scratch_lam = f64::NAN;
    let mut severe_diff_lam = f64::NAN;

    println!(
        "{:<12} {:>8} {:<10} {:>8} {:>8} {:>10} {:>10}",
        "scenario", "severity", "strategy", "lam_in", "lam_out", "TotalV", "dlb(ms)"
    );
    for (scenario, meshes) in [
        (
            "scattered",
            severities
                .iter()
                .map(|&s| (s, scattered(nparts, s)))
                .collect::<Vec<_>>(),
        ),
        (
            "front",
            severities
                .iter()
                .map(|&s| (s, front(nparts, s)))
                .collect::<Vec<_>>(),
        ),
    ] {
        for (severity, mesh) in &meshes {
            for strategy in [
                RepartitionStrategy::Scratch,
                RepartitionStrategy::Diffusive,
                RepartitionStrategy::Auto,
            ] {
                let o = run(mesh, nparts, strategy, method);
                println!(
                    "{:<12} {:>8} {:<10} {:>8.3} {:>8.3} {:>10.1} {:>10.3}",
                    scenario,
                    severity,
                    strategy.name(),
                    o.lambda_before,
                    o.lambda_after,
                    o.total_v,
                    o.dlb_ms
                );
                csv.push_str(&format!(
                    "{scenario},{severity},{},{},{:.4},{:.4},{:.1},{:.4}\n",
                    strategy.name(),
                    o.strategy,
                    o.lambda_before,
                    o.lambda_after,
                    o.total_v,
                    o.dlb_ms
                ));
                let mut row =
                    BenchRow::new(format!("{scenario}/s{severity}/{}", strategy.name()));
                row.lambda_before = Some(o.lambda_before);
                row.lambda_after = Some(o.lambda_after);
                row.total_v = Some(o.total_v);
                row.wall_ms = Some(o.dlb_ms);
                json_rows.push(row);

                let mildest = *severity == severities[0];
                let severest = *severity == *severities.last().unwrap();
                match (scenario, strategy) {
                    ("scattered", RepartitionStrategy::Scratch) if mildest => {
                        mild_scratch_v = o.total_v
                    }
                    ("scattered", RepartitionStrategy::Diffusive) if mildest => {
                        mild_diff_v = o.total_v
                    }
                    ("front", RepartitionStrategy::Scratch) if severest => {
                        severe_scratch_lam = o.lambda_after
                    }
                    ("front", RepartitionStrategy::Diffusive) if severest => {
                        severe_diff_lam = o.lambda_after
                    }
                    _ => {}
                }
            }
        }
    }

    println!(
        "\nmild scattered skew: diffusive TotalV {mild_diff_v:.1} vs scratch {mild_scratch_v:.1} ({})",
        if mild_diff_v <= mild_scratch_v {
            "REPRODUCED: diffusion migrates less"
        } else {
            "DIVERGED"
        }
    );
    println!(
        "severe front: scratch lambda {severe_scratch_lam:.3} vs diffusive {severe_diff_lam:.3} ({})",
        if severe_scratch_lam <= severe_diff_lam + 0.05 {
            "REPRODUCED: scratch quality holds up"
        } else {
            "DIVERGED"
        }
    );
    assert!(
        mild_diff_v <= mild_scratch_v + 1e-9,
        "diffusion must not out-migrate scratch on scattered mild skew \
         ({mild_diff_v} vs {mild_scratch_v})"
    );

    save_csv("ablation_diffusion.csv", &csv);
    write_bench_json("ablation_diffusion", &json_rows);
}
