//! Mitchell's ORIGINAL refinement-tree partitioner -- the baseline the
//! paper's §2.1 reformulation improves on.
//!
//! Mitchell's two-step algorithm: (1) compute the weight of every tree
//! node as the sum over its subtree's leaves; (2) partition by
//! recursive bisection of the forest, descending into subtrees and
//! splitting sibling lists so each side carries half the weight.
//! Complexity O(N log p + p log N), with awkward communication for
//! interior nodes shared across ranks (every ancestor's weight needs a
//! reduction); the paper replaces all of it with per-leaf prefix sums,
//! two traversals and a single `MPI_Scan` -- see `rtk.rs`.
//!
//! We implement the serial form faithfully (subtree weights + the
//! bisection descent) as the ablation baseline: identical partition
//! *quality* family, strictly more work per repartition.

use super::{CommOp, MethodTraits, PartitionInput, PartitionResult, Partitioner};
use crate::mesh::{TetMesh, NONE};
use crate::util::hash::FxHashMap;

pub struct MitchellRefinementTree {
    _private: (),
}

impl MitchellRefinementTree {
    pub fn new() -> Self {
        Self { _private: () }
    }
}

impl Default for MitchellRefinementTree {
    fn default() -> Self {
        Self::new()
    }
}

/// Step 1: subtree weights for every live node (post-order).
fn subtree_weights(
    mesh: &TetMesh,
    leaf_weight: &FxHashMap<u32, f64>,
) -> FxHashMap<u32, f64> {
    let mut w: FxHashMap<u32, f64> = FxHashMap::default();
    // iterative post-order over the forest
    for &root in &mesh.roots {
        let mut stack = vec![(root, false)];
        while let Some((id, expanded)) = stack.pop() {
            let e = mesh.elem(id);
            if e.dead {
                continue;
            }
            if e.children[0] == NONE {
                w.insert(id, leaf_weight.get(&id).copied().unwrap_or(0.0));
                continue;
            }
            if expanded {
                let sum = w.get(&e.children[0]).copied().unwrap_or(0.0)
                    + w.get(&e.children[1]).copied().unwrap_or(0.0);
                w.insert(id, sum);
            } else {
                stack.push((id, true));
                stack.push((e.children[1], false));
                stack.push((e.children[0], false));
            }
        }
    }
    w
}

/// A work item in the bisection descent: a run of sibling subtrees
/// (over the DFS order) plus the part range it must be split into.
struct Task {
    /// node ids forming a left-to-right forest slice
    nodes: Vec<u32>,
    part_lo: usize,
    part_hi: usize,
}

impl Partitioner for MitchellRefinementTree {
    fn name(&self) -> &'static str {
        "Mitchell-RT"
    }

    // refinement-tree traversal: implicitly incremental, no tunables
    fn traits(&self) -> MethodTraits {
        MethodTraits::INCREMENTAL
    }

    #[allow(unused_assignments)] // straddle-descent keeps `acc` updated past the last read
    fn partition(&self, input: &PartitionInput) -> PartitionResult {
        let p = input.nparts;
        let mut leaf_weight: FxHashMap<u32, f64> = FxHashMap::default();
        for (i, &id) in input.leaves.iter().enumerate() {
            leaf_weight.insert(id, input.weights[i]);
        }
        let w = subtree_weights(input.mesh, &leaf_weight);

        let mut part_of: FxHashMap<u32, u16> = FxHashMap::default();
        let mut tasks = vec![Task {
            nodes: input.mesh.roots.clone(),
            part_lo: 0,
            part_hi: p,
        }];

        while let Some(task) = tasks.pop() {
            let nparts = task.part_hi - task.part_lo;
            if nparts <= 1 || task.nodes.is_empty() {
                // assign all leaves below to part_lo
                for &n in &task.nodes {
                    assign_subtree(input.mesh, n, task.part_lo as u16, &mut part_of);
                }
                continue;
            }
            let total: f64 = task.nodes.iter().map(|n| w[n]).sum();
            let p_left = nparts / 2;
            let target = total * p_left as f64 / nparts as f64;

            // walk the slice accumulating subtree weights; descend into
            // the subtree that straddles the target
            let mut acc = 0.0;
            let mut left: Vec<u32> = Vec::new();
            let mut right: Vec<u32> = Vec::new();
            let mut it = task.nodes.iter().copied();
            for n in it.by_ref() {
                let wn = w[&n];
                if acc + wn <= target || wn == 0.0 {
                    acc += wn;
                    left.push(n);
                } else {
                    // straddling node: expand it (or cut here if leaf)
                    let e = input.mesh.elem(n);
                    if e.children[0] == NONE {
                        // leaf: put it on the lighter side
                        if target - acc > acc + wn - target {
                            left.push(n);
                        } else {
                            right.push(n);
                        }
                    } else {
                        // expand children into the slice between sides
                        let c = e.children;
                        let wc0 = w[&c[0]];
                        if acc + wc0 <= target {
                            acc += wc0;
                            left.push(c[0]);
                            right.push(c[1]);
                        } else {
                            // recurse into left child next round: push
                            // both children back as the straddle zone
                            right.push(c[1]);
                            // the left child still straddles: handle by
                            // a mini descent
                            let mut node = c[0];
                            loop {
                                let e2 = input.mesh.elem(node);
                                if e2.children[0] == NONE {
                                    if target - acc > acc + w[&node] - target {
                                        acc += w[&node];
                                        left.push(node);
                                    } else {
                                        right.insert(right.len() - 1, node);
                                    }
                                    break;
                                }
                                let [a, b] = e2.children;
                                if acc + w[&a] <= target {
                                    acc += w[&a];
                                    left.push(a);
                                    node = b;
                                } else {
                                    right.insert(right.len() - 1, b);
                                    node = a;
                                }
                            }
                        }
                    }
                    break;
                }
            }
            right.extend(it);

            tasks.push(Task {
                nodes: left,
                part_lo: task.part_lo,
                part_hi: task.part_lo + p_left,
            });
            tasks.push(Task {
                nodes: right,
                part_lo: task.part_lo + p_left,
                part_hi: task.part_hi,
            });
        }

        let parts: Vec<u16> = input
            .leaves
            .iter()
            .map(|id| part_of.get(id).copied().unwrap_or(0))
            .collect();
        // Mitchell's distributed form needs a reduction per tree level
        // for the shared interior-node weights plus the final bcast.
        let levels = input
            .leaves
            .iter()
            .map(|&id| input.mesh.elem(id).generation)
            .max()
            .unwrap_or(0) as usize
            + 1;
        let mut comm = Vec::new();
        for _ in 0..levels {
            comm.push(CommOp::Allreduce {
                bytes: input.mesh.roots.len() * 8,
            });
        }
        comm.push(CommOp::Bcast {
            bytes: input.nparts * 2,
        });
        PartitionResult { parts, comm }
    }
}

fn assign_subtree(mesh: &TetMesh, node: u32, part: u16, out: &mut FxHashMap<u32, u16>) {
    let mut stack = vec![node];
    while let Some(id) = stack.pop() {
        let e = mesh.elem(id);
        if e.dead {
            continue;
        }
        if e.children[0] == NONE {
            out.insert(id, part);
        } else {
            stack.push(e.children[0]);
            stack.push(e.children[1]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::rtk::RefinementTree;
    use crate::partition::testutil::{assert_valid_partition, setup_mesh};

    fn input_for(
        mesh: &TetMesh,
        nparts: usize,
    ) -> (Vec<u32>, Vec<f64>, Vec<u16>) {
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let owners = vec![0u16; leaves.len()];
        let _ = nparts;
        (leaves, weights, owners)
    }

    #[test]
    fn balances_unit_weights() {
        let mesh = setup_mesh(2);
        for p in [2usize, 4, 8] {
            let (leaves, weights, owners) = input_for(&mesh, p);
            let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, p);
            let r = MitchellRefinementTree::new().partition(&input);
            assert_valid_partition(&input, &r, 0.25);
        }
    }

    #[test]
    fn subtree_weights_sum_correctly() {
        let mesh = setup_mesh(2);
        let leaves = mesh.leaves_unordered();
        let mut lw = FxHashMap::default();
        for &l in &leaves {
            lw.insert(l, 1.0);
        }
        let w = subtree_weights(&mesh, &lw);
        let root_total: f64 = mesh.roots.iter().map(|r| w[r]).sum();
        assert!((root_total - leaves.len() as f64).abs() < 1e-9);
    }

    #[test]
    fn same_quality_family_as_prefix_sum_rtk() {
        // Mitchell and the paper's RTK cut the same DFS leaf sequence,
        // so their interface quality should be comparable
        use crate::mesh::topology::LeafTopology;
        let mesh = setup_mesh(3);
        let (leaves, weights, owners) = input_for(&mesh, 8);
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 8);
        let topo = LeafTopology::build_for(&mesh, leaves.clone());
        let cut_m = topo.interface_faces(&MitchellRefinementTree::new().partition(&input).parts);
        let cut_r = topo.interface_faces(&RefinementTree::new().partition(&input).parts);
        assert!(
            (cut_m as f64) < 1.6 * cut_r as f64 && (cut_r as f64) < 1.6 * cut_m as f64,
            "Mitchell {cut_m} vs RTK {cut_r}"
        );
    }

    #[test]
    fn every_leaf_assigned() {
        let mesh = setup_mesh(2);
        let (leaves, weights, owners) = input_for(&mesh, 5);
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 5);
        let r = MitchellRefinementTree::new().partition(&input);
        assert_eq!(r.parts.len(), leaves.len());
        assert!(r.parts.iter().all(|&p| (p as usize) < 5));
        // all 5 parts used
        let mut used = [false; 5];
        for &p in &r.parts {
            used[p as usize] = true;
        }
        assert!(used.iter().all(|&u| u));
    }
}
