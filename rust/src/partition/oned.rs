//! The 1-D partitioning problem (§2.3): given items with scalar keys
//! and weights, find p-1 splitters so each of the p key-intervals
//! carries equal weight.
//!
//! The algorithm is the paper's generalization of bisection search
//! (lifted from Zoltan): instead of bisecting, each round subdivides
//! into N = (p-1)*k + 1 probe intervals; every splitter maintains a
//! *bounding box* that shrinks each round, and only the boxes (not the
//! whole interval) are re-probed. Each round costs one Allreduce of the
//! probe histogram in the SPMD setting -- that is the collective we
//! log.
//!
//! Keys are `u64` (the SFC key space), so convergence is at most
//! 64 / log2(k+1) rounds; in practice 4-8 rounds with k = 8.

use super::CommOp;

/// Per-splitter search state.
#[derive(Debug, Clone, Copy)]
struct SplitterBox {
    lo: u64,
    hi: u64, // exclusive
    /// weight of items with key < lo
    w_lo: f64,
    /// weight of items with key < hi
    w_hi: f64,
    done: bool,
}

/// Result of the 1-D partition.
#[derive(Debug, Clone)]
pub struct OneDResult {
    /// p-1 splitter keys; item with key `x` goes to part
    /// `#{s in splitters : s <= x}`.
    pub splitters: Vec<u64>,
    pub comm: Vec<CommOp>,
    pub rounds: usize,
}

/// Find splitters for `nparts` equal-weight intervals. `tol` is the
/// acceptable relative weight error per splitter (of total weight);
/// `k` is the probes-per-splitter fan-out.
pub fn partition_1d(
    keys: &[u64],
    weights: &[f64],
    nparts: usize,
    k: usize,
    tol: f64,
) -> OneDResult {
    assert_eq!(keys.len(), weights.len());
    assert!(nparts >= 1);
    assert!(k >= 1);
    let total: f64 = weights.iter().sum();
    let mut comm = Vec::new();
    if nparts == 1 || keys.is_empty() || total <= 0.0 {
        return OneDResult {
            splitters: vec![u64::MAX; nparts.saturating_sub(1)],
            comm,
            rounds: 0,
        };
    }

    let nsplit = nparts - 1;
    let mut boxes: Vec<SplitterBox> = (0..nsplit)
        .map(|_| SplitterBox {
            lo: 0,
            hi: u64::MAX,
            w_lo: 0.0,
            w_hi: total,
            done: false,
        })
        .collect();

    let targets: Vec<f64> = (1..nparts).map(|i| total * i as f64 / nparts as f64).collect();

    let mut rounds = 0;
    const MAX_ROUNDS: usize = 80;
    while rounds < MAX_ROUNDS {
        rounds += 1;
        // Probe set: k interior probes per unresolved box.
        let mut probes: Vec<u64> = Vec::with_capacity(nsplit * k);
        for b in boxes.iter().filter(|b| !b.done) {
            let span = b.hi - b.lo;
            for j in 1..=k {
                let off = (span as u128 * j as u128 / (k as u128 + 1)) as u64;
                probes.push(b.lo + off.max(1).min(span.saturating_sub(1).max(1)));
            }
        }
        if probes.is_empty() {
            break;
        }
        probes.sort_unstable();
        probes.dedup();

        // Histogram: weight of items with key < probe. (SPMD: each rank
        // histograms its local items, then one Allreduce.)
        let below = weight_below(keys, weights, &probes);
        comm.push(CommOp::Allreduce {
            bytes: probes.len() * 8,
        });

        // Shrink each box around its target.
        let mut all_done = true;
        for (b, &target) in boxes.iter_mut().zip(&targets) {
            if b.done {
                continue;
            }
            for (i, &pr) in probes.iter().enumerate() {
                if pr <= b.lo || pr >= b.hi {
                    continue;
                }
                let w = below[i];
                if w <= target && w >= b.w_lo {
                    b.lo = pr;
                    b.w_lo = w;
                }
                if w >= target && w <= b.w_hi {
                    b.hi = pr;
                    b.w_hi = w;
                }
            }
            // done when the box weight range is within tolerance or the
            // key range cannot be subdivided further
            if (b.w_hi - b.w_lo) <= tol * total || b.hi - b.lo <= 1 {
                b.done = true;
            } else {
                all_done = false;
            }
        }
        if all_done {
            break;
        }
    }

    let splitters: Vec<u64> = boxes.iter().map(|b| b.hi).collect();
    OneDResult {
        splitters,
        comm,
        rounds,
    }
}

/// For each probe (sorted ascending), total weight of items with
/// key < probe. O(n log m) with binary search per item.
fn weight_below(keys: &[u64], weights: &[f64], probes: &[u64]) -> Vec<f64> {
    let mut acc = vec![0.0f64; probes.len() + 1];
    for (&key, &w) in keys.iter().zip(weights) {
        // first probe > key  ->  item counts toward all probes above it
        let idx = probes.partition_point(|&p| p <= key);
        acc[idx] += w;
    }
    // prefix: below[i] = sum of acc[0..=i-1]... items with key < probes[i]
    // acc[j] holds weight of items with probes[j-1] <= key < probes[j]
    let mut out = Vec::with_capacity(probes.len());
    let mut run = 0.0;
    for j in 0..probes.len() {
        run += acc[j];
        out.push(run);
    }
    out
}

/// Assign each key to its part given the splitters.
pub fn assign_parts(keys: &[u64], splitters: &[u64]) -> Vec<u16> {
    keys.iter()
        .map(|&k| splitters.partition_point(|&s| s <= k) as u16)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;
    use crate::util::stats::imbalance;

    fn part_weights(parts: &[u16], weights: &[f64], nparts: usize) -> Vec<f64> {
        let mut w = vec![0.0; nparts];
        for (&p, &wt) in parts.iter().zip(weights) {
            w[p as usize] += wt;
        }
        w
    }

    #[test]
    fn uniform_keys_balance() {
        let n = 10_000;
        let keys: Vec<u64> = (0..n).map(|i| (i as u64) << 40).collect();
        let weights = vec![1.0; n];
        for p in [2, 3, 7, 16] {
            let r = partition_1d(&keys, &weights, p, 8, 1e-4);
            let parts = assign_parts(&keys, &r.splitters);
            let w = part_weights(&parts, &weights, p);
            assert!(
                imbalance(&w) < 1.01,
                "p={p} imbalance {} weights {w:?}",
                imbalance(&w)
            );
        }
    }

    #[test]
    fn single_part_trivial() {
        let keys = [1u64, 2, 3];
        let weights = [1.0, 1.0, 1.0];
        let r = partition_1d(&keys, &weights, 1, 8, 1e-3);
        assert!(r.splitters.is_empty());
        assert_eq!(assign_parts(&keys, &r.splitters), vec![0, 0, 0]);
    }

    #[test]
    fn skewed_weights_balance() {
        let n = 5000;
        let keys: Vec<u64> = (0..n).map(|i| (i as u64) * 1_000_003).collect();
        // weight ~ index: heavily skewed toward high keys
        let weights: Vec<f64> = (0..n).map(|i| 1.0 + i as f64).collect();
        let p = 8;
        let r = partition_1d(&keys, &weights, p, 8, 1e-4);
        let parts = assign_parts(&keys, &r.splitters);
        let w = part_weights(&parts, &weights, p);
        assert!(imbalance(&w) < 1.02, "imbalance {}", imbalance(&w));
    }

    #[test]
    fn parts_are_contiguous_in_key_order() {
        let n = 2000;
        let keys: Vec<u64> = (0..n).map(|i| (i as u64) * 7_777_777).collect();
        let weights = vec![1.0; n];
        let r = partition_1d(&keys, &weights, 5, 8, 1e-4);
        let parts = assign_parts(&keys, &r.splitters);
        // keys ascending => parts must be non-decreasing
        for w in parts.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn logs_one_allreduce_per_round() {
        let keys: Vec<u64> = (0..1000).map(|i| (i as u64) << 30).collect();
        let weights = vec![1.0; 1000];
        let r = partition_1d(&keys, &weights, 4, 4, 1e-5);
        assert_eq!(r.comm.len(), r.rounds);
        assert!(r.rounds >= 1 && r.rounds < 80, "rounds {}", r.rounds);
    }

    #[test]
    fn converges_fast_with_large_k() {
        let keys: Vec<u64> = (0..50_000u64).map(|i| i * 123_457).collect();
        let weights = vec![1.0; keys.len()];
        let r8 = partition_1d(&keys, &weights, 16, 8, 1e-4);
        assert!(r8.rounds <= 24, "k=8 took {} rounds", r8.rounds);
    }

    #[test]
    fn empty_and_zero_weight_inputs() {
        let r = partition_1d(&[], &[], 4, 8, 1e-3);
        assert_eq!(r.splitters.len(), 3);
        let r = partition_1d(&[5u64], &[0.0], 4, 8, 1e-3);
        assert_eq!(r.splitters.len(), 3);
    }

    #[test]
    fn property_balance_random_inputs() {
        propcheck::check("1d partition balances random inputs", |rng| {
            let n = 500 + rng.gen_range(5000);
            let keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let weights: Vec<f64> = (0..n).map(|_| rng.gen_uniform(0.1, 2.0)).collect();
            let p = 2 + rng.gen_range(15);
            let r = partition_1d(&keys, &weights, p, 8, 1e-4);
            let parts = assign_parts(&keys, &r.splitters);
            let w = part_weights(&parts, &weights, p);
            // with random continuous-ish keys the balance should be tight;
            // allow slack for the heaviest single item straddling a cut
            let wmax: f64 = weights.iter().cloned().fold(0.0, f64::max);
            let ideal = weights.iter().sum::<f64>() / p as f64;
            let bound = 1.0 + (wmax / ideal) + 0.02;
            assert!(
                imbalance(&w) <= bound,
                "imbalance {} > {bound} (p={p}, n={n})",
                imbalance(&w)
            );
        });
    }

    #[test]
    fn property_parts_complete_and_in_range() {
        propcheck::check("1d assigns every item to a valid part", |rng| {
            let n = 100 + rng.gen_range(1000);
            let keys: Vec<u64> = (0..n).map(|_| rng.next_u64() >> rng.gen_range(32)).collect();
            let weights: Vec<f64> = (0..n).map(|_| rng.gen_uniform(0.5, 1.5)).collect();
            let p = 1 + rng.gen_range(12);
            let r = partition_1d(&keys, &weights, p, 4, 1e-3);
            assert_eq!(r.splitters.len(), p - 1);
            let parts = assign_parts(&keys, &r.splitters);
            assert_eq!(parts.len(), n);
            assert!(parts.iter().all(|&x| (x as usize) < p));
        });
    }
}
