//! Mesh partitioning methods (§2 of the paper).
//!
//! Implemented from scratch:
//! * [`rtk`] -- PHG's refinement-tree partitioner (§2.1), prefix-sum
//!   formulation, two traversals + one `MPI_Scan`.
//! * [`sfc`] -- Morton and Hilbert space-filling-curve partitioners
//!   (§2.2), with both of the paper's bounding-box normalizations.
//! * [`oned`] -- the generalized-k-section 1-D partitioner (§2.3) that
//!   the SFC methods reduce to.
//! * [`rcb`] / [`rib`] -- recursive coordinate / inertial bisection
//!   (the Zoltan-style geometric baselines).
//! * [`graph`] -- a multilevel k-way graph partitioner over the dual
//!   graph (the ParMETIS stand-in), plus the multilevel *adaptive*
//!   repartitioner `AdaptiveRepart` (Schloegel/Karypis-style: owner-
//!   respecting coarsening, owner-seeded initial partition, and k-way
//!   refinement whose `itr` knob trades edge cut against migration).
//! * [`diffusion`] -- first-order diffusive load flow on the rank
//!   chain: the migration-minimal incremental extreme the `Diffusive`
//!   strategy of [`crate::dlb::RebalancePipeline`] runs (and one pole
//!   of the design space `AdaptiveRepart` interpolates).
//! * [`metrics`] -- partition quality measures (imbalance, edge cut,
//!   interface faces, TotalV/MaxV migration volumes).
//!
//! Partitioners are pure: they map `(mesh, leaves, weights, nparts)` to
//! a part id per leaf plus a log of the MPI collectives the SPMD
//! version of the algorithm would have performed; the [`crate::dist`]
//! layer prices those against its alpha-beta network model.

pub mod diffusion;
pub mod graph;
pub mod metrics;
pub mod mitchell;
pub mod oned;
pub mod rcb;
pub mod rib;
pub mod rtk;
pub mod sfc;

use crate::format_err;
use crate::mesh::{ElemId, TetMesh};
use crate::util::error::Result;

/// A collective operation the SPMD algorithm performs, logged by the
/// partitioners and priced by [`crate::dist::NetworkModel::cost`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CommOp {
    /// Prefix scan over ranks (payload bytes per rank).
    Scan { bytes: usize },
    /// Allreduce (payload bytes).
    Allreduce { bytes: usize },
    /// Gather to root (total bytes at root).
    Gather { bytes: usize },
    /// Broadcast from root (payload bytes).
    Bcast { bytes: usize },
    /// Personalized all-to-all (total bytes moved, largest single message).
    AllToAllV { total_bytes: usize, max_msg: usize },
}

/// Input to a partitioner. `leaves` is the caller's canonical leaf
/// order; `weights[i]` is the computational weight of `leaves[i]`;
/// `owners[i]` is its current rank (used by SPMD cost modelling and by
/// incremental methods).
pub struct PartitionInput<'a> {
    pub mesh: &'a TetMesh,
    pub leaves: &'a [ElemId],
    pub weights: &'a [f64],
    pub owners: &'a [u16],
    pub nparts: usize,
}

impl<'a> PartitionInput<'a> {
    pub fn from_mesh(
        mesh: &'a TetMesh,
        leaves: &'a [ElemId],
        weights: &'a [f64],
        owners: &'a [u16],
        nparts: usize,
    ) -> Self {
        assert_eq!(leaves.len(), weights.len());
        assert_eq!(leaves.len(), owners.len());
        assert!(nparts >= 1 && nparts <= u16::MAX as usize);
        Self {
            mesh,
            leaves,
            weights,
            owners,
            nparts,
        }
    }

    pub fn total_weight(&self) -> f64 {
        self.weights.iter().sum()
    }
}

/// A partitioner's output: `parts[i]` is the new part of `leaves[i]`,
/// plus the collectives the distributed algorithm performed.
#[derive(Debug, Clone)]
pub struct PartitionResult {
    pub parts: Vec<u16>,
    pub comm: Vec<CommOp>,
}

/// One tunable knob of a partitioning method, declared statically in
/// [`MethodTraits::tunables`] so [`crate::dlb::Registry`] can validate
/// `name:key=val,...` method specs before construction-time surprises.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParamSpec {
    /// Spelling in `--method name:key=val` specs.
    pub key: &'static str,
    /// One-line description (the `phg-dlb methods` listing).
    pub description: &'static str,
    /// Inclusive valid range. Integer-valued tunables declare integral
    /// bounds and are rounded by the method's `set_tunable`.
    pub min: f64,
    pub max: f64,
    /// The value the plain constructor uses.
    pub default: f64,
}

/// Capabilities of a partitioning method, replacing the lone
/// `incremental()` bool the trait used to carry: whether small mesh
/// changes produce small partition changes, whether the method reads
/// `PartitionInput::owners` (true incremental repartitioners), and the
/// tunables `name:key=val` specs may set.
#[derive(Debug, Clone, Copy)]
pub struct MethodTraits {
    /// Small mesh changes yield small partition changes (geometric
    /// methods and RTK implicitly; graph methods from scratch do not)
    /// -- §1.
    pub incremental: bool,
    /// The method seeds from the *current* ownership in
    /// `PartitionInput::owners` (diffusion, AdaptiveRepart) rather
    /// than partitioning blind.
    pub uses_current_owners: bool,
    /// Knobs settable through `name:key=val,...` specs.
    pub tunables: &'static [ParamSpec],
}

impl MethodTraits {
    /// The common case: implicitly incremental, owner-blind, no knobs.
    pub const INCREMENTAL: MethodTraits = MethodTraits {
        incremental: true,
        uses_current_owners: false,
        tunables: &[],
    };
}

/// The partitioning methods compared in the paper's §3. Instantiate
/// them by name through [`crate::dlb::Registry`], the crate's single
/// method table.
pub trait Partitioner: Send + Sync {
    /// Short name used in reports ("RTK", "PHG/HSFC", ...).
    fn name(&self) -> &'static str;
    fn partition(&self, input: &PartitionInput) -> PartitionResult;
    /// Capabilities and tunables; see [`MethodTraits`].
    fn traits(&self) -> MethodTraits {
        MethodTraits::INCREMENTAL
    }
    /// Set a tunable declared in `traits().tunables`. The registry
    /// validates the key and range against the [`ParamSpec`] first, so
    /// implementations only translate key -> field.
    fn set_tunable(&mut self, key: &str, value: f64) -> Result<()> {
        let _ = value;
        Err(format_err!("method {} has no tunable {key:?}", self.name()))
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::mesh::generator;

    /// A refined cube mesh with unit weights and all-zero owners.
    pub fn setup_mesh(refines: usize) -> TetMesh {
        let mut m = generator::cube_mesh(2);
        for _ in 0..refines {
            let leaves = m.leaves_unordered();
            m.refine(&leaves);
        }
        m
    }

    /// Assert the PartitionResult is structurally valid and balanced
    /// within `tol` (imbalance factor <= 1 + tol).
    pub fn assert_valid_partition(
        input: &PartitionInput,
        result: &PartitionResult,
        tol: f64,
    ) {
        assert_eq!(result.parts.len(), input.leaves.len());
        let p = input.nparts;
        let mut wsum = vec![0.0f64; p];
        for (i, &part) in result.parts.iter().enumerate() {
            assert!((part as usize) < p, "part {part} out of range");
            wsum[part as usize] += input.weights[i];
        }
        let lambda = crate::util::stats::imbalance(&wsum);
        assert!(
            lambda <= 1.0 + tol,
            "imbalance {lambda} > {} for {} parts (weights {wsum:?})",
            1.0 + tol,
            p
        );
    }
}
