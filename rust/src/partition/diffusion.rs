//! Diffusive incremental repartitioning: first-order load flow on the
//! rank chain (cf. Rettinger & Rüde's diffusive DLB and Fehling &
//! Bangerth on repartitioning in generic hp-adaptive FEM). This is the
//! migration-minimal extreme of the repartitioning design space; the
//! multilevel ParMETIS-style `AdaptiveRepart` lives in
//! [`crate::partition::graph::adaptive`] and interpolates between this
//! pole and the scratch partitioner's cut-optimal one.
//!
//! Instead of partitioning from scratch and remapping, diffusion takes
//! the *current* distribution as input and moves load along the edges
//! of the rank-adjacency (quotient) graph until the per-rank loads
//! even out. Blocks of the maintained SFC order form a chain -- rank
//! blocks are contiguous runs of the refinement-forest DFS (§2.1), so
//! the quotient graph restricted to that order is a path -- and the
//! balancing flow on that path is solved by bounded first-order
//! diffusion sweeps ([`solve_flow`]). The flow is then *realized* by
//! peeling boundary elements off each block along the maintained SFC
//! order: the migrated weight never exceeds the flow volume by
//! construction, and SFC-contiguous blocks stay contiguous. (When the
//! current ownership is *not* DFS-contiguous -- e.g. right after a
//! scratch ParMETIS/RCB event under the `auto` strategy -- the chain
//! is ordered by each rank's mean SFC position and peeling still
//! respects the budgets and restores balance, but the transfers are
//! then between interleaved sets rather than true block boundaries.)
//! No remap phase is needed: every element that does not ride a flow
//! stays exactly where it is.
//!
//! SPMD cost: one `Allreduce` of the p rank loads; every rank then
//! solves the (tiny, O(p)) flow system redundantly and peels its own
//! boundary, so no further collectives are required before the
//! migration itself.

use super::{CommOp, MethodTraits, ParamSpec, PartitionInput, PartitionResult, Partitioner};
use crate::format_err;
use crate::mesh::{ElemId, TetMesh};
use crate::util::error::Result;
use crate::util::hash::{FxHashMap, FxHashSet};
use std::collections::BTreeSet;

/// Balancing flow on the rank chain, produced by [`solve_flow`].
#[derive(Debug, Clone)]
pub struct DiffusionFlow {
    /// Net weight to move across chain edge `i`, i.e. from the rank in
    /// chain slot `i` to the rank in slot `i + 1` (negative values
    /// flow leftward). Length `p - 1`.
    pub flows: Vec<f64>,
    /// Modeled per-slot loads after the flow is fully realized.
    pub loads_after: Vec<f64>,
    /// Sweeps actually performed (<= `max_sweeps`).
    pub sweeps: usize,
}

impl DiffusionFlow {
    /// Total weight the flow moves (sum of edge magnitudes): the upper
    /// bound on the realized migration TotalV.
    pub fn total_volume(&self) -> f64 {
        self.flows.iter().map(|f| f.abs()).sum()
    }

    /// Largest single edge flow: the bound on the largest (src, dst)
    /// message of the realizing `AllToAllV`.
    pub fn max_edge(&self) -> f64 {
        self.flows.iter().fold(0.0f64, |m, f| m.max(f.abs()))
    }

    /// Load-imbalance factor of [`DiffusionFlow::loads_after`].
    pub fn lambda_after(&self) -> f64 {
        crate::util::stats::imbalance(&self.loads_after)
    }
}

/// First-order (Jacobi) diffusion on the rank chain: each sweep moves
/// `alpha * (l_i - l_{i+1})` across every edge, with `alpha = 1/3`
/// (stable for maximum degree 2). Stops after `max_sweeps` or once the
/// imbalance factor of the modeled loads drops to `1 + lambda_tol`.
/// The stationary point is the exact prefix-surplus flow; bounding the
/// sweeps bounds the work and is precisely the quality-vs-cost knob
/// the strategy selection (DESIGN.md §7) trades on.
pub fn solve_flow(loads: &[f64], max_sweeps: usize, lambda_tol: f64) -> DiffusionFlow {
    let p = loads.len();
    let mut l = loads.to_vec();
    let mut flows = vec![0.0f64; p.saturating_sub(1)];
    let total: f64 = l.iter().sum();
    if p < 2 || total <= 0.0 {
        return DiffusionFlow {
            flows,
            loads_after: l,
            sweeps: 0,
        };
    }
    let mean = total / p as f64;
    const ALPHA: f64 = 1.0 / 3.0;
    let mut sweeps = 0;
    while sweeps < max_sweeps {
        let lmax = l.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        if lmax <= mean * (1.0 + lambda_tol) {
            break;
        }
        sweeps += 1;
        let prev = l.clone();
        for i in 0..p - 1 {
            let f = ALPHA * (prev[i] - prev[i + 1]);
            flows[i] += f;
            l[i] -= f;
            l[i + 1] += f;
        }
    }
    DiffusionFlow {
        flows,
        loads_after: l,
        sweeps,
    }
}

/// The rank chain of the current distribution: ranks ordered by the
/// mean position of their leaves along the maintained SFC (DFS) order,
/// plus each rank's load in that order. Ranks without leaves keep
/// their label-proportional slot so the chain stays total.
pub fn chain_loads(
    mesh: &TetMesh,
    leaves: &[ElemId],
    owners: &[u16],
    weights: &[f64],
    nparts: usize,
) -> (Vec<u16>, Vec<f64>) {
    assert_eq!(leaves.len(), owners.len());
    assert_eq!(leaves.len(), weights.len());
    let mut index_of: FxHashMap<ElemId, usize> = FxHashMap::default();
    index_of.reserve(leaves.len());
    for (i, &id) in leaves.iter().enumerate() {
        index_of.insert(id, i);
    }
    let mut pos_sum = vec![0.0f64; nparts];
    let mut count = vec![0usize; nparts];
    let mut loads = vec![0.0f64; nparts];
    let keep: FxHashSet<ElemId> = leaves.iter().copied().collect();
    let mut pos = 0usize;
    for id in mesh.leaves_dfs() {
        if !keep.contains(&id) {
            continue;
        }
        let i = index_of[&id];
        let r = (owners[i] as usize).min(nparts - 1);
        pos_sum[r] += pos as f64;
        count[r] += 1;
        loads[r] += weights[i];
        pos += 1;
    }
    let n = pos.max(1) as f64;
    let slot = |r: usize| -> f64 {
        if count[r] > 0 {
            pos_sum[r] / count[r] as f64
        } else {
            (r as f64 + 0.5) * n / nparts as f64
        }
    };
    let mut order: Vec<u16> = (0..nparts as u16).collect();
    order.sort_by(|&a, &b| {
        slot(a as usize)
            .partial_cmp(&slot(b as usize))
            .unwrap()
            .then(a.cmp(&b))
    });
    let chain = order.iter().map(|&r| loads[r as usize]).collect();
    (order, chain)
}

/// The diffusive incremental repartitioner. Registered as method
/// `Diffusion` and driven by the `Diffusive`/`Auto` strategies of
/// [`crate::dlb::RebalancePipeline`].
pub struct DiffusionRepartitioner {
    /// Bound on the first-order diffusion sweeps ([`solve_flow`]).
    pub max_sweeps: usize,
    /// Stop sweeping once the modeled imbalance factor reaches
    /// `1 + lambda_tol`.
    pub lambda_tol: f64,
}

impl DiffusionRepartitioner {
    pub fn new() -> Self {
        Self {
            max_sweeps: 1024,
            lambda_tol: 0.01,
        }
    }
}

impl Default for DiffusionRepartitioner {
    fn default() -> Self {
        Self::new()
    }
}

impl Partitioner for DiffusionRepartitioner {
    fn name(&self) -> &'static str {
        "Diffusion"
    }

    fn traits(&self) -> MethodTraits {
        MethodTraits {
            incremental: true,
            uses_current_owners: true,
            tunables: &[
                ParamSpec {
                    key: "max_sweeps",
                    description: "bound on first-order diffusion sweeps",
                    min: 1.0,
                    max: 1e9,
                    default: 1024.0,
                },
                ParamSpec {
                    key: "lambda_tol",
                    description: "stop sweeping at imbalance 1 + lambda_tol",
                    min: 1e-9,
                    max: 1.0,
                    default: 0.01,
                },
            ],
        }
    }

    fn set_tunable(&mut self, key: &str, value: f64) -> Result<()> {
        match key {
            "max_sweeps" => self.max_sweeps = value.round() as usize,
            "lambda_tol" => self.lambda_tol = value,
            other => return Err(format_err!("method Diffusion has no tunable {other:?}")),
        }
        Ok(())
    }

    fn partition(&self, input: &PartitionInput) -> PartitionResult {
        let p = input.nparts;
        // SPMD: every rank contributes its load, then solves the O(p)
        // flow system redundantly -- one collective total.
        let comm = vec![CommOp::Allreduce { bytes: p * 8 }];
        let n = input.leaves.len();
        if p <= 1 || n == 0 {
            return PartitionResult {
                parts: vec![0u16; n],
                comm,
            };
        }

        let mut index_of: FxHashMap<ElemId, usize> = FxHashMap::default();
        index_of.reserve(n);
        for (i, &id) in input.leaves.iter().enumerate() {
            index_of.insert(id, i);
        }
        let keep: FxHashSet<ElemId> = input.leaves.iter().copied().collect();
        // SFC positions: dfs_ids[pos] is the leaf at chain position pos
        let dfs_ids: Vec<ElemId> = input
            .mesh
            .leaves_dfs()
            .into_iter()
            .filter(|id| keep.contains(id))
            .collect();
        debug_assert_eq!(dfs_ids.len(), n);
        let mut owner: Vec<u16> = Vec::with_capacity(n);
        let mut weight: Vec<f64> = Vec::with_capacity(n);
        for id in &dfs_ids {
            let i = index_of[id];
            owner.push((input.owners[i] as usize).min(p - 1) as u16);
            weight.push(input.weights[i]);
        }
        let clamped_owners: Vec<u16> = input
            .owners
            .iter()
            .map(|&o| (o as usize).min(p - 1) as u16)
            .collect();

        let total: f64 = weight.iter().sum();
        if total <= 0.0 {
            // nothing to balance: keep the current distribution
            return PartitionResult {
                parts: clamped_owners,
                comm,
            };
        }

        // rank chain from the position-indexed structures built above
        // (same semantics as [`chain_loads`], which external callers
        // use, without rebuilding the hash maps and DFS walk)
        let mut pos_sum = vec![0.0f64; p];
        let mut count = vec![0usize; p];
        let mut loads = vec![0.0f64; p];
        for (pos, (&r, &w)) in owner.iter().zip(weight.iter()).enumerate() {
            pos_sum[r as usize] += pos as f64;
            count[r as usize] += 1;
            loads[r as usize] += w;
        }
        let slot = |r: usize| -> f64 {
            if count[r] > 0 {
                pos_sum[r] / count[r] as f64
            } else {
                (r as f64 + 0.5) * n as f64 / p as f64
            }
        };
        let mut order: Vec<u16> = (0..p as u16).collect();
        order.sort_by(|&a, &b| {
            slot(a as usize)
                .partial_cmp(&slot(b as usize))
                .unwrap()
                .then(a.cmp(&b))
        });
        let loads_chain: Vec<f64> = order.iter().map(|&r| loads[r as usize]).collect();
        let flow = solve_flow(&loads_chain, self.max_sweeps, self.lambda_tol);
        let eps = 1e-9 * (total / p as f64).max(1e-300);

        // members[r] = this rank's SFC positions, for boundary peeling
        let mut members: Vec<BTreeSet<u32>> = vec![BTreeSet::new(); p];
        for (pos, &r) in owner.iter().enumerate() {
            members[r as usize].insert(pos as u32);
        }
        // Budgeted peel of one edge: move up to `budget` weight from
        // `src` to `dst`, taking positions from the chosen end of
        // src's run. Never exceeds the budget, so the realized TotalV
        // is bounded by the flow volume -- the invariant the tests pin.
        // The flip side of that strictness is granularity: an edge
        // whose budget is smaller than its boundary element's weight
        // realizes as a no-op, so under heavily non-uniform weights a
        // small flow can leave lambda where it was (the rebalance is
        // then an honest no-op: lambda_after == lambda_before in the
        // report, and a lambda trigger will refire). Scratch
        // repartitioning is the escape hatch for such weight profiles
        // -- the flow-level lambda prediction in the pipeline's cost
        // model does not see this granularity, so a fixed `diffusive`
        // strategy on coarse heavy elements is a deliberate choice,
        // not something `auto` will always route around.
        let mut peel = |src: usize, dst: usize, budget: f64, from_back: bool| {
            let mut moved = 0.0f64;
            loop {
                let next = if from_back {
                    members[src].iter().next_back().copied()
                } else {
                    members[src].iter().next().copied()
                };
                let pos = match next {
                    Some(pos) => pos,
                    None => break,
                };
                let w = weight[pos as usize];
                if moved + w > budget + eps {
                    break;
                }
                members[src].remove(&pos);
                members[dst].insert(pos);
                owner[pos as usize] = dst as u16;
                moved += w;
            }
        };
        // Rightward pass: positive flows cascade along increasing SFC
        // positions (an element may ride several consecutive edges).
        for i in 0..p - 1 {
            if flow.flows[i] > eps {
                peel(order[i] as usize, order[i + 1] as usize, flow.flows[i], true);
            }
        }
        // Leftward pass: negative flows cascade the other way.
        for i in (0..p - 1).rev() {
            if flow.flows[i] < -eps {
                peel(
                    order[i + 1] as usize,
                    order[i] as usize,
                    -flow.flows[i],
                    false,
                );
            }
        }

        let mut parts = vec![0u16; n];
        for (pos, id) in dfs_ids.iter().enumerate() {
            parts[index_of[id]] = owner[pos];
        }
        PartitionResult { parts, comm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Distribution;
    use crate::partition::metrics::migration_volume;
    use crate::util::stats::imbalance;

    fn rank_loads(parts: &[u16], weights: &[f64], p: usize) -> Vec<f64> {
        let mut l = vec![0.0; p];
        for (&r, &w) in parts.iter().zip(weights) {
            l[r as usize] += w;
        }
        l
    }

    #[test]
    fn flow_conserves_total_load() {
        let loads = [10.0, 2.0, 0.0, 4.0, 9.0];
        let total: f64 = loads.iter().sum();
        let flow = solve_flow(&loads, 2000, 1e-6);
        let after: f64 = flow.loads_after.iter().sum();
        assert!((after - total).abs() < 1e-9, "{after} vs {total}");
        assert!(flow.lambda_after() <= imbalance(&loads) + 1e-12);
        assert!(flow.lambda_after() < 1.01, "{}", flow.lambda_after());
        // flows reproduce the load delta edge by edge
        let p = loads.len();
        for r in 0..p {
            let inflow = if r > 0 { flow.flows[r - 1] } else { 0.0 };
            let outflow = if r < p - 1 { flow.flows[r] } else { 0.0 };
            let expect = loads[r] - outflow + inflow;
            assert!(
                (flow.loads_after[r] - expect).abs() < 1e-9,
                "rank {r}: {} vs {expect}",
                flow.loads_after[r]
            );
        }
    }

    #[test]
    fn two_rank_step_imbalance_converges_geometrically() {
        // p = 2: the gap shrinks by 1/3 per sweep, so a small sweep
        // budget already lands under any reasonable trigger threshold
        let flow = solve_flow(&[12.0, 4.0], 8, 0.0);
        assert!(flow.sweeps <= 8);
        assert!(flow.lambda_after() < 1.01, "{}", flow.lambda_after());
        let f1 = solve_flow(&[12.0, 4.0], 1, 0.0);
        assert!((f1.flows[0] - 8.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs_do_not_panic() {
        let f = solve_flow(&[], 10, 0.01);
        assert!(f.flows.is_empty());
        let f = solve_flow(&[5.0], 10, 0.01);
        assert!(f.flows.is_empty());
        let f = solve_flow(&[0.0, 0.0], 10, 0.01);
        assert_eq!(f.sweeps, 0);

        let mut mesh = crate::mesh::generator::cube_mesh(1);
        let leaves = mesh.leaves_unordered();
        Distribution::new(2).assign_blocks(&mut mesh, &leaves);
        let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let d = DiffusionRepartitioner::new();
        // zero weights
        let zero = vec![0.0f64; leaves.len()];
        let input = PartitionInput::from_mesh(&mesh, &leaves, &zero, &owners, 3);
        let r = d.partition(&input);
        assert_eq!(r.parts.len(), leaves.len());
        // single part
        let w = vec![1.0f64; leaves.len()];
        let input = PartitionInput::from_mesh(&mesh, &leaves, &w, &owners, 1);
        let r = d.partition(&input);
        assert!(r.parts.iter().all(|&x| x == 0));
        // more parts than elements
        let input = PartitionInput::from_mesh(&mesh, &leaves, &w, &owners, 10);
        let r = d.partition(&input);
        assert!(r.parts.iter().all(|&x| (x as usize) < 10));
    }

    #[test]
    fn balances_a_refined_block_distribution() {
        let mut mesh = crate::mesh::generator::cube_mesh(2);
        let leaves = mesh.leaves_unordered();
        Distribution::new(4).assign_blocks(&mut mesh, &leaves);
        for _ in 0..2 {
            let marked: Vec<_> = mesh
                .leaves_unordered()
                .into_iter()
                .filter(|&id| mesh.elem(id).owner == 0)
                .collect();
            mesh.refine(&marked);
        }
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0f64; leaves.len()];
        let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let before = imbalance(&rank_loads(&owners, &weights, 4));
        assert!(before > 1.3, "skew not induced: {before}");

        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 4);
        let r = DiffusionRepartitioner::new().partition(&input);
        let after = imbalance(&rank_loads(&r.parts, &weights, 4));
        assert!(after < 1.1, "lambda {after} after diffusion");
        assert_eq!(r.comm.len(), 1);
        assert!(matches!(r.comm[0], CommOp::Allreduce { .. }));
    }

    #[test]
    fn realized_migration_bounded_by_flow_volume() {
        let mut mesh = crate::mesh::generator::cube_mesh(2);
        let leaves = mesh.leaves_unordered();
        Distribution::new(5).assign_blocks(&mut mesh, &leaves);
        for _ in 0..2 {
            let marked: Vec<_> = mesh
                .leaves_unordered()
                .into_iter()
                .filter(|&id| mesh.elem(id).owner == 1)
                .collect();
            mesh.refine(&marked);
        }
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0f64; leaves.len()];
        let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();

        let d = DiffusionRepartitioner::new();
        let (_, chain) = chain_loads(&mesh, &leaves, &owners, &weights, 5);
        let flow = solve_flow(&chain, d.max_sweeps, d.lambda_tol);
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 5);
        let r = d.partition(&input);
        let mv = migration_volume(&owners, &r.parts, &weights, 5);
        assert!(
            mv.total_v <= flow.total_volume() + 1e-9,
            "TotalV {} exceeds flow volume {}",
            mv.total_v,
            flow.total_volume()
        );
        assert!(mv.total_v > 0.0, "diffusion moved nothing");
    }

    #[test]
    fn blocks_stay_contiguous_along_the_sfc() {
        // starting from contiguous SFC blocks (ownership inherited
        // through refinement stays contiguous), the diffusive result
        // must still be contiguous runs of the DFS order
        let mut mesh = crate::mesh::generator::cube_mesh(2);
        let leaves = mesh.leaves_unordered();
        Distribution::new(6).assign_blocks(&mut mesh, &leaves);
        let marked: Vec<_> = mesh
            .leaves_unordered()
            .into_iter()
            .filter(|&id| mesh.elem(id).owner <= 1)
            .collect();
        mesh.refine(&marked);
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0f64; leaves.len()];
        let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();

        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 6);
        let r = DiffusionRepartitioner::new().partition(&input);

        let index_of: FxHashMap<ElemId, usize> =
            leaves.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        let (order, _) = chain_loads(&mesh, &leaves, &owners, &weights, 6);
        let chain_slot: FxHashMap<u16, usize> = order
            .iter()
            .enumerate()
            .map(|(slot, &rank)| (rank, slot))
            .collect();
        let slots: Vec<usize> = mesh
            .leaves_dfs()
            .iter()
            .map(|id| chain_slot[&r.parts[index_of[id]]])
            .collect();
        for w in slots.windows(2) {
            assert!(w[0] <= w[1], "diffusion broke SFC contiguity");
        }
    }
}
