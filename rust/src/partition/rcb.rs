//! Recursive coordinate bisection (RCB, Berger & Bokhari 1987): split
//! the element centroids by a weighted median along the longest axis
//! of their bounding box, recurse on both halves. Simple, fast,
//! implicitly incremental; quality is domain-dependent -- excellent on
//! the paper's long cylinder (Table 1), mediocre elsewhere.

use super::{CommOp, MethodTraits, PartitionInput, PartitionResult, Partitioner};
use crate::geometry::BBox;

pub struct Rcb {
    _private: (),
}

impl Rcb {
    pub fn new() -> Self {
        Self { _private: () }
    }
}

impl Default for Rcb {
    fn default() -> Self {
        Self::new()
    }
}

/// One (point, weight, original index) item.
#[derive(Clone, Copy, Debug)]
pub(crate) struct RcbItem {
    pub pos: [f64; 3],
    pub w: f64,
    pub idx: u32,
}

/// Split `items` in place: prefix gets `target` of the weight (along
/// `axis`), returns split position. Weighted quick-select.
fn weighted_split(items: &mut [RcbItem], axis: usize, target: f64) -> usize {
    // sort-based selection: robust and O(n log n); the whole RCB is
    // O(n log n log p) which matches Zoltan's practical profile
    items.sort_unstable_by(|a, b| a.pos[axis].partial_cmp(&b.pos[axis]).unwrap());
    let mut acc = 0.0;
    for (i, it) in items.iter().enumerate() {
        acc += it.w;
        if acc >= target {
            return i + 1;
        }
    }
    items.len()
}

fn rcb_recurse(
    items: &mut [RcbItem],
    part_lo: usize,
    part_hi: usize,
    parts: &mut [u16],
    comm: &mut Vec<CommOp>,
) {
    let nparts = part_hi - part_lo;
    if nparts <= 1 || items.is_empty() {
        for it in items.iter() {
            parts[it.idx as usize] = part_lo as u16;
        }
        return;
    }
    // longest axis of the current bounding box
    let mut bb = BBox::empty();
    for it in items.iter() {
        bb.expand(crate::geometry::Vec3::new(it.pos[0], it.pos[1], it.pos[2]));
    }
    let ext = bb.extent();
    let axis = if ext.x >= ext.y && ext.x >= ext.z {
        0
    } else if ext.y >= ext.z {
        1
    } else {
        2
    };

    let p_left = nparts / 2;
    let total: f64 = items.iter().map(|i| i.w).sum();
    let target = total * p_left as f64 / nparts as f64;
    // median search: SPMD RCB does ~log(n) rounds of histogram
    // allreduce per level; charge one representative collective
    comm.push(CommOp::Allreduce { bytes: 64 });
    let split = weighted_split(items, axis, target);
    let (left, right) = items.split_at_mut(split);
    rcb_recurse(left, part_lo, part_lo + p_left, parts, comm);
    rcb_recurse(right, part_lo + p_left, part_hi, parts, comm);
}

impl Partitioner for Rcb {
    fn name(&self) -> &'static str {
        "RCB"
    }

    // geometric: implicitly incremental, owner-blind, no tunables
    fn traits(&self) -> MethodTraits {
        MethodTraits::INCREMENTAL
    }

    fn partition(&self, input: &PartitionInput) -> PartitionResult {
        let mut items: Vec<RcbItem> = input
            .leaves
            .iter()
            .enumerate()
            .map(|(i, &id)| {
                let c = input.mesh.centroid(id);
                RcbItem {
                    pos: [c.x, c.y, c.z],
                    w: input.weights[i],
                    idx: i as u32,
                }
            })
            .collect();
        let mut parts = vec![0u16; input.leaves.len()];
        let mut comm = Vec::new();
        rcb_recurse(&mut items, 0, input.nparts, &mut parts, &mut comm);
        PartitionResult { parts, comm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generator;
    use crate::mesh::topology::LeafTopology;
    use crate::partition::testutil::{assert_valid_partition, setup_mesh};

    #[test]
    fn balances_unit_weights() {
        let mesh = setup_mesh(2);
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let owners = vec![0u16; leaves.len()];
        for p in [2usize, 3, 8, 13] {
            let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, p);
            let r = Rcb::new().partition(&input);
            assert_valid_partition(&input, &r, 0.05);
        }
    }

    #[test]
    fn cylinder_parts_are_slabs() {
        // on the long cylinder RCB should cut mainly along x, making
        // nearly-minimal interfaces -- the paper's "special case" where
        // RCB wins (Table 1 discussion)
        let mesh = generator::omega1_cylinder(3);
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let owners = vec![0u16; leaves.len()];
        let p = 8;
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, p);
        let r = Rcb::new().partition(&input);
        // each part's x-extent should be ~ length/p
        let mut lo = vec![f64::INFINITY; p];
        let mut hi = vec![f64::NEG_INFINITY; p];
        for (i, &id) in leaves.iter().enumerate() {
            let x = mesh.centroid(id).x;
            let k = r.parts[i] as usize;
            lo[k] = lo[k].min(x);
            hi[k] = hi[k].max(x);
        }
        for k in 0..p {
            assert!(
                hi[k] - lo[k] < 8.0 / p as f64 * 2.5,
                "part {k} x-extent {} too wide",
                hi[k] - lo[k]
            );
        }
    }

    #[test]
    fn better_than_random_cut() {
        let mesh = setup_mesh(3);
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let owners = vec![0u16; leaves.len()];
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 8);
        let r = Rcb::new().partition(&input);
        let topo = LeafTopology::build_for(&mesh, leaves.clone());
        let cut = topo.interface_faces(&r.parts);
        let random_cut = topo.n_interior_faces as f64 * (1.0 - 1.0 / 8.0);
        assert!((cut as f64) < 0.35 * random_cut);
    }

    #[test]
    fn nonuniform_weights() {
        let mesh = setup_mesh(2);
        let leaves = mesh.leaves_unordered();
        let weights: Vec<f64> = leaves
            .iter()
            .enumerate()
            .map(|(i, _)| 1.0 + (i % 5) as f64)
            .collect();
        let owners = vec![0u16; leaves.len()];
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 4);
        let r = Rcb::new().partition(&input);
        assert_valid_partition(&input, &r, 0.1);
    }
}
