//! The refinement-tree partitioner (RTK, §2.1) -- the paper's central
//! algorithmic contribution.
//!
//! Mitchell's refinement-tree method orders leaves by a DFS of the
//! refinement forest (left child first, siblings face-adjacent) and
//! cuts that sequence into p equal-weight runs. Mitchell's original
//! needs per-node subtree weights and costs O(N log p + p log N) with
//! awkward communication for shared interior nodes; the paper's
//! reformulation replaces subtree weights with per-leaf *prefix sums*:
//!
//!   S_i = sum_{j < i} w_j                         (eq. 1)
//!   leaf i -> part k  iff  S_i in [W k/p, W (k+1)/p)   (interval rule)
//!
//! distributed as (eq. 3):  S_{i,j} = sum_{q<i} W_q + local prefix --
//! i.e. Step 1: one local traversal summing local weights W_i; Step 2:
//! one `MPI_Scan`; Step 3: a second traversal assigning parts on the
//! fly. Two traversals + one scan, O(N) total.
//!
//! Our SPMD emulation mirrors the three steps exactly: the leaves of
//! each current rank are walked separately (in global DFS order), the
//! scan is logged as a collective, then parts are assigned.

use super::{CommOp, MethodTraits, PartitionInput, PartitionResult, Partitioner};
use crate::util::hash::FxHashMap;

pub struct RefinementTree {
    _private: (),
}

impl RefinementTree {
    pub fn new() -> Self {
        Self { _private: () }
    }
}

impl Default for RefinementTree {
    fn default() -> Self {
        Self::new()
    }
}

impl Partitioner for RefinementTree {
    fn name(&self) -> &'static str {
        "RTK"
    }

    // refinement-tree prefix sums: implicitly incremental, no tunables
    fn traits(&self) -> MethodTraits {
        MethodTraits::INCREMENTAL
    }

    fn partition(&self, input: &PartitionInput) -> PartitionResult {
        let p = input.nparts;
        let nranks = input
            .owners
            .iter()
            .copied()
            .max()
            .map(|m| m as usize + 1)
            .unwrap_or(1)
            .max(p);

        // weight/owner lookup in the caller's leaf order
        let mut index_of: FxHashMap<u32, usize> = FxHashMap::default();
        index_of.reserve(input.leaves.len());
        for (i, &id) in input.leaves.iter().enumerate() {
            index_of.insert(id, i);
        }

        // The DFS (RTK) leaf order. In PHG this order is implicit in
        // the maintained tree; the traversal itself is Step 1 + Step 3.
        let dfs = input.mesh.leaves_dfs();
        debug_assert_eq!(dfs.len(), input.leaves.len());

        // ---- Step 1: per-rank local weight sums (first traversal).
        // A rank's leaves appear in global DFS order; each rank sums
        // its own leaves locally.
        let mut rank_w = vec![0.0f64; nranks];
        for &id in &dfs {
            let i = index_of[&id];
            rank_w[input.owners[i] as usize] += input.weights[i];
        }

        // ---- Step 2: MPI_Scan over ranks (exclusive prefix of W_i).
        let mut rank_prefix = vec![0.0f64; nranks];
        let mut acc = 0.0;
        for r in 0..nranks {
            rank_prefix[r] = acc;
            acc += rank_w[r];
        }
        let total_w = acc;
        let comm = vec![CommOp::Scan {
            bytes: std::mem::size_of::<f64>(),
        }];

        if total_w <= 0.0 || p == 1 {
            return PartitionResult {
                parts: vec![0; input.leaves.len()],
                comm,
            };
        }

        // ---- Step 3: second traversal -- each leaf's prefix sum and
        // the interval rule. In PHG every rank holds a DFS-contiguous
        // run (the invariant RTK itself maintains), so eq. (3)
        // `rank_prefix[r] + local_run` *is* the global DFS prefix; our
        // single-address-space emulation computes that global prefix
        // directly, which coincides with eq. (3) whenever the paper's
        // precondition holds and stays correct even when the caller
        // hands us an arbitrary distribution.
        let _ = rank_prefix; // consumed by the modeled MPI_Scan above
        let mut parts = vec![0u16; input.leaves.len()];
        let inv_chunk = p as f64 / total_w;
        let mut acc = 0.0f64;
        for &id in &dfs {
            let i = index_of[&id];
            let k = ((acc * inv_chunk) as usize).min(p - 1);
            parts[i] = k as u16;
            acc += input.weights[i];
        }

        PartitionResult { parts, comm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::topology::LeafTopology;
    use crate::partition::testutil::{assert_valid_partition, setup_mesh};
    use crate::util::propcheck;

    fn inputs(
        mesh: &crate::mesh::TetMesh,
        nparts: usize,
    ) -> (Vec<u32>, Vec<f64>, Vec<u16>, usize) {
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let owners = vec![0u16; leaves.len()];
        (leaves, weights, owners, nparts)
    }

    #[test]
    fn balances_unit_weights() {
        let mesh = setup_mesh(2);
        for p in [2usize, 4, 7, 16] {
            let (leaves, weights, owners, _) = inputs(&mesh, p);
            let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, p);
            let r = RefinementTree::new().partition(&input);
            assert_valid_partition(&input, &r, 0.05);
        }
    }

    #[test]
    fn parts_contiguous_in_dfs_order() {
        // the interval rule makes each part a contiguous run of the
        // DFS sequence -- the property that gives RTK its quality
        let mesh = setup_mesh(2);
        let (leaves, weights, owners, p) = inputs(&mesh, 8);
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, p);
        let r = RefinementTree::new().partition(&input);
        let index_of: std::collections::HashMap<u32, usize> = leaves
            .iter()
            .enumerate()
            .map(|(i, &id)| (id, i))
            .collect();
        let dfs = mesh.leaves_dfs();
        let seq: Vec<u16> = dfs.iter().map(|id| r.parts[index_of[id]]).collect();
        for w in seq.windows(2) {
            assert!(w[0] <= w[1], "parts not monotone along DFS");
        }
    }

    #[test]
    fn weighted_balance() {
        let mesh = setup_mesh(2);
        let leaves = mesh.leaves_unordered();
        // weight proportional to element volume (realistic DOF weight)
        let weights: Vec<f64> = leaves
            .iter()
            .map(|&id| 1.0 + 1e6 * mesh.elem_volume(id))
            .collect();
        let owners = vec![0u16; leaves.len()];
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 6);
        let r = RefinementTree::new().partition(&input);
        assert_valid_partition(&input, &r, 0.1);
    }

    #[test]
    fn single_part_all_zero() {
        let mesh = setup_mesh(1);
        let (leaves, weights, owners, _) = inputs(&mesh, 1);
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 1);
        let r = RefinementTree::new().partition(&input);
        assert!(r.parts.iter().all(|&x| x == 0));
    }

    #[test]
    fn logs_exactly_one_scan() {
        let mesh = setup_mesh(1);
        let (leaves, weights, owners, p) = inputs(&mesh, 4);
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, p);
        let r = RefinementTree::new().partition(&input);
        assert_eq!(r.comm.len(), 1);
        assert!(matches!(r.comm[0], CommOp::Scan { .. }));
    }

    #[test]
    fn distributed_owners_same_result_as_serial() {
        // eq. (3): the distributed prefix sums must reproduce the
        // serial prefix sums when ranks hold DFS-contiguous chunks
        // (which is how RTK itself distributes).
        let mesh = setup_mesh(2);
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let index_of: std::collections::HashMap<u32, usize> = leaves
            .iter()
            .enumerate()
            .map(|(i, &id)| (id, i))
            .collect();

        // serial: all on rank 0
        let owners0 = vec![0u16; leaves.len()];
        let input0 = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners0, 4);
        let r0 = RefinementTree::new().partition(&input0);

        // distributed: 4 DFS-contiguous chunks
        let dfs = mesh.leaves_dfs();
        let mut owners1 = vec![0u16; leaves.len()];
        for (pos, id) in dfs.iter().enumerate() {
            owners1[index_of[id]] = (pos * 4 / dfs.len()) as u16;
        }
        let input1 = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners1, 4);
        let r1 = RefinementTree::new().partition(&input1);

        assert_eq!(r0.parts, r1.parts);
    }

    #[test]
    fn quality_parts_mostly_connected() {
        // RTK's DFS runs should give parts with small surface: check
        // interface fraction is far below random assignment
        let mesh = setup_mesh(3);
        let (leaves, weights, owners, p) = inputs(&mesh, 8);
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, p);
        let r = RefinementTree::new().partition(&input);
        let topo = LeafTopology::build_for(&mesh, leaves.clone());
        let cut = topo.interface_faces(&r.parts);
        // random partition cuts ~ (1 - 1/p) of interior faces
        let random_cut = topo.n_interior_faces as f64 * (1.0 - 1.0 / p as f64);
        assert!(
            (cut as f64) < 0.35 * random_cut,
            "cut {cut} vs random {random_cut}"
        );
    }

    #[test]
    fn incremental_small_change_small_part_churn() {
        let mut mesh = setup_mesh(2);
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let owners = vec![0u16; leaves.len()];
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 4);
        let before = RefinementTree::new().partition(&input);
        let part_of: std::collections::HashMap<u32, u16> = leaves
            .iter()
            .zip(before.parts.iter())
            .map(|(&l, &p)| (l, p))
            .collect();

        let marked: Vec<u32> = leaves.iter().take(6).copied().collect();
        mesh.refine(&marked);
        let leaves2 = mesh.leaves_unordered();
        let weights2 = vec![1.0; leaves2.len()];
        let owners2 = vec![0u16; leaves2.len()];
        let input2 = PartitionInput::from_mesh(&mesh, &leaves2, &weights2, &owners2, 4);
        let after = RefinementTree::new().partition(&input2);

        let mut kept = 0;
        let mut tracked = 0;
        for (i, &id) in leaves2.iter().enumerate() {
            if let Some(&old) = part_of.get(&id) {
                tracked += 1;
                if old == after.parts[i] {
                    kept += 1;
                }
            }
        }
        assert!(
            kept as f64 > 0.8 * tracked as f64,
            "only {kept}/{tracked} kept"
        );
    }

    #[test]
    fn property_random_weights_balanced() {
        propcheck::check_with(0x47B6, 16, "rtk balances random weights", |rng| {
            let mesh = setup_mesh(2);
            let leaves = mesh.leaves_unordered();
            let weights: Vec<f64> =
                (0..leaves.len()).map(|_| rng.gen_uniform(0.5, 2.0)).collect();
            let owners = vec![0u16; leaves.len()];
            let p = 2 + rng.gen_range(10);
            let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, p);
            let r = RefinementTree::new().partition(&input);
            // every part non-empty and assignment complete
            let mut wsum = vec![0.0; p];
            for (i, &part) in r.parts.iter().enumerate() {
                wsum[part as usize] += weights[i];
            }
            let wmax = weights.iter().cloned().fold(0.0f64, f64::max);
            let ideal = weights.iter().sum::<f64>() / p as f64;
            let lam = crate::util::stats::imbalance(&wsum);
            assert!(
                lam <= 1.0 + wmax / ideal,
                "imbalance {lam} with p={p}"
            );
        });
    }
}
