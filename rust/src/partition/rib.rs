//! Recursive inertial bisection (RIB, Simon 1991): like RCB but each
//! bisection is along the principal axis of the point set's inertia
//! (covariance) tensor instead of a coordinate axis, so cuts adapt to
//! tilted geometry. The 3x3 symmetric eigenproblem is solved by Jacobi
//! rotations (no linear-algebra crate in this environment).

use super::{CommOp, MethodTraits, PartitionInput, PartitionResult, Partitioner};

pub struct Rib {
    _private: (),
}

impl Rib {
    pub fn new() -> Self {
        Self { _private: () }
    }
}

impl Default for Rib {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Clone, Copy)]
struct Item {
    pos: [f64; 3],
    w: f64,
    idx: u32,
}

/// Largest-eigenvalue eigenvector of a symmetric 3x3 matrix via
/// cyclic Jacobi. Exposed (crate) for direct testing.
pub(crate) fn principal_axis(mut a: [[f64; 3]; 3]) -> [f64; 3] {
    let mut v = [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]];
    for _sweep in 0..32 {
        // largest off-diagonal
        let mut off = 0.0;
        for r in 0..3 {
            for c in (r + 1)..3 {
                off += a[r][c] * a[r][c];
            }
        }
        if off < 1e-24 {
            break;
        }
        for p in 0..3 {
            for q in (p + 1)..3 {
                if a[p][q].abs() < 1e-300 {
                    continue;
                }
                let theta = (a[q][q] - a[p][p]) / (2.0 * a[p][q]);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate a
                for k in 0..3 {
                    let akp = a[k][p];
                    let akq = a[k][q];
                    a[k][p] = c * akp - s * akq;
                    a[k][q] = s * akp + c * akq;
                }
                for k in 0..3 {
                    let apk = a[p][k];
                    let aqk = a[q][k];
                    a[p][k] = c * apk - s * aqk;
                    a[q][k] = s * apk + c * aqk;
                }
                // accumulate v
                for k in 0..3 {
                    let vkp = v[k][p];
                    let vkq = v[k][q];
                    v[k][p] = c * vkp - s * vkq;
                    v[k][q] = s * vkp + c * vkq;
                }
            }
        }
    }
    // pick column with largest eigenvalue
    let mut best = 0;
    for i in 1..3 {
        if a[i][i] > a[best][best] {
            best = i;
        }
    }
    [v[0][best], v[1][best], v[2][best]]
}

fn rib_recurse(
    items: &mut [Item],
    part_lo: usize,
    part_hi: usize,
    parts: &mut [u16],
    comm: &mut Vec<CommOp>,
) {
    let nparts = part_hi - part_lo;
    if nparts <= 1 || items.is_empty() {
        for it in items.iter() {
            parts[it.idx as usize] = part_lo as u16;
        }
        return;
    }
    // weighted centroid + covariance (the inertia tensor modulo trace)
    let total: f64 = items.iter().map(|i| i.w).sum();
    let mut cen = [0.0f64; 3];
    for it in items.iter() {
        for d in 0..3 {
            cen[d] += it.w * it.pos[d];
        }
    }
    for c in cen.iter_mut() {
        *c /= total.max(1e-300);
    }
    let mut cov = [[0.0f64; 3]; 3];
    for it in items.iter() {
        let d = [
            it.pos[0] - cen[0],
            it.pos[1] - cen[1],
            it.pos[2] - cen[2],
        ];
        for r in 0..3 {
            for c in 0..3 {
                cov[r][c] += it.w * d[r] * d[c];
            }
        }
    }
    let axis = principal_axis(cov);
    comm.push(CommOp::Allreduce { bytes: 9 * 8 + 64 });

    // project and split at the weighted median
    let p_left = nparts / 2;
    let target = total * p_left as f64 / nparts as f64;
    items.sort_unstable_by(|a, b| {
        let pa = a.pos[0] * axis[0] + a.pos[1] * axis[1] + a.pos[2] * axis[2];
        let pb = b.pos[0] * axis[0] + b.pos[1] * axis[1] + b.pos[2] * axis[2];
        pa.partial_cmp(&pb).unwrap()
    });
    let mut acc = 0.0;
    let mut split = items.len();
    for (i, it) in items.iter().enumerate() {
        acc += it.w;
        if acc >= target {
            split = i + 1;
            break;
        }
    }
    let (left, right) = items.split_at_mut(split);
    rib_recurse(left, part_lo, part_lo + p_left, parts, comm);
    rib_recurse(right, part_lo + p_left, part_hi, parts, comm);
}

impl Partitioner for Rib {
    fn name(&self) -> &'static str {
        "RIB"
    }

    // geometric: implicitly incremental, owner-blind, no tunables
    fn traits(&self) -> MethodTraits {
        MethodTraits::INCREMENTAL
    }

    fn partition(&self, input: &PartitionInput) -> PartitionResult {
        let mut items: Vec<Item> = input
            .leaves
            .iter()
            .enumerate()
            .map(|(i, &id)| {
                let c = input.mesh.centroid(id);
                Item {
                    pos: [c.x, c.y, c.z],
                    w: input.weights[i],
                    idx: i as u32,
                }
            })
            .collect();
        let mut parts = vec![0u16; input.leaves.len()];
        let mut comm = Vec::new();
        rib_recurse(&mut items, 0, input.nparts, &mut parts, &mut comm);
        PartitionResult { parts, comm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::testutil::{assert_valid_partition, setup_mesh};

    #[test]
    fn principal_axis_of_diagonal() {
        let a = [[5.0, 0.0, 0.0], [0.0, 2.0, 0.0], [0.0, 0.0, 1.0]];
        let v = principal_axis(a);
        assert!(v[0].abs() > 0.99, "{v:?}");
    }

    #[test]
    fn principal_axis_of_rotated() {
        // covariance of points along (1,1,0)
        let a = [[1.0, 1.0, 0.0], [1.0, 1.0, 0.0], [0.0, 0.0, 0.1]];
        let v = principal_axis(a);
        let dot = (v[0] + v[1]).abs() / 2.0f64.sqrt();
        assert!(dot > 0.99, "{v:?}");
    }

    #[test]
    fn balances() {
        let mesh = setup_mesh(2);
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let owners = vec![0u16; leaves.len()];
        for p in [2usize, 5, 8] {
            let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, p);
            let r = Rib::new().partition(&input);
            assert_valid_partition(&input, &r, 0.05);
        }
    }

    #[test]
    fn tilted_domain_first_cut_follows_diagonal() {
        // stretch a cube along (1,1,1) by using a box mesh then shearing
        let mut mesh = crate::mesh::generator::cube_mesh(3);
        for v in &mut mesh.vertices {
            let t = v.x;
            v.x += 3.0 * t; // stretch x
            v.y += 3.0 * t; // shear y along x: principal dir ~ (1, 0.75, 0)
        }
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let owners = vec![0u16; leaves.len()];
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 2);
        let r = Rib::new().partition(&input);
        assert_valid_partition(&input, &r, 0.05);
        // the two parts should separate along the stretched direction:
        // compare part centroids
        let mut c = [crate::geometry::Vec3::ZERO; 2];
        let mut n = [0usize; 2];
        for (i, &id) in leaves.iter().enumerate() {
            c[r.parts[i] as usize] += mesh.centroid(id);
            n[r.parts[i] as usize] += 1;
        }
        let d = c[0] / n[0] as f64 - c[1] / n[1] as f64;
        assert!(
            d.x.abs() > d.z.abs(),
            "separation {d:?} not along stretch"
        );
    }
}
