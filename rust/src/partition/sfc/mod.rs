//! Space-filling-curve partitioning (§2.2): map element centroids to
//! 1-D keys along a Morton or Hilbert curve, then run the 1-D
//! partitioner (§2.3).
//!
//! The normalization of the domain bounding box onto the unit cube is
//! the paper's PHG-vs-Zoltan distinction:
//!
//! * [`Normalization::AspectPreserving`] (PHG): divide all axes by the
//!   single longest extent `len = max(len_x, len_y, len_z)`, preserving
//!   the domain's aspect ratio and hence spatial locality;
//! * [`Normalization::PerAxis`] (Zoltan): divide each axis by its own
//!   extent, stretching anisotropic domains (the long cylinder) to a
//!   cube and destroying locality -- the measured quality gap between
//!   PHG/HSFC and Zoltan/HSFC in §3 comes from exactly this.

pub mod hilbert;
pub mod morton;

use super::oned::{assign_parts, partition_1d};
use super::{MethodTraits, PartitionInput, PartitionResult, Partitioner};
use crate::geometry::BBox;
use crate::mesh::{ElemId, TetMesh};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Curve {
    Morton,
    Hilbert,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Normalization {
    /// PHG: x1 = (x - x0)/len with len = max extent (locality-keeping).
    AspectPreserving,
    /// Zoltan: x1 = (x - x0)/len_x etc. (stretches the domain).
    PerAxis,
}

/// SFC keys for the given leaves. Exposed for reuse by RCB-adjacent
/// code and the benches.
pub fn sfc_keys(
    mesh: &TetMesh,
    leaves: &[ElemId],
    curve: Curve,
    norm: Normalization,
) -> Vec<u64> {
    let mut bb = BBox::empty();
    for &id in leaves {
        bb.expand(mesh.centroid(id));
    }
    keys_in_bbox(mesh, leaves, &bb, curve, norm)
}

fn keys_in_bbox(
    mesh: &TetMesh,
    leaves: &[ElemId],
    bb: &BBox,
    curve: Curve,
    norm: Normalization,
) -> Vec<u64> {
    let ext = bb.extent();
    let max_len = bb.max_extent().max(1e-300);
    let scale = match norm {
        Normalization::AspectPreserving => [max_len, max_len, max_len],
        Normalization::PerAxis => [
            ext.x.max(1e-300),
            ext.y.max(1e-300),
            ext.z.max(1e-300),
        ],
    };
    let side = (1u64 << morton::BITS) as f64;
    let to_int = |v: f64, lo: f64, s: f64| -> u32 {
        let t = ((v - lo) / s).clamp(0.0, 1.0);
        ((t * side) as u64).min((1 << morton::BITS) - 1) as u32
    };
    leaves
        .iter()
        .map(|&id| {
            let c = mesh.centroid(id);
            let xi = to_int(c.x, bb.lo.x, scale[0]);
            let yi = to_int(c.y, bb.lo.y, scale[1]);
            let zi = to_int(c.z, bb.lo.z, scale[2]);
            match curve {
                Curve::Morton => morton::morton_key(xi, yi, zi),
                Curve::Hilbert => hilbert::hilbert_key(xi, yi, zi),
            }
        })
        .collect()
}

/// The SFC partitioner: keys, then the §2.3 1-D partition, then the
/// subgrid-process mapping is applied separately by `remap`.
pub struct SfcPartitioner {
    pub curve: Curve,
    pub norm: Normalization,
    /// 1-D search fan-out (probes per splitter).
    pub k: usize,
    /// relative balance tolerance for the 1-D search
    pub tol: f64,
    name: &'static str,
}

impl SfcPartitioner {
    pub fn new(curve: Curve, norm: Normalization, name: &'static str) -> Self {
        Self {
            curve,
            norm,
            k: 8,
            tol: 1e-4,
            name,
        }
    }

    /// PHG's Morton SFC method.
    pub fn msfc() -> Self {
        Self::new(Curve::Morton, Normalization::AspectPreserving, "MSFC")
    }

    /// PHG's Hilbert SFC method (aspect-preserving normalization).
    pub fn phg_hsfc() -> Self {
        Self::new(Curve::Hilbert, Normalization::AspectPreserving, "PHG/HSFC")
    }

    /// Zoltan's Hilbert SFC method (per-axis normalization).
    pub fn zoltan_hsfc() -> Self {
        Self::new(Curve::Hilbert, Normalization::PerAxis, "Zoltan/HSFC")
    }
}

impl Partitioner for SfcPartitioner {
    fn name(&self) -> &'static str {
        self.name
    }

    // SFC order is stable under local refinement: implicitly
    // incremental, owner-blind, no tunables
    fn traits(&self) -> MethodTraits {
        MethodTraits::INCREMENTAL
    }

    fn partition(&self, input: &PartitionInput) -> PartitionResult {
        let keys = sfc_keys(input.mesh, input.leaves, self.curve, self.norm);
        let r = partition_1d(&keys, input.weights, input.nparts, self.k, self.tol);
        let parts = assign_parts(&keys, &r.splitters);
        PartitionResult {
            parts,
            comm: r.comm,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generator;
    use crate::partition::testutil::{assert_valid_partition, setup_mesh};

    fn run(curve: Curve, norm: Normalization, nparts: usize) {
        let mesh = setup_mesh(2);
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let owners = vec![0u16; leaves.len()];
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, nparts);
        let p = SfcPartitioner::new(curve, norm, "test");
        let r = p.partition(&input);
        assert_valid_partition(&input, &r, 0.15);
    }

    #[test]
    fn morton_balances() {
        run(Curve::Morton, Normalization::AspectPreserving, 4);
        run(Curve::Morton, Normalization::AspectPreserving, 7);
    }

    #[test]
    fn hilbert_balances() {
        run(Curve::Hilbert, Normalization::AspectPreserving, 4);
        run(Curve::Hilbert, Normalization::PerAxis, 8);
    }

    #[test]
    fn parts_are_spatially_coherent() {
        // each part's elements should form a compact blob: mean
        // distance to the part centroid well below the domain diameter
        let mesh = setup_mesh(2);
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let owners = vec![0u16; leaves.len()];
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 8);
        let r = SfcPartitioner::phg_hsfc().partition(&input);
        let mut cents = vec![crate::geometry::Vec3::ZERO; 8];
        let mut counts = vec![0usize; 8];
        for (i, &id) in leaves.iter().enumerate() {
            cents[r.parts[i] as usize] += mesh.centroid(id);
            counts[r.parts[i] as usize] += 1;
        }
        for (c, &n) in cents.iter_mut().zip(&counts) {
            if n > 0 {
                *c = *c / n as f64;
            }
        }
        let mut mean_d = 0.0;
        for (i, &id) in leaves.iter().enumerate() {
            mean_d += (mesh.centroid(id) - cents[r.parts[i] as usize]).norm();
        }
        mean_d /= leaves.len() as f64;
        // domain diameter = sqrt(3); compact blobs at p=8 should be ~< 0.35
        assert!(mean_d < 0.4, "mean dist to part centroid {mean_d}");
    }

    #[test]
    fn normalization_matters_on_anisotropic_domain() {
        // On the long cylinder, aspect-preserving HSFC should produce
        // fewer interface faces than per-axis HSFC (the paper's §2.2
        // claim; the ablation bench quantifies it).
        use crate::mesh::topology::LeafTopology;
        let mesh = generator::omega1_cylinder(3);
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let owners = vec![0u16; leaves.len()];
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 16);
        let topo = LeafTopology::build_for(&mesh, leaves.clone());

        let phg = SfcPartitioner::phg_hsfc().partition(&input);
        let zol = SfcPartitioner::zoltan_hsfc().partition(&input);
        let cut_phg = topo.interface_faces(&phg.parts);
        let cut_zol = topo.interface_faces(&zol.parts);
        assert!(
            (cut_phg as f64) < 1.05 * cut_zol as f64,
            "aspect-preserving {cut_phg} vs per-axis {cut_zol}"
        );
    }

    #[test]
    fn normalizations_agree_on_cube() {
        // On the unit cube the two normalizations coincide (table 2/3
        // observation), so the partitions should be identical.
        let mesh = setup_mesh(1);
        let leaves = mesh.leaves_unordered();
        let keys_a = sfc_keys(
            &mesh,
            &leaves,
            Curve::Hilbert,
            Normalization::AspectPreserving,
        );
        let keys_b = sfc_keys(&mesh, &leaves, Curve::Hilbert, Normalization::PerAxis);
        assert_eq!(keys_a, keys_b);
    }

    #[test]
    fn incremental_under_small_change() {
        // refine a few elements: most leaves keep their part (implicit
        // incrementality of SFC methods, §1)
        let mut mesh = setup_mesh(2);
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let owners = vec![0u16; leaves.len()];
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 4);
        let before = SfcPartitioner::phg_hsfc().partition(&input);
        let part_of_leaf: std::collections::HashMap<_, _> = leaves
            .iter()
            .zip(before.parts.iter())
            .map(|(&l, &p)| (l, p))
            .collect();

        // small local refinement
        let marked: Vec<_> = leaves.iter().take(8).copied().collect();
        mesh.refine(&marked);
        let leaves2 = mesh.leaves_unordered();
        let weights2 = vec![1.0; leaves2.len()];
        let owners2 = vec![0u16; leaves2.len()];
        let input2 = PartitionInput::from_mesh(&mesh, &leaves2, &weights2, &owners2, 4);
        let after = SfcPartitioner::phg_hsfc().partition(&input2);

        let mut kept = 0;
        let mut tracked = 0;
        for (i, &id) in leaves2.iter().enumerate() {
            if let Some(&old) = part_of_leaf.get(&id) {
                tracked += 1;
                if old == after.parts[i] {
                    kept += 1;
                }
            }
        }
        assert!(
            kept as f64 > 0.85 * tracked as f64,
            "only {kept}/{tracked} leaves kept their part"
        );
    }
}
