//! Morton (Z-order) space-filling curve: bit interleaving of the three
//! 21-bit integer coordinates into a 63-bit key. Simple and fast, but
//! the curve has large jumps, so its spatial locality is slightly worse
//! than Hilbert's -- exactly the MSFC-vs-HSFC trade-off in §2.2.

/// Number of bits per axis (3 * 21 = 63 <= 64).
pub const BITS: u32 = 21;

/// Spread the low 21 bits of `x` so consecutive bits land 3 apart
/// (magic-number bit twiddling, the standard 3-D morton gather).
#[inline]
fn spread(x: u64) -> u64 {
    let mut x = x & 0x1F_FFFF; // 21 bits
    x = (x | (x << 32)) & 0x1F_0000_0000_FFFF;
    x = (x | (x << 16)) & 0x1F_0000_FF00_00FF;
    x = (x | (x << 8)) & 0x100F_00F0_0F00_F00F;
    x = (x | (x << 4)) & 0x10C3_0C30_C30C_30C3;
    x = (x | (x << 2)) & 0x1249_2492_4924_9249;
    x
}

/// Morton key of integer coords (each < 2^21). Bit layout:
/// x gets bits 0, 3, 6, ...; y gets 1, 4, 7, ...; z gets 2, 5, 8, ...
#[inline]
pub fn morton_key(x: u32, y: u32, z: u32) -> u64 {
    spread(x as u64) | (spread(y as u64) << 1) | (spread(z as u64) << 2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn small_cases() {
        assert_eq!(morton_key(0, 0, 0), 0);
        assert_eq!(morton_key(1, 0, 0), 0b001);
        assert_eq!(morton_key(0, 1, 0), 0b010);
        assert_eq!(morton_key(0, 0, 1), 0b100);
        assert_eq!(morton_key(1, 1, 1), 0b111);
        assert_eq!(morton_key(2, 0, 0), 0b001_000);
        assert_eq!(morton_key(3, 5, 1), {
            // x=011, y=101, z=001 -> interleave z y x per bit level
            // bit0: x0=1,y0=1,z0=1 -> 111
            // bit1: x1=1,y1=0,z1=0 -> 001
            // bit2: x2=0,y2=1,z2=0 -> 010
            0b010_001_111
        });
    }

    #[test]
    fn injective_on_random_coords() {
        propcheck::check("morton is injective", |rng| {
            let a = (
                rng.gen_range(1 << 21) as u32,
                rng.gen_range(1 << 21) as u32,
                rng.gen_range(1 << 21) as u32,
            );
            let b = (
                rng.gen_range(1 << 21) as u32,
                rng.gen_range(1 << 21) as u32,
                rng.gen_range(1 << 21) as u32,
            );
            if a != b {
                assert_ne!(morton_key(a.0, a.1, a.2), morton_key(b.0, b.1, b.2));
            }
        });
    }

    #[test]
    fn monotone_along_axes() {
        // along each axis with the others 0, the key grows monotonically
        for i in 1..100u32 {
            assert!(morton_key(i, 0, 0) > morton_key(i - 1, 0, 0));
            assert!(morton_key(0, i, 0) > morton_key(0, i - 1, 0));
            assert!(morton_key(0, 0, i) > morton_key(0, 0, i - 1));
        }
    }

    #[test]
    fn max_coord_fits() {
        let m = (1u32 << BITS) - 1;
        let k = morton_key(m, m, m);
        assert_eq!(k, (1u64 << 63) - 1);
    }

    #[test]
    fn locality_beats_random_order() {
        // average key distance of adjacent cells should be far below
        // that of random cell pairs
        let n = 16u32;
        let mut adj = 0.0f64;
        let mut cnt = 0;
        for x in 0..n - 1 {
            for y in 0..n {
                for z in 0..n {
                    let a = morton_key(x, y, z) as f64;
                    let b = morton_key(x + 1, y, z) as f64;
                    adj += (a - b).abs();
                    cnt += 1;
                }
            }
        }
        adj /= cnt as f64;
        let far = (morton_key(0, 0, 0) as f64 - morton_key(n - 1, n - 1, n - 1) as f64).abs();
        assert!(adj < far / 8.0, "adjacent mean {adj} vs span {far}");
    }
}
