//! 3-D Hilbert space-filling curve via Skilling's transpose algorithm
//! ("Programming the Hilbert curve", AIP Conf. Proc. 707, 2004).
//!
//! Much better spatial locality than Morton (no long jumps), at the
//! cost of a more complex generator -- the trade-off §2.2 describes.
//! `AxestoTranspose` converts integer coordinates into the "transposed"
//! Hilbert index (one bit-plane per axis), which we then interleave
//! into a single 63-bit key.

pub const BITS: u32 = 21;

/// Hilbert key of integer coords (each < 2^21).
pub fn hilbert_key(x: u32, y: u32, z: u32) -> u64 {
    let mut xs = [x, y, z];
    axes_to_transpose(&mut xs, BITS);
    interleave_transposed(&xs, BITS)
}

/// In-place AxestoTranspose (Skilling 2004), n = 3 axes.
///
/// The per-bit loop is branchless (#Perf pass): the two cases of
/// Skilling's conditional are blended with a mask derived from the
/// tested bit, removing 63 unpredictable branches per key.
fn axes_to_transpose(xv: &mut [u32; 3], bits: u32) {
    let m = 1u32 << (bits - 1);

    // Inverse undo
    let mut q = m;
    while q > 1 {
        let p = q - 1;
        for i in 0..3 {
            // sel = all-ones when bit q of xv[i] is set
            let sel = 0u32.wrapping_sub((xv[i] >> q.trailing_zeros()) & 1);
            let t = (xv[0] ^ xv[i]) & p & !sel;
            xv[0] ^= (p & sel) | t;
            xv[i] ^= t;
        }
        q >>= 1;
    }

    // Gray encode
    for i in 1..3 {
        xv[i] ^= xv[i - 1];
    }
    let mut t = 0u32;
    q = m;
    while q > 1 {
        if xv[2] & q != 0 {
            t ^= q - 1;
        }
        q >>= 1;
    }
    for x in xv.iter_mut() {
        *x ^= t;
    }
}

/// Interleave the transposed index: the key's most-significant bit
/// triple is (bit b of X[0], X[1], X[2]) at b = bits-1.
///
/// Uses the same magic-number bit spreading as the Morton code instead
/// of a 63-iteration bit loop -- part of the #Perf pass (4.9x on the
/// hilbert-key microbench; see EXPERIMENTS.md).
#[inline]
fn interleave_transposed(xv: &[u32; 3], bits: u32) -> u64 {
    debug_assert!(bits <= 21);
    // X[0] is the most significant axis of each bit triple
    (spread21(xv[0] as u64) << 2) | (spread21(xv[1] as u64) << 1) | spread21(xv[2] as u64)
}

/// Spread the low 21 bits so consecutive bits land 3 apart.
#[inline]
fn spread21(x: u64) -> u64 {
    let mut x = x & 0x1F_FFFF;
    x = (x | (x << 32)) & 0x1F_0000_0000_FFFF;
    x = (x | (x << 16)) & 0x1F_0000_FF00_00FF;
    x = (x | (x << 8)) & 0x100F_00F0_0F00_F00F;
    x = (x | (x << 4)) & 0x10C3_0C30_C30C_30C3;
    x = (x | (x << 2)) & 0x1249_2492_4924_9249;
    x
}

/// Inverse of `interleave_transposed` (test support).
fn deinterleave(key: u64, bits: u32) -> [u32; 3] {
    let mut xv = [0u32; 3];
    let mut k = key;
    for b in 0..bits {
        for i in (0..3).rev() {
            xv[i] |= ((k & 1) as u32) << b;
            k >>= 1;
        }
    }
    xv
}

/// TransposetoAxes (Skilling 2004) -- the exact inverse, used by tests
/// to prove bijectivity.
fn transpose_to_axes(xv: &mut [u32; 3], bits: u32) {
    let n = 3;
    let m = 1u32 << (bits - 1);

    // Gray decode by H ^ (H/2)
    let mut t = xv[n - 1] >> 1;
    for i in (1..n).rev() {
        xv[i] ^= xv[i - 1];
    }
    xv[0] ^= t;

    // Undo excess work
    let mut q = 2u32;
    while q != m << 1 {
        let p = q - 1;
        for i in (0..n).rev() {
            if xv[i] & q != 0 {
                xv[0] ^= p;
            } else {
                t = (xv[0] ^ xv[i]) & p;
                xv[0] ^= t;
                xv[i] ^= t;
            }
        }
        q <<= 1;
    }
}

/// Inverse Hilbert: key -> integer coordinates (test support and the
/// partition-gallery visualizer).
pub fn hilbert_key_inverse(key: u64) -> [u32; 3] {
    let mut xv = deinterleave(key, BITS);
    transpose_to_axes(&mut xv, BITS);
    xv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    /// Hilbert keys at `bits` resolution, brute-forced by scaling up
    /// coordinates to the full 21-bit lattice.
    fn key_at(bits: u32, x: u32, y: u32, z: u32) -> u64 {
        let shift = BITS - bits;
        hilbert_key(x << shift, y << shift, z << shift) >> (3 * shift)
    }

    #[test]
    fn bits1_visits_all_octants_adjacently() {
        // At 1-bit resolution the curve visits the 8 octants in an
        // order where consecutive octants differ in exactly one axis
        // (the defining property of a Hilbert cell order).
        let mut order: Vec<(u64, (u32, u32, u32))> = Vec::new();
        for x in 0..2 {
            for y in 0..2 {
                for z in 0..2 {
                    order.push((key_at(1, x, y, z), (x, y, z)));
                }
            }
        }
        order.sort();
        let keys: Vec<u64> = order.iter().map(|e| e.0).collect();
        assert_eq!(keys, (0..8).collect::<Vec<u64>>(), "keys not a permutation");
        for w in order.windows(2) {
            let a = w[0].1;
            let b = w[1].1;
            let diff = (a.0 != b.0) as u32 + (a.1 != b.1) as u32 + (a.2 != b.2) as u32;
            assert_eq!(diff, 1, "octants {a:?} -> {b:?} not face-adjacent");
        }
    }

    #[test]
    fn curve_is_continuous_at_depth() {
        // Defining Hilbert property at any resolution: consecutive
        // cells along the curve are face neighbours (L1 distance 1).
        for bits in [2u32, 3, 4] {
            let n = 1u32 << bits;
            let mut cells: Vec<(u64, [u32; 3])> = Vec::new();
            for x in 0..n {
                for y in 0..n {
                    for z in 0..n {
                        cells.push((key_at(bits, x, y, z), [x, y, z]));
                    }
                }
            }
            cells.sort();
            // keys are a permutation of 0..n^3
            for (i, c) in cells.iter().enumerate() {
                assert_eq!(c.0, i as u64, "bits {bits}: keys not dense");
            }
            for w in cells.windows(2) {
                let d: u32 = w[0]
                    .1
                    .iter()
                    .zip(&w[1].1)
                    .map(|(a, b)| a.abs_diff(*b))
                    .sum();
                assert_eq!(
                    d, 1,
                    "bits {bits}: cells {:?} -> {:?} not adjacent",
                    w[0].1, w[1].1
                );
            }
        }
    }

    #[test]
    fn full_resolution_roundtrip() {
        propcheck::check("hilbert key inverse roundtrips", |rng| {
            let x = rng.gen_range(1 << BITS) as u32;
            let y = rng.gen_range(1 << BITS) as u32;
            let z = rng.gen_range(1 << BITS) as u32;
            let key = hilbert_key(x, y, z);
            assert_eq!(hilbert_key_inverse(key), [x, y, z]);
        });
    }

    #[test]
    fn injective_at_full_resolution() {
        propcheck::check("hilbert is injective", |rng| {
            let a = [
                rng.gen_range(1 << BITS) as u32,
                rng.gen_range(1 << BITS) as u32,
                rng.gen_range(1 << BITS) as u32,
            ];
            let b = [
                rng.gen_range(1 << BITS) as u32,
                rng.gen_range(1 << BITS) as u32,
                rng.gen_range(1 << BITS) as u32,
            ];
            if a != b {
                assert_ne!(
                    hilbert_key(a[0], a[1], a[2]),
                    hilbert_key(b[0], b[1], b[2])
                );
            }
        });
    }

    #[test]
    fn locality_beats_morton() {
        // The paper's reason to prefer HSFC: walking the curve, every
        // Hilbert step moves to a face-adjacent cell (mean L1 jump
        // exactly 1), while Morton makes long jumps (mean L1 jump > 1).
        use super::super::morton::morton_key;
        let bits = 4u32;
        let n = 1u32 << bits;
        let shift = BITS - bits;
        let mut h_cells: Vec<(u64, [u32; 3])> = Vec::new();
        let mut m_cells: Vec<(u64, [u32; 3])> = Vec::new();
        for x in 0..n {
            for y in 0..n {
                for z in 0..n {
                    h_cells.push((
                        hilbert_key(x << shift, y << shift, z << shift),
                        [x, y, z],
                    ));
                    m_cells.push((morton_key(x, y, z), [x, y, z]));
                }
            }
        }
        h_cells.sort();
        m_cells.sort();
        let mean_jump = |cells: &[(u64, [u32; 3])]| -> f64 {
            cells
                .windows(2)
                .map(|w| {
                    w[0].1
                        .iter()
                        .zip(&w[1].1)
                        .map(|(a, b)| a.abs_diff(*b) as f64)
                        .sum::<f64>()
                })
                .sum::<f64>()
                / (cells.len() - 1) as f64
        };
        let h = mean_jump(&h_cells);
        let m = mean_jump(&m_cells);
        assert!((h - 1.0).abs() < 1e-12, "hilbert mean jump {h} != 1");
        assert!(m > 1.3, "morton mean jump {m} unexpectedly small");
    }
}
