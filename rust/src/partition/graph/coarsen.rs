//! Coarsening phase: heavy-edge matching (HEM) + graph contraction.

use super::CsrGraph;
use crate::util::rng::Pcg32;

/// One round of heavy-edge matching followed by contraction.
/// Returns the coarse graph and the fine->coarse vertex map.
pub fn heavy_edge_matching(g: &CsrGraph, rng: &mut Pcg32) -> (CsrGraph, Vec<u32>) {
    let n = g.n();
    const UNMATCHED: u32 = u32::MAX;
    let mut mate = vec![UNMATCHED; n];

    // random visit order (standard HEM: breaks grid artifacts)
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);

    for &v in &order {
        let v = v as usize;
        if mate[v] != UNMATCHED {
            continue;
        }
        // heaviest incident edge to an unmatched neighbour
        let mut best: Option<(u32, f64)> = None;
        for (u, w) in g.neighbors(v) {
            if u as usize == v || mate[u as usize] != UNMATCHED {
                continue;
            }
            if best.map(|(_, bw)| w > bw).unwrap_or(true) {
                best = Some((u, w));
            }
        }
        match best {
            Some((u, _)) => {
                mate[v] = u;
                mate[u as usize] = v as u32;
            }
            None => {
                mate[v] = v as u32; // matched with itself
            }
        }
    }

    // assign coarse ids
    let mut map = vec![u32::MAX; n];
    let mut nc = 0u32;
    for v in 0..n {
        if map[v] != u32::MAX {
            continue;
        }
        let m = mate[v] as usize;
        map[v] = nc;
        map[m] = nc; // m == v for self-matched
        nc += 1;
    }

    // contract: sum vertex weights, merge parallel edges
    let ncz = nc as usize;
    let mut vwgt = vec![0.0f64; ncz];
    for v in 0..n {
        vwgt[map[v] as usize] += g.vwgt[v];
    }
    // build adjacency with a per-coarse-vertex scatter buffer
    let mut xadj = Vec::with_capacity(ncz + 1);
    let mut adjncy: Vec<u32> = Vec::with_capacity(g.adjncy.len() / 2);
    let mut adjwgt: Vec<f64> = Vec::with_capacity(g.adjncy.len() / 2);
    xadj.push(0u32);

    // coarse vertex -> its (up to two) fine vertices
    let mut members: Vec<(u32, u32)> = vec![(u32::MAX, u32::MAX); ncz];
    for v in 0..n {
        let c = map[v] as usize;
        if members[c].0 == u32::MAX {
            members[c].0 = v as u32;
        } else if members[c].0 != v as u32 {
            members[c].1 = v as u32;
        }
    }

    let mut pos_of: Vec<u32> = vec![u32::MAX; ncz]; // coarse nbr -> slot in current row
    let mut touched: Vec<u32> = Vec::with_capacity(32);
    for c in 0..ncz {
        let row_start = adjncy.len();
        let (a, b) = members[c];
        for fv in [a, b] {
            if fv == u32::MAX {
                continue;
            }
            for (u, w) in g.neighbors(fv as usize) {
                let cu = map[u as usize];
                if cu as usize == c {
                    continue; // internal edge vanishes
                }
                let slot = pos_of[cu as usize];
                if slot == u32::MAX {
                    pos_of[cu as usize] = adjncy.len() as u32;
                    touched.push(cu);
                    adjncy.push(cu);
                    adjwgt.push(w);
                } else {
                    adjwgt[slot as usize] += w;
                }
            }
        }
        for &t in &touched {
            pos_of[t as usize] = u32::MAX;
        }
        touched.clear();
        let _ = row_start;
        xadj.push(adjncy.len() as u32);
    }

    (
        CsrGraph {
            xadj,
            adjncy,
            adjwgt,
            vwgt,
        },
        map,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_graph(nx: usize, ny: usize) -> CsrGraph {
        let n = nx * ny;
        let id = |x: usize, y: usize| (y * nx + x) as u32;
        let mut xadj = vec![0u32];
        let mut adjncy = Vec::new();
        for y in 0..ny {
            for x in 0..nx {
                if x > 0 {
                    adjncy.push(id(x - 1, y));
                }
                if x + 1 < nx {
                    adjncy.push(id(x + 1, y));
                }
                if y > 0 {
                    adjncy.push(id(x, y - 1));
                }
                if y + 1 < ny {
                    adjncy.push(id(x, y + 1));
                }
                xadj.push(adjncy.len() as u32);
            }
        }
        let adjwgt = vec![1.0; adjncy.len()];
        CsrGraph {
            xadj,
            adjncy,
            adjwgt,
            vwgt: vec![1.0; n],
        }
    }

    #[test]
    fn coarse_graph_shrinks() {
        let g = grid_graph(10, 10);
        let mut rng = Pcg32::new(5);
        let (c, map) = heavy_edge_matching(&g, &mut rng);
        assert!(c.n() <= (g.n() + 1) / 2 + 10);
        assert!(c.n() >= g.n() / 2); // perfect matching halves exactly
        assert_eq!(map.len(), g.n());
        assert!(map.iter().all(|&m| (m as usize) < c.n()));
    }

    #[test]
    fn vertex_weight_conserved() {
        let g = grid_graph(8, 8);
        let mut rng = Pcg32::new(7);
        let (c, _) = heavy_edge_matching(&g, &mut rng);
        assert!((c.total_vwgt() - g.total_vwgt()).abs() < 1e-9);
    }

    #[test]
    fn edge_weight_conserved_modulo_internal() {
        // total edge weight of coarse graph = fine total minus matched
        // (internal) edges
        let g = grid_graph(6, 6);
        let fine_total: f64 = g.adjwgt.iter().sum();
        let mut rng = Pcg32::new(11);
        let (c, map) = heavy_edge_matching(&g, &mut rng);
        let coarse_total: f64 = c.adjwgt.iter().sum();
        // internal edge weight (counted twice in CSR, like totals)
        let mut internal = 0.0;
        for v in 0..g.n() {
            for (u, w) in g.neighbors(v) {
                if map[v] == map[u as usize] {
                    internal += w;
                }
            }
        }
        assert!(
            (coarse_total - (fine_total - internal)).abs() < 1e-9,
            "coarse {coarse_total} fine {fine_total} internal {internal}"
        );
    }

    #[test]
    fn coarse_adjacency_symmetric() {
        let g = grid_graph(7, 5);
        let mut rng = Pcg32::new(13);
        let (c, _) = heavy_edge_matching(&g, &mut rng);
        for v in 0..c.n() {
            for (u, w) in c.neighbors(v) {
                let back: f64 = c
                    .neighbors(u as usize)
                    .filter(|&(x, _)| x as usize == v)
                    .map(|(_, w)| w)
                    .sum();
                assert!(
                    (back - w).abs() < 1e-9,
                    "asymmetric coarse edge {v}<->{u}: {w} vs {back}"
                );
            }
        }
    }
}
