//! Initial bisection of the coarsest graph: greedy graph growing
//! (GGGP): BFS from a pseudo-peripheral seed, absorbing the frontier
//! vertex with the best cut gain until the grown region reaches the
//! target weight. Several seeds are tried; the best cut wins.

use super::CsrGraph;
use crate::util::rng::Pcg32;

/// Pseudo-peripheral vertex: start anywhere, BFS to the farthest
/// vertex, repeat once.
fn pseudo_peripheral(g: &CsrGraph, start: usize) -> usize {
    let mut far = start;
    for _ in 0..2 {
        let mut dist = vec![u32::MAX; g.n()];
        let mut q = std::collections::VecDeque::new();
        dist[far] = 0;
        q.push_back(far);
        let mut last = far;
        while let Some(v) = q.pop_front() {
            last = v;
            for (u, _) in g.neighbors(v) {
                if dist[u as usize] == u32::MAX {
                    dist[u as usize] = dist[v] + 1;
                    q.push_back(u as usize);
                }
            }
        }
        far = last;
    }
    far
}

/// Grow side 0 from a seed until it carries `frac` of the weight.
/// Returns side assignment; tries a few seeds, keeps the best cut.
pub fn grow_bisection(g: &CsrGraph, frac: f64, rng: &mut Pcg32) -> Vec<u8> {
    let n = g.n();
    if n == 0 {
        return Vec::new();
    }
    let total = g.total_vwgt();
    let target = total * frac;

    let mut best_side: Option<(f64, Vec<u8>)> = None;
    let tries = 4.min(n);
    for t in 0..tries {
        let seed = if t == 0 {
            pseudo_peripheral(g, rng.gen_range(n))
        } else {
            rng.gen_range(n)
        };
        let side = grow_from(g, seed, target);
        let cut = g.cut2(&side);
        if best_side
            .as_ref()
            .map(|(bc, _)| cut < *bc)
            .unwrap_or(true)
        {
            best_side = Some((cut, side));
        }
    }
    best_side.unwrap().1
}

fn grow_from(g: &CsrGraph, seed: usize, target: f64) -> Vec<u8> {
    let n = g.n();
    // side 1 = not grown yet
    let mut side = vec![1u8; n];
    // gain of moving v into the region: edges to region minus edges out
    let mut gain = vec![0.0f64; n];
    let mut in_frontier = vec![false; n];
    let mut frontier: Vec<u32> = Vec::new();

    let mut grown_w = 0.0;
    let mut v = seed;
    loop {
        side[v] = 0;
        grown_w += g.vwgt[v];
        if grown_w >= target {
            break;
        }
        for (u, w) in g.neighbors(v) {
            let u = u as usize;
            if side[u] == 1 {
                gain[u] += 2.0 * w;
                if !in_frontier[u] {
                    in_frontier[u] = true;
                    frontier.push(u as u32);
                }
            }
        }
        // pick the best frontier vertex (linear scan; coarsest graphs
        // are small, so this simple O(F) step is fine)
        let mut best: Option<(usize, f64)> = None;
        let mut best_pos = 0;
        for (pos, &u) in frontier.iter().enumerate() {
            let u = u as usize;
            if side[u] == 0 {
                continue;
            }
            if best.map(|(_, bg)| gain[u] > bg).unwrap_or(true) {
                best = Some((u, gain[u]));
                best_pos = pos;
            }
        }
        match best {
            Some((u, _)) => {
                frontier.swap_remove(best_pos);
                v = u;
            }
            None => {
                // disconnected: jump to any ungrown vertex
                match (0..n).find(|&u| side[u] == 1) {
                    Some(u) => v = u,
                    None => break,
                }
            }
        }
    }
    side
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ladder(n: usize) -> CsrGraph {
        // 2 x n grid
        let id = |r: usize, c: usize| (r * n + c) as u32;
        let mut xadj = vec![0u32];
        let mut adjncy = Vec::new();
        for r in 0..2 {
            for c in 0..n {
                if c > 0 {
                    adjncy.push(id(r, c - 1));
                }
                if c + 1 < n {
                    adjncy.push(id(r, c + 1));
                }
                adjncy.push(id(1 - r, c));
                xadj.push(adjncy.len() as u32);
            }
        }
        let adjwgt = vec![1.0; adjncy.len()];
        CsrGraph {
            xadj,
            adjncy,
            adjwgt,
            vwgt: vec![1.0; 2 * n],
        }
    }

    #[test]
    fn ladder_bisection_near_optimal() {
        let g = ladder(20);
        let mut rng = Pcg32::new(2);
        let side = grow_bisection(&g, 0.5, &mut rng);
        let cut = g.cut2(&side);
        // optimal cut of a 2x20 ladder at the waist = 2
        assert!(cut <= 6.0, "cut {cut}");
        let w0: f64 = (0..g.n()).filter(|&v| side[v] == 0).map(|v| g.vwgt[v]).sum();
        assert!((w0 - 20.0).abs() <= 2.0, "w0 {w0}");
    }

    #[test]
    fn respects_fraction() {
        let g = ladder(30);
        let mut rng = Pcg32::new(4);
        let side = grow_bisection(&g, 0.25, &mut rng);
        let w0: f64 = (0..g.n()).filter(|&v| side[v] == 0).map(|v| g.vwgt[v]).sum();
        assert!((w0 - 15.0).abs() <= 2.0, "w0 {w0} target 15");
    }

    #[test]
    fn pseudo_peripheral_is_far() {
        let g = ladder(25);
        let v = pseudo_peripheral(&g, 12);
        // a peripheral vertex of the ladder is at one of the 4 corners
        let c = (v % 25) as i64;
        assert!(c == 0 || c == 24, "peripheral col {c}");
    }

    #[test]
    fn single_vertex_graph() {
        let g = CsrGraph {
            xadj: vec![0, 0],
            adjncy: vec![],
            adjwgt: vec![],
            vwgt: vec![1.0],
        };
        let mut rng = Pcg32::new(9);
        let side = grow_bisection(&g, 0.5, &mut rng);
        assert_eq!(side.len(), 1);
    }
}
