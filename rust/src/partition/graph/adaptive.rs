//! Multilevel k-way **adaptive** repartitioning -- the
//! Schloegel/Karypis `AdaptiveRepart` of the ParMETIS family
//! (`ParMETIS_V3_AdaptiveRepart`), composed from the same
//! coarsen/seed/refine phases as the scratch multilevel method but
//! anchored to the *current* distribution:
//!
//! 1. **Owner-respecting coarsening** -- heavy-edge matching restricted
//!    to same-owner pairs ([`owner_constrained_matching`]), so every
//!    coarse vertex has a single well-defined owner and the current
//!    partition projects exactly onto every level of the hierarchy. In
//!    the SPMD formulation this makes the matching *communication-free*:
//!    a rank only ever matches vertices it already owns.
//! 2. **Owner-seeded initial partition** -- the coarsest partition *is*
//!    the projected current ownership (no graph growing), so the method
//!    starts from zero migration and pays only for the moves refinement
//!    chooses to make.
//! 3. **k-way boundary refinement at every level** with the combined
//!    gain `itr * cut_gain + migration_gain` ([`kway_refine`]).
//!
//! ## The `itr` tradeoff
//!
//! ParMETIS exposes the cut-vs-migration tradeoff as `itr`
//! (`ipc2redist`): the objective is `itr * edge_cut + TotalV`, i.e.
//! one unit of edge cut is worth `itr` units of migrated weight. Move
//! ordering under that objective is identical to the
//! `cut_gain + migration_gain / itr` form (positive scaling preserves
//! the sign and order of every gain), so the single parameter
//! continuously interpolates between the two repartitioning extremes:
//! `itr -> infinity` ignores migration and tracks the scratch
//! multilevel cut, `itr -> 0` ignores the cut and degenerates toward
//! diffusion-like minimal migration. The default (1000, ParMETIS's
//! own) sits at the cut-focused end: migration stays small anyway
//! because the owner-seeded start only migrates what refinement moves.
//!
//! ## SPMD cost shape
//!
//! Coarsening is communication-free (same-owner matching), the seed
//! partition needs no gather/broadcast (every rank knows its own
//! ownership), so the collective log is one `Allreduce` of the rank
//! loads plus one small `Allreduce` per refinement pass per level (the
//! part-load sync k-way refinement needs) and one boundary-sized
//! `AllToAllV` per level (exchanging boundary-vertex moves). Compare
//! the scratch multilevel log: per-level matching `AllToAllV`s over
//! the whole halo plus the coarsest-partition gather/broadcast.

use super::super::{
    CommOp, MethodTraits, ParamSpec, PartitionInput, PartitionResult, Partitioner,
};
use super::CsrGraph;
use crate::format_err;
use crate::mesh::topology::LeafTopology;
use crate::util::error::Result;
use crate::util::rng::Pcg32;

/// One round of heavy-edge matching restricted to same-owner pairs,
/// followed by contraction. Returns the coarse graph, the fine->coarse
/// vertex map, and the (well-defined) owner of every coarse vertex.
///
/// Matching never pairs vertices with different owners, so
/// `owners[v] == coarse_owners[map[v]]` for every fine vertex `v`: the
/// current partition projects exactly through every coarsening level.
pub fn owner_constrained_matching(
    g: &CsrGraph,
    owners: &[u16],
    rng: &mut Pcg32,
) -> (CsrGraph, Vec<u32>, Vec<u16>) {
    let n = g.n();
    debug_assert_eq!(owners.len(), n);
    const UNMATCHED: u32 = u32::MAX;
    let mut mate = vec![UNMATCHED; n];

    // random visit order (standard HEM: breaks grid artifacts)
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);

    for &v in &order {
        let v = v as usize;
        if mate[v] != UNMATCHED {
            continue;
        }
        // heaviest incident edge to an unmatched *same-owner* neighbour
        let mut best: Option<(u32, f64)> = None;
        for (u, w) in g.neighbors(v) {
            if u as usize == v
                || mate[u as usize] != UNMATCHED
                || owners[u as usize] != owners[v]
            {
                continue;
            }
            if best.map(|(_, bw)| w > bw).unwrap_or(true) {
                best = Some((u, w));
            }
        }
        match best {
            Some((u, _)) => {
                mate[v] = u;
                mate[u as usize] = v as u32;
            }
            None => {
                mate[v] = v as u32; // matched with itself
            }
        }
    }

    // assign coarse ids
    let mut map = vec![u32::MAX; n];
    let mut nc = 0u32;
    for v in 0..n {
        if map[v] != u32::MAX {
            continue;
        }
        let m = mate[v] as usize;
        map[v] = nc;
        map[m] = nc; // m == v for self-matched
        nc += 1;
    }

    // contract: sum vertex weights, carry owners, merge parallel edges
    let ncz = nc as usize;
    let mut vwgt = vec![0.0f64; ncz];
    let mut coarse_owners = vec![0u16; ncz];
    for v in 0..n {
        vwgt[map[v] as usize] += g.vwgt[v];
        coarse_owners[map[v] as usize] = owners[v]; // mates agree
    }
    let mut xadj = Vec::with_capacity(ncz + 1);
    let mut adjncy: Vec<u32> = Vec::with_capacity(g.adjncy.len() / 2);
    let mut adjwgt: Vec<f64> = Vec::with_capacity(g.adjncy.len() / 2);
    xadj.push(0u32);

    // coarse vertex -> its (up to two) fine vertices
    let mut members: Vec<(u32, u32)> = vec![(u32::MAX, u32::MAX); ncz];
    for v in 0..n {
        let c = map[v] as usize;
        if members[c].0 == u32::MAX {
            members[c].0 = v as u32;
        } else if members[c].0 != v as u32 {
            members[c].1 = v as u32;
        }
    }

    let mut pos_of: Vec<u32> = vec![u32::MAX; ncz]; // coarse nbr -> slot in current row
    let mut touched: Vec<u32> = Vec::with_capacity(32);
    for c in 0..ncz {
        let (a, b) = members[c];
        for fv in [a, b] {
            if fv == u32::MAX {
                continue;
            }
            for (u, w) in g.neighbors(fv as usize) {
                let cu = map[u as usize];
                if cu as usize == c {
                    continue; // internal edge vanishes
                }
                let slot = pos_of[cu as usize];
                if slot == u32::MAX {
                    pos_of[cu as usize] = adjncy.len() as u32;
                    touched.push(cu);
                    adjncy.push(cu);
                    adjwgt.push(w);
                } else {
                    adjwgt[slot as usize] += w;
                }
            }
        }
        for &t in &touched {
            pos_of[t as usize] = u32::MAX;
        }
        touched.clear();
        xadj.push(adjncy.len() as u32);
    }

    (
        CsrGraph {
            xadj,
            adjncy,
            adjwgt,
            vwgt,
        },
        map,
        coarse_owners,
    )
}

/// k-way boundary refinement with the combined adaptive gain.
///
/// Moving `v` from part `a` to part `b` is scored
/// `itr * cut_gain + migration_gain` where `cut_gain` is the k-way FM
/// gain (edge weight to `b` minus edge weight internal to `a`) and
/// `migration_gain` is `+vwgt` when the move brings `v` home to
/// `owners[v]`, `-vwgt` when it evicts `v` from home, `0` between two
/// foreign parts. Each pass walks candidates (boundary vertices plus
/// everything in an overweight part) in descending-gain order with a
/// vertex-id tiebreak, recomputes the gain at move time, and accepts a
/// move when the target fits under `mean * (1 + epsilon)` and the gain
/// is positive (or zero while balance strictly improves) -- or, forced,
/// when the source part is overweight and the move strictly shrinks
/// the source/target pairwise maximum. Returns the number of moves.
pub fn kway_refine(
    g: &CsrGraph,
    parts: &mut [u16],
    owners: &[u16],
    nparts: usize,
    itr: f64,
    epsilon: f64,
    passes: usize,
) -> usize {
    let n = g.n();
    if n == 0 || nparts <= 1 {
        return 0;
    }
    let total = g.total_vwgt();
    if total <= 0.0 {
        return 0;
    }
    let mean = total / nparts as f64;
    let max_load = mean * (1.0 + epsilon) + 1e-12;
    let tol = 1e-12 * (1.0 + itr) * mean.max(1.0);

    let mut loads = vec![0.0f64; nparts];
    for v in 0..n {
        loads[parts[v] as usize] += g.vwgt[v];
    }

    // per-part external connectivity of one vertex (scatter/reset)
    let mut conn = vec![0.0f64; nparts];
    let mut touched: Vec<u16> = Vec::with_capacity(16);

    let least_loaded = |loads: &[f64]| -> u16 {
        let mut best = 0usize;
        for p in 1..loads.len() {
            if loads[p] < loads[best] {
                best = p;
            }
        }
        best as u16
    };

    // best (target, gain) for v given current parts/loads; `spread`
    // adds the globally least-loaded part to the candidate targets so
    // overweight interiors can drain even without a boundary to it
    let best_move = |v: usize,
                     parts: &[u16],
                     loads: &[f64],
                     conn: &mut [f64],
                     touched: &mut Vec<u16>,
                     spread: bool|
     -> Option<(u16, f64)> {
        let a = parts[v];
        let w = g.vwgt[v];
        let own = owners[v];
        let mut internal = 0.0f64;
        for (u, ew) in g.neighbors(v) {
            let pu = parts[u as usize];
            if pu == a {
                internal += ew;
            } else {
                if conn[pu as usize] == 0.0 && !touched.contains(&pu) {
                    touched.push(pu);
                }
                conn[pu as usize] += ew;
            }
        }
        if spread {
            let ll = least_loaded(loads);
            if ll != a && !touched.contains(&ll) {
                touched.push(ll);
            }
        }
        if own != a && !touched.contains(&own) {
            touched.push(own);
        }
        let mut best: Option<(u16, f64)> = None;
        for &b in touched.iter() {
            let cut_gain = conn[b as usize] - internal;
            let migration_gain = if b == own && a != own {
                w
            } else if a == own && b != own {
                -w
            } else {
                0.0
            };
            let gain = itr * cut_gain + migration_gain;
            let better = match best {
                None => true,
                // deterministic tiebreak: lowest part id wins ties
                Some((bb, bg)) => gain > bg + 1e-15 || (gain >= bg - 1e-15 && b < bb),
            };
            if better {
                best = Some((b, gain));
            }
        }
        for &t in touched.iter() {
            conn[t as usize] = 0.0;
        }
        touched.clear();
        best
    };

    let mut moves = 0usize;
    for _pass in 0..passes {
        // candidates: boundary vertices, plus everything in an
        // overweight part (so imbalance can drain through interiors)
        let mut cand: Vec<(f64, u32)> = Vec::new();
        for v in 0..n {
            let a = parts[v] as usize;
            let boundary = g.neighbors(v).any(|(u, _)| parts[u as usize] != parts[v]);
            let over = loads[a] > max_load;
            if !(boundary || over) {
                continue;
            }
            if let Some((_, gain)) = best_move(v, parts, &loads, &mut conn, &mut touched, over)
            {
                cand.push((gain, v as u32));
            }
        }
        // descending gain, vertex id as the deterministic tiebreak
        cand.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });

        let mut moved_any = false;
        for &(_, v) in &cand {
            let v = v as usize;
            let a = parts[v];
            let w = g.vwgt[v];
            let over = loads[a as usize] > max_load;
            // recompute at move time: earlier moves changed the gains
            let (b, gain) =
                match best_move(v, parts, &loads, &mut conn, &mut touched, over) {
                    Some(m) => m,
                    None => continue,
                };
            let fits = loads[b as usize] + w <= max_load;
            let shrinks_pair_max = loads[b as usize] + w < loads[a as usize] - 1e-12;
            let improves =
                gain > tol || (gain >= -tol && shrinks_pair_max);
            let forced = over && shrinks_pair_max;
            if (fits && improves) || forced {
                parts[v] = b;
                loads[a as usize] -= w;
                loads[b as usize] += w;
                moves += 1;
                moved_any = true;
            }
        }
        if !moved_any {
            break;
        }
    }
    moves
}

/// The multilevel k-way adaptive repartitioner. Registered as method
/// `AdaptiveRepart` and driven directly or by the `Adaptive`/`Auto`
/// strategies of [`crate::dlb::RebalancePipeline`].
pub struct AdaptiveRepart {
    /// Cut-vs-migration tradeoff (ParMETIS `ipc2redist`): the move
    /// objective is `itr * cut_gain + migration_gain`, so large values
    /// chase the scratch cut and small values approach the diffusive
    /// migration minimum.
    pub itr: f64,
    /// Stop coarsening when fewer vertices than this (clamped up to
    /// `4 * nparts` so the coarsest level still resolves every part).
    pub coarsen_to: usize,
    /// Refinement passes per uncoarsening level (the coarsest level
    /// runs extra passes, like the scratch multilevel method).
    pub fm_passes: usize,
    /// Per-part load tolerance: refinement balances to
    /// `mean * (1 + epsilon)`.
    pub epsilon: f64,
    pub seed: u64,
}

impl AdaptiveRepart {
    /// ParMETIS-like defaults (`itr = 1000`: cut-focused, the library's
    /// own default for `ipc2redist`).
    pub fn parmetis_like() -> Self {
        Self {
            itr: 1000.0,
            coarsen_to: 64,
            fm_passes: 6,
            epsilon: 0.03,
            seed: 20170712,
        }
    }

    /// Builder: set the cut-vs-migration tradeoff.
    pub fn with_itr(mut self, itr: f64) -> Self {
        self.itr = itr;
        self
    }

    /// Partition a raw dual graph given current owners (the mesh-free
    /// core; `partition` wraps this). Returns the parts and the number
    /// of coarsening levels built (for the collective log).
    pub fn repartition_graph(
        &self,
        g: &CsrGraph,
        owners: &[u16],
        nparts: usize,
        rng: &mut Pcg32,
    ) -> (Vec<u16>, usize) {
        let n = g.n();
        let clamp = |o: u16| -> u16 { (o as usize).min(nparts - 1) as u16 };
        if nparts <= 1 || n == 0 {
            return (vec![0u16; n], 0);
        }
        let stop = self.coarsen_to.max(4 * nparts);

        // build the hierarchy: (graph, owners) per level + maps down
        let mut graphs: Vec<(CsrGraph, Vec<u16>)> =
            vec![(g.clone(), owners.iter().map(|&o| clamp(o)).collect())];
        let mut maps: Vec<Vec<u32>> = Vec::new();
        while graphs.last().unwrap().0.n() > stop {
            let (cur, own) = graphs.last().unwrap();
            let (coarse, map, cowners) = owner_constrained_matching(cur, own, rng);
            // coarsening stalled (no same-owner matchable edges left)
            if coarse.n() as f64 > 0.95 * cur.n() as f64 {
                break;
            }
            maps.push(map);
            graphs.push((coarse, cowners));
        }
        let levels = graphs.len();

        // owner-seeded coarsest partition: the projected current
        // ownership IS the initial partition (no graph growing)
        let (coarsest, cowners) = graphs.last().unwrap();
        let mut parts: Vec<u16> = cowners.clone();
        kway_refine(
            coarsest,
            &mut parts,
            cowners,
            nparts,
            self.itr,
            self.epsilon,
            // generous budget at the coarsest level: this is where the
            // owner-seeded partition gets balanced (cheap -- the graph
            // is small), and the pass loop exits early on a fixpoint
            (self.fm_passes * 4).max(32),
        );

        // uncoarsen: project up, refine against the *fine* owners so
        // the migration term always prices real element moves
        for lvl in (0..levels - 1).rev() {
            let map = &maps[lvl];
            let (fine, fowners) = &graphs[lvl];
            let mut fine_parts = vec![0u16; fine.n()];
            for v in 0..fine.n() {
                fine_parts[v] = parts[map[v] as usize];
            }
            parts = fine_parts;
            kway_refine(
                fine,
                &mut parts,
                fowners,
                nparts,
                self.itr,
                self.epsilon,
                self.fm_passes,
            );
        }
        (parts, levels)
    }
}

impl Partitioner for AdaptiveRepart {
    fn name(&self) -> &'static str {
        "AdaptiveRepart"
    }

    fn traits(&self) -> MethodTraits {
        MethodTraits {
            incremental: true,
            uses_current_owners: true,
            tunables: &[
                ParamSpec {
                    key: "itr",
                    description: "cut-vs-migration tradeoff (ParMETIS ipc2redist)",
                    min: 0.0,
                    max: 1e9,
                    default: 1000.0,
                },
                ParamSpec {
                    key: "fm_passes",
                    description: "refinement passes per uncoarsening level",
                    min: 1.0,
                    max: 64.0,
                    default: 6.0,
                },
                ParamSpec {
                    key: "coarsen_to",
                    description: "stop coarsening below this many vertices",
                    min: 8.0,
                    max: 1e6,
                    default: 64.0,
                },
                ParamSpec {
                    key: "epsilon",
                    description: "per-part load tolerance of the refinement",
                    min: 0.001,
                    max: 0.5,
                    default: 0.03,
                },
            ],
        }
    }

    fn set_tunable(&mut self, key: &str, value: f64) -> Result<()> {
        match key {
            "itr" => self.itr = value,
            "fm_passes" => self.fm_passes = value.round() as usize,
            "coarsen_to" => self.coarsen_to = value.round() as usize,
            "epsilon" => self.epsilon = value,
            other => {
                return Err(format_err!(
                    "method AdaptiveRepart has no tunable {other:?}"
                ))
            }
        }
        Ok(())
    }

    fn partition(&self, input: &PartitionInput) -> PartitionResult {
        let p = input.nparts;
        let topo = LeafTopology::build_for(input.mesh, input.leaves.to_vec());
        let (xadj, adjncy) = topo.dual_graph_csr();
        let adjwgt = vec![1.0; adjncy.len()];
        let g = CsrGraph {
            xadj,
            adjncy,
            adjwgt,
            vwgt: input.weights.to_vec(),
        };
        let mut rng = Pcg32::new(self.seed ^ (g.n() as u64).rotate_left(17));
        let (parts, levels) = self.repartition_graph(&g, input.owners, p, &mut rng);

        // SPMD collective log. Coarsening is communication-free (the
        // matching never crosses an owner boundary, so every
        // contraction is rank-local) and the seed partition needs no
        // gather/bcast; what remains is the initial load Allreduce,
        // one small load-sync Allreduce per refinement pass per level,
        // and one boundary-move exchange per level.
        let mut comm = vec![CommOp::Allreduce { bytes: p * 8 }];
        let boundary_faces = {
            let mut cut = 0usize;
            for v in 0..g.n() {
                for (u, _) in g.neighbors(v) {
                    if (u as usize) > v && parts[v] != parts[u as usize] {
                        cut += 1;
                    }
                }
            }
            cut
        };
        for _ in 0..levels.max(1) {
            for _ in 0..self.fm_passes.max(1) {
                comm.push(CommOp::Allreduce { bytes: p * 8 });
            }
            comm.push(CommOp::AllToAllV {
                total_bytes: boundary_faces * 8,
                max_msg: boundary_faces * 8 / p.max(1),
            });
        }
        PartitionResult { parts, comm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Distribution;
    use crate::partition::metrics::migration_volume;
    use crate::partition::testutil::setup_mesh;
    use crate::util::stats::imbalance;

    fn grid_graph(nx: usize, ny: usize) -> CsrGraph {
        let id = |x: usize, y: usize| (y * nx + x) as u32;
        let mut xadj = vec![0u32];
        let mut adjncy = Vec::new();
        for y in 0..ny {
            for x in 0..nx {
                if x > 0 {
                    adjncy.push(id(x - 1, y));
                }
                if x + 1 < nx {
                    adjncy.push(id(x + 1, y));
                }
                if y > 0 {
                    adjncy.push(id(x, y - 1));
                }
                if y + 1 < ny {
                    adjncy.push(id(x, y + 1));
                }
                xadj.push(adjncy.len() as u32);
            }
        }
        let adjwgt = vec![1.0; adjncy.len()];
        CsrGraph {
            xadj,
            adjncy,
            adjwgt,
            vwgt: vec![1.0; nx * ny],
        }
    }

    #[test]
    fn matching_never_crosses_owner_boundaries() {
        let g = grid_graph(10, 10);
        // vertical halves owned by ranks 0 / 1
        let owners: Vec<u16> = (0..100).map(|v| if v % 10 < 5 { 0 } else { 1 }).collect();
        let mut rng = Pcg32::new(5);
        let (coarse, map, cowners) = owner_constrained_matching(&g, &owners, &mut rng);
        assert_eq!(map.len(), 100);
        // the fine partition projects exactly: every fine vertex's
        // owner equals its coarse vertex's owner
        for v in 0..100 {
            assert_eq!(owners[v], cowners[map[v] as usize], "vertex {v}");
        }
        assert!((coarse.total_vwgt() - g.total_vwgt()).abs() < 1e-9);
        assert!(coarse.n() >= 50, "matching halves at best");
        assert!(coarse.n() < 100, "no edge matched at all");
    }

    #[test]
    fn refine_balances_owner_seeded_partition() {
        let g = grid_graph(12, 12);
        // rank 0 owns 3/4 of the grid: heavy imbalance
        let owners: Vec<u16> = (0..144).map(|v| if v % 12 < 9 { 0 } else { 1 }).collect();
        let mut parts = owners.clone();
        kway_refine(&g, &mut parts, &owners, 2, 1.0, 0.05, 40);
        let mut loads = [0.0f64; 2];
        for &p in &parts {
            loads[p as usize] += 1.0;
        }
        let lam = imbalance(&loads);
        assert!(lam <= 1.06, "lambda {lam} after refinement");
    }

    #[test]
    fn itr_zero_moves_least_itr_large_cuts_least() {
        let g = grid_graph(16, 16);
        // 3 uneven vertical strips over 4 parts (part 3 empty-ish)
        let owners: Vec<u16> =
            (0..256).map(|v| ((v % 16) / 6).min(3) as u16).collect();
        let unit = vec![1.0f64; 256];
        let run = |itr: f64| {
            let mut parts = owners.clone();
            kway_refine(&g, &mut parts, &owners, 4, itr, 0.05, 40);
            let mv = migration_volume(&owners, &parts, &unit, 4);
            let mut cut = 0.0;
            for v in 0..256 {
                for (u, w) in g.neighbors(v) {
                    if (u as usize) > v && parts[v] != parts[u as usize] {
                        cut += w;
                    }
                }
            }
            (mv.total_v, cut)
        };
        let (v_low, cut_low) = run(0.0);
        let (v_high, cut_high) = run(1e6);
        assert!(
            v_low <= v_high + 1e-9,
            "itr=0 migrated more ({v_low}) than itr=1e6 ({v_high})"
        );
        assert!(
            cut_high <= cut_low + 1e-9,
            "itr=1e6 cut {cut_high} worse than cut-blind itr=0 cut {cut_low}"
        );
    }

    #[test]
    fn mesh_partition_balances_and_is_deterministic() {
        let mut mesh = setup_mesh(2);
        let leaves = mesh.leaves_unordered();
        Distribution::new(4).assign_blocks(&mut mesh, &leaves);
        // skew: refine rank 0's block
        let marked: Vec<_> = mesh
            .leaves_unordered()
            .into_iter()
            .filter(|&id| mesh.elem(id).owner == 0)
            .collect();
        mesh.refine(&marked);
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0f64; leaves.len()];
        let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 4);

        let a = AdaptiveRepart::parmetis_like();
        let r1 = a.partition(&input);
        let r2 = a.partition(&input);
        assert_eq!(r1.parts, r2.parts, "fixed seed must be deterministic");

        let mut loads = vec![0.0f64; 4];
        for (i, &p) in r1.parts.iter().enumerate() {
            loads[p as usize] += weights[i];
        }
        let lam = imbalance(&loads);
        assert!(lam <= 1.0 + a.epsilon + 0.02, "lambda {lam}");
        // owner-seeded: migration well below a full relabel
        let mv = migration_volume(&owners, &r1.parts, &weights, 4);
        let total: f64 = weights.iter().sum();
        assert!(
            mv.total_v < 0.8 * total,
            "adaptive moved {} of {total}",
            mv.total_v
        );
        // comm log: Allreduces + per-level AllToAllV, no Gather/Bcast
        assert!(r1
            .comm
            .iter()
            .all(|op| matches!(op, CommOp::Allreduce { .. } | CommOp::AllToAllV { .. })));
    }

    #[test]
    fn degenerate_inputs_do_not_panic() {
        let mut mesh = crate::mesh::generator::cube_mesh(1);
        let leaves = mesh.leaves_unordered();
        Distribution::new(2).assign_blocks(&mut mesh, &leaves);
        let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let a = AdaptiveRepart::parmetis_like();
        let w = vec![1.0f64; leaves.len()];
        // single part
        let input = PartitionInput::from_mesh(&mesh, &leaves, &w, &owners, 1);
        let r = a.partition(&input);
        assert!(r.parts.iter().all(|&x| x == 0));
        // more parts than elements: still in range
        let input = PartitionInput::from_mesh(&mesh, &leaves, &w, &owners, 10);
        let r = a.partition(&input);
        assert!(r.parts.iter().all(|&x| (x as usize) < 10));
        // zero weights
        let zero = vec![0.0f64; leaves.len()];
        let input = PartitionInput::from_mesh(&mesh, &leaves, &zero, &owners, 3);
        let r = a.partition(&input);
        assert_eq!(r.parts.len(), leaves.len());
    }
}
