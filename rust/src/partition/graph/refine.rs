//! Uncoarsening refinement: boundary Fiduccia-Mattheyses passes.
//!
//! Each pass walks the current boundary vertices in descending gain
//! order and greedily moves a vertex to the other side when the move
//! (a) improves the cut, or (b) keeps the cut while improving balance,
//! subject to both sides staying within (1 + eps) of their targets.
//! Passes stop when a pass makes no move (local minimum).

use super::CsrGraph;

/// Gain of moving `v` to the other side: external - internal edge weight.
fn gain_of(g: &CsrGraph, side: &[u8], v: usize) -> f64 {
    let mut ext = 0.0;
    let mut int = 0.0;
    for (u, w) in g.neighbors(v) {
        if side[u as usize] == side[v] {
            int += w;
        } else {
            ext += w;
        }
    }
    ext - int
}

/// Refine `side` in place toward weight split (frac, 1-frac).
pub fn fm_refine(g: &CsrGraph, side: &mut [u8], frac: f64, epsilon: f64, passes: usize) {
    let n = g.n();
    if n == 0 {
        return;
    }
    let total = g.total_vwgt();
    let target0 = total * frac;
    let target1 = total - target0;
    let max0 = target0 * (1.0 + epsilon) + 1e-12;
    let max1 = target1 * (1.0 + epsilon) + 1e-12;

    let mut w0: f64 = (0..n).filter(|&v| side[v] == 0).map(|v| g.vwgt[v]).sum();

    for _pass in 0..passes {
        // collect boundary vertices with their gains
        let mut cand: Vec<(f64, u32)> = Vec::new();
        for v in 0..n {
            let boundary = g.neighbors(v).any(|(u, _)| side[u as usize] != side[v]);
            // also allow moves that fix imbalance even off-boundary
            let over = if side[v] == 0 { w0 > max0 } else { total - w0 > max1 };
            if boundary || over {
                cand.push((gain_of(g, side, v), v as u32));
            }
        }
        cand.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

        let mut moved_any = false;
        for &(_, v) in &cand {
            let v = v as usize;
            let gain = gain_of(g, side, v); // recompute: earlier moves changed it
            let (new_w0, fits) = if side[v] == 0 {
                let nw = w0 - g.vwgt[v];
                (nw, total - nw <= max1)
            } else {
                let nw = w0 + g.vwgt[v];
                (nw, nw <= max0)
            };
            if !fits {
                continue;
            }
            let balance_now = (w0 - target0).abs();
            let balance_after = (new_w0 - target0).abs();
            let improves = gain > 1e-12 || (gain >= -1e-12 && balance_after < balance_now - 1e-12);
            // forced move if current side is overweight
            let forced = if side[v] == 0 { w0 > max0 } else { total - w0 > max1 };
            if improves || (forced && balance_after < balance_now) {
                side[v] ^= 1;
                w0 = new_w0;
                moved_any = true;
            }
        }
        if !moved_any {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn grid_graph(nx: usize, ny: usize) -> CsrGraph {
        let id = |x: usize, y: usize| (y * nx + x) as u32;
        let mut xadj = vec![0u32];
        let mut adjncy = Vec::new();
        for y in 0..ny {
            for x in 0..nx {
                if x > 0 {
                    adjncy.push(id(x - 1, y));
                }
                if x + 1 < nx {
                    adjncy.push(id(x + 1, y));
                }
                if y > 0 {
                    adjncy.push(id(x, y - 1));
                }
                if y + 1 < ny {
                    adjncy.push(id(x, y + 1));
                }
                xadj.push(adjncy.len() as u32);
            }
        }
        let adjwgt = vec![1.0; adjncy.len()];
        CsrGraph {
            xadj,
            adjncy,
            adjwgt,
            vwgt: vec![1.0; nx * ny],
        }
    }

    #[test]
    fn improves_random_partition() {
        let g = grid_graph(12, 12);
        let mut rng = Pcg32::new(17);
        let mut side: Vec<u8> = (0..g.n()).map(|_| rng.gen_range(2) as u8).collect();
        let before = g.cut2(&side);
        fm_refine(&g, &mut side, 0.5, 0.05, 12);
        let after = g.cut2(&side);
        assert!(
            after < 0.6 * before,
            "cut {before} -> {after}: refinement too weak"
        );
        // balance respected
        let w0: f64 = (0..g.n()).filter(|&v| side[v] == 0).map(|v| g.vwgt[v]).sum();
        assert!((w0 - 72.0).abs() <= 72.0 * 0.05 + 1.0, "w0 {w0}");
    }

    #[test]
    fn preserves_good_partition() {
        // a clean half-half split of the grid: FM must not make it worse
        let g = grid_graph(10, 10);
        let mut side: Vec<u8> = (0..100).map(|v| if v % 10 < 5 { 0 } else { 1 }).collect();
        let before = g.cut2(&side);
        fm_refine(&g, &mut side, 0.5, 0.05, 6);
        let after = g.cut2(&side);
        assert!(after <= before, "cut {before} -> {after}");
    }

    #[test]
    fn fixes_imbalance() {
        let g = grid_graph(8, 8);
        // everything on side 0: heavily imbalanced
        let mut side = vec![0u8; 64];
        side[63] = 1;
        fm_refine(&g, &mut side, 0.5, 0.05, 40);
        let w0: f64 = (0..64).filter(|&v| side[v] == 0).map(|v| g.vwgt[v]).sum();
        assert!(
            (w0 - 32.0).abs() <= 32.0 * 0.2,
            "w0 {w0} still imbalanced"
        );
    }

    #[test]
    fn gain_computation() {
        let g = grid_graph(3, 1); // path 0-1-2
        let side = vec![0u8, 1, 1];
        // moving 0: ext edge to 1 (w 1) - internal none = +1
        assert_eq!(gain_of(&g, &side, 0), 1.0);
        // moving 1: ext edge to 0 - internal edge to 2 = 0
        assert_eq!(gain_of(&g, &side, 1), 0.0);
        // moving 2: ext none - internal to 1 = -1
        assert_eq!(gain_of(&g, &side, 2), -1.0);
    }
}
