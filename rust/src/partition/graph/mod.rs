//! Multilevel k-way graph partitioning over the mesh's dual graph --
//! the from-scratch ParMETIS stand-in (§3's "ParMETIS" column).
//!
//! Classic three-phase multilevel scheme (Karypis & Kumar; Hendrickson
//! & Leland):
//!   1. **Coarsen** by heavy-edge matching until the graph is small;
//!   2. **Initial partition** of the coarsest graph by greedy graph
//!      growing (BFS from a pseudo-peripheral seed to the target
//!      weight);
//!   3. **Uncoarsen**, projecting the partition up and running
//!      boundary Fiduccia-Mattheyses-style refinement at every level.
//!
//! k-way is obtained by recursive bisection (k splits into
//! ceil(k/2)/floor(k/2) with proportional weight targets), matching
//! the structure of serial METIS's pmetis. The method controls the
//! edge cut explicitly, so its partitions are the quality reference --
//! but it is the slowest method in the lineup, and the *from-scratch*
//! variant is not incremental: small mesh changes can produce very
//! different partitions (the partition-time oscillation the paper
//! observes in Fig 3.2/3.3). The [`adaptive`] module composes the same
//! coarsen/refine phases into `AdaptiveRepart`, the owner-seeded
//! multilevel repartitioner that *is* incremental.

pub mod adaptive;
mod bisect;
mod coarsen;
mod refine;

pub use adaptive::AdaptiveRepart;
pub(crate) use bisect::grow_bisection;
pub(crate) use coarsen::heavy_edge_matching;
pub(crate) use refine::fm_refine;

use super::{CommOp, MethodTraits, ParamSpec, PartitionInput, PartitionResult, Partitioner};
use crate::format_err;
use crate::util::error::Result;
use crate::mesh::topology::LeafTopology;
use crate::util::rng::Pcg32;

/// CSR graph with vertex and edge weights.
#[derive(Debug, Clone)]
pub struct CsrGraph {
    pub xadj: Vec<u32>,
    pub adjncy: Vec<u32>,
    pub adjwgt: Vec<f64>,
    pub vwgt: Vec<f64>,
}

impl CsrGraph {
    pub fn n(&self) -> usize {
        self.vwgt.len()
    }

    pub fn degree(&self, v: usize) -> usize {
        (self.xadj[v + 1] - self.xadj[v]) as usize
    }

    pub fn neighbors(&self, v: usize) -> impl Iterator<Item = (u32, f64)> + '_ {
        let lo = self.xadj[v] as usize;
        let hi = self.xadj[v + 1] as usize;
        self.adjncy[lo..hi]
            .iter()
            .zip(&self.adjwgt[lo..hi])
            .map(|(&n, &w)| (n, w))
    }

    pub fn total_vwgt(&self) -> f64 {
        self.vwgt.iter().sum()
    }

    /// Edge cut of a two-way side assignment.
    pub fn cut2(&self, side: &[u8]) -> f64 {
        let mut cut = 0.0;
        for v in 0..self.n() {
            for (u, w) in self.neighbors(v) {
                if (u as usize) > v && side[v] != side[u as usize] {
                    cut += w;
                }
            }
        }
        cut
    }
}

pub struct MultilevelGraph {
    /// stop coarsening when fewer vertices than this
    pub coarsen_to: usize,
    /// FM passes per uncoarsening level
    pub fm_passes: usize,
    /// allowed imbalance per bisection (each side within (1+eps)*target)
    pub epsilon: f64,
    pub seed: u64,
}

impl MultilevelGraph {
    pub fn parmetis_like() -> Self {
        Self {
            coarsen_to: 64,
            fm_passes: 6,
            epsilon: 0.03,
            seed: 20170712,
        }
    }
}

/// Multilevel two-way partition of `g` into weight fractions
/// (`frac`, 1-frac). Returns side (0/1) per vertex.
pub fn multilevel_bisect(
    g: &CsrGraph,
    frac: f64,
    coarsen_to: usize,
    fm_passes: usize,
    epsilon: f64,
    rng: &mut Pcg32,
) -> Vec<u8> {
    if g.n() <= coarsen_to {
        let mut side = grow_bisection(g, frac, rng);
        fm_refine(g, &mut side, frac, epsilon, fm_passes * 2);
        return side;
    }
    let (coarse, map) = heavy_edge_matching(g, rng);
    // coarsening stalled (no matchable edges): go direct
    if coarse.n() as f64 > 0.95 * g.n() as f64 {
        let mut side = grow_bisection(g, frac, rng);
        fm_refine(g, &mut side, frac, epsilon, fm_passes * 2);
        return side;
    }
    let coarse_side = multilevel_bisect(&coarse, frac, coarsen_to, fm_passes, epsilon, rng);
    // project up
    let mut side = vec![0u8; g.n()];
    for v in 0..g.n() {
        side[v] = coarse_side[map[v] as usize];
    }
    fm_refine(g, &mut side, frac, epsilon, fm_passes);
    side
}

/// Recursive-bisection k-way partition. `parts[v]` in `0..nparts`.
pub fn recursive_kway(
    g: &CsrGraph,
    nparts: usize,
    cfg: &MultilevelGraph,
    rng: &mut Pcg32,
) -> Vec<u16> {
    let mut parts = vec![0u16; g.n()];
    let vertices: Vec<u32> = (0..g.n() as u32).collect();
    kway_recurse(g, &vertices, 0, nparts, cfg, rng, &mut parts);
    parts
}

fn kway_recurse(
    g: &CsrGraph,
    vertices: &[u32],
    part_lo: usize,
    nparts: usize,
    cfg: &MultilevelGraph,
    rng: &mut Pcg32,
    parts: &mut [u16],
) {
    if nparts <= 1 || vertices.is_empty() {
        for &v in vertices {
            parts[v as usize] = part_lo as u16;
        }
        return;
    }
    let p_left = nparts / 2;
    let frac = p_left as f64 / nparts as f64;

    // extract the subgraph induced by `vertices`
    let sub = induced_subgraph(g, vertices);
    let side = multilevel_bisect(
        &sub,
        frac,
        cfg.coarsen_to,
        cfg.fm_passes,
        cfg.epsilon,
        rng,
    );
    let mut left = Vec::with_capacity(vertices.len() / 2 + 1);
    let mut right = Vec::with_capacity(vertices.len() / 2 + 1);
    for (i, &v) in vertices.iter().enumerate() {
        if side[i] == 0 {
            left.push(v);
        } else {
            right.push(v);
        }
    }
    kway_recurse(g, &left, part_lo, p_left, cfg, rng, parts);
    kway_recurse(g, &right, part_lo + p_left, nparts - p_left, cfg, rng, parts);
}

/// Induced subgraph over `vertices` (edges among them only).
pub(crate) fn induced_subgraph(g: &CsrGraph, vertices: &[u32]) -> CsrGraph {
    let mut local = vec![u32::MAX; g.n()];
    for (i, &v) in vertices.iter().enumerate() {
        local[v as usize] = i as u32;
    }
    let mut xadj = Vec::with_capacity(vertices.len() + 1);
    let mut adjncy = Vec::new();
    let mut adjwgt = Vec::new();
    let mut vwgt = Vec::with_capacity(vertices.len());
    xadj.push(0u32);
    for &v in vertices {
        vwgt.push(g.vwgt[v as usize]);
        for (u, w) in g.neighbors(v as usize) {
            let lu = local[u as usize];
            if lu != u32::MAX {
                adjncy.push(lu);
                adjwgt.push(w);
            }
        }
        xadj.push(adjncy.len() as u32);
    }
    CsrGraph {
        xadj,
        adjncy,
        adjwgt,
        vwgt,
    }
}

impl Partitioner for MultilevelGraph {
    fn name(&self) -> &'static str {
        "ParMETIS"
    }

    fn traits(&self) -> MethodTraits {
        MethodTraits {
            incremental: false,
            uses_current_owners: false,
            tunables: &[
                ParamSpec {
                    key: "coarsen_to",
                    description: "stop coarsening below this many vertices",
                    min: 8.0,
                    max: 1e6,
                    default: 64.0,
                },
                ParamSpec {
                    key: "fm_passes",
                    description: "FM passes per uncoarsening level",
                    min: 1.0,
                    max: 64.0,
                    default: 6.0,
                },
                ParamSpec {
                    key: "epsilon",
                    description: "allowed imbalance per bisection",
                    min: 0.001,
                    max: 0.5,
                    default: 0.03,
                },
            ],
        }
    }

    fn set_tunable(&mut self, key: &str, value: f64) -> Result<()> {
        match key {
            "coarsen_to" => self.coarsen_to = value.round() as usize,
            "fm_passes" => self.fm_passes = value.round() as usize,
            "epsilon" => self.epsilon = value,
            other => return Err(format_err!("method ParMETIS has no tunable {other:?}")),
        }
        Ok(())
    }

    fn partition(&self, input: &PartitionInput) -> PartitionResult {
        let topo = LeafTopology::build_for(input.mesh, input.leaves.to_vec());
        let (xadj, adjncy) = topo.dual_graph_csr();
        let adjwgt = vec![1.0; adjncy.len()];
        let g = CsrGraph {
            xadj,
            adjncy,
            adjwgt,
            vwgt: input.weights.to_vec(),
        };
        // Seed depends on the *current distribution* (like ParMETIS,
        // whose diffusion starts from the current parts): this is what
        // makes its runtime/partitions jitter as the mesh evolves.
        let mut rng = Pcg32::new(self.seed ^ (g.n() as u64).rotate_left(17));
        let parts = recursive_kway(&g, input.nparts, self, &mut rng);
        // SPMD multilevel: matching + contraction rounds exchange halo
        // data; charge one representative collective per level plus the
        // gather/bcast of the coarsest partition.
        let levels = ((g.n() as f64 / self.coarsen_to as f64).ln() / 0.6f64.ln())
            .abs()
            .ceil() as usize;
        let mut comm = Vec::new();
        for _ in 0..levels.max(1) {
            comm.push(CommOp::AllToAllV {
                total_bytes: g.adjncy.len() * 8 / 2,
                max_msg: g.adjncy.len() * 8 / (2 * input.nparts.max(1)),
            });
        }
        comm.push(CommOp::Gather {
            bytes: self.coarsen_to * 8,
        });
        comm.push(CommOp::Bcast {
            bytes: self.coarsen_to * 2,
        });
        PartitionResult { parts, comm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::topology::LeafTopology;
    use crate::partition::testutil::{assert_valid_partition, setup_mesh};
    use crate::partition::Partitioner;

    fn path_graph(n: usize) -> CsrGraph {
        let mut xadj = vec![0u32];
        let mut adjncy = Vec::new();
        for i in 0..n {
            if i > 0 {
                adjncy.push((i - 1) as u32);
            }
            if i + 1 < n {
                adjncy.push((i + 1) as u32);
            }
            xadj.push(adjncy.len() as u32);
        }
        let adjwgt = vec![1.0; adjncy.len()];
        CsrGraph {
            xadj,
            adjncy,
            adjwgt,
            vwgt: vec![1.0; n],
        }
    }

    #[test]
    fn bisect_path_graph_optimal_cut() {
        // optimal bisection of a path cuts exactly 1 edge
        let g = path_graph(64);
        let mut rng = Pcg32::new(1);
        let side = multilevel_bisect(&g, 0.5, 8, 4, 0.05, &mut rng);
        let cut = g.cut2(&side);
        // heuristic multilevel: allow a couple of extra cut edges over
        // the optimum of 1
        assert!(cut <= 4.0, "cut {cut} on a path");
        let w0: f64 = (0..g.n()).filter(|&v| side[v] == 0).map(|v| g.vwgt[v]).sum();
        assert!((w0 - 32.0).abs() <= 3.0, "w0 = {w0}");
    }

    #[test]
    fn induced_subgraph_structure() {
        let g = path_graph(10);
        let sub = induced_subgraph(&g, &[2, 3, 4, 7]);
        assert_eq!(sub.n(), 4);
        // edges: 2-3, 3-4 survive; 7 isolated
        let total_edges: usize = (0..sub.n()).map(|v| sub.degree(v)).sum();
        assert_eq!(total_edges, 4); // two undirected edges
        assert_eq!(sub.degree(3), 0);
    }

    #[test]
    fn kway_balances_mesh() {
        let mesh = setup_mesh(2);
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let owners = vec![0u16; leaves.len()];
        for p in [2usize, 4, 6, 8] {
            let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, p);
            let r = MultilevelGraph::parmetis_like().partition(&input);
            assert_valid_partition(&input, &r, 0.12);
        }
    }

    #[test]
    fn graph_cut_beats_geometric_methods() {
        // the paper's premise: graph partitioning gives the best cut
        let mesh = setup_mesh(3);
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0; leaves.len()];
        let owners = vec![0u16; leaves.len()];
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, 8);
        let topo = LeafTopology::build_for(&mesh, leaves.clone());

        let g_cut = topo.interface_faces(
            &MultilevelGraph::parmetis_like().partition(&input).parts,
        );
        let m_cut = topo.interface_faces(
            &crate::partition::sfc::SfcPartitioner::msfc()
                .partition(&input)
                .parts,
        );
        // our FM is simpler than METIS's (no rollback hill-climbing),
        // so require parity-with-slack rather than strict dominance;
        // the paper-shape claims live in the end-to-end benches.
        assert!(
            (g_cut as f64) < 1.3 * m_cut as f64,
            "graph cut {g_cut} vs morton cut {m_cut}"
        );
        // ... and both must crush a random assignment
        let mut rng2 = crate::util::rng::Pcg32::new(99);
        let rand_parts: Vec<u16> =
            (0..leaves.len()).map(|_| rng2.gen_range(8) as u16).collect();
        let r_cut = topo.interface_faces(&rand_parts);
        assert!((g_cut as f64) < 0.4 * r_cut as f64, "{g_cut} vs random {r_cut}");
    }

    #[test]
    fn disconnected_graph_handled() {
        // two disjoint paths
        let g;
        // break the middle edge by building from two halves manually
        let h = path_graph(8);
        let mut xadj = h.xadj.clone();
        let mut adjncy = h.adjncy.clone();
        for i in 0..8 {
            let lo = h.xadj[i] as usize;
            let hi = h.xadj[i + 1] as usize;
            for e in lo..hi {
                adjncy.push(h.adjncy[e] + 8);
            }
            xadj.push(adjncy.len() as u32);
        }
        g = CsrGraph {
            xadj,
            adjncy: adjncy.clone(),
            adjwgt: vec![1.0; adjncy.len()],
            vwgt: vec![1.0; 16],
        };
        let mut rng = Pcg32::new(3);
        let side = multilevel_bisect(&g, 0.5, 4, 4, 0.05, &mut rng);
        let w0: f64 = (0..16).filter(|&v| side[v] == 0).map(|v| g.vwgt[v]).sum();
        assert!((4.0..=12.0).contains(&w0), "w0 = {w0}");
    }
}
