//! Partition quality metrics: the quantities the paper's figures and
//! tables compare -- load imbalance, interface size (edge cut), and
//! migration volumes (TotalV / MaxV, §2.4).

use crate::mesh::topology::LeafTopology;

/// Full quality report of a partition.
#[derive(Debug, Clone)]
pub struct PartitionQuality {
    pub nparts: usize,
    /// max part weight / mean part weight (1.0 = perfect)
    pub imbalance: f64,
    /// number of interior mesh faces crossing a part boundary
    pub interface_faces: usize,
    /// interface_faces / total interior faces
    pub surface_index: f64,
    /// number of non-empty parts
    pub nonempty: usize,
}

pub fn quality(topo: &LeafTopology, parts: &[u16], weights: &[f64], nparts: usize) -> PartitionQuality {
    assert_eq!(parts.len(), weights.len());
    let mut wsum = vec![0.0f64; nparts];
    for (&p, &w) in parts.iter().zip(weights) {
        wsum[p as usize] += w;
    }
    let interface_faces = topo.interface_faces(parts);
    PartitionQuality {
        nparts,
        imbalance: crate::util::stats::imbalance(&wsum),
        interface_faces,
        surface_index: if topo.n_interior_faces == 0 {
            0.0
        } else {
            interface_faces as f64 / topo.n_interior_faces as f64
        },
        nonempty: wsum.iter().filter(|&&w| w > 0.0).count(),
    }
}

/// Migration volumes between an old and a new assignment of the same
/// leaves (§2.4): TotalV = total weight that changes rank; MaxV = the
/// largest per-rank traffic (send + receive).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MigrationVolume {
    pub total_v: f64,
    pub max_v: f64,
    /// fraction of total weight that moved
    pub moved_fraction: f64,
}

pub fn migration_volume(
    old_parts: &[u16],
    new_parts: &[u16],
    weights: &[f64],
    nparts: usize,
) -> MigrationVolume {
    assert_eq!(old_parts.len(), new_parts.len());
    assert_eq!(old_parts.len(), weights.len());
    let mut send = vec![0.0f64; nparts];
    let mut recv = vec![0.0f64; nparts];
    let mut total_v = 0.0;
    let mut total_w = 0.0;
    for i in 0..old_parts.len() {
        total_w += weights[i];
        if old_parts[i] != new_parts[i] {
            total_v += weights[i];
            send[old_parts[i] as usize] += weights[i];
            recv[new_parts[i] as usize] += weights[i];
        }
    }
    let max_v = send
        .iter()
        .zip(&recv)
        .map(|(s, r)| s + r)
        .fold(0.0f64, f64::max);
    MigrationVolume {
        total_v,
        max_v,
        moved_fraction: if total_w > 0.0 { total_v / total_w } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generator::cube_mesh;

    #[test]
    fn quality_of_trivial_partition() {
        let m = cube_mesh(2);
        let topo = LeafTopology::build(&m);
        let parts = vec![0u16; topo.n_leaves()];
        let weights = vec![1.0; topo.n_leaves()];
        let q = quality(&topo, &parts, &weights, 4);
        assert_eq!(q.interface_faces, 0);
        assert_eq!(q.surface_index, 0.0);
        assert_eq!(q.nonempty, 1);
        assert_eq!(q.imbalance, 4.0); // all weight on one of 4 parts
    }

    #[test]
    fn quality_balanced_two_parts() {
        let m = cube_mesh(2);
        let topo = LeafTopology::build(&m);
        let n = topo.n_leaves();
        let parts: Vec<u16> = (0..n).map(|i| (i % 2) as u16).collect();
        let weights = vec![1.0; n];
        let q = quality(&topo, &parts, &weights, 2);
        assert!((q.imbalance - 1.0).abs() < 1e-12);
        assert_eq!(q.nonempty, 2);
        assert!(q.interface_faces > 0);
        assert!(q.surface_index > 0.0 && q.surface_index <= 1.0);
    }

    #[test]
    fn migration_none_when_identical() {
        let old = vec![0u16, 1, 2, 1];
        let w = vec![1.0, 2.0, 3.0, 4.0];
        let mv = migration_volume(&old, &old, &w, 3);
        assert_eq!(mv.total_v, 0.0);
        assert_eq!(mv.max_v, 0.0);
        assert_eq!(mv.moved_fraction, 0.0);
    }

    #[test]
    fn migration_counts_moves() {
        let old = vec![0u16, 0, 1, 1];
        let new = vec![0u16, 1, 1, 0];
        let w = vec![1.0, 2.0, 3.0, 4.0];
        let mv = migration_volume(&old, &new, &w, 2);
        assert_eq!(mv.total_v, 6.0); // items 1 (w2) and 3 (w4) moved
        // rank 0: sends 2, receives 4 -> 6; rank 1: sends 4, receives 2 -> 6
        assert_eq!(mv.max_v, 6.0);
        assert!((mv.moved_fraction - 0.6).abs() < 1e-12);
    }

    #[test]
    fn migration_all_moved() {
        let old = vec![0u16, 0];
        let new = vec![1u16, 1];
        let w = vec![1.0, 1.0];
        let mv = migration_volume(&old, &new, &w, 2);
        assert_eq!(mv.total_v, 2.0);
        assert_eq!(mv.moved_fraction, 1.0);
    }
}
