//! The exact ghost layer of a partition (DESIGN.md §5).
//!
//! Built from the leaf face adjacency ([`LeafTopology`]): every
//! interior face whose two leaves live on different ranks is an
//! interface face, and each (rank, neighbour-rank) pair accumulates
//! the faces it shares. The solver's per-CG-iteration halo exchange is
//! then priced as one message per neighbour rank plus the bottleneck
//! rank's interface bytes -- which is how partition quality (interface
//! size, neighbour counts) feeds the modeled solve time, exactly as in
//! the paper's Fig 3.4.

use crate::mesh::topology::LeafTopology;
use crate::mesh::{TetMesh, NONE};
use crate::util::hash::FxHashMap;

/// Bytes shipped across one interface face in one direction per halo
/// update: the 3 shared P1 vertex values in f64. (Vertices shared by
/// several interface faces are counted per face -- a deliberate,
/// documented simplification; see DESIGN.md §5.)
pub const FACE_BYTES: usize = 24;

/// Ghost-layer summary of one partition over `nparts` ranks.
#[derive(Debug, Clone)]
pub struct Halo {
    pub nparts: usize,
    /// Total partition-boundary faces, each counted once.
    pub interface_faces: usize,
    /// Interface faces per unordered rank pair, keyed (lo, hi).
    pub faces_between: FxHashMap<(u16, u16), usize>,
    /// Per rank: sorted distinct neighbour ranks.
    pub neighbors: Vec<Vec<u16>>,
    /// Per rank: interface faces incident to the rank.
    pub rank_faces: Vec<usize>,
}

impl Halo {
    /// Build the exact ghost layer for the partition `owners` (one
    /// entry per `topo.leaves` element, values `< nparts`).
    pub fn build(mesh: &TetMesh, topo: &LeafTopology, owners: &[u16], nparts: usize) -> Self {
        assert_eq!(owners.len(), topo.n_leaves(), "owners/topology mismatch");
        debug_assert!(topo.leaves.iter().all(|&id| mesh.elem(id).is_leaf()));
        let mut faces_between: FxHashMap<(u16, u16), usize> = FxHashMap::default();
        let mut neighbor_sets: Vec<std::collections::BTreeSet<u16>> =
            vec![std::collections::BTreeSet::new(); nparts];
        let mut rank_faces = vec![0usize; nparts];
        let mut interface_faces = 0usize;

        for (i, nb) in topo.neighbors.iter().enumerate() {
            for &j in nb {
                // each interior face once: local index pair i < j
                if j == NONE || (j as usize) <= i {
                    continue;
                }
                let (a, b) = (owners[i], owners[j as usize]);
                if a == b {
                    continue;
                }
                assert!(
                    (a as usize) < nparts && (b as usize) < nparts,
                    "owner out of range: {a} / {b} >= {nparts}"
                );
                interface_faces += 1;
                let key = (a.min(b), a.max(b));
                *faces_between.entry(key).or_insert(0) += 1;
                rank_faces[a as usize] += 1;
                rank_faces[b as usize] += 1;
                neighbor_sets[a as usize].insert(b);
                neighbor_sets[b as usize].insert(a);
            }
        }
        Self {
            nparts,
            interface_faces,
            faces_between,
            neighbors: neighbor_sets
                .into_iter()
                .map(|s| s.into_iter().collect())
                .collect(),
            rank_faces,
        }
    }

    /// Largest neighbour count over all ranks: the per-iteration
    /// latency charge of the bottleneck rank.
    pub fn max_neighbors(&self) -> usize {
        self.neighbors.iter().map(|n| n.len()).max().unwrap_or(0)
    }

    /// Largest per-rank halo traffic in bytes (send + receive, i.e.
    /// `2 * FACE_BYTES` over each of the rank's interface faces): the
    /// bandwidth charge of the bottleneck rank.
    pub fn max_rank_bytes(&self) -> usize {
        self.rank_faces
            .iter()
            .map(|&f| 2 * f * FACE_BYTES)
            .max()
            .unwrap_or(0)
    }

    /// Total halo bytes moved per update over all ranks (each face
    /// exchanges both directions).
    pub fn total_bytes(&self) -> usize {
        2 * self.interface_faces * FACE_BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Distribution;
    use crate::mesh::generator;

    fn setup(nparts: usize) -> (TetMesh, LeafTopology, Vec<u16>) {
        let mut mesh = generator::cube_mesh(2);
        mesh.refine(&mesh.leaves_unordered());
        let leaves = mesh.leaves_unordered();
        Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
        let topo = LeafTopology::build(&mesh);
        let owners: Vec<u16> = topo
            .leaves
            .iter()
            .map(|&id| mesh.elem(id).owner)
            .collect();
        (mesh, topo, owners)
    }

    #[test]
    fn neighbor_lists_are_symmetric() {
        let (mesh, topo, owners) = setup(6);
        let halo = Halo::build(&mesh, &topo, &owners, 6);
        for (r, nbs) in halo.neighbors.iter().enumerate() {
            for &q in nbs {
                assert_ne!(q as usize, r, "rank {r} lists itself");
                assert!(
                    halo.neighbors[q as usize].contains(&(r as u16)),
                    "rank {r} lists {q} but not vice versa"
                );
            }
        }
    }

    #[test]
    fn interface_faces_counted_once_and_match_topology() {
        let (mesh, topo, owners) = setup(6);
        let halo = Halo::build(&mesh, &topo, &owners, 6);
        assert_eq!(halo.interface_faces, topo.interface_faces(&owners));
        let pair_sum: usize = halo.faces_between.values().sum();
        assert_eq!(pair_sum, halo.interface_faces);
        let per_rank_sum: usize = halo.rank_faces.iter().sum();
        assert_eq!(per_rank_sum, 2 * halo.interface_faces);
        assert!(halo.interface_faces > 0);
    }

    #[test]
    fn single_part_has_empty_halo() {
        let (mesh, topo, _) = setup(2);
        let owners = vec![0u16; topo.n_leaves()];
        let halo = Halo::build(&mesh, &topo, &owners, 1);
        assert_eq!(halo.interface_faces, 0);
        assert_eq!(halo.max_neighbors(), 0);
        assert_eq!(halo.max_rank_bytes(), 0);
        assert_eq!(halo.total_bytes(), 0);
    }

    #[test]
    fn bottleneck_bytes_scale_with_rank_faces() {
        let (mesh, topo, owners) = setup(6);
        let halo = Halo::build(&mesh, &topo, &owners, 6);
        let max_faces = *halo.rank_faces.iter().max().unwrap();
        assert_eq!(halo.max_rank_bytes(), 2 * max_faces * FACE_BYTES);
        assert!(halo.max_neighbors() <= 5);
        assert!(halo.max_neighbors() >= 1);
    }
}
