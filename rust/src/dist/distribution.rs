//! Rank ownership of the leaf elements (the virtual process map).
//!
//! A [`Distribution`] is the `dist` layer's view of "which rank holds
//! what": ownership itself is stored on the elements
//! ([`crate::mesh::Elem::owner`]) so it survives refinement (children
//! inherit the parent's rank -- the data-locality behaviour whose
//! erosion the DLB corrects); this type carries the rank count and the
//! operations over that map.
//!
//! Two operations matter to the paper's loop:
//! * [`Distribution::assign_blocks`] -- the initial decomposition:
//!   contiguous equal-count blocks along the maintained SFC order of
//!   the refinement forest (DFS over the SFC-sorted roots, §2.1).
//! * [`Distribution::imbalance`] -- the load-imbalance factor
//!   `lambda = max rank load / mean rank load` that the DLB policy
//!   (DESIGN.md §6) triggers on.

use crate::mesh::{ElemId, TetMesh};
use crate::util::hash::FxHashSet;

/// The virtual process set: `nparts` ranks owning the mesh's leaves.
#[derive(Debug, Clone)]
pub struct Distribution {
    /// Number of virtual ranks (the paper's p: 128 / 192).
    pub nparts: usize,
}

impl Distribution {
    pub fn new(nparts: usize) -> Self {
        assert!(
            (1..=u16::MAX as usize).contains(&nparts),
            "nparts {nparts} out of range"
        );
        Self { nparts }
    }

    /// The maintained SFC order of the given leaves: refinement-forest
    /// DFS (left child first) over the SFC-sorted roots. For the usual
    /// whole-mesh call this is exactly [`TetMesh::leaves_dfs`]; a
    /// subset keeps the DFS relative order.
    fn sfc_order(&self, mesh: &TetMesh, leaves: &[ElemId]) -> Vec<ElemId> {
        let dfs = mesh.leaves_dfs();
        if dfs.len() == leaves.len() {
            return dfs;
        }
        let keep: FxHashSet<ElemId> = leaves.iter().copied().collect();
        dfs.into_iter().filter(|id| keep.contains(id)).collect()
    }

    /// Initial decomposition: split the maintained SFC order of
    /// `leaves` into `nparts` contiguous blocks of (near-)equal leaf
    /// count and write the block index into each element's `owner`.
    /// Block `i` gets the slice `[i*n/p, (i+1)*n/p)`, so counts differ
    /// by at most one and lambda -> 1 under uniform weights.
    pub fn assign_blocks(&self, mesh: &mut TetMesh, leaves: &[ElemId]) {
        let ordered = self.sfc_order(mesh, leaves);
        let n = ordered.len();
        for (i, &id) in ordered.iter().enumerate() {
            mesh.set_owner(id, (i * self.nparts / n) as u16);
        }
    }

    /// Per-rank load: sum of `weights` over the leaves each rank owns.
    pub fn rank_loads(&self, mesh: &TetMesh, leaves: &[ElemId], weights: &[f64]) -> Vec<f64> {
        assert_eq!(leaves.len(), weights.len());
        let mut loads = vec![0.0f64; self.nparts];
        for (&id, &w) in leaves.iter().zip(weights) {
            let owner = mesh.elem(id).owner as usize;
            assert!(
                owner < self.nparts,
                "element {id} owned by rank {owner} >= nparts {}",
                self.nparts
            );
            loads[owner] += w;
        }
        loads
    }

    /// The load-imbalance factor `lambda = max_i load_i / mean_i
    /// load_i` over all `nparts` ranks (empty ranks count toward the
    /// mean). 1.0 is perfect balance; the DLB policy repartitions when
    /// lambda exceeds its trigger (DESIGN.md §6).
    pub fn imbalance(&self, mesh: &TetMesh, leaves: &[ElemId], weights: &[f64]) -> f64 {
        crate::util::stats::imbalance(&self.rank_loads(mesh, leaves, weights))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generator;

    #[test]
    fn block_assignment_balances_uniform_weights() {
        // lambda -> 1 under block assignment with unit weights, even
        // when nparts does not divide the leaf count
        let mut mesh = generator::cube_mesh(2);
        mesh.refine(&mesh.leaves_unordered());
        let leaves = mesh.leaves_unordered();
        for nparts in [2usize, 3, 7, 13] {
            let dist = Distribution::new(nparts);
            dist.assign_blocks(&mut mesh, &leaves);
            let weights = vec![1.0f64; leaves.len()];
            let lam = dist.imbalance(&mesh, &leaves, &weights);
            // counts differ by <= 1, so lambda <= ceil(n/p)/(n/p)
            let n = leaves.len() as f64;
            let bound = (n / nparts as f64).ceil() / (n / nparts as f64);
            assert!(
                lam <= bound + 1e-12,
                "p={nparts}: lambda {lam} > bound {bound}"
            );
            assert!(lam < 1.1, "p={nparts}: lambda {lam}");
        }
    }

    #[test]
    fn blocks_are_contiguous_along_sfc_order() {
        let mut mesh = generator::cube_mesh(2);
        let marked: Vec<_> = mesh
            .leaves_unordered()
            .into_iter()
            .filter(|&id| mesh.centroid(id).x < 0.7)
            .collect();
        mesh.refine(&marked);
        let leaves = mesh.leaves_unordered();
        let dist = Distribution::new(5);
        dist.assign_blocks(&mut mesh, &leaves);
        // owners must be monotone non-decreasing along the DFS order
        let owners: Vec<u16> = mesh
            .leaves_dfs()
            .iter()
            .map(|&id| mesh.elem(id).owner)
            .collect();
        for w in owners.windows(2) {
            assert!(w[0] <= w[1], "blocks not contiguous in SFC order");
        }
        assert_eq!(owners.first(), Some(&0));
        assert_eq!(owners.last(), Some(&4));
    }

    #[test]
    fn imbalance_matches_lambda_definition() {
        // 6 leaves on 3 ranks, skewed by hand: loads (4, 1, 1),
        // mean 2 -> lambda = 2
        let mut mesh = generator::cube_mesh(1);
        let leaves = mesh.leaves_unordered();
        assert_eq!(leaves.len(), 6);
        let owners = [0u16, 0, 0, 0, 1, 2];
        for (&id, &o) in leaves.iter().zip(owners.iter()) {
            mesh.set_owner(id, o);
        }
        let dist = Distribution::new(3);
        let weights = vec![1.0f64; 6];
        let loads = dist.rank_loads(&mesh, &leaves, &weights);
        assert_eq!(loads, vec![4.0, 1.0, 1.0]);
        let lam = dist.imbalance(&mesh, &leaves, &weights);
        assert!((lam - 2.0).abs() < 1e-12, "lambda {lam}");
    }

    #[test]
    fn empty_ranks_count_toward_the_mean() {
        // all weight on rank 0 of 4 -> lambda = 4 (not 1): stranding
        // ranks idle IS imbalance
        let mut mesh = generator::cube_mesh(1);
        let leaves = mesh.leaves_unordered();
        for &id in &leaves {
            mesh.set_owner(id, 0);
        }
        let dist = Distribution::new(4);
        let weights = vec![1.0f64; leaves.len()];
        let lam = dist.imbalance(&mesh, &leaves, &weights);
        assert!((lam - 4.0).abs() < 1e-12, "lambda {lam}");
    }

    #[test]
    fn more_parts_than_leaves_does_not_panic() {
        let mut mesh = generator::cube_mesh(1); // 6 leaves
        let leaves = mesh.leaves_unordered();
        let dist = Distribution::new(10);
        dist.assign_blocks(&mut mesh, &leaves);
        for &id in &leaves {
            assert!((mesh.elem(id).owner as usize) < 10);
        }
        let weights = vec![1.0f64; leaves.len()];
        // 6 non-empty ranks of 10: lambda = 1 / (6/10)
        let lam = dist.imbalance(&mesh, &leaves, &weights);
        assert!((lam - 10.0 / 6.0).abs() < 1e-12, "lambda {lam}");
    }
}
