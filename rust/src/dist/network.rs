//! The alpha-beta (latency-bandwidth) network model that prices the
//! collectives logged by the partitioners, the remapper and the
//! migration (DESIGN.md §4).
//!
//! A message of `b` bytes costs `alpha + b * beta`; collectives are
//! priced from the standard tree/butterfly algorithm shapes:
//! `ceil(log2 p)` stages for Scan / Allreduce / Bcast and the latency
//! part of Gather, one round of up to `p - 1` messages with a
//! bottleneck-rank bandwidth term for AllToAllV. With one rank there
//! is no network and every collective is free.

use crate::partition::CommOp;

/// Latency-bandwidth model of the interconnect between the `nparts`
/// virtual ranks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkModel {
    /// Number of virtual ranks (p).
    pub nparts: usize,
    /// Per-message latency in seconds.
    pub alpha: f64,
    /// Per-byte transfer time in seconds (1 / bandwidth).
    pub beta: f64,
}

impl NetworkModel {
    pub fn new(nparts: usize, alpha: f64, beta: f64) -> Self {
        assert!(nparts >= 1, "nparts must be >= 1");
        assert!(alpha >= 0.0 && beta >= 0.0, "negative network parameters");
        Self {
            nparts,
            alpha,
            beta,
        }
    }

    /// QDR-InfiniBand-like preset (the paper's cluster class):
    /// ~1.7 us MPI latency, ~3.2 GB/s effective per-link bandwidth.
    pub fn infiniband(nparts: usize) -> Self {
        Self::new(nparts, 1.7e-6, 1.0 / 3.2e9)
    }

    /// Stages of a binomial-tree / butterfly collective: ceil(log2 p).
    fn stages(&self) -> f64 {
        (self.nparts as f64).log2().ceil()
    }

    /// Modeled wall time of one collective (seconds).
    pub fn cost(&self, op: &CommOp) -> f64 {
        if self.nparts <= 1 {
            return 0.0;
        }
        let p = self.nparts as f64;
        match *op {
            // prefix scan: log2(p) stages, full payload each stage
            CommOp::Scan { bytes } => self.stages() * (self.alpha + bytes as f64 * self.beta),
            // reduce + broadcast butterfly: 2 log2(p) stages
            CommOp::Allreduce { bytes } => {
                2.0 * self.stages() * (self.alpha + bytes as f64 * self.beta)
            }
            // binomial gather: log2(p) latency stages; the root link
            // still moves every byte once
            CommOp::Gather { bytes } => self.stages() * self.alpha + bytes as f64 * self.beta,
            // binomial broadcast
            CommOp::Bcast { bytes } => self.stages() * (self.alpha + bytes as f64 * self.beta),
            // personalized all-to-all: up to p-1 messages per rank;
            // bandwidth is set by the bottleneck rank -- at least the
            // mean per-rank traffic, at least the largest message
            CommOp::AllToAllV {
                total_bytes,
                max_msg,
            } => {
                (p - 1.0) * self.alpha
                    + (total_bytes as f64 / p).max(max_msg as f64) * self.beta
            }
        }
    }

    /// Modeled time of a sequence of collectives, executed in order.
    pub fn sequence_time(&self, ops: &[CommOp]) -> f64 {
        ops.iter().map(|op| self.cost(op)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_ops(bytes: usize) -> [CommOp; 5] {
        [
            CommOp::Scan { bytes },
            CommOp::Allreduce { bytes },
            CommOp::Gather { bytes },
            CommOp::Bcast { bytes },
            CommOp::AllToAllV {
                total_bytes: bytes,
                max_msg: bytes / 4,
            },
        ]
    }

    #[test]
    fn single_rank_is_free() {
        let net = NetworkModel::infiniband(1);
        for op in all_ops(1 << 20) {
            assert_eq!(net.cost(&op), 0.0, "{op:?}");
        }
    }

    #[test]
    fn cost_is_monotone_in_bytes() {
        let net = NetworkModel::infiniband(32);
        for (small, large) in all_ops(1_000).iter().zip(all_ops(100_000).iter()) {
            assert!(
                net.cost(small) < net.cost(large),
                "{small:?} -> {large:?} not monotone"
            );
        }
    }

    #[test]
    fn cost_is_monotone_in_nparts() {
        // latency-bound collectives get strictly slower as p grows
        // across powers of two (more stages / more messages)
        for op in [
            CommOp::Scan { bytes: 4096 },
            CommOp::Allreduce { bytes: 4096 },
            CommOp::Gather { bytes: 4096 },
            CommOp::Bcast { bytes: 4096 },
            // one dominant message pins the bandwidth term, so the
            // per-message latency growth is visible
            CommOp::AllToAllV {
                total_bytes: 1 << 20,
                max_msg: 1 << 20,
            },
        ] {
            let mut last = 0.0;
            for p in [2usize, 4, 16, 64, 256] {
                let c = NetworkModel::infiniband(p).cost(&op);
                assert!(c > last, "{op:?}: cost({p}) = {c} <= {last}");
                last = c;
            }
        }
    }

    #[test]
    fn scan_matches_closed_form() {
        let net = NetworkModel::new(8, 2e-6, 1e-9);
        // 3 stages * (alpha + 100 bytes * beta)
        let c = net.cost(&CommOp::Scan { bytes: 100 });
        assert!((c - 3.0 * (2e-6 + 100.0 * 1e-9)).abs() < 1e-15);
        // non-power-of-two rounds stages up
        let net9 = NetworkModel::new(9, 2e-6, 1e-9);
        let c9 = net9.cost(&CommOp::Scan { bytes: 100 });
        assert!((c9 - 4.0 * (2e-6 + 100.0 * 1e-9)).abs() < 1e-15);
    }

    #[test]
    fn alltoallv_prices_bottleneck() {
        let net = NetworkModel::new(4, 1e-6, 1e-9);
        // mean traffic dominates when messages are uniform
        let c = net.cost(&CommOp::AllToAllV {
            total_bytes: 4000,
            max_msg: 100,
        });
        assert!((c - (3.0 * 1e-6 + 1000.0 * 1e-9)).abs() < 1e-15);
        // a single huge message dominates when skewed
        let c = net.cost(&CommOp::AllToAllV {
            total_bytes: 4000,
            max_msg: 3000,
        });
        assert!((c - (3.0 * 1e-6 + 3000.0 * 1e-9)).abs() < 1e-15);
    }

    #[test]
    fn sequence_time_sums() {
        let net = NetworkModel::infiniband(16);
        let ops = all_ops(10_000);
        let total: f64 = ops.iter().map(|op| net.cost(op)).sum();
        assert!((net.sequence_time(&ops) - total).abs() < 1e-18);
        assert_eq!(net.sequence_time(&[]), 0.0);
    }
}
