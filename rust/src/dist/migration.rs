//! Element migration: execute a (remapped) partition (DESIGN.md §5).
//!
//! The paper's DLB phase ends by actually moving elements: every leaf
//! whose new part differs from its current owner is shipped to the new
//! rank. In the virtual-SPMD layer that is an ownership rewrite plus
//! an accounting of what a real run would have sent: the Oliker-Biswas
//! migration volumes (TotalV / MaxV, via
//! [`crate::partition::metrics::migration_volume`]) and one modeled
//! `MPI_Alltoallv` carrying every moved element's payload.

use super::NetworkModel;
use crate::mesh::{ElemId, TetMesh};
use crate::partition::metrics::{migration_volume, MigrationVolume};
use crate::partition::CommOp;
use crate::util::hash::FxHashMap;

/// Bytes shipped per unit of element weight: 4 vertex coordinates
/// (96 B) rounded up to cover connectivity, tree and owner metadata.
/// Solution transfer is charged separately by the solver model.
pub const ELEM_BYTES: usize = 128;

/// What one migration did: the volumes it moved and the modeled
/// network time of moving them.
#[derive(Debug, Clone)]
pub struct MigrateOutcome {
    /// TotalV / MaxV / moved fraction between old owners and `parts`.
    pub volume: MigrationVolume,
    /// Modeled wall time of the transfer (seconds).
    pub modeled_time: f64,
    /// The collectives a real SPMD migration would have performed
    /// (empty when nothing moved).
    pub comm: Vec<CommOp>,
}

/// Rewrite each leaf's owner to its new part and price the transfer.
///
/// `parts[i]` is the (already remapped, DESIGN.md §6) destination rank
/// of `leaves[i]`; `weights[i]` its payload weight. Returns the
/// migration volumes computed against the owners *before* the rewrite,
/// so callers measure exactly what moved.
pub fn migrate(
    mesh: &mut TetMesh,
    leaves: &[ElemId],
    parts: &[u16],
    weights: &[f64],
    net: &NetworkModel,
) -> MigrateOutcome {
    assert_eq!(leaves.len(), parts.len());
    assert_eq!(leaves.len(), weights.len());
    let nparts = net.nparts;
    for &p in parts {
        assert!(
            (p as usize) < nparts,
            "destination part {p} >= nparts {nparts}"
        );
    }

    let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
    let volume = migration_volume(&owners, parts, weights, nparts);

    // largest single (src -> dst) message, for the bottleneck term
    let mut pair_w: FxHashMap<(u16, u16), f64> = FxHashMap::default();
    for ((&o, &p), &w) in owners.iter().zip(parts).zip(weights) {
        if o != p {
            *pair_w.entry((o, p)).or_insert(0.0) += w;
        }
    }
    let max_pair_w = pair_w.values().fold(0.0f64, |acc, &w| acc.max(w));

    for (&id, &p) in leaves.iter().zip(parts) {
        mesh.set_owner(id, p);
    }

    let total_bytes = (volume.total_v * ELEM_BYTES as f64).ceil() as usize;
    let max_msg = (max_pair_w * ELEM_BYTES as f64).ceil() as usize;
    let comm = if volume.total_v > 0.0 {
        vec![CommOp::AllToAllV {
            total_bytes,
            max_msg,
        }]
    } else {
        Vec::new()
    };
    let modeled_time = net.sequence_time(&comm);
    MigrateOutcome {
        volume,
        modeled_time,
        comm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Distribution;
    use crate::mesh::generator;

    fn setup(nparts: usize) -> (TetMesh, Vec<ElemId>, Vec<f64>) {
        let mut mesh = generator::cube_mesh(2);
        mesh.refine(&mesh.leaves_unordered());
        let leaves = mesh.leaves_unordered();
        Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
        let weights = vec![1.0f64; leaves.len()];
        (mesh, leaves, weights)
    }

    #[test]
    fn owners_match_parts_after_migrate() {
        let (mut mesh, leaves, weights) = setup(4);
        let net = NetworkModel::infiniband(4);
        // move everything one rank to the right (wrap-around)
        let parts: Vec<u16> = leaves
            .iter()
            .map(|&id| (mesh.elem(id).owner + 1) % 4)
            .collect();
        let out = migrate(&mut mesh, &leaves, &parts, &weights, &net);
        for (&id, &p) in leaves.iter().zip(&parts) {
            assert_eq!(mesh.elem(id).owner, p);
        }
        assert!((out.volume.moved_fraction - 1.0).abs() < 1e-12);
        assert!(out.modeled_time > 0.0);
        assert_eq!(out.comm.len(), 1);
    }

    #[test]
    fn identity_partition_moves_nothing() {
        let (mut mesh, leaves, weights) = setup(4);
        let net = NetworkModel::infiniband(4);
        let parts: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let out = migrate(&mut mesh, &leaves, &parts, &weights, &net);
        assert_eq!(out.volume.total_v, 0.0);
        assert_eq!(out.volume.max_v, 0.0);
        assert_eq!(out.modeled_time, 0.0);
        assert!(out.comm.is_empty());
        for (&id, &p) in leaves.iter().zip(&parts) {
            assert_eq!(mesh.elem(id).owner, p);
        }
    }

    #[test]
    fn volume_matches_metrics_against_pre_state() {
        let (mut mesh, leaves, _) = setup(3);
        let net = NetworkModel::infiniband(3);
        let weights: Vec<f64> = (0..leaves.len()).map(|i| 1.0 + (i % 3) as f64).collect();
        let owners_before: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let parts: Vec<u16> = (0..leaves.len()).map(|i| (i % 3) as u16).collect();
        let expect = migration_volume(&owners_before, &parts, &weights, 3);
        let out = migrate(&mut mesh, &leaves, &parts, &weights, &net);
        assert_eq!(out.volume, expect);
    }

    #[test]
    fn modeled_time_prices_the_logged_alltoallv() {
        let (mut mesh, leaves, weights) = setup(5);
        let net = NetworkModel::infiniband(5);
        let parts: Vec<u16> = (0..leaves.len()).map(|i| (i % 5) as u16).collect();
        let out = migrate(&mut mesh, &leaves, &parts, &weights, &net);
        assert!((out.modeled_time - net.sequence_time(&out.comm)).abs() < 1e-18);
        match out.comm[0] {
            CommOp::AllToAllV {
                total_bytes,
                max_msg,
            } => {
                assert_eq!(
                    total_bytes,
                    (out.volume.total_v * ELEM_BYTES as f64).ceil() as usize
                );
                assert!(max_msg > 0 && max_msg <= total_bytes);
            }
            ref other => panic!("expected AllToAllV, got {other:?}"),
        }
    }
}
