//! The virtual-SPMD execution layer: rank ownership, the alpha-beta
//! network model, the exact ghost (halo) layer, and element migration.
//!
//! The whole computation lives in one address space, but every element
//! carries an owning *virtual rank* ([`crate::mesh::Elem::owner`]).
//! Partitioners and the remapper run sequentially and log the MPI
//! collectives their SPMD formulations would have performed
//! ([`crate::partition::CommOp`]); this module prices those logs
//! against a latency-bandwidth network model, so partition quality and
//! communication cost show up in the reported times exactly as they do
//! on a real cluster (DESIGN.md §2-§5).
//!
//! Pieces:
//! * [`Distribution`] -- the leaf -> rank map: initial contiguous block
//!   assignment along the maintained SFC order, and the load-imbalance
//!   factor lambda that the DLB policy (DESIGN.md §6) triggers on.
//! * [`NetworkModel`] -- alpha-beta pricing of the five [`CommOp`]
//!   collectives; [`NetworkModel::infiniband`] is the paper-like preset.
//! * [`Halo`] -- the exact ghost layer of the current partition, built
//!   from face adjacency; feeds the modeled per-CG-iteration halo
//!   exchange (paper Fig 3.4).
//! * [`migrate`] -- executes a new (remapped) partition: rewrites
//!   element ownership, reports the Oliker-Biswas migration volumes
//!   (TotalV / MaxV) and the modeled all-to-all transfer time.
//!
//! [`CommOp`]: crate::partition::CommOp

pub mod distribution;
pub mod halo;
pub mod migration;
pub mod network;

pub use distribution::Distribution;
pub use halo::{Halo, FACE_BYTES};
pub use migration::{migrate, MigrateOutcome, ELEM_BYTES};
pub use network::NetworkModel;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generator;

    /// End-to-end over the whole layer: skew a block distribution by
    /// local refinement, migrate to a balanced partition, and check
    /// lambda collapses back to ~1 with a consistent modeled cost.
    #[test]
    fn rebalance_roundtrip_restores_lambda() {
        let nparts = 4usize;
        let mut mesh = generator::cube_mesh(2);
        let dist = Distribution::new(nparts);
        let initial = mesh.leaves_unordered();
        dist.assign_blocks(&mut mesh, &initial);

        // skew: refine rank 0's elements twice
        for _ in 0..2 {
            let marked: Vec<_> = mesh
                .leaves_unordered()
                .into_iter()
                .filter(|&id| mesh.elem(id).owner == 0)
                .collect();
            mesh.refine(&marked);
        }
        let leaves = mesh.leaves_unordered();
        let weights = vec![1.0f64; leaves.len()];
        let lam_skew = dist.imbalance(&mesh, &leaves, &weights);
        assert!(lam_skew > 1.3, "skew not induced: {lam_skew}");

        // a perfectly balanced (if cut-oblivious) new partition
        let n = leaves.len();
        let parts: Vec<u16> = (0..n).map(|i| (i * nparts / n) as u16).collect();
        let net = NetworkModel::infiniband(nparts);
        let out = migrate(&mut mesh, &leaves, &parts, &weights, &net);
        assert!(out.volume.total_v > 0.0);
        assert!(out.modeled_time > 0.0);

        let lam = dist.imbalance(&mesh, &leaves, &weights);
        assert!(lam < 1.05, "lambda {lam} after rebalance");
    }
}
