//! The PJRT client wrapper: lazy-compiled executable cache + typed
//! entry points for each artifact kind.
//!
//! Pattern (see /opt/xla-example/load_hlo): HLO text ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. Outputs arrive as a 1-tuple (aot.py
//! lowers with `return_tuple=True`), decomposed with `to_tuple`.
//!
//! For the CG hot loop, [`CgBuffers`] keeps the ELL matrix staged as
//! device buffers across iterations (`execute_b`), so each iteration
//! moves only the four state vectors.

use crate::format_err;
use crate::util::error::Result;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use super::artifacts::{find_artifacts_dir, Manifest};
use super::next_rung;

pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// executables compiled so far (observable for tests/perf logs)
    pub compile_count: RefCell<usize>,
}

/// Batched element matrices result (flattened f32, row-major).
#[derive(Debug, Clone)]
pub struct ElemBatchOut {
    /// (B,4,4) stiffness
    pub k: Vec<f32>,
    /// (B,4,4) mass
    pub m: Vec<f32>,
    /// (B,4) load
    pub b: Vec<f32>,
}

/// One CG iteration's outputs.
#[derive(Debug, Clone)]
pub struct CgStepOut {
    pub x: Vec<f32>,
    pub r: Vec<f32>,
    pub p: Vec<f32>,
    pub rz: f32,
    pub rnorm2: f32,
}

impl Runtime {
    /// Open the runtime against an artifact directory.
    pub fn new(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| format_err!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            compile_count: RefCell::new(0),
        })
    }

    /// Open against the default artifact location.
    pub fn open_default() -> Result<Self> {
        let dir = find_artifacts_dir()
            .ok_or_else(|| format_err!("artifacts not found: run `make artifacts`"))?;
        Self::new(&dir)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Ladder of element-batch sizes.
    pub fn elem_ladder(&self) -> Vec<usize> {
        self.manifest.ladder("elem_tet", "batch")
    }

    /// Ladder of CG system sizes.
    pub fn cg_ladder(&self) -> Vec<usize> {
        self.manifest.ladder("cg_step", "n")
    }

    /// ELL width the cg/spmv artifacts were lowered with.
    pub fn ell_width(&self) -> usize {
        self.manifest
            .of_kind("cg_step")
            .next()
            .and_then(|e| e.params.get("w").copied())
            .unwrap_or(32)
    }

    fn executable(&self, name: &str, file: &Path) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            file.to_str().ok_or_else(|| format_err!("non-utf8 path"))?,
        )
        .map_err(|e| format_err!("parse {}: {e:?}", file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format_err!("compile {name}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        *self.compile_count.borrow_mut() += 1;
        Ok(exe)
    }

    fn kind_exe(&self, kind: &str, param: &str, value: usize) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let entry = self
            .manifest
            .find(kind, param, value)
            .ok_or_else(|| format_err!("no {kind} artifact with {param}={value}"))?;
        let path = self.manifest.hlo_path(entry);
        self.executable(&entry.name.clone(), &path)
    }

    /// Run the batched element kernel on `n` elements (padding to the
    /// ladder internally). `coords`: n*12 f32; `fvals`: n*4 f32.
    /// Outputs are truncated back to `n` elements.
    pub fn elem_tet(&self, coords: &[f32], fvals: &[f32], n: usize) -> Result<ElemBatchOut> {
        assert_eq!(coords.len(), n * 12);
        assert_eq!(fvals.len(), n * 4);
        let ladder = self.elem_ladder();
        let rung = next_rung(&ladder, n)
            .ok_or_else(|| format_err!("element batch {n} exceeds largest rung {ladder:?}"))?;
        let exe = self.kind_exe("elem_tet", "batch", rung)?;

        let mut c = coords.to_vec();
        c.resize(rung * 12, 0.0); // degenerate padding -> zero outputs
        let mut f = fvals.to_vec();
        f.resize(rung * 4, 0.0);

        let lc = xla::Literal::vec1(&c)
            .reshape(&[rung as i64, 4, 3])
            .map_err(|e| format_err!("{e:?}"))?;
        let lf = xla::Literal::vec1(&f)
            .reshape(&[rung as i64, 4])
            .map_err(|e| format_err!("{e:?}"))?;
        let result = exe
            .execute::<xla::Literal>(&[lc, lf])
            .map_err(|e| format_err!("elem_tet execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format_err!("{e:?}"))?;
        let parts = result.to_tuple().map_err(|e| format_err!("{e:?}"))?;
        if parts.len() != 3 {
            return Err(format_err!("elem_tet returned {} outputs", parts.len()));
        }
        let mut k = parts[0].to_vec::<f32>().map_err(|e| format_err!("{e:?}"))?;
        let mut m = parts[1].to_vec::<f32>().map_err(|e| format_err!("{e:?}"))?;
        let mut b = parts[2].to_vec::<f32>().map_err(|e| format_err!("{e:?}"))?;
        k.truncate(n * 16);
        m.truncate(n * 16);
        b.truncate(n * 4);
        Ok(ElemBatchOut { k, m, b })
    }

    /// Stage an ELL system for repeated CG iterations. `n_pad` must be
    /// a ladder rung; vals/cols are (n_pad, w) row-major; diag_inv has
    /// zeros on padded/Dirichlet rows.
    pub fn stage_cg(
        &self,
        vals: &[f32],
        cols: &[i32],
        diag_inv: &[f32],
        n_pad: usize,
    ) -> Result<CgBuffers> {
        let w = self.ell_width();
        assert_eq!(vals.len(), n_pad * w);
        assert_eq!(cols.len(), n_pad * w);
        assert_eq!(diag_inv.len(), n_pad);
        let exe = self.kind_exe("cg_step", "n", n_pad)?;
        let dev = &self.client.devices()[0];
        let to_buf_f32 = |data: &[f32], dims: &[usize]| -> Result<xla::PjRtBuffer> {
            self.client
                .buffer_from_host_buffer(data, dims, Some(dev))
                .map_err(|e| format_err!("stage buffer: {e:?}"))
        };
        let vals_b = to_buf_f32(vals, &[n_pad, w])?;
        let dinv_b = to_buf_f32(diag_inv, &[n_pad])?;
        let cols_b = self
            .client
            .buffer_from_host_buffer(cols, &[n_pad, w], Some(dev))
            .map_err(|e| format_err!("stage cols: {e:?}"))?;
        Ok(CgBuffers {
            exe,
            vals: vals_b,
            cols: cols_b,
            diag_inv: dinv_b,
            n_pad,
        })
    }

    /// Standalone SpMV (benches + residual checks). All padded to rung.
    pub fn spmv(&self, vals: &[f32], cols: &[i32], x: &[f32], n_pad: usize) -> Result<Vec<f32>> {
        let w = self.ell_width();
        assert_eq!(vals.len(), n_pad * w);
        assert_eq!(x.len(), n_pad);
        let exe = self.kind_exe("spmv", "n", n_pad)?;
        let lv = xla::Literal::vec1(vals)
            .reshape(&[n_pad as i64, w as i64])
            .map_err(|e| format_err!("{e:?}"))?;
        let lc = xla::Literal::vec1(cols)
            .reshape(&[n_pad as i64, w as i64])
            .map_err(|e| format_err!("{e:?}"))?;
        let lx = xla::Literal::vec1(x);
        let result = exe
            .execute::<xla::Literal>(&[lv, lc, lx])
            .map_err(|e| format_err!("spmv execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format_err!("{e:?}"))?;
        let parts = result.to_tuple().map_err(|e| format_err!("{e:?}"))?;
        parts[0].to_vec::<f32>().map_err(|e| format_err!("{e:?}"))
    }
}

/// Staged CG system: matrix buffers live on the PJRT device across
/// iterations; only state vectors cross the boundary per step.
pub struct CgBuffers {
    exe: Rc<xla::PjRtLoadedExecutable>,
    vals: xla::PjRtBuffer,
    cols: xla::PjRtBuffer,
    diag_inv: xla::PjRtBuffer,
    pub n_pad: usize,
}

impl CgBuffers {
    /// One Jacobi-PCG iteration: (x, r, p, rz) -> (x', r', p', rz', |r'|^2).
    pub fn step(&self, x: &[f32], r: &[f32], p: &[f32], rz: f32) -> Result<CgStepOut> {
        let n = self.n_pad;
        assert_eq!(x.len(), n);
        let client = self.exe.client();
        let dev = &client.devices()[0];
        let xb = client
            .buffer_from_host_buffer(x, &[n], Some(dev))
            .map_err(|e| format_err!("{e:?}"))?;
        let rb = client
            .buffer_from_host_buffer(r, &[n], Some(dev))
            .map_err(|e| format_err!("{e:?}"))?;
        let pb = client
            .buffer_from_host_buffer(p, &[n], Some(dev))
            .map_err(|e| format_err!("{e:?}"))?;
        let rzb = client
            .buffer_from_host_buffer(&[rz], &[], Some(dev))
            .map_err(|e| format_err!("{e:?}"))?;
        let result = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&[
                &self.vals,
                &self.cols,
                &self.diag_inv,
                &xb,
                &rb,
                &pb,
                &rzb,
            ])
            .map_err(|e| format_err!("cg_step execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format_err!("{e:?}"))?;
        let parts = result.to_tuple().map_err(|e| format_err!("{e:?}"))?;
        if parts.len() != 5 {
            return Err(format_err!("cg_step returned {} outputs", parts.len()));
        }
        Ok(CgStepOut {
            x: parts[0].to_vec::<f32>().map_err(|e| format_err!("{e:?}"))?,
            r: parts[1].to_vec::<f32>().map_err(|e| format_err!("{e:?}"))?,
            p: parts[2].to_vec::<f32>().map_err(|e| format_err!("{e:?}"))?,
            rz: parts[3]
                .get_first_element::<f32>()
                .map_err(|e| format_err!("{e:?}"))?,
            rnorm2: parts[4]
                .get_first_element::<f32>()
                .map_err(|e| format_err!("{e:?}"))?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        Runtime::open_default().ok()
    }

    #[test]
    fn elem_tet_unit_tet_matches_analytics() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        // the reference unit tet
        let coords: Vec<f32> = vec![
            0.0, 0.0, 0.0, //
            1.0, 0.0, 0.0, //
            0.0, 1.0, 0.0, //
            0.0, 0.0, 1.0,
        ];
        let fvals = vec![1.0f32; 4];
        let out = rt.elem_tet(&coords, &fvals, 1).unwrap();
        let vol = 1.0 / 6.0f32;
        // K row sums are zero; K[1][1] = vol * 1
        let k = &out.k;
        for i in 0..4 {
            let row: f32 = (0..4).map(|j| k[i * 4 + j]).sum();
            assert!(row.abs() < 1e-5, "row {i} sum {row}");
        }
        assert!((k[5] - vol).abs() < 1e-5);
        // M diag = vol/10, off-diag vol/20
        assert!((out.m[0] - vol / 10.0).abs() < 1e-6);
        assert!((out.m[1] - vol / 20.0).abs() < 1e-6);
        // b_i = vol/4
        for i in 0..4 {
            assert!((out.b[i] - vol / 4.0).abs() < 1e-6);
        }
    }

    #[test]
    fn elem_tet_padding_invisible() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        // n = 3 (not a rung): padding must not leak into outputs
        let mut coords = Vec::new();
        let mut fvals = Vec::new();
        for s in 1..=3 {
            let s = s as f32;
            coords.extend_from_slice(&[
                0.0, 0.0, 0.0, s, 0.0, 0.0, 0.0, s, 0.0, 0.0, 0.0, s,
            ]);
            fvals.extend_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        }
        let out = rt.elem_tet(&coords, &fvals, 3).unwrap();
        assert_eq!(out.k.len(), 3 * 16);
        assert_eq!(out.b.len(), 3 * 4);
        // scaled tets have volume s^3/6: mass sums = volume
        for (i, s) in [1.0f32, 2.0, 3.0].iter().enumerate() {
            let msum: f32 = out.m[i * 16..(i + 1) * 16].iter().sum();
            let vol = s * s * s / 6.0;
            assert!(
                (msum - vol).abs() < 1e-4 * vol.max(1.0),
                "elem {i}: mass sum {msum} vs vol {vol}"
            );
        }
    }

    #[test]
    fn executable_cache_reuses() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let coords = vec![0.0f32; 12];
        let fvals = vec![0.0f32; 4];
        rt.elem_tet(&coords, &fvals, 1).unwrap();
        let c1 = *rt.compile_count.borrow();
        rt.elem_tet(&coords, &fvals, 1).unwrap();
        assert_eq!(*rt.compile_count.borrow(), c1, "recompiled same rung");
    }

    #[test]
    fn spmv_identity() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let ladder = rt.cg_ladder();
        let n = ladder[0];
        let w = rt.ell_width();
        let mut vals = vec![0.0f32; n * w];
        let mut cols = vec![0i32; n * w];
        for i in 0..n {
            vals[i * w] = 1.0;
            cols[i * w] = i as i32;
        }
        let x: Vec<f32> = (0..n).map(|i| (i % 17) as f32).collect();
        let y = rt.spmv(&vals, &cols, &x, n).unwrap();
        assert_eq!(y.len(), n);
        for i in 0..n {
            assert_eq!(y[i], x[i]);
        }
    }

    #[test]
    fn cg_solves_small_laplacian() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let ladder = rt.cg_ladder();
        let n_pad = ladder[0];
        let w = rt.ell_width();
        let n = 100; // real rows; rest is padding
        let mut vals = vec![0.0f32; n_pad * w];
        let mut cols = vec![0i32; n_pad * w];
        let mut dinv = vec![0.0f32; n_pad];
        for i in 0..n {
            vals[i * w] = 2.0;
            cols[i * w] = i as i32;
            if i > 0 {
                vals[i * w + 1] = -1.0;
                cols[i * w + 1] = (i - 1) as i32;
            }
            if i + 1 < n {
                vals[i * w + 2] = -1.0;
                cols[i * w + 2] = (i + 1) as i32;
            }
            dinv[i] = 0.5;
        }
        let bufs = rt.stage_cg(&vals, &cols, &dinv, n_pad).unwrap();
        // rhs: A * ones
        let mut b = vec![0.0f32; n_pad];
        b[0] = 1.0;
        b[n - 1] = 1.0;
        let mut x = vec![0.0f32; n_pad];
        let mut r = b.clone();
        let mut p: Vec<f32> = r.iter().zip(&dinv).map(|(a, d)| a * d).collect();
        let mut rz: f32 = r.iter().zip(&p).map(|(a, b)| a * b).sum();
        for _ in 0..400 {
            let out = bufs.step(&x, &r, &p, rz).unwrap();
            x = out.x;
            r = out.r;
            p = out.p;
            rz = out.rz;
            if out.rnorm2 < 1e-10 {
                break;
            }
        }
        for i in 0..n {
            assert!((x[i] - 1.0).abs() < 1e-3, "x[{i}] = {}", x[i]);
        }
        // padding untouched
        for i in n..n_pad {
            assert_eq!(x[i], 0.0);
        }
    }
}
