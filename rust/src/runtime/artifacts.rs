//! Artifact manifest: plain-text index written by aot.py.
//!
//! Line format: `name kind file key=value...`, e.g.
//! `cg_step_n4096_w32 cg_step cg_step_n4096_w32.hlo.txt n=4096 w=32`.

use crate::format_err;
use crate::util::error::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: String,
    pub file: String,
    pub params: HashMap<String, usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let name = it
                .next()
                .ok_or_else(|| format_err!("manifest line {lineno}: missing name"))?
                .to_string();
            let kind = it
                .next()
                .ok_or_else(|| format_err!("manifest line {lineno}: missing kind"))?
                .to_string();
            let file = it
                .next()
                .ok_or_else(|| format_err!("manifest line {lineno}: missing file"))?
                .to_string();
            let mut params = HashMap::new();
            for kv in it {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| format_err!("manifest line {lineno}: bad param {kv}"))?;
                params.insert(k.to_string(), v.parse::<usize>()?);
            }
            entries.push(ArtifactEntry {
                name,
                kind,
                file,
                params,
            });
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    pub fn of_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a ArtifactEntry> + 'a {
        self.entries.iter().filter(move |e| e.kind == kind)
    }

    /// Sorted ladder of a parameter across entries of a kind.
    pub fn ladder(&self, kind: &str, param: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .of_kind(kind)
            .filter_map(|e| e.params.get(param).copied())
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Entry of `kind` whose `param` equals `value`.
    pub fn find(&self, kind: &str, param: &str, value: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.params.get(param) == Some(&value))
    }

    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

/// Locate the artifacts directory: $PHG_DLB_ARTIFACTS, then
/// ./artifacts, then the crate root's artifacts/.
pub fn find_artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("PHG_DLB_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.join("manifest.txt").exists() {
            return Some(p);
        }
    }
    for cand in [
        PathBuf::from("artifacts"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ] {
        if cand.join("manifest.txt").exists() {
            return Some(cand);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, content: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), content).unwrap();
    }

    #[test]
    fn parses_entries_and_ladders() {
        let dir = std::env::temp_dir().join("phg_dlb_manifest_test");
        write_manifest(
            &dir,
            "a elem_tet a.hlo.txt batch=2048\n\
             b elem_tet b.hlo.txt batch=16384\n\
             c cg_step c.hlo.txt n=4096 w=32\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 3);
        assert_eq!(m.ladder("elem_tet", "batch"), vec![2048, 16384]);
        assert_eq!(m.ladder("cg_step", "n"), vec![4096]);
        let e = m.find("cg_step", "n", 4096).unwrap();
        assert_eq!(e.params["w"], 32);
        assert!(m.find("cg_step", "n", 9999).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_malformed_lines() {
        let dir = std::env::temp_dir().join("phg_dlb_manifest_bad");
        write_manifest(&dir, "only_name\n");
        assert!(Manifest::load(&dir).is_err());
        write_manifest(&dir, "a kind f.hlo badparam\n");
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn skips_comments_and_blanks() {
        let dir = std::env::temp_dir().join("phg_dlb_manifest_comments");
        write_manifest(&dir, "# header\n\na spmv a.hlo.txt n=8\n");
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        // integration-ish: when `make artifacts` has run, the real
        // manifest must parse and contain the expected kinds
        if let Some(dir) = find_artifacts_dir() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.ladder("elem_tet", "batch").is_empty());
            assert!(!m.ladder("cg_step", "n").is_empty());
            assert!(!m.ladder("spmv", "n").is_empty());
        }
    }
}
