//! PJRT runtime: load the AOT artifacts (HLO text emitted once by
//! `python/compile/aot.py`) and execute them from the Rust hot path.
//!
//! One compiled executable per model variant: the artifact manifest
//! lists a ladder of fixed shapes per kernel; callers pad up to the
//! next rung ([`Ladder`]). Executables compile lazily on first use and
//! are cached for the life of the runtime.
//!
//! Python never runs at request time: after `make artifacts` the Rust
//! binary is self-contained.

mod artifacts;
#[cfg(feature = "pjrt")]
mod client;
#[cfg(not(feature = "pjrt"))]
mod client_stub;

pub use artifacts::{find_artifacts_dir, ArtifactEntry, Manifest};
#[cfg(feature = "pjrt")]
pub use client::{CgBuffers, CgStepOut, ElemBatchOut, Runtime};
#[cfg(not(feature = "pjrt"))]
pub use client_stub::{CgBuffers, CgStepOut, ElemBatchOut, Runtime};

/// Pick the smallest rung >= `n` from a sorted ladder.
pub fn next_rung(ladder: &[usize], n: usize) -> Option<usize> {
    ladder.iter().copied().find(|&r| r >= n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_rung_picks_smallest_fit() {
        let ladder = [4096usize, 16384, 65536];
        assert_eq!(next_rung(&ladder, 1), Some(4096));
        assert_eq!(next_rung(&ladder, 4096), Some(4096));
        assert_eq!(next_rung(&ladder, 4097), Some(16384));
        assert_eq!(next_rung(&ladder, 65536), Some(65536));
        assert_eq!(next_rung(&ladder, 65537), None);
    }
}
