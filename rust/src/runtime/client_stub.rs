//! Stub PJRT client, compiled when the `pjrt` cargo feature is off
//! (the default). The real client (`client.rs`) binds the unvendored
//! `xla` crate; this stub carries the identical public surface but its
//! constructors always error, so [`Runtime`] can never be obtained and
//! every caller takes its native-engine fallback path
//! (`Runtime::open_default().ok()` is `None` everywhere).

use super::artifacts::Manifest;
use crate::util::error::{Error, Result};
use std::cell::RefCell;
use std::path::Path;

const DISABLED: &str = "PJRT runtime disabled: built without the `pjrt` cargo feature \
     (the `xla` crate is not vendored); native engines are used instead";

/// Unconstructible placeholder for the PJRT runtime.
pub struct Runtime {
    manifest: Manifest,
    /// executables compiled so far (always 0 in the stub)
    pub compile_count: RefCell<usize>,
}

/// Batched element matrices result (flattened f32, row-major).
#[derive(Debug, Clone)]
pub struct ElemBatchOut {
    /// (B,4,4) stiffness
    pub k: Vec<f32>,
    /// (B,4,4) mass
    pub m: Vec<f32>,
    /// (B,4) load
    pub b: Vec<f32>,
}

/// One CG iteration's outputs.
#[derive(Debug, Clone)]
pub struct CgStepOut {
    pub x: Vec<f32>,
    pub r: Vec<f32>,
    pub p: Vec<f32>,
    pub rz: f32,
    pub rnorm2: f32,
}

impl Runtime {
    pub fn new(_dir: &Path) -> Result<Self> {
        Err(Error::msg(DISABLED))
    }

    pub fn open_default() -> Result<Self> {
        Err(Error::msg(DISABLED))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn elem_ladder(&self) -> Vec<usize> {
        self.manifest.ladder("elem_tet", "batch")
    }

    pub fn cg_ladder(&self) -> Vec<usize> {
        self.manifest.ladder("cg_step", "n")
    }

    pub fn ell_width(&self) -> usize {
        32
    }

    pub fn elem_tet(&self, _coords: &[f32], _fvals: &[f32], _n: usize) -> Result<ElemBatchOut> {
        Err(Error::msg(DISABLED))
    }

    pub fn stage_cg(
        &self,
        _vals: &[f32],
        _cols: &[i32],
        _diag_inv: &[f32],
        _n_pad: usize,
    ) -> Result<CgBuffers> {
        Err(Error::msg(DISABLED))
    }

    pub fn spmv(&self, _vals: &[f32], _cols: &[i32], _x: &[f32], _n_pad: usize) -> Result<Vec<f32>> {
        Err(Error::msg(DISABLED))
    }
}

/// Placeholder for a staged CG system (never constructed).
pub struct CgBuffers {
    pub n_pad: usize,
}

impl CgBuffers {
    pub fn step(&self, _x: &[f32], _r: &[f32], _p: &[f32], _rz: f32) -> Result<CgStepOut> {
        Err(Error::msg(DISABLED))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_is_unobtainable() {
        let err = Runtime::open_default().unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
        assert!(Runtime::new(Path::new("/nonexistent")).is_err());
    }
}
