//! P1 FEM assembly: element matrices -> global CSR stiffness K, mass M
//! and load vector b.
//!
//! Two element-matrix engines with identical math:
//! * **PJRT** -- batched through the `elem_tet` artifact (the L1 Pallas
//!   kernel), f32; the production hot path.
//! * **native** -- straight f64 Rust, used as the correctness oracle
//!   and as fallback when artifacts are absent.

use super::csr::Csr;
use super::dof::DofMap;
use crate::geometry::Vec3;
use crate::mesh::topology::LeafTopology;
use crate::mesh::TetMesh;
use crate::runtime::Runtime;
use crate::util::sort::radix_sort_by_key;

/// Element stiffness/mass/load in f64 (native engine; mirrors
/// python/compile/kernels/elem_tet.py exactly).
pub fn elem_matrices(c: &[Vec3; 4], f: &[f64; 4]) -> ([f64; 16], [f64; 16], [f64; 4]) {
    let d1 = c[1] - c[0];
    let d2 = c[2] - c[0];
    let d3 = c[3] - c[0];
    let c23 = d2.cross(d3);
    let c31 = d3.cross(d1);
    let c12 = d1.cross(d2);
    let det = d1.dot(c23);
    let mut k = [0.0; 16];
    let mut m = [0.0; 16];
    let mut b = [0.0; 4];
    if det.abs() < 1e-300 {
        return (k, m, b);
    }
    let vol = det.abs() / 6.0;
    let g1 = c23 / det;
    let g2 = c31 / det;
    let g3 = c12 / det;
    let g0 = -(g1 + g2 + g3);
    let g = [g0, g1, g2, g3];
    for i in 0..4 {
        for j in 0..4 {
            k[i * 4 + j] = vol * g[i].dot(g[j]);
            m[i * 4 + j] = vol / 20.0 * if i == j { 2.0 } else { 1.0 };
        }
    }
    for i in 0..4 {
        for j in 0..4 {
            b[i] += m[i * 4 + j] * f[j];
        }
    }
    (k, m, b)
}

/// Assembled global system (no boundary conditions applied yet).
#[derive(Debug, Clone)]
pub struct Assembled {
    pub k: Csr,
    pub m: Csr,
    pub b: Vec<f64>,
}

/// The cached, reusable sparsity pattern of the P1 system on one
/// (mesh, topo, dof) triple. K and M share one skeleton; assembly
/// through the pattern scatters element contributions into `vals` by
/// precomputed slot indices instead of re-sorting `nel*16` triplets
/// per solve (DESIGN.md §11). Valid exactly while
/// [`TetMesh::revision`] is unchanged; ownership changes do not
/// invalidate it.
#[derive(Debug, Clone)]
pub struct AssemblyPattern {
    pub n_dofs: usize,
    /// Revision of the mesh this pattern was built from.
    pub mesh_revision: u64,
    /// Per leaf, in `topo.leaves` order: its 4 global dofs.
    pub elem_dofs: Vec<[u32; 4]>,
    /// Shared K/M CSR skeleton.
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    /// `nel*16` scatter slots: entry `e*16 + i*4 + j` is the `vals`
    /// index receiving element `e`'s local `(i, j)` contribution.
    pub slots: Vec<u32>,
}

impl AssemblyPattern {
    /// One stable radix sort over the `nel*16` (row, col) keys yields
    /// both the skeleton and the slot of every element contribution --
    /// versus *two* full sorts (K and M) per assembly on the triplet
    /// path.
    pub fn build(mesh: &TetMesh, topo: &LeafTopology, dof: &DofMap) -> Self {
        let nel = topo.leaves.len();
        let n = dof.n_dofs;
        let elem_dofs: Vec<[u32; 4]> = topo
            .leaves
            .iter()
            .map(|&id| {
                let v = mesh.verts_of(id);
                [
                    dof.dof_of_vertex[v[0] as usize],
                    dof.dof_of_vertex[v[1] as usize],
                    dof.dof_of_vertex[v[2] as usize],
                    dof.dof_of_vertex[v[3] as usize],
                ]
            })
            .collect();
        let mut keyed: Vec<(u64, u32)> = Vec::with_capacity(nel * 16);
        for (e, dofs) in elem_dofs.iter().enumerate() {
            for i in 0..4 {
                for j in 0..4 {
                    keyed.push((
                        ((dofs[i] as u64) << 32) | dofs[j] as u64,
                        (e * 16 + i * 4 + j) as u32,
                    ));
                }
            }
        }
        radix_sort_by_key(&mut keyed);
        let mut row_ptr = vec![0u32; n + 1];
        let mut col_idx: Vec<u32> = Vec::new();
        let mut slots = vec![0u32; nel * 16];
        let mut prev: Option<u64> = None;
        for &(key, payload) in &keyed {
            if prev != Some(key) {
                col_idx.push(key as u32);
                row_ptr[(key >> 32) as usize + 1] += 1;
                prev = Some(key);
            }
            slots[payload as usize] = (col_idx.len() - 1) as u32;
        }
        for r in 0..n {
            row_ptr[r + 1] += row_ptr[r];
        }
        Self {
            n_dofs: n,
            mesh_revision: mesh.revision(),
            elem_dofs,
            row_ptr,
            col_idx,
            slots,
        }
    }

    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    pub fn n_elems(&self) -> usize {
        self.elem_dofs.len()
    }

    /// An all-zero matrix over this pattern's skeleton, ready to be
    /// filled by slot scatter.
    pub fn zero_csr(&self) -> Csr {
        Csr {
            n: self.n_dofs,
            row_ptr: self.row_ptr.clone(),
            col_idx: self.col_idx.clone(),
            vals: vec![0.0; self.nnz()],
        }
    }

    /// Is this pattern still valid for `(mesh, dof)`?
    pub fn matches(&self, mesh: &TetMesh, dof: &DofMap) -> bool {
        self.mesh_revision == mesh.revision() && self.n_dofs == dof.n_dofs
    }
}

/// Assemble K, M, b through a prebuilt pattern: bitwise identical to
/// [`assemble`] with the native engine (the pattern scatter folds each
/// slot's contributions in the same (element, i, j) order as
/// `Csr::from_triplets`' stable duplicate fold), without any sorting.
pub fn assemble_with_pattern(
    mesh: &TetMesh,
    topo: &LeafTopology,
    dof: &DofMap,
    source: &[f64],
    pat: &AssemblyPattern,
) -> Assembled {
    assert_eq!(source.len(), dof.n_dofs);
    assert_eq!(pat.n_elems(), topo.leaves.len(), "stale pattern");
    assert_eq!(pat.n_dofs, dof.n_dofs, "stale pattern");
    let mut k = pat.zero_csr();
    let mut m = pat.zero_csr();
    let mut b = vec![0.0f64; dof.n_dofs];
    for e in 0..pat.n_elems() {
        let c = mesh.elem_coords(topo.leaves[e]);
        let dofs = &pat.elem_dofs[e];
        let f = [
            source[dofs[0] as usize],
            source[dofs[1] as usize],
            source[dofs[2] as usize],
            source[dofs[3] as usize],
        ];
        let (ke, me, be) = elem_matrices(&c, &f);
        for i in 0..4 {
            b[dofs[i] as usize] += be[i];
            for j in 0..4 {
                let s = pat.slots[e * 16 + i * 4 + j] as usize;
                k.vals[s] += ke[i * 4 + j];
                m.vals[s] += me[i * 4 + j];
            }
        }
    }
    Assembled { k, m, b }
}

/// Assemble K, M, b over the current leaves. `source` is evaluated at
/// vertices (P1 interpolation of f, matching the L2 graph).
/// When `rt` is Some, element matrices come from the PJRT artifact.
pub fn assemble(
    mesh: &TetMesh,
    topo: &LeafTopology,
    dof: &DofMap,
    source: &[f64],
    rt: Option<&Runtime>,
) -> Assembled {
    assert_eq!(source.len(), dof.n_dofs);
    let nel = topo.leaves.len();
    let n = dof.n_dofs;
    let mut kt: Vec<(u32, u32, f64)> = Vec::with_capacity(nel * 16);
    let mut mt: Vec<(u32, u32, f64)> = Vec::with_capacity(nel * 16);
    let mut b = vec![0.0f64; n];

    // per-element dof indices
    let elem_dofs: Vec<[u32; 4]> = topo
        .leaves
        .iter()
        .map(|&id| {
            let v = mesh.verts_of(id);
            [
                dof.dof_of_vertex[v[0] as usize],
                dof.dof_of_vertex[v[1] as usize],
                dof.dof_of_vertex[v[2] as usize],
                dof.dof_of_vertex[v[3] as usize],
            ]
        })
        .collect();

    let scatter = |kt: &mut Vec<(u32, u32, f64)>,
                   mt: &mut Vec<(u32, u32, f64)>,
                   b: &mut Vec<f64>,
                   e: usize,
                   ke: &[f64],
                   me: &[f64],
                   be: &[f64]| {
        let dofs = &elem_dofs[e];
        for i in 0..4 {
            b[dofs[i] as usize] += be[i];
            for j in 0..4 {
                kt.push((dofs[i], dofs[j], ke[i * 4 + j]));
                mt.push((dofs[i], dofs[j], me[i * 4 + j]));
            }
        }
    };

    let mut used_pjrt = false;
    if let Some(rt) = rt {
        // batched artifact path, chunked by the largest ladder rung
        let ladder = rt.elem_ladder();
        if let Some(&max_rung) = ladder.last() {
            used_pjrt = true;
            let mut e0 = 0usize;
            while e0 < nel {
                // greedy-down chunking (#Perf): take the largest rung
                // that fits the remainder so padding waste is bounded
                // by one sub-rung instead of rung/2 of dead elements
                let remaining = nel - e0;
                let chunk = ladder
                    .iter()
                    .rev()
                    .find(|&&r| r <= remaining)
                    .copied()
                    .unwrap_or(remaining)
                    .min(max_rung);
                let mut coords = Vec::with_capacity(chunk * 12);
                let mut fvals = Vec::with_capacity(chunk * 4);
                for e in e0..e0 + chunk {
                    let c = mesh.elem_coords(topo.leaves[e]);
                    for p in &c {
                        coords.extend_from_slice(&[p.x as f32, p.y as f32, p.z as f32]);
                    }
                    for d in &elem_dofs[e] {
                        fvals.push(source[*d as usize] as f32);
                    }
                }
                let out = rt
                    .elem_tet(&coords, &fvals, chunk)
                    .expect("elem_tet artifact execution failed");
                // scatter straight from the f32 buffers (#Perf: the
                // per-element Vec<f64> temporaries tripled allocation
                // pressure in this loop)
                for e in 0..chunk {
                    let dofs = &elem_dofs[e0 + e];
                    let ko = e * 16;
                    let bo = e * 4;
                    for i in 0..4 {
                        b[dofs[i] as usize] += out.b[bo + i] as f64;
                        for j in 0..4 {
                            kt.push((dofs[i], dofs[j], out.k[ko + i * 4 + j] as f64));
                            mt.push((dofs[i], dofs[j], out.m[ko + i * 4 + j] as f64));
                        }
                    }
                }
                e0 += chunk;
            }
        }
    }
    if !used_pjrt {
        for e in 0..nel {
            let c = mesh.elem_coords(topo.leaves[e]);
            let dofs = &elem_dofs[e];
            let f = [
                source[dofs[0] as usize],
                source[dofs[1] as usize],
                source[dofs[2] as usize],
                source[dofs[3] as usize],
            ];
            let (ke, me, be) = elem_matrices(&c, &f);
            scatter(&mut kt, &mut mt, &mut b, e, &ke, &me, &be);
        }
    }

    Assembled {
        k: Csr::from_triplets(n, kt),
        m: Csr::from_triplets(n, mt),
        b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generator::cube_mesh;

    fn setup() -> (TetMesh, LeafTopology, DofMap) {
        let mut m = cube_mesh(2);
        m.refine(&m.leaves_unordered());
        let topo = LeafTopology::build(&m);
        let dof = DofMap::build(&m, &topo);
        (m, topo, dof)
    }

    #[test]
    fn stiffness_kernel_contains_constants() {
        let (m, topo, dof) = setup();
        let src = vec![0.0; dof.n_dofs];
        let a = assemble(&m, &topo, &dof, &src, None);
        // K * 1 = 0
        let ones = vec![1.0; dof.n_dofs];
        let mut y = vec![0.0; dof.n_dofs];
        a.k.spmv(&ones, &mut y);
        for v in y {
            assert!(v.abs() < 1e-10, "K*1 component {v}");
        }
    }

    #[test]
    fn mass_total_is_volume() {
        let (m, topo, dof) = setup();
        let src = vec![0.0; dof.n_dofs];
        let a = assemble(&m, &topo, &dof, &src, None);
        let ones = vec![1.0; dof.n_dofs];
        let mut y = vec![0.0; dof.n_dofs];
        a.m.spmv(&ones, &mut y);
        let total: f64 = y.iter().sum();
        assert!((total - 1.0).abs() < 1e-10, "1' M 1 = {total}");
    }

    #[test]
    fn load_is_mass_times_source() {
        let (m, topo, dof) = setup();
        let src = dof.eval_at_dofs(&m, |p| p.x + p.y * p.z);
        let a = assemble(&m, &topo, &dof, &src, None);
        let mut y = vec![0.0; dof.n_dofs];
        a.m.spmv(&src, &mut y);
        for (bi, yi) in a.b.iter().zip(&y) {
            assert!((bi - yi).abs() < 1e-10);
        }
    }

    #[test]
    fn stiffness_energy_of_linear_field() {
        // u = x: u' K u = int |grad u|^2 = volume = 1
        let (m, topo, dof) = setup();
        let src = vec![0.0; dof.n_dofs];
        let a = assemble(&m, &topo, &dof, &src, None);
        let u = dof.eval_at_dofs(&m, |p| p.x);
        let mut y = vec![0.0; dof.n_dofs];
        a.k.spmv(&u, &mut y);
        let energy: f64 = u.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((energy - 1.0).abs() < 1e-10, "energy {energy}");
    }

    #[test]
    fn pattern_assembly_is_bitwise_identical_to_triplets() {
        let (m, topo, dof) = setup();
        let src = dof.eval_at_dofs(&m, |p| (3.0 * p.x).sin() - p.z);
        let trip = assemble(&m, &topo, &dof, &src, None);
        let pat = AssemblyPattern::build(&m, &topo, &dof);
        assert!(pat.matches(&m, &dof));
        let fill = assemble_with_pattern(&m, &topo, &dof, &src, &pat);
        assert_eq!(trip.k.row_ptr, fill.k.row_ptr);
        assert_eq!(trip.k.col_idx, fill.k.col_idx);
        for (a, b) in trip.k.vals.iter().zip(&fill.k.vals) {
            assert_eq!(a.to_bits(), b.to_bits(), "K differs");
        }
        for (a, b) in trip.m.vals.iter().zip(&fill.m.vals) {
            assert_eq!(a.to_bits(), b.to_bits(), "M differs");
        }
        for (a, b) in trip.b.iter().zip(&fill.b) {
            assert_eq!(a.to_bits(), b.to_bits(), "b differs");
        }
    }

    #[test]
    fn pattern_survives_source_changes_but_not_refinement() {
        let (mut m, topo, dof) = setup();
        let pat = AssemblyPattern::build(&m, &topo, &dof);
        // same structure, different source: reuse is valid
        let s1 = dof.eval_at_dofs(&m, |p| p.x);
        let s2 = dof.eval_at_dofs(&m, |p| p.y * p.y);
        let a1 = assemble_with_pattern(&m, &topo, &dof, &s1, &pat);
        let a2 = assemble_with_pattern(&m, &topo, &dof, &s2, &pat);
        assert_eq!(a1.k.nnz(), a2.k.nnz());
        for (x, y) in a1.k.vals.iter().zip(&a2.k.vals) {
            assert_eq!(x.to_bits(), y.to_bits(), "K must not depend on source");
        }
        // structural change invalidates
        m.refine(&m.leaves_unordered());
        assert!(!pat.matches(&m, &dof));
    }

    #[test]
    fn pjrt_assembly_matches_native() {
        let Ok(rt) = Runtime::open_default() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (m, topo, dof) = setup();
        let src = dof.eval_at_dofs(&m, |p| (p.x * 7.0).sin());
        let native = assemble(&m, &topo, &dof, &src, None);
        let pjrt = assemble(&m, &topo, &dof, &src, Some(&rt));
        assert_eq!(native.k.nnz(), pjrt.k.nnz());
        let mut max_rel = 0.0f64;
        for (a, b) in native.k.vals.iter().zip(&pjrt.k.vals) {
            let rel = (a - b).abs() / a.abs().max(1e-3);
            max_rel = max_rel.max(rel);
        }
        assert!(max_rel < 5e-4, "K mismatch rel {max_rel}");
        for (a, b) in native.b.iter().zip(&pjrt.b) {
            assert!((a - b).abs() < 1e-5, "b mismatch {a} vs {b}");
        }
    }
}
