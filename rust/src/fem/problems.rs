//! The paper's two test problems.
//!
//! **Example 3.1** (Helmholtz): -lap u + u = f on the cylinder with
//! Dirichlet data, exact solution u = cos(2 pi x) cos(2 pi y) cos(2 pi z),
//! so f = (12 pi^2 + 1) u. Smooth -> near-uniform refinement.
//!
//! **Example 3.2** (parabolic): u_t - lap u = f on (0,1)^3 x (0,1],
//! exact solution a narrow moving peak circling in the x-y plane near
//! z = 1: the mesh must refine around the peak and coarsen behind it
//! every step. f is derived from the exact solution by high-order
//! finite differences (method of manufactured solutions; the paper
//! does the same analytically).

use super::assemble::{assemble, Assembled};
use super::csr::Csr;
use super::dof::DofMap;
use super::solver::{solve, SolveStats, SolverOpts};
use crate::geometry::Vec3;
use crate::mesh::topology::LeafTopology;
use crate::mesh::TetMesh;
use crate::runtime::Runtime;

// ---------- Example 3.1: Helmholtz ----------

pub fn helmholtz_exact(p: Vec3) -> f64 {
    let t = 2.0 * std::f64::consts::PI;
    (t * p.x).cos() * (t * p.y).cos() * (t * p.z).cos()
}

pub fn helmholtz_source(p: Vec3) -> f64 {
    let pi2 = std::f64::consts::PI * std::f64::consts::PI;
    (12.0 * pi2 + 1.0) * helmholtz_exact(p)
}

/// Result of one Helmholtz solve on the current mesh.
#[derive(Debug, Clone)]
pub struct HelmholtzSolution {
    /// solution per dof
    pub u: Vec<f64>,
    pub stats: SolveStats,
    pub n_dofs: usize,
    /// max vertex error against the exact solution
    pub max_error: f64,
    /// sqrt(e' M e): the L2-projected error
    pub l2_error: f64,
}

/// Assemble A = K + M (the Helmholtz form), apply Dirichlet data from
/// the exact solution, solve, and report errors. `u0` optionally warm
/// starts the solver.
pub fn solve_helmholtz(
    mesh: &TetMesh,
    topo: &LeafTopology,
    dof: &DofMap,
    rt: Option<&Runtime>,
    opts: &SolverOpts,
    u0: Option<&[f64]>,
) -> HelmholtzSolution {
    let source = dof.eval_at_dofs(mesh, helmholtz_source);
    let Assembled { k, m, b } = assemble(mesh, topo, dof, &source, rt);
    let mut a = Csr::linear_combination(1.0, &k, 1.0, &m);
    let g = dof.eval_at_dofs(mesh, helmholtz_exact);
    let bc: Vec<f64> = g
        .iter()
        .zip(&dof.on_boundary)
        .map(|(&v, &ob)| if ob { v } else { 0.0 })
        .collect();
    let mut rhs = b;
    a.apply_dirichlet(&dof.on_boundary, &bc, &mut rhs);

    let mut u = match u0 {
        Some(w) if w.len() == dof.n_dofs => w.to_vec(),
        _ => vec![0.0; dof.n_dofs],
    };
    // boundary dofs must start at their fixed values for warm starts
    for (i, &ob) in dof.on_boundary.iter().enumerate() {
        if ob {
            u[i] = bc[i];
        }
    }
    let stats = solve(rt, &a, &rhs, &mut u, opts);

    let (max_error, l2_error) = errors_against(mesh, dof, &u, &m, helmholtz_exact);
    HelmholtzSolution {
        u,
        stats,
        n_dofs: dof.n_dofs,
        max_error,
        l2_error,
    }
}

/// (max vertex error, sqrt(e'Me)) against an exact solution.
pub fn errors_against(
    mesh: &TetMesh,
    dof: &DofMap,
    u: &[f64],
    mass: &Csr,
    exact: impl Fn(Vec3) -> f64,
) -> (f64, f64) {
    let ex = dof.eval_at_dofs(mesh, exact);
    let e: Vec<f64> = u.iter().zip(&ex).map(|(a, b)| a - b).collect();
    let max_error = e.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    let mut me = vec![0.0; e.len()];
    mass.spmv(&e, &mut me);
    let l2: f64 = e.iter().zip(&me).map(|(a, b)| a * b).sum::<f64>().max(0.0);
    (max_error, l2.sqrt())
}

// ---------- Example 3.2: moving-peak parabolic problem ----------

/// Center of the moving peak at time `t` (the paper's trajectory:
/// a circle of radius 2/5 around (1/2, 1/2), at z = 1).
pub fn peak_center(t: f64) -> Vec3 {
    let w = 8.0 * std::f64::consts::PI * t;
    Vec3::new(0.5 + 0.4 * w.sin(), 0.5 + 0.4 * w.cos(), 1.0)
}

/// The paper's exact solution:
/// u = exp( (25*((x-cx)^2 + (y-cy)^2 + (z-1)^2) + 0.9)^-1 - 2.5 ).
pub fn parabolic_exact(p: Vec3, t: f64) -> f64 {
    let c = peak_center(t);
    let d2 = (p.x - c.x).powi(2) + (p.y - c.y).powi(2) + (p.z - c.z).powi(2);
    (1.0 / (25.0 * d2 + 0.9) - 2.5).exp()
}

/// f = u_t - lap u by 4th-order central differences (manufactured
/// source; h chosen so FD error ~1e-9 is far below discretization
/// error).
pub fn parabolic_source(p: Vec3, t: f64) -> f64 {
    let h = 1e-3;
    let ut = (parabolic_exact(p, t + h) - parabolic_exact(p, t - h)) / (2.0 * h);
    let mut lap = 0.0;
    let hs = 1e-3;
    let u0 = parabolic_exact(p, t);
    for axis in 0..3 {
        let mut dp = p;
        let mut dm = p;
        match axis {
            0 => {
                dp.x += hs;
                dm.x -= hs;
            }
            1 => {
                dp.y += hs;
                dm.y -= hs;
            }
            _ => {
                dp.z += hs;
                dm.z -= hs;
            }
        }
        lap += (parabolic_exact(dp, t) - 2.0 * u0 + parabolic_exact(dm, t)) / (hs * hs);
    }
    ut - lap
}

/// One implicit-Euler step: (M/dt + K) u^{n+1} = M (u^n/dt + f^{n+1}),
/// Dirichlet from the exact solution at t^{n+1}.
pub struct ParabolicStep {
    pub u: Vec<f64>,
    pub stats: SolveStats,
    pub max_error: f64,
    pub l2_error: f64,
}

#[allow(clippy::too_many_arguments)]
pub fn parabolic_step(
    mesh: &TetMesh,
    topo: &LeafTopology,
    dof: &DofMap,
    rt: Option<&Runtime>,
    opts: &SolverOpts,
    u_prev: &[f64],
    t_next: f64,
    dt: f64,
) -> ParabolicStep {
    assert_eq!(u_prev.len(), dof.n_dofs);
    let source = dof.eval_at_dofs(mesh, |p| parabolic_source(p, t_next));
    let Assembled { k, m, b } = assemble(mesh, topo, dof, &source, rt);
    // A = M/dt + K ; rhs = M u_prev / dt + b  (b = M f already)
    let mut a = Csr::linear_combination(1.0, &k, 1.0 / dt, &m);
    let mut rhs = vec![0.0; dof.n_dofs];
    m.spmv(u_prev, &mut rhs);
    for (r, bv) in rhs.iter_mut().zip(&b) {
        *r = *r / dt + bv;
    }
    let bc: Vec<f64> = dof
        .on_boundary
        .iter()
        .enumerate()
        .map(|(i, &ob)| {
            if ob {
                parabolic_exact(
                    mesh.vertices[dof.vertex_of_dof[i] as usize],
                    t_next,
                )
            } else {
                0.0
            }
        })
        .collect();
    a.apply_dirichlet(&dof.on_boundary, &bc, &mut rhs);

    let mut u = u_prev.to_vec(); // warm start from previous time level
    for (i, &ob) in dof.on_boundary.iter().enumerate() {
        if ob {
            u[i] = bc[i];
        }
    }
    let stats = solve(rt, &a, &rhs, &mut u, opts);
    let (max_error, l2_error) = errors_against(mesh, dof, &u, &m, |p| parabolic_exact(p, t_next));
    ParabolicStep {
        u,
        stats,
        max_error,
        l2_error,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generator::cube_mesh;

    fn setup(refines: usize) -> (TetMesh, LeafTopology, DofMap) {
        let mut m = cube_mesh(2);
        for _ in 0..refines {
            m.refine(&m.leaves_unordered());
        }
        let topo = LeafTopology::build(&m);
        let dof = DofMap::build(&m, &topo);
        (m, topo, dof)
    }

    #[test]
    fn helmholtz_error_decreases_under_refinement() {
        let mut errs = Vec::new();
        for refines in [0usize, 3] {
            let (m, topo, dof) = setup(refines);
            let sol = solve_helmholtz(&m, &topo, &dof, None, &SolverOpts::default(), None);
            assert!(sol.stats.rel_residual < 1e-5);
            errs.push(sol.l2_error);
        }
        assert!(
            errs[1] < 0.55 * errs[0],
            "no convergence: {errs:?} (expected ~4x drop per full refine)"
        );
    }

    #[test]
    fn helmholtz_exact_satisfies_equation() {
        // spot check f = (12 pi^2 + 1) u really is -lap u + u via FD
        let p = Vec3::new(0.21, 0.37, 0.53);
        let h = 1e-4;
        let mut lap = 0.0;
        for axis in 0..3 {
            let mut dp = p;
            let mut dm = p;
            match axis {
                0 => {
                    dp.x += h;
                    dm.x -= h;
                }
                1 => {
                    dp.y += h;
                    dm.y -= h;
                }
                _ => {
                    dp.z += h;
                    dm.z -= h;
                }
            }
            lap += (helmholtz_exact(dp) - 2.0 * helmholtz_exact(p) + helmholtz_exact(dm))
                / (h * h);
        }
        let f = -lap + helmholtz_exact(p);
        assert!(
            (f - helmholtz_source(p)).abs() < 1e-3,
            "{f} vs {}",
            helmholtz_source(p)
        );
    }

    #[test]
    fn parabolic_peak_moves() {
        let c0 = peak_center(0.0);
        let c1 = peak_center(0.125); // half revolution at 8 pi t
        assert!((c0 - c1).norm() > 0.5);
        // peak value is at the center
        let t = 0.3;
        let c = peak_center(t);
        let at_peak = parabolic_exact(c, t);
        let off_peak = parabolic_exact(Vec3::new(0.0, 0.0, 0.0), t);
        // the peak's full dynamic range is exp(1/0.9) ~ 3x its floor
        assert!(at_peak > 2.5 * off_peak, "{at_peak} vs {off_peak}");
    }

    #[test]
    fn parabolic_step_tracks_exact_solution() {
        let (m, topo, dof) = setup(2);
        let dt = 1e-3;
        let mut u = dof.eval_at_dofs(&m, |p| parabolic_exact(p, 0.0));
        let mut last = ParabolicStep {
            u: u.clone(),
            stats: SolveStats {
                iterations: 0,
                rel_residual: 0.0,
                used_pjrt: false,
            },
            max_error: 0.0,
            l2_error: 0.0,
        };
        for n in 1..=3 {
            last = parabolic_step(
                &m,
                &topo,
                &dof,
                None,
                &SolverOpts::default(),
                &u,
                n as f64 * dt,
                dt,
            );
            u = last.u.clone();
        }
        // coarse mesh: just demand the solution stays near the exact one
        assert!(
            last.max_error < 0.05,
            "max error {} after 3 steps",
            last.max_error
        );
        assert!(last.stats.rel_residual < 1e-5);
    }

    #[test]
    fn manufactured_source_consistent() {
        // integrate one long step on a fine-ish mesh: error bounded by
        // O(dt) + O(h^2); with dt = 0.002 expect small errors
        let (m, topo, dof) = setup(2);
        let dt = 2e-3;
        let u0 = dof.eval_at_dofs(&m, |p| parabolic_exact(p, 0.0));
        let s = parabolic_step(
            &m,
            &topo,
            &dof,
            None,
            &SolverOpts::default(),
            &u0,
            dt,
            dt,
        );
        assert!(s.max_error < 0.03, "max err {}", s.max_error);
    }
}
