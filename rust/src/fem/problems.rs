//! Problem definitions: the reusable FEM solves the scenarios
//! ([`crate::scenario`]) are built from.
//!
//! * [`solve_stationary`] -- one solve of the reaction-diffusion form
//!   -lap u + u = f with Dirichlet data and errors taken from a
//!   manufactured exact solution. [`solve_helmholtz`] instantiates it
//!   with the paper's example 3.1: exact solution
//!   u = cos(2 pi x) cos(2 pi y) cos(2 pi z), so f = (12 pi^2 + 1) u.
//!   Smooth -> near-uniform refinement.
//! * [`parabolic_step`] -- one implicit-Euler step of u_t - lap u = f
//!   whose exact solution is a narrow moving peak carried along a
//!   trajectory `center: fn(t) -> Vec3`; f is derived from the exact
//!   solution by high-order finite differences (method of
//!   manufactured solutions; the paper does the same analytically).
//!   [`peak_center`] is the paper's example 3.2 trajectory (a circle
//!   near z = 1); [`oscillating_center`] sweeps back and forth
//!   through the cube center, revisiting old regions.

use super::assemble::Assembled;
use super::csr::Csr;
use super::dof::DofMap;
use super::solver::{SolveStats, SolverOpts};
use crate::exec::{Executor, RankPlan};
use crate::geometry::Vec3;
use crate::mesh::topology::LeafTopology;
use crate::mesh::TetMesh;
use crate::runtime::Runtime;

// ---------- Example 3.1: Helmholtz ----------

pub fn helmholtz_exact(p: Vec3) -> f64 {
    let t = 2.0 * std::f64::consts::PI;
    (t * p.x).cos() * (t * p.y).cos() * (t * p.z).cos()
}

pub fn helmholtz_source(p: Vec3) -> f64 {
    let pi2 = std::f64::consts::PI * std::f64::consts::PI;
    (12.0 * pi2 + 1.0) * helmholtz_exact(p)
}

/// Result of one stationary solve on the current mesh.
#[derive(Debug, Clone)]
pub struct StationarySolution {
    /// solution per dof
    pub u: Vec<f64>,
    pub stats: SolveStats,
    pub n_dofs: usize,
    /// max vertex error against the exact solution
    pub max_error: f64,
    /// sqrt(e' M e): the L2-projected error
    pub l2_error: f64,
}

/// Assemble A = K + M (the reaction-diffusion form -lap u + u = f),
/// apply Dirichlet data from the manufactured `exact` solution, solve,
/// and report errors against it. `u0` optionally warm starts the
/// solver. Assembly and the PCG run through `exec` over the rank
/// ownership in `plan` (DESIGN.md §9).
#[allow(clippy::too_many_arguments)]
pub fn solve_stationary(
    exec: &dyn Executor,
    plan: &RankPlan,
    mesh: &TetMesh,
    topo: &LeafTopology,
    dof: &DofMap,
    rt: Option<&Runtime>,
    opts: &SolverOpts,
    u0: Option<&[f64]>,
    source_fn: impl Fn(Vec3) -> f64,
    exact: impl Fn(Vec3) -> f64,
) -> StationarySolution {
    let source = dof.eval_at_dofs(mesh, &source_fn);
    let Assembled { k, m, b } = exec.assemble(plan, mesh, topo, dof, &source, rt);
    let mut a = Csr::linear_combination(1.0, &k, 1.0, &m);
    let g = dof.eval_at_dofs(mesh, &exact);
    let bc: Vec<f64> = g
        .iter()
        .zip(&dof.on_boundary)
        .map(|(&v, &ob)| if ob { v } else { 0.0 })
        .collect();
    let mut rhs = b;
    a.apply_dirichlet(&dof.on_boundary, &bc, &mut rhs);

    let mut u = match u0 {
        Some(w) if w.len() == dof.n_dofs => w.to_vec(),
        _ => vec![0.0; dof.n_dofs],
    };
    // boundary dofs must start at their fixed values for warm starts
    for (i, &ob) in dof.on_boundary.iter().enumerate() {
        if ob {
            u[i] = bc[i];
        }
    }
    let stats = exec.pcg(plan, &a, &rhs, &mut u, opts, rt);

    let (max_error, l2_error) = errors_against(mesh, dof, &u, &m, &exact);
    StationarySolution {
        u,
        stats,
        n_dofs: dof.n_dofs,
        max_error,
        l2_error,
    }
}

/// Example 3.1: [`solve_stationary`] with the paper's smooth
/// manufactured solution.
#[allow(clippy::too_many_arguments)]
pub fn solve_helmholtz(
    exec: &dyn Executor,
    plan: &RankPlan,
    mesh: &TetMesh,
    topo: &LeafTopology,
    dof: &DofMap,
    rt: Option<&Runtime>,
    opts: &SolverOpts,
    u0: Option<&[f64]>,
) -> StationarySolution {
    solve_stationary(
        exec,
        plan,
        mesh,
        topo,
        dof,
        rt,
        opts,
        u0,
        helmholtz_source,
        helmholtz_exact,
    )
}

/// (max vertex error, sqrt(e'Me)) against an exact solution.
pub fn errors_against(
    mesh: &TetMesh,
    dof: &DofMap,
    u: &[f64],
    mass: &Csr,
    exact: impl Fn(Vec3) -> f64,
) -> (f64, f64) {
    let ex = dof.eval_at_dofs(mesh, exact);
    let e: Vec<f64> = u.iter().zip(&ex).map(|(a, b)| a - b).collect();
    let max_error = e.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    let mut me = vec![0.0; e.len()];
    mass.spmv(&e, &mut me);
    let l2: f64 = e.iter().zip(&me).map(|(a, b)| a * b).sum::<f64>().max(0.0);
    (max_error, l2.sqrt())
}

// ---------- Example 3.2: moving-peak parabolic problem ----------

/// Center of the moving peak at time `t` (the paper's trajectory:
/// a circle of radius 2/5 around (1/2, 1/2), at z = 1).
pub fn peak_center(t: f64) -> Vec3 {
    let w = 8.0 * std::f64::consts::PI * t;
    Vec3::new(0.5 + 0.4 * w.sin(), 0.5 + 0.4 * w.cos(), 1.0)
}

/// Oscillating trajectory (the `oscillator` scenario): the peak
/// sweeps back and forth along x through the cube center, so the
/// refinement hotspot repeatedly revisits regions it has already
/// left (and the mesh has since coarsened).
pub fn oscillating_center(t: f64) -> Vec3 {
    let w = 32.0 * std::f64::consts::PI * t;
    Vec3::new(0.5 + 0.4 * w.sin(), 0.5, 0.5)
}

/// The paper's peak profile around a center `c`:
/// u = exp( (25*|p - c|^2 + 0.9)^-1 - 2.5 ).
pub fn moving_peak_exact(p: Vec3, c: Vec3) -> f64 {
    let d2 = (p.x - c.x).powi(2) + (p.y - c.y).powi(2) + (p.z - c.z).powi(2);
    (1.0 / (25.0 * d2 + 0.9) - 2.5).exp()
}

/// Example 3.2's exact solution: the peak carried along
/// [`peak_center`].
pub fn parabolic_exact(p: Vec3, t: f64) -> f64 {
    moving_peak_exact(p, peak_center(t))
}

/// f = u_t - lap u for the peak carried along `center`, by central
/// finite differences (manufactured source; h chosen so FD error
/// ~1e-9 is far below discretization error).
pub fn moving_peak_source(p: Vec3, t: f64, center: fn(f64) -> Vec3) -> f64 {
    let ex = |p: Vec3, t: f64| moving_peak_exact(p, center(t));
    let h = 1e-3;
    let ut = (ex(p, t + h) - ex(p, t - h)) / (2.0 * h);
    let mut lap = 0.0;
    let hs = 1e-3;
    let u0 = ex(p, t);
    for axis in 0..3 {
        let mut dp = p;
        let mut dm = p;
        match axis {
            0 => {
                dp.x += hs;
                dm.x -= hs;
            }
            1 => {
                dp.y += hs;
                dm.y -= hs;
            }
            _ => {
                dp.z += hs;
                dm.z -= hs;
            }
        }
        lap += (ex(dp, t) - 2.0 * u0 + ex(dm, t)) / (hs * hs);
    }
    ut - lap
}

/// [`moving_peak_source`] along the paper's circling trajectory.
pub fn parabolic_source(p: Vec3, t: f64) -> f64 {
    moving_peak_source(p, t, peak_center)
}

/// One implicit-Euler step: (M/dt + K) u^{n+1} = M (u^n/dt + f^{n+1}),
/// Dirichlet from the exact solution at t^{n+1}.
pub struct ParabolicStep {
    pub u: Vec<f64>,
    pub stats: SolveStats,
    pub max_error: f64,
    pub l2_error: f64,
}

/// Advance the moving-peak problem one time step. `center` selects
/// the trajectory (and with it the whole manufactured problem:
/// source, Dirichlet data and errors). Assembly and the PCG run
/// through `exec` over the rank ownership in `plan` (DESIGN.md §9).
#[allow(clippy::too_many_arguments)]
pub fn parabolic_step(
    exec: &dyn Executor,
    plan: &RankPlan,
    mesh: &TetMesh,
    topo: &LeafTopology,
    dof: &DofMap,
    rt: Option<&Runtime>,
    opts: &SolverOpts,
    u_prev: &[f64],
    t_next: f64,
    dt: f64,
    center: fn(f64) -> Vec3,
) -> ParabolicStep {
    assert_eq!(u_prev.len(), dof.n_dofs);
    let c_next = center(t_next);
    let source = dof.eval_at_dofs(mesh, |p| moving_peak_source(p, t_next, center));
    let Assembled { k, m, b } = exec.assemble(plan, mesh, topo, dof, &source, rt);
    // A = M/dt + K ; rhs = M u_prev / dt + b  (b = M f already)
    let mut a = Csr::linear_combination(1.0, &k, 1.0 / dt, &m);
    let mut rhs = vec![0.0; dof.n_dofs];
    m.spmv(u_prev, &mut rhs);
    for (r, bv) in rhs.iter_mut().zip(&b) {
        *r = *r / dt + bv;
    }
    let bc: Vec<f64> = dof
        .on_boundary
        .iter()
        .enumerate()
        .map(|(i, &ob)| {
            if ob {
                moving_peak_exact(mesh.vertices[dof.vertex_of_dof[i] as usize], c_next)
            } else {
                0.0
            }
        })
        .collect();
    a.apply_dirichlet(&dof.on_boundary, &bc, &mut rhs);

    let mut u = u_prev.to_vec(); // warm start from previous time level
    for (i, &ob) in dof.on_boundary.iter().enumerate() {
        if ob {
            u[i] = bc[i];
        }
    }
    let stats = exec.pcg(plan, &a, &rhs, &mut u, opts, rt);
    let (max_error, l2_error) = errors_against(mesh, dof, &u, &m, |p| moving_peak_exact(p, c_next));
    ParabolicStep {
        u,
        stats,
        max_error,
        l2_error,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::VirtualExec;
    use crate::mesh::generator::cube_mesh;

    fn setup(refines: usize) -> (TetMesh, LeafTopology, DofMap, RankPlan) {
        let mut m = cube_mesh(2);
        for _ in 0..refines {
            m.refine(&m.leaves_unordered());
        }
        let topo = LeafTopology::build(&m);
        let dof = DofMap::build(&m, &topo);
        let plan = RankPlan::serial(&m, &topo, &dof);
        (m, topo, dof, plan)
    }

    #[test]
    fn helmholtz_error_decreases_under_refinement() {
        let exec = VirtualExec::new(1);
        let mut errs = Vec::new();
        for refines in [0usize, 3] {
            let (m, topo, dof, plan) = setup(refines);
            let sol = solve_helmholtz(
                &exec,
                &plan,
                &m,
                &topo,
                &dof,
                None,
                &SolverOpts::default(),
                None,
            );
            assert!(sol.stats.rel_residual < 1e-5);
            errs.push(sol.l2_error);
        }
        assert!(
            errs[1] < 0.55 * errs[0],
            "no convergence: {errs:?} (expected ~4x drop per full refine)"
        );
    }

    #[test]
    fn helmholtz_exact_satisfies_equation() {
        // spot check f = (12 pi^2 + 1) u really is -lap u + u via FD
        let p = Vec3::new(0.21, 0.37, 0.53);
        let h = 1e-4;
        let mut lap = 0.0;
        for axis in 0..3 {
            let mut dp = p;
            let mut dm = p;
            match axis {
                0 => {
                    dp.x += h;
                    dm.x -= h;
                }
                1 => {
                    dp.y += h;
                    dm.y -= h;
                }
                _ => {
                    dp.z += h;
                    dm.z -= h;
                }
            }
            lap += (helmholtz_exact(dp) - 2.0 * helmholtz_exact(p) + helmholtz_exact(dm))
                / (h * h);
        }
        let f = -lap + helmholtz_exact(p);
        assert!(
            (f - helmholtz_source(p)).abs() < 1e-3,
            "{f} vs {}",
            helmholtz_source(p)
        );
    }

    #[test]
    fn parabolic_peak_moves() {
        let c0 = peak_center(0.0);
        let c1 = peak_center(0.125); // half revolution at 8 pi t
        assert!((c0 - c1).norm() > 0.5);
        // peak value is at the center
        let t = 0.3;
        let c = peak_center(t);
        let at_peak = parabolic_exact(c, t);
        let off_peak = parabolic_exact(Vec3::new(0.0, 0.0, 0.0), t);
        // the peak's full dynamic range is exp(1/0.9) ~ 3x its floor
        assert!(at_peak > 2.5 * off_peak, "{at_peak} vs {off_peak}");
    }

    #[test]
    fn parabolic_step_tracks_exact_solution() {
        let (m, topo, dof, plan) = setup(2);
        let exec = VirtualExec::new(1);
        let dt = 1e-3;
        let mut u = dof.eval_at_dofs(&m, |p| parabolic_exact(p, 0.0));
        let mut last = ParabolicStep {
            u: u.clone(),
            stats: SolveStats {
                iterations: 0,
                rel_residual: 0.0,
                used_pjrt: false,
            },
            max_error: 0.0,
            l2_error: 0.0,
        };
        for n in 1..=3 {
            last = parabolic_step(
                &exec,
                &plan,
                &m,
                &topo,
                &dof,
                None,
                &SolverOpts::default(),
                &u,
                n as f64 * dt,
                dt,
                peak_center,
            );
            u = last.u.clone();
        }
        // coarse mesh: just demand the solution stays near the exact one
        assert!(
            last.max_error < 0.05,
            "max error {} after 3 steps",
            last.max_error
        );
        assert!(last.stats.rel_residual < 1e-5);
    }

    #[test]
    fn manufactured_source_consistent() {
        // integrate one long step on a fine-ish mesh: error bounded by
        // O(dt) + O(h^2); with dt = 0.002 expect small errors
        let (m, topo, dof, plan) = setup(2);
        let exec = VirtualExec::new(1);
        let dt = 2e-3;
        let u0 = dof.eval_at_dofs(&m, |p| parabolic_exact(p, 0.0));
        let s = parabolic_step(
            &exec,
            &plan,
            &m,
            &topo,
            &dof,
            None,
            &SolverOpts::default(),
            &u0,
            dt,
            dt,
            peak_center,
        );
        assert!(s.max_error < 0.03, "max err {}", s.max_error);
    }

    #[test]
    fn oscillating_center_revisits_the_middle() {
        // the sweep passes back through x = 0.5 every half period
        let c0 = oscillating_center(0.0);
        assert!((c0.x - 0.5).abs() < 1e-12 && (c0.z - 0.5).abs() < 1e-12);
        let quarter = 1.0 / 64.0; // 32 pi t = pi/2: turnaround
        assert!(oscillating_center(quarter).x > 0.89);
        let half = 1.0 / 32.0; // 32 pi t = pi: back through the middle
        assert!((oscillating_center(half).x - 0.5).abs() < 1e-9);
        assert!(oscillating_center(3.0 * quarter).x < 0.11);
    }

    #[test]
    fn oscillator_step_tracks_exact_solution() {
        let (m, topo, dof, plan) = setup(2);
        let exec = VirtualExec::new(1);
        let dt = 1e-3;
        let u0 = dof.eval_at_dofs(&m, |p| moving_peak_exact(p, oscillating_center(0.0)));
        let s = parabolic_step(
            &exec,
            &plan,
            &m,
            &topo,
            &dof,
            None,
            &SolverOpts::default(),
            &u0,
            dt,
            dt,
            oscillating_center,
        );
        assert!(s.max_error < 0.03, "max err {}", s.max_error);
        assert!(s.stats.rel_residual < 1e-5);
    }
}
