//! P1 degree-of-freedom management: one DoF per active vertex, with
//! boundary detection for Dirichlet conditions.

use crate::mesh::topology::{LeafTopology, FACES};
use crate::mesh::{TetMesh, NONE};
use crate::util::hash::FxHashMap;

#[derive(Debug, Clone)]
pub struct DofMap {
    /// dense dof index per vertex id (u32::MAX = inactive vertex)
    pub dof_of_vertex: Vec<u32>,
    /// vertex id per dof
    pub vertex_of_dof: Vec<u32>,
    /// dofs on the domain boundary (Dirichlet set)
    pub on_boundary: Vec<bool>,
    pub n_dofs: usize,
}

impl DofMap {
    /// Build over the current leaves: active vertices in first-seen
    /// order, boundary = vertices of unshared faces.
    pub fn build(mesh: &TetMesh, topo: &LeafTopology) -> Self {
        let mut dof_of_vertex = vec![u32::MAX; mesh.vertices.len()];
        let mut vertex_of_dof = Vec::new();
        for &id in &topo.leaves {
            for &v in &mesh.verts_of(id) {
                if dof_of_vertex[v as usize] == u32::MAX {
                    dof_of_vertex[v as usize] = vertex_of_dof.len() as u32;
                    vertex_of_dof.push(v);
                }
            }
        }
        let n_dofs = vertex_of_dof.len();
        let mut on_boundary = vec![false; n_dofs];
        for (i, &id) in topo.leaves.iter().enumerate() {
            let verts = mesh.verts_of(id);
            for (fi, f) in FACES.iter().enumerate() {
                if topo.neighbors[i][fi] == NONE {
                    for &lv in f {
                        let v = verts[lv as usize];
                        on_boundary[dof_of_vertex[v as usize] as usize] = true;
                    }
                }
            }
        }
        Self {
            dof_of_vertex,
            vertex_of_dof,
            on_boundary,
            n_dofs,
        }
    }

    /// Evaluate a function at every dof's vertex position.
    pub fn eval_at_dofs(
        &self,
        mesh: &TetMesh,
        f: impl Fn(crate::geometry::Vec3) -> f64,
    ) -> Vec<f64> {
        self.vertex_of_dof
            .iter()
            .map(|&v| f(mesh.vertices[v as usize]))
            .collect()
    }

    /// Transfer a dof vector from an old dof map to this one by vertex
    /// identity (new vertices get `fill`); the P1 "interpolate to the
    /// adapted mesh" operation used between adaptive steps. New
    /// midpoint vertices get the mean of their edge endpoints when
    /// both are known, else `fill`.
    pub fn transfer_from(
        &self,
        old: &DofMap,
        old_vals: &[f64],
        mesh: &TetMesh,
        fill: f64,
    ) -> Vec<f64> {
        let mut out = vec![f64::NAN; self.n_dofs];
        let mut known = vec![false; self.n_dofs];
        for (d, &v) in self.vertex_of_dof.iter().enumerate() {
            let od = old.dof_of_vertex.get(v as usize).copied().unwrap_or(u32::MAX);
            if od != u32::MAX && (od as usize) < old_vals.len() {
                out[d] = old_vals[od as usize];
                known[d] = true;
            }
        }
        // midpoints: average parents when both known (walk refinement
        // forest midpoint info via elems is costly; geometric fallback:
        // leave at fill). P1 interpolation exactness for linears is
        // kept by the vertex-identity path; new vertices only appear
        // at edge midpoints whose endpoints existed, so one pass over
        // leaf edges finds them.
        let mut vert_dofs: FxHashMap<u32, u32> = FxHashMap::default();
        for (d, &v) in self.vertex_of_dof.iter().enumerate() {
            vert_dofs.insert(v, d as u32);
        }
        for (a, b, mid) in mesh.split_edges() {
            if let Some(&md) = vert_dofs.get(&mid) {
                let md = md as usize;
                if !known[md] {
                    let da = old
                        .dof_of_vertex
                        .get(a as usize)
                        .copied()
                        .unwrap_or(u32::MAX);
                    let db = old
                        .dof_of_vertex
                        .get(b as usize)
                        .copied()
                        .unwrap_or(u32::MAX);
                    if da != u32::MAX && db != u32::MAX {
                        out[md] = 0.5 * (old_vals[da as usize] + old_vals[db as usize]);
                        known[md] = true;
                    }
                }
            }
        }
        for (d, k) in known.iter().enumerate() {
            if !k {
                out[d] = fill;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generator::cube_mesh;

    #[test]
    fn counts_match_active_vertices() {
        let m = cube_mesh(2);
        let topo = LeafTopology::build(&m);
        let dof = DofMap::build(&m, &topo);
        assert_eq!(dof.n_dofs, 27); // 3^3 grid vertices
        assert_eq!(dof.vertex_of_dof.len(), 27);
    }

    #[test]
    fn boundary_detection_on_cube() {
        let m = cube_mesh(2);
        let topo = LeafTopology::build(&m);
        let dof = DofMap::build(&m, &topo);
        let nb = dof.on_boundary.iter().filter(|&&b| b).count();
        // 3^3 grid: 27 vertices, 1 interior
        assert_eq!(nb, 26);
        // interior vertex is at (0.5, 0.5, 0.5)
        for d in 0..dof.n_dofs {
            let v = dof.vertex_of_dof[d] as usize;
            let p = m.vertices[v];
            let interior = (p.x - 0.5).abs() < 1e-12
                && (p.y - 0.5).abs() < 1e-12
                && (p.z - 0.5).abs() < 1e-12;
            assert_eq!(!dof.on_boundary[d], interior);
        }
    }

    #[test]
    fn eval_at_dofs_positions() {
        let m = cube_mesh(1);
        let topo = LeafTopology::build(&m);
        let dof = DofMap::build(&m, &topo);
        let vals = dof.eval_at_dofs(&m, |p| p.x + 2.0 * p.y);
        for d in 0..dof.n_dofs {
            let p = m.vertices[dof.vertex_of_dof[d] as usize];
            assert_eq!(vals[d], p.x + 2.0 * p.y);
        }
    }

    #[test]
    fn transfer_preserves_linear_fields_under_refinement() {
        let mut m = cube_mesh(1);
        let topo0 = LeafTopology::build(&m);
        let dof0 = DofMap::build(&m, &topo0);
        let u0 = dof0.eval_at_dofs(&m, |p| 3.0 * p.x - p.y + 0.5 * p.z);

        m.refine(&m.leaves_unordered());
        let topo1 = LeafTopology::build(&m);
        let dof1 = DofMap::build(&m, &topo1);
        let u1 = dof1.transfer_from(&dof0, &u0, &m, 0.0);

        let exact = dof1.eval_at_dofs(&m, |p| 3.0 * p.x - p.y + 0.5 * p.z);
        for d in 0..dof1.n_dofs {
            assert!(
                (u1[d] - exact[d]).abs() < 1e-12,
                "dof {d}: {} vs {}",
                u1[d],
                exact[d]
            );
        }
    }

    #[test]
    fn transfer_after_coarsen_keeps_surviving_vertices() {
        let mut m = cube_mesh(1);
        m.refine(&m.leaves_unordered());
        let topo0 = LeafTopology::build(&m);
        let dof0 = DofMap::build(&m, &topo0);
        let u0 = dof0.eval_at_dofs(&m, |p| p.x * p.x);

        // coarsen everything back
        while m.coarsen(&m.leaves_unordered()) > 0 {}
        let topo1 = LeafTopology::build(&m);
        let dof1 = DofMap::build(&m, &topo1);
        let u1 = dof1.transfer_from(&dof0, &u0, &m, -1.0);
        for d in 0..dof1.n_dofs {
            let p = m.vertices[dof1.vertex_of_dof[d] as usize];
            assert!((u1[d] - p.x * p.x).abs() < 1e-12);
        }
    }
}
