//! Jacobi-preconditioned conjugate gradients.
//!
//! Production path: the `cg_step` AOT artifact -- ONE PJRT execute per
//! iteration, with the ELL matrix staged as device buffers and
//! alpha/beta computed inside the graph. Rust owns only the outer loop
//! and the convergence test (the paper's Hypre-BoomerAMG role is
//! played by this solver at our scale).
//!
//! Native path: the same algorithm in f64 Rust -- the correctness
//! oracle and the fallback when artifacts are absent or a row exceeds
//! the artifact's ELL width.

use super::csr::Csr;
use super::ell::csr_to_ell;
use crate::runtime::{next_rung, Runtime};

#[derive(Debug, Clone, Copy)]
pub struct SolveStats {
    pub iterations: usize,
    pub rel_residual: f64,
    /// which engine actually ran
    pub used_pjrt: bool,
}

#[derive(Debug, Clone, Copy)]
pub struct SolverOpts {
    pub tol: f64,
    pub max_iter: usize,
}

impl Default for SolverOpts {
    fn default() -> Self {
        Self {
            tol: 1e-6,
            max_iter: 2000,
        }
    }
}

/// f64 native Jacobi-PCG (oracle + fallback).
pub fn native_pcg(a: &Csr, b: &[f64], x: &mut [f64], opts: &SolverOpts) -> SolveStats {
    let n = a.n;
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);
    let diag = a.diag();
    let dinv: Vec<f64> = diag
        .iter()
        .map(|&d| if d != 0.0 { 1.0 / d } else { 0.0 })
        .collect();

    let bnorm2: f64 = b.iter().map(|v| v * v).sum();
    if bnorm2 == 0.0 {
        x.fill(0.0);
        return SolveStats {
            iterations: 0,
            rel_residual: 0.0,
            used_pjrt: false,
        };
    }
    let mut r = vec![0.0; n];
    a.spmv(x, &mut r);
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let mut z: Vec<f64> = r.iter().zip(&dinv).map(|(a, d)| a * d).collect();
    let mut p = z.clone();
    let mut rz: f64 = r.iter().zip(&z).map(|(a, b)| a * b).sum();
    let mut q = vec![0.0; n];
    let tol2 = opts.tol * opts.tol * bnorm2;

    for it in 0..opts.max_iter {
        let rnorm2: f64 = r.iter().map(|v| v * v).sum();
        if rnorm2 <= tol2 {
            return SolveStats {
                iterations: it,
                rel_residual: (rnorm2 / bnorm2).sqrt(),
                used_pjrt: false,
            };
        }
        a.spmv(&p, &mut q);
        let pq: f64 = p.iter().zip(&q).map(|(a, b)| a * b).sum();
        if pq <= 0.0 {
            break; // not SPD / breakdown
        }
        let alpha = rz / pq;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * q[i];
        }
        for i in 0..n {
            z[i] = r[i] * dinv[i];
        }
        let rz_new: f64 = r.iter().zip(&z).map(|(a, b)| a * b).sum();
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
    }
    let rnorm2: f64 = r.iter().map(|v| v * v).sum();
    SolveStats {
        iterations: opts.max_iter,
        rel_residual: (rnorm2 / bnorm2).sqrt(),
        used_pjrt: false,
    }
}

/// PJRT Jacobi-PCG through the cg_step artifact. Returns None when the
/// system does not fit any artifact rung or exceeds the ELL width
/// (caller should fall back to `native_pcg`).
pub fn pjrt_pcg(
    rt: &Runtime,
    a: &Csr,
    b: &[f64],
    x: &mut [f64],
    opts: &SolverOpts,
) -> Option<SolveStats> {
    let ladder = rt.cg_ladder();
    let n_pad = next_rung(&ladder, a.n)?;
    let ell = csr_to_ell(a, rt.ell_width(), n_pad)?;
    let bufs = rt.stage_cg(&ell.vals, &ell.cols, &ell.diag_inv, n_pad).ok()?;

    let bnorm2: f64 = b.iter().map(|v| v * v).sum();
    if bnorm2 == 0.0 {
        x.fill(0.0);
        return Some(SolveStats {
            iterations: 0,
            rel_residual: 0.0,
            used_pjrt: true,
        });
    }

    // f32 state, padded; start from the provided x (warm starts between
    // adaptive steps matter)
    let mut xs = vec![0.0f32; n_pad];
    for i in 0..a.n {
        xs[i] = x[i] as f32;
    }
    // r = b - A x in f64 for a clean start
    let mut r64 = vec![0.0; a.n];
    a.spmv(x, &mut r64);
    let mut rs = vec![0.0f32; n_pad];
    for i in 0..a.n {
        rs[i] = (b[i] - r64[i]) as f32;
    }
    let mut ps = vec![0.0f32; n_pad];
    for i in 0..a.n {
        ps[i] = rs[i] * ell.diag_inv[i];
    }
    let mut rz: f32 = rs.iter().zip(&ps).map(|(a, b)| a * b).sum();

    // f32 floor: don't demand more than single precision can resolve
    let tol2 = (opts.tol * opts.tol * bnorm2).max(1e-12 * bnorm2) as f32;
    let mut iterations = 0;
    let mut rnorm2 = rs.iter().map(|v| v * v).sum::<f32>();
    while iterations < opts.max_iter && rnorm2 > tol2 {
        let out = bufs.step(&xs, &rs, &ps, rz).ok()?;
        xs = out.x;
        rs = out.r;
        ps = out.p;
        rz = out.rz;
        rnorm2 = out.rnorm2;
        iterations += 1;
        if !rnorm2.is_finite() {
            return None; // f32 breakdown: let the native engine handle it
        }
    }
    for i in 0..a.n {
        x[i] = xs[i] as f64;
    }
    Some(SolveStats {
        iterations,
        rel_residual: ((rnorm2 as f64) / bnorm2).sqrt(),
        used_pjrt: true,
    })
}

/// Solve with the best available engine.
pub fn solve(rt: Option<&Runtime>, a: &Csr, b: &[f64], x: &mut [f64], opts: &SolverOpts) -> SolveStats {
    if let Some(rt) = rt {
        if let Some(stats) = pjrt_pcg(rt, a, b, x, opts) {
            return stats;
        }
    }
    native_pcg(a, b, x, opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn laplacian_2d(n: usize) -> (Csr, Vec<f64>) {
        // n x n grid 5-point laplacian, rhs = A * ones
        let id = |i: usize, j: usize| (i * n + j) as u32;
        let mut t = Vec::new();
        for i in 0..n {
            for j in 0..n {
                let r = id(i, j);
                t.push((r, r, 4.0));
                if i > 0 {
                    t.push((r, id(i - 1, j), -1.0));
                }
                if i + 1 < n {
                    t.push((r, id(i + 1, j), -1.0));
                }
                if j > 0 {
                    t.push((r, id(i, j - 1), -1.0));
                }
                if j + 1 < n {
                    t.push((r, id(i, j + 1), -1.0));
                }
            }
        }
        let a = Csr::from_triplets(n * n, t);
        let ones = vec![1.0; n * n];
        let mut b = vec![0.0; n * n];
        a.spmv(&ones, &mut b);
        (a, b)
    }

    #[test]
    fn native_pcg_solves_laplacian() {
        let (a, b) = laplacian_2d(16);
        let mut x = vec![0.0; a.n];
        let stats = native_pcg(&a, &b, &mut x, &SolverOpts::default());
        assert!(stats.rel_residual < 1e-6);
        for v in &x {
            assert!((v - 1.0).abs() < 1e-5);
        }
        assert!(stats.iterations < 200);
    }

    #[test]
    fn native_pcg_zero_rhs() {
        let (a, _) = laplacian_2d(4);
        let b = vec![0.0; a.n];
        let mut x = vec![5.0; a.n];
        let stats = native_pcg(&a, &b, &mut x, &SolverOpts::default());
        assert_eq!(stats.iterations, 0);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn native_pcg_warm_start_fewer_iterations() {
        let (a, b) = laplacian_2d(16);
        let mut cold = vec![0.0; a.n];
        let s_cold = native_pcg(&a, &b, &mut cold, &SolverOpts::default());
        let mut warm: Vec<f64> = cold.iter().map(|v| v * 0.999).collect();
        let s_warm = native_pcg(&a, &b, &mut warm, &SolverOpts::default());
        assert!(s_warm.iterations < s_cold.iterations);
    }

    #[test]
    fn pjrt_pcg_matches_native() {
        let Ok(rt) = Runtime::open_default() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let (a, b) = laplacian_2d(24); // 576 dofs -> rung 4096
        let opts = SolverOpts {
            tol: 1e-5,
            max_iter: 1000,
        };
        let mut xp = vec![0.0; a.n];
        let stats = pjrt_pcg(&rt, &a, &b, &mut xp, &opts).expect("pjrt path");
        assert!(stats.used_pjrt);
        assert!(stats.rel_residual < 1e-4, "relres {}", stats.rel_residual);
        for v in &xp {
            assert!((v - 1.0).abs() < 1e-3, "{v}");
        }
    }

    #[test]
    fn solve_falls_back_when_row_too_wide() {
        let Ok(rt) = Runtime::open_default() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        // dense row 0 of width 40 > ELL width 32
        let n = 64;
        let mut t = Vec::new();
        for j in 0..40u32 {
            t.push((0u32, j, if j == 0 { 50.0 } else { 0.1 }));
            t.push((j, 0u32, if j == 0 { 0.0 } else { 0.1 }));
        }
        for i in 1..n as u32 {
            t.push((i, i, 2.0));
        }
        let a = Csr::from_triplets(n, t);
        let b = vec![1.0; n];
        let mut x = vec![0.0; n];
        let stats = solve(Some(&rt), &a, &b, &mut x, &SolverOpts::default());
        assert!(!stats.used_pjrt, "should have fallen back to native");
        assert!(stats.rel_residual < 1e-5);
    }
}
