//! Native f64 SELL-C-sigma (sigma = 1) sparse matrix-vector kernel
//! over an explicit row list -- the solve-side hot loop's format
//! (DESIGN.md §11; the seed's `python/compile/kernels/spmv_ell.py` is
//! the batched-f32 exemplar this mirrors in f64).
//!
//! Rows are grouped into chunks of [`SELL_C`] lanes; each chunk stores
//! its entries column-major at the chunk's own width (the max row
//! length within the chunk), so short rows pay padding only up to
//! their chunk-mates, not the global maximum (plain ELL). sigma = 1
//! means rows are *not* reordered by length: the row order is the
//! caller's (the rank plan's ascending dof order), which is what keeps
//! every reduction downstream of the spmv deterministic.
//!
//! ## Determinism
//!
//! For finite `x`, `spmv` is bitwise identical to the CSR row gather
//! (`exec::pcg::spmv_rows`):
//! * each lane accumulates its row's entries at ascending `k`, i.e.
//!   in exactly the CSR column order;
//! * padding comes *after* the real entries and contributes
//!   `0.0 * x[pad_col]` = `±0.0`; the accumulator starts at `+0.0`
//!   and `(+0.0) + (-0.0) = +0.0` under round-to-nearest, so it can
//!   never hold `-0.0` when the padding terms arrive -- adding `±0.0`
//!   to it is then the identity, bit for bit;
//! * the pad column is the row's own first column (the row id itself
//!   for empty rows), so padding never reads out of bounds.

use super::csr::Csr;

/// Chunk height (lanes per chunk). 8 f64 lanes = one cache line per
/// column step per lane group; also the natural AVX-512/NEON-pair
/// width for the autovectorizer.
pub const SELL_C: usize = 8;

/// Rows longer than this make SELL padding pathological (one long row
/// inflates its whole chunk); [`SellF64::build`] refuses and the
/// caller falls back to the CSR gather.
pub const SELL_MAX_WIDTH: usize = 64;

/// A SELL-C-1 slab holding the rows one rank owns (any explicit row
/// subset of a [`Csr`]), writing results at the rows' *global* ids.
#[derive(Debug, Clone)]
pub struct SellF64 {
    /// Global row ids in caller order (chunk `i` serves lanes
    /// `rows[i*C .. i*C+C]`).
    rows: Vec<u32>,
    /// Per-chunk start offsets into `cols`/`vals`; chunk `i` spans
    /// `C * width_i` entries.
    chunk_ptr: Vec<u32>,
    cols: Vec<u32>,
    vals: Vec<f64>,
}

impl SellF64 {
    /// Pack the given rows of `a`. Returns `None` when any row exceeds
    /// [`SELL_MAX_WIDTH`] -- the caller's signal to use the CSR path.
    pub fn build(a: &Csr, rows: &[u32]) -> Option<Self> {
        let nr = rows.len();
        let nchunks = nr.div_ceil(SELL_C);
        let mut chunk_ptr = Vec::with_capacity(nchunks + 1);
        chunk_ptr.push(0u32);
        let mut total = 0usize;
        for ci in 0..nchunks {
            let mut w = 0usize;
            for rr in 0..SELL_C {
                let idx = ci * SELL_C + rr;
                if idx < nr {
                    let r = rows[idx] as usize;
                    let len = (a.row_ptr[r + 1] - a.row_ptr[r]) as usize;
                    if len > SELL_MAX_WIDTH {
                        return None;
                    }
                    w = w.max(len);
                }
            }
            total += w * SELL_C;
            chunk_ptr.push(total as u32);
        }
        let mut cols = vec![0u32; total];
        let mut vals = vec![0.0f64; total];
        for ci in 0..nchunks {
            let base = chunk_ptr[ci] as usize;
            let w = (chunk_ptr[ci + 1] as usize - base) / SELL_C;
            for rr in 0..SELL_C {
                let idx = ci * SELL_C + rr;
                if idx >= nr {
                    continue; // ghost lane: zeros against column 0
                }
                let r = rows[idx];
                let (rcols, rvals) = a.row(r as usize);
                let pad_col = rcols.first().copied().unwrap_or(r);
                for k in 0..w {
                    let p = base + k * SELL_C + rr;
                    if k < rcols.len() {
                        cols[p] = rcols[k];
                        vals[p] = rvals[k];
                    } else {
                        cols[p] = pad_col; // vals[p] stays 0.0
                    }
                }
            }
        }
        Some(Self {
            rows: rows.to_vec(),
            chunk_ptr,
            cols,
            vals,
        })
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Global row ids, in the caller's original order.
    pub fn rows(&self) -> &[u32] {
        &self.rows
    }

    /// Stored entries including padding (the format's footprint).
    pub fn stored(&self) -> usize {
        self.vals.len()
    }

    /// `y[rows] = A[rows, :] * x`; rows not in this slab are left
    /// untouched. Bitwise identical to the CSR row gather for finite
    /// `x` (see module docs).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        let nr = self.rows.len();
        for ci in 0..self.chunk_ptr.len() - 1 {
            let base = self.chunk_ptr[ci] as usize;
            let w = (self.chunk_ptr[ci + 1] as usize - base) / SELL_C;
            let mut acc = [0.0f64; SELL_C];
            let mut off = base;
            for _k in 0..w {
                let c = &self.cols[off..off + SELL_C];
                let v = &self.vals[off..off + SELL_C];
                for rr in 0..SELL_C {
                    acc[rr] += v[rr] * x[c[rr] as usize];
                }
                off += SELL_C;
            }
            let r0 = ci * SELL_C;
            for rr in 0..(nr - r0).min(SELL_C) {
                y[self.rows[r0 + rr] as usize] = acc[rr];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The CSR row gather SELL must reproduce bit for bit.
    fn spmv_ref(a: &Csr, rows: &[u32], x: &[f64], y: &mut [f64]) {
        for &r in rows {
            let (cols, vals) = a.row(r as usize);
            let mut acc = 0.0;
            for (c, v) in cols.iter().zip(vals) {
                acc += v * x[*c as usize];
            }
            y[r as usize] = acc;
        }
    }

    fn small() -> Csr {
        Csr::from_triplets(
            6,
            vec![
                (0, 0, 2.0),
                (0, 3, -1.0),
                (1, 1, 1.0),
                (2, 0, -0.0),
                (2, 2, 4.0),
                (2, 5, 0.5),
                (3, 3, 1.5),
                (4, 1, -2.0),
                (4, 4, 3.0),
                // row 5 empty
            ],
        )
    }

    #[test]
    fn matches_csr_gather_bitwise() {
        let a = small();
        let rows: Vec<u32> = (0..6).collect();
        let s = SellF64::build(&a, &rows).unwrap();
        assert_eq!(s.n_rows(), 6);
        let x = [1.5, -3.0, 0.25, 2.0, -0.5, 4.0];
        let mut y = vec![f64::NAN; 6];
        let mut yr = vec![f64::NAN; 6];
        s.spmv(&x, &mut y);
        spmv_ref(&a, &rows, &x, &mut yr);
        for (a, b) in y.iter().zip(&yr) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }

    #[test]
    fn row_subsets_and_order_are_respected() {
        let a = small();
        let rows = vec![4u32, 1, 5];
        let s = SellF64::build(&a, &rows).unwrap();
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut y = vec![77.0; 6];
        s.spmv(&x, &mut y);
        // untouched rows keep their values
        assert_eq!(y[0], 77.0);
        assert_eq!(y[2], 77.0);
        assert_eq!(y[3], 77.0);
        let mut yr = vec![77.0; 6];
        spmv_ref(&a, &rows, &x, &mut yr);
        assert_eq!(y, yr);
    }

    #[test]
    fn signed_zero_padding_is_harmless() {
        // lane with 1 real entry padded next to a 3-wide lane; x < 0
        // makes every pad product -0.0 -- the result must still match
        // the gather bit for bit (incl. y[5] = +0.0 for the empty row)
        let a = small();
        let rows: Vec<u32> = (0..6).collect();
        let s = SellF64::build(&a, &rows).unwrap();
        let x = [-1.0; 6];
        let mut y = vec![f64::NAN; 6];
        let mut yr = vec![f64::NAN; 6];
        s.spmv(&x, &mut y);
        spmv_ref(&a, &rows, &x, &mut yr);
        for (i, (a, b)) in y.iter().zip(&yr).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "row {i}: {a} vs {b}");
        }
        assert_eq!(y[5].to_bits(), 0.0f64.to_bits(), "empty row is +0.0");
    }

    #[test]
    fn wide_rows_refuse_to_build() {
        let n = SELL_MAX_WIDTH + 2;
        let mut trips = Vec::new();
        for c in 0..n as u32 {
            trips.push((0u32, c, 1.0)); // one row wider than the cap
        }
        trips.push((1, 1, 1.0));
        let a = Csr::from_triplets(n, trips);
        assert!(SellF64::build(&a, &[0, 1]).is_none());
        // excluding the wide row builds fine
        assert!(SellF64::build(&a, &[1]).is_some());
    }

    #[test]
    fn padding_is_bounded_per_chunk() {
        let a = small();
        let rows: Vec<u32> = (0..6).collect();
        let s = SellF64::build(&a, &rows).unwrap();
        // 6 rows -> 1 chunk of width 3 (row 2): 8 * 3 = 24 stored
        assert_eq!(s.stored(), 24);
    }
}
