//! f32 ELL conversion: the fixed-width layout the AOT cg_step/spmv
//! artifacts consume (see python/compile/kernels/spmv_ell.py).
//!
//! Rows are padded to the artifact width with (value 0, column 0);
//! the whole system is padded to the ladder rung with zero rows whose
//! `diag_inv` is 0, which the cg_step graph keeps exactly invariant.

use super::csr::Csr;

#[derive(Debug, Clone)]
pub struct EllF32 {
    /// padded system size (ladder rung)
    pub n_pad: usize,
    /// logical (unpadded) size
    pub n: usize,
    pub width: usize,
    /// (n_pad, width) row-major
    pub vals: Vec<f32>,
    pub cols: Vec<i32>,
    /// 1/diag, 0.0 on padded rows
    pub diag_inv: Vec<f32>,
}

/// Convert CSR to padded f32 ELL. Returns None if any row exceeds
/// `width` (caller falls back to the native CSR solver).
pub fn csr_to_ell(a: &Csr, width: usize, n_pad: usize) -> Option<EllF32> {
    assert!(n_pad >= a.n);
    if a.max_row_len() > width {
        return None;
    }
    let mut vals = vec![0.0f32; n_pad * width];
    let mut cols = vec![0i32; n_pad * width];
    let mut diag_inv = vec![0.0f32; n_pad];
    for r in 0..a.n {
        let (rc, rv) = a.row(r);
        for (k, (c, v)) in rc.iter().zip(rv).enumerate() {
            vals[r * width + k] = *v as f32;
            cols[r * width + k] = *c as i32;
            if *c as usize == r {
                diag_inv[r] = if *v != 0.0 { (1.0 / v) as f32 } else { 0.0 };
            }
        }
    }
    Some(EllF32 {
        n_pad,
        n: a.n,
        width,
        vals,
        cols,
        diag_inv,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tridiag(n: usize) -> Csr {
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i as u32, i as u32, 2.0));
            if i > 0 {
                t.push((i as u32, (i - 1) as u32, -1.0));
            }
            if i + 1 < n {
                t.push((i as u32, (i + 1) as u32, -1.0));
            }
        }
        Csr::from_triplets(n, t)
    }

    #[test]
    fn roundtrip_spmv_agrees() {
        let a = tridiag(10);
        let e = csr_to_ell(&a, 4, 16).unwrap();
        let x64: Vec<f64> = (0..10).map(|i| (i as f64).sin()).collect();
        let mut y64 = vec![0.0; 10];
        a.spmv(&x64, &mut y64);
        // manual ELL spmv in f32
        let mut x32 = vec![0.0f32; 16];
        for i in 0..10 {
            x32[i] = x64[i] as f32;
        }
        for r in 0..10 {
            let mut acc = 0.0f32;
            for k in 0..e.width {
                acc += e.vals[r * e.width + k] * x32[e.cols[r * e.width + k] as usize];
            }
            assert!((acc as f64 - y64[r]).abs() < 1e-5, "row {r}");
        }
    }

    #[test]
    fn rejects_wide_rows() {
        let a = tridiag(10);
        assert!(csr_to_ell(&a, 2, 16).is_none());
        assert!(csr_to_ell(&a, 3, 16).is_some());
    }

    #[test]
    fn diag_inv_zero_on_padding() {
        let a = tridiag(5);
        let e = csr_to_ell(&a, 4, 8).unwrap();
        for r in 5..8 {
            assert_eq!(e.diag_inv[r], 0.0);
            for k in 0..4 {
                assert_eq!(e.vals[r * 4 + k], 0.0);
            }
        }
        for r in 0..5 {
            assert!((e.diag_inv[r] - 0.5).abs() < 1e-7);
        }
    }
}
