//! P1 finite elements over the tet mesh: DoF management, assembly
//! (native f64 or batched through the PJRT artifacts), sparse formats,
//! the Jacobi-PCG solver (native or the cg_step artifact), and the
//! paper's two model problems.

pub mod assemble;
pub mod csr;
pub mod dof;
pub mod ell;
pub mod problems;
pub mod sell;
pub mod solver;

pub use assemble::{assemble, assemble_with_pattern, elem_matrices, Assembled, AssemblyPattern};
pub use csr::Csr;
pub use dof::DofMap;
pub use ell::{csr_to_ell, EllF32};
pub use sell::{SellF64, SELL_C, SELL_MAX_WIDTH};
pub use solver::{native_pcg, pjrt_pcg, solve, SolveStats, SolverOpts};
