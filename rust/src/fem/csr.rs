//! CSR sparse matrix: the f64 reference-side format. Assembled from
//! triplets; used for Dirichlet elimination, the native CG fallback,
//! and as the source for the f32 ELL conversion the PJRT path needs.

#[derive(Debug, Clone)]
pub struct Csr {
    pub n: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f64>,
}

impl Csr {
    /// Build from unsorted triplets, summing duplicates *in input
    /// order*: the sort is stable (LSD radix over a packed key with
    /// the input index as payload), so the value at each slot is the
    /// left-to-right fold of that slot's contributions as they appear
    /// in `trips`. Pattern-reuse assembly scatters contributions in
    /// exactly that order, which is what makes the two construction
    /// paths bitwise identical (DESIGN.md §11).
    pub fn from_triplets(n: usize, trips: Vec<(u32, u32, f64)>) -> Self {
        // single packed u64 key beats the tuple comparator ~2x (#Perf)
        let mut keyed: Vec<(u64, u32)> = trips
            .iter()
            .enumerate()
            .map(|(i, &(r, c, _))| (((r as u64) << 32) | c as u64, i as u32))
            .collect();
        crate::util::sort::radix_sort_by_key(&mut keyed);
        let mut row_ptr = vec![0u32; n + 1];
        let mut col_idx: Vec<u32> = Vec::with_capacity(trips.len());
        let mut vals: Vec<f64> = Vec::with_capacity(trips.len());
        let mut prev: Option<u64> = None;
        for &(key, i) in &keyed {
            let (r, c, v) = trips[i as usize];
            debug_assert!((r as usize) < n && (c as usize) < n);
            if prev == Some(key) {
                *vals.last_mut().unwrap() += v; // duplicate: fold
            } else {
                col_idx.push(c);
                // `0.0 + v` (not `v`): a scatter accumulator starting
                // at +0.0 can never hold -0.0, so the first
                // contribution is normalized identically here
                vals.push(0.0 + v);
                row_ptr[r as usize + 1] += 1; // per-row count for now
                prev = Some(key);
            }
        }
        for r in 0..n {
            row_ptr[r + 1] += row_ptr[r]; // counts -> offsets
        }
        Self {
            n,
            row_ptr,
            col_idx,
            vals,
        }
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn row(&self, r: usize) -> (&[u32], &[f64]) {
        let lo = self.row_ptr[r] as usize;
        let hi = self.row_ptr[r + 1] as usize;
        (&self.col_idx[lo..hi], &self.vals[lo..hi])
    }

    pub fn max_row_len(&self) -> usize {
        (0..self.n)
            .map(|r| (self.row_ptr[r + 1] - self.row_ptr[r]) as usize)
            .max()
            .unwrap_or(0)
    }

    pub fn diag(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.n];
        for r in 0..self.n {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                if *c as usize == r {
                    d[r] += v;
                }
            }
        }
        d
    }

    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        for r in 0..self.n {
            let (cols, vals) = self.row(r);
            let mut acc = 0.0;
            for (c, v) in cols.iter().zip(vals) {
                acc += v * x[*c as usize];
            }
            y[r] = acc;
        }
    }

    /// A' = alpha*A + beta*B entrywise (patterns may differ).
    pub fn linear_combination(alpha: f64, a: &Csr, beta: f64, b: &Csr) -> Csr {
        assert_eq!(a.n, b.n);
        let mut trips = Vec::with_capacity(a.nnz() + b.nnz());
        for r in 0..a.n {
            let (cols, vals) = a.row(r);
            for (c, v) in cols.iter().zip(vals) {
                trips.push((r as u32, *c, alpha * v));
            }
            let (cols, vals) = b.row(r);
            for (c, v) in cols.iter().zip(vals) {
                trips.push((r as u32, *c, beta * v));
            }
        }
        Csr::from_triplets(a.n, trips)
    }

    /// Symmetric Dirichlet elimination for constrained rows: zero row
    /// and column, put 1 on the diagonal, and fix up `rhs` so the
    /// constrained value is `bc_vals[r]` and interior equations see
    /// the lifted data. Standard "row/col elimination keeps SPD".
    pub fn apply_dirichlet(&mut self, constrained: &[bool], bc_vals: &[f64], rhs: &mut [f64]) {
        assert_eq!(constrained.len(), self.n);
        assert_eq!(rhs.len(), self.n);
        // rhs -= A[:, c] * g_c for interior rows; then zero cols
        for r in 0..self.n {
            if constrained[r] {
                continue;
            }
            let lo = self.row_ptr[r] as usize;
            let hi = self.row_ptr[r + 1] as usize;
            for k in lo..hi {
                let c = self.col_idx[k] as usize;
                if constrained[c] {
                    rhs[r] -= self.vals[k] * bc_vals[c];
                    self.vals[k] = 0.0;
                }
            }
        }
        for r in 0..self.n {
            if constrained[r] {
                let lo = self.row_ptr[r] as usize;
                let hi = self.row_ptr[r + 1] as usize;
                for k in lo..hi {
                    self.vals[k] = if self.col_idx[k] as usize == r { 1.0 } else { 0.0 };
                }
                rhs[r] = bc_vals[r];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_triplets_sums_duplicates() {
        let m = Csr::from_triplets(
            3,
            vec![(0, 0, 1.0), (0, 0, 2.0), (1, 2, 5.0), (2, 1, -1.0)],
        );
        assert_eq!(m.nnz(), 3);
        let (c, v) = m.row(0);
        assert_eq!(c, &[0]);
        assert_eq!(v, &[3.0]);
        let (c, v) = m.row(1);
        assert_eq!(c, &[2]);
        assert_eq!(v, &[5.0]);
    }

    #[test]
    fn handles_empty_rows() {
        let m = Csr::from_triplets(4, vec![(0, 1, 1.0), (3, 0, 2.0)]);
        assert_eq!(m.row(1).0.len(), 0);
        assert_eq!(m.row(2).0.len(), 0);
        assert_eq!(m.row(3).0, &[0]);
    }

    #[test]
    fn spmv_matches_dense() {
        let m = Csr::from_triplets(
            3,
            vec![
                (0, 0, 2.0),
                (0, 1, -1.0),
                (1, 0, -1.0),
                (1, 1, 2.0),
                (1, 2, -1.0),
                (2, 1, -1.0),
                (2, 2, 2.0),
            ],
        );
        let x = [1.0, 2.0, 3.0];
        let mut y = [0.0; 3];
        m.spmv(&x, &mut y);
        assert_eq!(y, [0.0, 0.0, 4.0]);
    }

    #[test]
    fn diag_extraction() {
        let m = Csr::from_triplets(2, vec![(0, 0, 3.0), (0, 1, 1.0), (1, 1, 4.0)]);
        assert_eq!(m.diag(), vec![3.0, 4.0]);
    }

    #[test]
    fn linear_combination_merges_patterns() {
        let a = Csr::from_triplets(2, vec![(0, 0, 1.0), (1, 1, 1.0)]);
        let b = Csr::from_triplets(2, vec![(0, 1, 1.0), (1, 1, 2.0)]);
        let c = Csr::linear_combination(2.0, &a, 3.0, &b);
        let (cols, vals) = c.row(0);
        assert_eq!(cols, &[0, 1]);
        assert_eq!(vals, &[2.0, 3.0]);
        let (cols, vals) = c.row(1);
        assert_eq!(cols, &[1]);
        assert_eq!(vals, &[2.0 + 6.0]);
    }

    #[test]
    fn dirichlet_elimination_symmetric_and_consistent() {
        // 1D laplacian on 4 nodes, u0 = 10, u3 = 20 fixed
        let mut a = Csr::from_triplets(
            4,
            vec![
                (0, 0, 2.0),
                (0, 1, -1.0),
                (1, 0, -1.0),
                (1, 1, 2.0),
                (1, 2, -1.0),
                (2, 1, -1.0),
                (2, 2, 2.0),
                (2, 3, -1.0),
                (3, 2, -1.0),
                (3, 3, 2.0),
            ],
        );
        let constrained = [true, false, false, true];
        let bc = [10.0, 0.0, 0.0, 20.0];
        let mut rhs = [0.0, 0.0, 0.0, 0.0];
        a.apply_dirichlet(&constrained, &bc, &mut rhs);
        // row 0: identity
        assert_eq!(a.row(0).1.iter().sum::<f64>(), 1.0);
        assert_eq!(rhs[0], 10.0);
        assert_eq!(rhs[3], 20.0);
        // interior rhs lifted: rhs[1] = 10, rhs[2] = 20
        assert_eq!(rhs[1], 10.0);
        assert_eq!(rhs[2], 20.0);
        // solve by hand: u1 = (10*2 + 20)/3 ... check via direct solve
        // 2u1 - u2 = 10; -u1 + 2u2 = 20 -> u1 = 40/3, u2 = 50/3
        // verify with a tiny dense solve through spmv residual
        let u = [10.0, 40.0 / 3.0, 50.0 / 3.0, 20.0];
        let mut y = [0.0; 4];
        a.spmv(&u, &mut y);
        for i in 0..4 {
            assert!((y[i] - rhs[i]).abs() < 1e-12);
        }
        // symmetry of the eliminated matrix
        for r in 0..4 {
            let (cols, vals) = a.row(r);
            for (c, v) in cols.iter().zip(vals) {
                let (cc, vv) = a.row(*c as usize);
                let back: f64 = cc
                    .iter()
                    .zip(vv)
                    .filter(|(x, _)| **x as usize == r)
                    .map(|(_, v)| *v)
                    .sum();
                assert!((back - v).abs() < 1e-12, "asymmetry at ({r},{c})");
            }
        }
    }
}
