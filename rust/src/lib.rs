//! # phg-dlb
//!
//! Reproduction of *"Dynamic load balancing for large-scale adaptive
//! finite element computation"* (Liu, Cui, Leng, Zhang; cs.DC 2017):
//! the dynamic load-balancing subsystem of the parallel adaptive FEM
//! platform PHG, rebuilt as a three-layer Rust + JAX + Pallas stack.
//!
//! Layer map (see DESIGN.md):
//! * **L3 (this crate)** -- the paper's contribution: the partitioners
//!   ([`partition`]), subgrid-process remapping ([`remap`]), migration
//!   and the virtual MPI runtime ([`dist`]), the DLB policy layer
//!   (triggers, weight models, the rebalance pipeline and the method
//!   registry: [`dlb`]), the problem scenarios behind `--problem`
//!   ([`scenario`]), the execution schedules behind `--exec`
//!   ([`exec`]: virtual-SPMD vs real shared-memory threads),
//!   the generic adaptive driver ([`coordinator`]) with
//!   checkpoint/restore ([`coordinator::checkpoint`]), the
//!   many-tenant solver daemon behind `phg-dlb serve` ([`serve`]),
//!   and structured
//!   observability: phase tracing + metrics ([`obs`])
//!   -- plus every substrate they
//!   need: tet meshes with refinement forests ([`mesh`]), bisection
//!   refinement ([`mesh::TetMesh::refine`]), error estimation
//!   ([`adapt`]), and P1 FEM ([`fem`]).
//! * **L2/L1 (python/, build time only)** -- the FEM compute graph and
//!   its Pallas kernels, AOT-lowered to HLO text and executed from
//!   [`runtime`] via PJRT.

pub mod adapt;
pub mod config;
pub mod coordinator;
pub mod dist;
pub mod dlb;
pub mod exec;
pub mod fem;
pub mod geometry;
pub mod mesh;
pub mod obs;
pub mod partition;
pub mod remap;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod util;
