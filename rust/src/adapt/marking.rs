//! Marking strategies: which elements to refine (or coarsen) given
//! per-element indicators. PHG ships the same family (max-strategy,
//! Doerfler bulk criterion, top-fraction); see Liu & Zhang 2009.

use crate::mesh::ElemId;

/// Max strategy: mark every element with eta >= theta * max(eta).
pub fn mark_max(leaves: &[ElemId], eta: &[f64], theta: f64) -> Vec<ElemId> {
    assert_eq!(leaves.len(), eta.len());
    let max = eta.iter().cloned().fold(0.0f64, f64::max);
    if max <= 0.0 {
        return Vec::new();
    }
    let cut = theta * max;
    leaves
        .iter()
        .zip(eta)
        .filter(|(_, &e)| e >= cut)
        .map(|(&id, _)| id)
        .collect()
}

/// Doerfler (bulk) criterion: smallest set carrying `theta` of the
/// total squared indicator.
pub fn mark_dorfler(leaves: &[ElemId], eta: &[f64], theta: f64) -> Vec<ElemId> {
    assert_eq!(leaves.len(), eta.len());
    let total2: f64 = eta.iter().map(|e| e * e).sum();
    if total2 <= 0.0 {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..leaves.len()).collect();
    order.sort_by(|&a, &b| eta[b].partial_cmp(&eta[a]).unwrap());
    let mut acc = 0.0;
    let mut out = Vec::new();
    for i in order {
        if acc >= theta * total2 {
            break;
        }
        acc += eta[i] * eta[i];
        out.push(leaves[i]);
    }
    out
}

/// Mark the top `frac` fraction of elements by indicator.
pub fn mark_top_fraction(leaves: &[ElemId], eta: &[f64], frac: f64) -> Vec<ElemId> {
    assert_eq!(leaves.len(), eta.len());
    let k = ((leaves.len() as f64 * frac).ceil() as usize).min(leaves.len());
    if k == 0 {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..leaves.len()).collect();
    order.sort_by(|&a, &b| eta[b].partial_cmp(&eta[a]).unwrap());
    order[..k].iter().map(|&i| leaves[i]).collect()
}

/// Coarsening marks: every element with eta <= theta * max(eta).
/// (Used by the time-dependent example where the feature moves away.)
pub fn mark_coarsen_threshold(leaves: &[ElemId], eta: &[f64], theta: f64) -> Vec<ElemId> {
    assert_eq!(leaves.len(), eta.len());
    let max = eta.iter().cloned().fold(0.0f64, f64::max);
    if max <= 0.0 {
        return leaves.to_vec();
    }
    let cut = theta * max;
    leaves
        .iter()
        .zip(eta)
        .filter(|(_, &e)| e <= cut)
        .map(|(&id, _)| id)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Vec<ElemId>, Vec<f64>) {
        let leaves: Vec<ElemId> = (0..10).collect();
        let eta = vec![0.1, 0.9, 0.2, 1.0, 0.05, 0.3, 0.8, 0.01, 0.5, 0.02];
        (leaves, eta)
    }

    #[test]
    fn max_strategy_thresholds() {
        let (leaves, eta) = setup();
        let marked = mark_max(&leaves, &eta, 0.75);
        // threshold 0.75: elements with eta >= 0.75 -> ids 1, 3, 6
        assert_eq!(marked, vec![1, 3, 6]);
    }

    #[test]
    fn max_strategy_theta_zero_marks_all() {
        let (leaves, eta) = setup();
        assert_eq!(mark_max(&leaves, &eta, 0.0).len(), leaves.len());
    }

    #[test]
    fn max_strategy_empty_on_zero_eta() {
        let leaves: Vec<ElemId> = (0..3).collect();
        assert!(mark_max(&leaves, &[0.0, 0.0, 0.0], 0.5).is_empty());
    }

    #[test]
    fn dorfler_carries_bulk() {
        let (leaves, eta) = setup();
        let marked = mark_dorfler(&leaves, &eta, 0.5);
        let marked_set: std::collections::HashSet<_> = marked.iter().collect();
        let tot: f64 = eta.iter().map(|e| e * e).sum();
        let got: f64 = leaves
            .iter()
            .zip(&eta)
            .filter(|(id, _)| marked_set.contains(id))
            .map(|(_, e)| e * e)
            .sum();
        assert!(got >= 0.5 * tot);
        // and it is minimal-ish: dropping the smallest marked element
        // would fall below the bulk
        assert!(marked.len() <= 4);
    }

    #[test]
    fn top_fraction_counts() {
        let (leaves, eta) = setup();
        assert_eq!(mark_top_fraction(&leaves, &eta, 0.3).len(), 3);
        assert_eq!(mark_top_fraction(&leaves, &eta, 1.0).len(), 10);
        assert!(mark_top_fraction(&leaves, &eta, 0.0).is_empty());
    }

    #[test]
    fn top_fraction_picks_largest() {
        let (leaves, eta) = setup();
        let marked = mark_top_fraction(&leaves, &eta, 0.2);
        assert!(marked.contains(&3)); // eta = 1.0
        assert!(marked.contains(&1)); // eta = 0.9
    }

    #[test]
    fn coarsen_marks_smallest() {
        let (leaves, eta) = setup();
        let marked = mark_coarsen_threshold(&leaves, &eta, 0.05);
        assert!(marked.contains(&7)); // 0.01
        assert!(marked.contains(&9)); // 0.02
        assert!(marked.contains(&4)); // 0.05
        assert!(!marked.contains(&3)); // 1.0
    }
}
