//! Per-element error indicators.
//!
//! `residual_indicator` is the classical residual a-posteriori
//! estimator for  -div(grad u) + c u = f  with P1 elements:
//!
//!   eta_T^2 = h_T^2 ||f - c u_h||_{L2(T)}^2
//!           + 1/2 sum_{F interior} h_F || [grad u_h . n] ||_{L2(F)}^2
//!
//! (on P1, the element residual's Laplacian term vanishes). The face
//! jump term needs the leaf adjacency from `mesh::topology`.
//!
//! `geometric_indicator` is the deterministic driver used by the
//! parabolic experiment: indicator = how close the element sits to an
//! analytic feature (the moving peak), mirroring how the paper's
//! example 3.2 concentrates the mesh near the extremum.

use crate::geometry::Vec3;
use crate::mesh::topology::{LeafTopology, FACES};
use crate::mesh::{ElemId, TetMesh, NONE};

/// P1 gradient of a scalar field given at the 4 vertices of leaf `id`.
pub fn p1_gradient(mesh: &TetMesh, id: ElemId, values: &[f64]) -> Vec3 {
    let e = mesh.elem(id);
    let c = mesh.elem_coords(id);
    let d1 = c[1] - c[0];
    let d2 = c[2] - c[0];
    let d3 = c[3] - c[0];
    let c23 = d2.cross(d3);
    let c31 = d3.cross(d1);
    let c12 = d1.cross(d2);
    let det = d1.dot(c23);
    if det.abs() < 1e-300 {
        return Vec3::ZERO;
    }
    let g1 = c23 / det;
    let g2 = c31 / det;
    let g3 = c12 / det;
    let g0 = -(g1 + g2 + g3);
    let u = [
        values[e.verts[0] as usize],
        values[e.verts[1] as usize],
        values[e.verts[2] as usize],
        values[e.verts[3] as usize],
    ];
    g0 * u[0] + g1 * u[1] + g2 * u[2] + g3 * u[3]
}

/// Residual estimator; returns eta_T (not squared) per leaf in
/// `topo.leaves` order.
///
/// * `u` -- P1 solution, indexed by vertex id.
/// * `f` -- source evaluated at a point.
/// * `c_coeff` -- reaction coefficient (1.0 for the paper's Helmholtz
///   form -lap u + u = f, 0.0 for the pure Laplacian).
pub fn residual_indicator(
    mesh: &TetMesh,
    topo: &LeafTopology,
    u: &[f64],
    f: impl Fn(Vec3) -> f64,
    c_coeff: f64,
) -> Vec<f64> {
    let n = topo.n_leaves();
    // element gradients (constant per element for P1)
    let grads: Vec<Vec3> = topo
        .leaves
        .iter()
        .map(|&id| p1_gradient(mesh, id, u))
        .collect();

    let mut eta2 = vec![0.0f64; n];

    for (i, &id) in topo.leaves.iter().enumerate() {
        let vol = mesh.elem_volume(id);
        let h = vol.cbrt();
        // element residual at centroid (midpoint rule)
        let cen = mesh.centroid(id);
        let e = mesh.elem(id);
        let u_cen = e
            .verts
            .iter()
            .map(|&v| u[v as usize])
            .sum::<f64>()
            / 4.0;
        let r = f(cen) - c_coeff * u_cen;
        eta2[i] += h * h * r * r * vol;

        // face jumps: visit each interior face once (i < j)
        for (fi, &j) in topo.neighbors[i].iter().enumerate() {
            if j == NONE || (j as usize) < i {
                continue;
            }
            let jg = grads[j as usize] - grads[i];
            // face area and normal
            let v = e.verts;
            let fv = FACES[fi];
            let a = mesh.vertices[v[fv[0] as usize] as usize];
            let b = mesh.vertices[v[fv[1] as usize] as usize];
            let c = mesh.vertices[v[fv[2] as usize] as usize];
            let nrm = (b - a).cross(c - a) * 0.5; // area-weighted normal
            let area = nrm.norm();
            if area == 0.0 {
                continue;
            }
            let jump = jg.dot(nrm / area);
            let hf = area.sqrt();
            let contrib = 0.5 * hf * jump * jump * area;
            eta2[i] += contrib;
            eta2[j as usize] += contrib;
        }
    }
    eta2.into_iter().map(f64::sqrt).collect()
}

/// Geometric indicator for a moving feature at `center` with spread
/// `width`: large for elements near the feature, ~0 far away. Scaled by
/// element size so refined elements near the peak eventually stop
/// being marked (equilibration), and coarse faraway elements win
/// coarsening marks.
pub fn geometric_indicator(
    mesh: &TetMesh,
    leaves: &[ElemId],
    center: Vec3,
    width: f64,
) -> Vec<f64> {
    leaves
        .iter()
        .map(|&id| {
            let d = (mesh.centroid(id) - center).norm();
            let h = mesh.elem_volume(id).cbrt();
            let proximity = (-d * d / (2.0 * width * width)).exp();
            h * proximity
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generator::cube_mesh;

    #[test]
    fn gradient_of_linear_field_is_exact() {
        let m = cube_mesh(2);
        // u = 2x - 3y + 0.5z + 7
        let u: Vec<f64> = m
            .vertices
            .iter()
            .map(|p| 2.0 * p.x - 3.0 * p.y + 0.5 * p.z + 7.0)
            .collect();
        for id in m.leaves_unordered() {
            let g = p1_gradient(&m, id, &u);
            assert!((g.x - 2.0).abs() < 1e-12);
            assert!((g.y + 3.0).abs() < 1e-12);
            assert!((g.z - 0.5).abs() < 1e-12);
        }
    }

    #[test]
    fn linear_solution_zero_jump_indicator() {
        // u linear and f = c*u: both residual terms vanish except the
        // quadrature error of f - c u_h at centroids, which is 0 here.
        let m = cube_mesh(2);
        let topo = LeafTopology::build(&m);
        let u: Vec<f64> = m.vertices.iter().map(|p| p.x + p.y).collect();
        let eta = residual_indicator(&m, &topo, &u, |p| p.x + p.y, 1.0);
        for e in eta {
            assert!(e < 1e-10, "eta = {e}");
        }
    }

    #[test]
    fn kink_produces_jump_indicator() {
        // u = |x - 0.5| has a gradient jump across x = 0.5
        let m = cube_mesh(2);
        let topo = LeafTopology::build(&m);
        let u: Vec<f64> = m.vertices.iter().map(|p| (p.x - 0.5).abs()).collect();
        let eta = residual_indicator(&m, &topo, &u, |_| 0.0, 0.0);
        // elements near the kink plane must dominate
        let mut near = 0.0f64;
        let mut far = 0.0f64;
        for (i, &id) in topo.leaves.iter().enumerate() {
            let cx = m.centroid(id).x;
            if (cx - 0.5).abs() < 0.25 {
                near = near.max(eta[i]);
            } else {
                far = far.max(eta[i]);
            }
        }
        assert!(near > 10.0 * far, "near {near} far {far}");
    }

    #[test]
    fn source_term_scales_indicator() {
        let m = cube_mesh(2);
        let topo = LeafTopology::build(&m);
        let u = vec![0.0; m.vertices.len()];
        let eta1 = residual_indicator(&m, &topo, &u, |_| 1.0, 1.0);
        let eta2 = residual_indicator(&m, &topo, &u, |_| 2.0, 1.0);
        for (a, b) in eta1.iter().zip(&eta2) {
            assert!((b / a - 2.0).abs() < 1e-10);
        }
    }

    #[test]
    fn geometric_indicator_peaks_at_center() {
        let m = cube_mesh(3);
        let leaves = m.leaves_unordered();
        let center = Vec3::new(0.5, 0.5, 0.5);
        let ind = geometric_indicator(&m, &leaves, center, 0.15);
        let (imax, _) = ind
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let d = (m.centroid(leaves[imax]) - center).norm();
        assert!(d < 0.35, "peak indicator element at distance {d}");
    }
}
