//! Adaptivity: a-posteriori error estimation and marking strategies.
//!
//! The paper's experiments drive refinement with residual-based
//! estimators over P1 FEM solutions (example 3.1) and refine+coarsen
//! around a moving solution feature (example 3.2). Both drivers live
//! here; the coordinator composes them with the DLB machinery.

pub mod estimator;
pub mod marking;

pub use estimator::{geometric_indicator, residual_indicator};
pub use marking::{mark_coarsen_threshold, mark_dorfler, mark_max, mark_top_fraction};
