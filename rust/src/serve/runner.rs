//! One job attempt, end to end: build (or restore) a driver, step it
//! to the budget, checkpoint on drain, and survive anything it throws.
//!
//! Panic isolation is the serve contract: a panicking job (bad config,
//! solver assertion, ...) is caught with `catch_unwind`, converted to
//! an error string, and reported through the registry; the daemon and
//! its other tenants keep running.

use crate::config::Config;
use crate::coordinator::AdaptiveDriver;
use crate::obs;
use crate::serve::job::{JobOutcome, JobRegistry, JobSpec};
use crate::serve::json::escape;
use crate::serve::ServeOptions;
use crate::util::error::{Context, Result};
use crate::util::timer::Stopwatch;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};

/// How one attempt ended.
pub enum RunOutcome {
    Completed,
    /// Drained at a step boundary; resumable from this checkpoint.
    Drained(PathBuf),
    Error(String),
}

pub struct JobRun {
    pub outcome: RunOutcome,
    pub stats: JobOutcome,
}

/// Per-step record kept for the job's private trace file (the global
/// tracer is a singleton; concurrent tenants each get their own file
/// instead of interleaving one).
struct StepEvent {
    step: usize,
    ts_us: u64,
    dur_us: u64,
    n_elements: usize,
    n_dofs: usize,
}

/// Run one attempt of `spec`. Never panics: job panics become
/// `RunOutcome::Error`. When `registry` carries `(registry, row)`,
/// per-step progress (steps done, mesh size, last lambda, attempt
/// wall) is pushed into that row so the status plane's `/jobs` route
/// sees the job move mid-run.
pub fn run_job(
    spec: &JobSpec,
    opts: &ServeOptions,
    drain: &AtomicBool,
    registry: Option<(&JobRegistry, usize)>,
) -> JobRun {
    let sw = Stopwatch::start();
    let result = catch_unwind(AssertUnwindSafe(|| {
        run_job_inner(spec, opts, drain, registry)
    }));
    let wall_s = sw.elapsed();
    let mut run = match result {
        Ok(Ok(run)) => run,
        Ok(Err(e)) => JobRun {
            outcome: RunOutcome::Error(format!("{e}")),
            stats: JobOutcome::default(),
        },
        Err(payload) => JobRun {
            outcome: RunOutcome::Error(format!("panicked: {}", panic_message(&payload))),
            stats: JobOutcome::default(),
        },
    };
    run.stats.wall_s = wall_s;
    let m = obs::metrics();
    m.observe("serve.job_wall_s", wall_s);
    match &run.outcome {
        RunOutcome::Completed => m.counter_add("serve.jobs_completed", 1),
        RunOutcome::Drained(_) => m.counter_add("serve.jobs_drained", 1),
        RunOutcome::Error(_) => m.counter_add("serve.job_errors", 1),
    }
    run
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

fn run_job_inner(
    spec: &JobSpec,
    opts: &ServeOptions,
    drain: &AtomicBool,
    registry: Option<(&JobRegistry, usize)>,
) -> Result<JobRun> {
    let mut cfg = Config::new();
    cfg.apply_pairs(&spec.overrides);
    cfg.set("nsteps", spec.steps);
    let driver_cfg = cfg.driver_config()?;
    let mut driver = match &spec.resume_from {
        Some(path) => AdaptiveDriver::restore(driver_cfg, path)?,
        None => AdaptiveDriver::for_scenario(driver_cfg)?,
    };

    let sw = Stopwatch::start();
    let mut events: Vec<StepEvent> = Vec::new();
    let mut drained: Option<PathBuf> = None;
    while driver.steps_completed() < spec.steps {
        if drain.load(Ordering::SeqCst) {
            let path = opts.checkpoint_dir.join(format!("{}.ckpt", spec.id));
            driver.checkpoint(&path)?;
            drained = Some(path);
            break;
        }
        let t0 = sw.elapsed();
        let more = driver.step();
        let t1 = sw.elapsed();
        if let Some(rec) = driver.timeline.records.last() {
            events.push(StepEvent {
                step: rec.step,
                ts_us: (t0 * 1e6) as u64,
                dur_us: ((t1 - t0) * 1e6) as u64,
                n_elements: rec.n_elements,
                n_dofs: rec.n_dofs,
            });
            if let Some((reg, row)) = registry {
                reg.progress(
                    row,
                    driver.steps_completed(),
                    rec.n_elements,
                    rec.n_dofs,
                    rec.imbalance_after,
                    sw.elapsed(),
                );
            }
        }
        // the per-job drain rehearsal hook (see JobSpec::drain_after):
        // counts steps of this attempt, not the pre-checkpoint prefix
        if let Some(after) = spec.drain_after {
            if driver.timeline.records.len() >= after {
                drain.store(true, Ordering::SeqCst);
            }
        }
        if !more {
            break;
        }
    }

    let last = driver.timeline.records.last();
    let stats = JobOutcome {
        steps_done: driver.steps_completed(),
        n_elements: last.map_or(0, |r| r.n_elements),
        n_dofs: last.map_or(0, |r| r.n_dofs),
        l2_error: last.map_or(0.0, |r| r.l2_error),
        wall_s: 0.0, // stamped by run_job from the attempt wall
    };
    record_job_metrics(&stats);
    if let Some(dir) = &opts.trace_dir {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating trace dir {}", dir.display()))?;
        let trace_path = dir.join(format!("job-{}.json", spec.id));
        write_trace(&trace_path, spec, &events, drained.is_some())?;
        let csv_path = dir.join(format!("job-{}.csv", spec.id));
        std::fs::write(&csv_path, driver.timeline.to_csv())
            .with_context(|| format!("writing {}", csv_path.display()))?;
    }
    let outcome = match drained {
        Some(path) => RunOutcome::Drained(path),
        None => RunOutcome::Completed,
    };
    Ok(JobRun { outcome, stats })
}

fn record_job_metrics(stats: &JobOutcome) {
    let m = obs::metrics();
    m.observe("serve.job_steps", stats.steps_done as f64);
    m.observe("serve.job_elements", stats.n_elements as f64);
}

/// Chrome-trace-format JSON (`{"traceEvents": [...]}`), one file per
/// job: a lifecycle span plus one "X" event per adaptive step.
fn write_trace(
    path: &std::path::Path,
    spec: &JobSpec,
    events: &[StepEvent],
    drained: bool,
) -> Result<()> {
    let total_us = events.last().map_or(0, |e| e.ts_us + e.dur_us);
    let mut out = String::new();
    out.push_str("{\"traceEvents\":[\n");
    out.push_str(&format!(
        "{{\"name\":\"job:{}\",\"cat\":\"serve\",\"ph\":\"X\",\"ts\":0,\"dur\":{},\
         \"pid\":1,\"tid\":0,\"args\":{{\"steps\":{},\"drained\":{}}}}}",
        escape(&spec.id),
        total_us.max(1),
        events.len(),
        drained
    ));
    for e in events {
        out.push_str(&format!(
            ",\n{{\"name\":\"step {}\",\"cat\":\"serve\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
             \"pid\":1,\"tid\":0,\"args\":{{\"n_elements\":{},\"n_dofs\":{}}}}}",
            e.step, e.ts_us, e.dur_us, e.n_elements, e.n_dofs
        ));
    }
    out.push_str("\n]}\n");
    std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}
