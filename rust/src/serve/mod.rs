//! Service mode: the driver as a long-running, many-tenant solver
//! (ROADMAP item 4, DESIGN.md §13).
//!
//! A stream of [`JobSpec`]s (JSONL: scenario + `DriverConfig`
//! overrides + step budget) is admitted in deterministic spec order
//! onto a pool of worker threads; each job runs a full
//! [`crate::coordinator::AdaptiveDriver`] on the shared `exec/`
//! machinery. The daemon's contracts:
//!
//! * **isolation** -- a panicking or erroring job is marked failed
//!   (with bounded retry + backoff first); the daemon keeps serving;
//! * **drain** -- on shutdown signal or `--drain-timeout`, in-flight
//!   jobs are checkpointed at the next step boundary (resumable
//!   bitwise-identically, see `coordinator::checkpoint`) and queued
//!   jobs are cancelled;
//! * **observability** -- per-job Chrome-trace files + timeline CSVs,
//!   `serve.*` metrics through [`crate::obs`], and a final
//!   jobs-summary table in machine-greppable `key=value` form.

pub mod job;
pub mod json;
pub mod runner;
pub mod signal;

pub use job::{JobOutcome, JobRecord, JobRegistry, JobSpec, JobState};

use crate::obs;
use crate::util::error::{Context, Result};
use crate::{bail, format_err};
use runner::RunOutcome;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Daemon configuration (the `phg-dlb serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Concurrent worker threads; 0 = one per available core, capped
    /// by the job count.
    pub workers: usize,
    /// Where drained jobs write `<id>.ckpt` snapshots.
    pub checkpoint_dir: PathBuf,
    /// Per-job trace/timeline directory; `None` disables the files.
    pub trace_dir: Option<PathBuf>,
    /// Request a drain after this many seconds (0 = never). The CLI
    /// also drains on SIGINT/SIGTERM via [`signal::install`].
    pub drain_timeout_s: f64,
    /// Base backoff before a retry attempt (doubles per attempt).
    pub retry_base_ms: u64,
    /// Loopback status plane (`/metrics`, `/jobs`, `/health`) on this
    /// port (0 = kernel-assigned); `None` = no thread, no socket.
    pub status_port: Option<u16>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            workers: 2,
            checkpoint_dir: PathBuf::from("out/ckpt"),
            trace_dir: Some(PathBuf::from("out/serve")),
            drain_timeout_s: 0.0,
            retry_base_ms: 100,
            status_port: None,
        }
    }
}

/// Final state of one serve run: the full registry table.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub jobs: Vec<JobRecord>,
}

impl ServeSummary {
    pub fn count(&self, state: JobState) -> usize {
        self.jobs.iter().filter(|j| j.state == state).count()
    }

    /// One `key=value` line per job plus a totals line -- greppable by
    /// the CI serve smoke step.
    pub fn format_table(&self) -> String {
        let mut out = String::new();
        for j in &self.jobs {
            out.push_str(&format!(
                "job {} state={} attempts={} steps={} elements={} dofs={} wall_ms={:.1}",
                j.spec.id,
                j.state.as_str(),
                j.attempts,
                j.steps_done,
                j.n_elements,
                j.n_dofs,
                j.wall_s * 1e3,
            ));
            if let Some(e) = &j.error {
                out.push_str(&format!(" error={e:?}"));
            }
            if let Some(p) = &j.checkpoint {
                out.push_str(&format!(" checkpoint={}", p.display()));
            }
            out.push('\n');
        }
        out.push_str(&format!(
            "serve: jobs={} done={} failed={} cancelled={}\n",
            self.jobs.len(),
            self.count(JobState::Done),
            self.count(JobState::Failed),
            self.count(JobState::Cancelled),
        ));
        out
    }
}

/// Run the daemon over `specs` until every job reaches a terminal
/// state (or a drain empties the queue). Returns the registry table;
/// per-job failures are reported there, not as an `Err` (daemon-level
/// problems -- empty job list, unwritable directories -- are errors).
pub fn serve(specs: Vec<JobSpec>, opts: &ServeOptions) -> Result<ServeSummary> {
    serve_with_drain(specs, opts, Arc::new(AtomicBool::new(false)))
}

/// [`serve`] with a caller-owned drain flag (set it from a signal
/// handler, a test, or an embedding server to stop admitting jobs and
/// checkpoint the in-flight ones).
pub fn serve_with_drain(
    specs: Vec<JobSpec>,
    opts: &ServeOptions,
    drain: Arc<AtomicBool>,
) -> Result<ServeSummary> {
    if specs.is_empty() {
        bail!("serve: no jobs (empty JSONL)");
    }
    let registry = Arc::new(JobRegistry::new(specs));
    obs::metrics().counter_add("serve.jobs_submitted", registry.len() as u64);
    // opt-in status plane: the registry snapshot closure is the only
    // coupling between obs::serve_status and the serve daemon
    let status = match opts.status_port {
        Some(port) => {
            let reg = Arc::clone(&registry);
            let server =
                obs::StatusServer::start(port, Some(Arc::new(move || reg.jobs_jsonl())))?;
            eprintln!("serve: status plane on http://{}", server.addr());
            Some(server)
        }
        None => None,
    };
    let result = run_registry(&registry, opts, &drain);
    if let Some(server) = status {
        server.stop();
    }
    result?;
    Ok(ServeSummary {
        jobs: registry.snapshot(),
    })
}

/// Drive the worker pool over a caller-owned registry until every job
/// is terminal (the body of [`serve_with_drain`], split out so tests
/// and embedders can own the registry -- e.g. to poll its live state
/// through a status server they also own).
pub fn run_registry(
    registry: &Arc<JobRegistry>,
    opts: &ServeOptions,
    drain: &Arc<AtomicBool>,
) -> Result<()> {
    // touch every serve.* counter so a dump after a clean run shows
    // an explicit 0 instead of omitting the metric (the CI smoke
    // greps for retry/drain/cancel counts by name)
    for name in [
        "serve.jobs_submitted",
        "serve.jobs_completed",
        "serve.jobs_drained",
        "serve.jobs_retried",
        "serve.jobs_cancelled",
        "serve.job_errors",
    ] {
        obs::metrics().counter_add(name, 0);
    }
    std::fs::create_dir_all(&opts.checkpoint_dir).with_context(|| {
        format!("creating checkpoint dir {}", opts.checkpoint_dir.display())
    })?;
    let workers = if opts.workers == 0 {
        crate::exec::available_threads().min(registry.len()).max(1)
    } else {
        opts.workers.min(registry.len())
    };

    let done = AtomicBool::new(false);
    let deadline = (opts.drain_timeout_s > 0.0).then(|| {
        std::time::Instant::now() + Duration::from_secs_f64(opts.drain_timeout_s)
    });
    std::thread::scope(|scope| {
        // watchdog: folds the signal flag and the drain timeout into
        // the shared drain flag, then exits with the workers
        let watchdog = {
            let drain = Arc::clone(drain);
            let done = &done;
            scope.spawn(move || loop {
                if done.load(Ordering::SeqCst) {
                    break;
                }
                if signal::drain_requested() {
                    drain.store(true, Ordering::SeqCst);
                }
                if let Some(deadline) = deadline {
                    if std::time::Instant::now() >= deadline {
                        drain.store(true, Ordering::SeqCst);
                    }
                }
                std::thread::sleep(Duration::from_millis(20));
            })
        };
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let registry = Arc::clone(registry);
                let drain = Arc::clone(drain);
                scope.spawn(move || worker_loop(&registry, opts, &drain))
            })
            .collect();
        for h in handles {
            h.join().expect("serve worker panicked outside isolation");
        }
        done.store(true, Ordering::SeqCst);
        watchdog.join().expect("serve watchdog panicked");
    });

    if !registry.all_terminal() {
        // can't happen: workers only exit on an empty queue or drain
        return Err(format_err!("serve: non-terminal jobs after shutdown"));
    }
    Ok(())
}

fn worker_loop(registry: &JobRegistry, opts: &ServeOptions, drain: &AtomicBool) {
    loop {
        if drain.load(Ordering::SeqCst) {
            // nothing new starts during a drain
            let cancelled = registry.cancel_queued();
            if cancelled > 0 {
                obs::metrics().counter_add("serve.jobs_cancelled", cancelled as u64);
            }
            return;
        }
        let Some((i, spec)) = registry.claim_next() else {
            return;
        };
        let run = runner::run_job(&spec, opts, drain, Some((registry, i)));
        match run.outcome {
            RunOutcome::Completed => registry.complete(i, run.stats),
            RunOutcome::Drained(path) => registry.suspend(i, path, run.stats),
            RunOutcome::Error(e) => {
                let attempts = registry.attempts(i);
                if attempts <= spec.max_retries {
                    obs::metrics().counter_add("serve.jobs_retried", 1);
                    let backoff = opts
                        .retry_base_ms
                        .saturating_mul(1 << (attempts - 1).min(4))
                        .min(2_000);
                    std::thread::sleep(Duration::from_millis(backoff));
                    registry.requeue(i, e);
                } else {
                    registry.fail(i, e, run.stats);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_opts(tag: &str) -> ServeOptions {
        let base = std::env::temp_dir().join(format!("phg_serve_{tag}_{}", std::process::id()));
        ServeOptions {
            workers: 2,
            checkpoint_dir: base.join("ckpt"),
            trace_dir: Some(base.join("trace")),
            drain_timeout_s: 0.0,
            retry_base_ms: 1,
            status_port: None,
        }
    }

    #[test]
    fn empty_job_list_is_a_daemon_error() {
        let err = serve(Vec::new(), &temp_opts("empty")).unwrap_err().to_string();
        assert!(err.contains("no jobs"), "{err}");
    }

    #[test]
    fn summary_table_is_greppable() {
        let specs =
            JobSpec::parse_jsonl("{\"id\": \"t\", \"problem\": \"helmholtz\", \"steps\": 1}\n")
                .unwrap();
        let reg = JobRegistry::new(specs);
        reg.claim_next().unwrap();
        reg.fail(0, "synthetic".to_string(), JobOutcome::default());
        let summary = ServeSummary {
            jobs: reg.snapshot(),
        };
        let table = summary.format_table();
        assert!(table.contains("job t state=failed attempts=1"), "{table}");
        assert!(table.contains("error=\"synthetic\""), "{table}");
        assert!(table.contains("serve: jobs=1 done=0 failed=1"), "{table}");
    }
}
