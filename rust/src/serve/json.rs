//! Minimal JSON parser for the serve job stream (JSONL job specs).
//!
//! The crate is hermetic (no crates.io access, see `util::error`), so
//! the daemon parses its own input format: standard JSON values, one
//! object per line. Objects keep their key order (`Vec` of pairs) so
//! config overrides apply in the order the user wrote them. Errors
//! name the byte offset within the line.

use crate::bail;
use crate::util::error::Result;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Member lookup on an object (first match; `None` otherwise).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
}

const MAX_DEPTH: usize = 64;

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

/// Parse one complete JSON value (trailing whitespace allowed, trailing
/// garbage is an error).
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        b: text.as_bytes(),
        pos: 0,
    };
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.b.len() {
        bail!("json: trailing data at offset {}", p.pos);
    }
    Ok(v)
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.pos) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("json: expected {:?} at offset {}", c as char, self.pos);
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            bail!("json: nesting deeper than {MAX_DEPTH} at offset {}", self.pos);
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("json: unexpected {:?} at offset {}", c as char, self.pos),
            None => bail!("json: unexpected end of input at offset {}", self.pos),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("json: bad literal at offset {}", self.pos);
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-'
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).expect("ascii");
        match s.parse::<f64>() {
            Ok(n) if n.is_finite() => Ok(Json::Num(n)),
            _ => bail!("json: bad number {s:?} at offset {start}"),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("json: unterminated string at offset {}", self.pos),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| crate::format_err!("json: bad escape at end of input"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            let c = if (0xd800..0xdc00).contains(&cp) {
                                // high surrogate: expect \uXXXX low half
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xdc00..0xe000).contains(&lo) {
                                    bail!("json: bad surrogate pair at offset {}", self.pos);
                                }
                                let n = 0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00);
                                char::from_u32(n)
                            } else {
                                char::from_u32(cp)
                            };
                            match c {
                                Some(c) => out.push(c),
                                None => bail!("json: bad codepoint at offset {}", self.pos),
                            }
                        }
                        _ => bail!("json: bad escape at offset {}", self.pos - 1),
                    }
                }
                Some(c) if c < 0x20 => {
                    bail!("json: raw control byte in string at offset {}", self.pos)
                }
                Some(_) => {
                    // consume one UTF-8 scalar (input is a &str, so
                    // slicing at char boundaries is safe)
                    let rest = std::str::from_utf8(&self.b[self.pos..]).expect("utf8 input");
                    let c = rest.chars().next().expect("nonempty");
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self
                .peek()
                .ok_or_else(|| crate::format_err!("json: bad \\u escape at end of input"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| crate::format_err!("json: bad hex digit at offset {}", self.pos))?;
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("json: expected ',' or ']' at offset {}", self.pos),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value(depth + 1)?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => bail!("json: expected ',' or '}}' at offset {}", self.pos),
            }
        }
    }
}

/// Escape a string for embedding in emitted JSON (the per-job trace
/// files).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_nesting() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
        let v = parse(r#"{"a": [1, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = match v.get("a").unwrap() {
            Json::Arr(a) => a,
            other => panic!("not an array: {other:?}"),
        };
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].get("b").and_then(Json::as_str), Some("x"));
    }

    #[test]
    fn unicode_escapes_and_surrogates() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert!(parse(r#""\ud83d""#).is_err());
    }

    #[test]
    fn errors_name_the_offset() {
        for bad in ["{", "[1,", r#"{"a" 1}"#, "tru", "1 2", "\"\u{1}\""] {
            let err = parse(bad).unwrap_err().to_string();
            assert!(
                err.contains("offset") || err.contains("end of input"),
                "{bad:?} -> {err}"
            );
        }
    }

    #[test]
    fn escape_roundtrips_through_parse() {
        let s = "line\nwith \"quotes\" \\ and \t tabs";
        let quoted = format!("\"{}\"", escape(s));
        assert_eq!(parse(&quoted).unwrap(), Json::Str(s.into()));
    }
}
