//! The serve job model: specs, states, and the registry/scheduler.
//!
//! A [`JobSpec`] is one line of JSONL: a scenario plus `DriverConfig`
//! overrides plus a step budget. The [`JobRegistry`] mirrors the
//! `dlb::Registry` idiom -- one flat, inspectable table of everything
//! the daemon knows -- and doubles as the scheduler: workers claim the
//! first queued entry under one lock, so admission order is the spec
//! order regardless of worker count.

use crate::serve::json::{self, Json};
use crate::util::error::Result;
use crate::{bail, format_err};
use std::path::PathBuf;
use std::sync::Mutex;

/// Keys with daemon-level meaning; everything else in a job object is
/// passed through as a `Config` override (`problem`, `nparts`, ...).
const RESERVED: [&str; 5] = ["id", "steps", "retries", "resume", "drain_after"];

/// One solve job: scenario + config overrides + step budget.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Unique name; also the stem of the job's trace/checkpoint files.
    pub id: String,
    /// `Config` overrides in the order the JSON object listed them.
    pub overrides: Vec<(String, String)>,
    /// Total adaptive steps to run (including steps completed before a
    /// checkpoint when `resume_from` is set).
    pub steps: usize,
    /// Extra attempts after a failure (bounded retry with backoff).
    pub max_retries: usize,
    /// Resume from this checkpoint instead of a fresh driver.
    pub resume_from: Option<PathBuf>,
    /// Testing/ops hook: request a daemon drain after this many steps
    /// of *this* job, so drain-and-checkpoint can be rehearsed
    /// deterministically (no timers involved).
    pub drain_after: Option<usize>,
}

impl JobSpec {
    /// Parse one JSONL line (a JSON object).
    pub fn from_json_line(line: &str, index: usize) -> Result<Self> {
        let v = json::parse(line)?;
        let pairs = match v {
            Json::Obj(pairs) => pairs,
            other => bail!("job {index}: expected a JSON object, got {other:?}"),
        };
        let mut spec = JobSpec {
            id: format!("job-{index}"),
            overrides: Vec::new(),
            steps: 4,
            max_retries: 0,
            resume_from: None,
            drain_after: None,
        };
        let mut steps_set = false;
        for (key, val) in pairs {
            match key.as_str() {
                "id" => {
                    spec.id = val
                        .as_str()
                        .ok_or_else(|| format_err!("job {index}: \"id\" must be a string"))?
                        .to_string();
                }
                "steps" => {
                    spec.steps = as_count(&val)
                        .ok_or_else(|| format_err!("job {index}: bad \"steps\""))?;
                    steps_set = true;
                }
                "retries" => {
                    spec.max_retries = as_count(&val)
                        .ok_or_else(|| format_err!("job {index}: bad \"retries\""))?;
                }
                "resume" => {
                    let p = val
                        .as_str()
                        .ok_or_else(|| format_err!("job {index}: \"resume\" must be a path"))?;
                    spec.resume_from = Some(PathBuf::from(p));
                }
                "drain_after" => {
                    spec.drain_after = Some(
                        as_count(&val)
                            .ok_or_else(|| format_err!("job {index}: bad \"drain_after\""))?,
                    );
                }
                _ => {
                    let s = match &val {
                        Json::Str(s) => s.clone(),
                        Json::Num(n) => {
                            if n.fract() == 0.0 && n.abs() < 1e15 {
                                format!("{}", *n as i64)
                            } else {
                                format!("{n}")
                            }
                        }
                        Json::Bool(b) => b.to_string(),
                        other => bail!(
                            "job {index}: override {key:?} must be a scalar, got {other:?}"
                        ),
                    };
                    // "nsteps" doubles as the step budget unless
                    // "steps" says otherwise
                    if key == "nsteps" && !steps_set {
                        if let Ok(n) = s.parse::<usize>() {
                            spec.steps = n;
                        }
                    }
                    spec.overrides.push((key, s));
                }
            }
        }
        if spec.id.is_empty()
            || !spec
                .id
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_' || c == '.')
        {
            bail!(
                "job {index}: id {:?} must be nonempty [A-Za-z0-9._-] (it names files)",
                spec.id
            );
        }
        Ok(spec)
    }

    /// Parse a whole JSONL document: one job object per line; blank
    /// lines and `#` comment lines are skipped. Ids must be unique.
    pub fn parse_jsonl(text: &str) -> Result<Vec<JobSpec>> {
        let mut specs: Vec<JobSpec> = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let spec = JobSpec::from_json_line(line, specs.len())
                .map_err(|e| format_err!("jobs line {}: {e}", lineno + 1))?;
            if specs.iter().any(|s| s.id == spec.id) {
                bail!("jobs line {}: duplicate job id {:?}", lineno + 1, spec.id);
            }
            specs.push(spec);
        }
        Ok(specs)
    }
}

/// JSON has no NaN/Infinity literals; the job table clamps them to 0.
fn finite_or_zero(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

fn as_count(v: &Json) -> Option<usize> {
    match v.as_f64() {
        Some(n) if n >= 0.0 && n.fract() == 0.0 && n < 1e9 => Some(n as usize),
        _ => None,
    }
}

/// Lifecycle of one job inside the daemon.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

/// One registry row: the spec plus everything observed about the job.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub spec: JobSpec,
    pub state: JobState,
    /// Attempts started (1 on the first run; retries increment).
    pub attempts: usize,
    /// Order in which the scheduler first admitted this job.
    pub admitted: Option<usize>,
    pub error: Option<String>,
    /// Where a drained (cancelled-but-resumable) job was checkpointed.
    pub checkpoint: Option<PathBuf>,
    pub steps_done: usize,
    pub n_elements: usize,
    pub n_dofs: usize,
    pub l2_error: f64,
    pub wall_s: f64,
    /// Last observed load-imbalance factor (0 until the driver has
    /// produced a step record).
    pub lambda: f64,
    /// Wall of the in-flight attempt so far; folded into `wall_s` and
    /// zeroed when the attempt finishes. Lets `/jobs` report a live
    /// wall for running jobs without double-counting finished ones.
    pub attempt_wall_s: f64,
}

/// The daemon's job table + deterministic scheduler (see module docs).
pub struct JobRegistry {
    rows: Mutex<Vec<JobRecord>>,
    admissions: Mutex<usize>,
}

impl JobRegistry {
    pub fn new(specs: Vec<JobSpec>) -> Self {
        let rows = specs
            .into_iter()
            .map(|spec| JobRecord {
                spec,
                state: JobState::Queued,
                attempts: 0,
                admitted: None,
                error: None,
                checkpoint: None,
                steps_done: 0,
                n_elements: 0,
                n_dofs: 0,
                l2_error: 0.0,
                wall_s: 0.0,
                lambda: 0.0,
                attempt_wall_s: 0.0,
            })
            .collect();
        Self {
            rows: Mutex::new(rows),
            admissions: Mutex::new(0),
        }
    }

    pub fn len(&self) -> usize {
        self.rows.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Claim the first queued job (marks it running, counts the
    /// attempt). Deterministic: spec order, under one lock.
    pub fn claim_next(&self) -> Option<(usize, JobSpec)> {
        let mut rows = self.rows.lock().unwrap();
        let i = rows.iter().position(|r| r.state == JobState::Queued)?;
        let row = &mut rows[i];
        row.state = JobState::Running;
        row.attempts += 1;
        if row.admitted.is_none() {
            let mut n = self.admissions.lock().unwrap();
            row.admitted = Some(*n);
            *n += 1;
        }
        Some((i, row.spec.clone()))
    }

    /// How many attempts job `i` has made so far.
    pub fn attempts(&self, i: usize) -> usize {
        self.rows.lock().unwrap()[i].attempts
    }

    pub fn complete(&self, i: usize, outcome: JobOutcome) {
        self.finish(i, JobState::Done, None, None, outcome);
    }

    pub fn fail(&self, i: usize, error: String, outcome: JobOutcome) {
        self.finish(i, JobState::Failed, Some(error), None, outcome);
    }

    /// Drained mid-flight: cancelled, but resumable from `checkpoint`.
    pub fn suspend(&self, i: usize, checkpoint: PathBuf, outcome: JobOutcome) {
        self.finish(i, JobState::Cancelled, None, Some(checkpoint), outcome);
    }

    /// Put a failed attempt back in the queue (bounded retry).
    pub fn requeue(&self, i: usize, error: String) {
        let mut rows = self.rows.lock().unwrap();
        let row = &mut rows[i];
        row.state = JobState::Queued;
        row.error = Some(error);
    }

    /// Mark every still-queued job cancelled (drain: nothing new
    /// runs); returns how many were cancelled so the daemon can count
    /// them into `serve.jobs_cancelled`.
    pub fn cancel_queued(&self) -> usize {
        let mut rows = self.rows.lock().unwrap();
        let mut n = 0;
        for row in rows.iter_mut() {
            if row.state == JobState::Queued {
                row.state = JobState::Cancelled;
                if row.error.is_none() {
                    row.error = Some("drained before starting".to_string());
                }
                n += 1;
            }
        }
        n
    }

    /// Live progress of a running attempt, fed by the runner at step
    /// granularity: the `/jobs` route reads these fields mid-run.
    pub fn progress(
        &self,
        i: usize,
        steps_done: usize,
        n_elements: usize,
        n_dofs: usize,
        lambda: f64,
        attempt_wall_s: f64,
    ) {
        let mut rows = self.rows.lock().unwrap();
        let row = &mut rows[i];
        row.steps_done = steps_done;
        row.n_elements = n_elements;
        row.n_dofs = n_dofs;
        row.lambda = lambda;
        row.attempt_wall_s = attempt_wall_s;
    }

    /// The live job table as JSONL: one JSON object per row in spec
    /// order -- what the status plane serves at `/jobs`. `wall_s`
    /// includes the in-flight attempt so a long-running job's wall
    /// visibly advances between polls.
    pub fn jobs_jsonl(&self) -> String {
        let rows = self.rows.lock().unwrap();
        let mut out = String::new();
        for row in rows.iter() {
            out.push_str(&format!(
                "{{\"id\":\"{}\",\"state\":\"{}\",\"attempts\":{},\"steps_done\":{},\
                 \"steps\":{},\"n_elements\":{},\"n_dofs\":{},\"lambda\":{},\"wall_s\":{}",
                json::escape(&row.spec.id),
                row.state.as_str(),
                row.attempts,
                row.steps_done,
                row.spec.steps,
                row.n_elements,
                row.n_dofs,
                finite_or_zero(row.lambda),
                finite_or_zero(row.wall_s + row.attempt_wall_s),
            ));
            if let Some(e) = &row.error {
                out.push_str(&format!(",\"error\":\"{}\"", json::escape(e)));
            }
            if let Some(c) = &row.checkpoint {
                out.push_str(&format!(
                    ",\"checkpoint\":\"{}\"",
                    json::escape(&c.display().to_string())
                ));
            }
            out.push_str("}\n");
        }
        out
    }

    fn finish(
        &self,
        i: usize,
        state: JobState,
        error: Option<String>,
        checkpoint: Option<PathBuf>,
        outcome: JobOutcome,
    ) {
        let mut rows = self.rows.lock().unwrap();
        let row = &mut rows[i];
        row.state = state;
        if error.is_some() {
            row.error = error;
        }
        row.checkpoint = checkpoint;
        row.steps_done = outcome.steps_done;
        row.n_elements = outcome.n_elements;
        row.n_dofs = outcome.n_dofs;
        row.l2_error = outcome.l2_error;
        row.wall_s += outcome.wall_s;
        row.attempt_wall_s = 0.0;
    }

    pub fn snapshot(&self) -> Vec<JobRecord> {
        self.rows.lock().unwrap().clone()
    }

    pub fn all_terminal(&self) -> bool {
        self.rows
            .lock()
            .unwrap()
            .iter()
            .all(|r| r.state.is_terminal())
    }
}

/// What one attempt of a job produced (folded into the registry row).
#[derive(Debug, Clone, Default)]
pub struct JobOutcome {
    pub steps_done: usize,
    pub n_elements: usize,
    pub n_dofs: usize,
    pub l2_error: f64,
    pub wall_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_parsing_reserved_keys_and_overrides() {
        let text = "\n# a comment\n\
            {\"id\": \"a\", \"problem\": \"helmholtz\", \"steps\": 3, \"nparts\": 4}\n\
            {\"problem\": \"parabolic\", \"nsteps\": 5, \"retries\": 2, \"dt\": 1.5e-3}\n";
        let specs = JobSpec::parse_jsonl(text).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].id, "a");
        assert_eq!(specs[0].steps, 3);
        assert_eq!(
            specs[0].overrides,
            vec![
                ("problem".to_string(), "helmholtz".to_string()),
                ("nparts".to_string(), "4".to_string()),
            ]
        );
        // nsteps doubles as the budget; integers stay integral
        assert_eq!(specs[1].id, "job-1");
        assert_eq!(specs[1].steps, 5);
        assert_eq!(specs[1].max_retries, 2);
        assert!(specs[1]
            .overrides
            .iter()
            .any(|(k, v)| k == "dt" && v == "0.0015"));
    }

    #[test]
    fn jsonl_rejects_bad_input_with_line_numbers() {
        let err = JobSpec::parse_jsonl("{\"id\": \"x\"}\n{oops}\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 2"), "{err}");
        let err = JobSpec::parse_jsonl("{\"id\": \"x\"}\n{\"id\": \"x\"}\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate"), "{err}");
        let err = JobSpec::parse_jsonl("{\"id\": \"../evil\"}\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("names files"), "{err}");
        assert!(RESERVED.contains(&"steps"));
    }

    #[test]
    fn jobs_jsonl_reflects_live_progress() {
        let specs =
            JobSpec::parse_jsonl("{\"id\": \"a\", \"steps\": 4}\n{\"id\": \"b\"}\n").unwrap();
        let reg = JobRegistry::new(specs);
        let (i, _) = reg.claim_next().unwrap();
        reg.progress(i, 2, 100, 50, 1.25, 0.5);
        let jsonl = reg.jobs_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"state\":\"running\""), "{}", lines[0]);
        assert!(lines[0].contains("\"steps_done\":2"), "{}", lines[0]);
        assert!(lines[0].contains("\"lambda\":1.25"), "{}", lines[0]);
        assert!(lines[0].contains("\"wall_s\":0.5"), "{}", lines[0]);
        assert!(lines[1].contains("\"state\":\"queued\""), "{}", lines[1]);
        for line in &lines {
            let v = json::parse(line).expect("valid JSON per line");
            assert!(v.get("id").is_some());
        }
        // finishing folds the attempt wall into wall_s exactly once
        reg.complete(
            i,
            JobOutcome {
                steps_done: 4,
                wall_s: 0.7,
                ..Default::default()
            },
        );
        let jsonl = reg.jobs_jsonl();
        assert!(
            jsonl.lines().next().unwrap().contains("\"wall_s\":0.7"),
            "{jsonl}"
        );
        // non-finite floats are clamped; every line stays valid JSON
        reg.progress(1, 0, 0, 0, f64::NAN, f64::INFINITY);
        for line in reg.jobs_jsonl().lines() {
            assert!(json::parse(line).is_ok(), "{line}");
        }
    }

    #[test]
    fn registry_claims_in_spec_order_and_tracks_states() {
        let specs = JobSpec::parse_jsonl(
            "{\"id\": \"a\"}\n{\"id\": \"b\"}\n{\"id\": \"c\"}\n",
        )
        .unwrap();
        let reg = JobRegistry::new(specs);
        assert_eq!(reg.len(), 3);
        let (i, s) = reg.claim_next().unwrap();
        assert_eq!((i, s.id.as_str()), (0, "a"));
        let (j, _) = reg.claim_next().unwrap();
        assert_eq!(j, 1);
        reg.complete(0, JobOutcome::default());
        // a failed attempt goes back to the head of the queue
        reg.requeue(1, "boom".to_string());
        let (k, _) = reg.claim_next().unwrap();
        assert_eq!(k, 1);
        assert_eq!(reg.attempts(1), 2);
        reg.fail(1, "boom".to_string(), JobOutcome::default());
        assert!(!reg.all_terminal());
        reg.cancel_queued();
        assert!(reg.all_terminal());
        let rows = reg.snapshot();
        assert_eq!(rows[0].state, JobState::Done);
        assert_eq!(rows[1].state, JobState::Failed);
        assert_eq!(rows[2].state, JobState::Cancelled);
        assert_eq!(rows[0].admitted, Some(0));
        assert_eq!(rows[1].admitted, Some(1));
        assert_eq!(rows[2].admitted, None);
    }
}
