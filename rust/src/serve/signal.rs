//! Shutdown-signal wiring for the daemon: SIGINT/SIGTERM set a global
//! drain flag; the serve loop polls it and drains gracefully
//! (checkpointing in-flight jobs) instead of dying mid-step.
//!
//! The crate is dependency-free, so this registers handlers through
//! libc's `signal(2)` directly (one tiny extern declaration instead of
//! a signal-handling crate). The handler body is async-signal-safe: a
//! single atomic store.

use std::sync::atomic::{AtomicBool, Ordering};

/// Set once a shutdown signal arrives; [`crate::serve::serve`] folds
/// it into its drain flag. Public so embedders can poll it too.
pub static DRAIN_REQUESTED: AtomicBool = AtomicBool::new(false);

const SIGINT: i32 = 2;
const SIGTERM: i32 = 15;

extern "C" fn mark_drain(_signum: i32) {
    DRAIN_REQUESTED.store(true, Ordering::SeqCst);
}

extern "C" {
    fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
}

/// Install SIGINT/SIGTERM handlers that request a drain. Idempotent.
/// Called by the `phg-dlb serve` CLI entry point only -- library users
/// (and tests) pass their own drain flag instead.
pub fn install() {
    unsafe {
        signal(SIGINT, mark_drain);
        signal(SIGTERM, mark_drain);
    }
}

/// Whether a shutdown signal has been observed.
pub fn drain_requested() -> bool {
    DRAIN_REQUESTED.load(Ordering::SeqCst)
}
