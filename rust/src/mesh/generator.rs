//! Structured tetrahedral mesh generators (the Netgen substitute).
//!
//! Each hexahedral cell is split into 6 tets by Kuhn/Freudenthal
//! subdivision: one tet per permutation of the axes, with vertices
//! listed in *path order* from the cell's low corner to its high corner
//! (the cell diagonal). Path-ordered Kuhn tets with Maubach tag 3 are a
//! compatibly-tagged mesh, so bisection refinement is conforming and
//! shape-bounded forever -- the same guarantee PHG's initial-mesh
//! pre-processing establishes.
//!
//! The paper's domains:
//!   * Omega_1 -- a long thin cylinder (diameter 1, length 8; aspect
//!     ratio ~8) meshed by radially warping a box mesh: this is the
//!     domain where aspect-ratio-preserving SFC normalization matters.
//!   * Omega_3 -- the unit cube.

use super::{TetMesh, VertId};
use crate::geometry::Vec3;

/// All 6 permutations of (0,1,2), fixed order for determinism.
const PERMS: [[usize; 3]; 6] = [
    [0, 1, 2],
    [0, 2, 1],
    [1, 0, 2],
    [1, 2, 0],
    [2, 0, 1],
    [2, 1, 0],
];

/// The 6 Kuhn tets of cell (i, j, k) as grid-index paths from the
/// cell's low corner to its high corner, one per axis permutation --
/// the single source of the subdivision shared by every structured
/// generator, so they can never diverge.
fn kuhn_cell_paths(i: usize, j: usize, k: usize) -> [[[usize; 3]; 4]; 6] {
    let mut out = [[[0usize; 3]; 4]; 6];
    for (t, perm) in PERMS.iter().enumerate() {
        let mut idx = [i, j, k];
        out[t][0] = idx;
        for (step, &axis) in perm.iter().enumerate() {
            idx[axis] += 1;
            out[t][step + 1] = idx;
        }
    }
    out
}

/// Structured box mesh: nx*ny*nz cells, 6 tets each, over [lo, hi].
pub fn box_mesh(nx: usize, ny: usize, nz: usize, lo: Vec3, hi: Vec3) -> TetMesh {
    assert!(nx > 0 && ny > 0 && nz > 0);
    let ext = hi - lo;
    let nvx = nx + 1;
    let nvy = ny + 1;
    let nvz = nz + 1;
    let vid = |i: usize, j: usize, k: usize| -> VertId { ((i * nvy + j) * nvz + k) as VertId };

    let mut vertices = Vec::with_capacity(nvx * nvy * nvz);
    for i in 0..nvx {
        for j in 0..nvy {
            for k in 0..nvz {
                vertices.push(Vec3::new(
                    lo.x + ext.x * i as f64 / nx as f64,
                    lo.y + ext.y * j as f64 / ny as f64,
                    lo.z + ext.z * k as f64 / nz as f64,
                ));
            }
        }
    }

    let mut tets = Vec::with_capacity(nx * ny * nz * 6);
    for i in 0..nx {
        for j in 0..ny {
            for k in 0..nz {
                for path in kuhn_cell_paths(i, j, k) {
                    let mut verts = [0 as VertId; 4];
                    for (v, ijk) in verts.iter_mut().zip(path) {
                        *v = vid(ijk[0], ijk[1], ijk[2]);
                    }
                    tets.push(verts);
                }
            }
        }
    }
    TetMesh::from_raw(vertices, tets)
}

/// Unit cube [0,1]^3 with n cells per side (the paper's Omega_3).
pub fn cube_mesh(n: usize) -> TetMesh {
    box_mesh(n, n, n, Vec3::ZERO, Vec3::new(1.0, 1.0, 1.0))
}

/// Long cylinder along x (the paper's Omega_1): radius `radius`,
/// length `length`, meshed by warping a box's square cross-section
/// onto the disk with the elliptical (squircle) map, which keeps all
/// cells well-shaped and the mesh conforming.
///
/// `nx` cells along the axis, `ns` cells across the diameter.
pub fn cylinder_mesh(nx: usize, ns: usize, radius: f64, length: f64) -> TetMesh {
    let mut mesh = box_mesh(
        nx,
        ns,
        ns,
        Vec3::new(0.0, -1.0, -1.0),
        Vec3::new(length, 1.0, 1.0),
    );
    for v in &mut mesh.vertices {
        let (u, w) = (v.y, v.z);
        // elliptical square->disk map
        let du = u * (1.0 - 0.5 * w * w).sqrt();
        let dw = w * (1.0 - 0.5 * u * u).sqrt();
        v.y = radius * du;
        v.z = radius * dw;
    }
    mesh
}

/// The paper's Omega_1 at a given resolution scale: diameter 1,
/// length 8 (aspect ratio 8), ~`scale` controls element count:
/// n_elems = 6 * (8*scale) * scale^2.
pub fn omega1_cylinder(scale: usize) -> TetMesh {
    cylinder_mesh(8 * scale, scale.max(2), 0.5, 8.0)
}

/// L-shaped prism (the corner-singularity domain): the unit cube with
/// the quadrant x > 1/2, y > 1/2 removed, leaving a reentrant edge
/// along (1/2, 1/2, z). `n` cells per side, rounded up to even so the
/// edge lies on the grid; only vertices of kept cells are emitted, so
/// every mesh vertex is active. Kuhn cells are face-consistent across
/// any cell subset, so the mesh is conforming and compatibly tagged
/// like [`box_mesh`].
pub fn lshape_mesh(n: usize) -> TetMesh {
    let n = (n.max(2) + 1) & !1usize;
    let nv = n + 1;
    let h = 1.0 / n as f64;
    let gidx = |i: usize, j: usize, k: usize| (i * nv + j) * nv + k;
    let mut grid = vec![u32::MAX; nv * nv * nv];
    let mut vertices: Vec<Vec3> = Vec::new();
    let mut tets: Vec<[VertId; 4]> = Vec::new();
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                if i >= n / 2 && j >= n / 2 {
                    continue; // the removed quadrant
                }
                for path in kuhn_cell_paths(i, j, k) {
                    let mut verts = [0 as VertId; 4];
                    for (v, ijk) in verts.iter_mut().zip(path) {
                        let g = gidx(ijk[0], ijk[1], ijk[2]);
                        if grid[g] == u32::MAX {
                            grid[g] = vertices.len() as u32;
                            vertices.push(Vec3::new(
                                ijk[0] as f64 * h,
                                ijk[1] as f64 * h,
                                ijk[2] as f64 * h,
                            ));
                        }
                        *v = grid[g];
                    }
                    tets.push(verts);
                }
            }
        }
    }
    TetMesh::from_raw(vertices, tets)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{tet_quality, tet_volume_signed};

    #[test]
    fn box_counts() {
        let m = box_mesh(2, 3, 4, Vec3::ZERO, Vec3::new(2.0, 3.0, 4.0));
        assert_eq!(m.n_leaves(), 2 * 3 * 4 * 6);
        assert_eq!(m.n_vertices(), 3 * 4 * 5);
        assert!((m.total_volume() - 24.0).abs() < 1e-9);
    }

    #[test]
    fn box_is_conforming() {
        let m = box_mesh(3, 2, 2, Vec3::ZERO, Vec3::new(1.0, 1.0, 1.0));
        m.check_invariants().unwrap();
    }

    #[test]
    fn kuhn_tets_nondegenerate() {
        let m = cube_mesh(2);
        for id in m.leaves_unordered() {
            let v = m.elem_coords(id);
            assert!(tet_volume_signed(&v).abs() > 1e-12);
            assert!(tet_quality(&m.elem_coords(id)) > 0.2);
        }
    }

    #[test]
    fn cube_refines_conformingly() {
        let mut m = cube_mesh(2);
        for _ in 0..3 {
            m.refine(&m.leaves_unordered());
            m.check_invariants().unwrap();
        }
    }

    #[test]
    fn cylinder_volume_near_pi_r2_l() {
        // squircle-warped box underestimates the disk slightly; with
        // moderate resolution we get within a few percent
        let m = cylinder_mesh(16, 8, 0.5, 8.0);
        let vol = m.total_volume();
        let exact = std::f64::consts::PI * 0.25 * 8.0;
        assert!(
            (vol - exact).abs() / exact < 0.1,
            "vol {vol} vs {exact}"
        );
    }

    #[test]
    fn cylinder_aspect_ratio_is_long() {
        let m = omega1_cylinder(2);
        let bb = m.bounding_box();
        assert!(bb.aspect_ratio() > 6.0, "AR = {}", bb.aspect_ratio());
        m.check_invariants().unwrap();
    }

    #[test]
    fn cylinder_cells_stay_valid_after_warp() {
        let m = cylinder_mesh(8, 4, 0.5, 4.0);
        for id in m.leaves_unordered() {
            assert!(m.elem_volume(id) > 0.0);
            assert!(tet_quality(&m.elem_coords(id)) > 0.05);
        }
    }

    #[test]
    fn cylinder_refines_conformingly() {
        let mut m = cylinder_mesh(4, 2, 0.5, 2.0);
        for _ in 0..2 {
            m.refine(&m.leaves_unordered());
            m.check_invariants().unwrap();
        }
    }

    #[test]
    fn lshape_counts_and_volume() {
        let m = lshape_mesh(4);
        // 3/4 of the cells survive
        assert_eq!(m.n_leaves(), 4 * 4 * 4 * 6 * 3 / 4);
        assert!((m.total_volume() - 0.75).abs() < 1e-9);
        // no orphan vertices: every emitted vertex belongs to a tet
        let mut used = vec![false; m.n_vertices()];
        for id in m.leaves_unordered() {
            for &v in &m.elem(id).verts {
                used[v as usize] = true;
            }
        }
        assert!(used.iter().all(|&u| u));
        m.check_invariants().unwrap();
    }

    #[test]
    fn lshape_odd_n_rounds_up_and_refines_conformingly() {
        let mut m = lshape_mesh(3); // rounds to 4
        assert_eq!(m.n_leaves(), 4 * 4 * 4 * 6 * 3 / 4);
        for _ in 0..2 {
            m.refine(&m.leaves_unordered());
            m.check_invariants().unwrap();
        }
        // the reentrant quadrant stays empty
        for id in m.leaves_unordered() {
            let c = m.centroid(id);
            assert!(
                c.x < 0.5 + 1e-9 || c.y < 0.5 + 1e-9,
                "element centroid {c:?} inside the removed quadrant"
            );
        }
    }
}
