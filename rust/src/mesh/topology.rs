//! Leaf-level topology: face adjacency (the dual graph).
//!
//! Rebuilt on demand from the current leaf set. Consumers: the
//! multilevel graph partitioner (dual graph = ParMETIS's input), the
//! residual error estimator (face jumps), partition quality metrics
//! (interface faces / edge cut), and the conformity checker.

use super::{ElemId, TetMesh, NONE};
use crate::util::hash::{face_key, FxHashMap};

/// Local faces of a tet: face `i` is opposite vertex `i`.
pub const FACES: [[u8; 3]; 4] = [[1, 2, 3], [0, 2, 3], [0, 1, 3], [0, 1, 2]];

/// Face-adjacency structure over the current leaves.
#[derive(Debug, Clone)]
pub struct LeafTopology {
    /// Leaf ids in the order used for local indices (arena order).
    pub leaves: Vec<ElemId>,
    /// ElemId -> local leaf index.
    pub index_of: FxHashMap<ElemId, u32>,
    /// Per leaf, per local face: neighbouring *local leaf index*, or
    /// `NONE` for boundary faces.
    pub neighbors: Vec<[u32; 4]>,
    /// Number of interior (shared) faces.
    pub n_interior_faces: usize,
    /// Number of boundary faces.
    pub n_boundary_faces: usize,
}

impl LeafTopology {
    pub fn build(mesh: &TetMesh) -> Self {
        let leaves = mesh.leaves_unordered();
        Self::build_for(mesh, leaves)
    }

    /// Build for an explicit leaf list (used by per-rank local builds).
    pub fn build_for(mesh: &TetMesh, leaves: Vec<ElemId>) -> Self {
        let mut index_of = FxHashMap::default();
        index_of.reserve(leaves.len());
        for (i, &id) in leaves.iter().enumerate() {
            index_of.insert(id, i as u32);
        }
        let mut neighbors = vec![[NONE; 4]; leaves.len()];
        // face key -> (leaf local idx, local face)
        let mut open: FxHashMap<u128, (u32, u8)> = FxHashMap::default();
        open.reserve(leaves.len() * 2);
        let mut interior = 0usize;
        for (i, &id) in leaves.iter().enumerate() {
            // streams the SoA vertex column directly (no Elem gather)
            let v = mesh.verts_of(id);
            for (fi, f) in FACES.iter().enumerate() {
                let key = face_key(v[f[0] as usize], v[f[1] as usize], v[f[2] as usize]);
                match open.remove(&key) {
                    Some((j, fj)) => {
                        neighbors[i][fi] = j;
                        neighbors[j as usize][fj as usize] = i as u32;
                        interior += 1;
                    }
                    None => {
                        open.insert(key, (i as u32, fi as u8));
                    }
                }
            }
        }
        let n_boundary_faces = open.len();
        Self {
            leaves,
            index_of,
            neighbors,
            n_interior_faces: interior,
            n_boundary_faces,
        }
    }

    pub fn n_leaves(&self) -> usize {
        self.leaves.len()
    }

    /// Dual graph in CSR form (xadj, adjncy) over local leaf indices --
    /// the input format of the multilevel graph partitioner.
    pub fn dual_graph_csr(&self) -> (Vec<u32>, Vec<u32>) {
        let n = self.leaves.len();
        let mut xadj = Vec::with_capacity(n + 1);
        let mut adjncy = Vec::with_capacity(self.n_interior_faces * 2);
        xadj.push(0u32);
        for nb in &self.neighbors {
            for &j in nb {
                if j != NONE {
                    adjncy.push(j);
                }
            }
            xadj.push(adjncy.len() as u32);
        }
        (xadj, adjncy)
    }

    /// Count faces whose two leaves live in different parts.
    pub fn interface_faces(&self, part_of: &[u16]) -> usize {
        debug_assert_eq!(part_of.len(), self.leaves.len());
        let mut cut = 0;
        for (i, nb) in self.neighbors.iter().enumerate() {
            for &j in nb {
                if j != NONE && (j as usize) > i && part_of[i] != part_of[j as usize] {
                    cut += 1;
                }
            }
        }
        cut
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Vec3;
    use crate::mesh::generator;

    fn mesh() -> TetMesh {
        generator::box_mesh(2, 2, 2, Vec3::ZERO, Vec3::new(1.0, 1.0, 1.0))
    }

    #[test]
    fn adjacency_is_symmetric() {
        let m = mesh();
        let topo = LeafTopology::build(&m);
        for (i, nb) in topo.neighbors.iter().enumerate() {
            for &j in nb {
                if j != NONE {
                    assert!(
                        topo.neighbors[j as usize].contains(&(i as u32)),
                        "asymmetric adjacency {i} <-> {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn face_counts_consistent() {
        let m = mesh();
        let topo = LeafTopology::build(&m);
        // 4 faces per tet, each interior face shared by 2
        let total = topo.n_leaves() * 4;
        assert_eq!(total, 2 * topo.n_interior_faces + topo.n_boundary_faces);
        // a 2x2x2 Kuhn box has 2*6 boundary faces per cube face... just
        // sanity: boundary face count equals 2 triangles * 4 cells * 6 sides
        assert_eq!(topo.n_boundary_faces, 48);
    }

    #[test]
    fn csr_matches_neighbors() {
        let m = mesh();
        let topo = LeafTopology::build(&m);
        let (xadj, adjncy) = topo.dual_graph_csr();
        assert_eq!(xadj.len(), topo.n_leaves() + 1);
        for (i, nb) in topo.neighbors.iter().enumerate() {
            let deg = nb.iter().filter(|&&j| j != NONE).count();
            assert_eq!((xadj[i + 1] - xadj[i]) as usize, deg);
        }
        assert_eq!(adjncy.len(), 2 * topo.n_interior_faces);
    }

    #[test]
    fn interface_faces_zero_for_single_part() {
        let m = mesh();
        let topo = LeafTopology::build(&m);
        let parts = vec![0u16; topo.n_leaves()];
        assert_eq!(topo.interface_faces(&parts), 0);
    }

    #[test]
    fn interface_faces_counts_cut() {
        let m = mesh();
        let topo = LeafTopology::build(&m);
        // put leaf 0 alone in part 1: cut = its interior degree
        let mut parts = vec![0u16; topo.n_leaves()];
        parts[0] = 1;
        let deg0 = topo.neighbors[0].iter().filter(|&&j| j != NONE).count();
        assert_eq!(topo.interface_faces(&parts), deg0);
    }

    #[test]
    fn adjacency_survives_refinement() {
        let mut m = mesh();
        m.refine(&m.leaves_unordered());
        let topo = LeafTopology::build(&m);
        assert_eq!(topo.n_leaves(), m.n_leaves());
        // Euler-ish sanity: interior faces > leaves for a refined box
        assert!(topo.n_interior_faces > topo.n_leaves());
    }
}
