//! Mesh I/O: legacy-VTK export for eyeballing partitions, plus the
//! binary snapshot substrate (`SnapWriter`/`SnapReader` and the full
//! forest serializer) backing driver checkpoints (DESIGN.md §13).
//!
//! The snapshot format is little-endian and exact: every `f64` crosses
//! the boundary as its IEEE bit pattern (`to_bits`/`from_bits`), never
//! as text, so a restored mesh is bitwise-identical to the one that was
//! saved. Allocation free lists are stored in their verbatim order --
//! `alloc_elem`/`alloc_vertex` pop from them, so the order determines
//! every future `ElemId`/`VertId` assignment and is part of the state.

use super::{ElemId, TetMesh, VertId, NONE};
use crate::geometry::Vec3;
use crate::util::error::Result;
use crate::{bail, format_err};
use crate::util::hash::FxHashMap;
use std::io::Write;
use std::path::Path;

/// Write the current leaves as an unstructured grid; `cell_data` maps
/// each leaf (in `leaves_unordered` order) to a scalar (e.g. part id).
pub fn write_vtk(
    mesh: &TetMesh,
    cell_data: &[f64],
    data_name: &str,
    path: &Path,
) -> std::io::Result<()> {
    let leaves = mesh.leaves_unordered();
    assert_eq!(cell_data.len(), leaves.len());

    // compact vertex numbering over active vertices
    let mut vert_map = vec![u32::MAX; mesh.vertices.len()];
    let mut verts = Vec::new();
    for &id in &leaves {
        for &v in &mesh.elem(id).verts {
            if vert_map[v as usize] == u32::MAX {
                vert_map[v as usize] = verts.len() as u32;
                verts.push(v);
            }
        }
    }

    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "# vtk DataFile Version 3.0")?;
    writeln!(f, "phg-dlb mesh")?;
    writeln!(f, "ASCII")?;
    writeln!(f, "DATASET UNSTRUCTURED_GRID")?;
    writeln!(f, "POINTS {} double", verts.len())?;
    for &v in &verts {
        let p = mesh.vertices[v as usize];
        writeln!(f, "{} {} {}", p.x, p.y, p.z)?;
    }
    writeln!(f, "CELLS {} {}", leaves.len(), leaves.len() * 5)?;
    for &id in &leaves {
        let v = mesh.elem(id).verts;
        writeln!(
            f,
            "4 {} {} {} {}",
            vert_map[v[0] as usize],
            vert_map[v[1] as usize],
            vert_map[v[2] as usize],
            vert_map[v[3] as usize]
        )?;
    }
    writeln!(f, "CELL_TYPES {}", leaves.len())?;
    for _ in &leaves {
        writeln!(f, "10")?; // VTK_TETRA
    }
    writeln!(f, "CELL_DATA {}", leaves.len())?;
    writeln!(f, "SCALARS {data_name} double 1")?;
    writeln!(f, "LOOKUP_TABLE default")?;
    for d in cell_data {
        writeln!(f, "{d}")?;
    }
    Ok(())
}

/// Little-endian binary encoder for snapshot sections. Plain
/// `Vec<u8>` underneath; the caller frames the stream (magic, version,
/// checksum) -- see `coordinator::checkpoint`.
#[derive(Default)]
pub struct SnapWriter {
    buf: Vec<u8>,
}

impl SnapWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Lengths and counts travel as u64 regardless of host pointer width.
    pub fn put_len(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// Exact: the IEEE bit pattern, never a decimal round-trip.
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_len(s.len());
        self.put_bytes(s.as_bytes());
    }
}

/// Offset-tracking decoder. Every read names what it wanted and the
/// byte offset where the stream ran out, so a truncated or corrupted
/// snapshot produces an actionable error instead of a panic.
pub struct SnapReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> SnapReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Current byte offset into the snapshot.
    pub fn offset(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "snapshot truncated at offset {}: wanted {n} bytes for {what}, {} remain",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    pub fn get_u16(&mut self, what: &str) -> Result<u16> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn get_u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn get_u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    /// Read a length/count and bound it by what the stream could still
    /// hold (`min_elem` = smallest encoding of one element), so a
    /// corrupted length field errors instead of attempting a huge
    /// allocation.
    pub fn get_len(&mut self, min_elem: usize, what: &str) -> Result<usize> {
        let off = self.pos;
        let n = self.get_u64(what)? as usize;
        if n.saturating_mul(min_elem.max(1)) > self.remaining() {
            bail!(
                "snapshot corrupt at offset {off}: length {n} for {what} exceeds {} bytes remaining",
                self.remaining()
            );
        }
        Ok(n)
    }

    pub fn get_f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.get_u64(what)?))
    }

    pub fn get_str(&mut self, what: &str) -> Result<String> {
        let n = self.get_len(1, what)?;
        let b = self.take(n, what)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| format_err!("snapshot corrupt: {what} is not UTF-8"))
    }
}

/// Serialize the full refinement forest: every SoA arena array
/// (including dead slots), root order, the edge-midpoint map (sorted by
/// key for a canonical byte stream), and the allocation free lists in
/// verbatim order. `scratch_leaves` is transient and not stored.
pub fn write_mesh(w: &mut SnapWriter, mesh: &TetMesh) {
    w.put_len(mesh.vertices.len());
    for p in &mesh.vertices {
        w.put_f64(p.x);
        w.put_f64(p.y);
        w.put_f64(p.z);
    }
    let n = mesh.everts.len();
    w.put_len(n);
    for ev in &mesh.everts {
        for &v in ev {
            w.put_u32(v);
        }
    }
    for &t in &mesh.tags {
        w.put_u8(t);
    }
    for &g in &mesh.generations {
        w.put_u16(g);
    }
    for &o in &mesh.owners {
        w.put_u16(o);
    }
    for &p in &mesh.parents {
        w.put_u32(p);
    }
    for c in &mesh.children {
        w.put_u32(c[0]);
        w.put_u32(c[1]);
    }
    for &m in &mesh.mid_vertices {
        w.put_u32(m);
    }
    for &d in &mesh.dead {
        w.put_u8(d as u8);
    }
    w.put_len(mesh.roots.len());
    for &r in &mesh.roots {
        w.put_u32(r);
    }
    let mut edges: Vec<(u64, VertId)> = mesh.edge_mid.iter().map(|(&k, &v)| (k, v)).collect();
    edges.sort_unstable();
    w.put_len(edges.len());
    for (k, v) in edges {
        w.put_u64(k);
        w.put_u32(v);
    }
    w.put_len(mesh.free_elems.len());
    for &e in &mesh.free_elems {
        w.put_u32(e);
    }
    w.put_len(mesh.free_verts.len());
    for &v in &mesh.free_verts {
        w.put_u32(v);
    }
    w.put_len(mesh.n_leaves);
    w.put_u64(mesh.revision);
}

/// Inverse of [`write_mesh`]. Validates id ranges so a corrupted
/// snapshot fails here rather than panicking deep in a leaf scan.
pub fn read_mesh(r: &mut SnapReader) -> Result<TetMesh> {
    let nv = r.get_len(24, "vertex count")?;
    let mut vertices = Vec::with_capacity(nv);
    for _ in 0..nv {
        let x = r.get_f64("vertex x")?;
        let y = r.get_f64("vertex y")?;
        let z = r.get_f64("vertex z")?;
        vertices.push(Vec3::new(x, y, z));
    }
    let n = r.get_len(4, "element slot count")?;
    let mut everts = Vec::with_capacity(n);
    for _ in 0..n {
        let mut ev = [0u32; 4];
        for v in &mut ev {
            *v = r.get_u32("element vertex")?;
        }
        everts.push(ev);
    }
    let mut tags = Vec::with_capacity(n);
    for _ in 0..n {
        tags.push(r.get_u8("element tag")?);
    }
    let mut generations = Vec::with_capacity(n);
    for _ in 0..n {
        generations.push(r.get_u16("element generation")?);
    }
    let mut owners = Vec::with_capacity(n);
    for _ in 0..n {
        owners.push(r.get_u16("element owner")?);
    }
    let mut parents = Vec::with_capacity(n);
    for _ in 0..n {
        parents.push(r.get_u32("element parent")?);
    }
    let mut children = Vec::with_capacity(n);
    for _ in 0..n {
        let a = r.get_u32("element child")?;
        let b = r.get_u32("element child")?;
        children.push([a, b]);
    }
    let mut mid_vertices = Vec::with_capacity(n);
    for _ in 0..n {
        mid_vertices.push(r.get_u32("element mid-vertex")?);
    }
    let mut dead = Vec::with_capacity(n);
    for _ in 0..n {
        dead.push(r.get_u8("element dead flag")? != 0);
    }
    let nroots = r.get_len(4, "root count")?;
    let mut roots = Vec::with_capacity(nroots);
    for _ in 0..nroots {
        roots.push(r.get_u32("root id")?);
    }
    let nedges = r.get_len(12, "edge-midpoint count")?;
    let mut edge_mid = FxHashMap::default();
    for _ in 0..nedges {
        let k = r.get_u64("edge key")?;
        let v = r.get_u32("edge midpoint")?;
        edge_mid.insert(k, v);
    }
    let nfe = r.get_len(4, "free-element count")?;
    let mut free_elems = Vec::with_capacity(nfe);
    for _ in 0..nfe {
        free_elems.push(r.get_u32("free element id")?);
    }
    let nfv = r.get_len(4, "free-vertex count")?;
    let mut free_verts = Vec::with_capacity(nfv);
    for _ in 0..nfv {
        free_verts.push(r.get_u32("free vertex id")?);
    }
    // plain count, not a length prefix: no bytes follow it
    let n_leaves = r.get_u64("leaf count")? as usize;
    let revision = r.get_u64("mesh revision")?;

    let elem_ok = |id: ElemId| id == NONE || (id as usize) < n;
    let vert_ok = |id: VertId| id == NONE || (id as usize) < nv;
    for i in 0..n {
        if everts[i].iter().any(|&v| (v as usize) >= nv) {
            bail!("snapshot corrupt: element {i} references vertex out of range");
        }
        if !elem_ok(parents[i]) || !elem_ok(children[i][0]) || !elem_ok(children[i][1]) {
            bail!("snapshot corrupt: element {i} has tree link out of range");
        }
        if !vert_ok(mid_vertices[i]) {
            bail!("snapshot corrupt: element {i} mid-vertex out of range");
        }
    }
    if roots.iter().any(|&id| (id as usize) >= n) {
        bail!("snapshot corrupt: root id out of range");
    }
    if free_elems.iter().any(|&id| (id as usize) >= n)
        || free_verts.iter().any(|&id| (id as usize) >= nv)
    {
        bail!("snapshot corrupt: free-list id out of range");
    }

    Ok(TetMesh {
        vertices,
        everts,
        tags,
        generations,
        owners,
        parents,
        children,
        mid_vertices,
        dead,
        roots,
        edge_mid,
        free_elems,
        free_verts,
        n_leaves,
        revision,
        scratch_leaves: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generator::cube_mesh;

    #[test]
    fn writes_parseable_vtk() {
        let m = cube_mesh(1);
        let data = vec![0.0; m.n_leaves()];
        let path = std::env::temp_dir().join("phg_dlb_test.vtk");
        write_vtk(&m, &data, "part", &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("POINTS 8 double"));
        assert!(text.contains("CELLS 6 30"));
        assert!(text.contains("SCALARS part double 1"));
        std::fs::remove_file(&path).ok();
    }

    fn refined_mesh() -> TetMesh {
        let mut m = cube_mesh(2);
        let marks: Vec<ElemId> = m.leaves_unordered().into_iter().step_by(3).collect();
        m.refine(&marks);
        let marks: Vec<ElemId> = m.leaves_unordered().into_iter().step_by(5).collect();
        m.refine(&marks);
        m
    }

    #[test]
    fn mesh_snapshot_roundtrips_bitwise() {
        let m = refined_mesh();
        let mut w = SnapWriter::new();
        write_mesh(&mut w, &m);
        let bytes = w.into_bytes();
        let mut r = SnapReader::new(&bytes);
        let back = read_mesh(&mut r).unwrap();
        assert_eq!(r.remaining(), 0);
        assert_eq!(back.n_leaves(), m.n_leaves());
        assert_eq!(back.roots, m.roots);
        assert_eq!(back.revision(), m.revision());
        assert_eq!(back.vertices.len(), m.vertices.len());
        for (a, b) in back.vertices.iter().zip(&m.vertices) {
            assert_eq!(a.x.to_bits(), b.x.to_bits());
            assert_eq!(a.y.to_bits(), b.y.to_bits());
            assert_eq!(a.z.to_bits(), b.z.to_bits());
        }
        let la = back.leaves_unordered();
        let lb = m.leaves_unordered();
        assert_eq!(la, lb);
        for &id in &la {
            assert_eq!(back.verts_of(id), m.verts_of(id));
            assert_eq!(back.owner_of(id), m.owner_of(id));
        }
        back.check_invariants().unwrap();

        // the snapshot encodes the same byte stream when re-serialized
        let mut w2 = SnapWriter::new();
        write_mesh(&mut w2, &back);
        assert_eq!(w2.into_bytes(), bytes);
    }

    #[test]
    fn truncated_snapshot_errors_name_the_offset() {
        let m = refined_mesh();
        let mut w = SnapWriter::new();
        write_mesh(&mut w, &m);
        let bytes = w.into_bytes();
        let cut = bytes.len() / 2;
        let mut r = SnapReader::new(&bytes[..cut]);
        let err = read_mesh(&mut r).unwrap_err().to_string();
        assert!(
            err.contains("offset"),
            "error should name the byte offset: {err}"
        );
    }
}
