//! Legacy-VTK export of the leaf mesh with per-element part ids --
//! lets partitions be eyeballed in ParaView (used by the
//! `partition_gallery` example).

use super::TetMesh;
use std::io::Write;
use std::path::Path;

/// Write the current leaves as an unstructured grid; `cell_data` maps
/// each leaf (in `leaves_unordered` order) to a scalar (e.g. part id).
pub fn write_vtk(
    mesh: &TetMesh,
    cell_data: &[f64],
    data_name: &str,
    path: &Path,
) -> std::io::Result<()> {
    let leaves = mesh.leaves_unordered();
    assert_eq!(cell_data.len(), leaves.len());

    // compact vertex numbering over active vertices
    let mut vert_map = vec![u32::MAX; mesh.vertices.len()];
    let mut verts = Vec::new();
    for &id in &leaves {
        for &v in &mesh.elem(id).verts {
            if vert_map[v as usize] == u32::MAX {
                vert_map[v as usize] = verts.len() as u32;
                verts.push(v);
            }
        }
    }

    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "# vtk DataFile Version 3.0")?;
    writeln!(f, "phg-dlb mesh")?;
    writeln!(f, "ASCII")?;
    writeln!(f, "DATASET UNSTRUCTURED_GRID")?;
    writeln!(f, "POINTS {} double", verts.len())?;
    for &v in &verts {
        let p = mesh.vertices[v as usize];
        writeln!(f, "{} {} {}", p.x, p.y, p.z)?;
    }
    writeln!(f, "CELLS {} {}", leaves.len(), leaves.len() * 5)?;
    for &id in &leaves {
        let v = mesh.elem(id).verts;
        writeln!(
            f,
            "4 {} {} {} {}",
            vert_map[v[0] as usize],
            vert_map[v[1] as usize],
            vert_map[v[2] as usize],
            vert_map[v[3] as usize]
        )?;
    }
    writeln!(f, "CELL_TYPES {}", leaves.len())?;
    for _ in &leaves {
        writeln!(f, "10")?; // VTK_TETRA
    }
    writeln!(f, "CELL_DATA {}", leaves.len())?;
    writeln!(f, "SCALARS {data_name} double 1")?;
    writeln!(f, "LOOKUP_TABLE default")?;
    for d in cell_data {
        writeln!(f, "{d}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generator::cube_mesh;

    #[test]
    fn writes_parseable_vtk() {
        let m = cube_mesh(1);
        let data = vec![0.0; m.n_leaves()];
        let path = std::env::temp_dir().join("phg_dlb_test.vtk");
        write_vtk(&m, &data, "part", &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("POINTS 8 double"));
        assert!(text.contains("CELLS 6 30"));
        assert!(text.contains("SCALARS part double 1"));
        std::fs::remove_file(&path).ok();
    }
}
