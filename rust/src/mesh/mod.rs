//! Tetrahedral mesh with a refinement forest.
//!
//! This is PHG's central substrate: a conforming tet mesh whose
//! elements carry the binary refinement tree produced by bisection
//! (`refine`), the structure the paper's RTK partitioner (§2.1) walks.
//!
//! Elements are tree *nodes*; only leaves are part of the computational
//! mesh. Refined elements stay in the arena as interior tree nodes;
//! coarsened children are tomb-stoned and their slots reused.
//!
//! Bisection follows Maubach's algorithm (tagged simplices), which for
//! the Kuhn-subdivision meshes our generators emit is exactly PHG's
//! bisection: conformity is restored by a closure pass, element quality
//! stays bounded over arbitrary refinement depth, and every bisection
//! yields the left/right child order whose DFS traversal gives the
//! face-connected leaf sequence RTK relies on.
//!
//! Storage is struct-of-arrays (DESIGN.md §11): one flat array per
//! field of the forest node, so the hot consumers -- leaf scans,
//! `LeafTopology::build_for`, `DofMap::build`, assembly's element-dof
//! gather -- stream exactly the fields they touch instead of striding
//! over full `Elem` structs. [`TetMesh::elem`] still hands out an
//! [`Elem`] *view* (by value, `Copy`) for the cold paths; hot loops
//! use the per-field accessors (`verts_of`, `owner_of`, `is_leaf`).

pub mod generator;
pub mod io;
pub mod topology;

use crate::geometry::{tet_volume, BBox, Vec3};
use crate::util::hash::{edge_key, FxHashMap};

pub type VertId = u32;
pub type ElemId = u32;

pub const NONE: u32 = u32::MAX;

/// A by-value view of one forest node, gathered from the mesh's SoA
/// arrays. Cheap to copy; reading a single field through
/// [`TetMesh::elem`] still gathers the whole view, so hot loops should
/// prefer the per-field accessors on [`TetMesh`].
#[derive(Debug, Clone, Copy)]
pub struct Elem {
    /// Vertices in Maubach order; refinement edge is (verts[0], verts[tag]).
    pub verts: [VertId; 4],
    /// Maubach tag, in {1, 2, 3}.
    pub tag: u8,
    /// Tree depth (roots at 0).
    pub generation: u16,
    /// Owning rank of this element's data (partition assignment).
    pub owner: u16,
    pub parent: ElemId,
    /// `[NONE, NONE]` for leaves.
    pub children: [ElemId; 2],
    /// Midpoint vertex created when this element was bisected.
    pub mid_vertex: VertId,
    /// Tomb-stone: slot is free for reuse.
    pub dead: bool,
}

impl Elem {
    pub fn is_leaf(&self) -> bool {
        !self.dead && self.children[0] == NONE
    }

    pub fn refine_edge(&self) -> (VertId, VertId) {
        (self.verts[0], self.verts[self.tag as usize])
    }
}

/// Statistics returned by a refinement pass.
#[derive(Debug, Default, Clone, Copy)]
pub struct RefineStats {
    /// Elements bisected because they were marked.
    pub marked_bisections: usize,
    /// Extra bisections forced by the conformity closure.
    pub closure_bisections: usize,
    /// Closure sweeps until conforming.
    pub closure_passes: usize,
}

#[derive(Debug, Clone)]
pub struct TetMesh {
    pub vertices: Vec<Vec3>,
    // ---- forest arenas, struct-of-arrays: index = ElemId ----
    everts: Vec<[VertId; 4]>,
    tags: Vec<u8>,
    generations: Vec<u16>,
    owners: Vec<u16>,
    parents: Vec<ElemId>,
    children: Vec<[ElemId; 2]>,
    mid_vertices: Vec<VertId>,
    dead: Vec<bool>,
    /// Refinement forest roots in maintained (SFC-sorted) order; this
    /// order is what makes RTK's leaf sequence stable across the whole
    /// adaptive computation (§2.1 of the paper).
    pub roots: Vec<ElemId>,
    /// Edge (packed key) -> midpoint vertex, for every edge ever split
    /// and not yet coarsened away.
    edge_mid: FxHashMap<u64, VertId>,
    free_elems: Vec<ElemId>,
    free_verts: Vec<VertId>,
    n_leaves: usize,
    /// Bumped on every structural change (bisect / coarsen); cached
    /// derived objects (assembly sparsity patterns) key on this.
    revision: u64,
    /// Reusable leaf worklist for the refine closure passes, so a
    /// fixpoint sweep over a million leaves allocates once, ever.
    scratch_leaves: Vec<ElemId>,
}

impl TetMesh {
    /// Build from raw vertices + tets. Tets must be positively oriented
    /// in Maubach vertex order and compatibly tagged (the generators
    /// guarantee this; `tag` defaults to 3, correct for Kuhn meshes).
    pub fn from_raw(vertices: Vec<Vec3>, tets: Vec<[VertId; 4]>) -> Self {
        let n = tets.len();
        Self {
            vertices,
            everts: tets,
            tags: vec![3; n],
            generations: vec![0; n],
            owners: vec![0; n],
            parents: vec![NONE; n],
            children: vec![[NONE, NONE]; n],
            mid_vertices: vec![NONE; n],
            dead: vec![false; n],
            roots: (0..n as u32).collect(),
            edge_mid: FxHashMap::default(),
            free_elems: Vec::new(),
            free_verts: Vec::new(),
            n_leaves: n,
            revision: 0,
            scratch_leaves: Vec::new(),
        }
    }

    pub fn n_leaves(&self) -> usize {
        self.n_leaves
    }

    pub fn n_vertices(&self) -> usize {
        self.vertices.len() - self.free_verts.len()
    }

    /// Number of arena slots (live + dead); valid `ElemId`s are
    /// `0..n_elem_slots`.
    pub fn n_elem_slots(&self) -> usize {
        self.everts.len()
    }

    /// Monotone counter of structural mutations (bisect/coarsen).
    /// Derived caches -- assembly patterns, topologies -- are valid
    /// exactly while this is unchanged. Ownership changes
    /// ([`set_owner`](Self::set_owner)) do *not* bump it: they move
    /// data between ranks but leave the mesh structure (and therefore
    /// any sparsity pattern) intact.
    pub fn revision(&self) -> u64 {
        self.revision
    }

    /// Gather the full node view. Cold-path convenience; hot loops use
    /// the per-field accessors below.
    #[inline]
    pub fn elem(&self, id: ElemId) -> Elem {
        let i = id as usize;
        Elem {
            verts: self.everts[i],
            tag: self.tags[i],
            generation: self.generations[i],
            owner: self.owners[i],
            parent: self.parents[i],
            children: self.children[i],
            mid_vertex: self.mid_vertices[i],
            dead: self.dead[i],
        }
    }

    #[inline]
    pub fn verts_of(&self, id: ElemId) -> [VertId; 4] {
        self.everts[id as usize]
    }

    #[inline]
    pub fn owner_of(&self, id: ElemId) -> u16 {
        self.owners[id as usize]
    }

    /// Assign element `id` to rank `owner` (partitioning / migration).
    #[inline]
    pub fn set_owner(&mut self, id: ElemId, owner: u16) {
        self.owners[id as usize] = owner;
    }

    #[inline]
    pub fn generation_of(&self, id: ElemId) -> u16 {
        self.generations[id as usize]
    }

    #[inline]
    pub fn parent_of(&self, id: ElemId) -> ElemId {
        self.parents[id as usize]
    }

    #[inline]
    pub fn children_of(&self, id: ElemId) -> [ElemId; 2] {
        self.children[id as usize]
    }

    #[inline]
    pub fn is_leaf(&self, id: ElemId) -> bool {
        let i = id as usize;
        !self.dead[i] && self.children[i][0] == NONE
    }

    pub fn elem_coords(&self, id: ElemId) -> [Vec3; 4] {
        let v = self.everts[id as usize];
        [
            self.vertices[v[0] as usize],
            self.vertices[v[1] as usize],
            self.vertices[v[2] as usize],
            self.vertices[v[3] as usize],
        ]
    }

    pub fn centroid(&self, id: ElemId) -> Vec3 {
        let c = self.elem_coords(id);
        (c[0] + c[1] + c[2] + c[3]) / 4.0
    }

    pub fn elem_volume(&self, id: ElemId) -> f64 {
        tet_volume(&self.elem_coords(id))
    }

    /// Bounding box over all *active* vertices (leaf-referenced).
    pub fn bounding_box(&self) -> BBox {
        let mut bb = BBox::empty();
        for id in 0..self.everts.len() as ElemId {
            if self.is_leaf(id) {
                for &v in &self.everts[id as usize] {
                    bb.expand(self.vertices[v as usize]);
                }
            }
        }
        bb
    }

    /// All leaves, arena order (fast scan; no traversal guarantees).
    pub fn leaves_unordered(&self) -> Vec<ElemId> {
        let mut out = Vec::with_capacity(self.n_leaves);
        self.leaves_unordered_into(&mut out);
        out
    }

    /// Arena-order leaf scan into a caller-owned buffer (cleared
    /// first): the allocation-free form the refine closure reuses.
    pub fn leaves_unordered_into(&self, out: &mut Vec<ElemId>) {
        out.clear();
        out.reserve(self.n_leaves);
        // stream the two SoA columns the predicate reads
        for (i, (&d, ch)) in self.dead.iter().zip(&self.children).enumerate() {
            if !d && ch[0] == NONE {
                out.push(i as ElemId);
            }
        }
    }

    /// Leaves in refinement-forest DFS order (left child before right):
    /// the RTK traversal order of §2.1. Iterative DFS to survive deep
    /// trees.
    pub fn leaves_dfs(&self) -> Vec<ElemId> {
        let mut out = Vec::with_capacity(self.n_leaves);
        let mut stack: Vec<ElemId> = Vec::new();
        for &root in self.roots.iter().rev() {
            stack.push(root);
        }
        while let Some(id) = stack.pop() {
            let i = id as usize;
            if self.dead[i] {
                continue;
            }
            let ch = self.children[i];
            if ch[0] == NONE {
                out.push(id);
            } else {
                stack.push(ch[1]);
                stack.push(ch[0]);
            }
        }
        out
    }

    /// Every live split element's refinement edge and its midpoint
    /// vertex, as `(a, b, mid)`: the information the dof transfer
    /// needs to interpolate onto newly created midpoint vertices.
    pub fn split_edges(&self) -> impl Iterator<Item = (VertId, VertId, VertId)> + '_ {
        (0..self.everts.len()).filter_map(move |i| {
            if self.dead[i] || self.children[i][0] == NONE || self.mid_vertices[i] == NONE {
                return None;
            }
            let v = &self.everts[i];
            Some((v[0], v[self.tags[i] as usize], self.mid_vertices[i]))
        })
    }

    /// Sum of all leaf volumes.
    pub fn total_volume(&self) -> f64 {
        self.leaves_unordered()
            .iter()
            .map(|&id| self.elem_volume(id))
            .sum()
    }

    /// Sort the forest roots by a key (used once at setup to order the
    /// initial mesh along an SFC, as the paper prescribes for RTK).
    pub fn sort_roots_by_key(&mut self, key: impl Fn(ElemId) -> u64) {
        self.roots.sort_by_key(|&r| key(r));
    }

    fn alloc_vertex(&mut self, p: Vec3) -> VertId {
        if let Some(v) = self.free_verts.pop() {
            self.vertices[v as usize] = p;
            v
        } else {
            self.vertices.push(p);
            (self.vertices.len() - 1) as VertId
        }
    }

    fn alloc_elem(&mut self, e: Elem) -> ElemId {
        if let Some(id) = self.free_elems.pop() {
            let i = id as usize;
            self.everts[i] = e.verts;
            self.tags[i] = e.tag;
            self.generations[i] = e.generation;
            self.owners[i] = e.owner;
            self.parents[i] = e.parent;
            self.children[i] = e.children;
            self.mid_vertices[i] = e.mid_vertex;
            self.dead[i] = e.dead;
            id
        } else {
            self.everts.push(e.verts);
            self.tags.push(e.tag);
            self.generations.push(e.generation);
            self.owners.push(e.owner);
            self.parents.push(e.parent);
            self.children.push(e.children);
            self.mid_vertices.push(e.mid_vertex);
            self.dead.push(e.dead);
            (self.everts.len() - 1) as ElemId
        }
    }

    /// Midpoint vertex of edge (a, b), creating it on first use. The
    /// shared map is what keeps simultaneous bisections of the same
    /// edge (from different elements) conforming.
    fn edge_midpoint(&mut self, a: VertId, b: VertId) -> VertId {
        let key = edge_key(a, b);
        if let Some(&v) = self.edge_mid.get(&key) {
            return v;
        }
        let p = self.vertices[a as usize].midpoint(self.vertices[b as usize]);
        let v = self.alloc_vertex(p);
        self.edge_mid.insert(key, v);
        v
    }

    /// Bisect one leaf (Maubach). Children inherit the owner -- new
    /// elements are born on their parent's process, which is exactly
    /// the data-locality behaviour whose erosion the DLB fixes.
    pub fn bisect(&mut self, id: ElemId) -> [ElemId; 2] {
        let i = id as usize;
        debug_assert!(self.is_leaf(id), "bisect of non-leaf {id}");
        let verts = self.everts[i];
        let tag = self.tags[i];
        let generation = self.generations[i];
        let owner = self.owners[i];
        let k = tag as usize;
        let z = self.edge_midpoint(verts[0], verts[k]);

        // Maubach child vertex lists.
        let mut c1 = verts;
        c1[k] = z;
        let mut c2 = [0u32; 4];
        for (i, slot) in c2.iter_mut().enumerate().take(k) {
            *slot = verts[i + 1];
        }
        c2[k] = z;
        for (i, slot) in c2.iter_mut().enumerate().skip(k + 1) {
            *slot = verts[i];
        }
        let new_tag = if tag > 1 { tag - 1 } else { 3 };

        let mk = |verts: [VertId; 4]| Elem {
            verts,
            tag: new_tag,
            generation: generation + 1,
            owner,
            parent: id,
            children: [NONE, NONE],
            mid_vertex: NONE,
            dead: false,
        };
        let a = self.alloc_elem(mk(c1));
        let b = self.alloc_elem(mk(c2));
        self.children[id as usize] = [a, b];
        self.mid_vertices[id as usize] = z;
        self.n_leaves += 1; // one leaf became two
        self.revision += 1;
        [a, b]
    }

    /// True if any edge of leaf `id` carries a registered midpoint,
    /// i.e. a neighbour has split an edge this leaf still spans.
    fn has_hanging_edge(&self, id: ElemId) -> bool {
        let v = self.everts[id as usize];
        for i in 0..4 {
            for j in (i + 1)..4 {
                if self.edge_mid.contains_key(&edge_key(v[i], v[j])) {
                    return true;
                }
            }
        }
        false
    }

    /// Refine: bisect all `marked` leaves, then run the conformity
    /// closure (bisect any leaf spanning a split edge) to a fixpoint.
    pub fn refine(&mut self, marked: &[ElemId]) -> RefineStats {
        let mut stats = RefineStats::default();
        for &id in marked {
            if self.is_leaf(id) {
                self.bisect(id);
                stats.marked_bisections += 1;
            }
        }
        // Closure to fixpoint. Each pass scans current leaves; new
        // leaves produced in a pass are checked in the next pass. The
        // worklist buffer is owned by the mesh and reused across
        // passes *and* across refine calls.
        const MAX_PASSES: usize = 1000;
        let mut worklist = std::mem::take(&mut self.scratch_leaves);
        loop {
            stats.closure_passes += 1;
            assert!(
                stats.closure_passes < MAX_PASSES,
                "conformity closure did not terminate (incompatible mesh tags?)"
            );
            let mut any = false;
            self.leaves_unordered_into(&mut worklist);
            for k in 0..worklist.len() {
                let id = worklist[k];
                if self.is_leaf(id) && self.has_hanging_edge(id) {
                    self.bisect(id);
                    stats.closure_bisections += 1;
                    any = true;
                }
            }
            if !any {
                break;
            }
        }
        self.scratch_leaves = worklist;
        stats
    }

    /// Coarsen: undo bisections whose midpoint patch is fully marked.
    /// A parent P (children both leaves) is *coarsenable* iff every
    /// leaf incident to P's midpoint vertex is itself a child-of-a-
    /// parent with the same midpoint, with a leaf sibling, and marked.
    /// Whole patches coarsen atomically, preserving conformity.
    /// Returns the number of parents un-refined.
    pub fn coarsen(&mut self, marked: &[ElemId]) -> usize {
        use std::collections::HashSet;
        let marked: HashSet<ElemId> = marked.iter().copied().collect();

        // Candidate parents: both children are leaves and marked.
        let mut patch_parents: FxHashMap<VertId, Vec<ElemId>> = FxHashMap::default();
        for i in 0..self.everts.len() {
            if self.dead[i] || self.children[i][0] == NONE {
                continue;
            }
            let [a, b] = self.children[i];
            if self.is_leaf(a) && self.is_leaf(b) && marked.contains(&a) && marked.contains(&b) {
                patch_parents
                    .entry(self.mid_vertices[i])
                    .or_default()
                    .push(i as ElemId);
            }
        }
        if patch_parents.is_empty() {
            return 0;
        }

        // Leaf incidence restricted to candidate midpoints. Reuses the
        // mesh-owned leaf worklist (same scratch the refine closure
        // uses; the two never run concurrently).
        let mut leaves = std::mem::take(&mut self.scratch_leaves);
        self.leaves_unordered_into(&mut leaves);
        let mut incidence: FxHashMap<VertId, Vec<ElemId>> = FxHashMap::default();
        for &id in &leaves {
            for &v in &self.everts[id as usize] {
                if patch_parents.contains_key(&v) {
                    incidence.entry(v).or_default().push(id);
                }
            }
        }
        self.scratch_leaves = leaves;

        let mut coarsened = 0;
        for (&mid, parents) in patch_parents.iter() {
            let incident = match incidence.get(&mid) {
                Some(v) => v,
                None => continue,
            };
            // Every incident leaf must be a child of one of `parents`.
            let children: std::collections::HashSet<ElemId> = parents
                .iter()
                .flat_map(|&p| self.children[p as usize])
                .collect();
            if !incident.iter().all(|l| children.contains(l)) {
                continue;
            }
            // Un-refine the whole patch.
            for &p in parents {
                let [a, b] = self.children[p as usize];
                self.dead[a as usize] = true;
                self.dead[b as usize] = true;
                self.free_elems.push(a);
                self.free_elems.push(b);
                self.children[p as usize] = [NONE, NONE];
                self.mid_vertices[p as usize] = NONE;
                self.n_leaves -= 1;
                coarsened += 1;
            }
            // Drop the midpoint vertex and its edge-map entry.
            // The parent refinement edge is the same for all patch
            // parents (they share the split edge).
            let p0 = parents[0] as usize;
            let (a, b) = (self.everts[p0][0], self.everts[p0][self.tags[p0] as usize]);
            self.edge_mid.remove(&edge_key(a, b));
            self.free_verts.push(mid);
            coarsened = coarsened.max(1);
        }
        if coarsened > 0 {
            self.revision += 1;
        }
        coarsened
    }

    /// Verify structural invariants (test / debug helper):
    /// conformity (no leaf spans a split edge; every interior face is
    /// shared by exactly 2 leaves), tree integrity, and leaf count.
    pub fn check_invariants(&self) -> Result<(), String> {
        let leaves = self.leaves_unordered();
        if leaves.len() != self.n_leaves {
            return Err(format!(
                "leaf count mismatch: cached {} actual {}",
                self.n_leaves,
                leaves.len()
            ));
        }
        for &id in &leaves {
            if self.has_hanging_edge(id) {
                return Err(format!("leaf {id} spans a split edge"));
            }
        }
        // face conformity
        let mut face_count: FxHashMap<u128, u32> = FxHashMap::default();
        for &id in &leaves {
            let v = self.everts[id as usize];
            for f in crate::mesh::topology::FACES {
                let key = crate::util::hash::face_key(
                    v[f[0] as usize],
                    v[f[1] as usize],
                    v[f[2] as usize],
                );
                *face_count.entry(key).or_insert(0) += 1;
            }
        }
        for (_, c) in face_count {
            if c > 2 {
                return Err(format!("face shared by {c} leaves"));
            }
        }
        // tree integrity
        for i in 0..self.everts.len() {
            if self.dead[i] {
                continue;
            }
            if self.children[i][0] != NONE {
                for &c in &self.children[i] {
                    if self.dead[c as usize] {
                        return Err(format!("elem {i} has dead child {c}"));
                    }
                    if self.parents[c as usize] != i as u32 {
                        return Err(format!("child {c} parent link broken"));
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::generator;
    use super::*;

    fn unit_cube() -> TetMesh {
        generator::box_mesh(1, 1, 1, Vec3::ZERO, Vec3::new(1.0, 1.0, 1.0))
    }

    #[test]
    fn cube_mesh_basics() {
        let m = unit_cube();
        assert_eq!(m.n_leaves(), 6);
        assert_eq!(m.n_vertices(), 8);
        assert!((m.total_volume() - 1.0).abs() < 1e-12);
        m.check_invariants().unwrap();
    }

    #[test]
    fn uniform_refine_doubles_leaves_preserves_volume() {
        let mut m = unit_cube();
        for step in 0..4 {
            let leaves = m.leaves_unordered();
            let stats = m.refine(&leaves);
            assert_eq!(stats.marked_bisections, leaves.len());
            m.check_invariants()
                .unwrap_or_else(|e| panic!("step {step}: {e}"));
            assert!((m.total_volume() - 1.0).abs() < 1e-12);
        }
        assert!(m.n_leaves() >= 6 * 16);
    }

    #[test]
    fn local_refine_stays_conforming() {
        let mut m = unit_cube();
        // refine around one corner repeatedly
        for _ in 0..6 {
            let marked: Vec<ElemId> = m
                .leaves_unordered()
                .into_iter()
                .filter(|&id| m.centroid(id).norm() < 0.95)
                .collect();
            assert!(!marked.is_empty());
            m.refine(&marked);
            m.check_invariants().unwrap();
            assert!((m.total_volume() - 1.0).abs() < 1e-12);
        }
        // graded, not uniform: far-corner elements stay coarser
        let gens: Vec<u16> = m
            .leaves_unordered()
            .iter()
            .map(|&id| m.elem(id).generation)
            .collect();
        let gmax = *gens.iter().max().unwrap();
        let gmin = *gens.iter().min().unwrap();
        assert!(m.n_leaves() > 30);
        assert!(gmax > gmin, "refinement was uniform (gmax {gmax} gmin {gmin})");
    }

    #[test]
    fn dfs_order_visits_all_leaves_once() {
        let mut m = unit_cube();
        m.refine(&m.leaves_unordered());
        m.refine(&m.leaves_unordered());
        let dfs = m.leaves_dfs();
        assert_eq!(dfs.len(), m.n_leaves());
        let mut sorted = dfs.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), dfs.len());
    }

    #[test]
    fn dfs_consecutive_leaves_share_vertices() {
        // Maubach sibling order: consecutive DFS leaves under the same
        // parent share a face; across parents they still overwhelmingly
        // share >= 1 vertex, which is the locality RTK exploits.
        let mut m = unit_cube();
        for _ in 0..3 {
            m.refine(&m.leaves_unordered());
        }
        let dfs = m.leaves_dfs();
        let mut share = 0;
        for w in dfs.windows(2) {
            let a = m.elem(w[0]).verts;
            let b = m.elem(w[1]).verts;
            let common = a.iter().filter(|x| b.contains(x)).count();
            if common >= 1 {
                share += 1;
            }
        }
        assert!(
            share as f64 >= 0.8 * (dfs.len() - 1) as f64,
            "only {share}/{} consecutive pairs share a vertex",
            dfs.len() - 1
        );
    }

    #[test]
    fn refine_then_coarsen_roundtrip() {
        let mut m = unit_cube();
        let v0 = m.total_volume();
        let n0 = m.n_leaves();
        m.refine(&m.leaves_unordered());
        let n1 = m.n_leaves();
        assert!(n1 > n0);
        // coarsen everything back
        let mut guard = 0;
        while m.n_leaves() > n0 {
            let c = m.coarsen(&m.leaves_unordered());
            if c == 0 {
                break;
            }
            m.check_invariants().unwrap();
            guard += 1;
            assert!(guard < 20);
        }
        assert_eq!(m.n_leaves(), n0);
        assert!((m.total_volume() - v0).abs() < 1e-12);
        m.check_invariants().unwrap();
    }

    #[test]
    fn coarsen_respects_partial_marks() {
        let mut m = unit_cube();
        m.refine(&m.leaves_unordered());
        let n1 = m.n_leaves();
        // mark only half the leaves: patches containing unmarked leaves
        // must survive
        let leaves = m.leaves_unordered();
        let half = &leaves[..leaves.len() / 2];
        m.coarsen(half);
        m.check_invariants().unwrap();
        assert!(m.n_leaves() <= n1);
        assert!((m.total_volume() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn owners_inherited_on_refine() {
        let mut m = unit_cube();
        for (i, &id) in m.leaves_unordered().iter().enumerate() {
            m.set_owner(id, (i % 3) as u16);
        }
        let before: FxHashMap<ElemId, u16> = m
            .leaves_unordered()
            .into_iter()
            .map(|id| (id, m.elem(id).owner))
            .collect();
        m.refine(&m.leaves_unordered());
        for id in m.leaves_unordered() {
            let mut anc = id;
            while m.elem(anc).parent != NONE {
                anc = m.elem(anc).parent;
            }
            // every leaf's owner matches some original ancestor's owner
            if let Some(&o) = before.get(&anc) {
                assert_eq!(m.elem(id).owner, o);
            }
        }
    }

    #[test]
    fn element_quality_bounded_under_deep_refinement() {
        use crate::geometry::tet_quality;
        let mut m = unit_cube();
        for _ in 0..6 {
            m.refine(&m.leaves_unordered());
        }
        let qmin = m
            .leaves_unordered()
            .iter()
            .map(|&id| tet_quality(&m.elem_coords(id)))
            .fold(f64::INFINITY, f64::min);
        // Maubach bisection cycles through 3 shape classes; quality is
        // bounded below uniformly in refinement depth.
        assert!(qmin > 0.1, "qmin = {qmin}");
    }

    #[test]
    fn generation_increments() {
        let mut m = unit_cube();
        m.refine(&m.leaves_unordered());
        for id in m.leaves_unordered() {
            assert_eq!(m.elem(id).generation, 1);
        }
    }

    #[test]
    fn revision_tracks_structure_not_ownership() {
        let mut m = unit_cube();
        let r0 = m.revision();
        m.set_owner(0, 2);
        assert_eq!(m.revision(), r0, "ownership must not invalidate caches");
        m.refine(&m.leaves_unordered());
        let r1 = m.revision();
        assert!(r1 > r0, "refine must bump the revision");
        while m.coarsen(&m.leaves_unordered()) > 0 {}
        assert!(m.revision() > r1, "coarsen must bump the revision");
    }

    #[test]
    fn split_edges_cover_all_midpoints() {
        let mut m = unit_cube();
        m.refine(&m.leaves_unordered());
        let mids: Vec<_> = m.split_edges().collect();
        assert!(!mids.is_empty());
        for (a, b, mid) in mids {
            let pm = m.vertices[mid as usize];
            let pa = m.vertices[a as usize];
            let pb = m.vertices[b as usize];
            assert!((pm - pa.midpoint(pb)).norm() < 1e-12);
        }
    }
}
