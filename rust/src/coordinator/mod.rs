//! The adaptive driver: PHG's computation loop with dynamic load
//! balancing as a first-class phase.
//!
//! Per adaptive step:  solve -> estimate -> mark -> refine/coarsen ->
//! check imbalance -> (partition -> remap -> migrate)?  with every
//! phase timed into a [`timeline::StepRecord`]. The DLB policy (§6 of
//! DESIGN.md) triggers on the load imbalance factor lambda; the
//! per-method trigger mirrors the paper's repartition counts (Table 1:
//! the graph method repartitions ~3x more often because it chases
//! partition quality).

pub mod report;
pub mod timeline;

use crate::adapt::{mark_coarsen_threshold, mark_max, residual_indicator};
use crate::dist::{migrate, Distribution, NetworkModel};
use crate::fem::problems::{
    parabolic_exact, parabolic_step, solve_helmholtz,
};
use crate::fem::{DofMap, SolverOpts};
use crate::mesh::topology::LeafTopology;
use crate::mesh::{ElemId, TetMesh};
use crate::partition::sfc::{sfc_keys, Curve, Normalization, SfcPartitioner};
use crate::partition::{
    graph::MultilevelGraph, rcb::Rcb, rib::Rib, rtk::RefinementTree, CommOp, PartitionInput,
    Partitioner,
};
use crate::remap::{apply_map, oliker_biswas, SimilarityMatrix};
use crate::runtime::Runtime;
use crate::util::timer::Stopwatch;
use timeline::{StepRecord, Timeline};

/// Look up a partitioner by its paper name.
pub fn partitioner_by_name(name: &str) -> Option<Box<dyn Partitioner>> {
    match name {
        "RTK" => Some(Box::new(RefinementTree::new())),
        "MSFC" => Some(Box::new(SfcPartitioner::msfc())),
        "PHG/HSFC" => Some(Box::new(SfcPartitioner::phg_hsfc())),
        "Zoltan/HSFC" => Some(Box::new(SfcPartitioner::zoltan_hsfc())),
        "RCB" => Some(Box::new(Rcb::new())),
        "RIB" => Some(Box::new(Rib::new())),
        "ParMETIS" => Some(Box::new(MultilevelGraph::parmetis_like())),
        "Mitchell-RT" => Some(Box::new(
            crate::partition::mitchell::MitchellRefinementTree::new(),
        )),
        _ => None,
    }
}

/// All method names in the paper's presentation order.
pub const METHOD_NAMES: [&str; 6] = [
    "RCB",
    "ParMETIS",
    "RTK",
    "MSFC",
    "PHG/HSFC",
    "Zoltan/HSFC",
];

#[derive(Debug, Clone)]
pub struct DriverConfig {
    /// virtual process count (the paper: 128 / 192)
    pub nparts: usize,
    /// partitioning method name
    pub method: String,
    /// DLB trigger: repartition when lambda exceeds this
    pub lambda_trigger: f64,
    /// marking fraction for refinement (max-strategy theta)
    pub theta_refine: f64,
    /// coarsening threshold (<= theta_coarsen * max eta); 0 = never
    pub theta_coarsen: f64,
    /// stop refining past this many leaves
    pub max_elements: usize,
    pub solver: SolverOpts,
    pub use_pjrt: bool,
    pub nsteps: usize,
    /// parabolic time step (example 3.2); ignored by Helmholtz
    pub dt: f64,
}

impl Default for DriverConfig {
    fn default() -> Self {
        Self {
            nparts: 16,
            method: "PHG/HSFC".to_string(),
            lambda_trigger: 1.2,
            theta_refine: 0.5,
            theta_coarsen: 0.0,
            max_elements: 200_000,
            solver: SolverOpts::default(),
            use_pjrt: true,
            nsteps: 10,
            dt: 1e-3,
        }
    }
}

/// The driver owns the mesh, the virtual distribution, and the method.
pub struct AdaptiveDriver {
    pub mesh: TetMesh,
    pub cfg: DriverConfig,
    pub net: NetworkModel,
    pub dist: Distribution,
    pub partitioner: Box<dyn Partitioner>,
    pub timeline: Timeline,
    pub runtime: Option<Runtime>,
    /// current solution (dof vector) and its dof map, for transfer
    u: Vec<f64>,
    dof: Option<DofMap>,
}

impl AdaptiveDriver {
    pub fn new(mut mesh: TetMesh, cfg: DriverConfig) -> Self {
        let partitioner =
            partitioner_by_name(&cfg.method).unwrap_or_else(|| panic!("unknown method {}", cfg.method));
        let net = NetworkModel::infiniband(cfg.nparts);
        let dist = Distribution::new(cfg.nparts);
        // the paper: order the initial mesh (tree roots) along an SFC
        // and maintain that order for the whole computation
        let leaves = mesh.leaves_unordered();
        let keys = sfc_keys(
            &mesh,
            &mesh.roots.clone(),
            Curve::Hilbert,
            Normalization::AspectPreserving,
        );
        let key_of: std::collections::HashMap<ElemId, u64> =
            mesh.roots.iter().copied().zip(keys).collect();
        mesh.sort_roots_by_key(|r| key_of[&r]);
        dist.assign_blocks(&mut mesh, &leaves);

        let runtime = if cfg.use_pjrt {
            Runtime::open_default().ok()
        } else {
            None
        };
        Self {
            mesh,
            cfg,
            net,
            dist,
            partitioner,
            timeline: Timeline::new(),
            runtime,
            u: Vec::new(),
            dof: None,
        }
    }

    fn modeled_comm(&self, ops: &[CommOp]) -> f64 {
        self.net.sequence_time(ops)
    }

    /// Run the DLB phase if the imbalance exceeds the trigger.
    /// Returns the updated record.
    fn maybe_rebalance(
        &mut self,
        leaves: &[ElemId],
        weights: &[f64],
        rec: &mut StepRecord,
    ) {
        rec.imbalance_before = self.dist.imbalance(&self.mesh, leaves, weights);
        if rec.imbalance_before <= self.cfg.lambda_trigger {
            rec.imbalance_after = rec.imbalance_before;
            return;
        }
        let owners: Vec<u16> = leaves.iter().map(|&id| self.mesh.elem(id).owner).collect();
        let input = PartitionInput::from_mesh(&self.mesh, leaves, weights, &owners, self.cfg.nparts);

        let sw = Stopwatch::start();
        let result = self.partitioner.partition(&input);
        rec.partition_time = sw.elapsed();
        rec.partition_comm_modeled = self.modeled_comm(&result.comm);

        // subgrid -> process mapping (§2.4)
        let sw = Stopwatch::start();
        let sim = SimilarityMatrix::build(&owners, &result.parts, weights, self.cfg.nparts, self.cfg.nparts);
        let remap = oliker_biswas(&sim);
        let mut parts = result.parts;
        apply_map(&mut parts, &remap.map);
        rec.partition_comm_modeled += self.modeled_comm(&remap.comm);
        let total_w: f64 = weights.iter().sum();
        rec.remap_kept_fraction = if total_w > 0.0 { remap.kept / total_w } else { 1.0 };

        let out = migrate(&mut self.mesh, leaves, &parts, weights, &self.net);
        rec.migrate_time = sw.elapsed();
        rec.migrate_modeled = out.modeled_time;
        rec.migration = Some(out.volume);
        rec.repartitioned = true;
        rec.imbalance_after = self.dist.imbalance(&self.mesh, leaves, weights);
    }

    /// Modeled per-iteration halo exchange from the *exact* ghost
    /// layer of the current partition: the bottleneck rank's shared-
    /// vertex bytes plus a latency charge per neighbour rank, per CG
    /// iteration. Partition quality enters the solve time through
    /// here, exactly as in the paper's Fig 3.4.
    fn solve_comm_model(&self, halo: &crate::dist::Halo, iterations: usize) -> f64 {
        iterations as f64
            * (halo.max_neighbors() as f64 * self.net.alpha
                + halo.max_rank_bytes() as f64 * self.net.beta)
    }

    /// One adaptive step of the Helmholtz experiment (example 3.1).
    /// Returns false when the growth budget is exhausted.
    pub fn helmholtz_step(&mut self) -> bool {
        let step = self.timeline.records.len();
        let mut rec = StepRecord::new(step);
        rec.nparts = self.cfg.nparts;

        let sw_topo = Stopwatch::start();
        let topo = LeafTopology::build(&self.mesh);
        let dof = DofMap::build(&self.mesh, &topo);
        let mut setup_time = sw_topo.elapsed();
        rec.n_elements = topo.n_leaves();
        rec.n_dofs = dof.n_dofs;

        // ---- solve
        let sw = Stopwatch::start();
        let u0 = self
            .dof
            .as_ref()
            .map(|old| dof.transfer_from(old, &self.u, &self.mesh, 0.0));
        let sol = solve_helmholtz(
            &self.mesh,
            &topo,
            &dof,
            self.runtime.as_ref(),
            &self.cfg.solver,
            u0.as_deref(),
        );
        let solve_wall = sw.elapsed();
        // split: assembly happens inside solve_helmholtz; attribute by
        // re-measuring is overkill -- charge it all to solve, keep
        // assemble_time for the explicit assembly benches
        rec.solve_time = solve_wall;
        rec.solve_iterations = sol.stats.iterations;
        rec.l2_error = sol.l2_error;
        rec.max_error = sol.max_error;

        // partition quality affects the halo model
        let owners_parts: Vec<u16> = topo
            .leaves
            .iter()
            .map(|&id| self.mesh.elem(id).owner)
            .collect();
        let halo = crate::dist::Halo::build(&self.mesh, &topo, &owners_parts, self.cfg.nparts);
        rec.interface_faces = halo.interface_faces;
        rec.solve_comm_modeled = self.solve_comm_model(&halo, sol.stats.iterations);

        // ---- estimate + mark + refine
        let sw = Stopwatch::start();
        let eta = residual_indicator(
            &self.mesh,
            &topo,
            &{
                // indicator needs vertex-indexed values
                let mut by_vertex = vec![0.0; self.mesh.vertices.len()];
                for (d, &v) in dof.vertex_of_dof.iter().enumerate() {
                    by_vertex[v as usize] = sol.u[d];
                }
                by_vertex
            },
            crate::fem::problems::helmholtz_source,
            1.0,
        );
        rec.estimate_time = sw.elapsed();

        let sw = Stopwatch::start();
        let can_grow = self.mesh.n_leaves() < self.cfg.max_elements;
        if can_grow {
            let marked = mark_max(&topo.leaves, &eta, self.cfg.theta_refine);
            self.mesh.refine(&marked);
        }
        rec.adapt_time = sw.elapsed() + setup_time;
        setup_time = 0.0;
        let _ = setup_time;

        // ---- DLB
        self.u = sol.u;
        self.dof = Some(dof);
        let leaves = self.mesh.leaves_unordered();
        let weights = vec![1.0f64; leaves.len()];
        self.maybe_rebalance(&leaves, &weights, &mut rec);

        self.timeline.push(rec);
        can_grow
    }

    /// One time step of the parabolic experiment (example 3.2):
    /// advance, then refine ahead of / coarsen behind the moving peak.
    pub fn parabolic_time_step(&mut self, t_next: f64) {
        let step = self.timeline.records.len();
        let mut rec = StepRecord::new(step);
        rec.nparts = self.cfg.nparts;

        let sw_setup = Stopwatch::start();
        let topo = LeafTopology::build(&self.mesh);
        let dof = DofMap::build(&self.mesh, &topo);
        let setup = sw_setup.elapsed();
        rec.n_elements = topo.n_leaves();
        rec.n_dofs = dof.n_dofs;

        // transfer previous solution (or initial condition)
        let u_prev = match (&self.dof, self.u.len()) {
            (Some(old), n) if n > 0 => dof.transfer_from(old, &self.u, &self.mesh, 0.0),
            _ => dof.eval_at_dofs(&self.mesh, |p| parabolic_exact(p, t_next - self.cfg.dt)),
        };

        let sw = Stopwatch::start();
        let out = parabolic_step(
            &self.mesh,
            &topo,
            &dof,
            self.runtime.as_ref(),
            &self.cfg.solver,
            &u_prev,
            t_next,
            self.cfg.dt,
        );
        rec.solve_time = sw.elapsed();
        rec.solve_iterations = out.stats.iterations;
        rec.l2_error = out.l2_error;
        rec.max_error = out.max_error;

        let owners_parts: Vec<u16> = topo
            .leaves
            .iter()
            .map(|&id| self.mesh.elem(id).owner)
            .collect();
        let halo = crate::dist::Halo::build(&self.mesh, &topo, &owners_parts, self.cfg.nparts);
        rec.interface_faces = halo.interface_faces;
        rec.solve_comm_modeled = self.solve_comm_model(&halo, out.stats.iterations);

        // ---- adapt around the moving peak: geometric indicator
        let sw = Stopwatch::start();
        let eta = crate::adapt::geometric_indicator(
            &self.mesh,
            &topo.leaves,
            crate::fem::problems::peak_center(t_next),
            0.25,
        );
        rec.estimate_time = sw.elapsed();

        let sw = Stopwatch::start();
        if self.mesh.n_leaves() < self.cfg.max_elements {
            let marked = mark_max(&topo.leaves, &eta, self.cfg.theta_refine);
            self.mesh.refine(&marked);
        }
        if self.cfg.theta_coarsen > 0.0 {
            // recompute over the *new* leaf set
            let leaves2 = self.mesh.leaves_unordered();
            let eta2 = crate::adapt::geometric_indicator(
                &self.mesh,
                &leaves2,
                crate::fem::problems::peak_center(t_next),
                0.25,
            );
            let cmarks = mark_coarsen_threshold(&leaves2, &eta2, self.cfg.theta_coarsen);
            self.mesh.coarsen(&cmarks);
        }
        rec.adapt_time = sw.elapsed() + setup;

        self.u = out.u;
        self.dof = Some(dof);

        let leaves = self.mesh.leaves_unordered();
        let weights = vec![1.0f64; leaves.len()];
        self.maybe_rebalance(&leaves, &weights, &mut rec);

        self.timeline.push(rec);
    }

    /// Run the full Helmholtz experiment.
    pub fn run_helmholtz(&mut self) {
        for _ in 0..self.cfg.nsteps {
            if !self.helmholtz_step() {
                break;
            }
        }
    }

    /// Run the full parabolic experiment over [t0, t0 + nsteps*dt].
    pub fn run_parabolic(&mut self, t0: f64) {
        for n in 1..=self.cfg.nsteps {
            self.parabolic_time_step(t0 + n as f64 * self.cfg.dt);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generator;

    fn quick_cfg(method: &str) -> DriverConfig {
        DriverConfig {
            nparts: 4,
            method: method.to_string(),
            lambda_trigger: 1.1,
            theta_refine: 0.5,
            theta_coarsen: 0.0,
            max_elements: 20_000,
            solver: SolverOpts {
                tol: 1e-5,
                max_iter: 500,
            },
            use_pjrt: false, // native engines: fast unit tests
            nsteps: 3,
            dt: 1e-3,
        }
    }

    #[test]
    fn registry_knows_all_methods() {
        for name in METHOD_NAMES {
            assert!(partitioner_by_name(name).is_some(), "missing {name}");
        }
        assert!(partitioner_by_name("RIB").is_some());
        assert!(partitioner_by_name("nope").is_none());
    }

    #[test]
    fn helmholtz_loop_runs_and_rebalances() {
        let mesh = generator::cube_mesh(2);
        let mut d = AdaptiveDriver::new(mesh, quick_cfg("RTK"));
        d.run_helmholtz();
        assert_eq!(d.timeline.records.len(), 3);
        // mesh grew
        let n0 = d.timeline.records[0].n_elements;
        let n2 = d.timeline.records[2].n_elements;
        assert!(n2 > n0, "{n0} -> {n2}");
        // every step that exceeded the trigger was rebalanced back
        for r in &d.timeline.records {
            if r.repartitioned {
                assert!(r.imbalance_after <= r.imbalance_before + 1e-9);
                assert!(r.partition_time > 0.0);
            }
        }
        // solves happened and converged
        for r in &d.timeline.records {
            assert!(r.solve_iterations > 0);
            assert!(r.n_dofs > 0);
        }
    }

    #[test]
    fn all_methods_drive_the_loop() {
        for name in METHOD_NAMES {
            let mesh = generator::cube_mesh(2);
            let mut cfg = quick_cfg(name);
            cfg.nsteps = 2;
            let mut d = AdaptiveDriver::new(mesh, cfg);
            d.run_helmholtz();
            assert_eq!(d.timeline.records.len(), 2, "method {name}");
            let last = d.timeline.records.last().unwrap();
            assert!(
                last.imbalance_after < 1.6,
                "method {name}: lambda {} not controlled",
                last.imbalance_after
            );
        }
    }

    #[test]
    fn parabolic_loop_refines_and_coarsens() {
        let mesh = generator::cube_mesh(3);
        let mut cfg = quick_cfg("PHG/HSFC");
        cfg.theta_coarsen = 0.02;
        cfg.nsteps = 4;
        cfg.dt = 2e-3;
        let mut d = AdaptiveDriver::new(mesh, cfg);
        d.run_parabolic(0.0);
        assert_eq!(d.timeline.records.len(), 4);
        for r in &d.timeline.records {
            assert!(r.max_error < 0.2, "error {}", r.max_error);
        }
        d.mesh.check_invariants().unwrap();
    }

    #[test]
    fn error_decreases_over_adaptive_steps() {
        let mesh = generator::cube_mesh(2);
        let mut cfg = quick_cfg("RTK");
        cfg.nsteps = 4;
        cfg.theta_refine = 0.3;
        let mut d = AdaptiveDriver::new(mesh, cfg);
        d.run_helmholtz();
        let first = d.timeline.records.first().unwrap().l2_error;
        let last = d.timeline.records.last().unwrap().l2_error;
        assert!(
            last < first,
            "adaptive refinement did not reduce error: {first} -> {last}"
        );
    }

    #[test]
    fn timeline_csv_roundtrip() {
        let mesh = generator::cube_mesh(2);
        let mut cfg = quick_cfg("MSFC");
        cfg.nsteps = 2;
        let mut d = AdaptiveDriver::new(mesh, cfg);
        d.run_helmholtz();
        let csv = d.timeline.to_csv();
        assert_eq!(csv.lines().count(), 3); // header + 2 rows
    }
}
