//! The adaptive driver: PHG's computation loop with dynamic load
//! balancing as a first-class phase.
//!
//! Per adaptive step:  solve -> estimate -> mark -> refine/coarsen ->
//! evaluate the trigger policy -> (partition -> remap -> migrate)?
//! with every phase timed into a [`timeline::StepRecord`]. The loop
//! is written exactly once ([`AdaptiveDriver::step`]) and is generic
//! over the problem: a [`Scenario`] owns the solve and the
//! refinement signals (DESIGN.md §8), while the DLB machinery is
//! composed from the [`crate::dlb`] subsystem: a [`TriggerPolicy`]
//! decides *when*, a [`WeightModel`] decides what load means, and
//! the [`RebalancePipeline`] executes the paper's partition ->
//! Oliker-Biswas remap -> migrate sequence (DESIGN.md §6).

pub mod checkpoint;
pub mod report;
pub mod timeline;

use crate::adapt::{mark_coarsen_threshold, mark_max};
use crate::dist::{Distribution, NetworkModel};
use crate::dlb::{
    dof_shares, trigger_by_name, weight_model_by_name, CostEstimate, Registry,
    RebalancePipeline, RepartitionStrategy, TriggerContext, TriggerPolicy, WeightModel,
};
use crate::exec::{executor_by_name, Executor, RankPlan};
use crate::fem::{DofMap, SolverOpts};
use crate::mesh::topology::LeafTopology;
use crate::mesh::{ElemId, TetMesh};
use crate::obs::{self, Phase};
use crate::partition::sfc::{sfc_keys, Curve, Normalization};
use crate::runtime::Runtime;
use crate::scenario::{Scenario, ScenarioRegistry, StepContext};
use crate::util::error::Result;
use crate::util::timer::Stopwatch;
use timeline::{StepRecord, Timeline};

#[derive(Debug, Clone)]
pub struct DriverConfig {
    /// problem scenario name (see [`ScenarioRegistry`])
    pub problem: String,
    /// virtual process count (the paper: 128 / 192)
    pub nparts: usize,
    /// partitioning method name (see [`Registry`])
    pub method: String,
    /// trigger policy spec: `lambda[:t]` | `every[:n]` | `always` |
    /// `costbenefit[:h]` (see [`crate::dlb::trigger_by_name`])
    pub trigger: String,
    /// weight model spec: `unit` | `dof` | `measured`
    pub weights: String,
    /// repartitioning strategy spec: `scratch` | `diffusive` |
    /// `adaptive` | `auto` (see [`RepartitionStrategy`], DESIGN.md §7,
    /// §12)
    pub strategy: String,
    /// execution schedule spec: `virtual` | `threads` (see
    /// [`crate::exec`], DESIGN.md §9)
    pub exec: String,
    /// worker budget for `--exec threads`; 0 = auto (one per core,
    /// capped at `nparts`)
    pub exec_threads: usize,
    /// threshold used by the default `lambda` trigger
    pub lambda_trigger: f64,
    /// marking fraction for refinement (max-strategy theta)
    pub theta_refine: f64,
    /// coarsening threshold (<= theta_coarsen * max eta); 0 = never
    pub theta_coarsen: f64,
    /// stop refining past this many leaves
    pub max_elements: usize,
    pub solver: SolverOpts,
    /// run solves through the PJRT artifacts when available; defaults
    /// to the `pjrt` cargo feature (the default build only has the
    /// always-erroring stub, so constructing a client would pay a
    /// pointless error/fallback path)
    pub use_pjrt: bool,
    pub nsteps: usize,
    /// time step for time-dependent scenarios; ignored by stationary
    /// ones
    pub dt: f64,
}

impl Default for DriverConfig {
    fn default() -> Self {
        Self {
            problem: "helmholtz".to_string(),
            nparts: 16,
            method: "PHG/HSFC".to_string(),
            trigger: "lambda".to_string(),
            weights: "unit".to_string(),
            strategy: "scratch".to_string(),
            exec: "virtual".to_string(),
            exec_threads: 0,
            lambda_trigger: 1.2,
            theta_refine: 0.5,
            theta_coarsen: 0.0,
            max_elements: 200_000,
            solver: SolverOpts::default(),
            use_pjrt: cfg!(feature = "pjrt"),
            nsteps: 10,
            dt: 1e-3,
        }
    }
}

/// The driver owns the mesh, the virtual distribution, the problem
/// scenario, and the DLB composition (pipeline + trigger + weight
/// model).
pub struct AdaptiveDriver {
    pub mesh: TetMesh,
    pub cfg: DriverConfig,
    pub scenario: Box<dyn Scenario>,
    pub pipeline: RebalancePipeline,
    pub trigger: Box<dyn TriggerPolicy>,
    pub weight_model: Box<dyn WeightModel>,
    /// the execution schedule the rank-parallel kernels run on
    /// (`--exec`, DESIGN.md §9)
    pub executor: Box<dyn Executor>,
    pub timeline: Timeline,
    pub runtime: Option<Runtime>,
    /// simulation clock: advanced by `dt` per step for time-dependent
    /// scenarios, frozen at 0 for stationary ones
    pub t: f64,
    /// current solution (dof vector) and its dof map, for transfer
    u: Vec<f64>,
    dof: Option<DofMap>,
    /// steps completed before this process took over (nonzero only for
    /// drivers built by `restore`): step numbering continues from here
    /// so a resumed timeline lines up with the uninterrupted one
    step_base: usize,
    /// EWMA of measured partitioner wall time; feeds the CostBenefit
    /// estimate (0 until the first rebalance)
    partition_wall_ewma: f64,
    /// previous step's SPMD-scaled solve time; feeds the CostBenefit
    /// estimate
    last_solve_parallel: f64,
}

impl AdaptiveDriver {
    /// Errors on an unknown problem, method, trigger, weight-model or
    /// strategy name (the message lists the valid ones).
    pub fn new(mesh: TetMesh, cfg: DriverConfig) -> Result<Self> {
        let scenario = ScenarioRegistry::create(&cfg.problem)?;
        Self::with_scenario(mesh, cfg, scenario)
    }

    /// Build a driver on the scenario's own default mesh.
    pub fn for_scenario(cfg: DriverConfig) -> Result<Self> {
        let scenario = ScenarioRegistry::create(&cfg.problem)?;
        let mesh = scenario.default_mesh();
        Self::with_scenario(mesh, cfg, scenario)
    }

    fn with_scenario(
        mut mesh: TetMesh,
        cfg: DriverConfig,
        scenario: Box<dyn Scenario>,
    ) -> Result<Self> {
        // the paper: order the initial mesh (tree roots) along an SFC
        // and maintain that order for the whole computation
        let leaves = mesh.leaves_unordered();
        let keys = sfc_keys(
            &mesh,
            &mesh.roots.clone(),
            Curve::Hilbert,
            Normalization::AspectPreserving,
        );
        let key_of: std::collections::HashMap<ElemId, u64> =
            mesh.roots.iter().copied().zip(keys).collect();
        mesh.sort_roots_by_key(|r| key_of[&r]);
        let mut driver = Self::compose(mesh, cfg, scenario)?;
        driver
            .pipeline
            .dist
            .assign_blocks(&mut driver.mesh, &leaves);
        Ok(driver)
    }

    /// Shared tail of the fresh and restored constructors: build the
    /// policy/executor composition around an already-prepared mesh.
    /// Deliberately does NOT sort roots or assign an initial partition:
    /// the restore path (`checkpoint` module) must keep the snapshot's
    /// root order and owners verbatim.
    fn compose(mesh: TetMesh, cfg: DriverConfig, scenario: Box<dyn Scenario>) -> Result<Self> {
        let pipeline = RebalancePipeline::new(
            Registry::create(&cfg.method)?,
            NetworkModel::infiniband(cfg.nparts),
            Distribution::new(cfg.nparts),
        )
        .with_strategy(RepartitionStrategy::parse(&cfg.strategy)?);
        let trigger = trigger_by_name(&cfg.trigger, cfg.lambda_trigger)?;
        let weight_model = weight_model_by_name(&cfg.weights)?;
        let executor = executor_by_name(&cfg.exec, cfg.nparts, cfg.exec_threads)?;
        let runtime = if cfg.use_pjrt {
            Runtime::open_default().ok()
        } else {
            None
        };
        Ok(Self {
            mesh,
            cfg,
            scenario,
            pipeline,
            trigger,
            weight_model,
            executor,
            timeline: Timeline::new(),
            runtime,
            t: 0.0,
            u: Vec::new(),
            dof: None,
            step_base: 0,
            partition_wall_ewma: 0.0,
            last_solve_parallel: 0.0,
        })
    }

    /// Evaluate the trigger policy and, if it fires, run the full
    /// rebalance pipeline, folding its report into the step record.
    ///
    /// Every evaluation -- fired or not -- is also offered to the
    /// flight recorder (DESIGN.md §14): when `--flight` is on, the
    /// per-strategy modeled-cost table is computed once up front and
    /// *both* the recorded event and the strategy resolution are read
    /// from it, so the logged argmin and the executed choice cannot
    /// disagree. When the recorder is off the whole block costs one
    /// relaxed atomic load and the lazy resolution paths are
    /// unchanged.
    fn maybe_rebalance(&mut self, leaves: &[ElemId], weights: &[f64], rec: &mut StepRecord) {
        rec.imbalance_before = self.pipeline.dist.imbalance(&self.mesh, leaves, weights);
        let flight_on = obs::flight().enabled();
        let table = if flight_on {
            self.pipeline.candidate_costs(
                &self.mesh,
                leaves,
                weights,
                self.last_solve_parallel,
                self.partition_wall_ewma,
            )
        } else {
            Vec::new()
        };
        // resolve (strategy, estimate) from the already-priced table:
        // concrete strategies read their own row, Auto takes the
        // argmin (strict <, earlier row wins -- the same rule as
        // RebalancePipeline::resolve_and_estimate)
        let configured = self.pipeline.strategy;
        let resolve_from_table = move |t: &[(RepartitionStrategy, CostEstimate, f64, f64)]| {
            match configured {
                RepartitionStrategy::Auto => {
                    let mut best = &t[0];
                    for row in &t[1..] {
                        if row.3 < best.3 {
                            best = row;
                        }
                    }
                    (best.0, best.1)
                }
                concrete => {
                    let row = t
                        .iter()
                        .find(|r| r.0 == concrete)
                        .expect("table covers every concrete strategy");
                    (row.0, row.1)
                }
            }
        };
        // the cost-model / strategy-resolution pass is O(n); run it at
        // most once per step, and only up front when the policy reads
        // the estimate (`auto` resolves against the solve history,
        // DESIGN.md §7)
        let mut resolved = None;
        let estimate = if self.trigger.needs_estimate() {
            let (strategy, estimate) = if flight_on {
                resolve_from_table(&table)
            } else {
                self.pipeline.resolve_and_estimate(
                    &self.mesh,
                    leaves,
                    weights,
                    self.last_solve_parallel,
                    self.partition_wall_ewma,
                )
            };
            resolved = Some(strategy);
            estimate
        } else {
            CostEstimate::default()
        };
        let ctx = TriggerContext {
            step: rec.step,
            lambda: rec.imbalance_before,
            estimate,
        };
        let candidates = || -> Vec<obs::CandidateCost> {
            table
                .iter()
                .map(|&(s, est, lambda_after, total)| obs::CandidateCost {
                    strategy: s.name(),
                    rebalance_cost: est.rebalance_cost,
                    saving_per_step: est.saving_per_step,
                    lambda_after,
                    total,
                })
                .collect()
        };
        if !self.trigger.should_rebalance(&ctx) {
            rec.imbalance_after = rec.imbalance_before;
            if flight_on {
                obs::flight().record(obs::FlightEvent {
                    step: rec.step,
                    lambda: rec.imbalance_before,
                    trigger: self.trigger.name(),
                    fired: false,
                    rebalance_cost: estimate.rebalance_cost,
                    saving_per_step: estimate.saving_per_step,
                    candidates: candidates(),
                    chosen: None,
                    realized: None,
                });
            }
            return;
        }
        let (strategy, modeled) = match resolved {
            Some(s) => (s, estimate),
            None if flight_on => resolve_from_table(&table),
            // resolve_and_estimate is the same pass resolve_strategy
            // runs, so the modeled cost for the audit below is free
            None => self.pipeline.resolve_and_estimate(
                &self.mesh,
                leaves,
                weights,
                self.last_solve_parallel,
                self.partition_wall_ewma,
            ),
        };
        let report = self
            .pipeline
            .rebalance_as(strategy, &mut self.mesh, leaves, weights);
        // the EWMA prices *scratch* partitioner walls for the cost
        // model; a diffusive flow solve would poison it with ~0s
        if report.strategy == RepartitionStrategy::Scratch {
            self.partition_wall_ewma = if self.partition_wall_ewma > 0.0 {
                0.5 * self.partition_wall_ewma + 0.5 * report.partition_wall
            } else {
                report.partition_wall
            };
        }
        // modeled-vs-measured audit: always on, one sample per
        // rebalance. The model-error summary and the dlb.flight.*
        // families in every metrics dump / exposition read these.
        let realized = report.dlb_time();
        let m = obs::metrics();
        m.counter_add("dlb.flight.rebalances", 1);
        m.observe("dlb.flight.modeled_cost_s", modeled.rebalance_cost);
        m.observe("dlb.flight.realized_cost_s", realized);
        if modeled.rebalance_cost > 0.0 && realized > 0.0 {
            let ratio_metric = match report.strategy {
                RepartitionStrategy::Scratch => "dlb.flight.model_ratio.scratch",
                RepartitionStrategy::Diffusive => "dlb.flight.model_ratio.diffusive",
                RepartitionStrategy::Adaptive => "dlb.flight.model_ratio.adaptive",
                RepartitionStrategy::Auto => unreachable!("rebalance_as resolves auto"),
            };
            m.observe(ratio_metric, modeled.rebalance_cost / realized);
        }
        if flight_on {
            obs::flight().record(obs::FlightEvent {
                step: rec.step,
                lambda: rec.imbalance_before,
                trigger: self.trigger.name(),
                fired: true,
                rebalance_cost: modeled.rebalance_cost,
                saving_per_step: modeled.saving_per_step,
                candidates: candidates(),
                chosen: Some(report.strategy.name()),
                realized: Some(obs::RealizedOutcome {
                    dlb_wall_s: realized,
                    total_v: report.volume.total_v,
                    lambda_after: report.lambda_after,
                }),
            });
        }
        rec.strategy = Some(report.strategy);
        rec.partition_time = report.partition_wall;
        rec.partition_comm_modeled = report.partition_comm_modeled + report.remap_comm_modeled;
        rec.migrate_time = report.migrate_wall;
        rec.migrate_modeled = report.migrate_modeled;
        rec.migration = Some(report.volume);
        rec.remap_kept_fraction = report.remap_kept_fraction;
        rec.imbalance_after = report.lambda_after;
        rec.repartitioned = true;
        rec.rebalance = Some(report);
    }

    /// Modeled per-iteration halo exchange from the *exact* ghost
    /// layer of the current partition: the bottleneck rank's shared-
    /// vertex bytes plus a latency charge per neighbour rank, per CG
    /// iteration. Partition quality enters the solve time through
    /// here, exactly as in the paper's Fig 3.4.
    fn solve_comm_model(&self, halo: &crate::dist::Halo, iterations: usize) -> f64 {
        let net = &self.pipeline.net;
        iterations as f64
            * (halo.max_neighbors() as f64 * net.alpha + halo.max_rank_bytes() as f64 * net.beta)
    }

    /// Feed the measured solve wall time back to the weight model as
    /// per-element costs (apportioned by each element's dof share) and
    /// remember the SPMD-scaled solve time for the CostBenefit trigger.
    /// The virtual executor's path: one sequential wall split across
    /// all leaves.
    fn record_solve_feedback(&mut self, leaves: &[ElemId], solve_wall: f64) {
        self.last_solve_parallel = solve_wall / self.cfg.nparts.max(1) as f64;
        // the apportionment pass is O(n); only pay for it when the
        // model actually records it
        if !self.weight_model.learns() {
            return;
        }
        let shares = dof_shares(&self.mesh, leaves);
        let total: f64 = shares.iter().sum();
        if total > 0.0 {
            let costs: Vec<f64> = shares.iter().map(|s| solve_wall * s / total).collect();
            self.weight_model.observe(&self.mesh, leaves, &costs);
        }
    }

    /// The measured executor's path: each rank's *own* busy seconds
    /// split over the elements that rank owns (by their dof share
    /// within the rank), so the weight model sees genuine per-rank
    /// timings instead of one global apportionment, and the
    /// CostBenefit trigger prices the real parallel wall.
    fn record_measured_feedback(
        &mut self,
        leaves: &[ElemId],
        plan: &RankPlan,
        rank_busy: &[f64],
        solve_wall: f64,
    ) {
        self.last_solve_parallel = solve_wall;
        if !self.weight_model.learns() {
            return;
        }
        let shares = dof_shares(&self.mesh, leaves);
        let mut costs = vec![0.0f64; leaves.len()];
        for (r, elems) in plan.elems.iter().enumerate() {
            let busy = rank_busy.get(r).copied().unwrap_or(0.0);
            let total: f64 = elems.iter().map(|&e| shares[e as usize]).sum();
            if total > 0.0 {
                for &e in elems {
                    costs[e as usize] = busy * shares[e as usize] / total;
                }
            }
        }
        self.weight_model.observe(&self.mesh, leaves, &costs);
    }

    /// One adaptive step of the configured scenario: solve ->
    /// estimate -> mark -> refine/coarsen -> DLB, all problem-specific
    /// pieces delegated to the [`Scenario`]. Returns false when a
    /// stationary scenario's growth budget is exhausted (the run
    /// loop's stop signal); time-dependent scenarios always continue
    /// and advance the clock by `dt`.
    pub fn step(&mut self) -> bool {
        let step = self.step_base + self.timeline.records.len();
        let mut rec = StepRecord::new(step);
        rec.nparts = self.cfg.nparts;
        let time_dependent = self.scenario.time_dependent();
        let t_next = if time_dependent {
            self.t + self.cfg.dt
        } else {
            0.0
        };

        let sw_setup = Stopwatch::start();
        let topo = LeafTopology::build(&self.mesh);
        let dof = DofMap::build(&self.mesh, &topo);
        // freeze this step's ownership into the executor's rank plan
        let owners_parts: Vec<u16> = topo
            .leaves
            .iter()
            .map(|&id| self.mesh.elem(id).owner)
            .collect();
        let plan = RankPlan::build(&self.mesh, &topo, &dof, &owners_parts, self.cfg.nparts);
        let setup_time = sw_setup.elapsed();
        rec.n_elements = topo.n_leaves();
        rec.n_dofs = dof.n_dofs;
        rec.exec = self.executor.name();

        // imbalance the solve actually ran under (feeds the lambda
        // factor in the timeline's SPMD solve-time accounting, §3);
        // overwritten below by the *measured* busy-time imbalance when
        // the executor really ran the ranks in parallel (§9)
        let solve_weights = self.weight_model.weights(&self.mesh, &topo.leaves);
        rec.solve_imbalance = self
            .pipeline
            .dist
            .imbalance(&self.mesh, &topo.leaves, &solve_weights);

        // the scenario reads the step through an immutable context;
        // scope it so the mutations below can borrow self again
        let (sol, eta, estimate_time, solve_wall) = {
            let ctx = StepContext {
                mesh: &self.mesh,
                topo: &topo,
                dof: &dof,
                exec: self.executor.as_ref(),
                plan: &plan,
                runtime: self.runtime.as_ref(),
                solver: &self.cfg.solver,
                t: t_next,
                dt: self.cfg.dt,
            };

            // previous solution transferred onto the new mesh, else
            // the scenario's seed (initial condition / cold start)
            let u_prev = match (&self.dof, self.u.len()) {
                (Some(old), n) if n > 0 => {
                    Some(dof.transfer_from(old, &self.u, &self.mesh, 0.0))
                }
                _ => self.scenario.initial_guess(&ctx),
            };

            // ---- solve (assembly happens inside the scenario's
            // solve; charge it all to solve_time, assemble_time is
            // for the explicit assembly benches)
            let sw = Stopwatch::start();
            let sol = {
                let _sp = obs::driver_span(Phase::Solve);
                self.scenario.solve(&ctx, u_prev.as_deref())
            };
            let solve_wall = sw.elapsed();

            // ---- estimate: scatter the solution to vertex ids (the
            // layout the estimators consume) only when the scenario's
            // indicator reads it, then ask the scenario
            let sw = Stopwatch::start();
            let _sp_est = obs::driver_span(Phase::Estimate);
            let u_vertex = if self.scenario.refine_indicator_reads_solution() {
                let mut by_vertex = vec![0.0; self.mesh.vertices.len()];
                for (d, &v) in dof.vertex_of_dof.iter().enumerate() {
                    by_vertex[v as usize] = sol.u[d];
                }
                by_vertex
            } else {
                Vec::new()
            };
            let eta = self.scenario.refine_indicator(&ctx, &u_vertex);
            (sol, eta, sw.elapsed(), solve_wall)
        };
        rec.solve_time = solve_wall;
        rec.solve_iterations = sol.stats.iterations;
        rec.l2_error = sol.l2_error;
        rec.max_error = sol.max_error;
        rec.estimate_time = estimate_time;

        // measured-vs-modeled split (§9): a measuring executor hands
        // back real per-rank busy times -- they replace the modeled
        // solve imbalance, mark the wall as genuinely parallel, and
        // feed the weight model per-rank costs
        let xrep = self.executor.take_report();
        if self.executor.measures() && !xrep.clocks.is_empty() {
            rec.solve_imbalance = xrep.measured_imbalance();
            rec.measured_parallel = true;
            rec.halo_exchange_time = xrep.halo_wall;
            rec.barrier_wait_time = xrep.max_barrier_wait();
            rec.halo_wait_time = xrep.max_halo_wait();
            self.record_measured_feedback(&topo.leaves, &plan, &xrep.clocks.busy, solve_wall);
            rec.exec_report = Some(xrep);
        } else {
            self.record_solve_feedback(&topo.leaves, solve_wall);
        }

        // partition quality affects the halo model
        let halo = crate::dist::Halo::build(&self.mesh, &topo, &owners_parts, self.cfg.nparts);
        rec.interface_faces = halo.interface_faces;
        rec.solve_comm_modeled = self.solve_comm_model(&halo, sol.stats.iterations);

        // ---- mark + refine, then coarsen where the scenario has a
        // solution-free signal for the fresh leaf set
        let sw = Stopwatch::start();
        let can_grow = self.mesh.n_leaves() < self.cfg.max_elements;
        if can_grow {
            let marked = {
                let _sp = obs::driver_span(Phase::Mark);
                mark_max(&topo.leaves, &eta, self.cfg.theta_refine)
            };
            let _sp = obs::driver_span(Phase::Refine);
            self.mesh.refine(&marked);
        }
        if self.cfg.theta_coarsen > 0.0 {
            let _sp = obs::driver_span(Phase::Refine);
            let leaves2 = self.mesh.leaves_unordered();
            let eta2 = self.scenario.coarsen_indicator(&self.mesh, &leaves2, t_next);
            if let Some(eta2) = eta2 {
                let cmarks = mark_coarsen_threshold(&leaves2, &eta2, self.cfg.theta_coarsen);
                self.mesh.coarsen(&cmarks);
            }
        }
        rec.adapt_time = sw.elapsed() + setup_time;

        // ---- DLB
        self.u = sol.u;
        self.dof = Some(dof);
        if time_dependent {
            self.t = t_next;
        }
        let leaves = self.mesh.leaves_unordered();
        let weights = self.weight_model.weights(&self.mesh, &leaves);
        self.maybe_rebalance(&leaves, &weights, &mut rec);

        let m = obs::metrics();
        m.counter_add("driver.steps", 1);
        if rec.repartitioned {
            m.counter_add("driver.rebalances", 1);
        }
        m.observe("driver.solve_s", rec.solve_time);
        m.observe("driver.estimate_s", rec.estimate_time);
        m.observe("driver.adapt_s", rec.adapt_time);
        m.observe("driver.lambda_solve", rec.solve_imbalance);
        if rec.measured_parallel {
            m.observe("driver.barrier_wait_s", rec.barrier_wait_time);
            m.observe("driver.halo_wait_s", rec.halo_wait_time);
            m.observe("driver.wait_fraction", rec.wait_fraction());
        }

        self.timeline.push(rec);
        time_dependent || can_grow
    }

    /// Run the configured scenario: `nsteps` adaptive (or time) steps,
    /// stopping early only when a stationary scenario exhausts its
    /// growth budget.
    pub fn run(&mut self) {
        for _ in 0..self.cfg.nsteps {
            if !self.step() {
                break;
            }
        }
    }

    /// The latest solution dof vector (empty before the first step);
    /// the cross-executor equivalence suite compares these.
    pub fn solution(&self) -> &[f64] {
        &self.u
    }

    /// Total adaptive steps this job has completed, counting steps run
    /// before a checkpoint/restore cycle (`step_base`). The serve
    /// runner loops on this against the job's step budget so a resumed
    /// job finishes its original budget, not budget-plus-prefix.
    pub fn steps_completed(&self) -> usize {
        self.step_base + self.timeline.records.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::generator;

    fn quick_cfg(method: &str) -> DriverConfig {
        DriverConfig {
            problem: "helmholtz".to_string(),
            nparts: 4,
            method: method.to_string(),
            trigger: "lambda".to_string(),
            weights: "unit".to_string(),
            strategy: "scratch".to_string(),
            exec: "virtual".to_string(),
            exec_threads: 0,
            lambda_trigger: 1.1,
            theta_refine: 0.5,
            theta_coarsen: 0.0,
            max_elements: 20_000,
            solver: SolverOpts {
                tol: 1e-5,
                max_iter: 500,
            },
            use_pjrt: false, // native engines: fast unit tests
            nsteps: 3,
            dt: 1e-3,
        }
    }

    #[test]
    fn unknown_names_error_cleanly() {
        let mesh = generator::cube_mesh(2);
        let err = AdaptiveDriver::new(mesh, quick_cfg("nope"))
            .err()
            .unwrap()
            .to_string();
        assert!(err.contains("RTK"), "error should list methods: {err}");

        let mesh = generator::cube_mesh(2);
        let mut cfg = quick_cfg("RTK");
        cfg.problem = "bogus".into();
        let err = AdaptiveDriver::new(mesh, cfg).err().unwrap().to_string();
        assert!(
            err.contains("oscillator"),
            "error should list scenarios: {err}"
        );

        let mesh = generator::cube_mesh(2);
        let mut cfg = quick_cfg("RTK");
        cfg.trigger = "bogus".into();
        assert!(AdaptiveDriver::new(mesh, cfg).is_err());

        let mesh = generator::cube_mesh(2);
        let mut cfg = quick_cfg("RTK");
        cfg.weights = "bogus".into();
        assert!(AdaptiveDriver::new(mesh, cfg).is_err());

        let mesh = generator::cube_mesh(2);
        let mut cfg = quick_cfg("RTK");
        cfg.strategy = "bogus".into();
        let err = AdaptiveDriver::new(mesh, cfg).err().unwrap().to_string();
        assert!(err.contains("diffusive"), "error should list strategies: {err}");

        let mesh = generator::cube_mesh(2);
        let mut cfg = quick_cfg("RTK");
        cfg.exec = "bogus".into();
        let err = AdaptiveDriver::new(mesh, cfg).err().unwrap().to_string();
        assert!(err.contains("threads"), "error should list executors: {err}");
    }

    #[test]
    fn threaded_executor_drives_the_loop_and_measures() {
        let mesh = generator::cube_mesh(2);
        let mut cfg = quick_cfg("PHG/HSFC");
        cfg.exec = "threads".to_string();
        let mut d = AdaptiveDriver::new(mesh, cfg).unwrap();
        d.run();
        assert_eq!(d.timeline.records.len(), 3);
        for r in &d.timeline.records {
            assert_eq!(r.exec, "threads");
            assert!(r.measured_parallel, "step {} not measured", r.step);
            assert!(r.solve_imbalance >= 1.0);
            // 4 ranks on a refining mesh must exchange something
            assert!(r.solve_iterations > 0);
            // the wait decomposition rides along with the measurement
            assert!(r.barrier_wait_time >= 0.0 && r.barrier_wait_time.is_finite());
            assert!(r.halo_wait_time >= 0.0 && r.halo_wait_time.is_finite());
            let rep = r.exec_report.as_ref().expect("per-rank profile kept");
            assert_eq!(rep.clocks.busy.len(), 4);
            assert!((0.0..=1.0).contains(&r.wait_fraction()));
        }
        let last = d.timeline.records.last().unwrap();
        assert!(last.imbalance_after < 1.6, "lambda {}", last.imbalance_after);
    }

    #[test]
    fn every_strategy_drives_the_loop() {
        for strategy in ["scratch", "diffusive", "adaptive", "auto"] {
            let mesh = generator::cube_mesh(2);
            let mut cfg = quick_cfg("PHG/HSFC");
            cfg.strategy = strategy.to_string();
            let mut d = AdaptiveDriver::new(mesh, cfg).unwrap();
            d.run();
            assert_eq!(d.timeline.records.len(), 3, "strategy {strategy}");
            let last = d.timeline.records.last().unwrap();
            assert!(
                last.imbalance_after < 1.6,
                "strategy {strategy}: lambda {} not controlled",
                last.imbalance_after
            );
            for r in &d.timeline.records {
                assert_eq!(r.repartitioned, r.strategy.is_some(), "strategy {strategy}");
                if let (Some(s), Some(rep)) = (r.strategy, r.rebalance.as_ref()) {
                    assert_eq!(s, rep.strategy);
                    match strategy {
                        "scratch" => assert_eq!(s, RepartitionStrategy::Scratch),
                        "diffusive" => assert_eq!(s, RepartitionStrategy::Diffusive),
                        "adaptive" => assert_eq!(s, RepartitionStrategy::Adaptive),
                        _ => assert_ne!(s, RepartitionStrategy::Auto),
                    }
                }
            }
        }
    }

    #[test]
    fn helmholtz_loop_runs_and_rebalances() {
        let mesh = generator::cube_mesh(2);
        let mut d = AdaptiveDriver::new(mesh, quick_cfg("RTK")).unwrap();
        d.run();
        assert_eq!(d.timeline.records.len(), 3);
        // mesh grew
        let n0 = d.timeline.records[0].n_elements;
        let n2 = d.timeline.records[2].n_elements;
        assert!(n2 > n0, "{n0} -> {n2}");
        // every step that exceeded the trigger was rebalanced back
        for r in &d.timeline.records {
            if r.repartitioned {
                assert!(r.imbalance_after <= r.imbalance_before + 1e-9);
                assert!(r.partition_time > 0.0);
                let rep = r.rebalance.as_ref().expect("report recorded");
                assert_eq!(rep.lambda_before, r.imbalance_before);
                assert_eq!(rep.lambda_after, r.imbalance_after);
            }
        }
        // solves happened and converged
        for r in &d.timeline.records {
            assert!(r.solve_iterations > 0);
            assert!(r.n_dofs > 0);
            assert!(r.solve_imbalance >= 1.0);
        }
    }

    #[test]
    fn all_methods_drive_the_loop() {
        for name in Registry::paper_names() {
            let mesh = generator::cube_mesh(2);
            let mut cfg = quick_cfg(name);
            cfg.nsteps = 2;
            let mut d = AdaptiveDriver::new(mesh, cfg).unwrap();
            d.run();
            assert_eq!(d.timeline.records.len(), 2, "method {name}");
            let last = d.timeline.records.last().unwrap();
            assert!(
                last.imbalance_after < 1.6,
                "method {name}: lambda {} not controlled",
                last.imbalance_after
            );
        }
    }

    #[test]
    fn parabolic_loop_refines_and_coarsens() {
        let mesh = generator::cube_mesh(3);
        let mut cfg = quick_cfg("PHG/HSFC");
        cfg.problem = "parabolic".to_string();
        cfg.theta_coarsen = 0.02;
        cfg.nsteps = 4;
        cfg.dt = 2e-3;
        let mut d = AdaptiveDriver::new(mesh, cfg).unwrap();
        d.run();
        assert_eq!(d.timeline.records.len(), 4);
        // the clock marched with the run
        assert!((d.t - 4.0 * 2e-3).abs() < 1e-12);
        for r in &d.timeline.records {
            assert!(r.max_error < 0.2, "error {}", r.max_error);
        }
        d.mesh.check_invariants().unwrap();
    }

    #[test]
    fn error_decreases_over_adaptive_steps() {
        let mesh = generator::cube_mesh(2);
        let mut cfg = quick_cfg("RTK");
        cfg.nsteps = 4;
        cfg.theta_refine = 0.3;
        let mut d = AdaptiveDriver::new(mesh, cfg).unwrap();
        d.run();
        let first = d.timeline.records.first().unwrap().l2_error;
        let last = d.timeline.records.last().unwrap().l2_error;
        assert!(
            last < first,
            "adaptive refinement did not reduce error: {first} -> {last}"
        );
    }

    #[test]
    fn timeline_csv_roundtrip() {
        let mesh = generator::cube_mesh(2);
        let mut cfg = quick_cfg("MSFC");
        cfg.nsteps = 2;
        let mut d = AdaptiveDriver::new(mesh, cfg).unwrap();
        d.run();
        let csv = d.timeline.to_csv();
        assert_eq!(csv.lines().count(), 3); // header + 2 rows
    }
}
