//! Report emitters: ASCII tables in the paper's format, plus CSV
//! series for the figures. The bench targets print these.

use super::timeline::Timeline;
use crate::dlb::RebalanceReport;

/// A row of the paper's Table 1 (total running time + repartitionings).
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub method: String,
    pub total_time: f64,
    pub repartitionings: usize,
}

pub fn format_table1(rows: &[Table1Row]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<12} {:>22} {:>22}\n",
        "Method", "total running time(s)", "# of repartitionings"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<12} {:>22.2} {:>22}\n",
            r.method, r.total_time, r.repartitionings
        ));
    }
    out
}

/// A row of the paper's Tables 2/3 (TAL / DLB / SOL / STP).
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub method: String,
    pub tal: f64,
    pub dlb: f64,
    pub sol: f64,
    pub stp: f64,
}

impl Table2Row {
    pub fn from_timeline(method: &str, tl: &Timeline) -> Self {
        let (tal, dlb, sol, stp) = tl.table_columns();
        Self {
            method: method.to_string(),
            tal,
            dlb,
            sol,
            stp,
        }
    }
}

pub fn format_table2(rows: &[Table2Row]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<12} {:>12} {:>12} {:>12} {:>12}\n",
        "Method", "Time TAL(s)", "Time DLB(s)", "Time SOL(s)", "Time STP(s)"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<12} {:>12.4} {:>12.4} {:>12.4} {:>12.4}\n",
            r.method, r.tal, r.dlb, r.sol, r.stp
        ));
    }
    out
}

/// Table of labelled [`RebalanceReport`]s: one row per rebalance with
/// lambda before/after, migration volumes, kept fraction and the
/// per-phase modeled cost split (the `dlb_policy_sweep` output).
pub fn format_rebalance_table(rows: &[(String, RebalanceReport)]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<22} {:<12} {:<10} {:>7} {:>7} {:>9} {:>9} {:>6} {:>11} {:>11} {:>11} {:>8}\n",
        "policy",
        "method",
        "strategy",
        "lam_in",
        "lam_out",
        "TotalV",
        "MaxV",
        "kept%",
        "part(us)",
        "remap(us)",
        "migr(us)",
        "ops"
    ));
    for (label, r) in rows {
        out.push_str(&format!(
            "{:<22} {:<12} {:<10} {:>7.3} {:>7.3} {:>9.1} {:>9.1} {:>6.1} {:>11.2} {:>11.2} {:>11.2} {:>8}\n",
            label,
            r.method,
            r.strategy.name(),
            r.lambda_before,
            r.lambda_after,
            r.volume.total_v,
            r.volume.max_v,
            100.0 * r.remap_kept_fraction,
            1e6 * r.partition_comm_modeled,
            1e6 * r.remap_comm_modeled,
            1e6 * r.migrate_modeled,
            r.comm_log.len()
        ));
    }
    out
}

/// Figure series: one (x, y) column pair per method, CSV.
pub fn format_figure_csv(
    xlabel: &str,
    ylabel: &str,
    series: &[(String, Vec<(f64, f64)>)],
) -> String {
    let mut out = format!("method,{xlabel},{ylabel}\n");
    for (name, pts) in series {
        for (x, y) in pts {
            out.push_str(&format!("{name},{x},{y}\n"));
        }
    }
    out
}

/// Write a report file under out/ (created if needed).
pub fn write_report(name: &str, content: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("out");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, content)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_formats() {
        let rows = vec![
            Table1Row {
                method: "RCB".into(),
                total_time: 3049.60,
                repartitionings: 60,
            },
            Table1Row {
                method: "RTK".into(),
                total_time: 3465.63,
                repartitionings: 59,
            },
        ];
        let s = format_table1(&rows);
        assert!(s.contains("RCB"));
        assert!(s.contains("3049.60"));
        assert!(s.contains("59"));
    }

    #[test]
    fn table2_formats() {
        let rows = vec![Table2Row {
            method: "PHG/HSFC".into(),
            tal: 6525.0,
            dlb: 0.0734,
            sol: 0.1886,
            stp: 0.9192,
        }];
        let s = format_table2(&rows);
        assert!(s.contains("PHG/HSFC"));
        assert!(s.contains("0.0734"));
        assert!(s.contains("Time STP"));
    }

    #[test]
    fn rebalance_table_formats() {
        use crate::dlb::RepartitionStrategy;
        use crate::partition::metrics::MigrationVolume;
        let rep = RebalanceReport {
            method: "RTK".into(),
            strategy: RepartitionStrategy::Scratch,
            lambda_before: 1.42,
            lambda_after: 1.01,
            volume: MigrationVolume {
                total_v: 120.0,
                max_v: 40.0,
                moved_fraction: 0.2,
            },
            remap_kept_fraction: 0.8,
            partition_wall: 1e-3,
            migrate_wall: 2e-3,
            partition_comm_modeled: 3e-6,
            remap_comm_modeled: 4e-6,
            migrate_modeled: 5e-6,
            comm_log: Vec::new(),
        };
        let s = format_rebalance_table(&[("lambda:1.20".into(), rep)]);
        assert!(s.contains("lambda:1.20"));
        assert!(s.contains("RTK"));
        assert!(s.contains("1.420"));
        assert!(s.contains("120.0"));
        assert_eq!(s.lines().count(), 2);
    }

    #[test]
    fn figure_csv_shape() {
        let series = vec![
            ("RTK".to_string(), vec![(1.0, 0.1), (2.0, 0.2)]),
            ("RCB".to_string(), vec![(1.0, 0.3)]),
        ];
        let csv = format_figure_csv("step", "seconds", &series);
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("method,step,seconds"));
    }
}
