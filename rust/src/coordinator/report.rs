//! Report emitters: ASCII tables in the paper's format, plus CSV
//! series for the figures. The bench targets print these.

use super::timeline::Timeline;
use crate::dlb::RebalanceReport;

/// A row of the paper's Table 1 (total running time + repartitionings).
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub method: String,
    pub total_time: f64,
    pub repartitionings: usize,
}

pub fn format_table1(rows: &[Table1Row]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<12} {:>22} {:>22}\n",
        "Method", "total running time(s)", "# of repartitionings"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<12} {:>22.2} {:>22}\n",
            r.method, r.total_time, r.repartitionings
        ));
    }
    out
}

/// A row of the paper's Tables 2/3 (TAL / DLB / SOL / STP).
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub method: String,
    pub tal: f64,
    pub dlb: f64,
    pub sol: f64,
    pub stp: f64,
}

impl Table2Row {
    pub fn from_timeline(method: &str, tl: &Timeline) -> Self {
        let (tal, dlb, sol, stp) = tl.table_columns();
        Self {
            method: method.to_string(),
            tal,
            dlb,
            sol,
            stp,
        }
    }
}

pub fn format_table2(rows: &[Table2Row]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<12} {:>12} {:>12} {:>12} {:>12}\n",
        "Method", "Time TAL(s)", "Time DLB(s)", "Time SOL(s)", "Time STP(s)"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<12} {:>12.4} {:>12.4} {:>12.4} {:>12.4}\n",
            r.method, r.tal, r.dlb, r.sol, r.stp
        ));
    }
    out
}

/// Table of labelled [`RebalanceReport`]s: one row per rebalance with
/// lambda before/after, migration volumes, kept fraction and the
/// per-phase modeled cost split (the `dlb_policy_sweep` output).
pub fn format_rebalance_table(rows: &[(String, RebalanceReport)]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<22} {:<12} {:<10} {:>7} {:>7} {:>9} {:>9} {:>6} {:>11} {:>11} {:>11} {:>8}\n",
        "policy",
        "method",
        "strategy",
        "lam_in",
        "lam_out",
        "TotalV",
        "MaxV",
        "kept%",
        "part(us)",
        "remap(us)",
        "migr(us)",
        "ops"
    ));
    for (label, r) in rows {
        out.push_str(&format!(
            "{:<22} {:<12} {:<10} {:>7.3} {:>7.3} {:>9.1} {:>9.1} {:>6.1} {:>11.2} {:>11.2} {:>11.2} {:>8}\n",
            label,
            r.method,
            r.strategy.name(),
            r.lambda_before,
            r.lambda_after,
            r.volume.total_v,
            r.volume.max_v,
            100.0 * r.remap_kept_fraction,
            1e6 * r.partition_comm_modeled,
            1e6 * r.remap_comm_modeled,
            1e6 * r.migrate_modeled,
            r.comm_log.len()
        ));
    }
    out
}

/// Figure series: one (x, y) column pair per method, CSV.
pub fn format_figure_csv(
    xlabel: &str,
    ylabel: &str,
    series: &[(String, Vec<(f64, f64)>)],
) -> String {
    let mut out = format!("method,{xlabel},{ylabel}\n");
    for (name, pts) in series {
        for (x, y) in pts {
            out.push_str(&format!("{name},{x},{y}\n"));
        }
    }
    out
}

/// Per-rank wall-decomposition table of one measured step: busy,
/// barrier-wait and halo-wait milliseconds per rank, the busy share
/// of the bottleneck, and the overall wait fraction. Printed when
/// `--exec threads` runs report (DESIGN.md §10); empty reports yield
/// a single explanatory line.
pub fn format_rank_profile(rep: &crate::exec::ExecReport) -> String {
    let busy = &rep.clocks.busy;
    if busy.is_empty() {
        return "rank profile: nothing measured (virtual executor)\n".to_string();
    }
    let max_busy = rep.max_busy().max(f64::MIN_POSITIVE);
    let get = |v: &[f64], r: usize| v.get(r).copied().unwrap_or(0.0);
    let mut out = String::new();
    out.push_str(&format!(
        "{:<6} {:>12} {:>12} {:>12} {:>10}\n",
        "rank", "busy(ms)", "barrier(ms)", "halo(ms)", "busy/max"
    ));
    for r in 0..busy.len() {
        out.push_str(&format!(
            "{:<6} {:>12.3} {:>12.3} {:>12.3} {:>10.3}\n",
            r,
            1e3 * busy[r],
            1e3 * get(&rep.clocks.barrier_wait, r),
            1e3 * get(&rep.clocks.halo_wait, r),
            busy[r] / max_busy
        ));
    }
    out.push_str(&format!(
        "wait fraction: {:.4} (lambda_measured {:.3})\n",
        rep.wait_fraction(),
        rep.measured_imbalance()
    ));
    out
}

/// Write a report file under out/ (created if needed).
pub fn write_report(name: &str, content: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("out");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, content)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_formats() {
        let rows = vec![
            Table1Row {
                method: "RCB".into(),
                total_time: 3049.60,
                repartitionings: 60,
            },
            Table1Row {
                method: "RTK".into(),
                total_time: 3465.63,
                repartitionings: 59,
            },
        ];
        let s = format_table1(&rows);
        assert!(s.contains("RCB"));
        assert!(s.contains("3049.60"));
        assert!(s.contains("59"));
    }

    #[test]
    fn table2_formats() {
        let rows = vec![Table2Row {
            method: "PHG/HSFC".into(),
            tal: 6525.0,
            dlb: 0.0734,
            sol: 0.1886,
            stp: 0.9192,
        }];
        let s = format_table2(&rows);
        assert!(s.contains("PHG/HSFC"));
        assert!(s.contains("0.0734"));
        assert!(s.contains("Time STP"));
    }

    #[test]
    fn rebalance_table_formats() {
        use crate::dlb::RepartitionStrategy;
        use crate::partition::metrics::MigrationVolume;
        let rep = RebalanceReport {
            method: "RTK".into(),
            strategy: RepartitionStrategy::Scratch,
            lambda_before: 1.42,
            lambda_after: 1.01,
            rank_loads_before: vec![142.0, 100.0, 100.0, 58.0],
            rank_loads_after: vec![101.0, 100.0, 100.0, 99.0],
            volume: MigrationVolume {
                total_v: 120.0,
                max_v: 40.0,
                moved_fraction: 0.2,
            },
            remap_kept_fraction: 0.8,
            partition_wall: 1e-3,
            migrate_wall: 2e-3,
            partition_comm_modeled: 3e-6,
            remap_comm_modeled: 4e-6,
            migrate_modeled: 5e-6,
            comm_log: Vec::new(),
        };
        let s = format_rebalance_table(&[("lambda:1.20".into(), rep)]);
        assert!(s.contains("lambda:1.20"));
        assert!(s.contains("RTK"));
        assert!(s.contains("1.420"));
        assert!(s.contains("120.0"));
        assert_eq!(s.lines().count(), 2);
    }

    #[test]
    fn rank_profile_formats_waits_per_rank() {
        use crate::exec::{ExecReport, RankClocks};
        let rep = ExecReport {
            clocks: RankClocks {
                busy: vec![0.004, 0.002],
                barrier_wait: vec![0.0, 0.002],
                halo_wait: vec![0.001, 0.0],
                halo_work: vec![0.0, 0.0],
            },
            ..Default::default()
        };
        let s = format_rank_profile(&rep);
        // header + 2 ranks + wait-fraction summary
        assert_eq!(s.lines().count(), 4);
        assert!(s.contains("busy/max"));
        assert!(s.contains("4.000"), "rank 0 busy ms: {s}");
        assert!(s.contains("0.500"), "rank 1 busy share: {s}");
        assert!(s.contains("wait fraction: 0.3333"), "{s}");
        // the empty report explains itself instead of panicking
        let empty = format_rank_profile(&ExecReport::default());
        assert!(empty.contains("nothing measured"));
    }

    #[test]
    fn figure_csv_shape() {
        let series = vec![
            ("RTK".to_string(), vec![(1.0, 0.1), (2.0, 0.2)]),
            ("RCB".to_string(), vec![(1.0, 0.3)]),
        ];
        let csv = format_figure_csv("step", "seconds", &series);
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("method,step,seconds"));
    }
}
