//! Driver checkpoint/restore: the full adaptive state as one versioned
//! binary snapshot (DESIGN.md §13).
//!
//! A checkpoint captures everything the next step reads: the
//! refinement forest with ownership and root order (via
//! `mesh::io::write_mesh`), the simulation clock, the current solution
//! and its dof map (the transfer source for the next solve), the step
//! counter, and every piece of learned DLB state -- measured-EWMA
//! weights, the partitioner-wall EWMA feeding `CostBenefit`, and the
//! adaptive repartitioner's wall EWMA feeding `Auto`'s argmin.
//!
//! Restore is `compose` + verbatim state injection: the fresh-start
//! constructor's root sort and initial block assignment are skipped, so
//! the restored driver sees exactly the mesh the checkpointed one did.
//! Because every decision a step makes is a deterministic function of
//! this state (the rank-ordered reduction rule, DESIGN.md §9.2), a
//! resumed run reproduces the uninterrupted run bitwise -- asserted by
//! `tests/serve_checkpoint.rs`.
//!
//! Framing: `MAGIC` (8 bytes), format version (u32), payload, then an
//! FxHash checksum (u64) over everything before it. Truncation errors
//! name the byte offset (see `mesh::io::SnapReader`); corruption that
//! survives parsing is caught by the checksum.

use super::{AdaptiveDriver, DriverConfig};
use crate::dlb::{TriggerPolicy, WeightModel};
use crate::fem::DofMap;
use crate::mesh::io::{read_mesh, write_mesh, SnapReader, SnapWriter};
use crate::scenario::ScenarioRegistry;
use crate::util::error::{Context, Result};
use crate::util::hash::FxHasher;
use crate::{bail, format_err};
use std::hash::Hasher;
use std::path::Path;

/// Leading bytes of every checkpoint file.
pub const MAGIC: &[u8; 8] = b"PHGCKPT\0";
/// Current format version. Bump on any layout change; readers reject
/// other versions with an explicit error (no silent reinterpretation).
pub const VERSION: u32 = 1;

fn checksum(bytes: &[u8]) -> u64 {
    let mut h = FxHasher::default();
    h.write(bytes);
    h.finish()
}

impl AdaptiveDriver {
    /// Serialize the full adaptive state to `path`. Valid at any step
    /// boundary (including before the first step).
    pub fn checkpoint(&self, path: &Path) -> Result<()> {
        let bytes = self.checkpoint_bytes();
        std::fs::write(path, bytes)
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    /// The checkpoint byte stream (see module docs for the framing).
    pub fn checkpoint_bytes(&self) -> Vec<u8> {
        let mut w = SnapWriter::new();
        w.put_bytes(MAGIC);
        w.put_u32(VERSION);
        w.put_str(&self.cfg.problem);
        w.put_len(self.cfg.nparts);
        w.put_len(self.steps_completed());
        w.put_f64(self.t);
        write_mesh(&mut w, &self.mesh);
        w.put_len(self.u.len());
        for &x in &self.u {
            w.put_f64(x);
        }
        match &self.dof {
            None => w.put_u8(0),
            Some(dof) => {
                w.put_u8(1);
                w.put_len(dof.dof_of_vertex.len());
                for &d in &dof.dof_of_vertex {
                    w.put_u32(d);
                }
                w.put_len(dof.vertex_of_dof.len());
                for &v in &dof.vertex_of_dof {
                    w.put_u32(v);
                }
                w.put_len(dof.on_boundary.len());
                for &b in &dof.on_boundary {
                    w.put_u8(b as u8);
                }
                w.put_len(dof.n_dofs);
            }
        }
        w.put_f64(self.partition_wall_ewma);
        w.put_f64(self.last_solve_parallel);
        match self.pipeline.adaptive_wall_estimate() {
            None => w.put_u8(0),
            Some(est) => {
                w.put_u8(1);
                w.put_f64(est);
            }
        }
        match self.weight_model.export_state() {
            None => w.put_u8(0),
            Some(state) => {
                w.put_u8(1);
                w.put_f64(state.alpha);
                w.put_len(state.costs.len());
                for (id, c) in &state.costs {
                    w.put_u32(*id);
                    w.put_f64(*c);
                }
            }
        }
        let sum = checksum(w.as_slice());
        let mut bytes = w.into_bytes();
        bytes.extend_from_slice(&sum.to_le_bytes());
        bytes
    }

    /// Rebuild a driver from a checkpoint written by
    /// [`AdaptiveDriver::checkpoint`]. `cfg` supplies the policy
    /// composition (method, trigger, executor, ...) and must name the
    /// same problem and part count the snapshot was taken under.
    pub fn restore(cfg: DriverConfig, path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::restore_bytes(cfg, &bytes)
    }

    /// [`AdaptiveDriver::restore`] from an in-memory byte stream.
    pub fn restore_bytes(cfg: DriverConfig, bytes: &[u8]) -> Result<Self> {
        if bytes.len() < MAGIC.len() + 4 + 8 {
            bail!(
                "checkpoint truncated at offset {}: not even a complete header",
                bytes.len()
            );
        }
        let (payload, tail) = bytes.split_at(bytes.len() - 8);
        let mut stored = [0u8; 8];
        stored.copy_from_slice(tail);
        let stored = u64::from_le_bytes(stored);
        let computed = checksum(payload);
        if stored != computed {
            bail!(
                "checkpoint corrupt: checksum mismatch at offset {} \
                 (stored {stored:#018x}, computed {computed:#018x})",
                payload.len()
            );
        }
        let mut r = SnapReader::new(payload);
        let magic = r.take(MAGIC.len(), "magic")?;
        if magic != MAGIC {
            bail!("not a phg-dlb checkpoint (bad magic at offset 0)");
        }
        let version = r.get_u32("format version")?;
        if version != VERSION {
            bail!("unsupported checkpoint format version {version} (this build reads {VERSION})");
        }
        let problem = r.get_str("problem name")?;
        if problem != cfg.problem {
            bail!(
                "checkpoint was taken for problem {problem:?} but the config names {:?}",
                cfg.problem
            );
        }
        let nparts = r.get_u64("nparts")? as usize;
        if nparts != cfg.nparts {
            bail!("checkpoint was taken with nparts {nparts} but the config names {}", cfg.nparts);
        }
        let steps = r.get_u64("steps completed")? as usize;
        let t = r.get_f64("simulation clock")?;
        let mesh = read_mesh(&mut r)?;
        let nu = r.get_len(8, "solution length")?;
        let mut u = Vec::with_capacity(nu);
        for _ in 0..nu {
            u.push(r.get_f64("solution value")?);
        }
        let dof = if r.get_u8("dof-map flag")? != 0 {
            let ndv = r.get_len(4, "dof_of_vertex length")?;
            let mut dof_of_vertex = Vec::with_capacity(ndv);
            for _ in 0..ndv {
                dof_of_vertex.push(r.get_u32("dof_of_vertex")?);
            }
            let nvd = r.get_len(4, "vertex_of_dof length")?;
            let mut vertex_of_dof = Vec::with_capacity(nvd);
            for _ in 0..nvd {
                vertex_of_dof.push(r.get_u32("vertex_of_dof")?);
            }
            let nb = r.get_len(1, "on_boundary length")?;
            let mut on_boundary = Vec::with_capacity(nb);
            for _ in 0..nb {
                on_boundary.push(r.get_u8("on_boundary")? != 0);
            }
            let n_dofs = r.get_u64("n_dofs")? as usize;
            if n_dofs != nvd || n_dofs != nu {
                bail!(
                    "checkpoint corrupt: dof map claims {n_dofs} dofs but carries {nvd} \
                     vertex slots and a solution of length {nu}"
                );
            }
            Some(DofMap {
                dof_of_vertex,
                vertex_of_dof,
                on_boundary,
                n_dofs,
            })
        } else {
            None
        };
        let partition_wall_ewma = r.get_f64("partition wall EWMA")?;
        let last_solve_parallel = r.get_f64("last solve parallel")?;
        let adaptive_wall = if r.get_u8("adaptive-wall flag")? != 0 {
            Some(r.get_f64("adaptive wall EWMA")?)
        } else {
            None
        };
        let weight_state = if r.get_u8("weight-state flag")? != 0 {
            let alpha = r.get_f64("weight EWMA alpha")?;
            let nc = r.get_len(12, "weight cost count")?;
            let mut costs = Vec::with_capacity(nc);
            for _ in 0..nc {
                let id = r.get_u32("weight cost id")?;
                let c = r.get_f64("weight cost value")?;
                costs.push((id, c));
            }
            Some(crate::dlb::WeightState { alpha, costs })
        } else {
            None
        };
        if r.remaining() != 0 {
            bail!(
                "checkpoint corrupt: {} unread bytes after the payload at offset {}",
                r.remaining(),
                r.offset()
            );
        }

        let scenario = ScenarioRegistry::create(&cfg.problem)?;
        let mut d = Self::compose(mesh, cfg, scenario)?;
        d.step_base = steps;
        d.t = t;
        d.u = u;
        d.dof = dof;
        d.partition_wall_ewma = partition_wall_ewma;
        d.last_solve_parallel = last_solve_parallel;
        d.trigger.advance_to(steps);
        d.pipeline.restore_adaptive_wall_estimate(adaptive_wall);
        if let Some(state) = &weight_state {
            d.weight_model.import_state(state);
        }
        d.mesh
            .check_invariants()
            .map_err(|e| format_err!("restored mesh fails invariants: {e}"))?;
        Ok(d)
    }
}
