//! Per-step records of the adaptive computation: everything the
//! paper's figures and tables aggregate (partition time, DLB time,
//! solve time, step time, repartition counts, quality metrics).

use crate::dlb::{RebalanceReport, RepartitionStrategy};
use crate::exec::ExecReport;
use crate::partition::metrics::MigrationVolume;

/// One adaptive (or time) step's accounting. Times in seconds;
/// `*_modeled` are alpha-beta network charges, the rest is measured
/// wall clock.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    /// virtual process count (for SPMD scaling of measured compute)
    pub nparts: usize,
    pub n_elements: usize,
    pub n_dofs: usize,
    /// load imbalance before any DLB this step
    pub imbalance_before: f64,
    pub imbalance_after: f64,
    /// load imbalance the solve actually ran under (before this
    /// step's refinement); scales the bottleneck rank's solve compute.
    /// Modeled from the weight profile under the virtual executor,
    /// *measured* from per-rank busy walls under `--exec threads`
    /// (DESIGN.md §9)
    pub solve_imbalance: f64,
    /// which execution schedule ran this step (`--exec`)
    pub exec: &'static str,
    /// true when `solve_time` is real parallel hardware time (a
    /// measuring executor ran the ranks concurrently); the SPMD
    /// substitution of §3 is then skipped
    pub measured_parallel: bool,
    /// measured bottleneck-rank halo-exchange wall seconds (0 under
    /// the virtual executor, whose halo cost is `solve_comm_modeled`)
    pub halo_exchange_time: f64,
    /// measured bottleneck-rank seconds blocked in phase barriers
    /// during the solve -- load imbalance made physical (0 under the
    /// virtual executor)
    pub barrier_wait_time: f64,
    /// measured bottleneck-rank seconds blocked waiting for halo
    /// messages (the wait part of `halo_exchange_time`)
    pub halo_wait_time: f64,
    /// the full per-rank measured profile (busy/waits/halo counters)
    /// behind the summary fields above; `None` under executors that
    /// measure nothing
    pub exec_report: Option<ExecReport>,
    pub repartitioned: bool,
    /// repartitioning strategy that ran this step's rebalance, if any
    /// (never `Auto`: the pipeline resolves it per event)
    pub strategy: Option<RepartitionStrategy>,
    /// full phase-by-phase report of this step's rebalance, if any
    pub rebalance: Option<RebalanceReport>,
    /// measured partitioner wall time
    pub partition_time: f64,
    /// modeled collectives of the partitioner + remap
    pub partition_comm_modeled: f64,
    /// measured remap+migrate restructuring time
    pub migrate_time: f64,
    /// modeled migration network time
    pub migrate_modeled: f64,
    pub migration: Option<MigrationVolume>,
    /// fraction of data kept in place by the Oliker-Biswas remap
    pub remap_kept_fraction: f64,
    pub interface_faces: usize,
    pub assemble_time: f64,
    /// measured solver wall time
    pub solve_time: f64,
    /// modeled halo-exchange time over all CG iterations
    pub solve_comm_modeled: f64,
    pub solve_iterations: usize,
    pub estimate_time: f64,
    pub adapt_time: f64,
    pub l2_error: f64,
    pub max_error: f64,
}

impl StepRecord {
    pub fn new(step: usize) -> Self {
        Self {
            step,
            nparts: 1,
            n_elements: 0,
            n_dofs: 0,
            imbalance_before: 1.0,
            imbalance_after: 1.0,
            solve_imbalance: 1.0,
            exec: "virtual",
            measured_parallel: false,
            halo_exchange_time: 0.0,
            barrier_wait_time: 0.0,
            halo_wait_time: 0.0,
            exec_report: None,
            repartitioned: false,
            strategy: None,
            rebalance: None,
            partition_time: 0.0,
            partition_comm_modeled: 0.0,
            migrate_time: 0.0,
            migrate_modeled: 0.0,
            migration: None,
            remap_kept_fraction: 1.0,
            interface_faces: 0,
            assemble_time: 0.0,
            solve_time: 0.0,
            solve_comm_modeled: 0.0,
            solve_iterations: 0,
            estimate_time: 0.0,
            adapt_time: 0.0,
            l2_error: 0.0,
            max_error: 0.0,
        }
    }

    /// DLB time: partitioning + remap/migration, measured + modeled
    /// (the quantity of Fig 3.3).
    pub fn dlb_time(&self) -> f64 {
        self.partition_time + self.partition_comm_modeled + self.migrate_time + self.migrate_modeled
    }

    /// Parallel solve time (Fig 3.4 / the SOL column). Virtual
    /// executor: the measured single-address-space solve is divided by
    /// the virtual process count and multiplied by the load-imbalance
    /// factor the solve ran under (the bottleneck rank holds
    /// `lambda x` the mean load -- DESIGN.md §3), then the
    /// partition-dependent modeled halo time is added. Measuring
    /// executor (`--exec threads`): the wall clock already *is*
    /// parallel hardware time with the real halo exchange inside it,
    /// so it is reported as-is and nothing alpha-beta is added.
    /// Note the measured wall also contains the scenario's sequential
    /// glue (system combination, Dirichlet setup, error norms), so it
    /// is the honest end-to-end solve wall, not the executor-parallel
    /// sections alone -- see DESIGN.md §9.3 before comparing SOL
    /// columns across executors.
    pub fn total_solve_time(&self) -> f64 {
        if self.measured_parallel {
            return self.solve_time;
        }
        self.solve_time * self.solve_imbalance.max(1.0) / self.nparts.max(1) as f64
            + self.solve_comm_modeled
    }

    /// Fraction of this step's accounted rank-seconds the ranks spent
    /// waiting (barriers + halo), 0 when nothing was measured.
    pub fn wait_fraction(&self) -> f64 {
        self.exec_report
            .as_ref()
            .map(|r| r.wait_fraction())
            .unwrap_or(0.0)
    }

    /// Parallel assembly/estimate/adapt compute, same SPMD scaling.
    fn scaled_local(&self, t: f64) -> f64 {
        t / self.nparts.max(1) as f64
    }

    /// Whole-step time (Fig 3.5 / the STP column): DLB (measured
    /// partition + modeled collectives + migration) plus the SPMD-scaled
    /// local phases.
    pub fn step_time(&self) -> f64 {
        self.dlb_time()
            + self.scaled_local(self.assemble_time + self.estimate_time + self.adapt_time)
            + self.total_solve_time()
    }
}

/// The whole run.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    pub records: Vec<StepRecord>,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    pub fn repartition_count(&self) -> usize {
        self.records.iter().filter(|r| r.repartitioned).count()
    }

    /// The paper's table columns: (TAL, mean DLB, mean SOL, mean STP).
    pub fn table_columns(&self) -> (f64, f64, f64, f64) {
        let n = self.records.len().max(1) as f64;
        let tal: f64 = self.records.iter().map(|r| r.step_time()).sum();
        let dlb: f64 = self.records.iter().map(|r| r.dlb_time()).sum::<f64>() / n;
        let sol: f64 = self
            .records
            .iter()
            .map(|r| r.total_solve_time())
            .sum::<f64>()
            / n;
        let stp = tal / n;
        (tal, dlb, sol, stp)
    }

    /// CSV dump (one row per step) for the figure benches.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "step,n_elements,n_dofs,imbalance_before,imbalance_after,solve_imbalance,\
             repartitioned,strategy,\
             partition_time,partition_comm_modeled,migrate_time,migrate_modeled,\
             moved_fraction,remap_kept_fraction,interface_faces,assemble_time,\
             solve_time,solve_comm_modeled,solve_iterations,estimate_time,adapt_time,\
             dlb_time,step_time,l2_error,max_error,exec,measured_parallel,\
             halo_exchange_time,barrier_wait_time,halo_wait_time,wait_fraction,\
             rank_busy_max,rank_busy_mean\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{},{},{},{:.4},{:.4},{:.4},{},{},{:.6},{:.6},{:.6},{:.6},{:.4},{:.4},{},{:.6},{:.6},{:.6},{},{:.6},{:.6},{:.6},{:.6},{:.3e},{:.3e},{},{},{:.6},{:.6},{:.6},{:.4},{:.6},{:.6}\n",
                r.step,
                r.n_elements,
                r.n_dofs,
                r.imbalance_before,
                r.imbalance_after,
                r.solve_imbalance,
                r.repartitioned as u8,
                r.strategy.map(|s| s.name()).unwrap_or("-"),
                r.partition_time,
                r.partition_comm_modeled,
                r.migrate_time,
                r.migrate_modeled,
                r.migration.map(|m| m.moved_fraction).unwrap_or(0.0),
                r.remap_kept_fraction,
                r.interface_faces,
                r.assemble_time,
                r.solve_time,
                r.solve_comm_modeled,
                r.solve_iterations,
                r.estimate_time,
                r.adapt_time,
                r.dlb_time(),
                r.step_time(),
                r.l2_error,
                r.max_error,
                r.exec,
                r.measured_parallel as u8,
                r.halo_exchange_time,
                r.barrier_wait_time,
                r.halo_wait_time,
                r.wait_fraction(),
                r.exec_report.as_ref().map(|x| x.max_busy()).unwrap_or(0.0),
                r.exec_report.as_ref().map(|x| x.mean_busy()).unwrap_or(0.0),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_time_is_sum_of_phases() {
        let mut r = StepRecord::new(0);
        r.partition_time = 1.0;
        r.migrate_time = 2.0;
        r.migrate_modeled = 0.5;
        r.assemble_time = 3.0;
        r.solve_time = 4.0;
        r.solve_comm_modeled = 0.25;
        r.estimate_time = 0.5;
        r.adapt_time = 0.5;
        assert!((r.dlb_time() - 3.5).abs() < 1e-12);
        assert!((r.total_solve_time() - 4.25).abs() < 1e-12);
        assert!((r.step_time() - 11.75).abs() < 1e-12);
    }

    #[test]
    fn solve_imbalance_scales_bottleneck_compute() {
        let mut r = StepRecord::new(0);
        r.nparts = 4;
        r.solve_time = 8.0;
        r.solve_comm_modeled = 0.5;
        // balanced: mean compute per rank
        assert!((r.total_solve_time() - 2.5).abs() < 1e-12);
        // bottleneck rank holds 1.5x the mean load
        r.solve_imbalance = 1.5;
        assert!((r.total_solve_time() - 3.5).abs() < 1e-12);
        // values below 1 are clamped (lambda >= 1 by definition)
        r.solve_imbalance = 0.5;
        assert!((r.total_solve_time() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn measured_parallel_wall_is_reported_as_is() {
        let mut r = StepRecord::new(0);
        r.nparts = 8;
        r.solve_time = 3.0;
        r.solve_comm_modeled = 0.5;
        r.solve_imbalance = 1.4;
        // virtual: SPMD substitution applies
        assert!((r.total_solve_time() - (3.0 * 1.4 / 8.0 + 0.5)).abs() < 1e-12);
        // threads: the wall already is parallel hardware time; no
        // division, no lambda scaling, no alpha-beta halo charge
        r.exec = "threads";
        r.measured_parallel = true;
        r.halo_exchange_time = 0.1;
        assert!((r.total_solve_time() - 3.0).abs() < 1e-12);
        let mut tl = Timeline::new();
        tl.push(r);
        let csv = tl.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(header.ends_with("rank_busy_mean"));
        assert!(header.contains("barrier_wait_time,halo_wait_time,wait_fraction"));
        let row = csv.lines().nth(1).unwrap();
        assert_eq!(header.split(',').count(), row.split(',').count());
        assert!(row.contains(",threads,1,"), "measured columns missing: {row}");
    }

    #[test]
    fn wait_columns_follow_the_exec_report() {
        use crate::exec::{ExecReport, RankClocks};
        let mut r = StepRecord::new(0);
        assert_eq!(r.wait_fraction(), 0.0);
        r.exec = "threads";
        r.measured_parallel = true;
        r.barrier_wait_time = 0.5;
        r.halo_wait_time = 0.25;
        r.exec_report = Some(ExecReport {
            clocks: RankClocks {
                busy: vec![2.0, 1.0],
                barrier_wait: vec![0.0, 0.5],
                halo_wait: vec![0.25, 0.0],
                halo_work: vec![0.0, 0.25],
            },
            ..Default::default()
        });
        // waits 0.75 of 4.0 accounted rank-seconds
        assert!((r.wait_fraction() - 0.75 / 4.0).abs() < 1e-12);
        let mut tl = Timeline::new();
        tl.push(r);
        let csv = tl.to_csv();
        let row = csv.lines().nth(1).unwrap();
        let cols: Vec<&str> = row.split(',').collect();
        // last five columns: barrier/halo waits, wait fraction,
        // max/mean busy
        assert_eq!(cols[cols.len() - 5], "0.500000");
        assert_eq!(cols[cols.len() - 4], "0.250000");
        assert_eq!(cols[cols.len() - 3], "0.1875");
        assert_eq!(cols[cols.len() - 2], "2.000000");
        assert_eq!(cols[cols.len() - 1], "1.500000");
    }

    #[test]
    fn table_columns_aggregate() {
        let mut tl = Timeline::new();
        for i in 0..4 {
            let mut r = StepRecord::new(i);
            r.solve_time = 1.0;
            r.partition_time = 0.5;
            r.repartitioned = i % 2 == 0;
            tl.push(r);
        }
        let (tal, dlb, sol, stp) = tl.table_columns();
        assert!((tal - 6.0).abs() < 1e-12);
        assert!((dlb - 0.5).abs() < 1e-12);
        assert!((sol - 1.0).abs() < 1e-12);
        assert!((stp - 1.5).abs() < 1e-12);
        assert_eq!(tl.repartition_count(), 2);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut tl = Timeline::new();
        tl.push(StepRecord::new(0));
        let csv = tl.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(
            lines[0].split(',').count(),
            lines[1].split(',').count(),
            "header/row column mismatch"
        );
    }
}
