//! Example 3.1: stationary Helmholtz -lap u + u = f on the long
//! cylinder Omega_1, exact solution
//! u = cos(2 pi x) cos(2 pi y) cos(2 pi z). The solution is smooth, so
//! the residual estimator spreads refinement near-uniformly and the
//! load grows everywhere at once -- the mild-skew baseline of the
//! paper's Tables 1 and Figs 3.2-3.5.

use super::{Scenario, SolveOutput, StepContext};
use crate::adapt::residual_indicator;
use crate::fem::problems::{helmholtz_source, solve_helmholtz};
use crate::mesh::{generator, TetMesh};

pub struct Helmholtz;

impl Scenario for Helmholtz {
    fn name(&self) -> &'static str {
        "helmholtz"
    }

    fn default_mesh(&self) -> TetMesh {
        generator::omega1_cylinder(2)
    }

    fn solve(&self, ctx: &StepContext, u_prev: Option<&[f64]>) -> SolveOutput {
        solve_helmholtz(
            ctx.exec,
            ctx.plan,
            ctx.mesh,
            ctx.topo,
            ctx.dof,
            ctx.runtime,
            ctx.solver,
            u_prev,
        )
        .into()
    }

    fn refine_indicator(&self, ctx: &StepContext, u_vertex: &[f64]) -> Vec<f64> {
        residual_indicator(ctx.mesh, ctx.topo, u_vertex, helmholtz_source, 1.0)
    }
}
