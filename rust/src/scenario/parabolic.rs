//! Time-dependent moving-peak scenarios: u_t - lap u = f on the unit
//! cube, exact solution the paper's narrow bump carried along a
//! prescribed trajectory. Every step the mesh refines ahead of the
//! peak and coarsens behind it, so load keeps shifting between the
//! virtual processes.
//!
//! Two trajectories are registered:
//! * `parabolic` -- example 3.2: the peak circles in the x-y plane
//!   near z = 1 and keeps entering fresh territory.
//! * `oscillator` -- the peak sweeps back and forth along x through
//!   the cube center, revisiting regions it refined and the mesh has
//!   since coarsened: the load hotspot returns to ranks that just
//!   gave elements away, stressing the Diffusive/Auto strategy split.

use super::{Scenario, SolveOutput, StepContext};
use crate::adapt::geometric_indicator;
use crate::fem::problems::{moving_peak_exact, oscillating_center, parabolic_step, peak_center};
use crate::geometry::Vec3;
use crate::mesh::{generator, ElemId, TetMesh};

/// Width of the geometric refinement signal around the peak (matches
/// the bump's footprint).
const INDICATOR_WIDTH: f64 = 0.25;

/// A parabolic problem whose exact solution is the bump carried along
/// `center`; the trajectory is the whole difference between the
/// registered moving-peak scenarios.
pub struct MovingPeak {
    name: &'static str,
    center: fn(f64) -> Vec3,
}

impl MovingPeak {
    /// Example 3.2: the peak circles near the top face.
    pub fn parabolic() -> Self {
        Self {
            name: "parabolic",
            center: peak_center,
        }
    }

    /// The peak sweeps back and forth through the cube center.
    pub fn oscillator() -> Self {
        Self {
            name: "oscillator",
            center: oscillating_center,
        }
    }
}

impl Scenario for MovingPeak {
    fn name(&self) -> &'static str {
        self.name
    }

    fn time_dependent(&self) -> bool {
        true
    }

    fn default_mesh(&self) -> TetMesh {
        generator::cube_mesh(4)
    }

    fn initial_guess(&self, ctx: &StepContext) -> Option<Vec<f64>> {
        let c = (self.center)(ctx.t - ctx.dt);
        Some(ctx.dof.eval_at_dofs(ctx.mesh, |p| moving_peak_exact(p, c)))
    }

    fn solve(&self, ctx: &StepContext, u_prev: Option<&[f64]>) -> SolveOutput {
        let u_prev = u_prev.expect("the driver seeds time-dependent scenarios");
        parabolic_step(
            ctx.exec,
            ctx.plan,
            ctx.mesh,
            ctx.topo,
            ctx.dof,
            ctx.runtime,
            ctx.solver,
            u_prev,
            ctx.t,
            ctx.dt,
            self.center,
        )
        .into()
    }

    fn refine_indicator_reads_solution(&self) -> bool {
        false // purely geometric: tracks the analytic peak location
    }

    fn refine_indicator(&self, ctx: &StepContext, _u_vertex: &[f64]) -> Vec<f64> {
        geometric_indicator(
            ctx.mesh,
            &ctx.topo.leaves,
            (self.center)(ctx.t),
            INDICATOR_WIDTH,
        )
    }

    fn coarsen_indicator(&self, mesh: &TetMesh, leaves: &[ElemId], t: f64) -> Option<Vec<f64>> {
        Some(geometric_indicator(
            mesh,
            leaves,
            (self.center)(t),
            INDICATOR_WIDTH,
        ))
    }
}
