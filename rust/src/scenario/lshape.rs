//! Corner-singularity scenario: -lap u + u = f on the L-shaped prism
//! (the unit cube minus its x > 1/2, y > 1/2 quadrant), manufactured
//! exact solution u = r^(2/3) sin(2 phi / 3) around the reentrant
//! edge. u is harmonic, so f = u, but grad u blows up like r^(-1/3)
//! at the edge: the residual estimator keeps marking the same few
//! elements no matter how deep the mesh gets.
//!
//! DLB-wise this is the opposite stress of the smooth Helmholtz
//! problem: load does not spread, it re-concentrates in place on the
//! ranks owning the edge -- short repeated imbalance spikes that the
//! diffusive strategy can discharge to the neighbouring ranks without
//! a global repartition.

use super::{Scenario, SolveOutput, StepContext};
use crate::adapt::residual_indicator;
use crate::fem::problems::solve_stationary;
use crate::geometry::Vec3;
use crate::mesh::{generator, TetMesh};

/// u = r^(2/3) sin(2 phi / 3) in cylindrical coordinates around the
/// reentrant edge (x, y) = (1/2, 1/2): harmonic in the plane,
/// constant along z, vanishing on both faces that meet at the edge.
/// `phi` is measured from the face x = 1/2 (y > 1/2) and grows
/// through the domain to 3 pi / 2 on the face y = 1/2 (x > 1/2).
pub fn corner_exact(p: Vec3) -> f64 {
    let dx = p.x - 0.5;
    let dy = p.y - 0.5;
    let r = (dx * dx + dy * dy).sqrt();
    if r < 1e-300 {
        return 0.0;
    }
    let mut phi = dy.atan2(dx) - 0.5 * std::f64::consts::PI;
    if phi < 0.0 {
        phi += 2.0 * std::f64::consts::PI;
    }
    r.powf(2.0 / 3.0) * (2.0 * phi / 3.0).sin()
}

/// -lap u + u = f with harmonic u gives f = u.
pub fn corner_source(p: Vec3) -> f64 {
    corner_exact(p)
}

pub struct LShape;

impl Scenario for LShape {
    fn name(&self) -> &'static str {
        "lshape"
    }

    fn default_mesh(&self) -> TetMesh {
        generator::lshape_mesh(4)
    }

    fn solve(&self, ctx: &StepContext, u_prev: Option<&[f64]>) -> SolveOutput {
        solve_stationary(
            ctx.exec,
            ctx.plan,
            ctx.mesh,
            ctx.topo,
            ctx.dof,
            ctx.runtime,
            ctx.solver,
            u_prev,
            corner_source,
            corner_exact,
        )
        .into()
    }

    fn refine_indicator(&self, ctx: &StepContext, u_vertex: &[f64]) -> Vec<f64> {
        residual_indicator(ctx.mesh, ctx.topo, u_vertex, corner_source, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corner_solution_is_harmonic_inside_the_domain() {
        // FD Laplacian ~ 0 away from the singular edge
        for p in [
            Vec3::new(0.2, 0.3, 0.5),
            Vec3::new(0.7, 0.2, 0.1),
            Vec3::new(0.2, 0.8, 0.9),
        ] {
            let h = 1e-4;
            let mut lap = 0.0;
            for axis in 0..3 {
                let mut dp = p;
                let mut dm = p;
                match axis {
                    0 => {
                        dp.x += h;
                        dm.x -= h;
                    }
                    1 => {
                        dp.y += h;
                        dm.y -= h;
                    }
                    _ => {
                        dp.z += h;
                        dm.z -= h;
                    }
                }
                lap += (corner_exact(dp) - 2.0 * corner_exact(p) + corner_exact(dm)) / (h * h);
            }
            assert!(lap.abs() < 1e-4, "lap u = {lap} at {p:?}");
        }
    }

    #[test]
    fn corner_solution_vanishes_on_reentrant_faces() {
        // face x = 1/2, y > 1/2 (phi = 0) and face y = 1/2, x > 1/2
        // (phi = 3 pi / 2)
        for t in [0.6, 0.8, 0.99] {
            assert!(corner_exact(Vec3::new(0.5, t, 0.3)).abs() < 1e-12);
            assert!(corner_exact(Vec3::new(t, 0.5, 0.7)).abs() < 1e-12);
        }
        // and is positive inside the domain
        assert!(corner_exact(Vec3::new(0.2, 0.2, 0.5)) > 0.0);
        assert!(corner_exact(Vec3::new(0.1, 0.9, 0.5)) > 0.0);
        assert!(corner_exact(Vec3::new(0.9, 0.1, 0.5)) > 0.0);
    }

    #[test]
    fn gradient_grows_toward_the_edge() {
        // |grad u| ~ r^(-1/3): halving r must grow the FD gradient
        let grad_mag = |r: f64| {
            let p = Vec3::new(0.5 - r / 2f64.sqrt(), 0.5 - r / 2f64.sqrt(), 0.5);
            let h = r * 1e-3;
            let gx = (corner_exact(Vec3::new(p.x + h, p.y, p.z))
                - corner_exact(Vec3::new(p.x - h, p.y, p.z)))
                / (2.0 * h);
            let gy = (corner_exact(Vec3::new(p.x, p.y + h, p.z))
                - corner_exact(Vec3::new(p.x, p.y - h, p.z)))
                / (2.0 * h);
            (gx * gx + gy * gy).sqrt()
        };
        assert!(grad_mag(0.01) > 1.2 * grad_mag(0.02));
    }
}
