//! Problem scenarios: the pluggable "what are we solving" axis of the
//! adaptive driver.
//!
//! The paper's point is that the solve -> estimate -> adapt ->
//! rebalance loop is problem-independent: the grid and the basis
//! functions change, the DLB machinery reacts. A [`Scenario`] owns
//! everything problem-specific -- the default mesh, the stepping mode
//! (stationary vs. time marching), the solve itself, and the
//! refinement/coarsening signals -- while the generic
//! [`crate::coordinator::AdaptiveDriver::step`] owns the shared
//! skeleton. Adding a workload is a [`SCENARIOS`] registry entry, not
//! a driver fork (DESIGN.md SS8).
//!
//! [`ScenarioRegistry`] mirrors [`crate::dlb::Registry`]: the single
//! name -> constructor table behind `--problem`, with sorted described
//! listings for `phg-dlb methods`.

mod helmholtz;
mod lshape;
mod parabolic;

pub use helmholtz::Helmholtz;
pub use lshape::{corner_exact, corner_source, LShape};
pub use parabolic::MovingPeak;

use crate::bail;
use crate::exec::{Executor, RankPlan};
use crate::fem::problems::{ParabolicStep, StationarySolution};
use crate::fem::{DofMap, SolveStats, SolverOpts};
use crate::mesh::topology::LeafTopology;
use crate::mesh::{ElemId, TetMesh};
use crate::runtime::Runtime;
use crate::util::error::Result;

/// Everything a scenario may read during one adaptive step: the
/// current mesh/topology/dof triple, the executor and its rank plan,
/// the PJRT runtime, the solver options, and the simulation clock.
pub struct StepContext<'a> {
    pub mesh: &'a TetMesh,
    pub topo: &'a LeafTopology,
    pub dof: &'a DofMap,
    /// The execution schedule this step's assembly + solve run on
    /// (DESIGN.md §9); scenarios pass it straight into the
    /// [`crate::fem::problems`] entry points.
    pub exec: &'a dyn Executor,
    /// Rank ownership frozen for this step (matches the mesh's
    /// `owner` fields at solve time).
    pub plan: &'a RankPlan,
    pub runtime: Option<&'a Runtime>,
    pub solver: &'a SolverOpts,
    /// time at the *end* of this step for time-dependent scenarios
    /// (`t_prev + dt`); 0 for stationary ones.
    pub t: f64,
    pub dt: f64,
}

/// What a scenario's solve hands back to the generic loop; the driver
/// copies these straight onto the step record, so their meanings match
/// [`crate::coordinator::timeline::StepRecord`].
pub struct SolveOutput {
    /// solution per dof
    pub u: Vec<f64>,
    pub stats: SolveStats,
    /// sqrt(e' M e) against the manufactured exact solution
    pub l2_error: f64,
    /// max vertex error against the manufactured exact solution
    pub max_error: f64,
}

impl From<StationarySolution> for SolveOutput {
    fn from(sol: StationarySolution) -> Self {
        Self {
            u: sol.u,
            stats: sol.stats,
            l2_error: sol.l2_error,
            max_error: sol.max_error,
        }
    }
}

impl From<ParabolicStep> for SolveOutput {
    fn from(out: ParabolicStep) -> Self {
        Self {
            u: out.u,
            stats: out.stats,
            l2_error: out.l2_error,
            max_error: out.max_error,
        }
    }
}

/// A problem scenario: everything the generic adaptive loop does
/// *not* own. Implementations must be deterministic given (mesh, t)
/// so runs are reproducible across methods, triggers and strategies.
pub trait Scenario {
    /// Registry name (`--problem <name>`).
    fn name(&self) -> &'static str;

    /// Time-dependent scenarios march `nsteps` time steps of size
    /// `dt` (the driver advances the clock and never stops early);
    /// stationary ones iterate solve -> refine and stop once the
    /// element budget is exhausted.
    fn time_dependent(&self) -> bool {
        false
    }

    /// Whether [`SolveOutput::l2_error`] / `max_error` measure a real
    /// manufactured-solution error (every built-in scenario: yes).
    fn has_exact(&self) -> bool {
        true
    }

    /// The domain this scenario is defined on (`--domain auto`).
    fn default_mesh(&self) -> TetMesh;

    /// Seed for a solve with no previous solution to transfer:
    /// time-dependent scenarios return their initial condition at
    /// `ctx.t - ctx.dt`; stationary ones default to a cold start.
    fn initial_guess(&self, ctx: &StepContext) -> Option<Vec<f64>> {
        let _ = ctx;
        None
    }

    /// Solve the problem on the current mesh. `u_prev` is the
    /// previous solution transferred onto this mesh (or the
    /// [`Scenario::initial_guess`]); stationary scenarios may use it
    /// as a warm start, time-dependent ones step from it.
    fn solve(&self, ctx: &StepContext, u_prev: Option<&[f64]>) -> SolveOutput;

    /// Whether [`Scenario::refine_indicator`] reads the solution.
    /// Scenarios with a purely geometric signal return false and the
    /// driver skips the O(n) dof -> vertex scatter (and hands them an
    /// empty `u_vertex`), so `estimate_time` stays a faithful
    /// indicator cost.
    fn refine_indicator_reads_solution(&self) -> bool {
        true
    }

    /// Per-leaf refinement signal in `ctx.topo.leaves` order.
    /// `u_vertex` is the fresh solution scattered to vertex ids (the
    /// layout every estimator in [`crate::adapt`] consumes); empty
    /// when [`Scenario::refine_indicator_reads_solution`] is false.
    fn refine_indicator(&self, ctx: &StepContext, u_vertex: &[f64]) -> Vec<f64>;

    /// Solution-free signal over a *fresh* leaf set, evaluated after
    /// refinement for `theta_coarsen` marking. `None` (the stationary
    /// default) disables coarsening: a residual estimator is stale by
    /// then, an analytic feature location is not.
    fn coarsen_indicator(&self, mesh: &TetMesh, leaves: &[ElemId], t: f64) -> Option<Vec<f64>> {
        let _ = (mesh, leaves, t);
        None
    }
}

/// One registered scenario: its `--problem` name, a one-line
/// description (the `phg-dlb methods` listing), and its constructor.
pub struct ScenarioSpec {
    pub name: &'static str,
    /// One-line description for listings and docs.
    pub description: &'static str,
    pub make: fn() -> Box<dyn Scenario>,
}

/// Every scenario, paper examples first, then the DLB stress tests.
pub const SCENARIOS: [ScenarioSpec; 4] = [
    ScenarioSpec {
        name: "helmholtz",
        description: "stationary Helmholtz on the long cylinder, smooth solution (example 3.1)",
        make: || Box::new(Helmholtz),
    },
    ScenarioSpec {
        name: "parabolic",
        description: "moving-peak parabolic, hotspot circling near z = 1 (example 3.2)",
        make: || Box::new(MovingPeak::parabolic()),
    },
    ScenarioSpec {
        name: "lshape",
        description: "corner singularity on the L-shaped prism: persistent localized refinement",
        make: || Box::new(LShape),
    },
    ScenarioSpec {
        name: "oscillator",
        description: "oscillating-source parabolic: the hotspot revisits coarsened regions",
        make: || Box::new(MovingPeak::oscillator()),
    },
];

/// Namespace for scenario lookup over [`SCENARIOS`], mirroring
/// [`crate::dlb::Registry`].
pub struct ScenarioRegistry;

impl ScenarioRegistry {
    /// Instantiate a scenario by name. Unknown names error with the
    /// full list of valid ones.
    pub fn create(name: &str) -> Result<Box<dyn Scenario>> {
        match SCENARIOS.iter().find(|s| s.name == name) {
            Some(spec) => Ok((spec.make)()),
            None => bail!(
                "unknown problem {name:?}; valid problems: {}",
                Self::names().join(", ")
            ),
        }
    }

    /// All registered scenario names, registry order.
    pub fn names() -> Vec<&'static str> {
        SCENARIOS.iter().map(|s| s.name).collect()
    }

    /// Every spec in sorted (byte-order) name order: the
    /// deterministic listing that `phg-dlb methods` prints.
    pub fn sorted_specs() -> Vec<&'static ScenarioSpec> {
        let mut specs: Vec<&'static ScenarioSpec> = SCENARIOS.iter().collect();
        specs.sort_by_key(|s| s.name);
        specs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_knows_all_scenarios() {
        for spec in &SCENARIOS {
            let s = ScenarioRegistry::create(spec.name).unwrap();
            assert_eq!(s.name(), spec.name, "registry name mismatch");
            assert!(!spec.description.is_empty(), "{} undescribed", spec.name);
            // the default mesh is non-trivial and usable
            let mesh = s.default_mesh();
            assert!(mesh.n_leaves() > 0, "{}: empty default mesh", spec.name);
            mesh.check_invariants().unwrap();
        }
    }

    #[test]
    fn unknown_scenario_lists_valid_names() {
        let err = ScenarioRegistry::create("nope").unwrap_err().to_string();
        assert!(err.contains("nope"), "{err}");
        for name in ScenarioRegistry::names() {
            assert!(err.contains(name), "error does not list {name}: {err}");
        }
    }

    #[test]
    fn sorted_specs_are_sorted_and_complete() {
        let specs = ScenarioRegistry::sorted_specs();
        assert_eq!(specs.len(), SCENARIOS.len());
        for w in specs.windows(2) {
            assert!(w[0].name < w[1].name, "{} !< {}", w[0].name, w[1].name);
        }
    }

    #[test]
    fn stepping_modes_are_declared() {
        assert!(!ScenarioRegistry::create("helmholtz").unwrap().time_dependent());
        assert!(!ScenarioRegistry::create("lshape").unwrap().time_dependent());
        assert!(ScenarioRegistry::create("parabolic").unwrap().time_dependent());
        assert!(ScenarioRegistry::create("oscillator").unwrap().time_dependent());
    }
}
