//! Geometric primitives: 3-vectors, axis-aligned bounding boxes, and
//! tetrahedron measures (volume, quality).

mod bbox;
mod tet;
mod vec3;

pub use bbox::BBox;
pub use tet::{tet_quality, tet_volume, tet_volume_signed};
pub use vec3::Vec3;
