//! Axis-aligned bounding box. Central to the SFC partitioners: the
//! paper's PHG/HSFC vs Zoltan/HSFC difference is precisely *how* the
//! domain bounding box is normalized to the unit cube (aspect-ratio
//! preserving vs per-axis), see `partition::sfc::Normalization`.

use super::Vec3;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BBox {
    pub lo: Vec3,
    pub hi: Vec3,
}

impl BBox {
    /// Empty box ready to `expand`.
    pub fn empty() -> Self {
        Self {
            lo: Vec3::new(f64::INFINITY, f64::INFINITY, f64::INFINITY),
            hi: Vec3::new(f64::NEG_INFINITY, f64::NEG_INFINITY, f64::NEG_INFINITY),
        }
    }

    pub fn new(lo: Vec3, hi: Vec3) -> Self {
        Self { lo, hi }
    }

    pub fn from_points<'a>(pts: impl IntoIterator<Item = &'a Vec3>) -> Self {
        let mut b = Self::empty();
        for p in pts {
            b.expand(*p);
        }
        b
    }

    pub fn expand(&mut self, p: Vec3) {
        self.lo = self.lo.min(p);
        self.hi = self.hi.max(p);
    }

    pub fn union(&self, o: &BBox) -> BBox {
        BBox {
            lo: self.lo.min(o.lo),
            hi: self.hi.max(o.hi),
        }
    }

    pub fn extent(&self) -> Vec3 {
        self.hi - self.lo
    }

    pub fn max_extent(&self) -> f64 {
        let e = self.extent();
        e.x.max(e.y).max(e.z)
    }

    pub fn center(&self) -> Vec3 {
        self.lo.midpoint(self.hi)
    }

    pub fn contains(&self, p: Vec3) -> bool {
        p.x >= self.lo.x
            && p.x <= self.hi.x
            && p.y >= self.lo.y
            && p.y <= self.hi.y
            && p.z >= self.lo.z
            && p.z <= self.hi.z
    }

    pub fn is_empty(&self) -> bool {
        self.lo.x > self.hi.x
    }

    /// Aspect ratio: longest extent / shortest non-zero extent.
    pub fn aspect_ratio(&self) -> f64 {
        let e = self.extent();
        let dims = [e.x, e.y, e.z];
        let max = dims.iter().cloned().fold(0.0f64, f64::max);
        let min = dims
            .iter()
            .cloned()
            .filter(|&d| d > 0.0)
            .fold(f64::INFINITY, f64::min);
        if min == f64::INFINITY || min == 0.0 {
            1.0
        } else {
            max / min
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_from_points() {
        let pts = [
            Vec3::new(0.0, 1.0, 2.0),
            Vec3::new(3.0, -1.0, 0.0),
            Vec3::new(1.0, 0.5, 5.0),
        ];
        let b = BBox::from_points(pts.iter());
        assert_eq!(b.lo, Vec3::new(0.0, -1.0, 0.0));
        assert_eq!(b.hi, Vec3::new(3.0, 1.0, 5.0));
        assert_eq!(b.extent(), Vec3::new(3.0, 2.0, 5.0));
        assert_eq!(b.max_extent(), 5.0);
    }

    #[test]
    fn empty_detection() {
        assert!(BBox::empty().is_empty());
        let mut b = BBox::empty();
        b.expand(Vec3::ZERO);
        assert!(!b.is_empty());
        assert_eq!(b.lo, b.hi);
    }

    #[test]
    fn contains_boundary() {
        let b = BBox::new(Vec3::ZERO, Vec3::new(1.0, 1.0, 1.0));
        assert!(b.contains(Vec3::ZERO));
        assert!(b.contains(Vec3::new(1.0, 1.0, 1.0)));
        assert!(b.contains(Vec3::new(0.5, 0.5, 0.5)));
        assert!(!b.contains(Vec3::new(1.5, 0.5, 0.5)));
    }

    #[test]
    fn aspect_ratio_cylinderish() {
        // long thin box like the paper's cylinder bounding box
        let b = BBox::new(Vec3::ZERO, Vec3::new(8.0, 1.0, 1.0));
        assert_eq!(b.aspect_ratio(), 8.0);
        let cube = BBox::new(Vec3::ZERO, Vec3::new(1.0, 1.0, 1.0));
        assert_eq!(cube.aspect_ratio(), 1.0);
    }

    #[test]
    fn union_covers_both() {
        let a = BBox::new(Vec3::ZERO, Vec3::new(1.0, 1.0, 1.0));
        let b = BBox::new(Vec3::new(2.0, -1.0, 0.0), Vec3::new(3.0, 0.0, 4.0));
        let u = a.union(&b);
        assert!(u.contains(Vec3::ZERO));
        assert!(u.contains(Vec3::new(3.0, 0.0, 4.0)));
    }
}
