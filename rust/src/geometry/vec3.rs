//! Plain 3-vector over f64. Mesh coordinates are f64 on the Rust side;
//! they are converted to f32 only at the PJRT boundary.

use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Vec3 {
    pub x: f64,
    pub y: f64,
    pub z: f64,
}

impl Vec3 {
    pub const ZERO: Vec3 = Vec3 {
        x: 0.0,
        y: 0.0,
        z: 0.0,
    };

    pub fn new(x: f64, y: f64, z: f64) -> Self {
        Self { x, y, z }
    }

    pub fn dot(self, o: Vec3) -> f64 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    pub fn cross(self, o: Vec3) -> Vec3 {
        Vec3::new(
            self.y * o.z - self.z * o.y,
            self.z * o.x - self.x * o.z,
            self.x * o.y - self.y * o.x,
        )
    }

    pub fn norm2(self) -> f64 {
        self.dot(self)
    }

    pub fn norm(self) -> f64 {
        self.norm2().sqrt()
    }

    pub fn normalized(self) -> Vec3 {
        let n = self.norm();
        if n == 0.0 {
            Vec3::ZERO
        } else {
            self / n
        }
    }

    pub fn min(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.min(o.x), self.y.min(o.y), self.z.min(o.z))
    }

    pub fn max(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.max(o.x), self.y.max(o.y), self.z.max(o.z))
    }

    pub fn component(self, axis: usize) -> f64 {
        match axis {
            0 => self.x,
            1 => self.y,
            2 => self.z,
            _ => panic!("axis {axis} out of range"),
        }
    }

    pub fn midpoint(self, o: Vec3) -> Vec3 {
        (self + o) * 0.5
    }
}

impl Add for Vec3 {
    type Output = Vec3;
    fn add(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x + o.x, self.y + o.y, self.z + o.z)
    }
}

impl AddAssign for Vec3 {
    fn add_assign(&mut self, o: Vec3) {
        *self = *self + o;
    }
}

impl Sub for Vec3 {
    type Output = Vec3;
    fn sub(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x - o.x, self.y - o.y, self.z - o.z)
    }
}

impl Mul<f64> for Vec3 {
    type Output = Vec3;
    fn mul(self, s: f64) -> Vec3 {
        Vec3::new(self.x * s, self.y * s, self.z * s)
    }
}

impl Div<f64> for Vec3 {
    type Output = Vec3;
    fn div(self, s: f64) -> Vec3 {
        Vec3::new(self.x / s, self.y / s, self.z / s)
    }
}

impl Neg for Vec3 {
    type Output = Vec3;
    fn neg(self) -> Vec3 {
        Vec3::new(-self.x, -self.y, -self.z)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_cross() {
        let ex = Vec3::new(1.0, 0.0, 0.0);
        let ey = Vec3::new(0.0, 1.0, 0.0);
        let ez = Vec3::new(0.0, 0.0, 1.0);
        assert_eq!(ex.dot(ey), 0.0);
        assert_eq!(ex.cross(ey), ez);
        assert_eq!(ey.cross(ez), ex);
        assert_eq!(ez.cross(ex), ey);
    }

    #[test]
    fn arithmetic() {
        let a = Vec3::new(1.0, 2.0, 3.0);
        let b = Vec3::new(4.0, 5.0, 6.0);
        assert_eq!(a + b, Vec3::new(5.0, 7.0, 9.0));
        assert_eq!(b - a, Vec3::new(3.0, 3.0, 3.0));
        assert_eq!(a * 2.0, Vec3::new(2.0, 4.0, 6.0));
        assert_eq!(a / 2.0, Vec3::new(0.5, 1.0, 1.5));
        assert_eq!(-a, Vec3::new(-1.0, -2.0, -3.0));
    }

    #[test]
    fn norm_and_normalize() {
        let v = Vec3::new(3.0, 4.0, 0.0);
        assert_eq!(v.norm(), 5.0);
        assert!((v.normalized().norm() - 1.0).abs() < 1e-15);
        assert_eq!(Vec3::ZERO.normalized(), Vec3::ZERO);
    }

    #[test]
    fn midpoint_and_minmax() {
        let a = Vec3::new(0.0, 2.0, -1.0);
        let b = Vec3::new(2.0, 0.0, 3.0);
        assert_eq!(a.midpoint(b), Vec3::new(1.0, 1.0, 1.0));
        assert_eq!(a.min(b), Vec3::new(0.0, 0.0, -1.0));
        assert_eq!(a.max(b), Vec3::new(2.0, 2.0, 3.0));
    }

    #[test]
    fn component_access() {
        let v = Vec3::new(7.0, 8.0, 9.0);
        assert_eq!(v.component(0), 7.0);
        assert_eq!(v.component(1), 8.0);
        assert_eq!(v.component(2), 9.0);
    }
}
