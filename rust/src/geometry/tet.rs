//! Tetrahedron measures.

use super::Vec3;

/// Signed volume: positive when (v1-v0, v2-v0, v3-v0) is right-handed.
pub fn tet_volume_signed(v: &[Vec3; 4]) -> f64 {
    let d1 = v[1] - v[0];
    let d2 = v[2] - v[0];
    let d3 = v[3] - v[0];
    d1.dot(d2.cross(d3)) / 6.0
}

pub fn tet_volume(v: &[Vec3; 4]) -> f64 {
    tet_volume_signed(v).abs()
}

/// Mean-ratio shape quality in (0, 1]; 1 for the regular tetrahedron,
/// -> 0 for degenerate slivers. Used to verify bisection refinement
/// keeps element quality bounded (the guarantee PHG's bisection relies
/// on for its a-priori estimates).
pub fn tet_quality(v: &[Vec3; 4]) -> f64 {
    let vol = tet_volume(v);
    if vol <= 0.0 {
        return 0.0;
    }
    let mut sum_l2 = 0.0;
    for i in 0..4 {
        for j in (i + 1)..4 {
            sum_l2 += (v[i] - v[j]).norm2();
        }
    }
    // regular tet with edge a: vol = a^3/(6 sqrt 2), sum_l2 = 6 a^2
    // quality = c * vol^{2/3} / sum_l2 normalized so regular == 1
    let c = 6.0 * (6.0 * 2.0f64.sqrt()).powf(2.0 / 3.0);
    c * vol.powf(2.0 / 3.0) / sum_l2
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit_tet() -> [Vec3; 4] {
        [
            Vec3::ZERO,
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(0.0, 1.0, 0.0),
            Vec3::new(0.0, 0.0, 1.0),
        ]
    }

    fn regular_tet() -> [Vec3; 4] {
        [
            Vec3::new(1.0, 1.0, 1.0),
            Vec3::new(1.0, -1.0, -1.0),
            Vec3::new(-1.0, 1.0, -1.0),
            Vec3::new(-1.0, -1.0, 1.0),
        ]
    }

    #[test]
    fn unit_tet_volume() {
        assert!((tet_volume(&unit_tet()) - 1.0 / 6.0).abs() < 1e-15);
    }

    #[test]
    fn signed_volume_flips_with_orientation() {
        let mut t = unit_tet();
        let v = tet_volume_signed(&t);
        t.swap(2, 3);
        assert!((tet_volume_signed(&t) + v).abs() < 1e-15);
    }

    #[test]
    fn regular_tet_quality_is_one() {
        assert!((tet_quality(&regular_tet()) - 1.0).abs() < 1e-10);
    }

    #[test]
    fn degenerate_quality_zero() {
        let t = [
            Vec3::ZERO,
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(2.0, 0.0, 0.0),
            Vec3::new(3.0, 0.0, 0.0),
        ];
        assert_eq!(tet_quality(&t), 0.0);
    }

    #[test]
    fn sliver_quality_low() {
        let t = [
            Vec3::ZERO,
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(0.0, 1.0, 0.0),
            Vec3::new(0.5, 0.5, 1e-3),
        ];
        let q = tet_quality(&t);
        assert!(q > 0.0 && q < 0.05, "q = {q}");
    }

    #[test]
    fn quality_scale_invariant() {
        let t = unit_tet();
        let scaled: [Vec3; 4] = [t[0] * 10.0, t[1] * 10.0, t[2] * 10.0, t[3] * 10.0];
        assert!((tet_quality(&t) - tet_quality(&scaled)).abs() < 1e-12);
    }
}
