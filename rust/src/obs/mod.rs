//! Observability: span-based phase tracing, a metrics registry, the
//! DLB decision flight recorder and a live status plane (DESIGN.md
//! §10, §14).
//!
//! Four mechanisms with different cost contracts:
//!
//! * **Tracing** ([`trace`]) -- per-rank buffers of timed phase
//!   spans, off by default, enabled by `--trace out.json`. Sites sit
//!   inside the PCG hot loop, so the disabled path is two relaxed
//!   atomic loads, no clock read, no allocation (enforced by
//!   `tests/obs_overhead.rs`).
//! * **Metrics** ([`metrics`]) -- always-on counters and histograms
//!   fed at step granularity by the driver, `RebalancePipeline` and
//!   both executors; dumped deterministically by `--metrics`,
//!   exposed in Prometheus text form by the status plane.
//! * **Flight recorder** ([`flight`]) -- off by default, enabled by
//!   `--flight out.jsonl`: one structured event per trigger
//!   evaluation with the per-strategy modeled-cost table and the
//!   realized outcome, so every DLB decision is auditable.
//! * **Status plane** ([`serve_status`]) -- opt-in `--status-port`
//!   loopback HTTP thread serving `/metrics`, `/jobs`, `/health`;
//!   off = no thread, no socket (also enforced by
//!   `tests/obs_overhead.rs`).

pub mod flight;
pub mod metrics;
pub mod serve_status;
pub mod trace;

pub use flight::{
    flight, model_error_summary, CandidateCost, FlightEvent, FlightRecorder, RealizedOutcome,
};
pub use metrics::{metrics, prom_name, HistSummary, Metrics};
pub use serve_status::{JobsProvider, StatusServer};
pub use trace::{span, tracer, Phase, Span, SpanEvent, Tracer, DRIVER_LANE};

/// Mirror state owned by other obs subsystems into the metrics
/// registry as counters: `obs.trace.dropped` (spans silently dropped
/// at the shard cap) and `obs.flight.dropped` (flight events
/// displaced from the ring). Called just before every metrics dump
/// and every `/metrics` scrape so the exported values are current.
pub fn sync_derived_metrics() {
    metrics().counter_set("obs.trace.dropped", tracer().dropped());
    metrics().counter_set("obs.flight.dropped", flight().dropped());
}

/// Open a span on the driver lane (the sequential phases of the
/// adaptive loop: solve, estimate, mark, refine, partition, remap,
/// migrate).
#[inline]
pub fn driver_span(phase: Phase) -> Span<'static> {
    trace::tracer().span_lane(DRIVER_LANE, phase)
}
