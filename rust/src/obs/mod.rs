//! Observability: span-based phase tracing and a metrics registry
//! (DESIGN.md §10).
//!
//! Two independent mechanisms with different cost contracts:
//!
//! * **Tracing** ([`trace`]) -- per-rank buffers of timed phase
//!   spans, off by default, enabled by `--trace out.json`. Sites sit
//!   inside the PCG hot loop, so the disabled path is two relaxed
//!   atomic loads, no clock read, no allocation (enforced by
//!   `tests/obs_overhead.rs`).
//! * **Metrics** ([`metrics`]) -- always-on counters and histograms
//!   fed at step granularity by the driver, `RebalancePipeline` and
//!   both executors; dumped deterministically by `--metrics`.

pub mod metrics;
pub mod trace;

pub use metrics::{metrics, HistSummary, Metrics};
pub use trace::{span, tracer, Phase, Span, SpanEvent, Tracer, DRIVER_LANE};

/// Open a span on the driver lane (the sequential phases of the
/// adaptive loop: solve, estimate, mark, refine, partition, remap,
/// migrate).
#[inline]
pub fn driver_span(phase: Phase) -> Span<'static> {
    trace::tracer().span_lane(DRIVER_LANE, phase)
}
