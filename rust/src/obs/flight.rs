//! DLB decision flight recorder (DESIGN.md §14): every trigger
//! evaluation -- fired or not -- becomes one structured event carrying
//! the inputs the policy saw (step, lambda, the cost estimate), the
//! per-strategy modeled-cost table the `Auto` argmin ranks, the chosen
//! strategy, and the realized outcome (measured DLB wall, TotalV,
//! lambda after) once the rebalance has run.
//!
//! The recorder is **off by default** and the disabled path is one
//! relaxed atomic load with no allocation (`tests/obs_overhead.rs`
//! enforces this) -- the coordinator gates event *construction* on
//! [`FlightRecorder::enabled`], so a run without `--flight` never
//! builds the candidate table for lambda/cadence triggers. Events land
//! in a bounded ring; overflow bumps a dropped counter instead of
//! growing without bound, mirroring the tracer's contract.
//!
//! Events from concurrent drivers (the serve daemon's tenants)
//! interleave in submission order; each event is complete when
//! recorded, so no cross-thread amend step exists to race.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Ring capacity: at one event per adaptive step this covers runs far
/// longer than any bench or serve batch; beyond it the oldest context
/// is less useful than knowing the drop count.
const RING_CAP: usize = 4096;

/// One row of the per-strategy modeled-cost table: what `estimate_for`
/// priced for this candidate at decision time.
#[derive(Debug, Clone, Copy)]
pub struct CandidateCost {
    /// `RepartitionStrategy::name()` of the candidate.
    pub strategy: &'static str,
    /// Modeled one-off rebalance cost (s).
    pub rebalance_cost: f64,
    /// Modeled solve time recovered per subsequent step (s).
    pub saving_per_step: f64,
    /// Predicted post-rebalance load-imbalance factor.
    pub lambda_after: f64,
    /// The `Auto` objective: `rebalance_cost + solve_parallel_time *
    /// max(lambda_after - 1, 0)` -- argmin over the table is the
    /// choice.
    pub total: f64,
}

/// What actually happened once the chosen strategy ran.
#[derive(Debug, Clone, Copy)]
pub struct RealizedOutcome {
    /// Measured + modeled DLB time of the rebalance (s),
    /// `RebalanceReport::dlb_time()`.
    pub dlb_wall_s: f64,
    /// Oliker-Biswas total migration volume.
    pub total_v: f64,
    /// Load-imbalance factor after migration.
    pub lambda_after: f64,
}

/// One trigger evaluation, fired or not.
#[derive(Debug, Clone)]
pub struct FlightEvent {
    /// Adaptive step index of the evaluating driver.
    pub step: usize,
    /// Load-imbalance factor the trigger saw.
    pub lambda: f64,
    /// Trigger policy display name (`lambda:1.20`, `costbenefit:8`).
    pub trigger: String,
    /// The verdict: did the policy fire?
    pub fired: bool,
    /// Modeled rebalance cost the trigger context carried (0 for
    /// policies that never read the estimate).
    pub rebalance_cost: f64,
    /// Modeled per-step saving the trigger context carried.
    pub saving_per_step: f64,
    /// Per-strategy modeled-cost table at decision time (diffusive,
    /// adaptive, scratch -- the `Auto` tie order).
    pub candidates: Vec<CandidateCost>,
    /// `RepartitionStrategy::name()` of the strategy that ran; `None`
    /// when the trigger kept the current distribution.
    pub chosen: Option<&'static str>,
    /// Realized wall/TotalV/lambda, filled in after the rebalance ran.
    pub realized: Option<RealizedOutcome>,
}

impl FlightEvent {
    /// One JSON object, a single JSONL line (no trailing newline).
    /// Hand-rolled like the rest of the crate's JSON output; every
    /// float is emitted through [`json_f64`] so the line stays valid
    /// JSON even for non-finite values.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(256);
        out.push_str(&format!(
            "{{\"step\":{},\"lambda\":{},\"trigger\":\"{}\",\"fired\":{},\
             \"rebalance_cost\":{},\"saving_per_step\":{}",
            self.step,
            json_f64(self.lambda),
            escape(&self.trigger),
            self.fired,
            json_f64(self.rebalance_cost),
            json_f64(self.saving_per_step),
        ));
        out.push_str(",\"candidates\":[");
        for (i, c) in self.candidates.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"strategy\":\"{}\",\"rebalance_cost\":{},\"saving_per_step\":{},\
                 \"lambda_after\":{},\"total\":{}}}",
                c.strategy,
                json_f64(c.rebalance_cost),
                json_f64(c.saving_per_step),
                json_f64(c.lambda_after),
                json_f64(c.total),
            ));
        }
        out.push(']');
        match self.chosen {
            Some(s) => out.push_str(&format!(",\"chosen\":\"{s}\"")),
            None => out.push_str(",\"chosen\":null"),
        }
        match &self.realized {
            Some(r) => out.push_str(&format!(
                ",\"realized\":{{\"dlb_wall_s\":{},\"total_v\":{},\"lambda_after\":{}}}",
                json_f64(r.dlb_wall_s),
                json_f64(r.total_v),
                json_f64(r.lambda_after),
            )),
            None => out.push_str(",\"realized\":null"),
        }
        out.push('}');
        out
    }
}

/// JSON has no NaN/Infinity literals; clamp non-finite floats to 0 so
/// a pathological estimate cannot corrupt the JSONL stream.
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

fn escape(s: &str) -> String {
    crate::serve::json::escape(s)
}

/// The recorder: a bounded ring of [`FlightEvent`]s behind one mutex,
/// with the tracer's enabled/dropped contract.
pub struct FlightRecorder {
    enabled: AtomicBool,
    ring: Mutex<VecDeque<FlightEvent>>,
    dropped: AtomicU64,
}

impl FlightRecorder {
    pub fn new() -> Self {
        Self {
            enabled: AtomicBool::new(false),
            ring: Mutex::new(VecDeque::new()),
            dropped: AtomicU64::new(0),
        }
    }

    /// Whether events are being recorded (one relaxed load -- the
    /// whole cost of a disabled recorder at the instrumentation site).
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Append one event. No-op (no lock, no allocation) when disabled;
    /// beyond the ring cap the *oldest* event is displaced and counted
    /// dropped -- the tail of a long run is the interesting part.
    pub fn record(&self, ev: FlightEvent) {
        if !self.enabled() {
            return;
        }
        let mut ring = self.ring.lock().expect("flight ring poisoned");
        if ring.len() >= RING_CAP {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(ev);
    }

    /// Events recorded and still in the ring.
    pub fn len(&self) -> usize {
        self.ring.lock().expect("flight ring poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events displaced at the ring cap (0 in any sane run).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Copy of the ring in record order; the ring is left intact.
    pub fn snapshot(&self) -> Vec<FlightEvent> {
        self.ring
            .lock()
            .expect("flight ring poisoned")
            .iter()
            .cloned()
            .collect()
    }

    /// Drop every event and reset the dropped counter (tests, and the
    /// boundary between CLI runs sharing the process).
    pub fn clear(&self) {
        self.ring.lock().expect("flight ring poisoned").clear();
        self.dropped.store(0, Ordering::Relaxed);
    }

    /// The whole ring as JSONL (`--flight out.jsonl` writes this).
    pub fn to_jsonl(&self) -> String {
        let ring = self.ring.lock().expect("flight ring poisoned");
        let mut out = String::new();
        for ev in ring.iter() {
            out.push_str(&ev.to_json());
            out.push('\n');
        }
        out
    }
}

impl Default for FlightRecorder {
    fn default() -> Self {
        Self::new()
    }
}

static FLIGHT: OnceLock<FlightRecorder> = OnceLock::new();

/// The process-wide flight recorder the coordinator feeds (disabled
/// until `--flight` or a test enables it).
pub fn flight() -> &'static FlightRecorder {
    FLIGHT.get_or_init(FlightRecorder::new)
}

/// Model-error summary from the always-on audit metrics
/// (`dlb.flight.model_ratio.<strategy>`: modeled cost / realized DLB
/// wall per rebalance): one line per strategy that rebalanced, plus a
/// totals line. Printed at run end by `--flight`; the underlying
/// histograms are in every `--metrics` dump regardless.
pub fn model_error_summary() -> String {
    let m = crate::obs::metrics();
    let mut out = String::new();
    for (strategy, name) in [
        ("scratch", "dlb.flight.model_ratio.scratch"),
        ("diffusive", "dlb.flight.model_ratio.diffusive"),
        ("adaptive", "dlb.flight.model_ratio.adaptive"),
    ] {
        if let Some(h) = m.histogram(name) {
            out.push_str(&format!(
                "flight: {strategy:<10} rebalances={} modeled/realized mean={:.3} \
                 p50={:.3} p95={:.3}\n",
                h.count, h.mean, h.p50, h.p95
            ));
        }
    }
    out.push_str(&format!(
        "flight: rebalances={} events={} dropped={}\n",
        m.counter("dlb.flight.rebalances"),
        flight().len(),
        flight().dropped(),
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(step: usize, fired: bool) -> FlightEvent {
        FlightEvent {
            step,
            lambda: 1.3,
            trigger: "lambda:1.20".to_string(),
            fired,
            rebalance_cost: 1e-3,
            saving_per_step: 2e-3,
            candidates: vec![CandidateCost {
                strategy: "diffusive",
                rebalance_cost: 1e-3,
                saving_per_step: 2e-3,
                lambda_after: 1.01,
                total: 1.2e-3,
            }],
            chosen: fired.then_some("diffusive"),
            realized: fired.then_some(RealizedOutcome {
                dlb_wall_s: 1.5e-3,
                total_v: 42.0,
                lambda_after: 1.02,
            }),
        }
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let r = FlightRecorder::new();
        assert!(!r.enabled());
        r.record(ev(0, true));
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn ring_bounds_and_counts_drops() {
        let r = FlightRecorder::new();
        r.set_enabled(true);
        for i in 0..RING_CAP + 10 {
            r.record(ev(i, false));
        }
        assert_eq!(r.len(), RING_CAP);
        assert_eq!(r.dropped(), 10);
        // oldest displaced: the ring starts at step 10
        assert_eq!(r.snapshot().first().unwrap().step, 10);
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn jsonl_is_one_complete_object_per_event() {
        let r = FlightRecorder::new();
        r.set_enabled(true);
        r.record(ev(0, false));
        r.record(ev(1, true));
        let jsonl = r.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"fired\":false"));
        assert!(lines[0].contains("\"chosen\":null"));
        assert!(lines[0].contains("\"realized\":null"));
        assert!(lines[1].contains("\"fired\":true"));
        assert!(lines[1].contains("\"chosen\":\"diffusive\""));
        assert!(lines[1].contains("\"total_v\":42"));
        // the crate's own JSON parser must accept every line
        for line in lines {
            let v = crate::serve::json::parse(line).expect("valid JSON");
            assert!(v.get("step").is_some());
            assert!(v.get("candidates").is_some());
        }
    }

    #[test]
    fn non_finite_floats_stay_valid_json() {
        let mut e = ev(0, false);
        e.lambda = f64::NAN;
        e.rebalance_cost = f64::INFINITY;
        let line = e.to_json();
        assert!(crate::serve::json::parse(&line).is_ok(), "{line}");
        assert!(!line.contains("NaN") && !line.contains("inf"), "{line}");
    }
}
