//! Zero-dependency status plane (DESIGN.md §14): an opt-in
//! `std::net::TcpListener` thread serving a minimal HTTP/1.1 surface:
//!
//! * `GET /metrics` -- Prometheus text exposition of the whole
//!   [`crate::obs::Metrics`] registry (names normalized
//!   `serve.jobs` -> `serve_jobs`, see [`crate::obs::prom_name`]);
//! * `GET /jobs`    -- live JSONL job table, one JSON object per job,
//!   supplied by the embedder (the serve daemon wires its
//!   `JobRegistry` in as a closure so `obs` never depends on `serve`);
//! * `GET /health`  -- `ok`.
//!
//! Off = off: no `--status-port` means no thread, no socket, no
//! allocation (`tests/obs_overhead.rs` proves it). The server binds
//! `127.0.0.1` only -- this is an operator's loopback window, not a
//! public API -- and handles one request per connection
//! (`Connection: close`), which keeps the loop free of any
//! keep-alive state machine.

use crate::util::error::{Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Supplier of the `/jobs` body: called per request, returns JSONL.
pub type JobsProvider = Arc<dyn Fn() -> String + Send + Sync>;

/// A running status server: one accept-loop thread plus the bound
/// address. Stop it with [`StatusServer::stop`]; dropping it stops it
/// too (best effort, still joins the thread).
pub struct StatusServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl StatusServer {
    /// Bind `127.0.0.1:port` (port 0 = kernel-assigned, for tests;
    /// read the result back from [`StatusServer::addr`]) and spawn the
    /// accept loop.
    pub fn start(port: u16, jobs: Option<JobsProvider>) -> Result<StatusServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding status port {port}"))?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let handle = {
            let shutdown = Arc::clone(&shutdown);
            std::thread::Builder::new()
                .name("phg-status".to_string())
                .spawn(move || accept_loop(listener, &shutdown, jobs))
                .context("spawning status thread")?
        };
        Ok(StatusServer {
            addr,
            shutdown,
            handle: Some(handle),
        })
    }

    /// The bound address (resolves port 0 to the kernel's choice).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the thread. The blocking `accept` is
    /// unblocked by a self-connection -- no platform-specific socket
    /// shutdown needed.
    pub fn stop(mut self) {
        self.shutdown_and_join();
    }

    fn shutdown_and_join(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // wake the accept loop; ignore failure (the thread may already
        // be past accept, or the listener gone at process teardown)
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(500));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for StatusServer {
    fn drop(&mut self) {
        if self.handle.is_some() {
            self.shutdown_and_join();
        }
    }
}

fn accept_loop(listener: TcpListener, shutdown: &AtomicBool, jobs: Option<JobsProvider>) {
    for conn in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        let Ok(stream) = conn else { continue };
        // a stalled client must not wedge the (single-threaded)
        // accept loop; 2s is generous for a loopback GET
        let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
        let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
        // per-request failures (disconnects, timeouts) are the
        // client's problem, never the daemon's
        let _ = handle_conn(stream, &jobs);
    }
}

fn handle_conn(mut stream: TcpStream, jobs: &Option<JobsProvider>) -> std::io::Result<()> {
    let path = match read_request_path(&mut stream)? {
        Some(p) => p,
        None => return Ok(()), // malformed request line: just close
    };
    let (status, content_type, body) = match path.as_str() {
        "/metrics" => {
            crate::obs::sync_derived_metrics();
            (
                "200 OK",
                // the Prometheus text exposition format version
                "text/plain; version=0.0.4; charset=utf-8",
                crate::obs::metrics().prometheus(),
            )
        }
        "/jobs" => (
            "200 OK",
            "application/x-ndjson",
            jobs.as_ref().map_or_else(String::new, |p| p()),
        ),
        "/health" => ("200 OK", "text/plain; charset=utf-8", "ok\n".to_string()),
        _ => (
            "404 Not Found",
            "text/plain; charset=utf-8",
            "not found (routes: /metrics /jobs /health)\n".to_string(),
        ),
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

/// Read up to the header terminator and return the request-line path
/// (`GET /metrics HTTP/1.1` -> `/metrics`). `None` on anything that
/// is not a well-formed GET -- this is a status window, not a server.
fn read_request_path(stream: &mut TcpStream) -> std::io::Result<Option<String>> {
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
            break;
        }
    }
    let text = String::from_utf8_lossy(&buf);
    let line = text.lines().next().unwrap_or("");
    let mut parts = line.split_whitespace();
    match (parts.next(), parts.next()) {
        (Some("GET"), Some(path)) => Ok(Some(path.to_string())),
        _ => Ok(None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Blocking loopback GET against a test server; returns
    /// (status line, body).
    fn get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").as_bytes())
            .unwrap();
        let mut text = String::new();
        s.read_to_string(&mut text).unwrap();
        let (head, body) = text.split_once("\r\n\r\n").expect("header terminator");
        (head.lines().next().unwrap().to_string(), body.to_string())
    }

    #[test]
    fn routes_serve_health_jobs_and_404() {
        let jobs: JobsProvider = Arc::new(|| "{\"id\":\"t\"}\n".to_string());
        let srv = StatusServer::start(0, Some(jobs)).expect("ephemeral bind");
        let addr = srv.addr();
        assert_ne!(addr.port(), 0, "port 0 must resolve to a real port");

        let (status, body) = get(addr, "/health");
        assert!(status.contains("200"), "{status}");
        assert_eq!(body, "ok\n");

        let (status, body) = get(addr, "/jobs");
        assert!(status.contains("200"), "{status}");
        assert_eq!(body, "{\"id\":\"t\"}\n");

        let (status, body) = get(addr, "/nope");
        assert!(status.contains("404"), "{status}");
        assert!(body.contains("/metrics"), "{body}");

        let (status, body) = get(addr, "/metrics");
        assert!(status.contains("200"), "{status}");
        // the registry is process-global and other tests feed it, so
        // only the format is asserted here: every non-comment line is
        // `name[{quantile}] value`
        for line in body.lines() {
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("name value");
            assert!(!name.is_empty());
            assert!(value.parse::<f64>().is_ok(), "unparsable value: {line}");
            // the metric name (before any {quantile=...} label set)
            // must be dot-free, i.e. prom_name-normalized
            let metric = name.split('{').next().unwrap();
            assert!(!metric.contains('.'), "un-normalized name: {line}");
        }
        srv.stop();
    }

    #[test]
    fn jobs_without_provider_is_empty_200() {
        let srv = StatusServer::start(0, None).expect("bind");
        let (status, body) = get(srv.addr(), "/jobs");
        assert!(status.contains("200"), "{status}");
        assert!(body.is_empty());
        srv.stop();
    }

    #[test]
    fn stop_joins_even_with_no_traffic() {
        let srv = StatusServer::start(0, None).expect("bind");
        srv.stop(); // must not hang on the blocking accept
    }
}
