//! Span-based phase tracing: per-rank buffers of timed phase spans,
//! exported as Chrome trace-event JSON (DESIGN.md §10).
//!
//! A [`Span`] is an RAII guard: opening records the monotonic start
//! time, dropping records the end and pushes one [`SpanEvent`] into
//! the rank's buffer. Tracing is **disabled by default** and the
//! disabled path is two relaxed atomic loads with no allocation and
//! no clock read (`tests/obs_overhead.rs` enforces this with a
//! counting allocator), so instrumented hot loops -- the PCG phases
//! run per rank per iteration -- cost nothing unless a trace was
//! asked for (`--trace out.json`).
//!
//! The exported JSON uses complete (`"ph": "X"`) events plus
//! `thread_name` metadata, one trace lane per rank and one for the
//! driver's sequential phases; Perfetto / `chrome://tracing` load it
//! directly.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// What a span measures. The names are the stable vocabulary of the
/// trace output and the per-phase aggregate; `assemble`/`spmv`/`dot`/
/// `axpy` are *logical* compute phases emitted identically by both
/// execution schedules, `halo_*`/`barrier_wait` exist only where the
/// schedule physically waits, and the rest are the driver's
/// sequential phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Phase {
    Assemble,
    Spmv,
    Dot,
    Axpy,
    HaloSend,
    HaloRecv,
    BarrierWait,
    Partition,
    Remap,
    Migrate,
    Estimate,
    Mark,
    Refine,
    Solve,
}

impl Phase {
    /// Every phase, documentation order.
    pub const ALL: [Phase; 14] = [
        Phase::Assemble,
        Phase::Spmv,
        Phase::Dot,
        Phase::Axpy,
        Phase::HaloSend,
        Phase::HaloRecv,
        Phase::BarrierWait,
        Phase::Partition,
        Phase::Remap,
        Phase::Migrate,
        Phase::Estimate,
        Phase::Mark,
        Phase::Refine,
        Phase::Solve,
    ];

    /// Stable span name (the `name` field of the trace events).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Assemble => "assemble",
            Phase::Spmv => "spmv",
            Phase::Dot => "dot",
            Phase::Axpy => "axpy",
            Phase::HaloSend => "halo_send",
            Phase::HaloRecv => "halo_recv",
            Phase::BarrierWait => "barrier_wait",
            Phase::Partition => "partition",
            Phase::Remap => "remap",
            Phase::Migrate => "migrate",
            Phase::Estimate => "estimate",
            Phase::Mark => "mark",
            Phase::Refine => "refine",
            Phase::Solve => "solve",
        }
    }

    /// Trace category (`cat`): which subsystem emits the phase.
    pub fn category(self) -> &'static str {
        match self {
            Phase::Assemble
            | Phase::Spmv
            | Phase::Dot
            | Phase::Axpy
            | Phase::HaloSend
            | Phase::HaloRecv
            | Phase::BarrierWait => "exec",
            Phase::Partition | Phase::Remap | Phase::Migrate => "dlb",
            Phase::Estimate | Phase::Mark | Phase::Refine | Phase::Solve => "driver",
        }
    }
}

/// Lane id of the driver's sequential phases (solve wrapper,
/// estimate, mark, refine, partition, remap, migrate): everything
/// that is not per-rank work.
pub const DRIVER_LANE: u32 = u32::MAX;

/// One closed span: which lane (rank or driver), which phase, and
/// monotonic nanoseconds since the tracer's epoch. `t1_ns >= t0_ns`
/// by construction (both read the same monotonic clock).
#[derive(Debug, Clone, Copy)]
pub struct SpanEvent {
    pub rank: u32,
    pub phase: Phase,
    pub t0_ns: u64,
    pub t1_ns: u64,
}

impl SpanEvent {
    /// Span duration in seconds.
    pub fn secs(&self) -> f64 {
        (self.t1_ns - self.t0_ns) as f64 * 1e-9
    }
}

/// One buffer per rank; ranks >= `SHARDS` share buffers modulo (the
/// tested configurations run nparts <= 64, where this *is* per-rank).
const SHARDS: usize = 64;

/// Hard cap per buffer so a pathological run cannot exhaust memory;
/// spans beyond it are counted in `dropped`, never silently lost.
const SHARD_CAP: usize = 1 << 20;

/// The span recorder. Thread-safe: ranks record concurrently into
/// their own buffers; the disabled fast path never takes a lock.
pub struct Tracer {
    enabled: AtomicBool,
    epoch: Instant,
    shards: Vec<Mutex<Vec<SpanEvent>>>,
    dropped: AtomicU64,
}

impl Tracer {
    /// A fresh, disabled tracer with its own epoch.
    pub fn new() -> Self {
        Self {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            shards: (0..SHARDS).map(|_| Mutex::new(Vec::new())).collect(),
            dropped: AtomicU64::new(0),
        }
    }

    /// Whether spans are being recorded (one relaxed load).
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Monotonic nanoseconds since this tracer's epoch.
    #[inline]
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Open a span on a rank lane. When tracing is disabled this
    /// performs no clock read and no allocation -- the guard is inert.
    #[inline]
    pub fn span(&self, rank: usize, phase: Phase) -> Span<'_> {
        self.span_lane(rank as u32, phase)
    }

    /// Open a span on an explicit lane ([`DRIVER_LANE`] included).
    #[inline]
    pub fn span_lane(&self, lane: u32, phase: Phase) -> Span<'_> {
        let live = if self.enabled() {
            Some((lane, phase, self.now_ns()))
        } else {
            None
        };
        Span { tracer: self, live }
    }

    /// Record an already-measured interval (the barrier helper in
    /// `exec::pcg` measures one wait and charges every rank of the
    /// worker's bundle). Callers gate on [`Tracer::enabled`].
    pub fn record_span(&self, rank: u32, phase: Phase, t0_ns: u64, t1_ns: u64) {
        self.push(SpanEvent {
            rank,
            phase,
            t0_ns,
            t1_ns,
        });
    }

    fn push(&self, ev: SpanEvent) {
        let mut buf = self.shards[(ev.rank as usize) % SHARDS]
            .lock()
            .expect("trace shard poisoned");
        if buf.len() < SHARD_CAP {
            buf.push(ev);
        } else {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Spans recorded so far.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("trace shard poisoned").len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans dropped at the buffer cap (0 in any sane run).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Drop every recorded span and reset the dropped counter.
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().expect("trace shard poisoned").clear();
        }
        self.dropped.store(0, Ordering::Relaxed);
    }

    /// All recorded spans, deterministically ordered by (start, lane,
    /// phase name, end). Buffers are left intact.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let mut out = Vec::with_capacity(self.len());
        for s in &self.shards {
            out.extend(s.lock().expect("trace shard poisoned").iter().copied());
        }
        out.sort_by(|a, b| {
            (a.t0_ns, a.rank, a.phase.name(), a.t1_ns)
                .cmp(&(b.t0_ns, b.rank, b.phase.name(), b.t1_ns))
        });
        out
    }

    /// [`Tracer::snapshot`], then clear.
    pub fn take(&self) -> Vec<SpanEvent> {
        let out = self.snapshot();
        self.clear();
        out
    }

    /// Compact aggregate: phase name -> (span count, total seconds),
    /// in deterministic (sorted) order.
    pub fn phase_totals(&self) -> BTreeMap<&'static str, (u64, f64)> {
        let mut totals: BTreeMap<&'static str, (u64, f64)> = BTreeMap::new();
        for ev in self.snapshot() {
            let e = totals.entry(ev.phase.name()).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += ev.secs();
        }
        totals
    }

    /// The whole buffer as Chrome trace-event JSON (the `--trace`
    /// output): complete `"X"` events in microseconds plus
    /// `thread_name` metadata -- tid 0 is the driver lane, tid `r+1`
    /// is rank `r`. Loads directly in Perfetto / `chrome://tracing`.
    pub fn chrome_trace_json(&self) -> String {
        let events = self.snapshot();
        let lane_tid = |rank: u32| -> u64 {
            if rank == DRIVER_LANE {
                0
            } else {
                rank as u64 + 1
            }
        };
        let mut lanes: Vec<u32> = events.iter().map(|e| e.rank).collect();
        lanes.sort_by_key(|&r| lane_tid(r));
        lanes.dedup();

        let mut lines: Vec<String> = Vec::with_capacity(events.len() + lanes.len() + 1);
        lines.push(
            "{\"ph\":\"M\",\"pid\":0,\"tid\":0,\"name\":\"process_name\",\
             \"args\":{\"name\":\"phg-dlb\"}}"
                .to_string(),
        );
        for &rank in &lanes {
            let name = if rank == DRIVER_LANE {
                "driver".to_string()
            } else {
                format!("rank {rank}")
            };
            lines.push(format!(
                "{{\"ph\":\"M\",\"pid\":0,\"tid\":{},\"name\":\"thread_name\",\
                 \"args\":{{\"name\":\"{name}\"}}}}",
                lane_tid(rank)
            ));
        }
        for ev in &events {
            lines.push(format!(
                "{{\"ph\":\"X\",\"pid\":0,\"tid\":{},\"name\":\"{}\",\"cat\":\"{}\",\
                 \"ts\":{:.3},\"dur\":{:.3}}}",
                lane_tid(ev.rank),
                ev.phase.name(),
                ev.phase.category(),
                ev.t0_ns as f64 / 1e3,
                (ev.t1_ns - ev.t0_ns) as f64 / 1e3,
            ));
        }
        let mut out = String::from("{\n\"displayTimeUnit\": \"ms\",\n\"traceEvents\": [\n");
        out.push_str(&lines.join(",\n"));
        out.push_str("\n]\n}\n");
        out
    }
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

/// RAII span guard: records one [`SpanEvent`] on drop. Inert (no
/// clock read, no allocation) when the tracer was disabled at open.
#[must_use]
pub struct Span<'a> {
    tracer: &'a Tracer,
    live: Option<(u32, Phase, u64)>,
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        if let Some((rank, phase, t0_ns)) = self.live.take() {
            let t1_ns = self.tracer.now_ns();
            self.tracer.push(SpanEvent {
                rank,
                phase,
                t0_ns,
                t1_ns,
            });
        }
    }
}

static TRACER: OnceLock<Tracer> = OnceLock::new();

/// The process-wide tracer every instrumentation site records into
/// (disabled until `--trace` or a test enables it).
pub fn tracer() -> &'static Tracer {
    TRACER.get_or_init(Tracer::new)
}

/// Open a span on the global tracer's rank lane.
#[inline]
pub fn span(rank: usize, phase: Phase) -> Span<'static> {
    tracer().span(rank, phase)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new();
        assert!(!t.enabled());
        {
            let _sp = t.span(0, Phase::Spmv);
        }
        assert!(t.is_empty());
    }

    #[test]
    fn spans_record_monotonic_intervals() {
        let t = Tracer::new();
        t.set_enabled(true);
        {
            let _outer = t.span(2, Phase::Solve);
            let _inner = t.span(2, Phase::Spmv);
        }
        let evs = t.snapshot();
        assert_eq!(evs.len(), 2);
        for e in &evs {
            assert!(e.t1_ns >= e.t0_ns);
            assert_eq!(e.rank, 2);
        }
        // the inner span (spmv) opened after and closed before the
        // outer one (drop order: inner first)
        let inner = evs.iter().find(|e| e.phase == Phase::Spmv).unwrap();
        let outer = evs.iter().find(|e| e.phase == Phase::Solve).unwrap();
        assert!(inner.t0_ns >= outer.t0_ns);
        assert!(inner.t1_ns <= outer.t1_ns);
    }

    #[test]
    fn take_drains_and_totals_aggregate() {
        let t = Tracer::new();
        t.set_enabled(true);
        for rk in 0..3 {
            let _sp = t.span(rk, Phase::Dot);
        }
        {
            let _sp = t.span_lane(DRIVER_LANE, Phase::Estimate);
        }
        let totals = t.phase_totals();
        assert_eq!(totals["dot"].0, 3);
        assert_eq!(totals["estimate"].0, 1);
        assert_eq!(t.take().len(), 4);
        assert!(t.is_empty());
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn chrome_json_has_events_and_lane_names() {
        let t = Tracer::new();
        t.set_enabled(true);
        {
            let _a = t.span(0, Phase::Assemble);
            let _b = t.span_lane(DRIVER_LANE, Phase::Partition);
        }
        let json = t.chrome_trace_json();
        assert!(json.starts_with('{'));
        assert!(json.trim_end().ends_with('}'));
        assert!(json.contains("\"traceEvents\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"name\":\"assemble\""));
        assert!(json.contains("\"name\":\"driver\""));
        assert!(json.contains("\"name\":\"rank 0\""));
    }

    #[test]
    fn phase_vocabulary_is_stable() {
        let mut names: Vec<&str> = Phase::ALL.iter().map(|p| p.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Phase::ALL.len(), "duplicate phase names");
        for p in Phase::ALL {
            assert!(!p.name().is_empty());
            assert!(matches!(p.category(), "exec" | "dlb" | "driver"));
        }
    }
}
