//! Metrics registry: named counters and streaming histograms with a
//! deterministic dump order (DESIGN.md §10).
//!
//! Unlike tracing (off by default, per-iteration granularity), the
//! registry is always on: it is fed at *step* granularity by the
//! driver, the rebalance pipeline and the executors, so its cost is
//! a handful of mutex-guarded map updates per adaptive step --
//! invisible next to a solve.
//!
//! Histograms are fixed-size power-of-two bucket arrays. The bucket
//! of a value is derived from its IEEE-754 exponent bits (not
//! `f64::log2`, whose rounding is not guaranteed identical across
//! platforms), so the same samples always land in the same buckets
//! everywhere. Quantiles (p50/p95) are read back as the midpoint of
//! the covering bucket, clamped to the exact observed [min, max];
//! min, max, count and sum are exact.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

/// Buckets span 2^-40 .. 2^23 (about 1e-12 s .. 8.4e6): everything
/// from a single axpy to a multi-week wall fits. Values outside are
/// clamped into the edge buckets; min/max stay exact regardless.
const BUCKETS: usize = 64;
const EXP_OFFSET: i32 = 40;

/// Streaming histogram: exact count/sum/min/max plus power-of-two
/// buckets for quantile estimates.
#[derive(Debug, Clone)]
pub struct Histogram {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    buckets: [u64; BUCKETS],
}

/// Bucket index from the IEEE exponent: floor(log2 v) for normal
/// positive v, deterministic bit arithmetic everywhere.
fn bucket_of(v: f64) -> usize {
    if !(v > 0.0) || !v.is_finite() {
        return 0;
    }
    let e = ((v.to_bits() >> 52) & 0x7ff) as i32 - 1023;
    (e + EXP_OFFSET).clamp(0, BUCKETS as i32 - 1) as usize
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: [0; BUCKETS],
        }
    }

    pub fn observe(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.buckets[bucket_of(v)] += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Quantile estimate: the midpoint (1.5 * 2^e) of the first
    /// bucket whose cumulative count covers `q`, clamped to the
    /// exact observed range.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                if i == 0 {
                    // zero/negative/subnormal catch-all: no midpoint
                    return self.min;
                }
                let mid = 1.5 * 2.0f64.powi(i as i32 - EXP_OFFSET);
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Read-only snapshot of one histogram, for tests and reports.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

#[derive(Debug)]
enum Entry {
    Counter(u64),
    Hist(Histogram),
}

/// The registry: a name-keyed map of counters and histograms. Names
/// are `&'static str` dotted paths (`"driver.solve_s"`), so feeding
/// a metric never allocates once its entry exists; `BTreeMap` keeps
/// the dump sorted by name with no extra work.
pub struct Metrics {
    inner: Mutex<BTreeMap<&'static str, Entry>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(BTreeMap::new()),
        }
    }

    /// Add to a monotonic counter, creating it at zero on first use.
    pub fn counter_add(&self, name: &'static str, by: u64) {
        let mut m = self.inner.lock().expect("metrics poisoned");
        match m.entry(name).or_insert(Entry::Counter(0)) {
            Entry::Counter(c) => *c += by,
            Entry::Hist(_) => debug_assert!(false, "metric {name} is a histogram"),
        }
    }

    /// Record one sample into a histogram, creating it on first use.
    pub fn observe(&self, name: &'static str, v: f64) {
        let mut m = self.inner.lock().expect("metrics poisoned");
        match m.entry(name).or_insert_with(|| Entry::Hist(Histogram::new())) {
            Entry::Hist(h) => h.observe(v),
            Entry::Counter(_) => debug_assert!(false, "metric {name} is a counter"),
        }
    }

    /// Current counter value (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        match self.inner.lock().expect("metrics poisoned").get(name) {
            Some(Entry::Counter(c)) => *c,
            _ => 0,
        }
    }

    /// Snapshot of a histogram, `None` if absent.
    pub fn histogram(&self, name: &str) -> Option<HistSummary> {
        match self.inner.lock().expect("metrics poisoned").get(name) {
            Some(Entry::Hist(h)) => Some(HistSummary {
                count: h.count(),
                mean: h.mean(),
                min: h.min(),
                max: h.max(),
                p50: h.quantile(0.50),
                p95: h.quantile(0.95),
            }),
            _ => None,
        }
    }

    /// Drop every metric (tests).
    pub fn clear(&self) {
        self.inner.lock().expect("metrics poisoned").clear();
    }

    /// Dump every metric, one line each, sorted by name -- counters
    /// as `name = value`, histograms as count/mean/p50/p95/max. The
    /// `--metrics` flag writes exactly this.
    pub fn dump(&self) -> String {
        let m = self.inner.lock().expect("metrics poisoned");
        let mut out = String::new();
        for (name, entry) in m.iter() {
            match entry {
                Entry::Counter(c) => {
                    out.push_str(&format!("{name} = {c}\n"));
                }
                Entry::Hist(h) => {
                    out.push_str(&format!(
                        "{name} count={} mean={:.6e} p50={:.6e} p95={:.6e} max={:.6e}\n",
                        h.count(),
                        h.mean(),
                        h.quantile(0.50),
                        h.quantile(0.95),
                        h.max()
                    ));
                }
            }
        }
        out
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

static METRICS: OnceLock<Metrics> = OnceLock::new();

/// The process-wide registry the driver, pipeline and executors feed.
pub fn metrics() -> &'static Metrics {
    METRICS.get_or_init(Metrics::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        assert_eq!(m.counter("steps"), 0);
        m.counter_add("steps", 1);
        m.counter_add("steps", 2);
        assert_eq!(m.counter("steps"), 3);
    }

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe("w", i as f64);
        }
        let h = m.histogram("w").unwrap();
        assert_eq!(h.count, 100);
        assert_eq!(h.max, 100.0);
        assert_eq!(h.min, 1.0);
        assert!((h.mean - 50.5).abs() < 1e-9);
        // p50 covers sample 50 -> the [32,64) bucket, midpoint 48
        assert!(h.p50 >= 32.0 && h.p50 < 64.0, "p50 = {}", h.p50);
        // p95 covers sample 95 -> the [64,128) bucket, clamped <= max
        assert!(h.p95 >= 64.0 && h.p95 <= 100.0, "p95 = {}", h.p95);
        assert!(h.p50 <= h.p95 && h.p95 <= h.max);
    }

    #[test]
    fn zero_and_tiny_samples_are_safe() {
        let m = Metrics::new();
        m.observe("t", 0.0);
        m.observe("t", 1e-300);
        m.observe("t", f64::NAN);
        let h = m.histogram("t").unwrap();
        assert_eq!(h.count, 3);
        assert_eq!(h.min.min(0.0), 0.0);
        // quantile of the catch-all bucket returns the exact min
        assert_eq!(m.histogram("t").unwrap().p50.min(0.0), 0.0);
    }

    #[test]
    fn dump_is_sorted_and_deterministic() {
        let m = Metrics::new();
        m.counter_add("z.count", 7);
        m.observe("a.wall_s", 0.25);
        m.observe("a.wall_s", 0.5);
        m.counter_add("m.items", 1);
        let d1 = m.dump();
        let d2 = m.dump();
        assert_eq!(d1, d2, "dump must be reproducible");
        let lines: Vec<&str> = d1.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("a.wall_s count=2"));
        assert!(lines[1].starts_with("m.items = 1"));
        assert!(lines[2].starts_with("z.count = 7"));
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let h = Histogram::new();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn bucket_of_is_exponent_exact() {
        assert_eq!(bucket_of(1.0), EXP_OFFSET as usize);
        assert_eq!(bucket_of(2.0), EXP_OFFSET as usize + 1);
        assert_eq!(bucket_of(3.9), EXP_OFFSET as usize + 1);
        assert_eq!(bucket_of(0.5), EXP_OFFSET as usize - 1);
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(-4.0), 0);
        assert_eq!(bucket_of(1e300), BUCKETS - 1);
    }
}
