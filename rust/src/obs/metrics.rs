//! Metrics registry: named counters and streaming histograms with a
//! deterministic dump order (DESIGN.md §10).
//!
//! Unlike tracing (off by default, per-iteration granularity), the
//! registry is always on: it is fed at *step* granularity by the
//! driver, the rebalance pipeline and the executors, so its cost is
//! a handful of mutex-guarded map updates per adaptive step --
//! invisible next to a solve.
//!
//! Histograms are fixed-size power-of-two bucket arrays. The bucket
//! of a value is derived from its IEEE-754 exponent bits (not
//! `f64::log2`, whose rounding is not guaranteed identical across
//! platforms), so the same samples always land in the same buckets
//! everywhere. Quantiles (p50/p95) are read back as the midpoint of
//! the covering bucket, clamped to the exact observed [min, max];
//! min, max, count and sum are exact.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

/// Buckets span 2^-40 .. 2^23 (about 1e-12 s .. 8.4e6): everything
/// from a single axpy to a multi-week wall fits. Values outside are
/// clamped into the edge buckets; min/max stay exact regardless.
const BUCKETS: usize = 64;
const EXP_OFFSET: i32 = 40;

/// Streaming histogram: exact count/sum/min/max plus power-of-two
/// buckets for quantile estimates.
#[derive(Debug, Clone)]
pub struct Histogram {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    buckets: [u64; BUCKETS],
}

/// Bucket index from the IEEE exponent: floor(log2 v) for normal
/// positive v, deterministic bit arithmetic everywhere.
fn bucket_of(v: f64) -> usize {
    if !(v > 0.0) || !v.is_finite() {
        return 0;
    }
    let e = ((v.to_bits() >> 52) & 0x7ff) as i32 - 1023;
    (e + EXP_OFFSET).clamp(0, BUCKETS as i32 - 1) as usize
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: [0; BUCKETS],
        }
    }

    /// Record one sample. Non-finite values (NaN, ±inf) are counted
    /// and land in the catch-all bucket, but stay out of sum/min/max
    /// so a single bad sample cannot poison the mean or wreck the
    /// quantile clamp range.
    pub fn observe(&mut self, v: f64) {
        self.count += 1;
        if v.is_finite() {
            self.sum += v;
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.buckets[bucket_of(v)] += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact sum of the finite samples.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Smallest finite sample; 0 when none has been observed (empty
    /// histogram, or nothing but NaN/±inf).
    pub fn min(&self) -> f64 {
        if self.min.is_finite() {
            self.min
        } else {
            0.0
        }
    }

    /// Largest finite sample; 0 when none has been observed.
    pub fn max(&self) -> f64 {
        if self.max.is_finite() {
            self.max
        } else {
            0.0
        }
    }

    /// Quantile estimate: the midpoint (1.5 * 2^e) of the first
    /// bucket whose cumulative count covers `q`, clamped to the
    /// exact observed range.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                if i == 0 {
                    // zero/negative/subnormal/non-finite catch-all:
                    // no midpoint, fall back to the guarded min
                    return self.min();
                }
                // i > 0 implies a finite positive sample was observed,
                // so the guarded accessors return a real range here
                let mid = 1.5 * 2.0f64.powi(i as i32 - EXP_OFFSET);
                return mid.clamp(self.min(), self.max());
            }
        }
        self.max()
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Read-only snapshot of one histogram, for tests and reports.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

#[derive(Debug)]
enum Entry {
    Counter(u64),
    Hist(Histogram),
}

/// Prometheus-normalized metric name: dots become underscores
/// (`serve.jobs` -> `serve_jobs`). Registration enforces (in debug
/// builds) that names contain nothing but `[a-z0-9_.]`, so this one
/// substitution is the whole mapping -- `/metrics`, [`Metrics::dump`]
/// and the bench extras agree on names by construction.
pub fn prom_name(name: &str) -> String {
    name.replace('.', "_")
}

/// A registrable metric name: starts with a lowercase letter, made of
/// `[a-z0-9_.]`, no trailing dot. Checked by `debug_assert!` at every
/// registration site so a bad name fails tier-1, never production.
fn valid_metric_name(name: &str) -> bool {
    let b = name.as_bytes();
    !b.is_empty()
        && b[0].is_ascii_lowercase()
        && b[b.len() - 1] != b'.'
        && b.iter()
            .all(|&c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == b'_' || c == b'.')
}

/// The registry: a name-keyed map of counters and histograms. Names
/// are `&'static str` dotted paths (`"driver.solve_s"`), so feeding
/// a metric never allocates once its entry exists; `BTreeMap` keeps
/// the dump sorted by name with no extra work.
pub struct Metrics {
    inner: Mutex<BTreeMap<&'static str, Entry>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(BTreeMap::new()),
        }
    }

    /// Add to a monotonic counter, creating it at zero on first use.
    pub fn counter_add(&self, name: &'static str, by: u64) {
        debug_assert!(valid_metric_name(name), "invalid metric name {name:?}");
        let mut m = self.inner.lock().expect("metrics poisoned");
        match m.entry(name).or_insert(Entry::Counter(0)) {
            Entry::Counter(c) => *c += by,
            Entry::Hist(_) => debug_assert!(false, "metric {name} is a histogram"),
        }
    }

    /// Set a counter to an absolute value. For gauges derived from
    /// state owned elsewhere (`obs.trace.dropped` mirrors
    /// `Tracer::dropped()`), refreshed by `obs::sync_derived_metrics`
    /// just before every dump or `/metrics` scrape.
    pub fn counter_set(&self, name: &'static str, v: u64) {
        debug_assert!(valid_metric_name(name), "invalid metric name {name:?}");
        let mut m = self.inner.lock().expect("metrics poisoned");
        match m.entry(name).or_insert(Entry::Counter(0)) {
            Entry::Counter(c) => *c = v,
            Entry::Hist(_) => debug_assert!(false, "metric {name} is a histogram"),
        }
    }

    /// Record one sample into a histogram, creating it on first use.
    pub fn observe(&self, name: &'static str, v: f64) {
        debug_assert!(valid_metric_name(name), "invalid metric name {name:?}");
        let mut m = self.inner.lock().expect("metrics poisoned");
        match m.entry(name).or_insert_with(|| Entry::Hist(Histogram::new())) {
            Entry::Hist(h) => h.observe(v),
            Entry::Counter(_) => debug_assert!(false, "metric {name} is a counter"),
        }
    }

    /// Current counter value (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        match self.inner.lock().expect("metrics poisoned").get(name) {
            Some(Entry::Counter(c)) => *c,
            _ => 0,
        }
    }

    /// Snapshot of a histogram, `None` if absent.
    pub fn histogram(&self, name: &str) -> Option<HistSummary> {
        match self.inner.lock().expect("metrics poisoned").get(name) {
            Some(Entry::Hist(h)) => Some(HistSummary {
                count: h.count(),
                mean: h.mean(),
                min: h.min(),
                max: h.max(),
                p50: h.quantile(0.50),
                p95: h.quantile(0.95),
            }),
            _ => None,
        }
    }

    /// Drop every metric (tests).
    pub fn clear(&self) {
        self.inner.lock().expect("metrics poisoned").clear();
    }

    /// Dump every metric, one line each, sorted by name -- counters
    /// as `name = value`, histograms as count/mean/p50/p95/max. The
    /// `--metrics` flag writes exactly this.
    pub fn dump(&self) -> String {
        let m = self.inner.lock().expect("metrics poisoned");
        let mut out = String::new();
        for (name, entry) in m.iter() {
            match entry {
                Entry::Counter(c) => {
                    out.push_str(&format!("{name} = {c}\n"));
                }
                Entry::Hist(h) => {
                    out.push_str(&format!(
                        "{name} count={} mean={:.6e} p50={:.6e} p95={:.6e} max={:.6e}\n",
                        h.count(),
                        h.mean(),
                        h.quantile(0.50),
                        h.quantile(0.95),
                        h.max()
                    ));
                }
            }
        }
        out
    }

    /// Prometheus text exposition (format 0.0.4) of the whole
    /// registry, names normalized via [`prom_name`]: counters as
    /// `# TYPE name counter` plus value, histograms as a summary with
    /// p50/p95 quantiles and exact `_sum`/`_count`. Served by
    /// `obs::serve_status` at `/metrics`.
    pub fn prometheus(&self) -> String {
        let m = self.inner.lock().expect("metrics poisoned");
        let mut out = String::new();
        for (name, entry) in m.iter() {
            let p = prom_name(name);
            match entry {
                Entry::Counter(c) => {
                    out.push_str(&format!("# TYPE {p} counter\n{p} {c}\n"));
                }
                Entry::Hist(h) => {
                    out.push_str(&format!(
                        "# TYPE {p} summary\n\
                         {p}{{quantile=\"0.5\"}} {}\n\
                         {p}{{quantile=\"0.95\"}} {}\n\
                         {p}_sum {}\n\
                         {p}_count {}\n",
                        h.quantile(0.50),
                        h.quantile(0.95),
                        h.sum(),
                        h.count()
                    ));
                }
            }
        }
        out
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

static METRICS: OnceLock<Metrics> = OnceLock::new();

/// The process-wide registry the driver, pipeline and executors feed.
pub fn metrics() -> &'static Metrics {
    METRICS.get_or_init(Metrics::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        assert_eq!(m.counter("steps"), 0);
        m.counter_add("steps", 1);
        m.counter_add("steps", 2);
        assert_eq!(m.counter("steps"), 3);
    }

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe("w", i as f64);
        }
        let h = m.histogram("w").unwrap();
        assert_eq!(h.count, 100);
        assert_eq!(h.max, 100.0);
        assert_eq!(h.min, 1.0);
        assert!((h.mean - 50.5).abs() < 1e-9);
        // p50 covers sample 50 -> the [32,64) bucket, midpoint 48
        assert!(h.p50 >= 32.0 && h.p50 < 64.0, "p50 = {}", h.p50);
        // p95 covers sample 95 -> the [64,128) bucket, clamped <= max
        assert!(h.p95 >= 64.0 && h.p95 <= 100.0, "p95 = {}", h.p95);
        assert!(h.p50 <= h.p95 && h.p95 <= h.max);
    }

    #[test]
    fn zero_and_tiny_samples_are_safe() {
        let m = Metrics::new();
        m.observe("t", 0.0);
        m.observe("t", 1e-300);
        m.observe("t", f64::NAN);
        let h = m.histogram("t").unwrap();
        assert_eq!(h.count, 3);
        assert_eq!(h.min.min(0.0), 0.0);
        // quantile of the catch-all bucket returns the exact min
        assert_eq!(m.histogram("t").unwrap().p50.min(0.0), 0.0);
    }

    #[test]
    fn dump_is_sorted_and_deterministic() {
        let m = Metrics::new();
        m.counter_add("z.count", 7);
        m.observe("a.wall_s", 0.25);
        m.observe("a.wall_s", 0.5);
        m.counter_add("m.items", 1);
        let d1 = m.dump();
        let d2 = m.dump();
        assert_eq!(d1, d2, "dump must be reproducible");
        let lines: Vec<&str> = d1.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("a.wall_s count=2"));
        assert!(lines[1].starts_with("m.items = 1"));
        assert!(lines[2].starts_with("z.count = 7"));
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let h = Histogram::new();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn non_finite_observations_do_not_poison() {
        let m = Metrics::new();
        m.observe("nf", 2.0);
        m.observe("nf", f64::NAN);
        m.observe("nf", f64::INFINITY);
        m.observe("nf", f64::NEG_INFINITY);
        m.observe("nf", 4.0);
        let h = m.histogram("nf").unwrap();
        assert_eq!(h.count, 5, "non-finite samples are still counted");
        assert_eq!(h.min, 2.0, "min tracks only finite samples");
        assert_eq!(h.max, 4.0, "max tracks only finite samples");
        assert!(h.mean.is_finite(), "mean = {}", h.mean);
        assert!(h.p50.is_finite() && h.p95.is_finite());
        assert!(h.p50 >= 2.0 && h.p95 <= 4.0, "p50={} p95={}", h.p50, h.p95);
    }

    #[test]
    fn all_non_finite_histogram_reads_zero() {
        let mut h = Histogram::new();
        h.observe(f64::NAN);
        h.observe(f64::INFINITY);
        assert_eq!(h.count(), 2);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.sum(), 0.0);
    }

    #[test]
    fn single_sample_quantiles_are_the_sample() {
        let mut h = Histogram::new();
        h.observe(3.0);
        assert_eq!(h.quantile(0.0), 3.0);
        assert_eq!(h.quantile(0.5), 3.0);
        assert_eq!(h.quantile(1.0), 3.0);
        assert_eq!(h.min(), 3.0);
        assert_eq!(h.max(), 3.0);
    }

    #[test]
    fn concurrent_counter_add_sums_exactly() {
        let m = Metrics::new();
        let threads = 8u64;
        let per = 10_000u64;
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| {
                    for _ in 0..per {
                        m.counter_add("c", 1);
                    }
                });
            }
        });
        assert_eq!(m.counter("c"), threads * per);
    }

    #[test]
    fn prom_name_normalizes_and_validates() {
        assert_eq!(prom_name("serve.jobs_submitted"), "serve_jobs_submitted");
        assert_eq!(
            prom_name("dlb.flight.model_ratio.scratch"),
            "dlb_flight_model_ratio_scratch"
        );
        assert_eq!(prom_name("plain"), "plain");
        assert!(valid_metric_name("driver.solve_s"));
        assert!(valid_metric_name("exec.threads.barrier_wait_s"));
        assert!(!valid_metric_name(""));
        assert!(!valid_metric_name("Driver.Solve"));
        assert!(!valid_metric_name("a b"));
        assert!(!valid_metric_name(".x"));
        assert!(!valid_metric_name("x."));
        assert!(!valid_metric_name("9x"));
        assert!(!valid_metric_name("serve-jobs"));
    }

    #[test]
    fn prometheus_round_trips_dump_names() {
        let m = Metrics::new();
        m.counter_add("serve.jobs", 3);
        m.observe("driver.solve_s", 0.5);
        let text = m.prometheus();
        assert!(text.contains("# TYPE serve_jobs counter\nserve_jobs 3\n"));
        assert!(text.contains("# TYPE driver_solve_s summary\n"));
        assert!(text.contains("driver_solve_s{quantile=\"0.5\"} 0.5\n"));
        assert!(text.contains("driver_solve_s_sum 0.5\n"));
        assert!(text.contains("driver_solve_s_count 1\n"));
        // every dump line's name maps onto exactly one exposition
        // family: normalization happens in one place for both views
        for line in m.dump().lines() {
            let name = line.split_whitespace().next().unwrap();
            assert!(
                text.contains(&format!("# TYPE {} ", prom_name(name))),
                "dump name {name} missing from exposition"
            );
        }
        // and the exposition itself is line-valid
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("name value");
            let metric = name.split('{').next().unwrap();
            assert!(!metric.contains('.'), "un-normalized: {line}");
            assert!(value.parse::<f64>().is_ok(), "bad value: {line}");
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "invalid metric name")]
    fn bad_names_are_rejected_at_registration_in_debug() {
        Metrics::new().counter_add("Bad Name", 1);
    }

    #[test]
    fn bucket_of_is_exponent_exact() {
        assert_eq!(bucket_of(1.0), EXP_OFFSET as usize);
        assert_eq!(bucket_of(2.0), EXP_OFFSET as usize + 1);
        assert_eq!(bucket_of(3.9), EXP_OFFSET as usize + 1);
        assert_eq!(bucket_of(0.5), EXP_OFFSET as usize - 1);
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(-4.0), 0);
        assert_eq!(bucket_of(1e300), BUCKETS - 1);
    }
}
