//! phg-dlb launcher: run the paper's experiments from the command line.
//!
//! ```text
//! phg-dlb run --problem helmholtz --domain cylinder --method RTK \
//!             --nparts 32 --nsteps 10 [--config file.toml]
//! phg-dlb run --problem lshape                     # scenario's own domain
//! phg-dlb partition --domain cylinder --method PHG/HSFC --nparts 64
//! phg-dlb compare --domain cylinder --nparts 32          # all methods
//! phg-dlb serve --jobs jobs.jsonl --serve-workers 4      # service mode
//! phg-dlb serve --jobs jobs.jsonl --status-port 8080     # + live /metrics /jobs
//! phg-dlb top --connect 127.0.0.1:8080                   # watch a daemon
//! phg-dlb methods | info
//! ```

use phg_dlb::config::Config;
use phg_dlb::coordinator::AdaptiveDriver;
use phg_dlb::dist::Distribution;
use phg_dlb::dlb::{Registry, RepartitionStrategy, TRIGGERS, WEIGHT_MODELS};
use phg_dlb::exec::EXECUTORS;
use phg_dlb::format_err;
use phg_dlb::mesh::generator;
use phg_dlb::mesh::topology::LeafTopology;
use phg_dlb::mesh::TetMesh;
use phg_dlb::obs;
use phg_dlb::partition::{metrics, PartitionInput};
use phg_dlb::runtime::Runtime;
use phg_dlb::scenario::ScenarioRegistry;
use phg_dlb::util::error::Result;
use phg_dlb::util::timer::Stopwatch;

fn prerefine(cfg: &Config, mut mesh: TetMesh) -> Result<TetMesh> {
    for _ in 0..cfg.get_usize("prerefine", 0)? {
        let leaves = mesh.leaves_unordered();
        mesh.refine(&leaves);
    }
    Ok(mesh)
}

fn make_domain(cfg: &Config, default_domain: &str) -> Result<TetMesh> {
    let domain = cfg.get_str("domain", default_domain);
    let scale = cfg.get_usize("scale", 3)?;
    let mesh = match domain.as_str() {
        "cube" => generator::cube_mesh(scale.max(1) * 2),
        "cylinder" => generator::omega1_cylinder(scale.max(2)),
        "lshape" => generator::lshape_mesh(scale.max(1) * 2),
        other => return Err(format_err!("unknown domain {other} (cube|cylinder|lshape)")),
    };
    prerefine(cfg, mesh)
}

/// Parse `--status-port` (0 or absent = off: no thread, no socket).
fn status_port(cfg: &Config) -> Result<Option<u16>> {
    let port = cfg.get_usize("status_port", 0)?;
    if port == 0 {
        Ok(None)
    } else if port <= u16::MAX as usize {
        Ok(Some(port as u16))
    } else {
        Err(format_err!("--status-port {port} out of range (1-65535)"))
    }
}

/// Start the loopback status plane for a single-run command; `jobs`
/// feeds the `/jobs` route (`None` serves an empty table).
fn start_status_plane(
    cfg: &Config,
    jobs: Option<obs::JobsProvider>,
) -> Result<Option<obs::StatusServer>> {
    match status_port(cfg)? {
        Some(port) => {
            let server = obs::StatusServer::start(port, jobs)?;
            eprintln!("status: http://{}", server.addr());
            Ok(Some(server))
        }
        None => Ok(None),
    }
}

fn cmd_run(cfg: &Config) -> Result<()> {
    let dc = cfg.driver_config()?;
    let problem = dc.problem.clone();
    // --domain auto (the default) = the scenario's own domain
    let mesh = match cfg.get_str("domain", "auto").as_str() {
        "auto" => {
            if cfg.contains("scale") {
                eprintln!(
                    "note: scale only applies to an explicit --domain; \
                     --domain auto uses the scenario's own mesh (use --prerefine to grow it)"
                );
            }
            prerefine(cfg, ScenarioRegistry::create(&problem)?.default_mesh())?
        }
        _ => make_domain(cfg, "auto")?,
    };
    println!(
        "# problem={problem} method={} nparts={} elements0={} nsteps={}",
        dc.method,
        dc.nparts,
        mesh.n_leaves(),
        dc.nsteps
    );
    let trace_path = cfg.get_str("trace", "");
    let metrics_path = cfg.get_str("metrics", "");
    if !trace_path.is_empty() {
        obs::tracer().set_enabled(true);
    }
    let flight_path = cfg.get_str("flight", "");
    if !flight_path.is_empty() {
        obs::flight().clear();
        obs::flight().set_enabled(true);
    }
    let status = start_status_plane(cfg, None)?;
    let mut driver = AdaptiveDriver::new(mesh, dc)?;
    let sw = Stopwatch::start();
    driver.run();
    let wall = sw.elapsed();

    let (tal, dlb, sol, stp) = driver.timeline.table_columns();
    println!("# steps={} wall={wall:.2}s", driver.timeline.records.len());
    println!("TAL(s) {tal:.4}  DLB(s) {dlb:.6}  SOL(s) {sol:.6}  STP(s) {stp:.6}");
    println!("repartitionings: {}", driver.timeline.repartition_count());
    if let Some(last) = driver.timeline.records.last() {
        println!(
            "final: elements={} dofs={} L2err={:.3e} maxerr={:.3e}",
            last.n_elements, last.n_dofs, last.l2_error, last.max_error
        );
    }
    // merged wall decomposition over every measured step: per-rank
    // busy / barrier-wait / halo-wait, and the run's wait fraction
    let mut agg = phg_dlb::exec::ExecReport::default();
    for r in &driver.timeline.records {
        if let Some(xr) = &r.exec_report {
            agg.clocks.merge(&xr.clocks);
            agg.halo_wall += xr.halo_wall;
            agg.halo_messages += xr.halo_messages;
            agg.halo_bytes += xr.halo_bytes;
        }
    }
    if !agg.clocks.is_empty() {
        println!(
            "waits: barrier {:.6}s halo {:.6}s (fraction {:.4} of rank-seconds)",
            agg.clocks.barrier_wait.iter().sum::<f64>(),
            agg.clocks.halo_wait.iter().sum::<f64>(),
            agg.wait_fraction()
        );
        let profile = phg_dlb::coordinator::report::format_rank_profile(&agg);
        print!("{profile}");
    }
    if !trace_path.is_empty() {
        let tr = obs::tracer();
        let (spans, dropped) = (tr.len(), tr.dropped());
        std::fs::write(&trace_path, tr.chrome_trace_json())?;
        println!("trace: {trace_path} ({spans} spans, {dropped} dropped)");
        for (name, (count, secs)) in tr.phase_totals() {
            println!("  {name:<14} {count:>8} spans {secs:>10.4}s");
        }
    }
    if !flight_path.is_empty() {
        let fr = obs::flight();
        std::fs::write(&flight_path, fr.to_jsonl())?;
        println!(
            "flight: {flight_path} ({} events, {} dropped)",
            fr.len(),
            fr.dropped()
        );
        print!("{}", obs::model_error_summary());
    }
    if !metrics_path.is_empty() {
        obs::sync_derived_metrics();
        let dump = obs::metrics().dump();
        if metrics_path == "-" {
            print!("{dump}");
        } else {
            std::fs::write(&metrics_path, &dump)?;
            println!("metrics: {metrics_path}");
        }
    }
    if let Some(server) = status {
        server.stop();
    }
    if cfg.get_bool("csv", false)? {
        let path = phg_dlb::coordinator::report::write_report(
            &format!(
                "run_{}_{}.csv",
                problem,
                cfg.get_str("method", "PHG/HSFC")
                    .replace(['/', ':', ',', '='], "_")
            ),
            &driver.timeline.to_csv(),
        )?;
        println!("csv: {}", path.display());
    }
    Ok(())
}

fn cmd_partition(cfg: &Config) -> Result<()> {
    let mut mesh = make_domain(cfg, "cube")?;
    let nparts = cfg.get_usize("nparts", 16)?;
    let method = cfg.get_str("method", "PHG/HSFC");
    let p = Registry::create(&method)?;
    let leaves = mesh.leaves_unordered();
    let weights = vec![1.0; leaves.len()];
    Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
    let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
    let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, nparts);

    let sw = Stopwatch::start();
    let result = p.partition(&input);
    let dt = sw.elapsed();

    let topo = LeafTopology::build_for(&mesh, leaves.clone());
    let q = metrics::quality(&topo, &result.parts, &weights, nparts);
    println!(
        "{method}: {} elements -> {} parts in {:.1} ms",
        leaves.len(),
        nparts,
        dt * 1e3
    );
    println!(
        "imbalance {:.4}  interface faces {} ({:.2}% of interior)  nonempty {}",
        q.imbalance,
        q.interface_faces,
        100.0 * q.surface_index,
        q.nonempty
    );
    Ok(())
}

fn cmd_compare(cfg: &Config) -> Result<()> {
    let mut mesh = make_domain(cfg, "cube")?;
    let nparts = cfg.get_usize("nparts", 16)?;
    let leaves = mesh.leaves_unordered();
    let weights = vec![1.0; leaves.len()];
    Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
    let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
    let topo = LeafTopology::build_for(&mesh, leaves.clone());
    println!(
        "{:<12} {:>10} {:>10} {:>12} {:>10}",
        "method", "time(ms)", "imbalance", "iface-faces", "surface%"
    );
    for name in Registry::paper_names() {
        let p = Registry::create(name)?;
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, nparts);
        let sw = Stopwatch::start();
        let r = p.partition(&input);
        let dt = sw.elapsed();
        let q = metrics::quality(&topo, &r.parts, &weights, nparts);
        println!(
            "{:<12} {:>10.2} {:>10.4} {:>12} {:>10.2}",
            name,
            dt * 1e3,
            q.imbalance,
            q.interface_faces,
            100.0 * q.surface_index
        );
    }
    Ok(())
}

fn cmd_serve(cfg: &Config) -> Result<()> {
    use phg_dlb::serve::{serve, signal, JobSpec, ServeOptions};

    let jobs_path = cfg.get_str("jobs", "");
    if jobs_path.is_empty() {
        return Err(format_err!(
            "serve needs --jobs <path.jsonl|-> (one JSON job object per line)"
        ));
    }
    let text = if jobs_path == "-" {
        use std::io::Read;
        let mut buf = String::new();
        std::io::stdin().read_to_string(&mut buf)?;
        buf
    } else {
        std::fs::read_to_string(&jobs_path)?
    };
    let specs = JobSpec::parse_jsonl(&text)?;
    let trace_dir = cfg.get_str("trace_dir", "out/serve");
    let opts = ServeOptions {
        workers: cfg.get_usize("serve_workers", 2)?,
        checkpoint_dir: cfg.get_str("checkpoint_dir", "out/ckpt").into(),
        trace_dir: (!trace_dir.is_empty()).then(|| trace_dir.into()),
        drain_timeout_s: cfg.get_f64("drain_timeout", 0.0)?,
        retry_base_ms: cfg.get_usize("retry_base_ms", 100)? as u64,
        status_port: status_port(cfg)?,
    };
    let flight_path = cfg.get_str("flight", "");
    if !flight_path.is_empty() {
        obs::flight().clear();
        obs::flight().set_enabled(true);
    }
    println!(
        "# serve: {} jobs, {} workers, checkpoints -> {}",
        specs.len(),
        if opts.workers == 0 {
            "auto".to_string()
        } else {
            opts.workers.to_string()
        },
        opts.checkpoint_dir.display()
    );
    signal::install();
    let summary = serve(specs, &opts)?;
    print!("{}", summary.format_table());
    if !flight_path.is_empty() {
        let fr = obs::flight();
        std::fs::write(&flight_path, fr.to_jsonl())?;
        println!(
            "flight: {flight_path} ({} events, {} dropped)",
            fr.len(),
            fr.dropped()
        );
        print!("{}", obs::model_error_summary());
    }
    let metrics_path = cfg.get_str("metrics", "");
    if !metrics_path.is_empty() {
        obs::sync_derived_metrics();
        let dump = obs::metrics().dump();
        if metrics_path == "-" {
            print!("{dump}");
        } else {
            std::fs::write(&metrics_path, &dump)?;
            println!("metrics: {metrics_path}");
        }
    }
    Ok(())
}

/// Blocking loopback HTTP GET against a status plane; returns the
/// response body (zero-dependency, mirrors `obs::serve_status`).
fn http_get(addr: &str, path: &str) -> Result<String> {
    use std::io::{Read, Write};
    let mut stream = std::net::TcpStream::connect(addr)
        .map_err(|e| format_err!("connecting {addr}: {e}"))?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(2)))?;
    write!(
        stream,
        "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n"
    )?;
    let mut text = String::new();
    stream.read_to_string(&mut text)?;
    match text.split_once("\r\n\r\n") {
        Some((_, body)) => Ok(body.to_string()),
        None => Err(format_err!("malformed HTTP response from {addr}{path}")),
    }
}

/// `phg-dlb top`: poll a daemon's status plane and render a
/// refreshing per-job table plus the headline serve counters.
fn cmd_top(cfg: &Config) -> Result<()> {
    use phg_dlb::serve::json;

    let addr = cfg.get_str("connect", "127.0.0.1:8080");
    let interval = cfg.get_f64("interval", 1.0)?.max(0.05);
    let polls = cfg.get_usize("polls", 0)?; // 0 = until interrupted
    let mut n = 0usize;
    loop {
        n += 1;
        let jobs = http_get(&addr, "/jobs")?;
        let prom = http_get(&addr, "/metrics")?;
        if n > 1 {
            // redraw in place from the second poll on; a single-poll
            // invocation stays clean for pipes and transcripts
            print!("\x1b[2J\x1b[H");
        }
        println!("phg-dlb top -- {addr} (poll {n})");
        println!(
            "{:<14} {:<10} {:>8} {:>9} {:>10} {:>10} {:>8} {:>9}",
            "job", "state", "attempts", "steps", "elements", "dofs", "lambda", "wall(s)"
        );
        for line in jobs.lines() {
            let v = json::parse(line)?;
            let s = |k: &str| v.get(k).and_then(|j| j.as_str()).unwrap_or("?").to_string();
            let f = |k: &str| v.get(k).and_then(|j| j.as_f64()).unwrap_or(0.0);
            println!(
                "{:<14} {:<10} {:>8} {:>9} {:>10} {:>10} {:>8.3} {:>9.2}",
                s("id"),
                s("state"),
                f("attempts") as u64,
                format!("{}/{}", f("steps_done") as u64, f("steps") as u64),
                f("n_elements") as u64,
                f("n_dofs") as u64,
                f("lambda"),
                f("wall_s"),
            );
        }
        let mut headline = String::new();
        for name in [
            "serve_jobs_submitted",
            "serve_jobs_completed",
            "serve_job_errors",
            "serve_jobs_retried",
            "serve_jobs_drained",
            "serve_jobs_cancelled",
        ] {
            if let Some(line) = prom.lines().find(|l| l.starts_with(&format!("{name} "))) {
                let value = line.rsplit(' ').next().unwrap_or("0");
                let short = name.trim_start_matches("serve_jobs_").trim_start_matches("serve_");
                headline.push_str(&format!(" {short}={value}"));
            }
        }
        if !headline.is_empty() {
            println!("serve:{headline}");
        }
        if polls > 0 && n >= polls {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(interval));
    }
}

fn cmd_info() -> Result<()> {
    println!("phg-dlb {}", env!("CARGO_PKG_VERSION"));
    match Runtime::open_default() {
        Ok(rt) => {
            println!("artifacts: OK ({} entries)", rt.manifest().entries.len());
            println!("  elem_tet ladder: {:?}", rt.elem_ladder());
            println!(
                "  cg ladder: {:?} (ELL width {})",
                rt.cg_ladder(),
                rt.ell_width()
            );
        }
        Err(e) => println!("artifacts: MISSING ({e}); native fallback engines will be used"),
    }
    Ok(())
}

fn main() {
    // surface config/registry errors as one clean line, not a panic
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(2);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = Config::new();
    if let Some(i) = args.iter().position(|a| a == "--config") {
        let path = args
            .get(i + 1)
            .ok_or_else(|| format_err!("--config needs a path"))?;
        cfg = Config::load(std::path::Path::new(path))?;
    }
    let rest = cfg.apply_args(&args)?;
    let sub = rest.first().map(|s| s.as_str()).unwrap_or("help");
    match sub {
        "run" => cmd_run(&cfg),
        "partition" => cmd_partition(&cfg),
        "compare" => cmd_compare(&cfg),
        "serve" => cmd_serve(&cfg),
        "top" => cmd_top(&cfg),
        "methods" => {
            // every pluggable registry, sorted or documentation order
            // + described, so CI log diffs and docs stay stable
            println!("methods (--method, parameterized as name:key=val,...):");
            for m in Registry::sorted_specs() {
                println!(
                    "  {:<16} {}{}",
                    m.name,
                    m.description,
                    if m.in_lineup { "" } else { "  [ablation only]" }
                );
                // capabilities + tunables, one indented line each
                let t = m.traits();
                println!(
                    "  {:<16}   [{}{}]",
                    "",
                    if t.incremental { "incremental" } else { "from scratch" },
                    if t.uses_current_owners {
                        ", uses current owners"
                    } else {
                        ""
                    }
                );
                for p in t.tunables {
                    println!(
                        "  {:<16}   {}={} in [{}, {}]: {}",
                        "", p.key, p.default, p.min, p.max, p.description
                    );
                }
            }
            println!("\nstrategies (--strategy, DESIGN.md \u{a7}7):");
            for s in RepartitionStrategy::all() {
                println!("  {:<16} {}", s.name(), s.description());
            }
            println!("\nscenarios (--problem, DESIGN.md \u{a7}8):");
            for s in ScenarioRegistry::sorted_specs() {
                println!("  {:<16} {}", s.name, s.description);
            }
            println!("\ntriggers (--trigger, DESIGN.md \u{a7}6):");
            for t in &TRIGGERS {
                println!("  {:<16} {}", t.name, t.description);
            }
            println!("\nweights (--weights, DESIGN.md \u{a7}6):");
            for w in &WEIGHT_MODELS {
                println!("  {:<16} {}", w.name, w.description);
            }
            println!("\nexecutors (--exec, DESIGN.md \u{a7}9):");
            for e in &EXECUTORS {
                println!("  {:<16} {}", e.name, e.description);
            }
            Ok(())
        }
        "info" => cmd_info(),
        _ => {
            println!(
                "usage: phg-dlb <run|partition|compare|serve|top|methods|info> [--key value ...]\n\
                 keys: problem (see `phg-dlb methods`) domain (auto|cube|cylinder|lshape)\n\
                 \x20     scale (explicit domains only) prerefine method nparts nsteps dt\n\
                 \x20     (method accepts tunables: name:key=val,... e.g. AdaptiveRepart:itr=100)\n\
                 \x20     trigger (lambda[:t]|every[:n]|always|costbenefit[:h])\n\
                 \x20     weights (unit|dof|measured)\n\
                 \x20     strategy (scratch|diffusive|adaptive|auto)\n\
                 \x20     exec (virtual|threads) exec_threads (0 = one per core)\n\
                 \x20     lambda_trigger theta_refine theta_coarsen max_elements\n\
                 \x20     trace (Chrome-trace JSON path) metrics (text path, - = stdout)\n\
                 \x20     flight (DLB decision JSONL path) status_port (loopback HTTP, 0 = off)\n\
                 \x20     solver_tol solver_max_iter use_pjrt csv config\n\
                 serve keys: jobs (JSONL path, - = stdin) serve_workers (0 = auto)\n\
                 \x20     checkpoint_dir trace_dir (\"\" disables) drain_timeout (s)\n\
                 \x20     retry_base_ms (backoff base; doubles per attempt)\n\
                 \x20     status_port flight (as above)\n\
                 top keys: connect (host:port, default 127.0.0.1:8080)\n\
                 \x20     interval (s, default 1) polls (0 = until interrupted)"
            );
            Ok(())
        }
    }
}
