//! phg-dlb launcher: run the paper's experiments from the command line.
//!
//! ```text
//! phg-dlb run --problem helmholtz --domain cylinder --method RTK \
//!             --nparts 32 --nsteps 10 [--config file.toml]
//! phg-dlb run --problem lshape                     # scenario's own domain
//! phg-dlb partition --domain cylinder --method PHG/HSFC --nparts 64
//! phg-dlb compare --domain cylinder --nparts 32          # all methods
//! phg-dlb serve --jobs jobs.jsonl --serve-workers 4      # service mode
//! phg-dlb methods | info
//! ```

use phg_dlb::config::Config;
use phg_dlb::coordinator::AdaptiveDriver;
use phg_dlb::dist::Distribution;
use phg_dlb::dlb::{Registry, RepartitionStrategy, TRIGGERS, WEIGHT_MODELS};
use phg_dlb::exec::EXECUTORS;
use phg_dlb::format_err;
use phg_dlb::mesh::generator;
use phg_dlb::mesh::topology::LeafTopology;
use phg_dlb::mesh::TetMesh;
use phg_dlb::obs;
use phg_dlb::partition::{metrics, PartitionInput};
use phg_dlb::runtime::Runtime;
use phg_dlb::scenario::ScenarioRegistry;
use phg_dlb::util::error::Result;
use phg_dlb::util::timer::Stopwatch;

fn prerefine(cfg: &Config, mut mesh: TetMesh) -> Result<TetMesh> {
    for _ in 0..cfg.get_usize("prerefine", 0)? {
        let leaves = mesh.leaves_unordered();
        mesh.refine(&leaves);
    }
    Ok(mesh)
}

fn make_domain(cfg: &Config, default_domain: &str) -> Result<TetMesh> {
    let domain = cfg.get_str("domain", default_domain);
    let scale = cfg.get_usize("scale", 3)?;
    let mesh = match domain.as_str() {
        "cube" => generator::cube_mesh(scale.max(1) * 2),
        "cylinder" => generator::omega1_cylinder(scale.max(2)),
        "lshape" => generator::lshape_mesh(scale.max(1) * 2),
        other => return Err(format_err!("unknown domain {other} (cube|cylinder|lshape)")),
    };
    prerefine(cfg, mesh)
}

fn cmd_run(cfg: &Config) -> Result<()> {
    let dc = cfg.driver_config()?;
    let problem = dc.problem.clone();
    // --domain auto (the default) = the scenario's own domain
    let mesh = match cfg.get_str("domain", "auto").as_str() {
        "auto" => {
            if cfg.contains("scale") {
                eprintln!(
                    "note: scale only applies to an explicit --domain; \
                     --domain auto uses the scenario's own mesh (use --prerefine to grow it)"
                );
            }
            prerefine(cfg, ScenarioRegistry::create(&problem)?.default_mesh())?
        }
        _ => make_domain(cfg, "auto")?,
    };
    println!(
        "# problem={problem} method={} nparts={} elements0={} nsteps={}",
        dc.method,
        dc.nparts,
        mesh.n_leaves(),
        dc.nsteps
    );
    let trace_path = cfg.get_str("trace", "");
    let metrics_path = cfg.get_str("metrics", "");
    if !trace_path.is_empty() {
        obs::tracer().set_enabled(true);
    }
    let mut driver = AdaptiveDriver::new(mesh, dc)?;
    let sw = Stopwatch::start();
    driver.run();
    let wall = sw.elapsed();

    let (tal, dlb, sol, stp) = driver.timeline.table_columns();
    println!("# steps={} wall={wall:.2}s", driver.timeline.records.len());
    println!("TAL(s) {tal:.4}  DLB(s) {dlb:.6}  SOL(s) {sol:.6}  STP(s) {stp:.6}");
    println!("repartitionings: {}", driver.timeline.repartition_count());
    if let Some(last) = driver.timeline.records.last() {
        println!(
            "final: elements={} dofs={} L2err={:.3e} maxerr={:.3e}",
            last.n_elements, last.n_dofs, last.l2_error, last.max_error
        );
    }
    // merged wall decomposition over every measured step: per-rank
    // busy / barrier-wait / halo-wait, and the run's wait fraction
    let mut agg = phg_dlb::exec::ExecReport::default();
    for r in &driver.timeline.records {
        if let Some(xr) = &r.exec_report {
            agg.clocks.merge(&xr.clocks);
            agg.halo_wall += xr.halo_wall;
            agg.halo_messages += xr.halo_messages;
            agg.halo_bytes += xr.halo_bytes;
        }
    }
    if !agg.clocks.is_empty() {
        println!(
            "waits: barrier {:.6}s halo {:.6}s (fraction {:.4} of rank-seconds)",
            agg.clocks.barrier_wait.iter().sum::<f64>(),
            agg.clocks.halo_wait.iter().sum::<f64>(),
            agg.wait_fraction()
        );
        let profile = phg_dlb::coordinator::report::format_rank_profile(&agg);
        print!("{profile}");
    }
    if !trace_path.is_empty() {
        let tr = obs::tracer();
        let (spans, dropped) = (tr.len(), tr.dropped());
        std::fs::write(&trace_path, tr.chrome_trace_json())?;
        println!("trace: {trace_path} ({spans} spans, {dropped} dropped)");
        for (name, (count, secs)) in tr.phase_totals() {
            println!("  {name:<14} {count:>8} spans {secs:>10.4}s");
        }
    }
    if !metrics_path.is_empty() {
        let dump = obs::metrics().dump();
        if metrics_path == "-" {
            print!("{dump}");
        } else {
            std::fs::write(&metrics_path, &dump)?;
            println!("metrics: {metrics_path}");
        }
    }
    if cfg.get_bool("csv", false)? {
        let path = phg_dlb::coordinator::report::write_report(
            &format!(
                "run_{}_{}.csv",
                problem,
                cfg.get_str("method", "PHG/HSFC")
                    .replace(['/', ':', ',', '='], "_")
            ),
            &driver.timeline.to_csv(),
        )?;
        println!("csv: {}", path.display());
    }
    Ok(())
}

fn cmd_partition(cfg: &Config) -> Result<()> {
    let mut mesh = make_domain(cfg, "cube")?;
    let nparts = cfg.get_usize("nparts", 16)?;
    let method = cfg.get_str("method", "PHG/HSFC");
    let p = Registry::create(&method)?;
    let leaves = mesh.leaves_unordered();
    let weights = vec![1.0; leaves.len()];
    Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
    let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
    let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, nparts);

    let sw = Stopwatch::start();
    let result = p.partition(&input);
    let dt = sw.elapsed();

    let topo = LeafTopology::build_for(&mesh, leaves.clone());
    let q = metrics::quality(&topo, &result.parts, &weights, nparts);
    println!(
        "{method}: {} elements -> {} parts in {:.1} ms",
        leaves.len(),
        nparts,
        dt * 1e3
    );
    println!(
        "imbalance {:.4}  interface faces {} ({:.2}% of interior)  nonempty {}",
        q.imbalance,
        q.interface_faces,
        100.0 * q.surface_index,
        q.nonempty
    );
    Ok(())
}

fn cmd_compare(cfg: &Config) -> Result<()> {
    let mut mesh = make_domain(cfg, "cube")?;
    let nparts = cfg.get_usize("nparts", 16)?;
    let leaves = mesh.leaves_unordered();
    let weights = vec![1.0; leaves.len()];
    Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
    let owners: Vec<u16> = leaves.iter().map(|&id| mesh.elem(id).owner).collect();
    let topo = LeafTopology::build_for(&mesh, leaves.clone());
    println!(
        "{:<12} {:>10} {:>10} {:>12} {:>10}",
        "method", "time(ms)", "imbalance", "iface-faces", "surface%"
    );
    for name in Registry::paper_names() {
        let p = Registry::create(name)?;
        let input = PartitionInput::from_mesh(&mesh, &leaves, &weights, &owners, nparts);
        let sw = Stopwatch::start();
        let r = p.partition(&input);
        let dt = sw.elapsed();
        let q = metrics::quality(&topo, &r.parts, &weights, nparts);
        println!(
            "{:<12} {:>10.2} {:>10.4} {:>12} {:>10.2}",
            name,
            dt * 1e3,
            q.imbalance,
            q.interface_faces,
            100.0 * q.surface_index
        );
    }
    Ok(())
}

fn cmd_serve(cfg: &Config) -> Result<()> {
    use phg_dlb::serve::{serve, signal, JobSpec, ServeOptions};

    let jobs_path = cfg.get_str("jobs", "");
    if jobs_path.is_empty() {
        return Err(format_err!(
            "serve needs --jobs <path.jsonl|-> (one JSON job object per line)"
        ));
    }
    let text = if jobs_path == "-" {
        use std::io::Read;
        let mut buf = String::new();
        std::io::stdin().read_to_string(&mut buf)?;
        buf
    } else {
        std::fs::read_to_string(&jobs_path)?
    };
    let specs = JobSpec::parse_jsonl(&text)?;
    let trace_dir = cfg.get_str("trace_dir", "out/serve");
    let opts = ServeOptions {
        workers: cfg.get_usize("serve_workers", 2)?,
        checkpoint_dir: cfg.get_str("checkpoint_dir", "out/ckpt").into(),
        trace_dir: (!trace_dir.is_empty()).then(|| trace_dir.into()),
        drain_timeout_s: cfg.get_f64("drain_timeout", 0.0)?,
        retry_base_ms: cfg.get_usize("retry_base_ms", 100)? as u64,
    };
    println!(
        "# serve: {} jobs, {} workers, checkpoints -> {}",
        specs.len(),
        if opts.workers == 0 {
            "auto".to_string()
        } else {
            opts.workers.to_string()
        },
        opts.checkpoint_dir.display()
    );
    signal::install();
    let summary = serve(specs, &opts)?;
    print!("{}", summary.format_table());
    let metrics_path = cfg.get_str("metrics", "");
    if !metrics_path.is_empty() {
        let dump = obs::metrics().dump();
        if metrics_path == "-" {
            print!("{dump}");
        } else {
            std::fs::write(&metrics_path, &dump)?;
            println!("metrics: {metrics_path}");
        }
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("phg-dlb {}", env!("CARGO_PKG_VERSION"));
    match Runtime::open_default() {
        Ok(rt) => {
            println!("artifacts: OK ({} entries)", rt.manifest().entries.len());
            println!("  elem_tet ladder: {:?}", rt.elem_ladder());
            println!(
                "  cg ladder: {:?} (ELL width {})",
                rt.cg_ladder(),
                rt.ell_width()
            );
        }
        Err(e) => println!("artifacts: MISSING ({e}); native fallback engines will be used"),
    }
    Ok(())
}

fn main() {
    // surface config/registry errors as one clean line, not a panic
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(2);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = Config::new();
    if let Some(i) = args.iter().position(|a| a == "--config") {
        let path = args
            .get(i + 1)
            .ok_or_else(|| format_err!("--config needs a path"))?;
        cfg = Config::load(std::path::Path::new(path))?;
    }
    let rest = cfg.apply_args(&args)?;
    let sub = rest.first().map(|s| s.as_str()).unwrap_or("help");
    match sub {
        "run" => cmd_run(&cfg),
        "partition" => cmd_partition(&cfg),
        "compare" => cmd_compare(&cfg),
        "serve" => cmd_serve(&cfg),
        "methods" => {
            // every pluggable registry, sorted or documentation order
            // + described, so CI log diffs and docs stay stable
            println!("methods (--method, parameterized as name:key=val,...):");
            for m in Registry::sorted_specs() {
                println!(
                    "  {:<16} {}{}",
                    m.name,
                    m.description,
                    if m.in_lineup { "" } else { "  [ablation only]" }
                );
                // capabilities + tunables, one indented line each
                let t = m.traits();
                println!(
                    "  {:<16}   [{}{}]",
                    "",
                    if t.incremental { "incremental" } else { "from scratch" },
                    if t.uses_current_owners {
                        ", uses current owners"
                    } else {
                        ""
                    }
                );
                for p in t.tunables {
                    println!(
                        "  {:<16}   {}={} in [{}, {}]: {}",
                        "", p.key, p.default, p.min, p.max, p.description
                    );
                }
            }
            println!("\nstrategies (--strategy, DESIGN.md \u{a7}7):");
            for s in RepartitionStrategy::all() {
                println!("  {:<16} {}", s.name(), s.description());
            }
            println!("\nscenarios (--problem, DESIGN.md \u{a7}8):");
            for s in ScenarioRegistry::sorted_specs() {
                println!("  {:<16} {}", s.name, s.description);
            }
            println!("\ntriggers (--trigger, DESIGN.md \u{a7}6):");
            for t in &TRIGGERS {
                println!("  {:<16} {}", t.name, t.description);
            }
            println!("\nweights (--weights, DESIGN.md \u{a7}6):");
            for w in &WEIGHT_MODELS {
                println!("  {:<16} {}", w.name, w.description);
            }
            println!("\nexecutors (--exec, DESIGN.md \u{a7}9):");
            for e in &EXECUTORS {
                println!("  {:<16} {}", e.name, e.description);
            }
            Ok(())
        }
        "info" => cmd_info(),
        _ => {
            println!(
                "usage: phg-dlb <run|partition|compare|serve|methods|info> [--key value ...]\n\
                 keys: problem (see `phg-dlb methods`) domain (auto|cube|cylinder|lshape)\n\
                 \x20     scale (explicit domains only) prerefine method nparts nsteps dt\n\
                 \x20     (method accepts tunables: name:key=val,... e.g. AdaptiveRepart:itr=100)\n\
                 \x20     trigger (lambda[:t]|every[:n]|always|costbenefit[:h])\n\
                 \x20     weights (unit|dof|measured)\n\
                 \x20     strategy (scratch|diffusive|adaptive|auto)\n\
                 \x20     exec (virtual|threads) exec_threads (0 = one per core)\n\
                 \x20     lambda_trigger theta_refine theta_coarsen max_elements\n\
                 \x20     trace (Chrome-trace JSON path) metrics (text path, - = stdout)\n\
                 \x20     solver_tol solver_max_iter use_pjrt csv config\n\
                 serve keys: jobs (JSONL path, - = stdin) serve_workers (0 = auto)\n\
                 \x20     checkpoint_dir trace_dir (\"\" disables) drain_timeout (s)\n\
                 \x20     retry_base_ms (backoff base; doubles per attempt)"
            );
            Ok(())
        }
    }
}
