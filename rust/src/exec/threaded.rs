//! The shared-memory SPMD executor: one worker thread per virtual
//! rank (capped at a thread budget), real halo exchange, measured
//! per-rank wall times (DESIGN.md §9).
//!
//! `ThreadedExec` runs the same rank-local assembly and distributed
//! Jacobi-PCG as [`VirtualExec`](crate::exec::VirtualExec) -- the
//! arithmetic is fixed by the [`RankPlan`], so the two agree bit for
//! bit -- but here the ranks genuinely execute concurrently
//! (`std::thread::scope` + `Barrier` + per-rank-pair channels), so
//! the wall clock is hardware time and the per-rank busy times are
//! *measured* load, not modeled. Those measurements feed the driver's
//! `solve_imbalance` and the `Measured` weight model. The PJRT
//! engines stay virtual-executor-only: this executor always runs the
//! native f64 kernels.

use crate::fem::{Assembled, AssemblyPattern, Csr, DofMap, SolveStats, SolverOpts};
use crate::mesh::topology::LeafTopology;
use crate::mesh::TetMesh;
use crate::obs::{self, Phase};
use crate::runtime::Runtime;
use crate::util::timer::Stopwatch;
use std::cell::RefCell;

use super::assemble::{combine_dense, dense_rank, RankDense};
use super::ghost::GhostPlan;
use super::pcg::{pcg_threaded, RankClocks};
use super::plan::RankPlan;
use super::{ExecReport, Executor};

/// The real shared-memory schedule (`--exec threads`).
#[derive(Debug)]
pub struct ThreadedExec {
    nranks: usize,
    /// Worker budget: threads actually spawned per phase is
    /// `min(threads, nranks)`.
    threads: usize,
    report: RefCell<ExecReport>,
    /// Sparsity pattern cache, reused across solves while the mesh
    /// revision is unchanged (DESIGN.md §11).
    pattern: RefCell<Option<AssemblyPattern>>,
}

impl ThreadedExec {
    /// `threads = 0` means auto: one worker per core, capped at the
    /// rank count.
    pub fn new(nranks: usize, threads: usize) -> Self {
        assert!(nranks >= 1);
        let budget = if threads == 0 {
            available_threads()
        } else {
            threads
        };
        Self {
            nranks,
            threads: budget.clamp(1, nranks),
            report: RefCell::new(ExecReport::default()),
            pattern: RefCell::new(None),
        }
    }

    /// The worker budget this executor resolved to.
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn add_clocks(&self, clocks: &RankClocks) {
        self.report.borrow_mut().clocks.merge(clocks);
    }
}

/// Detected hardware parallelism (1 when detection fails).
pub fn available_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

impl Executor for ThreadedExec {
    fn name(&self) -> &'static str {
        "threads"
    }

    fn nranks(&self) -> usize {
        self.nranks
    }

    fn measures(&self) -> bool {
        true
    }

    fn assemble(
        &self,
        plan: &RankPlan,
        mesh: &TetMesh,
        topo: &LeafTopology,
        dof: &DofMap,
        source: &[f64],
        _rt: Option<&Runtime>,
    ) -> Assembled {
        let p = plan.nranks;
        let nthreads = self.threads.clamp(1, p);
        let mut cache = self.pattern.borrow_mut();
        if !cache.as_ref().is_some_and(|pat| pat.matches(mesh, dof)) {
            obs::metrics().counter_add("exec.pattern_rebuilds", 1);
            *cache = Some(AssemblyPattern::build(mesh, topo, dof));
        } else {
            obs::metrics().counter_add("exec.pattern_reuses", 1);
        }
        let pat = cache.as_ref().unwrap();
        let mut outs: Vec<Option<(RankDense, f64)>> = (0..p).map(|_| None).collect();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..nthreads)
                .map(|t| {
                    let lo = t * p / nthreads;
                    let hi = (t + 1) * p / nthreads;
                    scope.spawn(move || {
                        let mut done = Vec::with_capacity(hi - lo);
                        for rk in lo..hi {
                            let _sp = obs::span(rk, Phase::Assemble);
                            let sw = Stopwatch::start();
                            let asm = dense_rank(mesh, topo, source, pat, &plan.elems[rk]);
                            done.push((rk, asm, sw.elapsed()));
                        }
                        done
                    })
                })
                .collect();
            for h in handles {
                for (rk, asm, wall) in h.join().expect("assembly worker panicked") {
                    outs[rk] = Some((asm, wall));
                }
            }
        });
        let mut clocks = RankClocks::with_ranks(p);
        let parts: Vec<RankDense> = outs
            .into_iter()
            .enumerate()
            .map(|(rk, o)| {
                let (asm, wall) = o.expect("rank assembled nothing");
                clocks.busy[rk] = wall;
                asm
            })
            .collect();
        self.add_clocks(&clocks);
        // serial rank-ordered scatter: bitwise equal to the triplet
        // combine, with no per-solve sort (DESIGN.md §11)
        combine_dense(pat, &plan.elems, parts)
    }

    fn pcg(
        &self,
        plan: &RankPlan,
        a: &Csr,
        b: &[f64],
        x: &mut [f64],
        opts: &SolverOpts,
        _rt: Option<&Runtime>,
    ) -> SolveStats {
        let ghost = GhostPlan::build(plan, a);
        let (stats, clocks, halo) = pcg_threaded(plan, &ghost, a, b, x, opts, self.threads);
        let m = obs::metrics();
        for &t in &clocks.busy {
            m.observe("exec.threads.rank_busy_s", t);
        }
        for &t in &clocks.barrier_wait {
            m.observe("exec.threads.barrier_wait_s", t);
        }
        for &t in &clocks.halo_wait {
            m.observe("exec.threads.halo_wait_s", t);
        }
        m.counter_add("exec.threads.halo_messages", halo.messages as u64);
        m.counter_add("exec.threads.halo_bytes", halo.bytes as u64);
        self.add_clocks(&clocks);
        {
            let mut rep = self.report.borrow_mut();
            rep.halo_wall += halo.wall;
            rep.halo_messages += halo.messages;
            rep.halo_bytes += halo.bytes;
        }
        stats
    }

    fn take_report(&self) -> ExecReport {
        self.report.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Distribution;
    use crate::exec::VirtualExec;
    use crate::mesh::generator;

    fn setup(nparts: usize) -> (TetMesh, LeafTopology, DofMap, RankPlan) {
        let mut mesh = generator::cube_mesh(2);
        mesh.refine(&mesh.leaves_unordered());
        let leaves = mesh.leaves_unordered();
        Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
        let topo = LeafTopology::build(&mesh);
        let dof = DofMap::build(&mesh, &topo);
        let owners: Vec<u16> = topo.leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let plan = RankPlan::build(&mesh, &topo, &dof, &owners, nparts);
        (mesh, topo, dof, plan)
    }

    #[test]
    fn threaded_matches_virtual_bit_for_bit() {
        let (mesh, topo, dof, plan) = setup(4);
        let virt = VirtualExec::new(4);
        let thr = ThreadedExec::new(4, 0);
        let src = dof.eval_at_dofs(&mesh, |p| (2.0 * p.x).cos() + p.y);

        let sv = virt.assemble(&plan, &mesh, &topo, &dof, &src, None);
        let st = thr.assemble(&plan, &mesh, &topo, &dof, &src, None);
        assert_eq!(sv.k.nnz(), st.k.nnz());
        for (a, b) in sv.k.vals.iter().zip(&st.k.vals) {
            assert_eq!(a.to_bits(), b.to_bits(), "assembly differs");
        }
        for (a, b) in sv.b.iter().zip(&st.b) {
            assert_eq!(a.to_bits(), b.to_bits(), "load vector differs");
        }

        let a = Csr::linear_combination(1.0, &sv.k, 1.0, &sv.m);
        let opts = SolverOpts::default();
        let mut uv = vec![0.0; dof.n_dofs];
        let mut ut = vec![0.0; dof.n_dofs];
        let stats_v = virt.pcg(&plan, &a, &sv.b, &mut uv, &opts, None);
        let stats_t = thr.pcg(&plan, &a, &st.b, &mut ut, &opts, None);
        assert_eq!(stats_v.iterations, stats_t.iterations);
        for (x, y) in uv.iter().zip(&ut) {
            assert_eq!(x.to_bits(), y.to_bits(), "solutions differ");
        }
    }

    #[test]
    fn pattern_cache_survives_resolves_and_refinement() {
        let (mut mesh, topo, dof, plan) = setup(3);
        let thr = ThreadedExec::new(3, 2);
        let src = dof.eval_at_dofs(&mesh, |p| p.x + p.y);
        let first = thr.assemble(&plan, &mesh, &topo, &dof, &src, None);
        // second solve on the unchanged mesh: cache hit, same bits
        let second = thr.assemble(&plan, &mesh, &topo, &dof, &src, None);
        for (a, b) in first.k.vals.iter().zip(&second.k.vals) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // refinement invalidates the cache; the rebuilt pattern must
        // describe the new mesh, not the old one
        mesh.refine(&mesh.leaves_unordered());
        let topo2 = LeafTopology::build(&mesh);
        let dof2 = DofMap::build(&mesh, &topo2);
        let owners: Vec<u16> = topo2.leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let plan2 = RankPlan::build(&mesh, &topo2, &dof2, &owners, 3);
        let src2 = dof2.eval_at_dofs(&mesh, |p| p.x + p.y);
        let third = thr.assemble(&plan2, &mesh, &topo2, &dof2, &src2, None);
        assert_eq!(third.k.n, dof2.n_dofs);
        assert!(third.k.nnz() > first.k.nnz());
    }

    #[test]
    fn report_accumulates_and_drains() {
        let (mesh, topo, dof, plan) = setup(3);
        let thr = ThreadedExec::new(3, 2);
        assert!(thr.measures());
        assert_eq!(thr.threads(), 2);
        let src = vec![1.0; dof.n_dofs];
        let sys = thr.assemble(&plan, &mesh, &topo, &dof, &src, None);
        let a = Csr::linear_combination(1.0, &sys.k, 1.0, &sys.m);
        let mut u = vec![0.0; dof.n_dofs];
        thr.pcg(&plan, &a, &sys.b, &mut u, &SolverOpts::default(), None);

        let rep = thr.take_report();
        assert_eq!(rep.clocks.busy.len(), 3);
        assert!(rep.clocks.busy.iter().sum::<f64>() > 0.0);
        assert_eq!(rep.clocks.barrier_wait.len(), 3);
        assert_eq!(rep.clocks.halo_wait.len(), 3);
        let wf = rep.wait_fraction();
        assert!((0.0..=1.0).contains(&wf), "wait fraction {wf}");
        assert!(rep.halo_messages > 0, "3 ranks must exchange ghosts");
        assert!(rep.halo_bytes > 0);
        assert!(rep.measured_imbalance() >= 1.0);
        // drained: a second take is empty
        let empty = thr.take_report();
        assert!(empty.clocks.busy.is_empty());
        assert_eq!(empty.halo_messages, 0);
    }

    #[test]
    fn thread_budget_resolution() {
        let t = ThreadedExec::new(8, 3);
        assert_eq!(t.threads(), 3);
        let t = ThreadedExec::new(2, 16);
        assert_eq!(t.threads(), 2, "budget capped at rank count");
        let t = ThreadedExec::new(4, 0);
        assert!(t.threads() >= 1 && t.threads() <= 4);
    }
}
