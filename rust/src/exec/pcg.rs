//! Distributed Jacobi-PCG over a [`RankPlan`]: one algorithm, two
//! execution schedules (DESIGN.md §9).
//!
//! The algorithm is [`crate::fem::native_pcg`] reorganized the way an
//! SPMD code runs it: every rank updates its owned rows, every global
//! dot product is a *rank-ordered* reduction (each rank's partial sum
//! over its ascending row list, partials combined in rank order), and
//! the SpMV reads off-rank entries of `p` through the
//! [`GhostPlan`] halo. Because the arithmetic -- per-rank loop
//! order, partial-sum order, reduction order -- is fixed by the plan
//! and never by the execution schedule, the two drivers here are
//! bit-identical:
//!
//! * [`pcg_sequential`] -- the virtual-SPMD schedule: one thread runs
//!   every rank's phase in rank order (ghost exchange is the identity
//!   in one address space).
//! * [`pcg_threaded`] -- the real schedule: one worker per virtual
//!   rank (capped at a thread budget), `std::sync::Barrier` between
//!   phases, ghost values physically moved through per-rank-pair
//!   reusable halo slots, reduction partials through an atomic slot
//!   array.
//!
//! That bitwise agreement is what makes the cross-executor
//! equivalence tests exact and `ThreadedExec` run-to-run
//! deterministic regardless of scheduling.
//!
//! Both schedules run the per-rank SpMV through [`RankSpmv`]: SELL
//! slabs ([`crate::fem::SellF64`]) over the plan's interior/boundary
//! row split when every row fits the width cap, the CSR row gather
//! otherwise. The SELL kernel is bitwise identical to the gather per
//! row (see `fem::sell`), so the substitution is invisible to the
//! equivalence proofs. The split also buys overlap in the threaded
//! schedule: interior rows (no off-rank columns) multiply while halo
//! messages are still in flight.

use crate::fem::{Csr, SellF64, SolveStats, SolverOpts};
use crate::obs::{self, Phase};
use crate::util::timer::Stopwatch;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Barrier, Condvar, Mutex};

use super::ghost::GhostPlan;
use super::plan::RankPlan;

/// One rank's SpMV kernel for the solve loop, chosen once per solve.
pub enum RankSpmv {
    /// SELL-C-1 slabs over the interior/boundary split (the fast
    /// path for FEM matrices, whose row widths are small and even).
    Sell { interior: SellF64, boundary: SellF64 },
    /// CSR row gather (a row exceeded [`crate::fem::SELL_MAX_WIDTH`]).
    Csr,
}

impl RankSpmv {
    pub fn build(a: &Csr, interior: &[u32], boundary: &[u32]) -> Self {
        match (SellF64::build(a, interior), SellF64::build(a, boundary)) {
            (Some(i), Some(b)) => RankSpmv::Sell {
                interior: i,
                boundary: b,
            },
            _ => RankSpmv::Csr,
        }
    }

    pub fn is_sell(&self) -> bool {
        matches!(self, RankSpmv::Sell { .. })
    }

    /// Multiply the interior rows (no off-rank columns: safe before
    /// the halo lands).
    #[inline]
    fn spmv_interior(&self, a: &Csr, rows: &[u32], x: &[f64], y: &mut [f64]) {
        match self {
            RankSpmv::Sell { interior, .. } => interior.spmv(x, y),
            RankSpmv::Csr => spmv_rows(a, rows, x, y),
        }
    }

    /// Multiply the boundary rows (requires ghost columns of `x`).
    #[inline]
    fn spmv_boundary(&self, a: &Csr, rows: &[u32], x: &[f64], y: &mut [f64]) {
        match self {
            RankSpmv::Sell { boundary, .. } => boundary.spmv(x, y),
            RankSpmv::Csr => spmv_rows(a, rows, x, y),
        }
    }
}

/// Build one kernel per rank and count the format choices.
fn build_kernels(a: &Csr, plan: &RankPlan) -> Vec<RankSpmv> {
    let kernels: Vec<RankSpmv> = (0..plan.nranks)
        .map(|rk| RankSpmv::build(a, &plan.interior[rk], &plan.boundary[rk]))
        .collect();
    let sell = kernels.iter().filter(|k| k.is_sell()).count();
    let m = obs::metrics();
    m.counter_add("exec.spmv.sell_ranks", sell as u64);
    m.counter_add("exec.spmv.csr_fallback_ranks", (kernels.len() - sell) as u64);
    kernels
}

/// A one-deep, reusable mailbox for one directed halo pair. The
/// buffer is allocated once at its exact payload size and rewritten
/// in place every round, so the steady-state solve loop allocates
/// nothing (mpsc channels allocate a node per send). One-deep is
/// enough: rank r publishes round `t+1` only after passing B1(t+1),
/// which orders after B2(t), which orders after the receiver consumed
/// round `t` -- the barrier schedule makes overwrite-before-consume
/// impossible.
struct HaloSlot {
    state: Mutex<SlotBuf>,
    cv: Condvar,
}

struct SlotBuf {
    /// Round number of the payload currently in `buf` (0 = none yet).
    seq: u64,
    buf: Vec<f64>,
}

impl HaloSlot {
    fn new(capacity: usize) -> Self {
        Self {
            state: Mutex::new(SlotBuf {
                seq: 0,
                buf: Vec::with_capacity(capacity),
            }),
            cv: Condvar::new(),
        }
    }

    /// Overwrite the slot with round `seq`'s payload and wake the
    /// receiver. `clear` + `extend` reuse the allocation: capacity
    /// was exact at construction, so this never grows.
    fn publish(&self, seq: u64, values: impl Iterator<Item = f64>) {
        let mut st = self.state.lock().expect("halo slot poisoned");
        debug_assert_eq!(st.seq + 1, seq, "halo round published out of order");
        st.buf.clear();
        st.buf.extend(values);
        st.seq = seq;
        drop(st);
        self.cv.notify_one();
    }

    /// Block until round `seq` is present, returning a guard over the
    /// payload.
    fn wait_for(&self, seq: u64) -> std::sync::MutexGuard<'_, SlotBuf> {
        let mut st = self.state.lock().expect("halo slot poisoned");
        while st.seq < seq {
            st = self.cv.wait(st).expect("halo slot poisoned");
        }
        debug_assert_eq!(st.seq, seq, "halo round skipped");
        st
    }
}

/// Measured halo traffic of one threaded solve.
#[derive(Debug, Clone, Copy, Default)]
pub struct HaloStats {
    /// Bottleneck rank's wall seconds spent packing, sending,
    /// receiving and unpacking ghost values (includes waiting on the
    /// producing rank -- that wait is the physical cost of imbalance).
    pub wall: f64,
    /// Directed messages over the whole solve.
    pub messages: usize,
    /// Payload bytes over the whole solve.
    pub bytes: usize,
}

/// Per-rank wall-clock decomposition of one threaded solve, in
/// seconds, indexed by rank. This is the measured answer to "where
/// did each rank's time go": compute, stalled at a phase barrier
/// (load imbalance made physical), stalled waiting for a halo
/// message, or doing halo pack/unpack work. When ranks are
/// multiplexed onto fewer workers, every rank of a bundle is charged
/// its worker's full waits -- each logical rank really was stalled
/// for that long.
#[derive(Debug, Clone, Default)]
pub struct RankClocks {
    /// Compute sections (SpMV, dots, axpy), excluding every wait.
    pub busy: Vec<f64>,
    /// Blocked in phase barriers (B1-B4 plus the two init barriers).
    pub barrier_wait: Vec<f64>,
    /// Blocked in `recv` waiting for a neighbour's halo message.
    pub halo_wait: Vec<f64>,
    /// Halo pack/send/unpack work (the non-blocking part).
    pub halo_work: Vec<f64>,
}

impl RankClocks {
    pub fn with_ranks(n: usize) -> Self {
        Self {
            busy: vec![0.0; n],
            barrier_wait: vec![0.0; n],
            halo_wait: vec![0.0; n],
            halo_work: vec![0.0; n],
        }
    }

    pub fn is_empty(&self) -> bool {
        self.busy.is_empty()
    }

    /// Element-wise accumulate (growing to `other`'s rank count).
    pub fn merge(&mut self, other: &RankClocks) {
        fn acc(dst: &mut Vec<f64>, src: &[f64]) {
            if dst.len() < src.len() {
                dst.resize(src.len(), 0.0);
            }
            for (a, &b) in dst.iter_mut().zip(src) {
                *a += b;
            }
        }
        acc(&mut self.busy, &other.busy);
        acc(&mut self.barrier_wait, &other.barrier_wait);
        acc(&mut self.halo_wait, &other.halo_wait);
        acc(&mut self.halo_work, &other.halo_work);
    }

    /// Bottleneck rank's barrier-wait seconds.
    pub fn max_barrier_wait(&self) -> f64 {
        self.barrier_wait.iter().cloned().fold(0.0, f64::max)
    }

    /// Bottleneck rank's halo-wait seconds.
    pub fn max_halo_wait(&self) -> f64 {
        self.halo_wait.iter().cloned().fold(0.0, f64::max)
    }

    /// Fraction of all accounted rank-seconds spent waiting (barrier
    /// + halo wait over busy + halo work + waits); 0 when empty.
    pub fn wait_fraction(&self) -> f64 {
        let work: f64 = self.busy.iter().sum::<f64>() + self.halo_work.iter().sum::<f64>();
        let wait: f64 =
            self.barrier_wait.iter().sum::<f64>() + self.halo_wait.iter().sum::<f64>();
        if work + wait <= 0.0 {
            0.0
        } else {
            wait / (work + wait)
        }
    }
}

/// Combine per-rank partials in rank order -- THE reduction rule.
/// Every global scalar in both schedules goes through this fold, so
/// its rounding never depends on the execution schedule.
#[inline]
pub fn ordered_sum(parts: &[f64]) -> f64 {
    parts.iter().fold(0.0, |s, &v| s + v)
}

#[inline]
fn ordered_sum_bits(slots: &[AtomicU64]) -> f64 {
    slots
        .iter()
        .fold(0.0, |s, a| s + f64::from_bits(a.load(Ordering::Relaxed)))
}

/// Partial dot product over one rank's ascending row list.
#[inline]
fn dot_rows(rows: &[u32], u: &[f64], v: &[f64]) -> f64 {
    let mut s = 0.0;
    for &i in rows {
        s += u[i as usize] * v[i as usize];
    }
    s
}

/// Rank-local SpMV: y[i] = A[i,:] . x for the rank's rows. `x` must
/// hold valid values at every owned row index and every ghost column.
/// The CSR reference the SELL kernel must match bit for bit.
#[inline]
pub fn spmv_rows(a: &Csr, rows: &[u32], x: &[f64], y: &mut [f64]) {
    for &i in rows {
        let (cols, vals) = a.row(i as usize);
        let mut acc = 0.0;
        for (c, v) in cols.iter().zip(vals) {
            acc += v * x[*c as usize];
        }
        y[i as usize] = acc;
    }
}

/// Rank-local init: x = x0, r = b - A x0, z = Dinv r, p = z over the
/// rank's rows. Returns the partial (b.b, r.z).
#[inline]
#[allow(clippy::too_many_arguments)]
fn init_rows(
    a: &Csr,
    rows: &[u32],
    b: &[f64],
    x0: &[f64],
    dinv: &[f64],
    x: &mut [f64],
    r: &mut [f64],
    z: &mut [f64],
    p: &mut [f64],
) -> (f64, f64) {
    for &i in rows {
        let i = i as usize;
        let (cols, vals) = a.row(i);
        let mut acc = 0.0;
        for (c, v) in cols.iter().zip(vals) {
            acc += v * x0[*c as usize];
        }
        x[i] = x0[i];
        r[i] = b[i] - acc;
        z[i] = r[i] * dinv[i];
        p[i] = z[i];
    }
    (dot_rows(rows, b, b), dot_rows(rows, r, z))
}

/// Rank-local alpha update: x += alpha p, r -= alpha q, z = Dinv r
/// over the rank's rows. Returns the partial r.z.
#[inline]
#[allow(clippy::too_many_arguments)]
fn update_rows(
    rows: &[u32],
    alpha: f64,
    p: &[f64],
    q: &[f64],
    dinv: &[f64],
    x: &mut [f64],
    r: &mut [f64],
    z: &mut [f64],
) -> f64 {
    for &i in rows {
        let i = i as usize;
        x[i] += alpha * p[i];
        r[i] -= alpha * q[i];
    }
    for &i in rows {
        let i = i as usize;
        z[i] = r[i] * dinv[i];
    }
    dot_rows(rows, r, z)
}

/// Rank-local direction update: p = z + beta p over the rank's rows.
#[inline]
fn direction_rows(rows: &[u32], beta: f64, z: &[f64], p: &mut [f64]) {
    for &i in rows {
        let i = i as usize;
        p[i] = z[i] + beta * p[i];
    }
}

fn jacobi_dinv(a: &Csr) -> Vec<f64> {
    a.diag()
        .iter()
        .map(|&d| if d != 0.0 { 1.0 / d } else { 0.0 })
        .collect()
}

/// The virtual-SPMD schedule: every rank's phase executed in rank
/// order by one thread. Ghost exchange is the identity (all vectors
/// live in one address space), but every value and every reduction is
/// computed exactly as [`pcg_threaded`] computes it.
pub fn pcg_sequential(
    plan: &RankPlan,
    a: &Csr,
    b: &[f64],
    x: &mut [f64],
    opts: &SolverOpts,
) -> SolveStats {
    let n = a.n;
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);
    let p_ranks = plan.nranks;
    let dinv = jacobi_dinv(a);
    let kernels = build_kernels(a, plan);
    let x0: Vec<f64> = x.to_vec();
    let mut r = vec![0.0; n];
    let mut z = vec![0.0; n];
    let mut pv = vec![0.0; n];
    let mut q = vec![0.0; n];
    let mut part_a = vec![0.0; p_ranks];
    let mut part_b = vec![0.0; p_ranks];

    for rk in 0..p_ranks {
        let rows = &plan.rows[rk];
        let (pb2, prz) = init_rows(a, rows, b, &x0, &dinv, x, &mut r, &mut z, &mut pv);
        part_a[rk] = pb2;
        part_b[rk] = prz;
    }
    let bnorm2 = ordered_sum(&part_a);
    let mut rz = ordered_sum(&part_b);
    if bnorm2 == 0.0 {
        x.fill(0.0);
        return SolveStats {
            iterations: 0,
            rel_residual: 0.0,
            used_pjrt: false,
        };
    }
    let tol2 = opts.tol * opts.tol * bnorm2;
    let mut iterations = opts.max_iter;
    let mut rnorm2 = f64::INFINITY;
    for it in 0..=opts.max_iter {
        for rk in 0..p_ranks {
            let _sp = obs::span(rk, Phase::Dot);
            part_a[rk] = dot_rows(&plan.rows[rk], &r, &r);
        }
        rnorm2 = ordered_sum(&part_a);
        if rnorm2 <= tol2 {
            iterations = it;
            break;
        }
        if it == opts.max_iter {
            break;
        }
        // ghost exchange of p: the identity in one address space
        for (rk, kernel) in kernels.iter().enumerate() {
            let _sp = obs::span(rk, Phase::Spmv);
            kernel.spmv_interior(a, &plan.interior[rk], &pv, &mut q);
            kernel.spmv_boundary(a, &plan.boundary[rk], &pv, &mut q);
        }
        for rk in 0..p_ranks {
            let _sp = obs::span(rk, Phase::Dot);
            part_b[rk] = dot_rows(&plan.rows[rk], &pv, &q);
        }
        let pq = ordered_sum(&part_b);
        if pq <= 0.0 {
            iterations = it;
            break; // not SPD / breakdown
        }
        let alpha = rz / pq;
        for rk in 0..p_ranks {
            let _sp = obs::span(rk, Phase::Axpy);
            part_a[rk] = update_rows(&plan.rows[rk], alpha, &pv, &q, &dinv, x, &mut r, &mut z);
        }
        let rz_new = ordered_sum(&part_a);
        let beta = rz_new / rz;
        rz = rz_new;
        for (rk, rows) in plan.rows.iter().enumerate() {
            let _sp = obs::span(rk, Phase::Axpy);
            direction_rows(rows, beta, &z, &mut pv);
        }
    }
    SolveStats {
        iterations,
        rel_residual: (rnorm2 / bnorm2).sqrt(),
        used_pjrt: false,
    }
}

/// Per-rank working vectors of the threaded schedule. Full-length so
/// the shared kernels index globally; only owned entries (and, for
/// `p`, received ghosts) are ever read.
struct RankState {
    x: Vec<f64>,
    r: Vec<f64>,
    z: Vec<f64>,
    p: Vec<f64>,
    q: Vec<f64>,
}

impl RankState {
    fn new(n: usize) -> Self {
        Self {
            x: vec![0.0; n],
            r: vec![0.0; n],
            z: vec![0.0; n],
            p: vec![0.0; n],
            q: vec![0.0; n],
        }
    }
}

/// One rank's endpoints: halo slots per neighbour, in the same order
/// as the ghost plan's send/recv lists.
struct RankComm<'a> {
    rank: usize,
    sends: Vec<&'a HaloSlot>,
    recvs: Vec<&'a HaloSlot>,
}

/// What one rank hands back to the caller after the solve.
struct RankOut {
    rank: usize,
    /// Owned x entries, in `plan.rows[rank]` order.
    x_vals: Vec<f64>,
    /// Wall seconds of this rank's compute sections (assembly-free:
    /// SpMV, dots, axpy), excluding barrier and halo waits.
    busy: f64,
    /// Wall seconds blocked in phase barriers.
    barrier_wait: f64,
    /// Wall seconds blocked in `recv` for a halo message.
    halo_wait: f64,
    /// Wall seconds of halo pack/send/unpack work (non-blocking).
    halo_work: f64,
}

/// The real schedule: `nthreads` workers execute the virtual ranks
/// (contiguous blocks when ranks outnumber workers), barrier-stepped
/// through the same phases as [`pcg_sequential`], with ghost values
/// moved through reusable per-rank-pair slots. Returns the stats, the
/// per-rank wall decomposition (busy seconds are the *measured* load
/// imbalance; barrier/halo waits are its physical cost) and the halo
/// traffic.
pub fn pcg_threaded(
    plan: &RankPlan,
    ghost: &GhostPlan,
    a: &Csr,
    b: &[f64],
    x: &mut [f64],
    opts: &SolverOpts,
    nthreads: usize,
) -> (SolveStats, RankClocks, HaloStats) {
    let n = a.n;
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);
    let p_ranks = plan.nranks;
    let nthreads = nthreads.clamp(1, p_ranks.max(1));
    let dinv = jacobi_dinv(a);
    let kernels = build_kernels(a, plan);
    let x0: Vec<f64> = x.to_vec();

    // one reusable slot per directed rank pair, buffers sized to the
    // exact payload once -- the iteration loop then allocates nothing.
    // Stored flat in ghost.send order; send_base[r] indexes rank r's
    // outgoing slots.
    let mut send_base = vec![0usize; p_ranks + 1];
    for r in 0..p_ranks {
        send_base[r + 1] = send_base[r] + ghost.send[r].len();
    }
    let slot_store: Vec<HaloSlot> = (0..p_ranks)
        .flat_map(|r| ghost.send[r].iter().map(|(_, list)| HaloSlot::new(list.len())))
        .collect();
    let mut comms: Vec<RankComm> = (0..p_ranks)
        .map(|r| RankComm {
            rank: r,
            sends: (0..ghost.send[r].len())
                .map(|k| &slot_store[send_base[r] + k])
                .collect(),
            recvs: ghost.recv[r]
                .iter()
                .map(|(src, _)| {
                    let s = *src as usize;
                    let k = ghost.send[s]
                        .iter()
                        .position(|(dest, _)| *dest as usize == r)
                        .expect("send/recv transpose broken");
                    &slot_store[send_base[s] + k]
                })
                .collect(),
        })
        .collect();

    // reduction slots: two concurrent scalars suffice (see the barrier
    // schedule below); Relaxed is enough because every read is
    // separated from the matching stores by a Barrier::wait
    let slot_a: Vec<AtomicU64> = (0..p_ranks).map(|_| AtomicU64::new(0)).collect();
    let slot_b: Vec<AtomicU64> = (0..p_ranks).map(|_| AtomicU64::new(0)).collect();
    let barrier = Barrier::new(nthreads);

    // contiguous rank blocks per worker
    let mut bundles: Vec<Vec<RankComm>> = (0..nthreads).map(|_| Vec::new()).collect();
    for (t, bundle) in bundles.iter_mut().enumerate() {
        let lo = t * p_ranks / nthreads;
        let hi = (t + 1) * p_ranks / nthreads;
        for _ in lo..hi {
            bundle.push(comms.remove(0));
        }
    }
    debug_assert!(comms.is_empty());

    let mut outs: Vec<Option<RankOut>> = (0..p_ranks).map(|_| None).collect();
    let mut stats = SolveStats {
        iterations: 0,
        rel_residual: 0.0,
        used_pjrt: false,
    };
    let mut halo_rounds = 0usize;
    std::thread::scope(|scope| {
        let handles: Vec<_> = bundles
            .into_iter()
            .map(|bundle| {
                let (a, b, x0, dinv, plan, ghost) = (a, b, &x0, &dinv, plan, ghost);
                let (slot_a, slot_b, barrier) = (&slot_a, &slot_b, &barrier);
                let kernels = &kernels;
                scope.spawn(move || {
                    worker(
                        bundle,
                        plan,
                        ghost,
                        kernels,
                        a,
                        b,
                        x0,
                        dinv,
                        opts,
                        slot_a,
                        slot_b,
                        barrier,
                    )
                })
            })
            .collect();
        for h in handles {
            let (rank_outs, st, rounds) = h.join().expect("pcg worker panicked");
            stats = st;
            halo_rounds = rounds;
            for o in rank_outs {
                outs[o.rank] = Some(o);
            }
        }
    });

    let mut clocks = RankClocks::with_ranks(p_ranks);
    let mut halo = HaloStats {
        wall: 0.0,
        messages: halo_rounds * ghost.messages_per_update(),
        bytes: halo_rounds * ghost.bytes_per_update(),
    };
    for o in outs {
        let o = o.expect("rank produced no output");
        clocks.busy[o.rank] = o.busy;
        clocks.barrier_wait[o.rank] = o.barrier_wait;
        clocks.halo_wait[o.rank] = o.halo_wait;
        clocks.halo_work[o.rank] = o.halo_work;
        halo.wall = halo.wall.max(o.halo_work + o.halo_wait);
        for (j, &d) in plan.rows[o.rank].iter().enumerate() {
            x[d as usize] = o.x_vals[j];
        }
    }
    (stats, clocks, halo)
}

/// One barrier wait, measured once and charged to every rank of the
/// worker's bundle (a multiplexed rank was genuinely stalled for the
/// whole wait). Emits a `barrier_wait` span per rank when tracing.
fn barrier_wait_timed(barrier: &Barrier, bundle: &[RankComm<'_>], waits: &mut [f64]) {
    let tr = obs::tracer();
    let t0 = if tr.enabled() { Some(tr.now_ns()) } else { None };
    let sw = Stopwatch::start();
    barrier.wait();
    let dt = sw.elapsed();
    if let Some(t0) = t0 {
        let t1 = tr.now_ns();
        for c in bundle {
            tr.record_span(c.rank as u32, Phase::BarrierWait, t0, t1);
        }
    }
    for w in waits.iter_mut() {
        *w += dt;
    }
}

/// One worker's whole solve: runs every phase for each of its ranks,
/// in rank order, between shared barriers. All workers compute every
/// global scalar redundantly from the slot arrays, so control flow
/// (convergence, breakdown) is identical across workers by
/// construction and the barrier counts always line up.
#[allow(clippy::too_many_arguments)]
fn worker(
    bundle: Vec<RankComm<'_>>,
    plan: &RankPlan,
    ghost: &GhostPlan,
    kernels: &[RankSpmv],
    a: &Csr,
    b: &[f64],
    x0: &[f64],
    dinv: &[f64],
    opts: &SolverOpts,
    slot_a: &[AtomicU64],
    slot_b: &[AtomicU64],
    barrier: &Barrier,
) -> (Vec<RankOut>, SolveStats, usize) {
    let n = a.n;
    let mut states: Vec<RankState> = bundle.iter().map(|_| RankState::new(n)).collect();
    let mut busy = vec![0.0; bundle.len()];
    let mut halo_w = vec![0.0; bundle.len()];
    let mut halo_wt = vec![0.0; bundle.len()];
    let mut barrier_w = vec![0.0; bundle.len()];

    // ---- init: local residual + first partials
    for (k, c) in bundle.iter().enumerate() {
        let sw = Stopwatch::start();
        let st = &mut states[k];
        let (pb2, prz) = init_rows(
            a,
            &plan.rows[c.rank],
            b,
            x0,
            dinv,
            &mut st.x,
            &mut st.r,
            &mut st.z,
            &mut st.p,
        );
        slot_a[c.rank].store(pb2.to_bits(), Ordering::Relaxed);
        slot_b[c.rank].store(prz.to_bits(), Ordering::Relaxed);
        busy[k] += sw.elapsed();
    }
    barrier_wait_timed(barrier, &bundle, &mut barrier_w);
    let bnorm2 = ordered_sum_bits(slot_a);
    let mut rz = ordered_sum_bits(slot_b);
    // protect the slots from the next iteration's stores until every
    // worker has read them
    barrier_wait_timed(barrier, &bundle, &mut barrier_w);

    let finish = |states: &[RankState],
                  busy: &[f64],
                  barrier_w: &[f64],
                  halo_wt: &[f64],
                  halo_w: &[f64],
                  st: SolveStats,
                  rounds| {
        let outs = bundle
            .iter()
            .enumerate()
            .map(|(k, c)| RankOut {
                rank: c.rank,
                x_vals: plan.rows[c.rank]
                    .iter()
                    .map(|&d| states[k].x[d as usize])
                    .collect(),
                busy: busy[k],
                barrier_wait: barrier_w[k],
                halo_wait: halo_wt[k],
                halo_work: halo_w[k],
            })
            .collect();
        (outs, st, rounds)
    };

    if bnorm2 == 0.0 {
        // b = 0: the solution is 0 (mirrors native_pcg's early out)
        for (k, c) in bundle.iter().enumerate() {
            for &d in &plan.rows[c.rank] {
                states[k].x[d as usize] = 0.0;
            }
        }
        let st = SolveStats {
            iterations: 0,
            rel_residual: 0.0,
            used_pjrt: false,
        };
        return finish(&states, &busy, &barrier_w, &halo_wt, &halo_w, st, 0);
    }

    let tol2 = opts.tol * opts.tol * bnorm2;
    let mut iterations = opts.max_iter;
    let mut rnorm2 = f64::INFINITY;
    let mut rounds = 0usize;
    for it in 0..=opts.max_iter {
        // ---- convergence check: partial |r|^2, rank-ordered reduce
        for (k, c) in bundle.iter().enumerate() {
            let sw = Stopwatch::start();
            let v = {
                let _sp = obs::span(c.rank, Phase::Dot);
                dot_rows(&plan.rows[c.rank], &states[k].r, &states[k].r)
            };
            slot_a[c.rank].store(v.to_bits(), Ordering::Relaxed);
            busy[k] += sw.elapsed();
        }
        barrier_wait_timed(barrier, &bundle, &mut barrier_w); // B1
        rnorm2 = ordered_sum_bits(slot_a);
        if rnorm2 <= tol2 {
            iterations = it;
            break;
        }
        if it == opts.max_iter {
            break;
        }
        // ---- halo: ship owned boundary p values, then fill ghosts.
        // All sends happen before any recv on this worker; a recv
        // blocks only until the producing worker's publish lands, so
        // the slots themselves are the synchronization. The payload
        // is written straight into the pair's reusable buffer: the
        // steady-state loop allocates nothing.
        rounds += 1;
        for (k, c) in bundle.iter().enumerate() {
            let _sp = obs::span(c.rank, Phase::HaloSend);
            let sw = Stopwatch::start();
            for (tx, (_, list)) in c.sends.iter().zip(&ghost.send[c.rank]) {
                tx.publish(rounds as u64, list.iter().map(|&d| states[k].p[d as usize]));
            }
            halo_w[k] += sw.elapsed();
        }
        // overlap: interior rows have no off-rank columns, so their
        // q entries compute while neighbour messages are in flight
        for (k, c) in bundle.iter().enumerate() {
            let sw = Stopwatch::start();
            let st = &mut states[k];
            let _sp = obs::span(c.rank, Phase::Spmv);
            kernels[c.rank].spmv_interior(a, &plan.interior[c.rank], &st.p, &mut st.q);
            busy[k] += sw.elapsed();
        }
        for (k, c) in bundle.iter().enumerate() {
            let _sp = obs::span(c.rank, Phase::HaloRecv);
            let st = &mut states[k];
            for (rx, (_, list)) in c.recvs.iter().zip(&ghost.recv[c.rank]) {
                // blocked until the producing rank's publish lands:
                // the wait half of the halo cost
                let sw = Stopwatch::start();
                let msg = rx.wait_for(rounds as u64);
                halo_wt[k] += sw.elapsed();
                let sw = Stopwatch::start();
                debug_assert_eq!(msg.buf.len(), list.len());
                for (&d, &v) in list.iter().zip(&msg.buf) {
                    st.p[d as usize] = v;
                }
                drop(msg);
                halo_w[k] += sw.elapsed();
            }
        }
        // ---- boundary SpMV + partial p.q
        for (k, c) in bundle.iter().enumerate() {
            let sw = Stopwatch::start();
            let st = &mut states[k];
            {
                let _sp = obs::span(c.rank, Phase::Spmv);
                kernels[c.rank].spmv_boundary(a, &plan.boundary[c.rank], &st.p, &mut st.q);
            }
            let v = {
                let _sp = obs::span(c.rank, Phase::Dot);
                dot_rows(&plan.rows[c.rank], &st.p, &st.q)
            };
            slot_b[c.rank].store(v.to_bits(), Ordering::Relaxed);
            busy[k] += sw.elapsed();
        }
        barrier_wait_timed(barrier, &bundle, &mut barrier_w); // B2
        let pq = ordered_sum_bits(slot_b);
        if pq <= 0.0 {
            iterations = it;
            break; // not SPD / breakdown, all workers agree
        }
        let alpha = rz / pq;
        // ---- alpha update + partial r.z
        for (k, c) in bundle.iter().enumerate() {
            let sw = Stopwatch::start();
            let st = &mut states[k];
            let v = {
                let _sp = obs::span(c.rank, Phase::Axpy);
                update_rows(
                    &plan.rows[c.rank],
                    alpha,
                    &st.p,
                    &st.q,
                    dinv,
                    &mut st.x,
                    &mut st.r,
                    &mut st.z,
                )
            };
            slot_a[c.rank].store(v.to_bits(), Ordering::Relaxed);
            busy[k] += sw.elapsed();
        }
        barrier_wait_timed(barrier, &bundle, &mut barrier_w); // B3
        let rz_new = ordered_sum_bits(slot_a);
        let beta = rz_new / rz;
        rz = rz_new;
        // ---- direction update
        for (k, c) in bundle.iter().enumerate() {
            let sw = Stopwatch::start();
            let st = &mut states[k];
            let _sp = obs::span(c.rank, Phase::Axpy);
            direction_rows(&plan.rows[c.rank], beta, &st.z, &mut st.p);
            busy[k] += sw.elapsed();
        }
        barrier_wait_timed(barrier, &bundle, &mut barrier_w); // B4: p consistent before next halo
    }
    let st = SolveStats {
        iterations,
        rel_residual: (rnorm2 / bnorm2).sqrt(),
        used_pjrt: false,
    };
    finish(&states, &busy, &barrier_w, &halo_wt, &halo_w, st, rounds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Distribution;
    use crate::fem::{native_pcg, DofMap};
    use crate::mesh::generator;
    use crate::mesh::topology::LeafTopology;

    /// 2D grid Laplacian partitioned into contiguous row blocks.
    fn laplacian(n: usize) -> (Csr, Vec<f64>) {
        let id = |i: usize, j: usize| (i * n + j) as u32;
        let mut t = Vec::new();
        for i in 0..n {
            for j in 0..n {
                let r = id(i, j);
                t.push((r, r, 4.0));
                if i > 0 {
                    t.push((r, id(i - 1, j), -1.0));
                }
                if i + 1 < n {
                    t.push((r, id(i + 1, j), -1.0));
                }
                if j > 0 {
                    t.push((r, id(i, j - 1), -1.0));
                }
                if j + 1 < n {
                    t.push((r, id(i, j + 1), -1.0));
                }
            }
        }
        let a = Csr::from_triplets(n * n, t);
        let ones = vec![1.0; n * n];
        let mut b = vec![0.0; n * n];
        a.spmv(&ones, &mut b);
        (a, b)
    }

    /// Hand-built plan: contiguous row blocks, no element lists. With
    /// no mesh to derive the interior/boundary split from, every row
    /// is conservatively boundary (always correct: boundary rows
    /// multiply after the halo lands).
    fn block_plan(n: usize, nranks: usize) -> RankPlan {
        let mut rank_of_dof = vec![0u16; n];
        let mut rows: Vec<Vec<u32>> = vec![Vec::new(); nranks];
        for d in 0..n {
            let r = d * nranks / n;
            rank_of_dof[d] = r as u16;
            rows[r].push(d as u32);
        }
        RankPlan {
            nranks,
            elems: vec![Vec::new(); nranks],
            rank_of_dof,
            interior: vec![Vec::new(); nranks],
            boundary: rows.clone(),
            rows,
        }
    }

    #[test]
    fn sequential_matches_native_solution() {
        let (a, b) = laplacian(16);
        let plan = block_plan(a.n, 4);
        // tight tolerance so the convergence bound, not the stopping
        // criterion, dominates the cross-algorithm comparison
        let opts = SolverOpts {
            tol: 1e-10,
            max_iter: 2000,
        };
        let mut xs = vec![0.0; a.n];
        let stats = pcg_sequential(&plan, &a, &b, &mut xs, &opts);
        assert!(stats.rel_residual < 1e-10);
        let mut xn = vec![0.0; a.n];
        let sn = native_pcg(&a, &b, &mut xn, &opts);
        // different reduction order: same solution to solver accuracy
        for (s, v) in xs.iter().zip(&xn) {
            assert!((s - v).abs() < 1e-6, "{s} vs {v}");
        }
        assert!(stats.iterations.abs_diff(sn.iterations) <= 5);
    }

    #[test]
    fn threaded_is_bitwise_equal_to_sequential() {
        let (a, b) = laplacian(20);
        for nranks in [1usize, 3, 5] {
            let plan = block_plan(a.n, nranks);
            let ghost = GhostPlan::build(&plan, &a);
            let opts = SolverOpts {
                tol: 1e-8,
                max_iter: 500,
            };
            let mut xs = vec![0.0; a.n];
            let st_seq = pcg_sequential(&plan, &a, &b, &mut xs, &opts);
            for nthreads in [1usize, 2, 8] {
                let mut xt = vec![0.0; a.n];
                let (st_thr, clocks, halo) =
                    pcg_threaded(&plan, &ghost, &a, &b, &mut xt, &opts, nthreads);
                assert_eq!(st_seq.iterations, st_thr.iterations, "p={nranks} t={nthreads}");
                assert_eq!(
                    st_seq.rel_residual.to_bits(),
                    st_thr.rel_residual.to_bits(),
                    "p={nranks} t={nthreads}"
                );
                for (i, (s, t)) in xs.iter().zip(&xt).enumerate() {
                    assert_eq!(
                        s.to_bits(),
                        t.to_bits(),
                        "x[{i}] differs: p={nranks} t={nthreads}"
                    );
                }
                assert_eq!(clocks.busy.len(), nranks);
                assert_eq!(clocks.barrier_wait.len(), nranks);
                assert_eq!(clocks.halo_wait.len(), nranks);
                assert!(clocks.busy.iter().all(|&t| t >= 0.0));
                assert!(clocks.barrier_wait.iter().all(|&t| t.is_finite() && t >= 0.0));
                assert!(clocks.halo_wait.iter().all(|&t| t.is_finite() && t >= 0.0));
                let wf = clocks.wait_fraction();
                assert!((0.0..=1.0).contains(&wf), "wait fraction {wf}");
                if nranks > 1 {
                    assert!(halo.messages > 0, "no halo traffic at p={nranks}");
                    assert!(halo.bytes > halo.messages);
                    // the halo wall covers both the work and wait parts
                    let hmax = (0..nranks)
                        .map(|r| clocks.halo_work[r] + clocks.halo_wait[r])
                        .fold(0.0, f64::max);
                    assert!((halo.wall - hmax).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn threaded_is_run_to_run_deterministic() {
        let (a, b) = laplacian(12);
        let plan = block_plan(a.n, 4);
        let ghost = GhostPlan::build(&plan, &a);
        let opts = SolverOpts::default();
        let mut first = vec![0.0; a.n];
        let (s1, _, _) = pcg_threaded(&plan, &ghost, &a, &b, &mut first, &opts, 4);
        for _ in 0..3 {
            let mut again = vec![0.0; a.n];
            let (s2, _, _) = pcg_threaded(&plan, &ghost, &a, &b, &mut again, &opts, 4);
            assert_eq!(s1.iterations, s2.iterations);
            for (u, v) in first.iter().zip(&again) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
    }

    #[test]
    fn zero_rhs_returns_zero() {
        let (a, _) = laplacian(6);
        let plan = block_plan(a.n, 3);
        let ghost = GhostPlan::build(&plan, &a);
        let b = vec![0.0; a.n];
        let mut x = vec![5.0; a.n];
        let (st, _, _) = pcg_threaded(&plan, &ghost, &a, &b, &mut x, &SolverOpts::default(), 2);
        assert_eq!(st.iterations, 0);
        assert!(x.iter().all(|&v| v == 0.0));
        let mut xs = vec![5.0; a.n];
        let ss = pcg_sequential(&plan, &a, &b, &mut xs, &SolverOpts::default());
        assert_eq!(ss.iterations, 0);
        assert!(xs.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn warm_start_converges_faster() {
        let (a, b) = laplacian(16);
        let plan = block_plan(a.n, 4);
        let opts = SolverOpts::default();
        let mut cold = vec![0.0; a.n];
        let s_cold = pcg_sequential(&plan, &a, &b, &mut cold, &opts);
        let mut warm: Vec<f64> = cold.iter().map(|v| v * 0.999).collect();
        let s_warm = pcg_sequential(&plan, &a, &b, &mut warm, &opts);
        assert!(s_warm.iterations < s_cold.iterations);
    }

    #[test]
    fn fem_plan_roundtrip_through_both_schedules() {
        // a real mesh-derived plan (scattered row ownership, ghost
        // lists from the actual FEM pattern), not just row blocks
        let mut mesh = generator::cube_mesh(2);
        mesh.refine(&mesh.leaves_unordered());
        let leaves = mesh.leaves_unordered();
        Distribution::new(4).assign_blocks(&mut mesh, &leaves);
        let topo = LeafTopology::build(&mesh);
        let dof = DofMap::build(&mesh, &topo);
        let owners: Vec<u16> = topo.leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let plan = RankPlan::build(&mesh, &topo, &dof, &owners, 4);
        let src = vec![1.0; dof.n_dofs];
        let sys = crate::fem::assemble(&mesh, &topo, &dof, &src, None);
        let a = Csr::linear_combination(1.0, &sys.k, 1.0, &sys.m);
        let ghost = GhostPlan::build(&plan, &a);
        let opts = SolverOpts {
            tol: 1e-9,
            max_iter: 2000,
        };
        let mut xs = vec![0.0; a.n];
        let st = pcg_sequential(&plan, &a, &sys.b, &mut xs, &opts);
        assert!(st.rel_residual < 1e-8, "relres {}", st.rel_residual);
        let mut xt = vec![0.0; a.n];
        let (tt, clocks, _) = pcg_threaded(&plan, &ghost, &a, &sys.b, &mut xt, &opts, 3);
        assert_eq!(st.iterations, tt.iterations);
        for (s, t) in xs.iter().zip(&xt) {
            assert_eq!(s.to_bits(), t.to_bits());
        }
        assert!(clocks.busy.iter().sum::<f64>() > 0.0);
    }

    #[test]
    fn rank_clocks_merge_and_fractions() {
        let mut a = RankClocks::with_ranks(2);
        a.busy = vec![3.0, 1.0];
        a.barrier_wait = vec![0.0, 2.0];
        let mut b = RankClocks::default();
        b.merge(&a);
        b.merge(&a);
        assert_eq!(b.busy, vec![6.0, 2.0]);
        assert_eq!(b.barrier_wait, vec![0.0, 4.0]);
        assert_eq!(b.max_barrier_wait(), 4.0);
        assert_eq!(b.max_halo_wait(), 0.0);
        // waits 4 of 12 accounted rank-seconds
        assert!((b.wait_fraction() - 4.0 / 12.0).abs() < 1e-12);
        assert_eq!(RankClocks::default().wait_fraction(), 0.0);
    }
}
