//! Rank-local P1 assembly: each rank computes element matrices for
//! the leaves it owns; the per-rank contributions are combined in
//! rank order into one global system (DESIGN.md §9).
//!
//! The math is exactly [`crate::fem::elem_matrices`]; what this module
//! fixes is the *order*: triplets are concatenated rank by rank (each
//! rank's elements ascending) and the load vectors are accumulated
//! rank by rank, so the assembled system is bit-identical whether the
//! per-rank loops ran sequentially ([`VirtualExec`]) or on worker
//! threads ([`ThreadedExec`]).
//!
//! [`VirtualExec`]: crate::exec::VirtualExec
//! [`ThreadedExec`]: crate::exec::ThreadedExec

use crate::fem::{assemble::elem_matrices, Assembled, AssemblyPattern, Csr, DofMap};
use crate::mesh::topology::LeafTopology;
use crate::mesh::TetMesh;

/// One rank's assembly contribution: its elements' stiffness/mass
/// triplets and a full-length load vector holding only its elements'
/// scatter.
pub struct RankAssembly {
    pub kt: Vec<(u32, u32, f64)>,
    pub mt: Vec<(u32, u32, f64)>,
    pub b: Vec<f64>,
}

/// Assemble one rank's owned elements (`elems` indexes `topo.leaves`),
/// native f64 engine.
pub fn assemble_rank(
    mesh: &TetMesh,
    topo: &LeafTopology,
    dof: &DofMap,
    source: &[f64],
    elems: &[u32],
) -> RankAssembly {
    let mut kt = Vec::with_capacity(elems.len() * 16);
    let mut mt = Vec::with_capacity(elems.len() * 16);
    let mut b = vec![0.0f64; dof.n_dofs];
    for &e in elems {
        let id = topo.leaves[e as usize];
        let verts = mesh.verts_of(id);
        let dofs = [
            dof.dof_of_vertex[verts[0] as usize],
            dof.dof_of_vertex[verts[1] as usize],
            dof.dof_of_vertex[verts[2] as usize],
            dof.dof_of_vertex[verts[3] as usize],
        ];
        let c = mesh.elem_coords(id);
        let f = [
            source[dofs[0] as usize],
            source[dofs[1] as usize],
            source[dofs[2] as usize],
            source[dofs[3] as usize],
        ];
        let (ke, me, be) = elem_matrices(&c, &f);
        for i in 0..4 {
            b[dofs[i] as usize] += be[i];
            for j in 0..4 {
                kt.push((dofs[i], dofs[j], ke[i * 4 + j]));
                mt.push((dofs[i], dofs[j], me[i * 4 + j]));
            }
        }
    }
    RankAssembly { kt, mt, b }
}

/// One rank's *dense* element contributions for the pattern-reuse
/// path: element matrices kept as 4x4 blocks (no triplets, nothing to
/// sort) plus the rank's partial load vector, scattered inside the
/// worker exactly like [`assemble_rank`] does.
pub struct RankDense {
    pub ke: Vec<[f64; 16]>,
    pub me: Vec<[f64; 16]>,
    pub b: Vec<f64>,
}

/// Compute one rank's dense element matrices (the FLOP-heavy part,
/// safe to run on a worker thread). `elems` indexes `topo.leaves`;
/// dofs come from the pattern's cached `elem_dofs`.
pub fn dense_rank(
    mesh: &TetMesh,
    topo: &LeafTopology,
    source: &[f64],
    pat: &AssemblyPattern,
    elems: &[u32],
) -> RankDense {
    let mut ke = Vec::with_capacity(elems.len());
    let mut me = Vec::with_capacity(elems.len());
    let mut b = vec![0.0f64; pat.n_dofs];
    for &e in elems {
        let c = mesh.elem_coords(topo.leaves[e as usize]);
        let dofs = pat.elem_dofs[e as usize];
        let f = [
            source[dofs[0] as usize],
            source[dofs[1] as usize],
            source[dofs[2] as usize],
            source[dofs[3] as usize],
        ];
        let (k_e, m_e, b_e) = elem_matrices(&c, &f);
        for i in 0..4 {
            b[dofs[i] as usize] += b_e[i];
        }
        ke.push(k_e);
        me.push(m_e);
    }
    RankDense { ke, me, b }
}

/// Scatter per-rank dense contributions through a prebuilt pattern,
/// rank by rank -- bitwise identical to [`combine`] over
/// [`assemble_rank`] parts (same per-slot fold order: ranks in order,
/// each rank's elements ascending, `(i, j)` row-major; the load
/// vectors fold rank-wise exactly as `combine` does), with zero
/// sorting per solve.
pub fn combine_dense(
    pat: &AssemblyPattern,
    elems_of_rank: &[Vec<u32>],
    parts: Vec<RankDense>,
) -> Assembled {
    let mut k = pat.zero_csr();
    let mut m = pat.zero_csr();
    let mut b = vec![0.0f64; pat.n_dofs];
    for (part, elems) in parts.iter().zip(elems_of_rank) {
        for (loc, &e) in elems.iter().enumerate() {
            let ke = &part.ke[loc];
            let me = &part.me[loc];
            let s0 = e as usize * 16;
            for ij in 0..16 {
                let s = pat.slots[s0 + ij] as usize;
                k.vals[s] += ke[ij];
                m.vals[s] += me[ij];
            }
        }
        for (acc, v) in b.iter_mut().zip(&part.b) {
            *acc += v;
        }
    }
    Assembled { k, m, b }
}

/// Combine per-rank contributions in rank order into the global
/// system. The caller must pass `parts` indexed by rank.
pub fn combine(n_dofs: usize, parts: Vec<RankAssembly>) -> Assembled {
    let nnz: usize = parts.iter().map(|p| p.kt.len()).sum();
    let mut kt = Vec::with_capacity(nnz);
    let mut mt = Vec::with_capacity(nnz);
    let mut b = vec![0.0f64; n_dofs];
    for part in parts {
        kt.extend(part.kt);
        mt.extend(part.mt);
        for (acc, v) in b.iter_mut().zip(&part.b) {
            *acc += v;
        }
    }
    Assembled {
        k: Csr::from_triplets(n_dofs, kt),
        m: Csr::from_triplets(n_dofs, mt),
        b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Distribution;
    use crate::exec::plan::RankPlan;
    use crate::fem::assemble;
    use crate::mesh::generator;

    fn setup(nparts: usize) -> (TetMesh, LeafTopology, DofMap, RankPlan) {
        let mut mesh = generator::cube_mesh(2);
        mesh.refine(&mesh.leaves_unordered());
        let leaves = mesh.leaves_unordered();
        Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
        let topo = LeafTopology::build(&mesh);
        let dof = DofMap::build(&mesh, &topo);
        let owners: Vec<u16> = topo.leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let plan = RankPlan::build(&mesh, &topo, &dof, &owners, nparts);
        (mesh, topo, dof, plan)
    }

    #[test]
    fn ranked_assembly_matches_global_assembly() {
        let (mesh, topo, dof, plan) = setup(4);
        let src = dof.eval_at_dofs(&mesh, |p| (3.0 * p.x).sin() + p.y * p.z);
        let global = assemble::assemble(&mesh, &topo, &dof, &src, None);
        let parts: Vec<RankAssembly> = (0..plan.nranks)
            .map(|r| assemble_rank(&mesh, &topo, &dof, &src, &plan.elems[r]))
            .collect();
        let ranked = combine(dof.n_dofs, parts);
        assert_eq!(global.k.nnz(), ranked.k.nnz());
        assert_eq!(global.m.nnz(), ranked.m.nnz());
        // same entries to rounding (summation order differs from the
        // global element loop, so exact equality is not guaranteed)
        for (a, b) in global.k.vals.iter().zip(&ranked.k.vals) {
            assert!((a - b).abs() < 1e-12 * a.abs().max(1.0), "{a} vs {b}");
        }
        for (a, b) in global.b.iter().zip(&ranked.b) {
            assert!((a - b).abs() < 1e-13, "{a} vs {b}");
        }
    }

    #[test]
    fn dense_pattern_combine_is_bitwise_identical_to_triplet_combine() {
        let (mesh, topo, dof, plan) = setup(5);
        let src = dof.eval_at_dofs(&mesh, |p| p.x * p.y - 0.25 * p.z);
        let trip_parts: Vec<RankAssembly> = (0..plan.nranks)
            .map(|r| assemble_rank(&mesh, &topo, &dof, &src, &plan.elems[r]))
            .collect();
        let trip = combine(dof.n_dofs, trip_parts);
        let pat = AssemblyPattern::build(&mesh, &topo, &dof);
        let dense_parts: Vec<RankDense> = (0..plan.nranks)
            .map(|r| dense_rank(&mesh, &topo, &src, &pat, &plan.elems[r]))
            .collect();
        let dense = combine_dense(&pat, &plan.elems, dense_parts);
        assert_eq!(trip.k.row_ptr, dense.k.row_ptr);
        assert_eq!(trip.k.col_idx, dense.k.col_idx);
        for (a, b) in trip.k.vals.iter().zip(&dense.k.vals) {
            assert_eq!(a.to_bits(), b.to_bits(), "K differs: {a} vs {b}");
        }
        for (a, b) in trip.m.vals.iter().zip(&dense.m.vals) {
            assert_eq!(a.to_bits(), b.to_bits(), "M differs: {a} vs {b}");
        }
        for (a, b) in trip.b.iter().zip(&dense.b) {
            assert_eq!(a.to_bits(), b.to_bits(), "b differs: {a} vs {b}");
        }
    }

    #[test]
    fn rank_count_does_not_change_the_combined_system_structure() {
        // the same mesh assembled under different rank plans must give
        // the same sparsity and (near-)identical values
        let ranked = |nparts: usize| {
            let (mesh, topo, dof, plan) = setup(nparts);
            let src = dof.eval_at_dofs(&mesh, |p| p.x);
            let parts: Vec<RankAssembly> = (0..plan.nranks)
                .map(|r| assemble_rank(&mesh, &topo, &dof, &src, &plan.elems[r]))
                .collect();
            combine(dof.n_dofs, parts)
        };
        let one = ranked(1);
        let six = ranked(6);
        assert_eq!(one.k.nnz(), six.k.nnz());
        assert_eq!(one.b.len(), six.b.len());
        for (a, b) in one.b.iter().zip(&six.b) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
        for (a, b) in one.m.vals.iter().zip(&six.m.vals) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }
}
