//! The virtual-SPMD executor: every rank's work executed by one
//! thread, communication priced by the alpha-beta model instead of
//! performed (DESIGN.md §2, §9).
//!
//! This is the crate's original execution model extracted behind the
//! [`Executor`] trait: assembly and the Jacobi-PCG run rank phase by
//! rank phase in one address space, the ghost exchange is the
//! identity, and the timeline's SPMD substitution (measured wall /
//! nparts x lambda + modeled halo) turns the sequential wall clock
//! into a modeled parallel time. When PJRT artifacts are available the
//! batched L1 kernels take over assembly and the CG loop wholesale
//! (they are engine substitutions, not schedule changes).

use crate::fem::{
    assemble, pjrt_pcg, Assembled, AssemblyPattern, Csr, DofMap, SolveStats, SolverOpts,
};
use crate::mesh::topology::LeafTopology;
use crate::mesh::TetMesh;
use crate::obs::{self, Phase};
use crate::runtime::Runtime;
use std::cell::RefCell;

use super::assemble::{combine_dense, dense_rank, RankDense};
use super::pcg::pcg_sequential;
use super::plan::RankPlan;
use super::{ExecReport, Executor};

/// The sequential + modeled path (`--exec virtual`).
#[derive(Debug, Clone)]
pub struct VirtualExec {
    nranks: usize,
    /// Sparsity pattern cache, reused across solves while the mesh
    /// revision is unchanged (DESIGN.md §11).
    pattern: RefCell<Option<AssemblyPattern>>,
}

impl VirtualExec {
    pub fn new(nranks: usize) -> Self {
        assert!(nranks >= 1);
        Self {
            nranks,
            pattern: RefCell::new(None),
        }
    }
}

impl Executor for VirtualExec {
    fn name(&self) -> &'static str {
        "virtual"
    }

    fn nranks(&self) -> usize {
        self.nranks
    }

    fn assemble(
        &self,
        plan: &RankPlan,
        mesh: &TetMesh,
        topo: &LeafTopology,
        dof: &DofMap,
        source: &[f64],
        rt: Option<&Runtime>,
    ) -> Assembled {
        if rt.is_some() {
            // the batched artifact path chunks globally by ladder
            // rungs; keep it untouched (engine substitution, §3)
            return assemble(mesh, topo, dof, source, rt);
        }
        let mut cache = self.pattern.borrow_mut();
        if !cache.as_ref().is_some_and(|p| p.matches(mesh, dof)) {
            obs::metrics().counter_add("exec.pattern_rebuilds", 1);
            *cache = Some(AssemblyPattern::build(mesh, topo, dof));
        } else {
            obs::metrics().counter_add("exec.pattern_reuses", 1);
        }
        let pat = cache.as_ref().unwrap();
        let parts: Vec<RankDense> = (0..plan.nranks)
            .map(|r| {
                let _sp = obs::span(r, Phase::Assemble);
                dense_rank(mesh, topo, source, pat, &plan.elems[r])
            })
            .collect();
        combine_dense(pat, &plan.elems, parts)
    }

    fn pcg(
        &self,
        plan: &RankPlan,
        a: &Csr,
        b: &[f64],
        x: &mut [f64],
        opts: &SolverOpts,
        rt: Option<&Runtime>,
    ) -> SolveStats {
        if let Some(rt) = rt {
            if let Some(stats) = pjrt_pcg(rt, a, b, x, opts) {
                return stats;
            }
        }
        obs::metrics().counter_add("exec.virtual.pcg_solves", 1);
        pcg_sequential(plan, a, b, x, opts)
    }

    fn take_report(&self) -> ExecReport {
        ExecReport::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Distribution;
    use crate::mesh::generator;

    #[test]
    fn virtual_exec_solves_a_reaction_diffusion_system() {
        let mut mesh = generator::cube_mesh(2);
        mesh.refine(&mesh.leaves_unordered());
        let leaves = mesh.leaves_unordered();
        Distribution::new(4).assign_blocks(&mut mesh, &leaves);
        let topo = LeafTopology::build(&mesh);
        let dof = DofMap::build(&mesh, &topo);
        let owners: Vec<u16> = topo.leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let plan = RankPlan::build(&mesh, &topo, &dof, &owners, 4);
        let exec = VirtualExec::new(4);
        assert_eq!(exec.name(), "virtual");
        assert!(!exec.measures());

        let src = vec![1.0; dof.n_dofs];
        let sys = exec.assemble(&plan, &mesh, &topo, &dof, &src, None);
        let a = Csr::linear_combination(1.0, &sys.k, 1.0, &sys.m);
        let mut u = vec![0.0; dof.n_dofs];
        let stats = exec.pcg(&plan, &a, &sys.b, &mut u, &SolverOpts::default(), None);
        assert!(stats.iterations > 0);
        assert!(stats.rel_residual < 1e-6);
        assert!(!stats.used_pjrt);
        // the virtual executor measures nothing: empty report
        let rep = exec.take_report();
        assert!(rep.clocks.is_empty());
        assert_eq!(rep.halo_messages, 0);
    }
}
