//! The execution subsystem: *how* the virtual ranks actually run
//! (DESIGN.md §9).
//!
//! Everything above this layer -- partitioners, the DLB policy loop,
//! the scenarios -- talks about virtual ranks; this module decides
//! what a rank physically is. An [`Executor`] owns the two
//! rank-parallel kernels of an adaptive step, rank-local assembly and
//! the distributed Jacobi-PCG, both driven by a per-step ownership
//! [`RankPlan`]:
//!
//! * [`VirtualExec`] (`--exec virtual`) -- the crate's original mode:
//!   one thread executes every rank's phase in rank order and the
//!   timeline prices communication with the alpha-beta model. Nothing
//!   is measured in parallel; imbalance is modeled from weights.
//! * [`ThreadedExec`] (`--exec threads`) -- real shared-memory SPMD:
//!   one `std::thread` worker per virtual rank (capped at the core
//!   count), barrier-stepped phases, ghost-dof values physically
//!   exchanged along the [`GhostPlan`] halo, rank-ordered
//!   deterministic reductions. Wall clock is hardware time; per-rank
//!   busy times are *measured* load that replaces the modeled
//!   `solve_imbalance` and feeds the `measured` weight model.
//!
//! Both executors run bit-identical arithmetic (the plan fixes every
//! loop and reduction order), so `--exec` changes how fast the answer
//! arrives and how honestly it is timed -- never the answer itself.
//! That same determinism is what lets [`crate::serve`] multiplex many
//! driver tenants over this machinery and still promise bitwise
//! checkpoint/resume equivalence ([`crate::coordinator::checkpoint`]).

pub mod assemble;
pub mod ghost;
pub mod pcg;
pub mod plan;
mod threaded;
mod virtual_exec;

pub use ghost::GhostPlan;
pub use pcg::{pcg_sequential, pcg_threaded, spmv_rows, HaloStats, RankClocks, RankSpmv};
pub use plan::RankPlan;
pub use threaded::{available_threads, ThreadedExec};
pub use virtual_exec::VirtualExec;

use crate::bail;
use crate::fem::{Assembled, Csr, DofMap, SolveStats, SolverOpts};
use crate::mesh::topology::LeafTopology;
use crate::mesh::TetMesh;
use crate::runtime::Runtime;
use crate::util::error::Result;

/// What an executor measured while running one adaptive step's
/// assembly + solve. Drained by [`Executor::take_report`]; empty for
/// executors that measure nothing ([`VirtualExec`]).
#[derive(Debug, Clone, Default)]
pub struct ExecReport {
    /// Per-rank wall decomposition: busy seconds of compute sections
    /// (assembly, SpMV, dots, axpy) plus barrier-wait, halo-wait and
    /// halo-work seconds -- the measured load profile and the
    /// measured cost of imbalance (DESIGN.md §10).
    pub clocks: RankClocks,
    /// Bottleneck rank's wall seconds spent on halo exchange.
    pub halo_wall: f64,
    /// Directed halo messages over the step.
    pub halo_messages: usize,
    /// Halo payload bytes over the step.
    pub halo_bytes: usize,
}

impl ExecReport {
    /// Measured load-imbalance factor `max busy / mean busy` (1.0 when
    /// nothing was measured).
    pub fn measured_imbalance(&self) -> f64 {
        let busy = &self.clocks.busy;
        if busy.is_empty() || busy.iter().sum::<f64>() <= 0.0 {
            return 1.0;
        }
        crate::util::stats::imbalance(busy).max(1.0)
    }

    /// Bottleneck rank's busy seconds (0 when nothing was measured).
    pub fn max_busy(&self) -> f64 {
        self.clocks.busy.iter().cloned().fold(0.0, f64::max)
    }

    /// Mean per-rank busy seconds (0 when nothing was measured).
    pub fn mean_busy(&self) -> f64 {
        if self.clocks.busy.is_empty() {
            0.0
        } else {
            self.clocks.busy.iter().sum::<f64>() / self.clocks.busy.len() as f64
        }
    }

    /// Bottleneck rank's barrier-wait seconds.
    pub fn max_barrier_wait(&self) -> f64 {
        self.clocks.max_barrier_wait()
    }

    /// Bottleneck rank's halo-wait seconds.
    pub fn max_halo_wait(&self) -> f64 {
        self.clocks.max_halo_wait()
    }

    /// Fraction of accounted rank-seconds spent waiting.
    pub fn wait_fraction(&self) -> f64 {
        self.clocks.wait_fraction()
    }
}

/// A pluggable execution schedule for the rank-parallel kernels of an
/// adaptive step. Implementations must be deterministic: repeated
/// calls with the same inputs produce bit-identical outputs, and all
/// executors agree bit for bit (the cross-executor contract the
/// equivalence suite enforces).
pub trait Executor {
    /// Registry name (`--exec <name>`).
    fn name(&self) -> &'static str;

    /// Virtual rank count this executor was built for.
    fn nranks(&self) -> usize;

    /// Whether [`Executor::take_report`] carries genuine parallel
    /// measurements (true only for schedules that really ran ranks
    /// concurrently).
    fn measures(&self) -> bool {
        false
    }

    /// Assemble K, M, b over the plan's elements. `rt` is the PJRT
    /// runtime for executors that support the artifact engines.
    fn assemble(
        &self,
        plan: &RankPlan,
        mesh: &TetMesh,
        topo: &LeafTopology,
        dof: &DofMap,
        source: &[f64],
        rt: Option<&Runtime>,
    ) -> Assembled;

    /// Jacobi-PCG on `A x = b` with the plan's row ownership and
    /// rank-ordered deterministic reductions.
    fn pcg(
        &self,
        plan: &RankPlan,
        a: &Csr,
        b: &[f64],
        x: &mut [f64],
        opts: &SolverOpts,
        rt: Option<&Runtime>,
    ) -> SolveStats;

    /// Drain the measurements accumulated since the last call.
    fn take_report(&self) -> ExecReport;
}

/// One registered executor: its `--exec` name and a one-line
/// description (the `phg-dlb methods` listing).
pub struct ExecutorSpec {
    pub name: &'static str,
    pub description: &'static str,
}

/// Every executor, default first.
pub const EXECUTORS: [ExecutorSpec; 2] = [
    ExecutorSpec {
        name: "virtual",
        description: "sequential virtual-SPMD: ranks run in one thread, comm priced alpha-beta",
    },
    ExecutorSpec {
        name: "threads",
        description: "shared-memory SPMD: one worker per rank (capped at cores), measured walls",
    },
];

/// Instantiate an executor from its config/CLI spec. `threads` is the
/// `--exec-threads` budget (0 = auto: one worker per core). Unknown
/// names error with the valid list.
pub fn executor_by_name(spec: &str, nranks: usize, threads: usize) -> Result<Box<dyn Executor>> {
    match spec {
        "virtual" => Ok(Box::new(VirtualExec::new(nranks))),
        "threads" => Ok(Box::new(ThreadedExec::new(nranks, threads))),
        other => bail!("unknown executor {other:?}; valid executors: virtual, threads"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_knows_all_executors() {
        for spec in &EXECUTORS {
            let e = executor_by_name(spec.name, 4, 2).unwrap();
            assert_eq!(e.name(), spec.name);
            assert_eq!(e.nranks(), 4);
            assert!(!spec.description.is_empty());
        }
    }

    #[test]
    fn unknown_executor_lists_valid_names() {
        let err = executor_by_name("mpi", 4, 0).unwrap_err().to_string();
        assert!(err.contains("mpi"), "{err}");
        for spec in &EXECUTORS {
            assert!(err.contains(spec.name), "error does not list {}: {err}", spec.name);
        }
    }

    #[test]
    fn measured_imbalance_handles_empty_and_skewed() {
        assert_eq!(ExecReport::default().measured_imbalance(), 1.0);
        let rep = ExecReport {
            clocks: RankClocks {
                busy: vec![3.0, 1.0, 1.0, 1.0],
                ..Default::default()
            },
            ..Default::default()
        };
        assert!((rep.measured_imbalance() - 2.0).abs() < 1e-12);
        assert_eq!(rep.max_busy(), 3.0);
        assert!((rep.mean_busy() - 1.5).abs() < 1e-12);
        let zero = ExecReport {
            clocks: RankClocks {
                busy: vec![0.0, 0.0],
                ..Default::default()
            },
            ..Default::default()
        };
        assert_eq!(zero.measured_imbalance(), 1.0);
    }

    #[test]
    fn report_wait_summaries_follow_clocks() {
        let rep = ExecReport {
            clocks: RankClocks {
                busy: vec![1.0, 1.0],
                barrier_wait: vec![0.5, 0.1],
                halo_wait: vec![0.0, 0.4],
                halo_work: vec![0.0, 0.0],
            },
            ..Default::default()
        };
        assert_eq!(rep.max_barrier_wait(), 0.5);
        assert_eq!(rep.max_halo_wait(), 0.4);
        // 1.0 of 3.0 accounted rank-seconds are waits
        assert!((rep.wait_fraction() - 1.0 / 3.0).abs() < 1e-12);
    }
}
