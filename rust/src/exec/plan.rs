//! Rank-local ownership: which rank assembles which elements and
//! owns which matrix rows (DESIGN.md §9).
//!
//! A [`RankPlan`] is the per-step contract between the driver and an
//! [`crate::exec::Executor`]: it freezes the element -> rank map (the
//! mesh's `owner` fields at solve time) into per-rank element lists,
//! and derives from it a *row* ownership over the P1 dofs -- every dof
//! is owned by exactly one rank (the owner of the first leaf, in
//! topology order, that touches its vertex). Rank-local assembly
//! iterates `elems[r]`; the distributed Jacobi-PCG updates `rows[r]`.
//!
//! Both executors consume the same plan, and every per-rank list is
//! sorted ascending, so the arithmetic (element scatter order, partial
//! dot products) is identical across executors by construction -- the
//! bit-reproducibility rule of DESIGN.md §9.

use crate::fem::DofMap;
use crate::mesh::topology::LeafTopology;
use crate::mesh::TetMesh;

/// Element and row ownership of one partition over `nranks` ranks.
#[derive(Debug, Clone)]
pub struct RankPlan {
    pub nranks: usize,
    /// Per rank: the local leaf indices (into `topo.leaves`) it owns,
    /// ascending -- the elements the rank assembles.
    pub elems: Vec<Vec<u32>>,
    /// Per dof: the owning rank (owner of the first leaf in topology
    /// order touching the dof's vertex).
    pub rank_of_dof: Vec<u16>,
    /// Per rank: the dof indices it owns, ascending -- the matrix rows
    /// the rank updates in the distributed solve.
    pub rows: Vec<Vec<u32>>,
    /// Per rank: the subset of `rows[r]` every one of whose matrix
    /// columns is also rank-`r`-owned (ascending). A P1 row's columns
    /// are exactly the dofs sharing a leaf with it, so a row is
    /// interior iff every leaf touching its vertex has all four dofs
    /// on the same rank. Interior rows can spmv without halo data --
    /// the SELL fast path.
    pub interior: Vec<Vec<u32>>,
    /// Per rank: `rows[r]` minus `interior[r]` (ascending) -- rows
    /// with at least one off-rank column, which must wait for the
    /// halo exchange.
    pub boundary: Vec<Vec<u32>>,
}

impl RankPlan {
    /// Freeze the current ownership into a plan. `owners` has one rank
    /// per `topo.leaves` entry (the usual `mesh.elem(id).owner` scan).
    pub fn build(
        mesh: &TetMesh,
        topo: &LeafTopology,
        dof: &DofMap,
        owners: &[u16],
        nranks: usize,
    ) -> Self {
        assert_eq!(owners.len(), topo.n_leaves(), "owners/topology mismatch");
        assert!(nranks >= 1, "need at least one rank");
        let mut elems: Vec<Vec<u32>> = vec![Vec::new(); nranks];
        for (i, &r) in owners.iter().enumerate() {
            assert!((r as usize) < nranks, "owner {r} >= nranks {nranks}");
            elems[r as usize].push(i as u32);
        }
        // first-seen leaf owner wins the row: deterministic in the
        // leaf order, independent of execution
        let mut rank_of_dof = vec![u16::MAX; dof.n_dofs];
        for (i, &id) in topo.leaves.iter().enumerate() {
            for &v in &mesh.verts_of(id) {
                let d = dof.dof_of_vertex[v as usize] as usize;
                if rank_of_dof[d] == u16::MAX {
                    rank_of_dof[d] = owners[i];
                }
            }
        }
        let mut rows: Vec<Vec<u32>> = vec![Vec::new(); nranks];
        for (d, &r) in rank_of_dof.iter().enumerate() {
            debug_assert!(r != u16::MAX, "dof {d} touched by no leaf");
            rows[r as usize].push(d as u32);
        }
        // interior/boundary split: a leaf whose four dofs straddle
        // ranks makes all four of them boundary (each then has an
        // off-rank column in its matrix row); a leaf on one rank
        // contributes only same-rank columns
        let mut is_boundary = vec![false; dof.n_dofs];
        for &id in &topo.leaves {
            let v = mesh.verts_of(id);
            let d = v.map(|v| dof.dof_of_vertex[v as usize] as usize);
            let r0 = rank_of_dof[d[0]];
            if d.iter().any(|&di| rank_of_dof[di] != r0) {
                for &di in &d {
                    is_boundary[di] = true;
                }
            }
        }
        let mut interior: Vec<Vec<u32>> = vec![Vec::new(); nranks];
        let mut boundary: Vec<Vec<u32>> = vec![Vec::new(); nranks];
        for (r, rs) in rows.iter().enumerate() {
            for &d in rs {
                if is_boundary[d as usize] {
                    boundary[r].push(d);
                } else {
                    interior[r].push(d);
                }
            }
        }
        Self {
            nranks,
            elems,
            rank_of_dof,
            rows,
            interior,
            boundary,
        }
    }

    /// One-rank plan owning everything: the serial setup unit tests
    /// and single-process tools use.
    pub fn serial(mesh: &TetMesh, topo: &LeafTopology, dof: &DofMap) -> Self {
        let owners = vec![0u16; topo.n_leaves()];
        Self::build(mesh, topo, dof, &owners, 1)
    }

    /// Total dofs covered by the row ownership (sanity: equals the
    /// dof count).
    pub fn n_rows(&self) -> usize {
        self.rows.iter().map(|r| r.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Distribution;
    use crate::mesh::generator;

    fn setup(nparts: usize) -> (TetMesh, LeafTopology, DofMap, Vec<u16>) {
        let mut mesh = generator::cube_mesh(2);
        mesh.refine(&mesh.leaves_unordered());
        let leaves = mesh.leaves_unordered();
        Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
        let topo = LeafTopology::build(&mesh);
        let dof = DofMap::build(&mesh, &topo);
        let owners: Vec<u16> = topo.leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        (mesh, topo, dof, owners)
    }

    #[test]
    fn plan_partitions_elements_and_rows() {
        let (mesh, topo, dof, owners) = setup(4);
        let plan = RankPlan::build(&mesh, &topo, &dof, &owners, 4);
        let total_elems: usize = plan.elems.iter().map(|e| e.len()).sum();
        assert_eq!(total_elems, topo.n_leaves());
        assert_eq!(plan.n_rows(), dof.n_dofs);
        // each dof owned exactly once, by the rank its list says
        for (r, rows) in plan.rows.iter().enumerate() {
            for &d in rows {
                assert_eq!(plan.rank_of_dof[d as usize] as usize, r);
            }
        }
        // lists are ascending (the deterministic-arithmetic invariant)
        for lists in [&plan.elems, &plan.rows] {
            for l in lists.iter() {
                for w in l.windows(2) {
                    assert!(w[0] < w[1], "per-rank list not ascending");
                }
            }
        }
    }

    #[test]
    fn row_owner_touches_the_row() {
        // the owning rank of a dof must own at least one element
        // containing that dof's vertex
        let (mesh, topo, dof, owners) = setup(5);
        let plan = RankPlan::build(&mesh, &topo, &dof, &owners, 5);
        for (d, &r) in plan.rank_of_dof.iter().enumerate() {
            let v = dof.vertex_of_dof[d];
            let touches = plan.elems[r as usize].iter().any(|&e| {
                mesh.elem(topo.leaves[e as usize]).verts.contains(&v)
            });
            assert!(touches, "rank {r} owns dof {d} but no element touching it");
        }
    }

    #[test]
    fn serial_plan_owns_everything() {
        let (mesh, topo, dof, _) = setup(3);
        let plan = RankPlan::serial(&mesh, &topo, &dof);
        assert_eq!(plan.nranks, 1);
        assert_eq!(plan.elems[0].len(), topo.n_leaves());
        assert_eq!(plan.rows[0].len(), dof.n_dofs);
        // one rank: nothing straddles, every row is interior
        assert_eq!(plan.interior[0].len(), dof.n_dofs);
        assert!(plan.boundary[0].is_empty());
    }

    #[test]
    fn interior_boundary_split_partitions_rows() {
        let (mesh, topo, dof, owners) = setup(4);
        let plan = RankPlan::build(&mesh, &topo, &dof, &owners, 4);
        for r in 0..4 {
            // disjoint union, order preserved: merging the two
            // ascending lists reproduces rows[r]
            let mut merged: Vec<u32> = plan.interior[r]
                .iter()
                .chain(&plan.boundary[r])
                .copied()
                .collect();
            merged.sort_unstable();
            assert_eq!(merged, plan.rows[r]);
            for l in [&plan.interior[r], &plan.boundary[r]] {
                for w in l.windows(2) {
                    assert!(w[0] < w[1], "split list not ascending");
                }
            }
        }
        // a 4-way block partition of a refined cube has both kinds
        let ni: usize = plan.interior.iter().map(|l| l.len()).sum();
        let nb: usize = plan.boundary.iter().map(|l| l.len()).sum();
        assert_eq!(ni + nb, dof.n_dofs);
        assert!(nb > 0, "expected straddling rows");
        assert!(ni > 0, "expected interior rows");
    }

    #[test]
    fn interior_rows_have_only_same_rank_columns() {
        // cross-check against the assembled matrix: interior rows
        // must not reference an off-rank dof, boundary rows must
        let (mesh, topo, dof, owners) = setup(3);
        let plan = RankPlan::build(&mesh, &topo, &dof, &owners, 3);
        let src = vec![1.0; dof.n_dofs];
        let asm = crate::fem::assemble(&mesh, &topo, &dof, &src, None);
        for r in 0..3 {
            for &d in &plan.interior[r] {
                let (cols, _) = asm.k.row(d as usize);
                for &c in cols {
                    assert_eq!(
                        plan.rank_of_dof[c as usize] as usize, r,
                        "interior row {d} of rank {r} has off-rank column {c}"
                    );
                }
            }
            for &d in &plan.boundary[r] {
                let (cols, _) = asm.k.row(d as usize);
                assert!(
                    cols.iter().any(|&c| plan.rank_of_dof[c as usize] as usize != r),
                    "boundary row {d} of rank {r} has no off-rank column"
                );
            }
        }
    }
}
