//! The dof-level ghost layer of a rank plan: exactly which off-rank
//! values each rank's SpMV reads, and therefore exactly what the
//! threaded executor's halo exchange moves (DESIGN.md §9).
//!
//! Built from the assembled matrix pattern: rank `r`'s ghost set is
//! the set of columns of its owned rows that another rank owns. This
//! is the dof-granularity refinement of [`crate::dist::Halo`]: every
//! face-adjacent rank pair of the face halo also couples through
//! shared P1 vertices here (plus the vertex/edge-adjacent pairs the
//! face count cannot see), so the same partition quality that the
//! alpha-beta model prices is what the threaded executor physically
//! pays per CG iteration.

use crate::fem::Csr;
use crate::util::hash::FxHashSet;
use std::collections::BTreeMap;

use super::plan::RankPlan;

/// One direction of the halo: for each rank, its neighbour ranks
/// (ascending) and the ascending dof list exchanged with each.
pub type HaloLists = Vec<Vec<(u16, Vec<u32>)>>;

/// The exchange pattern of one (plan, matrix) pair.
#[derive(Debug, Clone)]
pub struct GhostPlan {
    /// Per rank: (owner rank, dofs to receive from it) -- the rank's
    /// ghost values, grouped by who sends them.
    pub recv: HaloLists,
    /// Per rank: (destination rank, owned dofs to send to it) -- the
    /// exact transpose of `recv`.
    pub send: HaloLists,
}

impl GhostPlan {
    /// Scan the owned rows' columns of `a` and group every off-rank
    /// column by its owner.
    pub fn build(plan: &RankPlan, a: &Csr) -> Self {
        let p = plan.nranks;
        let mut recv_maps: Vec<BTreeMap<u16, Vec<u32>>> = vec![BTreeMap::new(); p];
        for (r, rows) in plan.rows.iter().enumerate() {
            let mut seen: FxHashSet<u32> = FxHashSet::default();
            for &i in rows {
                let (cols, _) = a.row(i as usize);
                for &c in cols {
                    let owner = plan.rank_of_dof[c as usize];
                    if owner as usize != r && seen.insert(c) {
                        recv_maps[r].entry(owner).or_default().push(c);
                    }
                }
            }
        }
        let mut send_maps: Vec<BTreeMap<u16, Vec<u32>>> = vec![BTreeMap::new(); p];
        let mut recv: HaloLists = Vec::with_capacity(p);
        for (r, map) in recv_maps.into_iter().enumerate() {
            let mut lists = Vec::with_capacity(map.len());
            for (owner, mut dofs) in map {
                dofs.sort_unstable();
                send_maps[owner as usize].insert(r as u16, dofs.clone());
                lists.push((owner, dofs));
            }
            recv.push(lists);
        }
        let send: HaloLists = send_maps
            .into_iter()
            .map(|m| m.into_iter().collect())
            .collect();
        Self { recv, send }
    }

    /// Unordered neighbour rank pairs that exchange anything.
    pub fn neighbor_pairs(&self) -> FxHashSet<(u16, u16)> {
        let mut pairs = FxHashSet::default();
        for (r, lists) in self.recv.iter().enumerate() {
            for (s, _) in lists {
                let r = r as u16;
                pairs.insert((r.min(*s), r.max(*s)));
            }
        }
        pairs
    }

    /// Directed messages per halo update (one per (sender, receiver)
    /// pair with a non-empty list).
    pub fn messages_per_update(&self) -> usize {
        self.send.iter().map(|l| l.len()).sum()
    }

    /// f64 payload bytes moved per halo update, all ranks.
    pub fn bytes_per_update(&self) -> usize {
        8 * self
            .send
            .iter()
            .map(|l| l.iter().map(|(_, d)| d.len()).sum::<usize>())
            .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Distribution, Halo};
    use crate::fem::{assemble, DofMap};
    use crate::mesh::generator;
    use crate::mesh::topology::LeafTopology;

    fn setup(nparts: usize) -> (RankPlan, Csr, Halo) {
        let mut mesh = generator::cube_mesh(2);
        mesh.refine(&mesh.leaves_unordered());
        let leaves = mesh.leaves_unordered();
        Distribution::new(nparts).assign_blocks(&mut mesh, &leaves);
        let topo = LeafTopology::build(&mesh);
        let dof = DofMap::build(&mesh, &topo);
        let owners: Vec<u16> = topo.leaves.iter().map(|&id| mesh.elem(id).owner).collect();
        let plan = RankPlan::build(&mesh, &topo, &dof, &owners, nparts);
        let src = vec![0.0; dof.n_dofs];
        let sys = assemble(&mesh, &topo, &dof, &src, None);
        let halo = Halo::build(&mesh, &topo, &owners, nparts);
        (plan, sys.k, halo)
    }

    #[test]
    fn send_is_the_transpose_of_recv() {
        let (plan, a, _) = setup(4);
        let g = GhostPlan::build(&plan, &a);
        for (r, lists) in g.recv.iter().enumerate() {
            for (s, dofs) in lists {
                let back = g.send[*s as usize]
                    .iter()
                    .find(|(d, _)| *d as usize == r)
                    .expect("send list missing");
                assert_eq!(&back.1, dofs, "send/recv lists disagree {s}->{r}");
                // received dofs really are owned by the sender
                for &d in dofs {
                    assert_eq!(plan.rank_of_dof[d as usize], *s);
                }
            }
        }
        assert_eq!(
            g.messages_per_update(),
            g.recv.iter().map(|l| l.len()).sum::<usize>()
        );
        assert!(g.bytes_per_update() > 0);
    }

    #[test]
    fn ghosts_cover_every_off_rank_column() {
        let (plan, a, _) = setup(3);
        let g = GhostPlan::build(&plan, &a);
        for (r, rows) in plan.rows.iter().enumerate() {
            let ghosts: FxHashSet<u32> = g.recv[r]
                .iter()
                .flat_map(|(_, d)| d.iter().copied())
                .collect();
            for &i in rows {
                let (cols, _) = a.row(i as usize);
                for &c in cols {
                    if plan.rank_of_dof[c as usize] as usize != r {
                        assert!(ghosts.contains(&c), "rank {r} misses ghost dof {c}");
                    }
                }
            }
        }
    }

    #[test]
    fn face_halo_pairs_are_dof_halo_pairs() {
        // every face-adjacent rank pair of dist::Halo must also couple
        // at the dof level (faces share 3 vertices); the dof halo may
        // add vertex/edge-adjacent pairs on top
        let (plan, a, halo) = setup(5);
        let g = GhostPlan::build(&plan, &a);
        let pairs = g.neighbor_pairs();
        for (&(lo, hi), &faces) in &halo.faces_between {
            assert!(faces > 0);
            assert!(
                pairs.contains(&(lo, hi)),
                "face-halo pair ({lo},{hi}) missing from the dof halo"
            );
        }
    }

    #[test]
    fn single_rank_has_no_ghosts() {
        let (plan, a, _) = setup(1);
        let g = GhostPlan::build(&plan, &a);
        assert_eq!(g.messages_per_update(), 0);
        assert_eq!(g.bytes_per_update(), 0);
        assert!(g.recv[0].is_empty() && g.send[0].is_empty());
    }
}
